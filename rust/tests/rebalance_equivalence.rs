//! Rebalancing equivalence & replay: elastic shard rebalancing must be
//! invisible when off (or quiet) and deterministic when it fires.
//!
//! Layers of pinning:
//!
//! 1. **Off-path invisibility** — with `--rebalance off` the pooled
//!    engine's virtual-clock CSV traces are byte-identical to a serial
//!    static-placement reference engine (the pre-rebalancer contract),
//!    with and without a `slow:` scenario attached.
//! 2. **Quiet-trigger invisibility** — a rebalancer that is attached but
//!    whose threshold never fires produces bytes identical to no
//!    rebalancer at all: observation plumbing alone must not perturb a
//!    run.
//! 3. **Replay determinism** — under `slow:`/`rack:` scenarios the
//!    migration schedule and the full trace are reproduced exactly by a
//!    second run, and by a run whose scenario went through the JSON
//!    surface (`Scenario::to_json` → `Json::parse` →
//!    `Scenario::from_json`) instead of the DSL.
//! 4. **Acceptance** — on the `slow:2@5`-style and `rack:` scenarios the
//!    rebalanced coded run finishes at strictly lower virtual wall-clock
//!    than static placement at (near-)equal final suboptimality, and the
//!    `migrate:FROM>TO:ROWS` labels land in the CSV events cell.
//! 5. **Zero-respawn handoff** — shard migration reuses the resident
//!    lane threads; the pool's spawn count is frozen across moves.

use anyhow::Result;
use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel, Scenario};
use codedopt::config::Json;
use codedopt::encoding::EncoderKind;
use codedopt::linalg::{self, DataMat, StorageKind};
use codedopt::optim::{
    CodedFista, CodedGd, CodedLbfgs, FistaConfig, GdConfig, LbfgsConfig, Optimizer, Prox,
    RunOutput,
};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::runtime::{ComputeEngine, NativeEngine, RebalanceConfig};

// ------------------------------------------------------------ reference

/// Serial static-placement reference engine (same shape as the one in
/// `pool_equivalence.rs`): the exact per-worker fused kernels, no
/// `session` — so it *cannot* host a rebalancer, which is precisely what
/// makes it the anchor for the `--rebalance off` pre-PR trace.
struct RefSlot {
    x: DataMat,
    y: Vec<f64>,
    grad_buf: Vec<f64>,
    resid_buf: Vec<f64>,
}

struct StaticRefEngine {
    slots: Vec<RefSlot>,
}

impl StaticRefEngine {
    fn new(prob: &EncodedProblem) -> Self {
        let p = prob.p();
        StaticRefEngine {
            slots: prob
                .shards
                .iter()
                .map(|s| RefSlot {
                    x: s.x.clone(),
                    y: s.y.clone(),
                    grad_buf: vec![0.0; p],
                    resid_buf: vec![0.0; s.x.rows()],
                })
                .collect(),
        }
    }
}

impl ComputeEngine for StaticRefEngine {
    fn name(&self) -> &'static str {
        "static-reference"
    }

    fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let s = &mut self.slots[worker];
        let f = s.x.fused_grad(w, &s.y, &mut s.grad_buf, &mut s.resid_buf);
        Ok((s.grad_buf.clone(), f))
    }

    fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64> {
        let s = &mut self.slots[worker];
        s.x.gemv_into(d, &mut s.resid_buf);
        Ok(linalg::dot(&s.resid_buf, &s.resid_buf))
    }

    fn worker_grad_batch(
        &mut self,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        let s = &mut self.slots[worker];
        s.grad_buf.fill(0.0);
        let mut f = 0.0;
        for &(lo, hi) in segs {
            f += s.x.fused_grad_range(w, &s.y, &mut s.grad_buf, &mut s.resid_buf, lo, hi);
        }
        Ok((s.grad_buf.clone(), f))
    }

    fn workers(&self) -> usize {
        self.slots.len()
    }
}

// ------------------------------------------------------------- fixtures

/// The golden workload: ridge n=96 p=8, Hadamard β=2 over m=8 workers →
/// 24 encoded rows per shard (dense pad bucket 32).
fn fixture() -> EncodedProblem {
    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    EncodedProblem::encode_stored(&prob, EncoderKind::Hadamard, 2.0, 8, 3, StorageKind::Dense)
        .expect("encode")
}

fn cluster_over(
    enc: &EncodedProblem,
    engine: Box<dyn ComputeEngine>,
    wait_for: usize,
    delay: DelayModel,
) -> Cluster {
    let cfg = ClusterConfig {
        workers: 8,
        wait_for,
        delay,
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 11,
    };
    Cluster::new(enc, engine, cfg).expect("cluster")
}

const ITERS: usize = 20;

fn run_optimizer(opt: &str, enc: &EncodedProblem, cluster: &mut Cluster, iters: usize) -> RunOutput {
    match opt {
        "gd" => CodedGd::new(GdConfig { zeta: 0.5, epsilon: Some(0.3), ..Default::default() })
            .run(enc, cluster, iters)
            .expect("gd run"),
        "lbfgs" => CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.3), ..Default::default() })
            .run(enc, cluster, iters)
            .expect("lbfgs run"),
        "fista" => CodedFista::new(FistaConfig {
            prox: Prox::L1 { l1: 0.001 },
            epsilon: Some(0.3),
            ..Default::default()
        })
        .run(enc, cluster, iters)
        .expect("fista run"),
        other => panic!("unknown optimizer {other}"),
    }
}

/// One virtual-clock run on the pooled engine, optional scenario, with
/// the given rebalance policy (`None` = never call `set_rebalancer`,
/// i.e. the literal pre-PR code path).
fn pooled_run(
    opt: &str,
    scenario: Option<Scenario>,
    rebalance: Option<RebalanceConfig>,
    wait_for: usize,
    delay: DelayModel,
    iters: usize,
) -> RunOutput {
    let enc = fixture();
    let engine = Box::new(NativeEngine::new(&enc));
    let mut cluster = cluster_over(&enc, engine, wait_for, delay);
    if let Some(sc) = scenario {
        cluster.set_scenario(sc).unwrap();
    }
    if let Some(cfg) = rebalance {
        cluster.set_rebalancer(&enc, cfg).unwrap();
    }
    run_optimizer(opt, &enc, &mut cluster, iters)
}

fn migration_schedule(out: &RunOutput) -> Vec<(usize, String)> {
    out.trace
        .records
        .iter()
        .filter(|r| !r.migrations.is_empty())
        .map(|r| (r.iter, r.migrations.clone()))
        .collect()
}

// -------------------------------------------------- off-path invisibility

/// `--rebalance off` (no rebalancer attached) must equal the serial
/// static-placement engine byte for byte — quiet run and `slow:` run.
#[test]
fn rebalance_off_matches_static_reference_bitwise() {
    for scenario in [None, Some("slow:2:3@5")] {
        for opt in ["gd", "lbfgs", "fista"] {
            let serial = {
                let enc = fixture();
                let engine = Box::new(StaticRefEngine::new(&enc));
                let mut cluster =
                    cluster_over(&enc, engine, 6, DelayModel::Constant { ms: 2.0 });
                if let Some(dsl) = scenario {
                    cluster.set_scenario(Scenario::parse(dsl).unwrap()).unwrap();
                }
                run_optimizer(opt, &enc, &mut cluster, ITERS).trace.to_csv()
            };
            let pooled = pooled_run(
                opt,
                scenario.map(|d| Scenario::parse(d).unwrap()),
                None,
                6,
                DelayModel::Constant { ms: 2.0 },
                ITERS,
            )
            .trace
            .to_csv();
            let off = pooled_run(
                opt,
                scenario.map(|d| Scenario::parse(d).unwrap()),
                Some(RebalanceConfig::Off),
                6,
                DelayModel::Constant { ms: 2.0 },
                ITERS,
            )
            .trace
            .to_csv();
            assert_eq!(
                pooled, serial,
                "{opt}/{scenario:?}: pooled static trace drifted from the serial reference"
            );
            assert_eq!(
                off, serial,
                "{opt}/{scenario:?}: --rebalance off is not bitwise identical to static placement"
            );
            assert!(!off.contains("migrate:"), "{opt}: off-path trace carries migration labels");
        }
    }
}

/// A rebalancer that is attached but never fires (astronomical
/// threshold) must also be bitwise invisible: the observation plumbing
/// alone cannot perturb the RNG stream, the admitted sets, or a single
/// payload bit.
#[test]
fn quiet_trigger_matches_static_placement_bitwise() {
    let quiet = RebalanceConfig::Ewma { alpha: 0.5, threshold: 1e9 };
    for scenario in [None, Some("slow:2:3@5")] {
        for opt in ["gd", "lbfgs"] {
            let stat = pooled_run(
                opt,
                scenario.map(|d| Scenario::parse(d).unwrap()),
                None,
                6,
                DelayModel::Constant { ms: 2.0 },
                ITERS,
            );
            let reb = pooled_run(
                opt,
                scenario.map(|d| Scenario::parse(d).unwrap()),
                Some(quiet),
                6,
                DelayModel::Constant { ms: 2.0 },
                ITERS,
            );
            assert!(migration_schedule(&reb).is_empty(), "{opt}: quiet trigger migrated");
            assert_eq!(
                reb.trace.to_csv(),
                stat.trace.to_csv(),
                "{opt}/{scenario:?}: a quiet rebalancer perturbed the trace"
            );
        }
    }
}

// ---------------------------------------------------- replay determinism

/// With a `slow:` scenario, replaying the run from the DSL *and* from
/// the JSON surface reproduces the exact same migration schedule and the
/// exact same trace bytes — twice.
#[test]
fn dsl_and_json_replays_reproduce_the_migration_schedule() {
    let dsl = "slow:2:3@5";
    let policy = RebalanceConfig::Ewma { alpha: 1.0, threshold: 1.5 };
    let from_dsl = || Scenario::parse(dsl).unwrap();
    let from_json = || {
        let j = Json::parse(&Scenario::parse(dsl).unwrap().to_json()).unwrap();
        Scenario::from_json(&j).unwrap()
    };
    let run = |sc: Scenario| pooled_run("gd", Some(sc), Some(policy), 8, DelayModel::None, 40);

    let a = run(from_dsl());
    let b = run(from_dsl());
    let c = run(from_json());
    let d = run(from_json());

    let sched = migration_schedule(&a);
    assert!(!sched.is_empty(), "scenario never triggered a migration");
    assert!(
        sched[0].1.starts_with("migrate:2>"),
        "first move should shed rows off the scripted slow worker, got {:?}",
        sched[0]
    );
    for (label, out) in [("dsl replay", &b), ("json", &c), ("json replay", &d)] {
        assert_eq!(sched, migration_schedule(out), "{label}: migration schedule diverged");
        assert_eq!(a.trace.to_csv(), out.trace.to_csv(), "{label}: trace bytes diverged");
    }
}

// ------------------------------------------------------------ acceptance

fn beats_static(dsl: &str, wait_for: usize, delay: DelayModel) {
    let iters = 60;
    let policy = RebalanceConfig::Ewma { alpha: 1.0, threshold: 1.5 };
    let stat = pooled_run("gd", Some(Scenario::parse(dsl).unwrap()), None, wait_for, delay.clone(), iters);
    let reb =
        pooled_run("gd", Some(Scenario::parse(dsl).unwrap()), Some(policy), wait_for, delay, iters);

    assert!(migration_schedule(&stat).is_empty(), "{dsl}: static arm migrated");
    let sched = migration_schedule(&reb);
    assert!(!sched.is_empty(), "{dsl}: rebalancer never triggered");

    // the migration labels land in the CSV events cell
    let csv = reb.trace.to_csv();
    assert!(csv.contains("migrate:"), "{dsl}: CSV lost the migration labels");

    // strictly lower virtual wall-clock ...
    let (t_stat, t_reb) = (stat.trace.total_sim_ms(), reb.trace.total_sim_ms());
    assert!(
        t_reb < t_stat,
        "{dsl}: rebalanced {t_reb} ms !< static {t_stat} ms"
    );

    // ... at (near-)equal final suboptimality
    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    let f_star = prob.exact_solution().map(|w| prob.objective(&w)).expect("ridge is solvable");
    let gap_stat = stat.trace.last_objective() - f_star;
    let gap_reb = reb.trace.last_objective() - f_star;
    assert!(
        gap_reb <= gap_stat.abs() * 1.25 + 1e-9,
        "{dsl}: rebalanced gap {gap_reb:e} worse than static gap {gap_stat:e}"
    );
}

/// One worker turns 3× slow at round 5 with k = m (no first-k slack):
/// the planner sheds a band off it and the run finishes strictly sooner.
#[test]
fn rebalanced_beats_static_on_slow_worker() {
    beats_static("slow:2:3@5", 8, DelayModel::None);
}

/// A whole rack (workers 0–2) turns 4× slow at round 10 with k = 6: the
/// m − k = 2 admission slack cannot hide three stragglers, so only
/// migration recovers the round time.
#[test]
fn rebalanced_beats_static_on_slow_rack() {
    beats_static("rack:0-2:4@10", 6, DelayModel::Constant { ms: 2.0 });
}

// ------------------------------------------------------ zero-respawn

/// Shard handoff rides the resident lanes: across observed migrations
/// the pool's spawn count is frozen and nothing is parked.
#[test]
fn migrations_never_respawn_pool_threads() {
    let enc = fixture();
    let mut cluster =
        cluster_over(&enc, Box::new(NativeEngine::new(&enc)), 8, DelayModel::None);
    cluster.set_scenario(Scenario::parse("slow:2:3@0").unwrap()).unwrap();
    cluster
        .set_rebalancer(&enc, RebalanceConfig::Ewma { alpha: 1.0, threshold: 1.5 })
        .unwrap();
    let w = vec![0.1; 8];
    cluster.grad_round(&w).unwrap();
    let spawned = cluster.engine_session().expect("pooled session").spawn_count();
    assert!(spawned > 0);
    let mut moves = 0usize;
    for _ in 0..8 {
        let (_, round) = cluster.grad_round(&w).unwrap();
        moves += round.migrations.len();
    }
    assert!(moves > 0, "scripted slow worker never provoked a migration");
    assert_eq!(
        cluster.engine_session().unwrap().spawn_count(),
        spawned,
        "shard migration must never respawn lane threads"
    );
    assert_eq!(cluster.engine_session().unwrap().parked_count(), 0);
}
