//! Integration: the PJRT/XLA engine executes the AOT HLO artifacts and
//! matches the native Rust engine bit-for-bit-ish (f32 tolerance).
//!
//! This is the cross-layer correctness proof: Pallas kernel (L1) → JAX
//! graph (L2) → HLO text → PJRT executable → Rust coordinator (L3).
//! Requires `make artifacts` (skipped with a clear message otherwise).

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel};
use codedopt::encoding::EncoderKind;
use codedopt::optim::{CodedLbfgs, LbfgsConfig, Optimizer};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::runtime::{ComputeEngine, Manifest, NativeEngine, XlaEngine};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = codedopt::runtime::artifacts::default_dir();
    if Manifest::load(&dir).is_ok() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

/// p=64 matches the quickstart artifact bucket set.
fn test_problem(seed: u64) -> (QuadProblem, EncodedProblem) {
    let prob = QuadProblem::synthetic_gaussian(256, 64, 0.05, seed);
    let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, seed).unwrap();
    (prob, enc)
}

#[test]
fn xla_engine_matches_native_gradients() {
    let Some(dir) = artifacts_dir() else { return };
    let (_, enc) = test_problem(1);
    let mut native = NativeEngine::new(&enc);
    let mut xla = XlaEngine::new(&enc, dir).expect("XlaEngine init");
    let w: Vec<f64> = (0..64).map(|i| 0.01 * (i as f64 - 32.0)).collect();
    for worker in 0..8 {
        let (gn, fn_) = native.worker_grad(worker, &w).unwrap();
        let (gx, fx) = xla.worker_grad(worker, &w).unwrap();
        // f32 kernel vs f64 native: relative tolerance
        let scale = fn_.abs().max(1.0);
        assert!(
            (fn_ - fx).abs() / scale < 1e-4,
            "worker {worker}: f native {fn_} vs xla {fx}"
        );
        for (j, (a, b)) in gn.iter().zip(&gx).enumerate() {
            let s = a.abs().max(1.0);
            assert!(
                (a - b).abs() / s < 1e-3,
                "worker {worker} grad[{j}]: native {a} vs xla {b}"
            );
        }
    }
}

#[test]
fn xla_engine_matches_native_linesearch() {
    let Some(dir) = artifacts_dir() else { return };
    let (_, enc) = test_problem(2);
    let mut native = NativeEngine::new(&enc);
    let mut xla = XlaEngine::new(&enc, dir).expect("XlaEngine init");
    let d: Vec<f64> = (0..64).map(|i| ((i * 7 % 13) as f64 - 6.0) * 0.1).collect();
    for worker in 0..8 {
        let qn = native.linesearch(worker, &d).unwrap();
        let qx = xla.linesearch(worker, &d).unwrap();
        assert!(
            (qn - qx).abs() / qn.max(1.0) < 1e-4,
            "worker {worker}: q native {qn} vs xla {qx}"
        );
    }
}

#[test]
fn full_lbfgs_run_on_xla_engine_converges() {
    let Some(dir) = artifacts_dir() else { return };
    let (prob, enc) = test_problem(3);
    let engine = Box::new(XlaEngine::new(&enc, dir).expect("XlaEngine init"));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Exp { mean_ms: 10.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 3,
    };
    let mut cluster = Cluster::new(&enc, engine, cfg).unwrap();
    let lbfgs = CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.2), ..Default::default() });
    let out = lbfgs.run(&enc, &mut cluster, 30).unwrap();
    assert!(!out.trace.diverged(), "XLA-engine L-BFGS diverged");
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let f0 = prob.objective(&[0.0; 64]);
    let f_end = out.trace.best_objective();
    assert!(
        f_end - f_star < 0.15 * (f0 - f_star),
        "no convergence on XLA engine: end {f_end}, f* {f_star}, f0 {f0}"
    );
}

#[test]
fn xla_engine_fails_fast_on_missing_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    // p = 13 has no artifacts
    let prob = QuadProblem::synthetic_gaussian(64, 13, 0.0, 4);
    let enc = EncodedProblem::encode(&prob, EncoderKind::Gaussian, 2.0, 4, 4).unwrap();
    let err = match XlaEngine::new(&enc, dir) {
        Ok(_) => panic!("expected missing-shape error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("artifact"), "unexpected error: {err}");
}
