//! Property tests for every CLI/config grammar: `DelayModel`,
//! `LrSchedule`, `GradMode`, `RebalanceConfig`, `ServePolicy`,
//! `TemporalScheme`, and
//! the fault-scenario DSL all promise `parse(x.to_string()) == x` (the
//! config/JSON round-trip contract) and strict rejection of malformed
//! input — plus a scheduler-fairness property for the serve scheduler.
//! Driven by the seeded `testutil::property` harness, so every failure
//! reports a reproducible case seed.

use codedopt::cluster::{AdmitPolicy, DelayModel, FaultEvent, Scenario};
use codedopt::encoding::temporal::TemporalScheme;
use codedopt::linalg::GradMode;
use codedopt::optim::LrSchedule;
use codedopt::rng::Pcg64;
use codedopt::runtime::{RebalanceConfig, SchedJob, Scheduler, ServePolicy};
use codedopt::testutil::{gen_range, property};

fn any_positive(rng: &mut Pcg64) -> f64 {
    // spans magnitudes and fractional digits; Display/parse of f64 is
    // shortest-round-trip in Rust, so any finite positive value must
    // survive the grammar round trip
    rng.range_f64(1e-3, 1e3) * 10f64.powi(gen_range(rng, 0, 4) as i32 - 2)
}

fn any_delay_model(rng: &mut Pcg64) -> DelayModel {
    match gen_range(rng, 0, 6) {
        0 => DelayModel::None,
        1 => DelayModel::Constant { ms: any_positive(rng) },
        2 => DelayModel::Exp { mean_ms: any_positive(rng) },
        3 => DelayModel::ShiftedExp { shift_ms: any_positive(rng), mean_ms: any_positive(rng) },
        4 => DelayModel::Pareto { scale_ms: any_positive(rng), shape: any_positive(rng) },
        5 => DelayModel::ExpWithFailures {
            mean_ms: any_positive(rng),
            p_fail: rng.range_f64(0.0, 1.0),
        },
        _ => DelayModel::HeteroExp {
            mean_ms: any_positive(rng),
            factors: (0..gen_range(rng, 1, 5)).map(|_| any_positive(rng)).collect(),
        },
    }
}

#[test]
fn delay_model_grammar_round_trips_every_variant() {
    property("delay model parse<->Display", 200, |rng| {
        let model = any_delay_model(rng);
        let text = model.to_string();
        let back = DelayModel::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} failed: {e}"));
        assert_eq!(back, model, "round trip drifted for {text:?}");
    });
}

#[test]
fn delay_model_rejects_malformed_grammar() {
    // wrong arity (both directions), bad numbers, unknown heads
    for bad in [
        "", ":", "exp", "exp:", "exp:abc", "exp:10:99", "none:1", "const", "const:3:4",
        "shifted:5", "shifted:5:10:15", "pareto:2", "pareto:2:1.5:9", "expfail:10",
        "expfail:10:0.05:1", "hetero", "hetero:10", "hetero:10:", "hetero:10:1,x",
        "hetero:10:1,2:3", "uniform:1:2", "exp:10,5",
    ] {
        assert!(DelayModel::parse(bad).is_err(), "should reject {bad:?}");
    }
}

fn any_lr_schedule(rng: &mut Pcg64) -> LrSchedule {
    match gen_range(rng, 0, 2) {
        0 => LrSchedule::Constant,
        1 => LrSchedule::InvT { t0: any_positive(rng) },
        _ => LrSchedule::Cosine { period: gen_range(rng, 1, 100_000) },
    }
}

#[test]
fn lr_schedule_grammar_round_trips_every_variant() {
    property("lr schedule parse<->Display", 200, |rng| {
        let sched = any_lr_schedule(rng);
        let text = sched.to_string();
        let back = LrSchedule::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} failed: {e}"));
        assert_eq!(back, sched, "round trip drifted for {text:?}");
    });
}

#[test]
fn lr_schedule_rejects_malformed_grammar() {
    for bad in [
        "", ":", "cosine", "cosine:0", "cosine:-1", "cosine:2.5", "cosine:abc",
        "cosine:10:20", "invt:0", "invt:-3", "invt:abc", "invt:1:2", "constant:1",
        "const:1", "warp", "warp:9", "1/t:0",
    ] {
        assert!(LrSchedule::parse(bad).is_err(), "should reject {bad:?}");
    }
}

fn any_grad_mode(rng: &mut Pcg64) -> GradMode {
    match gen_range(rng, 0, 2) {
        0 => GradMode::Gemv,
        1 => GradMode::Gram,
        _ => GradMode::Auto,
    }
}

#[test]
fn grad_mode_grammar_round_trips_every_variant() {
    property("grad mode parse<->Display", 60, |rng| {
        let mode = any_grad_mode(rng);
        let text = mode.to_string();
        let back = GradMode::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} failed: {e}"));
        assert_eq!(back, mode, "round trip drifted for {text:?}");
        // labels are case-insensitive on input, canonical on output
        let upper = GradMode::parse(&text.to_ascii_uppercase())
            .unwrap_or_else(|e| panic!("uppercase reparse of {text:?} failed: {e}"));
        assert_eq!(upper, mode);
        assert_eq!(mode.label(), text);
    });
}

#[test]
fn grad_mode_rejects_malformed_grammar() {
    for bad in [
        "", " ", "gem", "gemv ", " gram", "grams", "auto:1", "gemv|gram", "hessian", "g",
        "full", "cache",
    ] {
        assert!(GradMode::parse(bad).is_err(), "should reject {bad:?}");
    }
}

fn any_rebalance(rng: &mut Pcg64) -> RebalanceConfig {
    match gen_range(rng, 0, 1) {
        0 => RebalanceConfig::Off,
        _ => RebalanceConfig::Ewma {
            // the validated domain: α ∈ (0, 1], threshold ≥ 1
            alpha: rng.range_f64(1e-6, 1.0),
            threshold: 1.0 + any_positive(rng),
        },
    }
}

#[test]
fn rebalance_grammar_round_trips_every_variant() {
    property("rebalance parse<->Display", 200, |rng| {
        let cfg = any_rebalance(rng);
        let text = cfg.to_string();
        let back = RebalanceConfig::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} failed: {e}"));
        assert_eq!(back, cfg, "round trip drifted for {text:?}");
    });
}

#[test]
fn rebalance_grammar_rejects_malformed() {
    // wrong arity (both directions, exactly like `DelayModel::parse`),
    // out-of-domain numerics, unknown heads
    for bad in [
        "", ":", "on", "off:1", "ewma", "ewma:", "ewma:0.5", "ewma:0.5:",
        "ewma:0.5:2:9", "ewma:abc:2", "ewma:0.5:abc", "ewma:0:2", "ewma:1.5:2",
        "ewma:0.5:0.5", "ewma:-0.1:2", "ewma:0.5:-3", "ewma:nan:2", "ewma:0.5:inf",
        "ewma:0.5,2", "greedy:0.5:2",
    ] {
        assert!(RebalanceConfig::parse(bad).is_err(), "should reject {bad:?}");
    }
}

fn any_serve_policy(rng: &mut Pcg64) -> ServePolicy {
    match gen_range(rng, 0, 2) {
        0 => ServePolicy::Fifo,
        1 => ServePolicy::Fair,
        _ => ServePolicy::Priority { classes: gen_range(rng, 1, 64) },
    }
}

#[test]
fn serve_policy_grammar_round_trips_every_variant() {
    property("serve policy parse<->Display", 200, |rng| {
        let policy = any_serve_policy(rng);
        let text = policy.to_string();
        let back = ServePolicy::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} failed: {e}"));
        assert_eq!(back, policy, "round trip drifted for {text:?}");
    });
}

#[test]
fn serve_policy_rejects_malformed_grammar() {
    // wrong arity (both directions), bad/zero class counts, unknown heads
    for bad in [
        "", ":", "fifo:", "fifo:1", "fair:", "fair:2", "priority", "priority:",
        "priority:0", "priority:-1", "priority:abc", "priority:1.5", "priority:2:3",
        "priority:2,3", "rr", "prio:2", "first-come", "fifo fair",
    ] {
        assert!(ServePolicy::parse(bad).is_err(), "should reject {bad:?}");
    }
}

/// Fair-share fairness: whenever the scheduler picks a job, that job is
/// at most one dispatched round ahead of every other still-active job —
/// no active job ever trails the leader by more than one full sweep.
#[test]
fn fair_scheduler_never_starves_an_active_job() {
    property("fair scheduler sweep bound", 200, |rng| {
        let n = gen_range(rng, 1, 8);
        let lens: Vec<usize> = (0..n).map(|_| gen_range(rng, 0, 12)).collect();
        let mut remaining = lens.clone();
        let mut counts = vec![0usize; n];
        let mut sched = Scheduler::new(ServePolicy::Fair);
        loop {
            let view: Vec<SchedJob> =
                remaining.iter().map(|&r| SchedJob { done: r == 0, class: 0 }).collect();
            let Some(i) = sched.next(&view) else { break };
            counts[i] += 1;
            remaining[i] -= 1;
            for (j, &r) in remaining.iter().enumerate() {
                if r > 0 {
                    assert!(
                        counts[i] <= counts[j] + 1,
                        "job {i} ran {} rounds while active job {j} has {} (lens {lens:?})",
                        counts[i],
                        counts[j]
                    );
                }
            }
        }
        assert_eq!(counts, lens, "every job must run exactly its round budget");
    });
}

fn any_temporal_scheme(rng: &mut Pcg64) -> TemporalScheme {
    match gen_range(rng, 0, 2) {
        0 => TemporalScheme::None,
        1 => {
            // the validated domain: window ≥ 1, 1 ≤ burst ≤ window
            let window = gen_range(rng, 1, 16);
            TemporalScheme::Seq { window, burst: gen_range(rng, 1, window) }
        }
        // q ∈ (0, 1]; Display/parse of f64 is shortest-round-trip
        _ => TemporalScheme::Stoch { q: rng.range_f64(1e-6, 1.0) },
    }
}

#[test]
fn temporal_scheme_grammar_round_trips_every_variant() {
    property("temporal scheme parse<->Display", 200, |rng| {
        let scheme = any_temporal_scheme(rng);
        let text = scheme.to_string();
        let back = TemporalScheme::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} failed: {e}"));
        assert_eq!(back, scheme, "round trip drifted for {text:?}");
    });
}

#[test]
fn temporal_scheme_rejects_malformed_grammar() {
    // wrong arity (both directions), out-of-domain numerics, unknown heads
    for bad in [
        "", ":", "none:1", "seq", "seq:", "seq:4", "seq:4:", "seq:4:2:1", "seq:0:1",
        "seq:4:0", "seq:2:3", "seq:abc:1", "seq:4:abc", "seq:4,2", "stoch", "stoch:",
        "stoch:0", "stoch:1.5", "stoch:-0.5", "stoch:nan", "stoch:inf", "stoch:abc",
        "stoch:0.5:1", "burst:3", "window:4:2",
    ] {
        assert!(TemporalScheme::parse(bad).is_err(), "should reject {bad:?}");
    }
}

fn any_event(rng: &mut Pcg64) -> FaultEvent {
    let worker = gen_range(rng, 0, 31);
    let round = gen_range(rng, 0, 10_000) as u64;
    match gen_range(rng, 0, 5) {
        0 => FaultEvent::Crash { worker, round },
        1 => FaultEvent::Recover { worker, round },
        2 => FaultEvent::Leave { worker, round },
        3 => FaultEvent::Join { worker, round },
        4 => FaultEvent::Slow { worker, factor: any_positive(rng), round },
        _ => {
            let lo = gen_range(rng, 0, 15);
            FaultEvent::Rack {
                lo,
                hi: gen_range(rng, lo, 31),
                factor: any_positive(rng),
                round,
            }
        }
    }
}

fn any_admit(rng: &mut Pcg64) -> AdmitPolicy {
    let set = |rng: &mut Pcg64| -> Vec<usize> {
        // distinct ids (validation rejects duplicates; the grammar itself
        // round-trips any list, distinct keeps the scenario attachable)
        let mut ids: Vec<usize> = (0..32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(gen_range(rng, 1, 6));
        ids
    };
    match gen_range(rng, 0, 4) {
        0 => AdmitPolicy::FirstK,
        1 => AdmitPolicy::Rotate {
            k: if gen_range(rng, 0, 1) == 0 { None } else { Some(gen_range(rng, 1, 32)) },
        },
        2 => AdmitPolicy::Fixed { workers: set(rng) },
        _ => AdmitPolicy::Cycle { sets: (0..gen_range(rng, 1, 4)).map(|_| set(rng)).collect() },
    }
}

#[test]
fn scenario_dsl_round_trips_generated_scenarios() {
    property("scenario parse<->Display", 300, |rng| {
        let mut sc = Scenario {
            events: (0..gen_range(rng, 0, 6)).map(|_| any_event(rng)).collect(),
            admit: any_admit(rng),
        };
        if sc.events.is_empty() && sc.admit == AdmitPolicy::FirstK {
            // the empty scenario has no DSL form (parse rejects "")
            sc.admit = AdmitPolicy::Rotate { k: None };
        }
        let text = sc.to_string();
        let back = Scenario::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} failed: {e}"));
        assert_eq!(back, sc, "round trip drifted for {text:?}");
    });
}

#[test]
fn scenario_json_round_trips_generated_scenarios() {
    use codedopt::config::Json;
    property("scenario to_json<->from_json", 200, |rng| {
        let sc = Scenario {
            events: (0..gen_range(rng, 0, 6)).map(|_| any_event(rng)).collect(),
            admit: any_admit(rng),
        };
        let text = sc.to_json();
        let parsed = Json::parse(&text)
            .unwrap_or_else(|e| panic!("to_json emitted invalid JSON {text:?}: {e}"));
        let back = Scenario::from_json(&parsed)
            .unwrap_or_else(|e| panic!("from_json of {text:?} failed: {e}"));
        assert_eq!(back, sc, "json round trip drifted for {text:?}");
    });
}

#[test]
fn scenario_dsl_rejects_malformed_grammar() {
    for bad in [
        "", ";", ",", "crash:1@2,", ",crash:1@2", "crash:1@2;;admit:rotate:k",
        "admit:rotate:k;admit:rotate:k", "admit:", "admit:rotate", "admit:fixed:",
        "admit:fixed:1..2", "admit:cycle:1//2", "crash:1", "crash:1@", "slow:1@4",
        "rack:1:2@3", "melt:1@2", "crash:1@2 recover:1@3",
    ] {
        assert!(Scenario::parse(bad).is_err(), "should reject {bad:?}");
    }
}

/// Generated scenarios that validation accepts attach to a matching
/// cluster-sized worker count; oversized references are refused.
#[test]
fn scenario_validation_tracks_worker_bounds() {
    property("scenario validate bounds", 100, |rng| {
        let sc = Scenario {
            events: (0..gen_range(rng, 1, 4)).map(|_| any_event(rng)).collect(),
            admit: AdmitPolicy::FirstK,
        };
        // every generated id is < 32, so m = 32 always validates...
        sc.validate(32).unwrap();
        // ...and the tightest failing bound is exactly the max referenced id
        let max_ref = sc
            .events
            .iter()
            .map(|e| match *e {
                FaultEvent::Crash { worker, .. }
                | FaultEvent::Recover { worker, .. }
                | FaultEvent::Leave { worker, .. }
                | FaultEvent::Join { worker, .. }
                | FaultEvent::Slow { worker, .. } => worker,
                FaultEvent::Rack { hi, .. } => hi,
            })
            .max()
            .unwrap();
        assert!(sc.validate(max_ref).is_err());
        sc.validate(max_ref + 1).unwrap();
    });
}
