//! Pipelined-round equivalence pinning for `runtime::temporal`.
//!
//! The contract the tentpole rests on: pipelining is a *latency* change,
//! never a *numerics* change.
//!
//! 1. **Depth 1 is the serial loop** — `run_pipelined(.., depth = 1)`
//!    reproduces `Optimizer::run` bit for bit (final iterate and full CSV
//!    trace) for gd/lbfgs/sgd across hadamard, replication, and uncoded
//!    encodings, with and without an adversarial `admit:rotate:k`
//!    scenario in the loop.
//! 2. **Virtual-clock depth invariance** — under `ClockMode::Virtual` the
//!    simulated clock stays serial at any depth, so depths 2 and 4 must
//!    replay the depth-1 trace byte for byte. Any drift means pipeline
//!    state (deferred acks, reorder window, scenario RNG) leaked into the
//!    numerics.
//! 3. **Temporal schemes ride the same rails** — `seq:W:B` and `stoch:Q`
//!    encodings run under the pipelined stepper with the same depth
//!    invariance, and descend on the true objective.
//! 4. **Determinism** — a pipelined run replays itself exactly.

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel, Scenario};
use codedopt::encoding::temporal::TemporalScheme;
use codedopt::encoding::EncoderKind;
use codedopt::linalg::StorageKind;
use codedopt::optim::{
    CodedGd, CodedLbfgs, CodedSgd, GdConfig, LbfgsConfig, LrSchedule, RunOutput, SgdConfig,
    SteppedOptimizer,
};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::runtime::{run_pipelined, NativeEngine};

const ITERS: usize = 12;

fn problem() -> QuadProblem {
    QuadProblem::synthetic_gaussian(96, 8, 0.05, 7)
}

fn encode(kind: EncoderKind, beta: f64) -> EncodedProblem {
    EncodedProblem::encode_stored(&problem(), kind, beta, 8, 3, StorageKind::Dense)
        .expect("encode")
}

fn encode_temporal(scheme: TemporalScheme) -> EncodedProblem {
    EncodedProblem::encode_temporal(&problem(), scheme, 8, 3).expect("encode temporal")
}

/// Fresh cluster per run: pipelining equivalence only holds when both
/// sides start from identical scenario/RNG state.
fn cluster(enc: &EncodedProblem, scenario: Option<&str>) -> Cluster {
    let eng = Box::new(NativeEngine::new(enc));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Constant { ms: 2.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 11,
    };
    let mut cluster = Cluster::new(enc, eng, cfg).expect("cluster");
    if let Some(dsl) = scenario {
        cluster.set_scenario(Scenario::parse(dsl).expect("scenario")).expect("set_scenario");
    }
    cluster
}

fn optimizer(name: &str) -> Box<dyn SteppedOptimizer> {
    match name {
        "gd" => Box::new(CodedGd::new(GdConfig {
            zeta: 0.5,
            epsilon: Some(0.3),
            ..Default::default()
        })),
        "lbfgs" => Box::new(CodedLbfgs::new(LbfgsConfig {
            epsilon: Some(0.3),
            ..Default::default()
        })),
        "sgd" => Box::new(CodedSgd::new(SgdConfig {
            lr: Some(0.02),
            schedule: LrSchedule::InvT { t0: 10.0 },
            momentum: 0.5,
            batch_frac: 0.5,
            seed: 5,
            ..Default::default()
        })),
        other => panic!("unknown optimizer {other}"),
    }
}

fn run_serial(name: &str, enc: &EncodedProblem, scenario: Option<&str>) -> RunOutput {
    let mut cluster = cluster(enc, scenario);
    optimizer(name).run(enc, &mut cluster, ITERS).expect("serial run")
}

fn run_at_depth(
    name: &str,
    enc: &EncodedProblem,
    scenario: Option<&str>,
    depth: usize,
) -> RunOutput {
    let mut cluster = cluster(enc, scenario);
    run_pipelined(&*optimizer(name), enc, &mut cluster, ITERS, None, depth)
        .expect("pipelined run")
}

fn assert_outputs_identical(a: &RunOutput, b: &RunOutput, what: &str) {
    assert_eq!(a.w, b.w, "{what}: final iterates differ");
    assert_eq!(a.trace.to_csv(), b.trace.to_csv(), "{what}: traces differ");
}

// ------------------------------------------------------------- contract 1

#[test]
fn depth_one_matches_the_serial_loop_bit_for_bit() {
    let combos: &[(EncoderKind, f64)] = &[
        (EncoderKind::Hadamard, 2.0),
        (EncoderKind::Replication, 2.0),
        (EncoderKind::Identity, 1.0),
    ];
    for &(kind, beta) in combos {
        let enc = encode(kind, beta);
        for opt in ["gd", "lbfgs", "sgd"] {
            for scenario in [None, Some("admit:rotate:k")] {
                let serial = run_serial(opt, &enc, scenario);
                let piped = run_at_depth(opt, &enc, scenario, 1);
                assert_outputs_identical(
                    &serial,
                    &piped,
                    &format!("{opt}/{kind}/scenario={scenario:?}/depth=1"),
                );
            }
        }
    }
}

// ------------------------------------------------------------- contract 2

#[test]
fn virtual_clock_traces_are_depth_invariant() {
    let enc = encode(EncoderKind::Hadamard, 2.0);
    for opt in ["gd", "lbfgs", "sgd"] {
        for scenario in [None, Some("admit:rotate:k")] {
            let base = run_at_depth(opt, &enc, scenario, 1);
            for depth in [2, 4] {
                let deep = run_at_depth(opt, &enc, scenario, depth);
                assert_outputs_identical(
                    &base,
                    &deep,
                    &format!("{opt}/hadamard/scenario={scenario:?}/depth={depth}"),
                );
            }
        }
    }
}

// ------------------------------------------------------------- contract 3

#[test]
fn temporal_schemes_are_depth_invariant_and_descend() {
    let schemes = [
        TemporalScheme::parse("seq:4:2").unwrap(),
        TemporalScheme::parse("stoch:0.5").unwrap(),
    ];
    let prob = problem();
    let f0 = prob.objective(&vec![0.0; prob.p()]);
    for scheme in schemes {
        let enc = encode_temporal(scheme);
        for opt in ["gd", "lbfgs"] {
            let base = run_at_depth(opt, &enc, None, 1);
            let deep = run_at_depth(opt, &enc, None, 4);
            assert_outputs_identical(&base, &deep, &format!("{opt}/{scheme}/depth=4"));
            let f_final = prob.objective(&base.w);
            assert!(
                f_final < f0,
                "{opt}/{scheme}: no descent on the true objective ({f_final} vs {f0})"
            );
        }
    }
}

// ------------------------------------------------------------- contract 4

#[test]
fn pipelined_runs_replay_themselves() {
    let enc = encode(EncoderKind::Hadamard, 2.0);
    let dsl = "crash:3@2,recover:3@6,slow:1:4@1";
    let a = run_at_depth("gd", &enc, Some(dsl), 4);
    let b = run_at_depth("gd", &enc, Some(dsl), 4);
    assert_outputs_identical(&a, &b, "gd/hadamard/churn/depth=4 replay");
}
