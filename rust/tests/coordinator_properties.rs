//! Property tests on the coordinator invariants: routing (first-k gather),
//! batching (aggregation semantics), and state (L-BFGS overlap machinery)
//! across randomized cluster shapes, delay models, and encoder families.
//!
//! Uses the in-tree seeded property harness (`codedopt::testutil`) —
//! proptest is unavailable in the offline build; every failure reports a
//! reproducing seed.

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel};
use codedopt::encoding::EncoderKind;
use codedopt::linalg;
use codedopt::optim::{CodedGd, CodedLbfgs, GdConfig, LbfgsConfig, Optimizer};
use codedopt::problem::{EncodedProblem, QuadProblem, Scheme};
use codedopt::rng::Pcg64;
use codedopt::runtime::{ComputeEngine, NativeEngine};
use codedopt::testutil::{gen_range, property};

fn random_cluster_shape(rng: &mut Pcg64) -> (usize, usize) {
    let m = gen_range(rng, 2, 12);
    let k = gen_range(rng, 1, m);
    (m, k)
}

fn random_delay(rng: &mut Pcg64) -> DelayModel {
    match rng.next_below(4) {
        0 => DelayModel::Exp { mean_ms: 1.0 + 20.0 * rng.next_f64() },
        1 => DelayModel::ShiftedExp { shift_ms: 2.0, mean_ms: 5.0 },
        2 => DelayModel::Pareto { scale_ms: 1.0, shape: 1.5 },
        _ => DelayModel::Constant { ms: 3.0 },
    }
}

fn build(
    rng: &mut Pcg64,
    kind: EncoderKind,
    beta: f64,
    m: usize,
    k: usize,
) -> (EncodedProblem, Cluster) {
    let n = gen_range(rng, m.max(8), 96).next_power_of_two();
    let p = gen_range(rng, 2, 12);
    let seed = rng.next_u64();
    let prob = QuadProblem::synthetic_gaussian(n, p, 0.01, seed);
    let enc = EncodedProblem::encode(&prob, kind, beta, m, seed).expect("encode");
    let engine = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: m,
        wait_for: k,
        delay: random_delay(rng),
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed,
    };
    let cluster = Cluster::new(&enc, engine, cfg).expect("cluster");
    (enc, cluster)
}

/// Routing invariant: every round admits exactly k workers (absent
/// failures), they are distinct and valid ids, in ascending arrival
/// order, and the round duration is the k-th arrival.
#[test]
fn prop_first_k_gather_invariants() {
    property("first-k gather", 30, |rng| {
        let (m, k) = random_cluster_shape(rng);
        let (enc, mut cluster) = build(rng, EncoderKind::Gaussian, 2.0, m, k);
        let w = vec![0.1; enc.p()];
        for _ in 0..5 {
            let (responses, round) = cluster.grad_round(&w).unwrap();
            assert_eq!(round.admitted.len(), k, "admitted exactly k");
            assert_eq!(responses.len(), k);
            let mut seen = std::collections::HashSet::new();
            for &wid in &round.admitted {
                assert!(wid < m, "worker id in range");
                assert!(seen.insert(wid), "no duplicate workers");
            }
            // arrival times sorted, k-th defines elapsed
            for pair in round.arrivals.windows(2) {
                assert!(pair[0].1 <= pair[1].1, "arrivals sorted");
            }
            assert_eq!(round.elapsed_ms, round.arrivals[k - 1].1);
            // admitted = k smallest arrivals
            let cutoff = round.arrivals[k - 1].1;
            for &(wid, t) in &round.arrivals[k..] {
                assert!(t >= cutoff, "worker {wid} arrived early but not admitted");
            }
        }
    });
}

/// Batching invariant: coded/uncoded aggregation over ALL workers equals
/// the true raw gradient exactly (tight frames) and the objective matches.
#[test]
fn prop_full_aggregation_is_exact() {
    property("full aggregation exact", 25, |rng| {
        let kind = match rng.next_below(3) {
            0 => EncoderKind::Hadamard,
            1 => EncoderKind::Dft,
            _ => EncoderKind::Identity,
        };
        let beta = if kind == EncoderKind::Identity { 1.0 } else { 2.0 };
        let m = gen_range(rng, 2, 8);
        let (enc, _) = build(rng, kind, beta, m, m);
        let p = enc.p();
        let w: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
        let mut engine = NativeEngine::new(&enc);
        let all = engine.worker_grad_all(&w).unwrap();
        let responses: Vec<(usize, Vec<f64>, f64)> = all
            .into_iter()
            .enumerate()
            .map(|(i, (g, f))| (i, g, f))
            .collect();
        let (g_est, f_est) = enc.aggregate_grad(&w, &responses);
        let g_true = enc.raw.grad(&w);
        let f_true = enc.raw.objective(&w);
        let g_err = linalg::norm2(&linalg::sub(&g_est, &g_true))
            / linalg::norm2(&g_true).max(1e-12);
        assert!(g_err < 1e-6, "gradient rel err {g_err} ({kind:?})");
        assert!(
            (f_est - f_true).abs() / f_true.max(1e-12) < 1e-6,
            "objective {f_est} vs {f_true}"
        );
    });
}

/// Batching invariant: aggregation is permutation-invariant in arrival
/// order (the leader must not depend on who answered first).
#[test]
fn prop_aggregation_order_invariant() {
    property("aggregation order-invariant", 20, |rng| {
        let (m, k) = random_cluster_shape(rng);
        let (enc, mut cluster) = build(rng, EncoderKind::Hadamard, 2.0, m, k);
        let p = enc.p();
        let w: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
        let (mut responses, _) = cluster.grad_round(&w).unwrap();
        let (g1, f1) = enc.aggregate_grad(&w, &responses);
        // shuffle arrival order
        for i in (1..responses.len()).rev() {
            let j = rng.next_below((i + 1) as u64) as usize;
            responses.swap(i, j);
        }
        let (g2, f2) = enc.aggregate_grad(&w, &responses);
        assert!((f1 - f2).abs() < 1e-12);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-12);
        }
    });
}

/// Replication dedup invariant: duplicate copies of a partition never
/// change the estimate, regardless of which copies respond.
#[test]
fn prop_replication_dedup() {
    property("replication dedup", 20, |rng| {
        let partitions = gen_range(rng, 2, 5);
        let m = partitions * 2; // beta 2
        let n = (partitions * gen_range(rng, 4, 16)).next_power_of_two();
        let p = gen_range(rng, 2, 8);
        let seed = rng.next_u64();
        let prob = QuadProblem::synthetic_gaussian(n, p, 0.0, seed);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Replication, 2.0, m, seed).unwrap();
        assert_eq!(enc.scheme, Scheme::Replicated { partitions });
        let w: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
        let mut engine = NativeEngine::new(&enc);
        let all = engine.worker_grad_all(&w).unwrap();
        let resp = |ids: &[usize]| -> Vec<(usize, Vec<f64>, f64)> {
            ids.iter().map(|&i| (i, all[i].0.clone(), all[i].1)).collect()
        };
        // one copy of partition j vs both copies: same estimate
        for j in 0..partitions {
            let (g_one, _) = enc.aggregate_grad(&w, &resp(&[j]));
            let (g_both, _) = enc.aggregate_grad(&w, &resp(&[j, j + partitions]));
            for (a, b) in g_one.iter().zip(&g_both) {
                assert!((a - b).abs() < 1e-10, "partition {j}: dedup failed");
            }
        }
    });
}

/// State invariant: optimizer runs are exactly reproducible from the seed
/// (bitwise trace equality).
#[test]
fn prop_runs_are_deterministic() {
    property("deterministic runs", 10, |rng| {
        let (m, k) = random_cluster_shape(rng);
        let kind = match rng.next_below(3) {
            0 => EncoderKind::Hadamard,
            1 => EncoderKind::Gaussian,
            _ => EncoderKind::Identity,
        };
        let beta = if kind == EncoderKind::Identity { 1.0 } else { 2.0 };
        let seed_snapshot = rng.clone();
        let run = |rng: &mut Pcg64| {
            let (enc, mut cluster) = build(rng, kind, beta, m, k);
            let lb = CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.3), ..Default::default() });
            lb.run(&enc, &mut cluster, 8).unwrap()
        };
        let out1 = run(&mut seed_snapshot.clone());
        let out2 = run(&mut seed_snapshot.clone());
        assert_eq!(out1.trace.len(), out2.trace.len());
        for (a, b) in out1.trace.records.iter().zip(&out2.trace.records) {
            assert_eq!(a.f_true.to_bits(), b.f_true.to_bits(), "bitwise reproducible");
            assert_eq!(a.responders, b.responders);
        }
    });
}

/// State invariant: GD with a Theorem-1 step on full participation never
/// increases the true objective.
#[test]
fn prop_gd_monotone_at_full_participation() {
    property("GD monotone (k=m)", 15, |rng| {
        let m = gen_range(rng, 2, 8);
        let (enc, mut cluster) = build(rng, EncoderKind::Hadamard, 2.0, m, m);
        let gd = CodedGd::new(GdConfig { zeta: 0.5, epsilon: Some(0.0), ..Default::default() });
        let out = gd.run(&enc, &mut cluster, 15).unwrap();
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].f_true <= pair[0].f_true + 1e-9,
                "objective increased at iter {}",
                pair[1].iter
            );
        }
    });
}

/// Clock invariant: simulated time is nonnegative per round and additive
/// across rounds.
#[test]
fn prop_sim_clock_monotone() {
    property("sim clock", 15, |rng| {
        let (m, k) = random_cluster_shape(rng);
        let (enc, mut cluster) = build(rng, EncoderKind::Gaussian, 2.0, m, k);
        let w = vec![0.0; enc.p()];
        let mut last = 0.0;
        for _ in 0..6 {
            let (_, round) = cluster.grad_round(&w).unwrap();
            assert!(round.elapsed_ms >= 0.0);
            let now = cluster.sim_ms;
            assert!(now >= last, "clock went backwards");
            assert!((now - last - round.elapsed_ms).abs() < 1e-9, "clock additivity");
            last = now;
        }
    });
}

/// Encoding invariant: for every coded family, shard rows partition the
/// encoded rows and padding rows are exactly zero.
#[test]
fn prop_shard_partition_covers_encoded_rows() {
    property("shard partition", 20, |rng| {
        let kinds = [
            EncoderKind::Gaussian,
            EncoderKind::Hadamard,
            EncoderKind::Dft,
            EncoderKind::PaleyEtf,
            EncoderKind::HadamardEtf,
            EncoderKind::SteinerEtf,
        ];
        let kind = kinds[rng.next_below(kinds.len() as u64) as usize];
        let m = gen_range(rng, 2, 6);
        let n = gen_range(rng, 16, 48);
        let p = gen_range(rng, 2, 6);
        let seed = rng.next_u64();
        let prob = QuadProblem::synthetic_gaussian(n, p, 0.0, seed);
        let enc = EncodedProblem::encode(&prob, kind, 2.0, m, seed).expect("encode");
        assert_eq!(enc.m(), m);
        let real_rows: usize = enc.shards.iter().map(|s| s.rows_real).sum();
        assert!(
            (real_rows as f64 - enc.beta * n as f64).abs() < 1.0,
            "{kind:?}: shard rows {real_rows} != beta*n = {}",
            enc.beta * n as f64
        );
        for s in &enc.shards {
            assert!(s.x.rows() >= s.rows_real);
            assert!(s.x.rows().is_power_of_two() && s.x.rows() >= 8);
            // padding rows are exactly zero
            for r in s.rows_real..s.x.rows() {
                assert!((0..s.x.cols()).all(|c| s.x.get(r, c) == 0.0));
                assert_eq!(s.y[r], 0.0);
            }
        }
    });
}

/// L-BFGS state invariant: the overlap pair machinery never produces a
/// non-finite iterate, across delay models and small k (worst case for
/// overlap size), for coded encoders.
#[test]
fn prop_lbfgs_iterates_stay_finite() {
    property("lbfgs finite", 15, |rng| {
        let m = gen_range(rng, 3, 10);
        let k = gen_range(rng, 1, m);
        let (enc, mut cluster) = build(rng, EncoderKind::Hadamard, 2.0, m, k);
        let lb = CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.3), ..Default::default() });
        let out = lb.run(&enc, &mut cluster, 12).unwrap();
        assert!(out.w.iter().all(|x| x.is_finite()), "non-finite iterate");
        for r in &out.trace.records {
            assert!(r.f_true.is_finite(), "non-finite objective at {}", r.iter);
            assert!(r.alpha.is_finite() && r.alpha > 0.0);
        }
    });
}
