//! Stochastic-subsystem contracts, end to end:
//!
//! 1. `CodedSgd` at `batch_frac = 1.0` with a constant step reproduces
//!    `CodedGd` iterates **bit for bit** under `ClockMode::Virtual`, for
//!    every scheme and every k (the full-batch path *is* the full
//!    gradient round).
//! 2. The sampled encoded mini-batch gradient is **unbiased** in
//!    expectation over the sampling RNG stream: averaged over many
//!    `BatchPlan`s, the leader's `aggregate_grad_batch` estimate
//!    converges to the full-round estimate (which at k = m, coded, is the
//!    true gradient).
//! 3. The `SgdConfig` JSON surface round-trips and rejects malformed
//!    `lr-schedule` strings.

use codedopt::prelude::*;
use codedopt::rng::Pcg64;
use codedopt::testutil::{gen_range, property};

fn build_cluster(
    kind: EncoderKind,
    beta: f64,
    m: usize,
    k: usize,
    seed: u64,
) -> (EncodedProblem, Cluster) {
    let prob = QuadProblem::synthetic_gaussian(128, 8, 0.05, 77);
    let enc = EncodedProblem::encode(&prob, kind, beta, m, seed).unwrap();
    let eng = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: m,
        wait_for: k,
        delay: DelayModel::Exp { mean_ms: 10.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed,
    };
    let cluster = Cluster::new(&enc, eng, cfg).unwrap();
    (enc, cluster)
}

/// Acceptance contract (a): full-batch SGD ≡ GD, bit for bit, across
/// random schemes, k, and seeds.
#[test]
fn sgd_full_batch_reproduces_gd_iterates_bitwise() {
    property("sgd(batch=1) == gd bitwise", 8, |rng| {
        let kinds = [EncoderKind::Hadamard, EncoderKind::Gaussian, EncoderKind::Identity];
        let kind = kinds[gen_range(rng, 0, kinds.len() - 1)];
        let beta = if kind == EncoderKind::Identity { 1.0 } else { 2.0 };
        let m = 8;
        let k = gen_range(rng, 2, m);
        let seed = rng.next_u64() % 1000;
        let alpha = 0.001 + 0.02 * rng.next_f64();

        let (enc, mut cl_sgd) = build_cluster(kind, beta, m, k, seed);
        let (_, mut cl_gd) = build_cluster(kind, beta, m, k, seed);
        let sgd = CodedSgd::new(SgdConfig {
            lr: Some(alpha),
            batch_frac: 1.0,
            schedule: LrSchedule::Constant,
            ..Default::default()
        });
        let gd = CodedGd::new(GdConfig { alpha_override: Some(alpha), ..Default::default() });
        let out_s = sgd.run(&enc, &mut cl_sgd, 25).unwrap();
        let out_g = gd.run(&enc, &mut cl_gd, 25).unwrap();

        for (a, b) in out_s.w.iter().zip(&out_g.w) {
            assert_eq!(a.to_bits(), b.to_bits(), "iterate mismatch ({kind:?}, k={k})");
        }
        assert_eq!(out_s.trace.len(), out_g.trace.len());
        for (ra, rb) in out_s.trace.records.iter().zip(&out_g.trace.records) {
            assert_eq!(ra.f_true.to_bits(), rb.f_true.to_bits());
            assert_eq!(ra.f_est.to_bits(), rb.f_est.to_bits());
            assert_eq!(ra.grad_norm.to_bits(), rb.grad_norm.to_bits());
            assert_eq!(ra.sim_ms.to_bits(), rb.sim_ms.to_bits());
            assert_eq!(ra.compute_ms.to_bits(), rb.compute_ms.to_bits());
            assert_eq!(ra.responders, rb.responders);
        }
    });
}

/// Acceptance contract (b): unbiasedness of the sampled encoded gradient
/// over the RNG stream, through the full cluster path (engine → streaming
/// collector → leader aggregation).
#[test]
fn sampled_encoded_gradient_is_unbiased_over_rng_stream() {
    let m = 8;
    let (enc, mut cluster) = build_cluster(EncoderKind::Hadamard, 2.0, m, m, 3);
    let mut wrng = Pcg64::seeded(41);
    let w: Vec<f64> = (0..8).map(|_| wrng.next_gaussian()).collect();
    let g_true = enc.raw.grad(&w);

    let mut rng = Pcg64::new(9, 0xba7c);
    let trials = 2500;
    let mut mean = vec![0.0; 8];
    let mut max_single_dev: f64 = 0.0;
    for _ in 0..trials {
        let plan = enc.sample_batch(0.5, &mut rng);
        let (responses, _) = cluster.grad_batch_round(&w, &plan).unwrap();
        let (g, _) = enc.aggregate_grad_batch(&w, &responses, &plan);
        let dev: f64 = g.iter().zip(&g_true).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        max_single_dev = max_single_dev.max(dev);
        for (mi, gi) in mean.iter_mut().zip(&g) {
            *mi += gi / trials as f64;
        }
    }
    let num: f64 = mean.iter().zip(&g_true).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let den: f64 = g_true.iter().map(|x| x * x).sum::<f64>().sqrt();
    let rel = num / den;
    assert!(rel < 0.05, "mean of sampled gradients biased: rel err {rel}");
    // sanity: the estimator is actually stochastic, not secretly full-batch
    assert!(max_single_dev > 1e-8, "single-round estimates never deviated");
}

/// Satellite: the SGD config JSON surface round-trips and malformed
/// lr-schedule strings are rejected at every entry point.
#[test]
fn sgd_config_json_round_trip_and_rejection() {
    let cfg = SgdConfig {
        lr: Some(0.07),
        schedule: LrSchedule::InvT { t0: 25.0 },
        momentum: 0.5,
        batch_frac: 0.2,
        epoch_len: 5,
        patience: 4,
        plateau_tol: 0.01,
        seed: 123,
    };
    let text = cfg.to_json();
    let back = SgdConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, cfg);

    for bad in ["warp", "cosine", "cosine:0", "invt:-1", "constant:7"] {
        assert!(LrSchedule::parse(bad).is_err(), "parse should reject {bad:?}");
        let doc = format!("{{\"lr_schedule\": \"{bad}\"}}");
        let j = Json::parse(&doc).unwrap();
        assert!(SgdConfig::from_json(&j).is_err(), "from_json should reject {bad:?}");
    }
}

/// The per-iteration trace CSV carries the per-round compute-time column
/// the `fig_sgd` bench relies on (`Round.compute_ms`, admitted-mean).
#[test]
fn sgd_trace_csv_has_populated_compute_ms_column() {
    let (enc, mut cluster) = build_cluster(EncoderKind::Hadamard, 2.0, 8, 4, 5);
    let sgd = CodedSgd::new(SgdConfig { batch_frac: 0.25, ..Default::default() });
    let out = sgd.run(&enc, &mut cluster, 12).unwrap();
    let csv = out.trace.to_csv();
    let header = csv.lines().next().unwrap();
    assert!(header.ends_with("sim_ms,compute_ms,events"), "header: {header}");
    assert_eq!(csv.lines().count(), 13);
    for r in &out.trace.records {
        assert!(r.compute_ms > 0.0 && r.compute_ms.is_finite());
    }
}
