//! Zero-allocation steady state — the regression gate behind the
//! broadcast slab, the rearmable collector, and the recycled payload
//! pool.
//!
//! A counting global allocator watches a `NativeEngine` drive gradient
//! rounds through the recycled dispatch path (persistent collector,
//! `visit_responses` by reference, `rearm_all`, broadcast slab). The
//! assertion is **min allocations over steady rounds == 0**: std's mpsc
//! channels amortize one message-block allocation per ~31 sends per
//! channel, so *some* rounds legitimately touch the heap — but between
//! block refills every round must be completely allocation-free, or a
//! per-round `Vec` has crept back into the dispatch path. With one lane
//! thread (`with_threads(1)`) the channel count is minimal and the
//! alloc-free rounds dominate the window.
//!
//! Everything lives in one `#[test]` because the allocation counter is
//! process-global: concurrently running tests would bleed into each
//! other's per-round deltas.

use codedopt::encoding::EncoderKind;
use codedopt::linalg::GradMode;
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::runtime::{ComputeEngine, GradCollector, NativeEngine};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: defers every operation to `System`; the counter is a relaxed
// atomic with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const WARMUP: usize = 6;
const ROUNDS: usize = 48;

/// One recycled round: broadcast through the slab, read responses by
/// reference, rearm the collector in place.
fn recycled_round(eng: &mut NativeEngine, w: &[f64], sink: &GradCollector) {
    eng.worker_grad_streamed(w, sink).unwrap();
    sink.visit_responses(|wid, payload, _ms| {
        std::hint::black_box((wid, &payload.0, payload.1));
    });
    sink.rearm_all();
}

/// Drive `ROUNDS` steady rounds and return (min, sum) of per-round
/// allocation counts, after `WARMUP` rounds fill the slab and the
/// collector's spare pool.
fn steady_allocs(eng: &mut NativeEngine, w: &[f64], m: usize) -> (u64, u64) {
    let sink = GradCollector::collect_all(m);
    for _ in 0..WARMUP {
        recycled_round(eng, w, &sink);
    }
    let mut min = u64::MAX;
    let mut sum = 0u64;
    for _ in 0..ROUNDS {
        let a0 = ALLOCS.load(Ordering::Relaxed);
        recycled_round(eng, w, &sink);
        let a = ALLOCS.load(Ordering::Relaxed) - a0;
        min = min.min(a);
        sum += a;
    }
    (min, sum)
}

#[test]
fn steady_state_rounds_allocate_zero() {
    let m = 4;
    let prob = QuadProblem::synthetic_gaussian(16 * m, 12, 0.05, 7);
    let enc = EncodedProblem::encode(&prob, EncoderKind::Identity, 1.0, m, 7).unwrap();
    let w = vec![0.1; 12];

    // gemv path
    let mut eng = NativeEngine::new(&enc).with_threads(1);
    let (min, sum) = steady_allocs(&mut eng, &w, m);
    assert_eq!(
        min, 0,
        "gemv dispatch path allocated on every steady round \
         (mean {:.2}/round) — a per-round Vec crept back in",
        sum as f64 / ROUNDS as f64
    );
    let (reused, fresh) = eng.broadcast_buffer_stats();
    assert!(
        reused > fresh,
        "broadcast slab barely recycling: {reused} reused vs {fresh} fresh"
    );

    // the mpsc amortized cost is small: well under one block per round
    // per channel would be ~2/round here; anything bigger means a
    // structural per-round allocation slipped past the min statistic
    assert!(
        (sum as f64 / ROUNDS as f64) < 2.0,
        "steady rounds average {:.2} allocations — more than mpsc block \
         amortization can explain",
        sum as f64 / ROUNDS as f64
    );

    // gram path: the cached-Gram fast path must be as quiet — its round
    // serves g = G·w − c from staged buffers with no temporaries
    let gram_enc = enc.clone().with_grad_mode(GradMode::Gram).unwrap();
    let mut eng = NativeEngine::new(&gram_enc).with_threads(1);
    let (min, sum) = steady_allocs(&mut eng, &w, m);
    assert_eq!(
        min, 0,
        "gram dispatch path allocated on every steady round \
         (mean {:.2}/round)",
        sum as f64 / ROUNDS as f64
    );
    let (reused, _fresh) = eng.broadcast_buffer_stats();
    assert!(reused > 0, "gram-mode engine never recycled a broadcast buffer");
}
