//! Streaming-vs-batch equivalence: the streaming first-k gather must be
//! an exact drop-in for the historical batch-synchronous path under
//! `ClockMode::Virtual` — same RNG stream, same admitted set, bit-equal
//! round records and gradient payloads — while `ClockMode::Measured`
//! exercises the genuinely event-driven path end to end.

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel};
use codedopt::encoding::EncoderKind;
use codedopt::optim::{CodedLbfgs, LbfgsConfig, Optimizer};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::rng::Pcg64;
use codedopt::runtime::{ComputeEngine, CurvCollector, GradCollector, NativeEngine};
use codedopt::testutil::{gen_range, property};

fn random_delay(rng: &mut Pcg64) -> DelayModel {
    match rng.next_below(5) {
        0 => DelayModel::Exp { mean_ms: 1.0 + 20.0 * rng.next_f64() },
        1 => DelayModel::ShiftedExp { shift_ms: 2.0, mean_ms: 5.0 },
        2 => DelayModel::ExpWithFailures { mean_ms: 5.0, p_fail: 0.3 },
        3 => DelayModel::Constant { ms: 3.0 },
        _ => DelayModel::None,
    }
}

/// Replica of the historical (pre-streaming) batch gather: the cluster's
/// delay RNG stream (`Pcg64::new(seed, 0xc105)`), worker-index sampling
/// order, stable sort by arrival, first-k admission, k-th arrival as the
/// round duration. Any divergence from this is a reproducibility break.
struct LegacyGather {
    rng: Pcg64,
    wait_for: usize,
    delay: DelayModel,
    compute_ms: Vec<f64>,
}

impl LegacyGather {
    fn new(cfg: &ClusterConfig, enc: &EncodedProblem) -> Self {
        let compute_ms = enc
            .shards
            .iter()
            .map(|s| 2.0 * s.x.rows() as f64 * s.x.cols() as f64 * 2.0 / 1e6 * cfg.ms_per_mflop)
            .collect();
        LegacyGather {
            rng: Pcg64::new(cfg.seed, 0xc105),
            wait_for: cfg.wait_for,
            delay: cfg.delay.clone(),
            compute_ms,
        }
    }

    /// One round's (admitted, arrivals, elapsed_ms, failed).
    #[allow(clippy::type_complexity)]
    fn round(&mut self) -> (Vec<usize>, Vec<(usize, f64)>, f64, Vec<usize>) {
        let m = self.compute_ms.len();
        let mut arrivals: Vec<(usize, f64)> = Vec::with_capacity(m);
        let mut failed = Vec::new();
        for i in 0..m {
            let delay = self.delay.sample(&mut self.rng, i);
            if delay.is_finite() {
                arrivals.push((i, self.compute_ms[i] + delay));
            } else {
                failed.push(i);
            }
        }
        arrivals.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let k = self.wait_for.min(arrivals.len());
        let admitted: Vec<usize> = arrivals[..k].iter().map(|&(w, _)| w).collect();
        let elapsed = arrivals.get(k.saturating_sub(1)).map(|&(_, t)| t).unwrap_or(0.0);
        (admitted, arrivals, elapsed, failed)
    }
}

/// The tentpole acceptance property: a seeded `ClockMode::Virtual` run
/// produces bit-identical `Round` records (admitted set, arrivals,
/// `elapsed_ms`) and bit-identical admitted gradients through the
/// streaming refactor, across cluster shapes and delay models.
#[test]
fn prop_virtual_streaming_is_bit_identical_to_legacy_batch() {
    property("virtual streaming ≡ legacy batch", 25, |rng| {
        let m = gen_range(rng, 2, 10);
        let k = gen_range(rng, 1, m);
        let n = gen_range(rng, m.max(8), 64).next_power_of_two();
        let p = gen_range(rng, 2, 10);
        let seed = rng.next_u64();
        let prob = QuadProblem::synthetic_gaussian(n, p, 0.01, seed);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, m, seed).unwrap();
        let cfg = ClusterConfig {
            workers: m,
            wait_for: k,
            delay: random_delay(rng),
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5,
            seed,
        };
        let mut cluster =
            Cluster::new(&enc, Box::new(NativeEngine::new(&enc)), cfg.clone()).unwrap();
        let mut legacy = LegacyGather::new(&cfg, &enc);
        let mut batch_engine = NativeEngine::new(&enc);

        for r in 0..4 {
            let w: Vec<f64> = (0..p).map(|j| 0.1 * (r as f64 + 1.0) * (j as f64 - 1.0)).collect();
            let (responses, round) = cluster.grad_round(&w).unwrap();
            let all = batch_engine.worker_grad_all(&w).unwrap();
            let (admitted, arrivals, elapsed, failed) = legacy.round();

            assert_eq!(round.admitted, admitted, "admitted set changed");
            assert_eq!(round.failed, failed, "failed set changed");
            assert_eq!(
                round.elapsed_ms.to_bits(),
                elapsed.to_bits(),
                "elapsed_ms not bit-identical"
            );
            assert_eq!(round.arrivals.len(), arrivals.len());
            for ((w1, t1), (w2, t2)) in round.arrivals.iter().zip(&arrivals) {
                assert_eq!(w1, w2, "arrival order changed");
                assert_eq!(t1.to_bits(), t2.to_bits(), "arrival time not bit-identical");
            }
            // admitted payloads == the batch surface's, bit for bit
            assert_eq!(responses.len(), admitted.len());
            for ((wid, g, f), &expect_wid) in responses.iter().zip(&admitted) {
                assert_eq!(*wid, expect_wid);
                let (g_ref, f_ref) = &all[*wid];
                assert_eq!(f.to_bits(), f_ref.to_bits(), "objective payload differs");
                for (a, b) in g.iter().zip(g_ref) {
                    assert_eq!(a.to_bits(), b.to_bits(), "gradient payload differs");
                }
            }
        }
    });
}

/// The engine-surface half of the satellite: `worker_grad_streamed` into
/// a collect-all sink delivers exactly the `worker_grad_all` payload set.
#[test]
fn prop_streamed_surface_matches_batch_surface() {
    property("streamed surface ≡ batch surface", 20, |rng| {
        let m = gen_range(rng, 2, 10);
        let n = gen_range(rng, m.max(8), 64).next_power_of_two();
        let p = gen_range(rng, 2, 10);
        let seed = rng.next_u64();
        let prob = QuadProblem::synthetic_gaussian(n, p, 0.0, seed);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Gaussian, 2.0, m, seed).unwrap();
        let mut eng = NativeEngine::new(&enc);
        let w: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();

        let batch = eng.worker_grad_all(&w).unwrap();
        let sink = GradCollector::collect_all(m);
        eng.worker_grad_streamed(&w, &sink).unwrap();
        let got = sink.into_collected();
        assert_eq!(got.delivery_order.len(), m, "all workers must deliver");
        for i in 0..m {
            let (payload, ms) = got.responses[i].as_ref().expect("missing response");
            assert!(*ms >= 0.0);
            assert_eq!(payload.1.to_bits(), batch[i].1.to_bits());
            for (a, b) in payload.0.iter().zip(&batch[i].0) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }

        let d: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
        let ls_batch = eng.linesearch_all(&d).unwrap();
        let ls_sink = CurvCollector::collect_all(m);
        eng.linesearch_streamed(&d, &ls_sink).unwrap();
        let ls = ls_sink.into_collected();
        for i in 0..m {
            let (q, _) = ls.responses[i].expect("missing linesearch response");
            assert_eq!(q.to_bits(), ls_batch[i].to_bits());
        }
    });
}

/// Measured-clock end to end: a full coded L-BFGS run on the streaming
/// gather with real per-worker timing converges like the virtual one and
/// advances a strictly positive wall-clock-derived simulated time.
#[test]
fn measured_clock_full_run_converges() {
    let prob = QuadProblem::synthetic_gaussian(256, 16, 0.05, 7);
    let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 7).unwrap();
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Exp { mean_ms: 1.0 },
        clock: ClockMode::Measured,
        ms_per_mflop: 0.5,
        seed: 7,
    };
    let mut cluster = Cluster::new(&enc, Box::new(NativeEngine::new(&enc)), cfg).unwrap();
    let out = CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.2), ..Default::default() })
        .run(&enc, &mut cluster, 40)
        .unwrap();
    assert!(!out.trace.diverged(), "measured-clock L-BFGS diverged");
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let f0 = prob.objective(&[0.0; 16]);
    assert!(
        out.trace.best_objective() - f_star < 0.15 * (f0 - f_star),
        "no convergence on the measured-clock streaming path"
    );
    assert!(cluster.sim_ms > 0.0, "measured clock never advanced");
    // every round admitted exactly k
    for r in &out.trace.records {
        assert_eq!(r.responders, 6);
    }
}
