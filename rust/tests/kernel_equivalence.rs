//! Kernel-equivalence suite: the contracts the raw-speed pass rests on.
//!
//! 1. **f64 SIMD ≡ scalar, bitwise.** The SIMD lane bundles are the scalar
//!    kernels' unrolled accumulator arrays made explicit, with horizontal
//!    sums reduced in the same left-to-right order — so every dense and
//!    CSR kernel must produce bit-identical f64 output with and without
//!    `--features simd`. CI runs this file under both builds; golden
//!    traces and the replay/equivalence suites therefore never fork on
//!    the feature.
//! 2. **The dispatched public path is one of the two.** `Mat`/`CsrMat`
//!    methods must route to exactly the implementation
//!    `kernels::simd_active()` claims.
//! 3. **f32 mode converges.** Coded GD on f32-narrowed shards reaches the
//!    Theorem-1 neighborhood of the f64 run within a documented tolerance
//!    (workers compute in f32; leader aggregation and steps stay f64, so
//!    the per-round perturbation is a bounded gradient error).

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel};
use codedopt::encoding::EncoderKind;
use codedopt::linalg::kernels;
use codedopt::linalg::{CsrMat, DataMat, Mat, Precision, StorageKind};
use codedopt::optim::{CodedGd, GdConfig, Optimizer, RunOutput};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::rng::Pcg64;
use codedopt::runtime::NativeEngine;

/// Shapes that cover every tail path: row pairing (odd/even rows), the
/// 4-lane main loop + 2-lane + scalar column tails, and single-row mats.
const SHAPES: &[(usize, usize)] = &[
    (1, 1),
    (1, 7),
    (2, 4),
    (3, 5),
    (7, 3),
    (8, 8),
    (9, 12),
    (16, 17),
    (33, 19),
    (64, 31),
];

fn dense(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seeded(seed);
    Mat::from_fn(rows, cols, |_, _| rng.next_gaussian())
}

fn vecn(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::seeded(seed ^ 0x5eed);
    (0..n).map(|_| rng.next_gaussian()).collect()
}

/// A CSR matrix with ragged rows, including empty rows.
fn sparse(rows: usize, cols: usize, seed: u64) -> CsrMat {
    let mut rng = Pcg64::seeded(seed ^ 0xc52);
    let mut row_ptr = vec![0usize];
    let mut col_idx: Vec<u32> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    for i in 0..rows {
        // row i holds (i % (cols+1)) entries when i % 5 != 0, else empty —
        // exercises 0-, short-, and accumulator-length entry loops
        let nnz = if i % 5 == 0 { 0 } else { (i % (cols + 1)).min(cols) };
        let mut cs: Vec<u32> = (0..cols as u32).collect();
        // partial Fisher–Yates: first nnz entries are a random subset
        for t in 0..nnz {
            let j = t + (rng.next_u64() as usize) % (cols - t);
            cs.swap(t, j);
        }
        let mut picked: Vec<u32> = cs[..nnz].to_vec();
        picked.sort_unstable();
        for c in picked {
            col_idx.push(c);
            vals.push(rng.next_gaussian());
        }
        row_ptr.push(col_idx.len());
    }
    CsrMat::from_raw(rows, cols, row_ptr, col_idx, vals)
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// 1. f64 SIMD ≡ scalar, bitwise
// ---------------------------------------------------------------------------

#[test]
fn dot_bitwise() {
    for &(r, c) in SHAPES {
        let n = r * c;
        let a = vecn(n, 1);
        let b = vecn(n, 2);
        assert_eq!(
            kernels::dot_scalar(&a, &b).to_bits(),
            kernels::dot_simd(&a, &b).to_bits(),
            "dot len={n}"
        );
    }
}

#[test]
fn dense_gemv_bitwise() {
    for &(r, c) in SHAPES {
        let m = dense(r, c, 3);
        let x = vecn(c, 4);
        let mut ys = vec![0.0; r];
        let mut yv = vec![0.0; r];
        kernels::mat_gemv_into_scalar(&m, &x, &mut ys);
        kernels::mat_gemv_into_simd(&m, &x, &mut yv);
        assert_eq!(bits(&ys), bits(&yv), "gemv {r}x{c}");
    }
}

#[test]
fn dense_gemv_t_bitwise() {
    for &(r, c) in SHAPES {
        let m = dense(r, c, 5);
        let x = vecn(r, 6);
        let mut ys = vec![0.0; c];
        let mut yv = vec![0.0; c];
        kernels::mat_gemv_t_into_scalar(&m, &x, &mut ys);
        kernels::mat_gemv_t_into_simd(&m, &x, &mut yv);
        assert_eq!(bits(&ys), bits(&yv), "gemv_t {r}x{c}");
    }
}

#[test]
fn dense_fused_grad_range_bitwise_full_and_partial_windows() {
    for &(r, c) in SHAPES {
        let m = dense(r, c, 7);
        let w = vecn(c, 8);
        let y = vecn(r, 9);
        // full window plus every partial window start/end combination the
        // circular mini-batch sampler can produce (two-segment wraps are
        // two independent calls, so covering arbitrary [lo, hi) covers
        // wrapped blocks too)
        let mut windows = vec![(0, r)];
        for lo in [0, r / 3, r / 2] {
            for hi in [r / 2, (2 * r) / 3, r] {
                if lo < hi {
                    windows.push((lo, hi));
                }
            }
        }
        for (lo, hi) in windows {
            let mut gs = vecn(c, 10); // nonzero: the kernel accumulates
            let mut gv = gs.clone();
            let mut bs = vec![0.0; r];
            let mut bv = vec![0.0; r];
            let fs = kernels::mat_fused_grad_range_scalar(&m, &w, &y, &mut gs, &mut bs, lo, hi);
            let fv = kernels::mat_fused_grad_range_simd(&m, &w, &y, &mut gv, &mut bv, lo, hi);
            assert_eq!(fs.to_bits(), fv.to_bits(), "fused f {r}x{c} [{lo},{hi})");
            assert_eq!(bits(&gs), bits(&gv), "fused g {r}x{c} [{lo},{hi})");
            assert_eq!(bits(&bs), bits(&bv), "fused resid {r}x{c} [{lo},{hi})");
        }
    }
}

#[test]
fn dense_wrapped_window_composition_bitwise() {
    // a wrapped circular block = tail segment then head segment, both
    // accumulating into the same g — exactly how the SGD sampler calls it
    let (r, c) = (33, 19);
    let m = dense(r, c, 11);
    let w = vecn(c, 12);
    let y = vecn(r, 13);
    let (start, len) = (r - 5, 12); // wraps: [28, 33) then [0, 7)
    let mut gs = vec![0.0; c];
    let mut gv = vec![0.0; c];
    let mut bs = vec![0.0; r];
    let mut bv = vec![0.0; r];
    let fs = kernels::mat_fused_grad_range_scalar(&m, &w, &y, &mut gs, &mut bs, start, r)
        + kernels::mat_fused_grad_range_scalar(&m, &w, &y, &mut gs, &mut bs, 0, len - (r - start));
    let fv = kernels::mat_fused_grad_range_simd(&m, &w, &y, &mut gv, &mut bv, start, r)
        + kernels::mat_fused_grad_range_simd(&m, &w, &y, &mut gv, &mut bv, 0, len - (r - start));
    assert_eq!(fs.to_bits(), fv.to_bits());
    assert_eq!(bits(&gs), bits(&gv));
    assert_eq!(bits(&bs), bits(&bv));
}

#[test]
fn dense_gram_bitwise() {
    for &(r, c) in SHAPES {
        let m = dense(r, c, 14);
        let gs = kernels::mat_gram_scalar(&m);
        let gv = kernels::mat_gram_simd(&m);
        for j in 0..c {
            for l in 0..c {
                assert_eq!(
                    gs.get(j, l).to_bits(),
                    gv.get(j, l).to_bits(),
                    "gram {r}x{c} at ({j},{l})"
                );
            }
        }
    }
}

#[test]
fn csr_gemv_bitwise() {
    for &(r, c) in SHAPES {
        let m = sparse(r, c, 15);
        let x = vecn(c, 16);
        let mut ys = vec![0.0; r];
        let mut yv = vec![0.0; r];
        kernels::csr_gemv_into_scalar(&m, &x, &mut ys);
        kernels::csr_gemv_into_simd(&m, &x, &mut yv);
        assert_eq!(bits(&ys), bits(&yv), "csr gemv {r}x{c}");
    }
}

#[test]
fn csr_gemv_t_bitwise() {
    for &(r, c) in SHAPES {
        let m = sparse(r, c, 17);
        let x = vecn(r, 18);
        let mut ys = vec![0.0; c];
        let mut yv = vec![0.0; c];
        kernels::csr_gemv_t_into_scalar(&m, &x, &mut ys);
        kernels::csr_gemv_t_into_simd(&m, &x, &mut yv);
        assert_eq!(bits(&ys), bits(&yv), "csr gemv_t {r}x{c}");
    }
}

#[test]
fn csr_fused_grad_range_bitwise_with_empty_rows() {
    for &(r, c) in SHAPES {
        let m = sparse(r, c, 19);
        let w = vecn(c, 20);
        let y = vecn(r, 21);
        for (lo, hi) in [(0, r), (r / 3, r), (0, (2 * r) / 3 + 1), (r / 2, r / 2 + 1)] {
            if lo >= hi {
                continue;
            }
            let mut gs = vecn(c, 22);
            let mut gv = gs.clone();
            let mut bs = vec![0.0; r];
            let mut bv = vec![0.0; r];
            let fs = kernels::csr_fused_grad_range_scalar(&m, &w, &y, &mut gs, &mut bs, lo, hi);
            let fv = kernels::csr_fused_grad_range_simd(&m, &w, &y, &mut gv, &mut bv, lo, hi);
            assert_eq!(fs.to_bits(), fv.to_bits(), "csr fused f {r}x{c} [{lo},{hi})");
            assert_eq!(bits(&gs), bits(&gv), "csr fused g {r}x{c} [{lo},{hi})");
            assert_eq!(bits(&bs), bits(&bv), "csr fused resid {r}x{c} [{lo},{hi})");
        }
    }
}

// ---------------------------------------------------------------------------
// 2. The dispatched public path routes per simd_active()
// ---------------------------------------------------------------------------

#[test]
fn public_methods_route_to_the_active_implementation() {
    let (r, c) = (33, 19);
    let m = dense(r, c, 23);
    let x = vecn(c, 24);
    let mut expected = vec![0.0; r];
    if kernels::simd_active() {
        kernels::mat_gemv_into_simd(&m, &x, &mut expected);
    } else {
        kernels::mat_gemv_into_scalar(&m, &x, &mut expected);
    }
    assert_eq!(bits(&m.gemv(&x)), bits(&expected));

    let s = sparse(r, c, 25);
    let mut got = vec![0.0; r];
    let mut want = vec![0.0; r];
    s.gemv_into(&x, &mut got);
    if kernels::simd_active() {
        kernels::csr_gemv_into_simd(&s, &x, &mut want);
    } else {
        kernels::csr_gemv_into_scalar(&s, &x, &mut want);
    }
    assert_eq!(bits(&got), bits(&want));
}

// ---------------------------------------------------------------------------
// 3. f32 mode reaches the Theorem-1 neighborhood
// ---------------------------------------------------------------------------

fn coded_gd_run(prob: &QuadProblem, precision: Precision, seed: u64) -> RunOutput {
    let enc = EncodedProblem::encode_stored_prec(
        prob,
        EncoderKind::Hadamard,
        2.0,
        8,
        seed,
        StorageKind::Auto,
        precision,
    )
    .unwrap();
    let engine = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Exp { mean_ms: 10.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed,
    };
    let mut cluster = Cluster::new(&enc, engine, cfg).unwrap();
    CodedGd::new(GdConfig { epsilon: Some(0.2), seed, ..Default::default() })
        .run(&enc, &mut cluster, 120)
        .unwrap()
}

/// Coded GD with f32 worker shards lands in the same Theorem-1
/// neighborhood as f64. Tolerance: the f32 run's gap may exceed the f64
/// run's by at most 5% of the initial suboptimality — narrowing perturbs
/// each round's gradient by O(ε_f32 ‖X̃‖‖w‖), which GD's contraction
/// absorbs; it cannot change where the iterates settle at this scale.
#[test]
fn f32_coded_gd_matches_f64_neighborhood() {
    let (prob, _) = QuadProblem::planted(256, 24, 0.0, 0.01, 11);
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let f0 = prob.objective(&[0.0; 24]);
    let out64 = coded_gd_run(&prob, Precision::F64, 11);
    let out32 = coded_gd_run(&prob, Precision::F32, 11);
    let gap64 = out64.trace.best_objective() - f_star;
    let gap32 = out32.trace.best_objective() - f_star;
    assert!(!out32.trace.diverged(), "f32 run diverged");
    assert!(
        gap64 < 0.02 * (f0 - f_star),
        "f64 baseline did not converge: gap {gap64:.3e}"
    );
    assert!(
        gap32 < gap64 + 0.05 * (f0 - f_star),
        "f32 gap {gap32:.3e} strayed beyond f64 gap {gap64:.3e} + 5% of f0−f*"
    );
}

/// The narrowed problem the f32 run solves really is narrowed: shard
/// payloads halve and the recorded precision label round-trips.
#[test]
fn f32_shards_are_half_size_end_to_end() {
    let (prob, _) = QuadProblem::planted(128, 16, 0.0, 0.01, 3);
    let enc64 = EncodedProblem::encode_stored_prec(
        &prob,
        EncoderKind::Hadamard,
        2.0,
        4,
        3,
        StorageKind::Dense,
        Precision::F64,
    )
    .unwrap();
    let enc32 = EncodedProblem::encode_stored_prec(
        &prob,
        EncoderKind::Hadamard,
        2.0,
        4,
        3,
        StorageKind::Dense,
        Precision::F32,
    )
    .unwrap();
    assert_eq!(enc32.precision, Precision::F32);
    assert_eq!(Precision::parse(&enc32.precision.to_string()).unwrap(), Precision::F32);
    let x64: usize = enc64.shards.iter().map(|s| s.x.mem_bytes()).sum();
    let x32: usize = enc32.shards.iter().map(|s| s.x.mem_bytes()).sum();
    assert_eq!(x32 * 2, x64, "f32 X̃ payload must be exactly half");
    assert!(enc32.shards.iter().all(|s| matches!(s.x, DataMat::DenseF32(_))));
}
