//! Multi-tenant serve equivalence: hosting many jobs on one shared
//! [`WorkerPool`](codedopt::runtime::WorkerPool) must be invisible to
//! every job.
//!
//! Layers of pinning:
//!
//! 1. **Solo equivalence** — N concurrent jobs (3 optimizers × 3
//!    schemes) run interleaved on one `JobServer`; each job's
//!    virtual-clock CSV trace and final iterate must match a solo run of
//!    the same spec on a fresh `NativeEngine`, **byte for byte**. Round
//!    interleaving can reorder pool commands, but it must never change a
//!    payload bit, an admitted set, or a delay draw.
//! 2. **Scheduling invisibility** — fifo / fair / priority produce
//!    identical per-job traces: any serial interleaving of a job set is
//!    equivalent to any other (the determinism contract of
//!    `runtime::serve`).
//! 3. **Encode-once cache** — a second identical job hits the
//!    [`EncodedShardCache`] (one encode, one hit) and still reproduces
//!    the solo trace.
//! 4. **Fault isolation** — a `crash:`/`slow:` scenario scoped to one
//!    job leaves every sibling's trace byte-identical to a clean solo
//!    run, while the scoped job reproduces the solo *faulted* run.

use anyhow::Result;
use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel, Scenario};
use codedopt::encoding::EncoderKind;
use codedopt::linalg::StorageKind;
use codedopt::optim::{
    CodedGd, CodedLbfgs, CodedSgd, GdConfig, LbfgsConfig, LrSchedule, Optimizer, RunOutput,
    SgdConfig,
};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::runtime::{
    EncodedShardCache, JobServer, JobSpec, NativeEngine, ServeOptimizer, ServePolicy,
};
use std::sync::Arc;

// ------------------------------------------------------------- fixtures

/// The PR-4 golden workload (shared with `pool_equivalence.rs`): small
/// ridge problem, 8 workers, k = 6, deterministic `const:2` delays.
fn fixture(kind: EncoderKind, beta: f64) -> EncodedProblem {
    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    EncodedProblem::encode_stored(&prob, kind, beta, 8, 3, StorageKind::Dense).expect("encode")
}

fn ccfg() -> ClusterConfig {
    ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Constant { ms: 2.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 11,
    }
}

const SCHEMES: &[(EncoderKind, f64)] = &[
    (EncoderKind::Hadamard, 2.0),
    (EncoderKind::Replication, 2.0),
    (EncoderKind::Identity, 1.0),
];

const OPTS: &[&str] = &["gd", "sgd", "lbfgs"];

const ITERS: usize = 20;

/// The served form of each optimizer config (identical to the solo
/// configs in [`solo_run`]).
fn serve_opt(opt: &str) -> ServeOptimizer {
    match opt {
        "gd" => ServeOptimizer::Gd(GdConfig { zeta: 0.5, epsilon: Some(0.3), ..Default::default() }),
        "sgd" => ServeOptimizer::Sgd(SgdConfig {
            lr: Some(0.02),
            schedule: LrSchedule::InvT { t0: 10.0 },
            momentum: 0.5,
            batch_frac: 0.5,
            seed: 5,
            ..Default::default()
        }),
        "lbfgs" => ServeOptimizer::Lbfgs(LbfgsConfig { epsilon: Some(0.3), ..Default::default() }),
        other => panic!("unknown optimizer {other}"),
    }
}

/// Solo baseline: the same spec on its own fresh engine + cluster,
/// through the classic [`Optimizer::run`] path.
fn solo_run(opt: &str, enc: &EncodedProblem, scenario: Option<&str>) -> RunOutput {
    let mut cluster =
        Cluster::new(enc, Box::new(NativeEngine::new(enc)), ccfg()).expect("cluster");
    if let Some(dsl) = scenario {
        cluster.set_scenario(Scenario::parse(dsl).unwrap()).unwrap();
    }
    let out: Result<RunOutput> = match opt {
        "gd" => CodedGd::new(GdConfig { zeta: 0.5, epsilon: Some(0.3), ..Default::default() })
            .run(enc, &mut cluster, ITERS),
        "sgd" => CodedSgd::new(SgdConfig {
            lr: Some(0.02),
            schedule: LrSchedule::InvT { t0: 10.0 },
            momentum: 0.5,
            batch_frac: 0.5,
            seed: 5,
            ..Default::default()
        })
        .run(enc, &mut cluster, ITERS),
        "lbfgs" => CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.3), ..Default::default() })
            .run(enc, &mut cluster, ITERS),
        other => panic!("unknown optimizer {other}"),
    };
    out.expect("solo run")
}

fn submit_job(
    server: &mut JobServer,
    enc: &Arc<EncodedProblem>,
    opt: &str,
    scenario: Option<Scenario>,
) -> usize {
    server
        .submit(JobSpec {
            enc: Arc::clone(enc),
            cluster: ccfg(),
            optimizer: serve_opt(opt),
            iters: ITERS,
            w0: None,
            scenario,
            priority: 0,
        })
        .expect("submit")
}

// -------------------------------------------------- solo equivalence

/// 9 concurrent jobs (every optimizer × scheme) interleaved on one
/// pool: each job's trace and final iterate must equal its solo run.
#[test]
fn served_jobs_match_solo_runs_bitwise() {
    let mut server = JobServer::with_lanes(3, ServePolicy::Fair);
    let mut specs = Vec::new();
    for &(kind, beta) in SCHEMES {
        for &opt in OPTS {
            let enc = Arc::new(fixture(kind, beta));
            let id = submit_job(&mut server, &enc, opt, None);
            specs.push((id, opt, kind, enc));
        }
    }
    let outcomes = server.run().expect("serve");
    assert_eq!(outcomes.len(), specs.len());
    for ((id, opt, kind, enc), o) in specs.iter().zip(&outcomes) {
        assert_eq!(o.job, *id);
        assert_eq!(o.rounds, ITERS, "{opt}/{kind:?}: round count");
        let solo = solo_run(opt, enc, None);
        assert_eq!(
            o.output.trace.to_csv(),
            solo.trace.to_csv(),
            "{opt}/{kind:?}: served trace differs from the solo run"
        );
        assert_eq!(o.output.w.len(), solo.w.len());
        for (a, b) in o.output.w.iter().zip(&solo.w) {
            assert_eq!(a.to_bits(), b.to_bits(), "{opt}/{kind:?}: final iterate differs");
        }
    }
    // the jobs genuinely interleaved: under fair scheduling every job is
    // dispatched exactly ITERS rounds, round-robin
    for (id, opt, kind, _) in &specs {
        let n = server.schedule().iter().filter(|&&j| j == *id).count();
        assert_eq!(n, ITERS, "{opt}/{kind:?}: dispatched rounds");
    }
    let first_sweep: Vec<usize> = server.schedule()[..specs.len()].to_vec();
    let ids: Vec<usize> = specs.iter().map(|(id, ..)| *id).collect();
    assert_eq!(first_sweep, ids, "fair scheduling must round-robin the first sweep");
}

// --------------------------------------------- scheduling invisibility

/// The scheduling policy decides only *when* a job's rounds run, never
/// what they compute: per-job traces are policy-invariant.
#[test]
fn scheduling_policy_is_invisible_to_job_results() {
    let run_with = |policy: ServePolicy| -> Vec<String> {
        let enc = Arc::new(fixture(EncoderKind::Hadamard, 2.0));
        let mut server = JobServer::with_lanes(2, policy);
        for (j, &opt) in OPTS.iter().enumerate() {
            server
                .submit(JobSpec {
                    enc: Arc::clone(&enc),
                    cluster: ccfg(),
                    optimizer: serve_opt(opt),
                    iters: ITERS,
                    w0: None,
                    scenario: None,
                    priority: j,
                })
                .expect("submit");
        }
        server.run().expect("serve").iter().map(|o| o.output.trace.to_csv()).collect()
    };
    let fair = run_with(ServePolicy::Fair);
    assert_eq!(fair, run_with(ServePolicy::Fifo), "fifo vs fair");
    assert_eq!(fair, run_with(ServePolicy::Priority { classes: 2 }), "priority vs fair");
}

/// Pool lane count is equally invisible (1-lane serial pool vs wide
/// pool).
#[test]
fn pool_width_is_invisible_to_served_jobs() {
    let run_width = |threads: usize| -> Vec<String> {
        let enc = Arc::new(fixture(EncoderKind::Hadamard, 2.0));
        let mut server = JobServer::with_lanes(threads, ServePolicy::Fair);
        for &opt in OPTS {
            submit_job(&mut server, &enc, opt, None);
        }
        server.run().expect("serve").iter().map(|o| o.output.trace.to_csv()).collect()
    };
    assert_eq!(run_width(1), run_width(4), "lane layout leaked into served traces");
}

// ------------------------------------------------------- encode cache

/// A sweep of identical jobs encodes once: the second submission is a
/// cache hit sharing the same `Arc`, and both jobs still reproduce the
/// solo trace.
#[test]
fn identical_jobs_share_one_encode() {
    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    let mut cache = EncodedShardCache::new();
    let mut server = JobServer::with_lanes(2, ServePolicy::Fifo);
    for _ in 0..2 {
        let enc = cache
            .get_or_encode(&prob, EncoderKind::Hadamard, 2.0, 8, 3, StorageKind::Dense)
            .expect("cache encode");
        submit_job(&mut server, &enc, "gd", None);
    }
    assert_eq!(
        (cache.encodes(), cache.hits()),
        (1, 1),
        "second identical job must hit the shard cache, not re-encode"
    );
    let outcomes = server.run().expect("serve");
    assert_eq!(outcomes[0].output.trace.to_csv(), outcomes[1].output.trace.to_csv());
    let solo = solo_run("gd", &fixture(EncoderKind::Hadamard, 2.0), None);
    assert_eq!(
        outcomes[0].output.trace.to_csv(),
        solo.trace.to_csv(),
        "cache-shared encode changed the trace"
    );
}

// ------------------------------------------------------ fault isolation

/// A crash/slow scenario scoped to one job: the scoped job reproduces
/// the solo faulted run; siblings submitted before *and* after it stay
/// byte-identical to the clean solo run.
#[test]
fn job_scoped_faults_leave_siblings_untouched() {
    let dsl = "crash:2@3,slow:1:3@5,recover:2@9;admit:rotate:k";
    let enc = Arc::new(fixture(EncoderKind::Hadamard, 2.0));
    let mut server = JobServer::with_lanes(2, ServePolicy::Fair);
    for j in 0..3 {
        let scenario = (j == 1).then(|| Scenario::parse(dsl).unwrap());
        submit_job(&mut server, &enc, "gd", scenario);
    }
    let outcomes = server.run().expect("serve");
    let clean = solo_run("gd", &enc, None).trace.to_csv();
    let faulted = solo_run("gd", &enc, Some(dsl)).trace.to_csv();
    assert_ne!(clean, faulted, "fixture scenario must actually perturb the trace");
    assert_eq!(outcomes[0].output.trace.to_csv(), clean, "sibling before the faulted job");
    assert_eq!(outcomes[1].output.trace.to_csv(), faulted, "scoped job must see its scenario");
    assert_eq!(outcomes[2].output.trace.to_csv(), clean, "sibling after the faulted job");
    assert!(faulted.contains("crash:2@3") && faulted.contains("slow:1"), "events logged");
    assert!(!clean.contains("crash:") && !clean.contains("slow:"), "siblings saw no events");
}
