//! Fault-injection scenarios + the golden-trace regression harness.
//!
//! Three layers of pinning:
//!
//! 1. **Golden traces** — every optimizer (GD, SGD, L-BFGS, FISTA) ×
//!    scheme (hadamard, replication, uncoded) × storage (dense, CSR) runs
//!    a fixed deterministic workload on the `const:` delay model under
//!    `ClockMode::Virtual`, and its full CSV trace must match the
//!    checked-in golden under `rust/tests/golden/` **byte for byte**.
//!    A missing golden is bootstrapped (written and reported) so the
//!    first toolchain run pins the baseline; `UPDATE_GOLDEN=1` (or
//!    `tools/regen_golden.sh`) rewrites intentionally. The multi-tenant
//!    serve mode is pinned the same way: two fair-share jobs on one
//!    pool, with and without a job-scoped `slow:` script.
//! 2. **Scenario semantics** — crash/recover, slow-onset, rack-wide
//!    correlated stragglers, churn, and the `admit:` subset grammar drive
//!    the round machinery end to end, including the defined empty-round
//!    behavior when every worker is gone.
//! 3. **The adversarial acceptance case** — under `admit:rotate:k`
//!    (worst-case rotating m−k stragglers) on a problem whose dominant
//!    data block contradicts the rest, hadamard-coded GD and SGD stay in
//!    the Theorem-1 neighborhood at *every* phase of the rotation while
//!    the uncoded baseline is yanked away from the true solution each
//!    cycle; the whole trace replays bit-for-bit from the scenario
//!    file alone.

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel, Scenario};
use codedopt::config::Json;
use codedopt::encoding::EncoderKind;
use codedopt::linalg::{Mat, StorageKind};
use codedopt::optim::{
    CodedFista, CodedGd, CodedLbfgs, CodedSgd, FistaConfig, GdConfig, LbfgsConfig, LrSchedule,
    Optimizer, Prox, RunOutput, SgdConfig,
};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::rng::Pcg64;
use codedopt::runtime::{NativeEngine, RebalanceConfig};
use std::path::PathBuf;

// ---------------------------------------------------------------- helpers

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Compare `csv` against the checked-in golden `name`, bootstrapping the
/// file when absent and rewriting it under `UPDATE_GOLDEN=1`. On mismatch
/// the panic message names the first differing line.
fn check_golden(name: &str, csv: &str) {
    let path = golden_dir().join(name);
    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !path.exists() {
        std::fs::create_dir_all(golden_dir()).expect("creating tests/golden");
        std::fs::write(&path, csv).expect("writing golden");
        if !update {
            eprintln!(
                "golden {name}: no checked-in baseline — bootstrapped; \
                 commit rust/tests/golden/{name} to pin it"
            );
        }
        return;
    }
    let want = std::fs::read_to_string(&path).expect("reading golden");
    if want == csv {
        return;
    }
    for (i, (w, g)) in want.lines().zip(csv.lines()).enumerate() {
        assert_eq!(
            g, w,
            "golden {name} drifted at line {} (run tools/regen_golden.sh if intended)",
            i + 1
        );
    }
    panic!(
        "golden {name} drifted: line count {} vs {} (run tools/regen_golden.sh if intended)",
        csv.lines().count(),
        want.lines().count()
    );
}

/// The fixed golden workload: small ridge problem, 8 workers, k = 6,
/// deterministic `const:2` delays, virtual clock.
fn golden_cluster(
    kind: EncoderKind,
    beta: f64,
    storage: StorageKind,
) -> (EncodedProblem, Cluster) {
    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    let enc = EncodedProblem::encode_stored(&prob, kind, beta, 8, 3, storage).expect("encode");
    let eng = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Constant { ms: 2.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 11,
    };
    let cluster = Cluster::new(&enc, eng, cfg).expect("cluster");
    (enc, cluster)
}

/// scheme/storage combos the golden matrix covers (sparse storage only
/// where the scheme preserves it; hadamard densifies by construction).
const COMBOS: &[(&str, EncoderKind, f64, StorageKind)] = &[
    ("hadamard_dense", EncoderKind::Hadamard, 2.0, StorageKind::Dense),
    ("replication_dense", EncoderKind::Replication, 2.0, StorageKind::Dense),
    ("replication_sparse", EncoderKind::Replication, 2.0, StorageKind::Sparse),
    ("uncoded_dense", EncoderKind::Identity, 1.0, StorageKind::Dense),
    ("uncoded_sparse", EncoderKind::Identity, 1.0, StorageKind::Sparse),
];

const GOLDEN_ITERS: usize = 20;

fn run_optimizer(
    opt: &str,
    enc: &EncodedProblem,
    cluster: &mut Cluster,
    iters: usize,
) -> RunOutput {
    match opt {
        "gd" => CodedGd::new(GdConfig { zeta: 0.5, epsilon: Some(0.3), ..Default::default() })
            .run(enc, cluster, iters)
            .expect("gd run"),
        "sgd" => CodedSgd::new(SgdConfig {
            lr: Some(0.02),
            schedule: LrSchedule::InvT { t0: 10.0 },
            momentum: 0.5,
            batch_frac: 0.5,
            seed: 5,
            ..Default::default()
        })
        .run(enc, cluster, iters)
        .expect("sgd run"),
        "lbfgs" => CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.3), ..Default::default() })
            .run(enc, cluster, iters)
            .expect("lbfgs run"),
        "fista" => CodedFista::new(FistaConfig {
            prox: Prox::L1 { l1: 0.001 },
            epsilon: Some(0.3),
            ..Default::default()
        })
        .run(enc, cluster, iters)
        .expect("fista run"),
        other => panic!("unknown optimizer {other}"),
    }
}

fn golden_matrix_for(opt: &str) {
    for &(combo, kind, beta, storage) in COMBOS {
        let (enc, mut cluster) = golden_cluster(kind, beta, storage);
        let out = run_optimizer(opt, &enc, &mut cluster, GOLDEN_ITERS);
        assert_eq!(out.trace.len(), GOLDEN_ITERS, "{opt}/{combo}: short trace");
        assert!(
            out.trace.records.iter().all(|r| r.f_true.is_finite()),
            "{opt}/{combo}: non-finite objective"
        );
        check_golden(&format!("{opt}_{combo}.csv"), &out.trace.to_csv());
    }
}

// -------------------------------------------------- golden-trace harness

#[test]
fn golden_traces_gd() {
    golden_matrix_for("gd");
}

#[test]
fn golden_traces_sgd() {
    golden_matrix_for("sgd");
}

#[test]
fn golden_traces_lbfgs() {
    golden_matrix_for("lbfgs");
}

#[test]
fn golden_traces_fista() {
    golden_matrix_for("fista");
}

/// Scenario-annotated golden: the event-annotated trace (events column
/// included) is pinned byte for byte too.
#[test]
fn golden_trace_gd_with_scenario() {
    let dsl = "slow:2:3@5,crash:3@8,recover:3@14;admit:rotate:k";
    let (enc, mut cluster) =
        golden_cluster(EncoderKind::Hadamard, 2.0, StorageKind::Dense);
    cluster.set_scenario(Scenario::parse(dsl).unwrap()).unwrap();
    let out = run_optimizer("gd", &enc, &mut cluster, GOLDEN_ITERS);
    let csv = out.trace.to_csv();
    assert!(csv.contains("crash:3@8"), "events column missing the crash annotation");
    assert!(csv.contains("recover:3@14"), "events column missing the recover annotation");
    check_golden("gd_hadamard_dense_scenario.csv", &csv);
}

/// Rebalancing goldens: the elastic resharder on the golden cluster
/// (`const:2`, k = 6) is pinned byte for byte — migration schedule and
/// all — for a single scripted slow worker and for a rack-wide slowdown.
/// Bootstrap-on-missing applies exactly as for the static goldens.
fn golden_rebalanced(name: &str, dsl: &str, first_move: &str) {
    let (enc, mut cluster) = golden_cluster(EncoderKind::Hadamard, 2.0, StorageKind::Dense);
    cluster.set_scenario(Scenario::parse(dsl).unwrap()).unwrap();
    cluster
        .set_rebalancer(&enc, RebalanceConfig::Ewma { alpha: 1.0, threshold: 1.5 })
        .unwrap();
    let out = run_optimizer("gd", &enc, &mut cluster, GOLDEN_ITERS);
    let csv = out.trace.to_csv();
    assert!(
        csv.contains(first_move),
        "{name}: rebalanced golden carries no {first_move:?} migration label"
    );
    check_golden(name, &csv);
}

#[test]
fn golden_trace_gd_rebalanced_slow_worker() {
    golden_rebalanced("gd_hadamard_dense_rebalance_slow.csv", "slow:2:3@5", "migrate:2>");
}

#[test]
fn golden_trace_gd_rebalanced_rack() {
    golden_rebalanced("gd_hadamard_dense_rebalance_rack.csv", "rack:0-2:4@10", "migrate:");
}

/// Multi-tenant serve goldens: two gd jobs fair-share one resident pool
/// on the golden workload; the pinned artifact concatenates each job's
/// CSV under a `# job N` header line. `scoped` optionally attaches a
/// scenario to one job id. Bootstrap-on-missing applies exactly as for
/// the static goldens. Returns the per-job CSVs for extra assertions.
fn golden_served(name: &str, scoped: Option<(usize, &str)>) -> Vec<String> {
    use codedopt::runtime::{JobServer, JobSpec, ServeOptimizer, ServePolicy};
    use std::sync::Arc;

    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    let enc = Arc::new(
        EncodedProblem::encode_stored(&prob, EncoderKind::Hadamard, 2.0, 8, 3, StorageKind::Dense)
            .expect("encode"),
    );
    let ccfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Constant { ms: 2.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 11,
    };
    let mut server = JobServer::with_lanes(2, ServePolicy::Fair);
    for j in 1..=2usize {
        let scenario = scoped
            .filter(|&(id, _)| id == j)
            .map(|(_, dsl)| Scenario::parse(dsl).unwrap());
        server
            .submit(JobSpec {
                enc: Arc::clone(&enc),
                cluster: ccfg.clone(),
                optimizer: ServeOptimizer::Gd(GdConfig {
                    zeta: 0.5,
                    epsilon: Some(0.3),
                    ..Default::default()
                }),
                iters: GOLDEN_ITERS,
                w0: None,
                scenario,
                priority: 0,
            })
            .expect("submit");
    }
    let outcomes = server.run().expect("serve");
    let csvs: Vec<String> = outcomes.iter().map(|o| o.output.trace.to_csv()).collect();
    let mut combined = String::new();
    for (o, csv) in outcomes.iter().zip(&csvs) {
        combined.push_str(&format!("# job {}\n", o.job));
        combined.push_str(csv);
    }
    check_golden(name, &combined);
    csvs
}

#[test]
fn golden_trace_serve_fair_two_jobs() {
    let csvs = golden_served("serve_fair_2job.csv", None);
    // same spec, same cluster seed: the two jobs must be bitwise twins
    assert_eq!(csvs[0], csvs[1], "identical specs must produce identical served traces");
}

/// A `slow:` script scoped to job 1 annotates only job 1's block; the
/// sibling stays byte-identical to a clean solo run of the same spec.
#[test]
fn golden_trace_serve_scoped_slow() {
    let dsl = "slow:2:3@5";
    let csvs = golden_served("serve_scoped_slow.csv", Some((1, dsl)));
    assert!(csvs[0].contains("slow:2:3@5"), "scoped job lost its event annotation");
    assert!(!csvs[1].contains("slow:"), "sibling observed the scoped scenario");
    let (enc, mut cluster) = golden_cluster(EncoderKind::Hadamard, 2.0, StorageKind::Dense);
    let solo = run_optimizer("gd", &enc, &mut cluster, GOLDEN_ITERS);
    assert_eq!(csvs[1], solo.trace.to_csv(), "sibling trace drifted from its solo run");
}

/// The per-*iteration* rotate contract, pinned end to end: L-BFGS runs
/// two cluster rounds per iteration (gradient + line search), so a
/// rotate window that slid per *dispatch* would step the adversary twice
/// as fast and hand the line search a different straggler set than its
/// own gradient round. The golden trace pins the per-iteration sliding
/// byte for byte; the responder assertion catches the half-window
/// regression directly (with `rotate:k` every round still admits k, but
/// the trace bytes shift because the admitted *sets* change).
#[test]
fn golden_trace_lbfgs_rotate_slides_per_iteration() {
    let (enc, mut cluster) = golden_cluster(EncoderKind::Hadamard, 2.0, StorageKind::Dense);
    cluster.set_scenario(Scenario::parse("admit:rotate:k").unwrap()).unwrap();
    let out = run_optimizer("lbfgs", &enc, &mut cluster, GOLDEN_ITERS);
    for r in &out.trace.records {
        assert_eq!(r.responders, 6, "rotate:k admits exactly k each iteration");
    }
    check_golden("lbfgs_hadamard_dense_rotate.csv", &out.trace.to_csv());
}

/// L-BFGS runs two cluster rounds per iteration (gradient + line
/// search); events firing on the line-search round must still reach the
/// iteration's trace record.
#[test]
fn lbfgs_trace_carries_linesearch_round_events() {
    let (enc, mut cluster) = golden_cluster(EncoderKind::Hadamard, 2.0, StorageKind::Dense);
    // scenario round 1 is iteration 0's line-search round
    cluster.set_scenario(Scenario::parse("crash:3@1,recover:3@4").unwrap()).unwrap();
    let out = run_optimizer("lbfgs", &enc, &mut cluster, 4);
    assert!(
        out.trace.records[0].events.contains("crash:3@1"),
        "line-search round event lost: {:?}",
        out.trace.records.iter().map(|r| r.events.clone()).collect::<Vec<_>>()
    );
    assert!(
        out.trace.records[2].events.contains("recover:3@4"),
        "gradient-round event lost (round 4 = iteration 2's gradient round)"
    );
}

/// The golden CSVs themselves are deterministic within a session: two
/// fresh runs of one combo emit identical bytes (this is what the CI
/// drift job re-checks across whole `cargo test` invocations).
#[test]
fn golden_workload_is_deterministic() {
    let run = || {
        let (enc, mut cluster) =
            golden_cluster(EncoderKind::Hadamard, 2.0, StorageKind::Dense);
        run_optimizer("lbfgs", &enc, &mut cluster, GOLDEN_ITERS).trace.to_csv()
    };
    assert_eq!(run(), run());
}

// ------------------------------------------- empty-round defined behavior

/// `ExpWithFailures` with p_fail = 1: every worker fails every round. The
/// round must complete with a defined empty result — no deadlock, no
/// divide-by-zero — and the aggregation falls back to the ridge-only
/// gradient.
#[test]
fn all_workers_failing_yields_defined_empty_rounds() {
    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 3).unwrap();
    let eng = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::ExpWithFailures { mean_ms: 1.0, p_fail: 1.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 0,
    };
    let mut cluster = Cluster::new(&enc, eng, cfg).unwrap();
    let w = vec![0.3; 8];

    let (responses, round) = cluster.grad_round(&w).unwrap();
    assert!(responses.is_empty());
    assert!(round.admitted.is_empty());
    assert_eq!(round.failed, (0..8).collect::<Vec<_>>());
    assert_eq!(round.elapsed_ms, 0.0);
    assert_eq!(round.admitted_compute_ms(), 0.0);

    // aggregation over zero responders: exactly the ridge term, finite
    let (g, f_est) = enc.aggregate_grad(&w, &responses);
    for (gi, wi) in g.iter().zip(&w) {
        assert_eq!(*gi, prob.lambda * wi, "empty-round gradient must be ridge-only");
    }
    assert!(f_est.is_finite());

    // the mini-batch path too (this is where a division by b could hide)
    let mut rng = Pcg64::seeded(4);
    let plan = enc.sample_batch(0.5, &mut rng);
    let (responses, round) = cluster.grad_batch_round(&w, &plan).unwrap();
    assert!(responses.is_empty() && round.admitted.is_empty());
    let (g, f_est) = enc.aggregate_grad_batch(&w, &responses, &plan);
    assert!(f_est.is_finite());
    for (gi, wi) in g.iter().zip(&w) {
        assert_eq!(*gi, prob.lambda * wi);
    }
}

/// A full optimizer run across all-failed rounds stays finite and makes
/// no progress (the iterate only feels the ridge shrinkage).
#[test]
fn optimizers_survive_rounds_with_no_responders() {
    for opt in ["gd", "sgd"] {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.1, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 1).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: 4,
            delay: DelayModel::ExpWithFailures { mean_ms: 1.0, p_fail: 1.0 },
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5,
            seed: 2,
        };
        let mut cluster = Cluster::new(&enc, eng, cfg).unwrap();
        let out = run_optimizer(opt, &enc, &mut cluster, 5);
        assert_eq!(out.trace.len(), 5, "{opt}");
        for r in &out.trace.records {
            assert!(r.f_true.is_finite(), "{opt}: objective went non-finite");
            assert_eq!(r.responders, 0, "{opt}");
            assert_eq!(r.sim_ms, 0.0, "{opt}: empty rounds advance no simulated time");
            // regression: the per-record compute-time summary averages
            // over the admitted set; on an all-workers-gone round it is
            // *defined* as 0.0, never a 0/0 NaN
            assert_eq!(r.compute_ms, 0.0, "{opt}: empty-round compute_ms must be 0");
        }
        let csv = out.trace.to_csv();
        assert!(!csv.contains("NaN"), "{opt}: NaN leaked into the trace CSV:\n{csv}");
    }
}

/// Scenario-scripted total loss: crash every worker mid-run, then recover
/// one. Works under both clocks — the measured-mode collector must cancel
/// immediately instead of waiting for admissions that can never come.
#[test]
fn crash_all_scenario_is_defined_under_both_clocks() {
    let dsl = "crash:0@2,crash:1@2,crash:2@2,crash:3@2,recover:1@4";
    for clock in [ClockMode::Virtual, ClockMode::Measured] {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.05, 3);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 4, 1).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig {
            workers: 4,
            wait_for: 3,
            delay: DelayModel::None,
            clock,
            ms_per_mflop: 0.5,
            seed: 0,
        };
        let mut cluster = Cluster::new(&enc, eng, cfg).unwrap();
        cluster.set_scenario(Scenario::parse(dsl).unwrap()).unwrap();
        let w = vec![0.1; 6];
        let mut responders = Vec::new();
        for _ in 0..5 {
            let (responses, round) = cluster.grad_round(&w).unwrap();
            assert_eq!(responses.len(), round.admitted.len(), "{clock:?}");
            responders.push(round.admitted.len());
        }
        assert_eq!(responders[..2], [3, 3], "{clock:?}: healthy rounds admit k");
        assert_eq!(responders[2..4], [0, 0], "{clock:?}: crash-all rounds are empty");
        assert_eq!(responders[4], 1, "{clock:?}: the recovered worker responds alone");
    }
}

// ------------------------------------- the adversarial acceptance case

/// A problem whose dominant data block *contradicts* the rest: heavy rows
/// (10x scale, workers' shard 0 under the uncoded 8-way split) want
/// `-w0`, the light rows want `+w0`. The true solution tracks the heavy
/// block; any scheme that ever optimizes from the light rows alone is
/// pulled far away.
fn adversarial_problem() -> QuadProblem {
    let (n, p, heavy, scale) = (256usize, 12usize, 32usize, 10.0);
    let mut rng = Pcg64::new(77, 0xadba);
    let w0: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
    let x = Mat::from_fn(n, p, |i, _| {
        let g = rng.next_gaussian();
        if i < heavy {
            scale * g
        } else {
            g
        }
    });
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let t: f64 = x.row(i).iter().zip(&w0).map(|(a, b)| a * b).sum();
            if i < heavy {
                -t
            } else {
                t
            }
        })
        .collect();
    QuadProblem::new(x, y, 0.01)
}

fn adversarial_cluster(prob: &QuadProblem, kind: EncoderKind, beta: f64) -> (EncodedProblem, Cluster) {
    let enc = EncodedProblem::encode(prob, kind, beta, 8, 13).unwrap();
    let eng = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Exp { mean_ms: 10.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 13,
    };
    let mut cluster = Cluster::new(&enc, eng, cfg).unwrap();
    cluster.set_scenario(Scenario::parse("admit:rotate:k").unwrap()).unwrap();
    (enc, cluster)
}

/// Worst gap over the last full rotation cycle (all 8 window phases), so
/// the statistic cannot be gamed by sampling a lucky phase.
fn worst_last_cycle_gap(out: &RunOutput, f_star: f64) -> f64 {
    let recs = &out.trace.records;
    recs[recs.len() - 8..]
        .iter()
        .map(|r| r.f_true - f_star)
        .fold(f64::NEG_INFINITY, f64::max)
}

/// Theorem 1's claim under the worst-case rotating straggler set: the
/// hadamard-coded optimizers converge to (and stay in) a neighborhood of
/// the optimum at every rotation phase, while the uncoded baseline is
/// yanked off the true solution every time the rotation excludes the
/// dominant shard.
#[test]
fn adversarial_rotation_coded_converges_uncoded_drifts() {
    let iters = 400;
    let prob = adversarial_problem();
    let w_star = prob.exact_solution().unwrap();
    let f_star = prob.objective(&w_star);
    let f0 = prob.objective(&vec![0.0; prob.p()]);
    let span = f0 - f_star;
    assert!(span > 0.0);

    // hadamard-coded GD: Theorem-1 default step (estimated epsilon)
    let (enc_c, mut cl_c) = adversarial_cluster(&prob, EncoderKind::Hadamard, 2.0);
    let gd = CodedGd::new(GdConfig::default());
    let out_c = gd.run(&enc_c, &mut cl_c, iters).unwrap();
    assert!(!out_c.trace.diverged(), "coded GD diverged under rotate:k");
    let worst_c = worst_last_cycle_gap(&out_c, f_star);
    assert!(
        worst_c < 0.35 * span,
        "coded GD left the Theorem-1 neighborhood: worst last-cycle gap {worst_c:.3e} \
         vs span {span:.3e}"
    );

    // hadamard-coded SGD (mini-batch rounds under the same rotation)
    let (enc_s, mut cl_s) = adversarial_cluster(&prob, EncoderKind::Hadamard, 2.0);
    let sgd = CodedSgd::new(SgdConfig { batch_frac: 0.5, seed: 9, ..Default::default() });
    let out_s = sgd.run(&enc_s, &mut cl_s, iters).unwrap();
    assert!(!out_s.trace.diverged(), "coded SGD diverged under rotate:k");
    let best_s = out_s.trace.best_objective() - f_star;
    assert!(
        best_s < 0.5 * span,
        "coded SGD made no progress under rotate:k: best gap {best_s:.3e} vs span {span:.3e}"
    );
    let worst_s = worst_last_cycle_gap(&out_s, f_star);
    assert!(
        worst_s < 0.6 * span,
        "coded SGD left its neighborhood: worst last-cycle gap {worst_s:.3e}"
    );

    // uncoded baseline, identical optimizer and rotation
    let (enc_u, mut cl_u) = adversarial_cluster(&prob, EncoderKind::Identity, 1.0);
    let out_u = gd.run(&enc_u, &mut cl_u, iters).unwrap();
    let worst_u = worst_last_cycle_gap(&out_u, f_star);
    assert!(
        worst_u > 3.0 * worst_c.max(1e-12),
        "uncoded should be yanked well off the optimum every cycle: \
         uncoded worst {worst_u:.3e} vs coded worst {worst_c:.3e}"
    );
    assert!(
        worst_u > 3e-3 * span,
        "uncoded worst-phase gap {worst_u:.3e} unexpectedly small vs span {span:.3e}"
    );
}

/// The full adversarial trace replays bit-for-bit from the scenario file
/// alone under the virtual clock: DSL string, JSON round-trip, and a
/// re-run all emit identical CSV bytes.
#[test]
fn adversarial_trace_replays_bit_for_bit() {
    let dsl = "slow:4:3@20,crash:7@50,recover:7@120;admit:rotate:k";
    let run_from = |scenario: Scenario| -> String {
        let prob = adversarial_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 13).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: 6,
            delay: DelayModel::Exp { mean_ms: 10.0 },
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5,
            seed: 13,
        };
        let mut cluster = Cluster::new(&enc, eng, cfg).unwrap();
        cluster.set_scenario(scenario).unwrap();
        let gd = CodedGd::new(GdConfig { epsilon: Some(0.3), ..Default::default() });
        gd.run(&enc, &mut cluster, 160).unwrap().trace.to_csv()
    };

    let direct = run_from(Scenario::parse(dsl).unwrap());

    // through the JSON config surface (what --scenario-json reads)
    let json_text = Scenario::parse(dsl).unwrap().to_json();
    let from_json = run_from(Scenario::from_json(&Json::parse(&json_text).unwrap()).unwrap());
    assert_eq!(direct, from_json, "JSON-loaded scenario produced a different trace");

    // and a plain re-run
    assert_eq!(direct, run_from(Scenario::parse(dsl).unwrap()));

    // the trace is event-annotated where the script fired
    assert!(direct.contains("crash:7@50"));
    assert!(direct.contains("slow:4:3@20"));
}
