//! End-to-end integration: the complete system (data → encode → simulated
//! straggler cluster → coding-oblivious optimizer → decoded solution) at
//! realistic-but-fast scales, exercising every scheme + both algorithms.

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel};
use codedopt::encoding::EncoderKind;
use codedopt::linalg;
use codedopt::optim::{CodedGd, CodedLbfgs, GdConfig, LbfgsConfig, Optimizer, RunOutput};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::runtime::NativeEngine;

#[allow(clippy::too_many_arguments)]
fn run(
    prob: &QuadProblem,
    kind: EncoderKind,
    beta: f64,
    m: usize,
    k: usize,
    iters: usize,
    lbfgs: bool,
    seed: u64,
) -> RunOutput {
    let enc = EncodedProblem::encode(prob, kind, beta, m, seed).unwrap();
    let engine = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: m,
        wait_for: k,
        delay: DelayModel::Exp { mean_ms: 10.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed,
    };
    let mut cluster = Cluster::new(&enc, engine, cfg).unwrap();
    if lbfgs {
        CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.2), seed, ..Default::default() })
            .run(&enc, &mut cluster, iters)
            .unwrap()
    } else {
        CodedGd::new(GdConfig { epsilon: Some(0.2), seed, ..Default::default() })
            .run(&enc, &mut cluster, iters)
            .unwrap()
    }
}

/// Every coded family solves the same problem to a small neighborhood
/// with k < m; the theory's promise, end to end. A planted problem keeps
/// f* ≈ 0, so the Theorem-1/2 neighborhood (∝ f*) is tiny and convergence
/// is crisp for every family.
#[test]
fn all_coded_families_converge_with_stragglers() {
    let (prob, _) = QuadProblem::planted(256, 24, 0.0, 0.01, 11);
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let f0 = prob.objective(&[0.0; 24]);
    for kind in [
        EncoderKind::Gaussian,
        EncoderKind::Hadamard,
        EncoderKind::Dft,
        EncoderKind::PaleyEtf,
        EncoderKind::HadamardEtf,
        EncoderKind::SteinerEtf,
    ] {
        let out = run(&prob, kind, 2.0, 8, 6, 80, true, 11);
        let gap = out.trace.best_objective() - f_star;
        assert!(
            gap < 0.02 * (f0 - f_star),
            "{kind:?}: gap {gap:.4e} too large (f0−f* = {:.4e})",
            f0 - f_star
        );
        assert!(!out.trace.diverged(), "{kind:?} diverged");
    }
}

/// GD (Theorem 1) and L-BFGS (Theorem 2) both converge; L-BFGS needs far
/// fewer iterations on an ill-conditioned problem (the reason the paper
/// uses it for the experiments).
#[test]
fn both_algorithms_converge_lbfgs_faster() {
    // planted problem with geometrically decaying column scales:
    // condition number ~1e2 — GD crawls, L-BFGS does not
    let (base, w_star) = QuadProblem::planted(256, 16, 0.0, 0.0, 13);
    let p = 16usize;
    let x = codedopt::linalg::Mat::from_fn(256, p, |i, j| {
        base.x.get(i, j) * (0.1f64 + 0.9 * (j as f64 / (p - 1) as f64)).powi(2)
    });
    let y = x.gemv(&w_star);
    let prob = QuadProblem::new(x, y, 0.0);
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let gd = run(&prob, EncoderKind::Hadamard, 2.0, 8, 7, 40, false, 13);
    let lb = run(&prob, EncoderKind::Hadamard, 2.0, 8, 7, 40, true, 13);
    let gap_gd = gd.trace.last_objective() - f_star;
    let gap_lb = lb.trace.last_objective() - f_star;
    assert!(gap_gd.is_finite() && gap_lb.is_finite());
    assert!(
        gap_lb < gap_gd,
        "L-BFGS gap {gap_lb:.3e} should beat GD gap {gap_gd:.3e} at equal iterations"
    );
}

/// The paper's central comparison at small η, in the paper's regime
/// (p > n, where a lost partition loses irrecoverable directions):
/// coded beats uncoded decisively; replication sits in between on
/// average (seed-averaged).
#[test]
fn coded_beats_uncoded_at_small_eta() {
    let prob = QuadProblem::synthetic_gaussian(128, 192, 0.05, 17);
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let mean_gap = |kind: EncoderKind, beta: f64| -> f64 {
        (0..3)
            .map(|s| {
                let out = run(&prob, kind, beta, 8, 3, 100, true, 17 + s);
                out.trace.best_objective() - f_star
            })
            .sum::<f64>()
            / 3.0
    };
    let g_coded = mean_gap(EncoderKind::Hadamard, 2.0);
    let g_repl = mean_gap(EncoderKind::Replication, 2.0);
    let g_uncoded = mean_gap(EncoderKind::Identity, 1.0);
    assert!(
        g_coded < g_uncoded,
        "coded {g_coded:.3e} should beat uncoded {g_uncoded:.3e}"
    );
    // Replication is a strong baseline at this η on *average* (the paper's
    // Tables show the same — its weakness is worst-case smoothness, cf.
    // Fig. 4); require only that it, too, beats uncoded and stays finite.
    assert!(
        g_repl < g_uncoded && g_repl.is_finite(),
        "replication {g_repl:.3e} should beat uncoded {g_uncoded:.3e}"
    );
}

/// Tight-frame exactness: with k = m, the coded solution matches the true
/// ridge optimum to solver precision (the §4 optimality-preservation
/// property), while Gaussian coding does not recover it exactly.
#[test]
fn tight_frame_exact_at_full_participation_gaussian_not() {
    let prob = QuadProblem::synthetic_gaussian(128, 12, 0.05, 19);
    let w_star = prob.exact_solution().unwrap();
    let had = run(&prob, EncoderKind::Hadamard, 2.0, 4, 4, 150, true, 19);
    let rel_had = linalg::norm2(&linalg::sub(&had.w, &w_star)) / linalg::norm2(&w_star);
    assert!(rel_had < 1e-4, "tight frame k=m should recover w*: rel {rel_had:.2e}");

    let gau = run(&prob, EncoderKind::Gaussian, 2.0, 4, 4, 150, true, 19);
    let rel_gau = linalg::norm2(&linalg::sub(&gau.w, &w_star)) / linalg::norm2(&w_star);
    assert!(
        rel_gau > rel_had,
        "gaussian (non-tight) should be less exact: {rel_gau:.2e} vs {rel_had:.2e}"
    );
}

/// Fail-stop resilience: with worker failures on top of delays, the coded
/// system still converges (fewer than k responders is tolerated).
#[test]
fn coded_survives_failstop_workers() {
    let prob = QuadProblem::synthetic_gaussian(256, 16, 0.05, 23);
    let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 23).unwrap();
    let engine = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::ExpWithFailures { mean_ms: 10.0, p_fail: 0.15 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 23,
    };
    let mut cluster = Cluster::new(&enc, engine, cfg).unwrap();
    let out = CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.3), ..Default::default() })
        .run(&enc, &mut cluster, 80)
        .unwrap();
    assert!(!out.trace.diverged(), "diverged under fail-stop");
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let f0 = prob.objective(&[0.0; 16]);
    assert!(
        out.trace.best_objective() - f_star < 0.1 * (f0 - f_star),
        "no convergence under failures"
    );
}

/// The end-to-end MF pipeline: synthetic data → split → coded ALS →
/// sane RMSE, with both local and distributed solves exercised.
#[test]
fn mf_pipeline_end_to_end() {
    use codedopt::mf::{synthetic_movielens, train, MfConfig, SyntheticConfig};
    let all = synthetic_movielens(&SyntheticConfig::small(29));
    let (tr, te) = all.split(0.2, 29);
    let cfg = MfConfig {
        embed: 8,
        epochs: 2,
        m: 4,
        k: 3,
        encoder: EncoderKind::Hadamard,
        dist_threshold: 48,
        lbfgs_iters: 6,
        seed: 29,
        ..Default::default()
    };
    let out = train(&tr, &te, &cfg).unwrap();
    assert!(out.dist_solves > 0 && out.local_solves > 0);
    assert!(*out.test_rmse.last().unwrap() < 1.1, "test rmse {:?}", out.test_rmse);
    assert!(out.total_ms() > 0.0);
}
