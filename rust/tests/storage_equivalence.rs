//! Dense ≡ sparse storage equivalence — the contract behind
//! `--storage dense|sparse|auto`.
//!
//! Two layers of guarantee:
//!
//! 1. **Kernel property tests** pin every kernel the workers run (`gemv`,
//!    `gemv_t`, `fused_grad`, `fused_grad_range`, `gram`) to agree between
//!    the dense and CSR backends within 1e-12 over random matrices that
//!    include empty rows, structurally zero columns, and partial /
//!    wrap-around batch ranges. (The hot-path kernels in fact agree *bit
//!    for bit* — the CSR kernels mirror the dense accumulation order — and
//!    a dedicated test pins that stronger property.)
//! 2. **Trace equivalence**: on MovieLens-shaped data (the sparse one-hot
//!    ratings design), a replication-encoded run under the Virtual clock
//!    produces the *identical* optimizer trace — iterates, objectives,
//!    step sizes, admitted sets — with `--storage sparse` as with dense,
//!    while the sparse run's simulated time is strictly smaller (the
//!    virtual flop model charges nnz, not rows·cols) and its shards
//!    occupy a fraction of the memory.

use codedopt::linalg::{CsrMat, Mat, StorageKind};
use codedopt::mf::{synthetic_movielens, SyntheticConfig};
use codedopt::prelude::*;
use codedopt::rng::Pcg64;
use codedopt::testutil::{gen_range, property};

/// Random matrix with the given entry density, plus guaranteed empty rows
/// and structurally zero columns when the shape allows it.
fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Mat {
    let mut m = Mat::from_fn(rows, cols, |_, _| {
        if rng.next_f64() < density {
            rng.next_gaussian()
        } else {
            0.0
        }
    });
    if rows > 2 {
        let dead_row = rng.next_below(rows as u64) as usize;
        m.row_mut(dead_row).fill(0.0);
    }
    if cols > 2 {
        let dead_col = rng.next_below(cols as u64) as usize;
        for i in 0..rows {
            m.row_mut(i)[dead_col] = 0.0;
        }
    }
    m
}

#[test]
fn prop_kernels_agree_dense_vs_sparse() {
    property("dense == sparse kernels", 40, |rng| {
        let rows = gen_range(rng, 1, 40);
        let cols = gen_range(rng, 1, 24);
        let density = 0.05 + 0.5 * rng.next_f64();
        let d = random_sparse(rng, rows, cols, density);
        let s = CsrMat::from_dense(&d);
        let w: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
        let x: Vec<f64> = (0..rows).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..rows).map(|_| rng.next_gaussian()).collect();

        // gemv
        for (a, b) in d.gemv(&w).iter().zip(&s.gemv(&w)) {
            assert!((a - b).abs() <= 1e-12, "gemv: {a} vs {b}");
        }
        // gemv_t
        for (a, b) in d.gemv_t(&x).iter().zip(&s.gemv_t(&x)) {
            assert!((a - b).abs() <= 1e-12, "gemv_t: {a} vs {b}");
        }
        // fused_grad
        let (mut gd, mut gs) = (vec![0.0; cols], vec![0.0; cols]);
        let (mut bd, mut bs) = (vec![0.0; rows], vec![0.0; rows]);
        let fd = d.fused_grad(&w, &y, &mut gd, &mut bd);
        let fs = s.fused_grad(&w, &y, &mut gs, &mut bs);
        assert!((fd - fs).abs() <= 1e-12, "fused_grad objective: {fd} vs {fs}");
        for (a, b) in gd.iter().zip(&gs) {
            assert!((a - b).abs() <= 1e-12, "fused_grad gradient: {a} vs {b}");
        }
        // fused_grad_range over a random partial range and a wrapped
        // two-segment block (the mini-batch shapes)
        let lo = gen_range(rng, 0, rows - 1);
        let hi = gen_range(rng, lo, rows);
        gd.fill(0.0);
        gs.fill(0.0);
        let fd = d.fused_grad_range(&w, &y, &mut gd, &mut bd, lo, hi);
        let fs = s.fused_grad_range(&w, &y, &mut gs, &mut bs, lo, hi);
        assert!((fd - fs).abs() <= 1e-12, "range objective: {fd} vs {fs}");
        for (a, b) in gd.iter().zip(&gs) {
            assert!((a - b).abs() <= 1e-12, "range gradient: {a} vs {b}");
        }
        if rows >= 4 {
            let cut = gen_range(rng, 1, rows - 1);
            gd.fill(0.0);
            gs.fill(0.0);
            let fd = d.fused_grad_range(&w, &y, &mut gd, &mut bd, cut, rows)
                + d.fused_grad_range(&w, &y, &mut gd, &mut bd, 0, cut);
            let fs = s.fused_grad_range(&w, &y, &mut gs, &mut bs, cut, rows)
                + s.fused_grad_range(&w, &y, &mut gs, &mut bs, 0, cut);
            assert!((fd - fs).abs() <= 1e-12, "wrapped objective: {fd} vs {fs}");
            for (a, b) in gd.iter().zip(&gs) {
                assert!((a - b).abs() <= 1e-12, "wrapped gradient: {a} vs {b}");
            }
        }
        // gram
        assert!(s.gram().max_abs_diff(&d.gram()) <= 1e-12, "gram mismatch");
    });
}

#[test]
fn prop_hot_path_kernels_agree_bitwise() {
    // the stronger property the trace equivalence rests on: the worker
    // hot-path kernels (gemv for line search, fused_grad[_range] for
    // gradient rounds) mirror the dense accumulation order exactly
    property("dense == sparse bits", 25, |rng| {
        let rows = gen_range(rng, 1, 33);
        let cols = gen_range(rng, 1, 19);
        let d = random_sparse(rng, rows, cols, 0.3);
        let s = CsrMat::from_dense(&d);
        let w: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..rows).map(|_| rng.next_gaussian()).collect();
        for (a, b) in d.gemv(&w).iter().zip(&s.gemv(&w)) {
            assert_eq!(a.to_bits(), b.to_bits(), "gemv bits");
        }
        let (mut gd, mut gs) = (vec![0.0; cols], vec![0.0; cols]);
        let (mut bd, mut bs) = (vec![0.0; rows], vec![0.0; rows]);
        let fd = d.fused_grad(&w, &y, &mut gd, &mut bd);
        let fs = s.fused_grad(&w, &y, &mut gs, &mut bs);
        assert_eq!(fd.to_bits(), fs.to_bits(), "fused objective bits");
        for (a, b) in gd.iter().zip(&gs) {
            assert_eq!(a.to_bits(), b.to_bits(), "fused gradient bits");
        }
    });
}

/// MovieLens-shaped sparse ridge problem: the one-hot ratings design,
/// truncated to `n` rows so the replication partitioner produces
/// equal-sized (equal-nnz) shards.
fn movielens_problem(n: usize, lambda: f64, seed: u64) -> QuadProblem {
    let data = synthetic_movielens(&SyntheticConfig::small(seed));
    let (design, y) = data.to_design();
    assert!(design.rows() >= n, "generator produced too few ratings");
    QuadProblem::new(design.row_band(0, n), y[..n].to_vec(), lambda)
}

struct RunResult {
    out: RunOutput,
    sim_ms: f64,
    mem_bytes: usize,
}

fn run_gd(prob: &QuadProblem, storage: StorageKind, iters: usize) -> RunResult {
    let m = 8;
    let enc =
        EncodedProblem::encode_stored(prob, EncoderKind::Replication, 2.0, m, 9, storage).unwrap();
    let engine = Box::new(NativeEngine::new(&enc));
    // ms_per_mflop is large so compute (not injected delay) dominates the
    // round clock — per-worker compute is uniform within each run (equal
    // rows, equal nnz), so admission ordering is still purely delay-driven
    // and identical across storages.
    let cfg = ClusterConfig {
        workers: m,
        wait_for: 6,
        delay: DelayModel::Exp { mean_ms: 10.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 200.0,
        seed: 9,
    };
    let mut cluster = Cluster::new(&enc, engine, cfg).unwrap();
    let gd = CodedGd::new(GdConfig { epsilon: Some(0.5), seed: 9, ..Default::default() });
    let out = gd.run(&enc, &mut cluster, iters).unwrap();
    RunResult { out, sim_ms: cluster.sim_ms, mem_bytes: enc.shard_mem_bytes() }
}

fn run_sgd(prob: &QuadProblem, storage: StorageKind, iters: usize) -> RunResult {
    let m = 8;
    let enc =
        EncodedProblem::encode_stored(prob, EncoderKind::Replication, 2.0, m, 9, storage).unwrap();
    let engine = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: m,
        wait_for: 6,
        delay: DelayModel::Exp { mean_ms: 10.0 },
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 11,
    };
    let mut cluster = Cluster::new(&enc, engine, cfg).unwrap();
    let sgd = CodedSgd::new(SgdConfig {
        lr: Some(0.05),
        batch_frac: 0.5,
        momentum: 0.25,
        seed: 3,
        ..Default::default()
    });
    let out = sgd.run(&enc, &mut cluster, iters).unwrap();
    RunResult { out, sim_ms: cluster.sim_ms, mem_bytes: enc.shard_mem_bytes() }
}

fn assert_traces_identical(dense: &RunOutput, sparse: &RunOutput) {
    assert_eq!(dense.trace.len(), sparse.trace.len());
    for (a, b) in dense.trace.records.iter().zip(&sparse.trace.records) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.f_true.to_bits(), b.f_true.to_bits(), "iter {}: f_true", a.iter);
        assert_eq!(a.f_est.to_bits(), b.f_est.to_bits(), "iter {}: f_est", a.iter);
        assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits(), "iter {}: grad_norm", a.iter);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "iter {}: alpha", a.iter);
        assert_eq!(a.responders, b.responders, "iter {}: responders", a.iter);
    }
    for (a, b) in dense.w.iter().zip(&sparse.w) {
        assert_eq!(a.to_bits(), b.to_bits(), "final iterate differs");
    }
}

#[test]
fn sparse_storage_reproduces_dense_virtual_clock_trace() {
    // n divisible by partitions (m/β = 4) → equal rows, and the one-hot
    // design has exactly 3 nnz/row → equal per-worker virtual compute, so
    // the delay-driven admission schedule is identical across storages.
    let prob = movielens_problem(2048, 0.05, 31);
    let dense = run_gd(&prob, StorageKind::Dense, 12);
    let sparse = run_gd(&prob, StorageKind::Sparse, 12);
    assert_traces_identical(&dense.out, &sparse.out);
    // ... but the sparse run is *cheaper* on both axes the backends trade:
    assert!(
        sparse.sim_ms < dense.sim_ms * 0.25,
        "nnz flop model should make sparse rounds far faster: {} vs {} ms",
        sparse.sim_ms,
        dense.sim_ms
    );
    assert!(
        sparse.mem_bytes < dense.mem_bytes / 4,
        "CSR shards should be far smaller: {} vs {} bytes",
        sparse.mem_bytes,
        dense.mem_bytes
    );
    // sanity: the run actually optimized something
    assert!(dense.out.trace.last_objective() < dense.out.trace.records[0].f_true);
}

#[test]
fn sparse_storage_reproduces_dense_sgd_trace() {
    // the stochastic path too: block-row mini-batch sampling, the
    // range-restricted fused kernel, and the batch-scaled virtual flop
    // model are all storage-oblivious
    let prob = movielens_problem(2048, 0.05, 37);
    let dense = run_sgd(&prob, StorageKind::Dense, 10);
    let sparse = run_sgd(&prob, StorageKind::Sparse, 10);
    assert_traces_identical(&dense.out, &sparse.out);
    assert!(sparse.sim_ms < dense.sim_ms);
}

#[test]
fn auto_storage_matches_explicit_sparse_on_csr_input() {
    let prob = movielens_problem(1024, 0.05, 41);
    let auto = EncodedProblem::encode(&prob, EncoderKind::Replication, 2.0, 8, 5).unwrap();
    assert_eq!(auto.storage, StorageKind::Sparse);
    let explicit =
        EncodedProblem::encode_stored(&prob, EncoderKind::Replication, 2.0, 8, 5, StorageKind::Sparse)
            .unwrap();
    assert_eq!(auto.shard_mem_bytes(), explicit.shard_mem_bytes());
    for (a, b) in auto.shards.iter().zip(&explicit.shards) {
        assert_eq!(a.x.max_abs_diff(&b.x), 0.0);
    }
}

#[test]
fn lbfgs_runs_on_sparse_storage() {
    // obliviousness across the remaining optimizer surface: L-BFGS (grad
    // + line-search rounds) on CSR shards converges on the sparse design
    let prob = movielens_problem(1024, 0.1, 43);
    let enc =
        EncodedProblem::encode_stored(&prob, EncoderKind::Identity, 1.0, 8, 7, StorageKind::Sparse)
            .unwrap();
    let engine = Box::new(NativeEngine::new(&enc));
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 8,
        delay: DelayModel::None,
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 7,
    };
    let mut cluster = Cluster::new(&enc, engine, cfg).unwrap();
    let lb = CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.0), ..Default::default() });
    let out = lb.run(&enc, &mut cluster, 20).unwrap();
    let f_star = prob.objective(&prob.exact_solution().unwrap());
    let f0 = prob.objective(&vec![0.0; prob.p()]);
    let f_end = out.trace.last_objective();
    assert!(f_end.is_finite());
    assert!(
        f_end - f_star < 0.1 * (f0 - f_star),
        "L-BFGS on CSR barely moved: f0 {f0}, f_end {f_end}, f* {f_star}"
    );
}
