//! Pooled-dispatch equivalence: the persistent worker pool must be a
//! pure execution-plumbing change.
//!
//! Layers of pinning:
//!
//! 1. **Serial-reference equivalence** — every optimizer (GD, SGD,
//!    L-BFGS, FISTA) × scheme (hadamard, replication, uncoded) runs the
//!    PR-4 golden workload twice: once on the pool-backed
//!    [`NativeEngine`], once on a serial reference engine that executes
//!    the identical fused kernels through the trait's default (serial)
//!    streamed implementations. The virtual-clock CSV traces must match
//!    **byte for byte** — pooled dispatch can reorder deliveries, but it
//!    must never change a payload bit or an admitted set. (The same
//!    workload is also pinned against the checked-in goldens by
//!    `fault_scenarios.rs`; this test keeps its teeth even on a fresh
//!    checkout with no baselines.)
//! 2. **Crash-park equivalence** — a scenario that crashes and recovers
//!    a worker parks/unparks its resident pool thread; the trace must
//!    equal the reference engine's (which computes and discards), and
//!    the parked thread must rejoin on `recover:` with zero respawns.
//! 3. **Lane-layout invisibility** — pool sizes 1/3/8 produce identical
//!    bytes; two identical pooled runs produce identical bytes under the
//!    virtual clock, and identical non-wall-time columns under the
//!    measured clock with a single lane (where admission order is
//!    deterministic).
//! 4. **Structural zero-spawn** — no `thread::scope` left anywhere in
//!    the round call path, and the engine's spawn count is frozen after
//!    pool startup.

use codedopt::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel, Scenario};
use codedopt::encoding::EncoderKind;
use codedopt::linalg::{self, DataMat, StorageKind};
use codedopt::optim::{
    CodedFista, CodedGd, CodedLbfgs, CodedSgd, FistaConfig, GdConfig, LbfgsConfig, LrSchedule,
    Optimizer, Prox, RunOutput, SgdConfig,
};
use codedopt::problem::{EncodedProblem, QuadProblem};
use codedopt::runtime::{ComputeEngine, NativeEngine};
use anyhow::Result;

// ------------------------------------------------------------ reference

/// Serial reference engine: the exact per-worker fused kernels and
/// scratch discipline of the pool lanes, driven through the trait's
/// default (serial, spawn-free) streamed implementations. No `session`
/// — the cluster's park path is a no-op here, which is precisely what
/// makes trace equality against the pooled engine meaningful.
struct RefSlot {
    x: DataMat,
    y: Vec<f64>,
    grad_buf: Vec<f64>,
    resid_buf: Vec<f64>,
}

struct RefEngine {
    slots: Vec<RefSlot>,
}

impl RefEngine {
    fn new(prob: &EncodedProblem) -> Self {
        let p = prob.p();
        RefEngine {
            slots: prob
                .shards
                .iter()
                .map(|s| RefSlot {
                    x: s.x.clone(),
                    y: s.y.clone(),
                    grad_buf: vec![0.0; p],
                    resid_buf: vec![0.0; s.x.rows()],
                })
                .collect(),
        }
    }
}

impl ComputeEngine for RefEngine {
    fn name(&self) -> &'static str {
        "serial-reference"
    }

    fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let s = &mut self.slots[worker];
        let f = s.x.fused_grad(w, &s.y, &mut s.grad_buf, &mut s.resid_buf);
        Ok((s.grad_buf.clone(), f))
    }

    fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64> {
        let s = &mut self.slots[worker];
        s.x.gemv_into(d, &mut s.resid_buf);
        Ok(linalg::dot(&s.resid_buf, &s.resid_buf))
    }

    fn worker_grad_batch(
        &mut self,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        let s = &mut self.slots[worker];
        s.grad_buf.fill(0.0);
        let mut f = 0.0;
        for &(lo, hi) in segs {
            f += s.x.fused_grad_range(w, &s.y, &mut s.grad_buf, &mut s.resid_buf, lo, hi);
        }
        Ok((s.grad_buf.clone(), f))
    }

    fn workers(&self) -> usize {
        self.slots.len()
    }
}

// ------------------------------------------------------------- fixtures

/// The PR-4 golden workload: small ridge problem, 8 workers, k = 6,
/// deterministic `const:2` delays.
fn fixture(kind: EncoderKind, beta: f64) -> EncodedProblem {
    let prob = QuadProblem::synthetic_gaussian(96, 8, 0.05, 7);
    EncodedProblem::encode_stored(&prob, kind, beta, 8, 3, StorageKind::Dense).expect("encode")
}

fn cluster_over(
    enc: &EncodedProblem,
    engine: Box<dyn ComputeEngine>,
    clock: ClockMode,
) -> Cluster {
    let cfg = ClusterConfig {
        workers: 8,
        wait_for: 6,
        delay: DelayModel::Constant { ms: 2.0 },
        clock,
        ms_per_mflop: 0.5,
        seed: 11,
    };
    Cluster::new(enc, engine, cfg).expect("cluster")
}

const SCHEMES: &[(EncoderKind, f64)] = &[
    (EncoderKind::Hadamard, 2.0),
    (EncoderKind::Replication, 2.0),
    (EncoderKind::Identity, 1.0),
];

const ITERS: usize = 20;

fn run_optimizer(opt: &str, enc: &EncodedProblem, cluster: &mut Cluster) -> RunOutput {
    match opt {
        "gd" => CodedGd::new(GdConfig { zeta: 0.5, epsilon: Some(0.3), ..Default::default() })
            .run(enc, cluster, ITERS)
            .expect("gd run"),
        "sgd" => CodedSgd::new(SgdConfig {
            lr: Some(0.02),
            schedule: LrSchedule::InvT { t0: 10.0 },
            momentum: 0.5,
            batch_frac: 0.5,
            seed: 5,
            ..Default::default()
        })
        .run(enc, cluster, ITERS)
        .expect("sgd run"),
        "lbfgs" => CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.3), ..Default::default() })
            .run(enc, cluster, ITERS)
            .expect("lbfgs run"),
        "fista" => CodedFista::new(FistaConfig {
            prox: Prox::L1 { l1: 0.001 },
            epsilon: Some(0.3),
            ..Default::default()
        })
        .run(enc, cluster, ITERS)
        .expect("fista run"),
        other => panic!("unknown optimizer {other}"),
    }
}

/// One virtual-clock CSV trace with the given engine factory.
fn trace_with(
    opt: &str,
    kind: EncoderKind,
    beta: f64,
    scenario: Option<&str>,
    make_engine: impl FnOnce(&EncodedProblem) -> Box<dyn ComputeEngine>,
) -> String {
    let enc = fixture(kind, beta);
    let engine = make_engine(&enc);
    let mut cluster = cluster_over(&enc, engine, ClockMode::Virtual);
    if let Some(dsl) = scenario {
        cluster.set_scenario(Scenario::parse(dsl).unwrap()).unwrap();
    }
    run_optimizer(opt, &enc, &mut cluster).trace.to_csv()
}

// ----------------------------------------------- serial-reference pinning

fn pooled_matches_reference_for(opt: &str) {
    for &(kind, beta) in SCHEMES {
        let pooled = trace_with(opt, kind, beta, None, |e| Box::new(NativeEngine::new(e)));
        let serial = trace_with(opt, kind, beta, None, |e| Box::new(RefEngine::new(e)));
        assert_eq!(
            pooled, serial,
            "{opt}/{kind:?}: pooled dispatch changed the virtual-clock trace"
        );
    }
}

#[test]
fn pooled_gd_matches_serial_reference_bitwise() {
    pooled_matches_reference_for("gd");
}

#[test]
fn pooled_sgd_matches_serial_reference_bitwise() {
    pooled_matches_reference_for("sgd");
}

#[test]
fn pooled_lbfgs_matches_serial_reference_bitwise() {
    pooled_matches_reference_for("lbfgs");
}

#[test]
fn pooled_fista_matches_serial_reference_bitwise() {
    pooled_matches_reference_for("fista");
}

// ------------------------------------------------- crash-park invariant

/// Crash → park, recover → rejoin, all bit-for-bit against the reference
/// engine (which computes crashed workers' responses and discards them):
/// parking must be pure compute skipping, never a semantic change.
#[test]
fn crash_park_rejoin_reproduces_reference_traces() {
    let dsl = "crash:2@3,leave:5@6,recover:2@9,join:5@12;admit:rotate:k";
    for opt in ["gd", "sgd"] {
        let pooled = trace_with(opt, EncoderKind::Hadamard, 2.0, Some(dsl), |e| {
            Box::new(NativeEngine::new(e))
        });
        let serial = trace_with(opt, EncoderKind::Hadamard, 2.0, Some(dsl), |e| {
            Box::new(RefEngine::new(e))
        });
        assert_eq!(pooled, serial, "{opt}: crash-park changed the scenario trace");
        assert!(pooled.contains("crash:2@3") && pooled.contains("recover:2@9"));
    }
}

/// The parked worker's lane thread survives the crash and rejoins on
/// recover — zero respawns across the whole churn.
#[test]
fn parked_thread_rejoins_without_respawn() {
    let enc = fixture(EncoderKind::Hadamard, 2.0);
    let mut cluster = cluster_over(&enc, Box::new(NativeEngine::new(&enc)), ClockMode::Virtual);
    cluster.set_scenario(Scenario::parse("crash:2@1,recover:2@3").unwrap()).unwrap();
    let w = vec![0.1; 8];
    cluster.grad_round(&w).unwrap();
    let spawned = cluster.engine_session().expect("pooled engine session").spawn_count();
    assert!(spawned > 0);
    let parked_per_round: Vec<usize> = (1..5)
        .map(|_| {
            cluster.grad_round(&w).unwrap();
            cluster.engine_session().unwrap().parked_count()
        })
        .collect();
    assert_eq!(parked_per_round, vec![1, 1, 0, 0], "park/rejoin sequence");
    assert_eq!(
        cluster.engine_session().unwrap().spawn_count(),
        spawned,
        "crash/recover churn must never respawn threads"
    );
}

// --------------------------------------------- lane-layout invisibility

#[test]
fn pool_size_is_bitwise_invisible() {
    for opt in ["gd", "sgd"] {
        let traces: Vec<String> = [1usize, 3, 8]
            .iter()
            .map(|&threads| {
                trace_with(opt, EncoderKind::Hadamard, 2.0, None, |e| {
                    Box::new(NativeEngine::new(e).with_threads(threads))
                })
            })
            .collect();
        assert_eq!(traces[0], traces[1], "{opt}: 1 vs 3 lanes");
        assert_eq!(traces[0], traces[2], "{opt}: 1 vs 8 lanes");
    }
}

fn pooled_lbfgs_trace() -> String {
    trace_with("lbfgs", EncoderKind::Hadamard, 2.0, None, |e| Box::new(NativeEngine::new(e)))
}

#[test]
fn double_run_is_byte_identical_under_virtual_clock() {
    assert_eq!(pooled_lbfgs_trace(), pooled_lbfgs_trace());
}

/// Measured-clock CSVs carry wall-clock columns (`sim_ms`,
/// `compute_ms`) that legitimately differ between runs; everything else
/// — iterates, objectives, step sizes, admitted counts, events — must be
/// byte-identical when the pool has one lane (deterministic delivery
/// order). The CI job re-checks this across whole processes.
#[test]
fn double_run_measured_clock_matches_on_non_walltime_columns() {
    let run = || -> String {
        let enc = fixture(EncoderKind::Hadamard, 2.0);
        let engine = Box::new(NativeEngine::new(&enc).with_threads(1));
        let mut cluster = cluster_over(&enc, engine, ClockMode::Measured);
        run_optimizer("gd", &enc, &mut cluster).trace.to_csv()
    };
    let (a, b) = (run(), run());
    let strip = |csv: &str| -> Vec<String> {
        csv.lines()
            .map(|line| {
                let cols: Vec<&str> = line.split(',').collect();
                assert_eq!(cols.len(), 9, "unexpected CSV shape: {line}");
                // drop sim_ms (6) and compute_ms (7)
                [&cols[..6], &cols[8..]].concat().join(",")
            })
            .collect()
    };
    assert_eq!(strip(&a), strip(&b), "measured-clock iterates must be deterministic");
}

// ------------------------------------------------- structural zero-spawn

/// No per-round spawn primitives survive anywhere in the round call
/// path: the native engine and the cluster are spawn-free source-wise
/// (the only spawns live in pool construction and the XLA service
/// startup), and a long pooled run's spawn count is frozen after
/// startup.
#[test]
fn round_call_path_is_structurally_spawn_free() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for file in ["src/runtime/native.rs", "src/cluster/mod.rs", "src/runtime/stream.rs"] {
        let text = std::fs::read_to_string(root.join(file)).expect("reading source");
        // executable lines only: doc comments legitimately mention the
        // removed scoped-spawn fan-out as history
        let code: String = text
            .lines()
            .filter(|line| !line.trim_start().starts_with("//"))
            .collect::<Vec<_>>()
            .join("\n");
        assert!(
            !code.contains("thread::scope"),
            "{file}: thread::scope found in the round call path"
        );
        assert!(
            !code.contains(".spawn("),
            "{file}: thread spawn found in the round call path"
        );
    }

    let enc = fixture(EncoderKind::Hadamard, 2.0);
    let mut cluster = cluster_over(&enc, Box::new(NativeEngine::new(&enc)), ClockMode::Virtual);
    let w = vec![0.1; 8];
    cluster.grad_round(&w).unwrap();
    let spawned = cluster.engine_session().unwrap().spawn_count();
    for _ in 0..40 {
        cluster.grad_round(&w).unwrap();
        cluster.linesearch_round(&w).unwrap();
    }
    assert_eq!(
        cluster.engine_session().unwrap().spawn_count(),
        spawned,
        "steady-state rounds must spawn zero threads"
    );
}

// ------------------------------------------------------- reconfiguration

/// In-place reconfiguration through the session equals a fresh engine,
/// bit for bit, across a problem swap (different n, p, m, scheme).
#[test]
fn reconfigured_pool_matches_fresh_engine_bitwise() {
    let enc_a = fixture(EncoderKind::Hadamard, 2.0);
    let prob_b = QuadProblem::synthetic_gaussian(64, 6, 0.1, 21);
    let enc_b = EncodedProblem::encode(&prob_b, EncoderKind::Identity, 1.0, 4, 1).unwrap();

    let mut engine: Box<dyn ComputeEngine> = Box::new(NativeEngine::new(&enc_a));
    let w_a = vec![0.2; 8];
    engine.worker_grad_all(&w_a).unwrap();
    let spawned = engine.session().unwrap().spawn_count();
    engine.session().unwrap().reconfigure(&enc_b).unwrap();
    assert_eq!(engine.workers(), 4);
    assert_eq!(engine.session().unwrap().spawn_count(), spawned, "reconfigure respawned");

    let mut fresh: Box<dyn ComputeEngine> = Box::new(NativeEngine::new(&enc_b));
    let w = vec![0.3; 6];
    let a = engine.worker_grad_all(&w).unwrap();
    let b = fresh.worker_grad_all(&w).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, ((ga, fa), (gb, fb))) in a.iter().zip(&b).enumerate() {
        assert_eq!(fa.to_bits(), fb.to_bits(), "worker {i} objective");
        for (x, y) in ga.iter().zip(gb) {
            assert_eq!(x.to_bits(), y.to_bits(), "worker {i} gradient");
        }
    }
}
