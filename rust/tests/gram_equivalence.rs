//! `--grad-mode gram` ≡ `--grad-mode gemv` — the numeric contract behind
//! the per-shard Gram-cache gradient fast path.
//!
//! The Gram path serves a full-shard gradient round as one symmetric
//! p×p gemv (`g = G·w − c` with `G = X̃ᵀX̃`, `c = X̃ᵀỹ` staged once)
//! instead of streaming the n_w×p shard twice. Floating point is not
//! associative, so the two paths are *not* bitwise-equal — the pin is
//! numeric: on every optimizer that takes full-shard rounds (GD,
//! L-BFGS, full-batch SGD) and across encoder families, the final
//! iterate agrees to ≤1e-9 relative error, with the responder schedule
//! identical under the virtual clock. Alongside the equivalence pin:
//! the `auto` cost model (`p² < 2·nnz` madds per shard), the dense-f64
//! precondition (CSR and f32 shards are hard errors), and the
//! memory-accounting contract (`shard_mem_bytes` counts the cache).

use codedopt::linalg::{GradMode, Mat, Precision, StorageKind};
use codedopt::prelude::*;
use codedopt::rng::Pcg64;

fn random_problem(n: usize, p: usize, lambda: f64, seed: u64) -> QuadProblem {
    let mut rng = Pcg64::new(seed, 77);
    let x = Mat::from_fn(n, p, |_, _| rng.next_gaussian());
    let y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
    QuadProblem::new(x, y, lambda)
}

/// Run `opt` on `enc` under the virtual clock and return the output.
/// `wait_for = m` + no delay makes the admission schedule trivially
/// identical across grad modes, isolating the numeric comparison.
fn run_collect_all(enc: &EncodedProblem, opt: &dyn Optimizer, iters: usize) -> RunOutput {
    let m = enc.m();
    let engine = Box::new(NativeEngine::new(enc));
    let cfg = ClusterConfig {
        workers: m,
        wait_for: m,
        delay: DelayModel::None,
        clock: ClockMode::Virtual,
        ms_per_mflop: 0.5,
        seed: 13,
    };
    let mut cluster = Cluster::new(enc, engine, cfg).unwrap();
    opt.run(enc, &mut cluster, iters).unwrap()
}

fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let den: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    num / den.max(1e-300)
}

#[test]
fn gram_matches_gemv_on_every_full_round_optimizer() {
    let prob = random_problem(256, 24, 0.05, 5);
    let optimizers: Vec<(&str, Box<dyn Optimizer>)> = vec![
        ("gd", Box::new(CodedGd::new(GdConfig { epsilon: Some(0.5), seed: 9, ..Default::default() }))),
        ("lbfgs", Box::new(CodedLbfgs::new(LbfgsConfig { epsilon: Some(0.0), ..Default::default() }))),
        (
            "sgd-full",
            Box::new(CodedSgd::new(SgdConfig {
                lr: Some(0.02),
                batch_frac: 1.0,
                momentum: 0.25,
                seed: 3,
                ..Default::default()
            })),
        ),
    ];
    for (kind, beta) in [
        (EncoderKind::Hadamard, 2.0),
        (EncoderKind::Replication, 2.0),
        (EncoderKind::Identity, 1.0),
    ] {
        let gemv =
            EncodedProblem::encode_stored(&prob, kind, beta, 8, 7, StorageKind::Dense).unwrap();
        let gram = gemv.clone().with_grad_mode(GradMode::Gram).unwrap();
        assert!(gram.shards.iter().all(|s| s.grad_mode == GradMode::Gram));
        for (name, opt) in &optimizers {
            let a = run_collect_all(&gemv, opt.as_ref(), 15);
            let b = run_collect_all(&gram, opt.as_ref(), 15);
            let err = rel_err(&a.w, &b.w);
            assert!(
                err <= 1e-9,
                "{kind:?}/{name}: final iterates diverged, rel err {err:e}"
            );
            assert_eq!(a.trace.len(), b.trace.len(), "{kind:?}/{name}: trace length");
            for (ra, rb) in a.trace.records.iter().zip(&b.trace.records) {
                let df = (ra.f_true - rb.f_true).abs() / ra.f_true.abs().max(1e-300);
                assert!(df <= 1e-9, "{kind:?}/{name} iter {}: f_true drift {df:e}", ra.iter);
            }
        }
    }
}

#[test]
fn gram_matches_gemv_under_first_k_straggling() {
    // first-k admission with exponential delays: the delay draws dwarf
    // the (mode-dependent) virtual compute charge, so both modes admit
    // the same responder sets round for round — and must then agree on
    // the η-scaled aggregate to ≤1e-9.
    let prob = random_problem(256, 16, 0.1, 11);
    let gemv =
        EncodedProblem::encode_stored(&prob, EncoderKind::Hadamard, 2.0, 8, 3, StorageKind::Dense)
            .unwrap();
    let gram = gemv.clone().with_grad_mode(GradMode::Gram).unwrap();
    let run = |enc: &EncodedProblem| {
        let engine = Box::new(NativeEngine::new(enc));
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: 6,
            delay: DelayModel::Exp { mean_ms: 50.0 },
            clock: ClockMode::Virtual,
            ms_per_mflop: 1e-6,
            seed: 21,
        };
        let mut cluster = Cluster::new(enc, engine, cfg).unwrap();
        let gd = CodedGd::new(GdConfig { epsilon: Some(0.5), seed: 9, ..Default::default() });
        gd.run(enc, &mut cluster, 12).unwrap()
    };
    let a = run(&gemv);
    let b = run(&gram);
    for (ra, rb) in a.trace.records.iter().zip(&b.trace.records) {
        assert_eq!(ra.responders, rb.responders, "iter {}: responder schedule", ra.iter);
    }
    let err = rel_err(&a.w, &b.w);
    assert!(err <= 1e-9, "straggling run diverged, rel err {err:e}");
}

#[test]
fn auto_selects_gram_iff_cost_model_wins() {
    // tall shards: p² = 576 ≪ 2·rows·p per shard → every shard Gram
    let tall = random_problem(512, 24, 0.05, 17);
    let enc = EncodedProblem::encode_stored(&tall, EncoderKind::Hadamard, 2.0, 8, 5, StorageKind::Dense)
        .unwrap()
        .with_grad_mode(GradMode::Auto)
        .unwrap();
    assert_eq!(enc.grad_mode, GradMode::Auto);
    for s in &enc.shards {
        let (rows, p) = (s.x.rows(), s.x.cols());
        assert!(p * p < 2 * rows * p, "test shape no longer in the gram regime");
        assert_eq!(s.grad_mode, GradMode::Gram, "worker {}", s.partition_id);
    }

    // short wide shards: p² ≥ 2·rows·p per shard → every shard Gemv
    let wide = random_problem(64, 48, 0.05, 19);
    let enc =
        EncodedProblem::encode_stored(&wide, EncoderKind::Identity, 1.0, 8, 5, StorageKind::Dense)
            .unwrap()
            .with_grad_mode(GradMode::Auto)
            .unwrap();
    for s in &enc.shards {
        let (rows, p) = (s.x.rows(), s.x.cols());
        assert!(p * p >= 2 * rows * p, "test shape no longer in the gemv regime");
        assert_eq!(s.grad_mode, GradMode::Gemv, "worker {}", s.partition_id);
    }

    // CSR shards never auto-promote, whatever the shape says
    let enc =
        EncodedProblem::encode_stored(&tall, EncoderKind::Identity, 1.0, 8, 5, StorageKind::Sparse)
            .unwrap()
            .with_grad_mode(GradMode::Auto)
            .unwrap();
    assert!(enc.shards.iter().all(|s| s.grad_mode == GradMode::Gemv));
}

#[test]
fn gram_rejects_csr_shards_naming_the_worker() {
    let prob = random_problem(128, 12, 0.05, 23);
    let enc =
        EncodedProblem::encode_stored(&prob, EncoderKind::Identity, 1.0, 4, 5, StorageKind::Sparse)
            .unwrap();
    let err = enc.with_grad_mode(GradMode::Gram).unwrap_err().to_string();
    assert!(err.contains("CSR"), "error should name the storage axis: {err}");
    assert!(err.contains("worker 0"), "error should name the offending worker: {err}");
}

#[test]
fn gram_rejects_f32_shards() {
    let prob = random_problem(128, 12, 0.05, 29);
    let enc = EncodedProblem::encode_stored_prec(
        &prob,
        EncoderKind::Hadamard,
        2.0,
        4,
        5,
        StorageKind::Dense,
        Precision::F32,
    )
    .unwrap();
    let err = enc.with_grad_mode(GradMode::Gram).unwrap_err().to_string();
    assert!(err.contains("f64"), "error should name the precision axis: {err}");
}

#[test]
fn shard_mem_bytes_counts_the_gram_cache() {
    let prob = random_problem(256, 24, 0.05, 31);
    let gemv =
        EncodedProblem::encode_stored(&prob, EncoderKind::Hadamard, 2.0, 8, 7, StorageKind::Dense)
            .unwrap();
    let gram = gemv.clone().with_grad_mode(GradMode::Gram).unwrap();
    let p = gemv.p();
    let cache = (p * p + p + 1) * std::mem::size_of::<f64>();
    assert_eq!(
        gram.shard_mem_bytes(),
        gemv.shard_mem_bytes() + 8 * cache,
        "every one of the 8 shards should account one Gram cache"
    );
}
