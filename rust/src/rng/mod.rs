//! Deterministic pseudo-randomness for the whole stack.
//!
//! Every stochastic component (data generation, Gaussian encoders, straggler
//! delays, shuffles, train/test splits) draws from a seeded [`Pcg64`] so that
//! experiments and tests are exactly reproducible. PCG-XSL-RR 128/64 —
//! O'Neill's PCG family; small, fast, and statistically strong enough for
//! simulation workloads (we are not doing cryptography).

/// PCG-XSL-RR 128/64 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Box–Muller produces variates in pairs; the second is cached here.
    cached_gaussian: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Pcg64 { state: 0, inc, cached_gaussian: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (used to give each worker /
    /// shard its own stream without coupling draws).
    pub fn split(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64(), stream.wrapping_mul(2).wrapping_add(1))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) (Lemire-style rejection-free enough:
    /// use 128-bit multiply with rejection for exactness).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // widening multiply + rejection to remove modulo bias
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (both variates used).
    pub fn next_gaussian(&mut self) -> f64 {
        match self.cached_gaussian.take() {
            Some(g) => g,
            None => {
                // avoid log(0)
                let u1 = loop {
                    let u = self.next_f64();
                    if u > 1e-300 {
                        break u;
                    }
                };
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                let theta = 2.0 * core::f64::consts::PI * u2;
                self.cached_gaussian = Some(r * theta.sin());
                r * theta.cos()
            }
        }
    }

    /// Exponential with the given mean.
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        assert!(mean > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Pareto(scale, shape) — heavy-tailed delays.
    pub fn next_pareto(&mut self, scale: f64, shape: f64) -> f64 {
        assert!(scale > 0.0 && shape > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        scale / u.powf(1.0 / shape)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A uniformly random `k`-subset of `0..n`, in shuffled order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} of {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k entries are the sample
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_hits_everything() {
        let mut r = Pcg64::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.next_below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Pcg64::seeded(13);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.next_exp(10.0)).sum::<f64>() / n as f64;
        assert!((m - 10.0).abs() < 0.2, "mean {m}");
    }

    #[test]
    fn pareto_exceeds_scale() {
        let mut r = Pcg64::seeded(17);
        for _ in 0..1_000 {
            assert!(r.next_pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn sample_indices_unique_and_in_range() {
        let mut r = Pcg64::seeded(19);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Pcg64::seeded(23);
        let p = r.permutation(64);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut parent1 = Pcg64::seeded(5);
        let mut parent2 = Pcg64::seeded(5);
        let mut c1 = parent1.split(1);
        let mut c2 = parent2.split(1);
        for _ in 0..32 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        let mut c3 = parent1.split(2);
        let same = (0..32).filter(|_| c1.next_u64() == c3.next_u64()).count();
        assert!(same < 2);
    }
}
