//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog [subcommand] [--flag value | --switch] [key=value ...]`.
//! `--flag value` and `--flag=value` both work; bare `--switch` is a
//! boolean; trailing `key=value` pairs become config overrides.

use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token, if any.
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
    /// Trailing `key=value` config overrides.
    pub overrides: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (program name excluded).
    pub fn from_iter<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with('-') && !first.contains('=') {
                out.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--") && !n.contains('='))
                    .unwrap_or(false)
                    && !name.is_empty()
                {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.insert(name.to_string());
                }
            } else if tok.contains('=') {
                out.overrides.push(tok);
            } else {
                bail!("unexpected argument {tok:?}");
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::from_iter(std::env::args().skip(1))
    }

    /// Raw value of `--name`, if given.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// True if the bare switch `--name` was given.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.contains(name)
    }

    /// `--name` as usize, or `default` when absent.
    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// `--name` as f64, or `default` when absent.
    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not a number")),
        }
    }

    /// `--name` as u64, or `default` when absent.
    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// `--name` as a string, or `default` when absent.
    pub fn flag_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flags.get(name).map(|s| s.as_str()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::from_iter(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["ridge", "--workers", "32", "--k=12", "--verbose", "seed=7"]);
        assert_eq!(a.subcommand.as_deref(), Some("ridge"));
        assert_eq!(a.flag_usize("workers", 0).unwrap(), 32);
        assert_eq!(a.flag_usize("k", 0).unwrap(), 12);
        assert!(a.switch("verbose"));
        assert_eq!(a.overrides, vec!["seed=7"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.flag_usize("missing", 5).unwrap(), 5);
        assert_eq!(a.flag_str("enc", "hadamard"), "hadamard");
        assert!(!a.switch("anything"));
    }

    #[test]
    fn trailing_switch_without_value() {
        let a = parse(&["mf", "--fast"]);
        assert!(a.switch("fast"));
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::from_iter(["mf".to_string(), "oops".to_string()]).is_err());
    }

    #[test]
    fn flag_type_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.flag_usize("n", 0).is_err());
    }
}
