//! `codedopt` command-line interface.
//!
//! Subcommands:
//! * `ridge`     — one encoded ridge-regression run (the Fig. 4 workload)
//! * `serve`     — many concurrent ridge jobs on one shared worker pool
//! * `mf`        — synthetic-MovieLens matrix factorization (Fig. 5/6)
//! * `spectrum`  — `S_AᵀS_A` spectra per encoder (Fig. 2/3)
//! * `check-artifacts` — validate + compile every AOT artifact
//!
//! All take `--flag value` options; `--help` prints per-command usage.

pub mod args;

pub use args::Args;

use crate::cluster::{ClockMode, Cluster, ClusterConfig, DelayModel, Scenario};
use crate::config::Json;
use crate::encoding::temporal::TemporalScheme;
use crate::encoding::EncoderKind;
use crate::linalg::{GradMode, Precision, StorageKind};
use crate::optim::{
    CodedGd, CodedLbfgs, CodedSgd, GdConfig, LbfgsConfig, LrSchedule, Optimizer, SgdConfig,
    SteppedOptimizer,
};
use crate::problem::{EncodedProblem, QuadProblem};
use crate::runtime::{
    build_engine_with, run_pipelined, EncodedShardCache, EngineKind, JobServer, JobSpec,
    RebalanceConfig, ServeOptimizer, ServePolicy,
};
use anyhow::{Context, Result};

const HELP: &str = "\
codedopt — straggler mitigation in distributed optimization through data encoding
            (Karakus, Sun, Yin, Diggavi — NIPS 2017)

USAGE: codedopt <subcommand> [--flag value ...]

SUBCOMMANDS
  ridge             encoded distributed ridge regression (Fig. 4 workload)
    --n 4096 --p 6000 --lambda 0.05 --workers 32 --k 12 --beta 2.0
    --encoder hadamard|uncoded|replication|gaussian|paley|hadamard-etf|steiner|dft
    --optimizer lbfgs|gd|sgd (alias --algo) --iters 100
    --engine native|xla --delay exp:10 --seed 0
    --clock virtual|measured   virtual: deterministic flop-model round times;
                               measured: per-worker wall-clock with straggler
                               cancellation (streaming first-k gather)
    --storage dense|sparse|auto  shard storage backend: auto (default) keeps
                               sparse data CSR where the scheme preserves it;
                               sparse forces CSR (errors for densifying
                               encoders; the xla engine needs dense)
    --precision f64|f32  worker-shard arithmetic precision (default f64):
                               f32 halves shard memory and runs the f32
                               kernels on workers while encoding and the
                               leader stay f64 (needs --engine native)
    --grad-mode gemv|gram|auto  full-shard gradient kernel (default gemv):
                               gram precomputes G_w = X̃ᵀX̃ and c_w = X̃ᵀỹ at
                               staging and serves each round as one p×p
                               gemv (wins when p² < 2·nnz per shard); auto
                               picks per shard by that cost model; needs
                               dense f64 shards and --engine native
    --threads 0     native-engine resident worker-pool size: the pool is
                    spawned once per run and every round is dispatched to
                    its shard-owning lanes (0 = all cores)
    --scheme none|seq:W:B|stoch:Q  temporal gradient-coding scheme (default
                    none): seq:W:B splits each worker's home block into W
                    per-round window slots and mirrors the first B on a
                    buddy at weight 1/sqrt(2) (S^T S = I, beta ~ 1+B/W,
                    exact at full participation); stoch:Q backs every raw
                    row on a random buddy with probability Q (unbiased in
                    expectation). Replaces --encoder; not combinable with
                    --rebalance or --storage sparse
    --pipeline-depth 1  measured-clock round pipelining: keep up to D
                    rounds' straggler tails in flight, retiring each round
                    at its k-th admission and deferring ack drains (1 =
                    serial blocking rounds; virtual-clock traces are
                    depth-invariant by construction)
    --scenario DSL  deterministic fault script layered over --delay, e.g.
                    crash:3@10,recover:3@25;admit:rotate:k
                    (events crash|recover|leave|join|slow|rack + an optional
                    admit: policy forcing exact admitted subsets)
    --scenario-json <path>  same scenario from a JSON file
                    ({\"events\": [...], \"admit\": \"...\"})
    --rebalance off|ewma:ALPHA:THRESHOLD  elastic load-aware shard
                    rebalancing (default off): an EWMA speed model over
                    observed per-round rates plans at most one lazy
                    block-row migration per gradient round once the
                    slowest predicted finish exceeds THRESHOLD x the
                    fastest (needs --engine native; coded/uncoded
                    schemes; gd/lbfgs only)
    --csv <path>    write the per-iteration trace as CSV (includes the
                    event-annotated `events` column)
    SGD-only flags (--optimizer sgd):
    --batch-frac 0.1           per-round block-row mini-batch fraction (0,1];
                               1.0 reproduces gd's iterates bit for bit
    --lr 0.05                  base step size (default: the Theorem-1 rule)
    --lr-schedule constant|invt[:T0]|cosine:PERIOD
    --momentum 0.0             Polyak heavy-ball momentum in [0,1)
    --epoch-len 0              rounds per plateau epoch (0 = one data pass)
    --plateau-patience 0       non-improving epochs before early stop (0 = off)
    --plateau-tol 0.001        relative encoded-objective improvement threshold

  serve             many concurrent ridge jobs multiplexed on ONE resident worker
                    pool (multi-tenant mode; per-job virtual traces are
                    bitwise-identical to solo runs)
    --jobs 4        number of concurrent jobs (each gets its own cluster seed
                    seed+j, so delay streams differ while data is shared)
    --serve-policy fair|fifo|priority:N   round scheduler: fair round-robins
                    active jobs, fifo drains them in submission order,
                    priority:N serves the lowest of N classes first
                    (job j gets class j)
    --csv-dir PATH  write each job's trace to PATH/job<ID>.csv
    --scenario DSL --scenario-job ID   fault script scoped to ONE job (1-based
                    job id, default 1); sibling jobs never observe it
    plus the ridge problem/cluster flags: --n --p --lambda --workers --k
    --beta --encoder --optimizer (gd|lbfgs|sgd, default gd; alias --algo)
    --iters --delay --clock --storage --precision --grad-mode --threads
    --seed and the SGD-only flags (--batch-frac --lr --lr-schedule
    --momentum --epoch-len --plateau-patience --plateau-tol)

  mf                coded matrix factorization on synthetic MovieLens (Fig. 5/6)
    --users 240 --items 160 --ratings 8000 --embed 15 --lambda 10
    --epochs 5 --workers 8 --k 4 --encoder hadamard --beta 2.0
    --dist-threshold 64 --iters 8 --delay exp:10 --clock virtual|measured
    --storage dense|sparse|auto --precision f64|f32 --threads 0 --seed 0

  spectrum          eigenvalue spectra of S_A^T S_A (Fig. 2/3)
    --n 64 --beta 2.0 --workers 32 --k 16 --trials 10 --seed 0
    --encoders hadamard,gaussian,paley    comma-separated list
    --hist          print ASCII histograms

  check-artifacts   compile every artifact in the manifest on PJRT
    --dir artifacts

  help              this message
";

/// CLI entry point (also used by `main.rs`).
pub fn main_entry() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Dispatch a parsed command line (testable without process exit).
pub fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("ridge") => cmd_ridge(args),
        Some("serve") => cmd_serve(args),
        Some("mf") => cmd_mf(args),
        Some("spectrum") => cmd_spectrum(args),
        Some("check-artifacts") => cmd_check_artifacts(args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand {other:?} (try `codedopt help`)"),
    }
}

fn cmd_ridge(args: &Args) -> Result<()> {
    let n = args.flag_usize("n", 1024)?;
    let p = args.flag_usize("p", 256)?;
    let lambda = args.flag_f64("lambda", 0.05)?;
    let m = args.flag_usize("workers", 16)?;
    let k = args.flag_usize("k", m)?;
    let beta = args.flag_f64("beta", 2.0)?;
    let iters = args.flag_usize("iters", 100)?;
    let seed = args.flag_u64("seed", 0)?;
    let kind = EncoderKind::parse(args.flag_str("encoder", "hadamard"))?;
    let engine_kind = EngineKind::parse(args.flag_str("engine", "native"))?;
    let delay = DelayModel::parse(args.flag_str("delay", "exp:10"))?;
    let clock = ClockMode::parse(args.flag_str("clock", "virtual"))?;
    let storage = StorageKind::parse(args.flag_str("storage", "auto"))?;
    let precision = Precision::parse(args.flag_str("precision", "f64"))?;
    if precision == Precision::F32 && engine_kind == EngineKind::Xla {
        anyhow::bail!(
            "--precision f32 needs --engine native: the AOT HLO artifacts \
             are compiled for f64 dense shards"
        );
    }
    let grad_mode = GradMode::parse(args.flag_str("grad-mode", "gemv"))?;
    if grad_mode != GradMode::Gemv && engine_kind == EngineKind::Xla {
        anyhow::bail!(
            "--grad-mode {grad_mode} needs --engine native: the AOT HLO \
             artifacts are compiled for the gemv gradient kernel"
        );
    }
    let threads = args.flag_usize("threads", 0)?;
    let scheme = TemporalScheme::parse(args.flag_str("scheme", "none"))?;
    if scheme != TemporalScheme::None && args.flag("encoder").is_some() {
        anyhow::bail!(
            "--scheme {scheme} is a temporal gradient code that replaces the \
             within-round encoder; drop --encoder (or use --scheme none)"
        );
    }
    let pipeline_depth = args.flag_usize("pipeline-depth", 1)?;
    anyhow::ensure!(pipeline_depth >= 1, "--pipeline-depth must be >= 1");
    let scenario = match (args.flag("scenario"), args.flag("scenario-json")) {
        (Some(_), Some(_)) => {
            anyhow::bail!("--scenario and --scenario-json are mutually exclusive")
        }
        (Some(dsl), None) => Some(Scenario::parse(dsl)?),
        (None, Some(path)) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading scenario file {path}"))?;
            Some(Scenario::from_json(
                &Json::parse(&text).with_context(|| format!("parsing {path}"))?,
            )?)
        }
        (None, None) => None,
    };
    let rebalance = RebalanceConfig::parse(args.flag_str("rebalance", "off"))?;
    // --optimizer is canonical; --algo stays as the historical alias
    let algo = args.flag("optimizer").unwrap_or_else(|| args.flag_str("algo", "lbfgs"));
    if algo == "sgd" && rebalance != RebalanceConfig::Off {
        anyhow::bail!(
            "--rebalance is not supported with --optimizer sgd: mini-batch \
             aggregation reads the static per-worker row counts that migration \
             changes (use gd or lbfgs)"
        );
    }

    let code_label = if scheme == TemporalScheme::None {
        format!("encoder={kind}")
    } else {
        format!("scheme={scheme}")
    };
    println!(
        "# ridge: n={n} p={p} λ={lambda} m={m} k={k} β={beta} {code_label} \
         engine={engine_kind:?} clock={clock:?} algo={algo}{}",
        if pipeline_depth > 1 { format!(" pipeline-depth={pipeline_depth}") } else { String::new() }
    );
    let prob = QuadProblem::synthetic_gaussian(n, p, lambda, seed);
    let enc = if scheme == TemporalScheme::None {
        EncodedProblem::encode_stored_prec(&prob, kind, beta, m, seed, storage, precision)?
    } else {
        EncodedProblem::encode_temporal_stored_prec(&prob, scheme, m, seed, storage, precision)?
    }
    .with_grad_mode(grad_mode)?;
    println!(
        "# storage={} precision={} grad-mode={} ({} shard bytes across {} workers){}",
        enc.storage,
        enc.precision,
        grad_mode,
        enc.shard_mem_bytes(),
        enc.m(),
        if threads > 0 { format!("  threads={threads}") } else { String::new() }
    );
    let engine = build_engine_with(engine_kind, &enc, threads)?;
    let ccfg = ClusterConfig {
        workers: m,
        wait_for: k,
        delay,
        clock,
        ms_per_mflop: 0.5,
        seed,
    };
    let mut cluster = Cluster::new(&enc, engine, ccfg)?;
    if let Some(sc) = scenario {
        println!("# scenario: {sc}");
        cluster.set_scenario(sc)?;
    }
    if rebalance != RebalanceConfig::Off {
        println!("# rebalance: {rebalance}");
        cluster.set_rebalancer(&enc, rebalance)?;
    }
    // depth 1 takes the historical blocking path; deeper runs retire each
    // round at its k-th admission (a no-op for virtual-clock timing)
    let run_at_depth = |opt: &dyn SteppedOptimizer, cluster: &mut Cluster| {
        if pipeline_depth > 1 {
            run_pipelined(opt, &enc, cluster, iters, None, pipeline_depth)
        } else {
            opt.run(&enc, cluster, iters)
        }
    };
    let out = match algo {
        "gd" => run_at_depth(&CodedGd::new(GdConfig { seed, ..Default::default() }), &mut cluster)?,
        "lbfgs" => {
            let cfg = LbfgsConfig { seed, ..Default::default() };
            run_at_depth(&CodedLbfgs::new(cfg), &mut cluster)?
        }
        "sgd" => {
            let lr = args
                .flag("lr")
                .map(|v| v.parse::<f64>().with_context(|| format!("--lr {v}: not a number")))
                .transpose()?;
            let cfg = SgdConfig {
                lr,
                schedule: LrSchedule::parse(args.flag_str("lr-schedule", "constant"))?,
                momentum: args.flag_f64("momentum", 0.0)?,
                batch_frac: args.flag_f64("batch-frac", 0.1)?,
                epoch_len: args.flag_usize("epoch-len", 0)?,
                patience: args.flag_usize("plateau-patience", 0)?,
                plateau_tol: args.flag_f64("plateau-tol", 1e-3)?,
                seed,
            };
            cfg.validate()?;
            run_at_depth(&CodedSgd::new(cfg), &mut cluster)?
        }
        other => anyhow::bail!("unknown --optimizer {other:?} (gd|lbfgs|sgd)"),
    };
    let f_star = prob
        .exact_solution()
        .map(|w| prob.objective(&w))
        .unwrap_or(f64::NAN);
    println!("iter  f(w)          f_est         alpha       |A|   sim_ms");
    let stride = (out.trace.len() / 20).max(1);
    for r in out.trace.records.iter().step_by(stride) {
        println!(
            "{:>4}  {:.6e}  {:.6e}  {:.3e}  {:>3}  {:>9.2}",
            r.iter, r.f_true, r.f_est, r.alpha, r.responders, r.sim_ms
        );
    }
    println!(
        "# final f={:.6e}  f*={:.6e}  diverged={}  total sim time={:.1} ms",
        out.trace.last_objective(),
        f_star,
        out.trace.diverged(),
        out.trace.total_sim_ms()
    );
    if let Some(path) = args.flag("csv") {
        std::fs::write(path, out.trace.to_csv()).with_context(|| format!("writing {path}"))?;
        println!("# trace written to {path}");
    }
    Ok(())
}

/// Parse the shared `--optimizer`/`--algo` + SGD flag surface into a
/// [`ServeOptimizer`] (the serve path needs a config value, not a run call).
fn parse_serve_optimizer(args: &Args, seed: u64) -> Result<ServeOptimizer> {
    let algo = args.flag("optimizer").unwrap_or_else(|| args.flag_str("algo", "gd"));
    Ok(match algo {
        "gd" => ServeOptimizer::Gd(GdConfig { seed, ..Default::default() }),
        "lbfgs" => ServeOptimizer::Lbfgs(LbfgsConfig { seed, ..Default::default() }),
        "sgd" => {
            let lr = args
                .flag("lr")
                .map(|v| v.parse::<f64>().with_context(|| format!("--lr {v}: not a number")))
                .transpose()?;
            let cfg = SgdConfig {
                lr,
                schedule: LrSchedule::parse(args.flag_str("lr-schedule", "constant"))?,
                momentum: args.flag_f64("momentum", 0.0)?,
                batch_frac: args.flag_f64("batch-frac", 0.1)?,
                epoch_len: args.flag_usize("epoch-len", 0)?,
                patience: args.flag_usize("plateau-patience", 0)?,
                plateau_tol: args.flag_f64("plateau-tol", 1e-3)?,
                seed,
            };
            cfg.validate()?;
            ServeOptimizer::Sgd(cfg)
        }
        other => anyhow::bail!("unknown --optimizer {other:?} (gd|lbfgs|sgd)"),
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs = args.flag_usize("jobs", 4)?;
    anyhow::ensure!(jobs >= 1, "--jobs must be >= 1");
    let n = args.flag_usize("n", 256)?;
    let p = args.flag_usize("p", 32)?;
    let lambda = args.flag_f64("lambda", 0.05)?;
    let m = args.flag_usize("workers", 8)?;
    let k = args.flag_usize("k", m)?;
    let beta = args.flag_f64("beta", 2.0)?;
    let iters = args.flag_usize("iters", 20)?;
    let seed = args.flag_u64("seed", 0)?;
    let kind = EncoderKind::parse(args.flag_str("encoder", "hadamard"))?;
    let delay = DelayModel::parse(args.flag_str("delay", "exp:10"))?;
    let clock = ClockMode::parse(args.flag_str("clock", "virtual"))?;
    let storage = StorageKind::parse(args.flag_str("storage", "auto"))?;
    let precision = Precision::parse(args.flag_str("precision", "f64"))?;
    let grad_mode = GradMode::parse(args.flag_str("grad-mode", "gemv"))?;
    let threads = args.flag_usize("threads", 0)?;
    let policy = ServePolicy::parse(args.flag_str("serve-policy", "fair"))?;
    let optimizer = parse_serve_optimizer(args, seed)?;
    let scenario = args.flag("scenario").map(Scenario::parse).transpose()?;
    let scenario_job = args.flag_usize("scenario-job", 1)?;
    if scenario.is_some() {
        anyhow::ensure!(
            (1..=jobs).contains(&scenario_job),
            "--scenario-job {scenario_job} out of range (job ids are 1..={jobs})"
        );
    }

    println!(
        "# serve: jobs={jobs} policy={policy} n={n} p={p} λ={lambda} m={m} k={k} \
         encoder={kind} algo={}",
        optimizer.label()
    );
    let prob = QuadProblem::synthetic_gaussian(n, p, lambda, seed);
    let mut cache = EncodedShardCache::new();
    let mut server = JobServer::with_lanes(threads, policy);
    for j in 0..jobs {
        let enc =
            cache.get_or_encode_mode(&prob, kind, beta, m, seed, storage, precision, grad_mode)?;
        let cluster = ClusterConfig {
            workers: m,
            wait_for: k,
            delay: delay.clone(),
            clock,
            ms_per_mflop: 0.5,
            seed: seed + j as u64,
        };
        let job_scenario = if scenario_job == j + 1 { scenario.clone() } else { None };
        if let Some(sc) = &job_scenario {
            println!("# scenario (job {}): {sc}", j + 1);
        }
        server.submit(JobSpec {
            enc,
            cluster,
            optimizer: optimizer.clone(),
            iters,
            w0: None,
            scenario: job_scenario,
            priority: j,
        })?;
    }
    println!("# cache: encodes={} hits={}", cache.encodes(), cache.hits());
    let outcomes = server.run()?;
    println!("job   rounds  final_f");
    for o in &outcomes {
        println!("{:>3}  {:>6}  {:.6e}", o.job, o.rounds, o.output.trace.last_objective());
    }
    if let Some(dir) = args.flag("csv-dir") {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir}"))?;
        for o in &outcomes {
            let path = format!("{dir}/job{}.csv", o.job);
            std::fs::write(&path, o.output.trace.to_csv())
                .with_context(|| format!("writing {path}"))?;
        }
        println!("# {} traces written to {dir}", outcomes.len());
    }
    Ok(())
}

fn cmd_mf(args: &Args) -> Result<()> {
    use crate::mf::{synthetic_movielens, train, MfConfig, SyntheticConfig};
    if args.flag("scenario").is_some() || args.flag("scenario-json").is_some() {
        anyhow::bail!(
            "--scenario is not supported by `mf`: the MF pipeline spins up many \
             short-lived subsolver clusters, so one round-indexed script has no \
             single cluster to attach to; use `ridge` for scenario runs"
        );
    }
    let seed = args.flag_u64("seed", 0)?;
    let scfg = SyntheticConfig {
        n_users: args.flag_usize("users", 240)?,
        n_items: args.flag_usize("items", 160)?,
        n_ratings: args.flag_usize("ratings", 8000)?,
        ..SyntheticConfig::small(seed)
    };
    let m = args.flag_usize("workers", 8)?;
    let cfg = MfConfig {
        embed: args.flag_usize("embed", 15)?,
        lambda: args.flag_f64("lambda", 10.0)?,
        epochs: args.flag_usize("epochs", 5)?,
        m,
        k: args.flag_usize("k", (m / 2).max(1))?,
        encoder: EncoderKind::parse(args.flag_str("encoder", "hadamard"))?,
        beta: args.flag_f64("beta", 2.0)?,
        dist_threshold: args.flag_usize("dist-threshold", 64)?,
        lbfgs_iters: args.flag_usize("iters", 8)?,
        delay: DelayModel::parse(args.flag_str("delay", "exp:10"))?,
        clock: ClockMode::parse(args.flag_str("clock", "virtual"))?,
        storage: StorageKind::parse(args.flag_str("storage", "auto"))?,
        precision: Precision::parse(args.flag_str("precision", "f64"))?,
        threads: args.flag_usize("threads", 0)?,
        seed,
        ..Default::default()
    };
    println!(
        "# mf: users={} items={} ratings~{} embed={} m={} k={} encoder={} storage={} precision={}",
        scfg.n_users, scfg.n_items, scfg.n_ratings, cfg.embed, cfg.m, cfg.k, cfg.encoder,
        cfg.storage, cfg.precision
    );
    let all = synthetic_movielens(&scfg);
    let (tr, te) = all.split(0.2, seed ^ 0x5117);
    let out = train(&tr, &te, &cfg)?;
    println!("epoch  train_rmse  test_rmse");
    for (e, (trr, ter)) in out.train_rmse.iter().zip(&out.test_rmse).enumerate() {
        println!("{:>5}  {:>10.4}  {:>9.4}", e + 1, trr, ter);
    }
    println!(
        "# sim time: distributed={:.1} ms, local={:.1} ms ({} dist / {} local solves, {} capped)",
        out.sim_ms, out.local_ms, out.dist_solves, out.local_solves, out.capped
    );
    Ok(())
}

fn cmd_spectrum(args: &Args) -> Result<()> {
    use crate::encoding::spectrum::{histogram, sample_spectrum};
    let n = args.flag_usize("n", 64)?;
    let beta = args.flag_f64("beta", 2.0)?;
    let m = args.flag_usize("workers", 32)?;
    let k = args.flag_usize("k", 16)?;
    let trials = args.flag_usize("trials", 10)?;
    let seed = args.flag_u64("seed", 0)?;
    let list = args.flag_str("encoders", "uncoded,gaussian,hadamard,paley,hadamard-etf,steiner");
    println!("# spectrum of S_A^T S_A/(c·η): n={n} β={beta} m={m} k={k} trials={trials}");
    println!("{:<14} {:>9} {:>9} {:>9} {:>7}", "encoder", "λmin", "λmax", "ε", "bulk");
    for name in list.split(',') {
        let kind = EncoderKind::parse(name.trim())?;
        let enc = kind.build(n, beta, seed)?;
        let s = enc.materialize();
        let stats = sample_spectrum(&s, m, k, trials, seed, enc.gram_scale());
        println!(
            "{:<14} {:>9.4} {:>9.4} {:>9.4} {:>6.1}%",
            kind.label(),
            stats.lambda_min,
            stats.lambda_max,
            stats.epsilon,
            100.0 * stats.bulk_fraction
        );
        if args.switch("hist") {
            let h = histogram(&stats.eigs, 0.0, 2.0, 40);
            let max = *h.iter().max().unwrap_or(&1) as f64;
            for (b, &c) in h.iter().enumerate() {
                if c > 0 {
                    let lo = b as f64 * 0.05;
                    let bar = "#".repeat(((c as f64 / max) * 50.0).ceil() as usize);
                    println!("    [{:4.2},{:4.2}) {bar} {c}", lo, lo + 0.05);
                }
            }
        }
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_check_artifacts(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.flag_str("dir", "artifacts"));
    let manifest = crate::runtime::Manifest::load(&dir)?;
    println!("# {} artifacts in {dir:?}", manifest.entries.len());
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT: {e:?}"))?;
    for e in &manifest.entries {
        let path = dir.join(&e.file);
        let text_len = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|err| anyhow::anyhow!("parse {}: {err:?}", e.name))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|err| anyhow::anyhow!("compile {}: {err:?}", e.name))?;
        println!("  ok {} ({} bytes, kind={}, dims={:?})", e.name, text_len, e.kind, e.dims);
    }
    println!("# all artifacts compile on PJRT cpu");
    Ok(())
}

/// Without the `xla` feature, validate the manifest and file presence
/// only — the PJRT compile check needs the real bindings.
#[cfg(not(feature = "xla"))]
fn cmd_check_artifacts(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.flag_str("dir", "artifacts"));
    let manifest = crate::runtime::Manifest::load(&dir)?;
    println!("# {} artifacts in {dir:?}", manifest.entries.len());
    for e in &manifest.entries {
        let path = dir.join(&e.file);
        let meta = std::fs::metadata(&path)
            .with_context(|| format!("artifact file missing: {path:?}"))?;
        println!("  ok {} ({} bytes, kind={}, dims={:?})", e.name, meta.len(), e.kind, e.dims);
    }
    println!(
        "# manifest + files OK; PJRT compile check skipped (built without the `xla` feature)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(toks: &[&str]) -> Result<()> {
        dispatch(&Args::from_iter(toks.iter().map(|s| s.to_string())).unwrap())
    }

    #[test]
    fn help_runs() {
        run(&["help"]).unwrap();
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&["frobnicate"]).is_err());
    }

    #[test]
    fn tiny_ridge_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "5",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_spectrum_runs() {
        run(&[
            "spectrum", "--n", "16", "--workers", "8", "--k", "4", "--trials", "2",
            "--encoders", "gaussian,hadamard",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_ridge_measured_clock_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
            "--clock", "measured",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_ridge_sparse_storage_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
            "--encoder", "uncoded", "--storage", "sparse",
        ])
        .unwrap();
    }

    #[test]
    fn ridge_sparse_storage_rejects_densifying_encoder() {
        assert!(run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--encoder", "hadamard", "--storage", "sparse",
        ])
        .is_err());
    }

    #[test]
    fn ridge_rejects_bad_storage() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--storage", "ram",
        ])
        .is_err());
    }

    #[test]
    fn tiny_ridge_f32_precision_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "5",
            "--precision", "f32",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_ridge_f32_sparse_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
            "--encoder", "uncoded", "--storage", "sparse", "--precision", "f32",
        ])
        .unwrap();
    }

    #[test]
    fn ridge_rejects_bad_precision() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--precision", "f16",
        ])
        .is_err());
    }

    #[test]
    fn ridge_rejects_f32_with_xla_engine() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--engine", "xla", "--precision", "f32",
        ])
        .is_err());
    }

    #[test]
    fn tiny_ridge_gram_mode_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "5",
            "--grad-mode", "gram",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_ridge_auto_grad_mode_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
            "--grad-mode", "auto", "--optimizer", "sgd", "--batch-frac", "0.5",
        ])
        .unwrap();
    }

    #[test]
    fn ridge_rejects_bad_grad_mode() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--grad-mode", "hessian",
        ])
        .is_err());
    }

    #[test]
    fn ridge_rejects_gram_with_sparse_storage() {
        assert!(run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--encoder", "uncoded", "--storage", "sparse", "--grad-mode", "gram",
        ])
        .is_err());
    }

    #[test]
    fn ridge_rejects_gram_with_f32_precision() {
        assert!(run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--precision", "f32", "--grad-mode", "gram",
        ])
        .is_err());
    }

    #[test]
    fn ridge_rejects_gram_with_xla_engine() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--engine", "xla", "--grad-mode", "gram",
        ])
        .is_err());
    }

    #[test]
    fn tiny_ridge_thread_cap_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
            "--threads", "2",
        ])
        .unwrap();
    }

    #[test]
    fn ridge_rejects_bad_algo() {
        let r = run(&["ridge", "--n", "32", "--p", "4", "--algo", "bogus", "--iters", "1"]);
        assert!(r.is_err());
    }

    #[test]
    fn tiny_ridge_sgd_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "6",
            "--optimizer", "sgd", "--batch-frac", "0.5", "--lr-schedule", "invt:5",
            "--momentum", "0.5",
        ])
        .unwrap();
    }

    #[test]
    fn ridge_sgd_via_algo_alias_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "4", "--iters", "3",
            "--algo", "sgd", "--batch-frac", "1.0",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_serve_runs() {
        run(&[
            "serve", "--jobs", "3", "--n", "64", "--p", "8", "--workers", "4", "--k", "3",
            "--iters", "4", "--threads", "2",
        ])
        .unwrap();
    }

    #[test]
    fn serve_priority_policy_runs() {
        run(&[
            "serve", "--jobs", "3", "--n", "64", "--p", "8", "--workers", "4", "--k", "3",
            "--iters", "2", "--serve-policy", "priority:2", "--threads", "2",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_serve_f32_runs() {
        run(&[
            "serve", "--jobs", "2", "--n", "64", "--p", "8", "--workers", "4", "--k", "3",
            "--iters", "3", "--threads", "2", "--precision", "f32",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_serve_gram_mode_runs() {
        run(&[
            "serve", "--jobs", "2", "--n", "64", "--p", "8", "--workers", "4", "--k", "3",
            "--iters", "3", "--threads", "2", "--grad-mode", "gram",
        ])
        .unwrap();
    }

    #[test]
    fn serve_rejects_gram_with_f32_precision() {
        assert!(run(&[
            "serve", "--jobs", "2", "--n", "64", "--p", "8", "--workers", "4", "--k", "3",
            "--iters", "1", "--precision", "f32", "--grad-mode", "gram",
        ])
        .is_err());
    }

    #[test]
    fn serve_rejects_bad_policy() {
        assert!(run(&[
            "serve", "--jobs", "2", "--n", "32", "--p", "4", "--workers", "4", "--k", "4",
            "--iters", "1", "--serve-policy", "rr",
        ])
        .is_err());
    }

    #[test]
    fn serve_scoped_scenario_runs_and_writes_csvs() {
        let dir = std::env::temp_dir().join("codedopt_cli_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        run(&[
            "serve", "--jobs", "2", "--n", "64", "--p", "8", "--workers", "4", "--k", "3",
            "--iters", "4", "--threads", "2", "--scenario", "slow:1:3@1", "--scenario-job",
            "2", "--csv-dir", dir.to_str().unwrap(),
        ])
        .unwrap();
        assert!(dir.join("job1.csv").exists() && dir.join("job2.csv").exists());
    }

    #[test]
    fn serve_rejects_out_of_range_scenario_job() {
        assert!(run(&[
            "serve", "--jobs", "2", "--n", "32", "--p", "4", "--workers", "4", "--k", "4",
            "--iters", "1", "--scenario", "crash:1@2", "--scenario-job", "3",
        ])
        .is_err());
    }

    #[test]
    fn tiny_ridge_scenario_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "6",
            "--scenario", "crash:1@2,recover:1@4;admit:rotate:k",
        ])
        .unwrap();
    }

    #[test]
    fn ridge_scenario_json_file_runs() {
        let dir = std::env::temp_dir().join("codedopt_cli_scenario_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("scenario.json");
        std::fs::write(&path, "{\"events\": [\"slow:0:4@1\"], \"admit\": \"fixed:1.2\"}")
            .unwrap();
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "4",
            "--scenario-json", path.to_str().unwrap(),
        ])
        .unwrap();
    }

    #[test]
    fn ridge_rejects_bad_scenario() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "3", "--iters", "1",
            "--scenario", "explode:1@2",
        ])
        .is_err());
        // out-of-range worker caught at attach time
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "3", "--iters", "1",
            "--scenario", "crash:9@2",
        ])
        .is_err());
        // mutually exclusive sources
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "3", "--iters", "1",
            "--scenario", "crash:1@2", "--scenario-json", "nope.json",
        ])
        .is_err());
    }

    #[test]
    fn mf_rejects_scenario_flags_and_names_the_supported_path() {
        for flags in [
            &["--scenario", "crash:1@2"][..],
            &["--scenario-json", "scenario.json"][..],
        ] {
            let mut toks = vec![
                "mf", "--users", "20", "--items", "10", "--ratings", "100", "--epochs", "1",
            ];
            toks.extend_from_slice(flags);
            let err = run(&toks).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("use `ridge` for scenario runs"),
                "mf scenario rejection must point at the supported path, got: {msg}"
            );
        }
    }

    #[test]
    fn tiny_mf_f32_runs() {
        run(&[
            "mf", "--users", "20", "--items", "10", "--ratings", "100", "--epochs", "1",
            "--workers", "4", "--k", "2", "--dist-threshold", "8", "--iters", "2",
            "--precision", "f32",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_ridge_rebalance_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "4", "--iters", "6",
            "--rebalance", "ewma:0.5:2", "--delay", "none", "--scenario", "slow:1:3@0",
        ])
        .unwrap();
    }

    #[test]
    fn ridge_rejects_bad_rebalance_grammar() {
        for bad in ["on", "ewma:0.5", "ewma:0:2", "ewma:0.5:0.5"] {
            assert!(
                run(&[
                    "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters",
                    "1", "--rebalance", bad,
                ])
                .is_err(),
                "should reject --rebalance {bad:?}"
            );
        }
    }

    #[test]
    fn ridge_rejects_rebalance_with_sgd() {
        let err = run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--optimizer", "sgd", "--rebalance", "ewma:0.5:2",
        ])
        .unwrap_err();
        assert!(format!("{err:#}").contains("sgd"), "error should name the conflict: {err:#}");
    }

    #[test]
    fn ridge_rejects_rebalance_with_partition_dedup_scheme() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--encoder", "replication", "--rebalance", "ewma:0.5:2",
        ])
        .is_err());
    }

    #[test]
    fn tiny_ridge_seq_scheme_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
            "--scheme", "seq:4:1",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_ridge_stoch_scheme_runs() {
        run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
            "--scheme", "stoch:0.5", "--optimizer", "lbfgs",
        ])
        .unwrap();
    }

    #[test]
    fn tiny_ridge_pipelined_runs_on_both_clocks() {
        for clock in ["virtual", "measured"] {
            run(&[
                "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "3",
                "--clock", clock, "--pipeline-depth", "2", "--threads", "2",
            ])
            .unwrap();
        }
    }

    #[test]
    fn ridge_rejects_scheme_combined_with_encoder() {
        let err = run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--scheme", "seq:4:1", "--encoder", "hadamard",
        ])
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("--encoder"),
            "error should name the conflict: {err:#}"
        );
    }

    #[test]
    fn ridge_rejects_malformed_scheme_and_zero_pipeline_depth() {
        assert!(run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--scheme", "seq:4",
        ])
        .is_err());
        assert!(run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--pipeline-depth", "0",
        ])
        .is_err());
    }

    #[test]
    fn ridge_rejects_temporal_scheme_with_sparse_storage_or_rebalance() {
        assert!(run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--scheme", "seq:4:1", "--storage", "sparse",
        ])
        .is_err());
        assert!(run(&[
            "ridge", "--n", "64", "--p", "8", "--workers", "4", "--k", "3", "--iters", "1",
            "--scheme", "seq:4:1", "--rebalance", "ewma:0.5:2",
        ])
        .is_err());
    }

    #[test]
    fn ridge_sgd_rejects_bad_lr_schedule() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--optimizer", "sgd", "--lr-schedule", "warp:3",
        ])
        .is_err());
    }

    #[test]
    fn ridge_sgd_rejects_bad_batch_frac() {
        assert!(run(&[
            "ridge", "--n", "32", "--p", "4", "--workers", "4", "--k", "4", "--iters", "1",
            "--optimizer", "sgd", "--batch-frac", "1.5",
        ])
        .is_err());
    }
}
