//! Persistent worker pool: shard-owning resident threads with zero
//! per-round spawn, shared by any number of concurrently-served jobs.
//!
//! The historical native engine re-entered `std::thread::scope` for every
//! round, so each GD/SGD/L-BFGS/FISTA iteration paid thread creation,
//! shard re-borrow, and stack setup — overhead a real m-node deployment
//! amortizes exactly once, at cluster start. [`WorkerPool`] is that
//! amortization: a fixed set of **lanes** (OS threads) spawned once, each
//! *owning* a contiguous range of worker slots per staged job (shard data
//! moved in at staging — no per-round borrow dance) plus a resident
//! scratch buffer per worker, receiving round commands over a per-lane
//! channel and streaming results into the round's
//! [`Collector`](super::stream::Collector) exactly like the scoped-spawn
//! engine did.
//!
//! # Multi-tenant job protocol
//!
//! Every command carries a **job id**. A job is one staged encoded
//! problem: its shards are chunked over the shared lanes with a per-job
//! chunk size (`chunk_j = ceil(m_j / lanes)`), its park mask is layered
//! per job over the lanes (a `crash:` scenario parking job A's worker 3
//! never touches job B's worker 3), and its rounds address only its own
//! slots. The single-tenant surface ([`WorkerPool::new`],
//! [`WorkerPool::grad_streamed`], …) is job 0 of the same machinery, so
//! the resident engine and every historical trace are byte-identical to
//! the pre-serve pool. [`WorkerPool::with_lanes`] spawns a job-less pool
//! for the serve path; [`WorkerPool::stage_job`] and
//! [`WorkerPool::retire`] add and drop tenants without respawning.
//!
//! # Command/response protocol
//!
//! Each lane runs a small state machine over its command channel:
//!
//! | command | effect | acknowledged |
//! |---------|--------|--------------|
//! | `Grad` | fused gradient over the job's slots on this lane, streamed into the sink | yes |
//! | `GradBatch` | range-restricted mini-batch gradient over a [`BatchPlan`] | yes |
//! | `Curv` | line-search `‖X̃_i d‖²` per slot | yes |
//! | `SetParked` | mark one owned worker of one job parked/unparked | no (ordered channel) |
//! | `Reconfigure` | replace one job's slot range with a new problem's shards | yes |
//! | `Migrate` | swap individual owned workers' slots (rebalancer shard handoff; park flags and worker count preserved, only affected lanes addressed) | yes |
//! | `Retire` | drop one job's slots (serve-job completion) | yes |
//! | `Shutdown` | exit the lane thread (sent by `Drop`) | no (joined) |
//!
//! Round dispatch sends one command per lane, then blocks on each lane's
//! acknowledgement. A lane drops its [`Collector`](super::stream::Collector)
//! handle *before* acknowledging, so when dispatch returns, the caller's handle is the
//! only one left and `into_collected` succeeds; dispatch hands each lane
//! a lane-registered clone and tags the sink with the job id, so a leaked
//! handle is attributed to its job and lane by the sole-owner panic.
//! Broadcast vectors cross the channel as `Arc<[f64]>` — one copy into
//! the Arc per round, one refcount bump per lane. Worker-side compute
//! allocates nothing: the gradient/residual scratch is resident in each
//! slot, and the only per-round allocations left are the round's
//! *messages* (broadcast copy, mini-batch plan, collector, delivered
//! payload clones) — exactly what a network backend would serialize
//! anyway, and what `fig_dispatch` counts.
//!
//! # Crash-park invariant
//!
//! A scenario `crash:`/`leave:` event **parks** the worker instead of
//! tearing down its lane: the slot (shard + scratch) stays resident and
//! the lane simply skips it during round fan-out, so a later
//! `recover:`/`join:` unparks it with zero restaging cost. Parking is an
//! engine-side *compute-skipping* optimization only — admission already
//! excludes crashed workers via delay/eligibility masks, which is why
//! virtual-clock traces are bit-for-bit identical whether or not the
//! engine supports parking (pinned by `rust/tests/pool_equivalence.rs`).
//! Direct per-worker calls (`only = Some(w)`) ignore the parked flag:
//! they are a staging/debug surface, not round fan-out.

use super::stream::{CurvCollector, GradCollector};
use crate::linalg::{DataMat, GradMode, Mat};
use crate::problem::{BatchPlan, EncodedProblem, WorkerShard};
use anyhow::{anyhow, ensure, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Recycling slab for the `Arc<[f64]>` broadcast buffers that cross the
/// lane channels every round (`w` for gradient rounds, `d` for
/// line-search rounds — same length `p`, so one slab serves both).
///
/// Lifecycle: [`BufferPool::acquire`] first sweeps `in_flight` — every
/// buffer whose refcount has dropped back to 1 (all lanes acked and
/// dropped their clones, the dispatch call returned) moves to `free` —
/// then serves the request from `free` via `Arc::get_mut` +
/// `copy_from_slice`, falling back to a fresh `Arc::from` when nothing
/// round-tripped yet. Under pipelined dispatch (depth > 1) the lanes
/// still hold clones of the previous rounds' buffers at acquire time, so
/// their refcounts stay above 1 and the slab *naturally* degrades to
/// fresh allocation — exactly the fallback the deferred path needs, with
/// no mode flag. A problem swap that changes `p` retires stale-length
/// buffers on the way through (`free` only ever holds current-length
/// buffers; mismatched reclaims are dropped).
pub(crate) struct BufferPool {
    free: Vec<Arc<[f64]>>,
    in_flight: Vec<Arc<[f64]>>,
    /// Buffers served by recycling an earlier round's allocation.
    reused: u64,
    /// Buffers served by a fresh heap allocation.
    fresh: u64,
}

impl BufferPool {
    pub(crate) fn new() -> Self {
        BufferPool { free: Vec::new(), in_flight: Vec::new(), reused: 0, fresh: 0 }
    }

    /// Hand out a broadcast buffer holding a copy of `data`, recycling a
    /// round-tripped buffer when one is available (see the type docs).
    /// The slab keeps one clone in `in_flight` to observe the refcount.
    pub(crate) fn acquire(&mut self, data: &[f64]) -> Arc<[f64]> {
        let mut i = 0;
        while i < self.in_flight.len() {
            if Arc::strong_count(&self.in_flight[i]) == 1 {
                let buf = self.in_flight.swap_remove(i);
                if buf.len() == data.len() {
                    self.free.push(buf);
                }
            } else {
                i += 1;
            }
        }
        let buf = loop {
            match self.free.pop() {
                Some(mut buf) if buf.len() == data.len() => {
                    Arc::get_mut(&mut buf)
                        .expect("free slab buffers are sole-owned")
                        .copy_from_slice(data);
                    self.reused += 1;
                    break buf;
                }
                Some(_) => continue, // stale length from a problem swap
                None => {
                    self.fresh += 1;
                    break Arc::from(data);
                }
            }
        };
        self.in_flight.push(buf.clone());
        buf
    }

    /// `(reused, fresh)` acquisition counts since construction.
    pub(crate) fn stats(&self) -> (u64, u64) {
        (self.reused, self.fresh)
    }
}

/// Per-shard Gram cache: `G = X̃ᵀX̃` (p×p, exactly symmetric), `c = X̃ᵀỹ`
/// and `yty = ỹᵀỹ`, precomputed once at staging time so every gradient
/// round is one symmetric f64 GEMV:
///
/// ```text
/// g = G·w − c          (≡ X̃ᵀ(X̃w − ỹ))
/// f = wᵀ(G·w) − 2·wᵀc + yty   (≡ ‖X̃w − ỹ‖²)
/// ```
///
/// The identity is exact in real arithmetic; in floats the accumulation
/// is reassociated (p-length dot products instead of row-wise fused
/// passes), which is why `--grad-mode gram` carries a ≤1e-9 *numeric*
/// equivalence pin rather than the gemv path's bitwise one.
struct GramCache {
    g: Mat,
    c: Vec<f64>,
    yty: f64,
}

impl GramCache {
    fn build(x: &DataMat, y: &[f64]) -> GramCache {
        let g = x.gram();
        let mut c = vec![0.0; x.cols()];
        x.gemv_t_into(y, &mut c);
        let yty = crate::linalg::dot(y, y);
        GramCache { g, c, yty }
    }
}

/// One worker's resident data + scratch (the kernels allocate nothing;
/// the delivered payload is recycled through the collector's spare list
/// when one round-tripped, cloned fresh otherwise). The shard keeps
/// whatever storage backend the partitioner produced — the fused kernels
/// are storage-dispatched inside [`DataMat`] — plus an optional Gram
/// cache when the shard was resolved to `--grad-mode gram`.
pub(crate) struct Slot {
    x: DataMat,
    y: Vec<f64>,
    grad_buf: Vec<f64>,
    resid_buf: Vec<f64>,
    /// `Some` iff the shard's resolved grad mode is [`GradMode::Gram`]:
    /// full-shard gradient rounds take the cached-Gram fast path.
    /// Mini-batch rounds always use the row-restricted fused kernels —
    /// a Gram matrix has no row structure left to restrict.
    gram: Option<GramCache>,
}

impl Slot {
    /// Stage every shard of `prob` (data + preallocated scratch buffers).
    pub(crate) fn stage(prob: &EncodedProblem) -> Vec<Slot> {
        prob.shards.iter().map(|s| Slot::stage_shard(s, prob.p())).collect()
    }

    /// Stage a single shard (the rebalancer's migration handoff unit).
    /// Gram-mode shards rebuild their cache here, which is what keeps a
    /// migrated shard's cache consistent with its data by construction.
    pub(crate) fn stage_shard(shard: &WorkerShard, p: usize) -> Slot {
        let gram = (shard.grad_mode == GradMode::Gram)
            .then(|| GramCache::build(&shard.x, &shard.y));
        Slot {
            x: shard.x.clone(),
            y: shard.y.clone(),
            grad_buf: vec![0.0; p],
            resid_buf: vec![0.0; shard.x.rows()],
            gram,
        }
    }
}

/// One round command shipped to a lane (module docs have the table).
enum Command {
    /// Full-shard gradient round for one job.
    Grad {
        job: usize,
        w: Arc<[f64]>,
        sink: GradCollector,
        only: Option<usize>,
        skip_parked: bool,
    },
    /// Mini-batch gradient round over a [`BatchPlan`] for one job.
    GradBatch {
        job: usize,
        w: Arc<[f64]>,
        plan: Arc<BatchPlan>,
        sink: GradCollector,
        only: Option<usize>,
    },
    /// Line-search round for one job.
    Curv {
        job: usize,
        d: Arc<[f64]>,
        sink: CurvCollector,
        only: Option<usize>,
        skip_parked: bool,
    },
    /// Park or unpark one owned worker of one job (crash-park invariant).
    SetParked { job: usize, worker: usize, parked: bool },
    /// Replace one job's owned slots (problem swap / job staging).
    Reconfigure { job: usize, base: usize, slots: Vec<Slot> },
    /// Swap individual owned workers' slots in place (shard migration):
    /// unlike `Reconfigure` this preserves park flags and worker count.
    Migrate { job: usize, slots: Vec<(usize, Slot)> },
    /// Drop one job's slots (a served job finished).
    Retire { job: usize },
    /// Exit the lane thread.
    Shutdown,
}

/// One job's owned worker range on a lane, with its per-job park mask.
struct JobSlots {
    base: usize,
    slots: Vec<Slot>,
    parked: Vec<bool>,
}

impl JobSlots {
    fn run_grad(
        &mut self,
        w: &[f64],
        sink: &GradCollector,
        only: Option<usize>,
        skip_parked: bool,
    ) {
        let JobSlots { base, slots, parked } = self;
        for (j, slot) in slots.iter_mut().enumerate() {
            let wid = *base + j;
            if let Some(o) = only {
                if o != wid {
                    continue;
                }
            } else if skip_parked && parked[j] {
                continue;
            }
            if sink.is_cancelled() {
                break;
            }
            let t0 = std::time::Instant::now();
            let f = match &slot.gram {
                // Gram fast path: g = G·w − c, f = wᵀ(Gw) − 2wᵀc + yty.
                // The wᵀ(Gw) dot runs *before* the c subtraction so the
                // objective uses the unmodified G·w product.
                Some(gc) => {
                    gc.g.gemv_into(w, &mut slot.grad_buf);
                    let wgw = crate::linalg::dot(w, &slot.grad_buf);
                    let wc = crate::linalg::dot(w, &gc.c);
                    for (gi, ci) in slot.grad_buf.iter_mut().zip(&gc.c) {
                        *gi -= ci;
                    }
                    wgw - 2.0 * wc + gc.yty
                }
                None => slot.x.fused_grad(w, &slot.y, &mut slot.grad_buf, &mut slot.resid_buf),
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            sink.deliver(wid, recycle_payload(sink, &slot.grad_buf, f), ms);
        }
    }

    fn run_grad_batch(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
        sink: &GradCollector,
        only: Option<usize>,
    ) {
        let JobSlots { base, slots, parked } = self;
        for (j, slot) in slots.iter_mut().enumerate() {
            let wid = *base + j;
            if let Some(o) = only {
                if o != wid {
                    continue;
                }
            } else if parked[j] {
                continue;
            }
            if sink.is_cancelled() {
                break;
            }
            let t0 = std::time::Instant::now();
            slot.grad_buf.fill(0.0);
            let mut f = 0.0;
            for &(lo, hi) in &plan.segments[wid] {
                f += slot.x.fused_grad_range(
                    w,
                    &slot.y,
                    &mut slot.grad_buf,
                    &mut slot.resid_buf,
                    lo,
                    hi,
                );
            }
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            sink.deliver(wid, recycle_payload(sink, &slot.grad_buf, f), ms);
        }
    }

    fn run_curv(
        &mut self,
        d: &[f64],
        sink: &CurvCollector,
        only: Option<usize>,
        skip_parked: bool,
    ) {
        let JobSlots { base, slots, parked } = self;
        for (j, slot) in slots.iter_mut().enumerate() {
            let wid = *base + j;
            if let Some(o) = only {
                if o != wid {
                    continue;
                }
            } else if skip_parked && parked[j] {
                continue;
            }
            if sink.is_cancelled() {
                break;
            }
            let t0 = std::time::Instant::now();
            slot.x.gemv_into(d, &mut slot.resid_buf);
            let q = crate::linalg::dot(&slot.resid_buf, &slot.resid_buf);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            sink.deliver(wid, q, ms);
        }
    }
}

/// Build one gradient delivery, recycling a spare payload vector donated
/// by the collector's previous round when one is available (the
/// steady-state case under a rearmed sink: the spare has the right
/// capacity already, so `clear` + `extend_from_slice` copies without
/// allocating). A fresh sink, or a sink whose payloads were drained out
/// by the caller, has no spares and falls back to a plain clone.
fn recycle_payload(sink: &GradCollector, grad: &[f64], f: f64) -> (Vec<f64>, f64) {
    match sink.take_spare() {
        Some((mut buf, _)) => {
            buf.clear();
            buf.extend_from_slice(grad);
            (buf, f)
        }
        None => (grad.to_vec(), f),
    }
}

/// Lane-thread state: every staged job's owned slots, by job id.
struct LaneState {
    jobs: BTreeMap<usize, JobSlots>,
}

/// Lane main loop. Collector handles are dropped **before** the
/// acknowledgement is sent — the dispatch side relies on this to unwrap
/// the round's collector right after the last ack (see the module docs).
/// Acks carry no payload: the round commands are infallible on the lane
/// side (a round for a job with no slots on this lane is an ack-only
/// no-op), so the only failure mode is a dead lane, which dispatch
/// observes as a channel disconnect.
fn lane_main(mut st: LaneState, rx: Receiver<Command>, ack: Sender<()>) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Grad { job, w, sink, only, skip_parked } => {
                if let Some(js) = st.jobs.get_mut(&job) {
                    js.run_grad(&w, &sink, only, skip_parked);
                }
                drop(sink);
                drop(w);
                let _ = ack.send(());
            }
            Command::GradBatch { job, w, plan, sink, only } => {
                if let Some(js) = st.jobs.get_mut(&job) {
                    js.run_grad_batch(&w, &plan, &sink, only);
                }
                drop(sink);
                drop(plan);
                drop(w);
                let _ = ack.send(());
            }
            Command::Curv { job, d, sink, only, skip_parked } => {
                if let Some(js) = st.jobs.get_mut(&job) {
                    js.run_curv(&d, &sink, only, skip_parked);
                }
                drop(sink);
                drop(d);
                let _ = ack.send(());
            }
            Command::SetParked { job, worker, parked } => {
                if let Some(js) = st.jobs.get_mut(&job) {
                    if let Some(j) = worker.checked_sub(js.base) {
                        if j < js.parked.len() {
                            js.parked[j] = parked;
                        }
                    }
                }
            }
            Command::Reconfigure { job, base, slots } => {
                let parked = vec![false; slots.len()];
                st.jobs.insert(job, JobSlots { base, slots, parked });
                let _ = ack.send(());
            }
            Command::Migrate { job, slots } => {
                if let Some(js) = st.jobs.get_mut(&job) {
                    for (worker, slot) in slots {
                        if let Some(j) = worker.checked_sub(js.base) {
                            if j < js.slots.len() {
                                js.slots[j] = slot;
                            }
                        }
                    }
                }
                let _ = ack.send(());
            }
            Command::Retire { job } => {
                st.jobs.remove(&job);
                let _ = ack.send(());
            }
            Command::Shutdown => break,
        }
    }
}

/// A lane: one resident OS thread plus its command/ack channels.
struct Lane {
    tx: Sender<Command>,
    ack: Receiver<()>,
    handle: Option<JoinHandle<()>>,
}

/// Leader-side routing state for one staged job.
struct JobMeta {
    /// Worker (= shard) count of this job.
    workers: usize,
    /// Contiguous chunk size: worker `w` of this job lives on lane
    /// `w / chunk`.
    chunk: usize,
    /// Leader-side mirror of the job's per-worker park flags.
    parked: Vec<bool>,
}

/// The persistent worker pool (module docs have the full contract).
///
/// Each staged job's workers are chunked contiguously with
/// `chunk = ceil(m / lanes)`, so worker `w` of job `j` lives on lane
/// `w / chunk_j`; lanes past the job's last chunk hold no slots for it
/// and acknowledge its rounds as no-ops. For the single-tenant
/// constructor ([`WorkerPool::new`]) the lane count is
/// `min(threads, m).max(1)` (`threads = 0` resolves to available
/// parallelism) — the same chunking the scoped-spawn engine used, so
/// delivery semantics are unchanged.
pub struct WorkerPool {
    lanes: Vec<Lane>,
    /// Routing state per staged job id.
    jobs: BTreeMap<usize, JobMeta>,
    spawned: u64,
    /// Set when a reconfigure failed partway (some lanes swapped, the
    /// routing state did not): every later dispatch refuses cleanly
    /// instead of routing worker ids over a half-swapped pool.
    poisoned: bool,
    /// Sent-masks of deferred broadcasts whose per-lane acks have not
    /// been drained yet (oldest first). The ack channels are strict
    /// FIFO — one ack per successfully-sent command — so **every**
    /// blocking dispatch must drain this queue first or it would consume
    /// a deferred round's acks as its own (see
    /// [`WorkerPool::grad_deferred_for`]).
    deferred: VecDeque<Vec<bool>>,
    /// Recycling slab for the per-round `Arc<[f64]>` broadcast buffers.
    wbuf: BufferPool,
    /// Reusable sent-mask for blocking broadcasts (cleared and resized
    /// in place each round — zero allocations once capacity settles).
    sent_mask: Vec<bool>,
    /// Retired sent-masks of drained deferred rounds, recycled by the
    /// next deferred dispatch (bounded by the deepest pipeline seen).
    mask_spares: Vec<Vec<bool>>,
}

fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

impl WorkerPool {
    /// Spawn a pool owning `prob`'s shards as job 0, with at most
    /// `threads` lanes (`0` = available parallelism).
    pub fn new(prob: &EncodedProblem, threads: usize) -> Self {
        WorkerPool::from_slots(Slot::stage(prob), threads)
    }

    pub(crate) fn from_slots(slots: Vec<Slot>, threads: usize) -> Self {
        let workers = slots.len();
        let lane_count = resolve_threads(threads).min(workers).max(1);
        let chunk = workers.div_ceil(lane_count).max(1);
        let mut lanes = Vec::with_capacity(lane_count);
        let mut spawned = 0u64;
        let mut slots = slots.into_iter();
        let mut base = 0;
        while base < workers {
            let take = chunk.min(workers - base);
            let lane_slots: Vec<Slot> = slots.by_ref().take(take).collect();
            let mut jobs = BTreeMap::new();
            jobs.insert(0, JobSlots { base, slots: lane_slots, parked: vec![false; take] });
            lanes.push(spawn_lane(lanes.len(), LaneState { jobs }));
            spawned += 1;
            base += take;
        }
        let mut jobs = BTreeMap::new();
        jobs.insert(0, JobMeta { workers, chunk, parked: vec![false; workers] });
        WorkerPool {
            lanes,
            jobs,
            spawned,
            poisoned: false,
            deferred: VecDeque::new(),
            wbuf: BufferPool::new(),
            sent_mask: Vec::new(),
            mask_spares: Vec::new(),
        }
    }

    /// Spawn a job-less pool with `threads` resident lanes (`0` =
    /// available parallelism) — the serve-mode constructor. Jobs are
    /// staged onto the shared lanes with [`WorkerPool::stage_job`] and
    /// dropped with [`WorkerPool::retire`]; no thread is ever spawned
    /// after this call.
    pub fn with_lanes(threads: usize) -> Self {
        let lane_count = resolve_threads(threads).max(1);
        let mut lanes = Vec::with_capacity(lane_count);
        for i in 0..lane_count {
            lanes.push(spawn_lane(i, LaneState { jobs: BTreeMap::new() }));
        }
        WorkerPool {
            lanes,
            jobs: BTreeMap::new(),
            spawned: lane_count as u64,
            poisoned: false,
            deferred: VecDeque::new(),
            wbuf: BufferPool::new(),
            sent_mask: Vec::new(),
            mask_spares: Vec::new(),
        }
    }

    /// Worker count of job 0 (the single-tenant surface); 0 when job 0 is
    /// not staged.
    pub fn workers(&self) -> usize {
        self.jobs.get(&0).map_or(0, |m| m.workers)
    }

    /// Number of resident lanes (OS threads).
    pub fn size(&self) -> usize {
        self.lanes.len()
    }

    /// Total OS threads ever spawned by this pool. Constant after
    /// construction — the zero-per-round-spawn invariant the dispatch
    /// bench and equivalence tests assert structurally.
    pub fn spawn_count(&self) -> u64 {
        self.spawned
    }

    /// Leader-side view of job 0's per-worker park flags.
    pub fn parked(&self) -> &[bool] {
        self.jobs.get(&0).map_or(&[], |m| &m.parked)
    }

    /// Ids of the currently staged jobs.
    pub fn staged_jobs(&self) -> Vec<usize> {
        self.jobs.keys().copied().collect()
    }

    /// Worker count of one staged job (`None` if the job is not staged).
    pub fn workers_for(&self, job: usize) -> Option<usize> {
        self.jobs.get(&job).map(|m| m.workers)
    }

    /// Parked workers of one staged job (0 if the job is not staged).
    pub fn parked_count_for(&self, job: usize) -> usize {
        self.jobs.get(&job).map_or(0, |m| m.parked.iter().filter(|&&x| x).count())
    }

    fn meta(&self, job: usize) -> Result<&JobMeta> {
        self.jobs.get(&job).ok_or_else(|| anyhow!("job {job} is not staged on this pool"))
    }

    /// Send one command per lane, then wait for every lane's ack. The ack
    /// pass always drains every lane that was successfully sent to, so a
    /// mid-broadcast failure cannot desynchronize later rounds.
    fn broadcast(&mut self, mut make: impl FnMut(usize) -> Command) -> Result<()> {
        ensure!(
            !self.poisoned,
            "worker pool poisoned by a failed reconfigure; rebuild the engine"
        );
        // A blocking round must not race the deferred rounds' acks (the
        // ack channels are FIFO): retire every outstanding deferred
        // dispatch before taking our own acks.
        self.drain_deferred()?;
        // reusable mask: blocking rounds own their acks within this call,
        // so one resident mask serves every round (disjoint field borrow
        // against `self.lanes` below)
        self.sent_mask.clear();
        self.sent_mask.resize(self.lanes.len(), false);
        let sent = &mut self.sent_mask;
        let mut err: Option<anyhow::Error> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            match lane.tx.send(make(i)) {
                Ok(()) => sent[i] = true,
                Err(_) => {
                    err.get_or_insert_with(|| anyhow!("pool lane {i} is gone (thread exited)"));
                }
            }
        }
        for (i, lane) in self.lanes.iter().enumerate() {
            if !sent[i] {
                continue;
            }
            if lane.ack.recv().is_err() {
                err.get_or_insert_with(|| anyhow!("pool lane {i} died mid-round"));
            }
        }
        match err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Send one command to a single lane and wait for its ack.
    fn dispatch_one(&mut self, lane_idx: usize, cmd: Command) -> Result<()> {
        ensure!(
            !self.poisoned,
            "worker pool poisoned by a failed reconfigure; rebuild the engine"
        );
        self.drain_deferred()?;
        let lane = &self.lanes[lane_idx];
        lane.tx
            .send(cmd)
            .map_err(|_| anyhow!("pool lane {lane_idx} is gone (thread exited)"))?;
        lane.ack
            .recv()
            .map_err(|_| anyhow!("pool lane {lane_idx} died mid-round"))
    }

    // ------------------------------------------------ job-aware surface

    /// Stage (or restage) `prob` as job `job` on the shared lanes: every
    /// lane receives the job's new slot range (park flags reset), keeping
    /// the resident threads. The job's worker count may change; the lane
    /// count never does.
    pub fn stage_job(&mut self, job: usize, prob: &EncodedProblem) -> Result<()> {
        self.stage_job_slots(job, Slot::stage(prob))
    }

    pub(crate) fn stage_job_slots(&mut self, job: usize, slots: Vec<Slot>) -> Result<()> {
        let workers = slots.len();
        let lane_count = self.lanes.len().max(1);
        let chunk = workers.div_ceil(lane_count).max(1);
        let mut pending: Vec<Vec<Slot>> = Vec::with_capacity(lane_count);
        let mut slots = slots.into_iter();
        for i in 0..self.lanes.len() {
            let base = (i * chunk).min(workers);
            let take = chunk.min(workers - base);
            pending.push(slots.by_ref().take(take).collect());
        }
        let mut pending = pending.into_iter();
        let res = self.broadcast(|i| Command::Reconfigure {
            job,
            base: (i * chunk).min(workers),
            slots: pending.next().expect("one slot batch per lane"),
        });
        if res.is_err() {
            // some lanes may hold the new slots while the routing state
            // below was never updated: refuse all further dispatch
            self.poisoned = true;
            return res;
        }
        self.jobs.insert(job, JobMeta { workers, chunk, parked: vec![false; workers] });
        Ok(())
    }

    /// Drop job `job` from every lane (a served job finished): its slots
    /// are freed, the lanes stay resident for the remaining tenants.
    pub fn retire(&mut self, job: usize) -> Result<()> {
        ensure!(self.jobs.contains_key(&job), "job {job} is not staged on this pool");
        self.broadcast(|_| Command::Retire { job })?;
        self.jobs.remove(&job);
        Ok(())
    }

    /// Stream one full-gradient round for `job` into `sink` (skips the
    /// job's parked workers).
    pub fn grad_streamed_for(
        &mut self,
        job: usize,
        w: &[f64],
        sink: &GradCollector,
    ) -> Result<()> {
        let workers = self.meta(job)?.workers;
        ensure!(sink.workers() == workers, "sink worker count mismatch for job {job}");
        sink.tag_job(job);
        let w: Arc<[f64]> = self.wbuf.acquire(w);
        self.broadcast(|i| Command::Grad {
            job,
            w: w.clone(),
            sink: sink.clone_for_lane(i),
            only: None,
            skip_parked: true,
        })
    }

    /// `(reused, fresh)` broadcast-buffer acquisition counts of the
    /// recycling slab — the structural observable the slab tests and the
    /// dispatch bench assert on (a depth-1 steady state reuses every
    /// round; pipelined depth > 1 falls back to fresh buffers).
    pub fn broadcast_buffer_stats(&self) -> (u64, u64) {
        self.wbuf.stats()
    }

    /// Stream one mini-batch gradient round for `job` into `sink` (skips
    /// the job's parked workers). `plan` must cover exactly the job's
    /// worker count; it is cloned once (not per lane) to cross the
    /// channel — a few segment tuples per worker, and the sampler mints a
    /// fresh plan each round anyway.
    pub fn grad_batch_streamed_for(
        &mut self,
        job: usize,
        w: &[f64],
        plan: &BatchPlan,
        sink: &GradCollector,
    ) -> Result<()> {
        let workers = self.meta(job)?.workers;
        assert_eq!(plan.workers(), workers, "batch plan worker count mismatch");
        ensure!(sink.workers() == workers, "sink worker count mismatch for job {job}");
        sink.tag_job(job);
        let w: Arc<[f64]> = self.wbuf.acquire(w);
        let plan = Arc::new(plan.clone());
        self.broadcast(|i| Command::GradBatch {
            job,
            w: w.clone(),
            plan: plan.clone(),
            sink: sink.clone_for_lane(i),
            only: None,
        })
    }

    /// Stream one line-search round for `job` into `sink` (skips the
    /// job's parked workers).
    pub fn curv_streamed_for(
        &mut self,
        job: usize,
        d: &[f64],
        sink: &CurvCollector,
    ) -> Result<()> {
        let workers = self.meta(job)?.workers;
        ensure!(sink.workers() == workers, "sink worker count mismatch for job {job}");
        sink.tag_job(job);
        let d: Arc<[f64]> = self.wbuf.acquire(d);
        self.broadcast(|i| Command::Curv {
            job,
            d: d.clone(),
            sink: sink.clone_for_lane(i),
            only: None,
            skip_parked: true,
        })
    }

    /// One worker's `(g_i, f_i)` for `job` (ignores the parked flag —
    /// direct calls are a staging/debug surface, not round fan-out).
    pub fn grad_one_for(&mut self, job: usize, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let meta = self.meta(job)?;
        ensure!(worker < meta.workers, "worker id {worker} out of range");
        let (workers, lane) = (meta.workers, worker / meta.chunk);
        let sink = GradCollector::collect_all(workers);
        sink.tag_job(job);
        self.dispatch_one(
            lane,
            Command::Grad {
                job,
                w: Arc::from(w),
                sink: sink.clone_for_lane(lane),
                only: Some(worker),
                skip_parked: false,
            },
        )?;
        let mut c = sink.into_collected();
        c.responses[worker]
            .take()
            .map(|(payload, _)| payload)
            .ok_or_else(|| anyhow!("pool delivered no response for worker {worker}"))
    }

    /// One worker's mini-batch gradient for `job` over explicit row
    /// segments.
    pub fn grad_batch_one_for(
        &mut self,
        job: usize,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        let meta = self.meta(job)?;
        ensure!(worker < meta.workers, "worker id {worker} out of range");
        let (workers, lane) = (meta.workers, worker / meta.chunk);
        let mut segments = vec![Vec::new(); workers];
        segments[worker] = segs.to_vec();
        let plan = Arc::new(BatchPlan { segments });
        let sink = GradCollector::collect_all(workers);
        sink.tag_job(job);
        self.dispatch_one(
            lane,
            Command::GradBatch {
                job,
                w: Arc::from(w),
                plan,
                sink: sink.clone_for_lane(lane),
                only: Some(worker),
            },
        )?;
        let mut c = sink.into_collected();
        c.responses[worker]
            .take()
            .map(|(payload, _)| payload)
            .ok_or_else(|| anyhow!("pool delivered no response for worker {worker}"))
    }

    /// One worker's `‖X̃_i d‖²` for `job` (ignores the parked flag).
    pub fn curv_one_for(&mut self, job: usize, worker: usize, d: &[f64]) -> Result<f64> {
        let meta = self.meta(job)?;
        ensure!(worker < meta.workers, "worker id {worker} out of range");
        let (workers, lane) = (meta.workers, worker / meta.chunk);
        let sink = CurvCollector::collect_all(workers);
        sink.tag_job(job);
        self.dispatch_one(
            lane,
            Command::Curv {
                job,
                d: Arc::from(d),
                sink: sink.clone_for_lane(lane),
                only: Some(worker),
                skip_parked: false,
            },
        )?;
        let mut c = sink.into_collected();
        c.responses[worker]
            .take()
            .map(|(q, _)| q)
            .ok_or_else(|| anyhow!("pool delivered no response for worker {worker}"))
    }

    /// All of `job`'s workers' `(g_i, f_i)` in worker order (computes
    /// parked workers too — the batch-synchronous reference surface).
    pub fn grad_all_for(&mut self, job: usize, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
        let workers = self.meta(job)?.workers;
        let sink = GradCollector::collect_all(workers);
        sink.tag_job(job);
        let w: Arc<[f64]> = Arc::from(w);
        self.broadcast(|i| Command::Grad {
            job,
            w: w.clone(),
            sink: sink.clone_for_lane(i),
            only: None,
            skip_parked: false,
        })?;
        let c = sink.into_collected();
        c.responses
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.map(|(payload, _)| payload)
                    .ok_or_else(|| anyhow!("pool delivered no response for worker {i}"))
            })
            .collect()
    }

    /// All of `job`'s workers' line-search terms in worker order.
    pub fn curv_all_for(&mut self, job: usize, d: &[f64]) -> Result<Vec<f64>> {
        let workers = self.meta(job)?.workers;
        let sink = CurvCollector::collect_all(workers);
        sink.tag_job(job);
        let d: Arc<[f64]> = Arc::from(d);
        self.broadcast(|i| Command::Curv {
            job,
            d: d.clone(),
            sink: sink.clone_for_lane(i),
            only: None,
            skip_parked: false,
        })?;
        let c = sink.into_collected();
        c.responses
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.map(|(q, _)| q)
                    .ok_or_else(|| anyhow!("pool delivered no response for worker {i}"))
            })
            .collect()
    }

    /// Park or unpark one worker of `job` (see the crash-park invariant
    /// in the module docs). Infallible: a dead lane surfaces as an error
    /// on the next round dispatch, not here, and an unstaged job is a
    /// no-op.
    pub fn set_parked_for(&mut self, job: usize, worker: usize, parked: bool) {
        let Some(meta) = self.jobs.get_mut(&job) else { return };
        if worker >= meta.workers {
            return;
        }
        meta.parked[worker] = parked;
        let lane = worker / meta.chunk;
        let _ = self.lanes[lane].tx.send(Command::SetParked { job, worker, parked });
    }

    /// Swap individual workers' resident shards of `job` in place — the
    /// rebalancer's migration handoff. Unlike [`WorkerPool::stage_job`]
    /// this preserves park flags, worker count, lane routing, and every
    /// untouched slot; **only the affected lanes** receive a (waited-on)
    /// command, and no thread is spawned (`spawn_count` is unchanged).
    /// `p` is the gradient dimension for the fresh scratch buffers. A
    /// handoff that fails partway poisons the pool exactly like a failed
    /// reconfigure: some lanes may hold the new shard while others never
    /// got theirs, so all further dispatch refuses cleanly.
    pub fn migrate_for(
        &mut self,
        job: usize,
        p: usize,
        changed: &[(usize, WorkerShard)],
    ) -> Result<()> {
        ensure!(
            !self.poisoned,
            "worker pool poisoned by a failed reconfigure; rebuild the engine"
        );
        // migrate_for runs its own send/ack loop outside `broadcast`, so
        // it must honor the same drain-first discipline.
        self.drain_deferred()?;
        let meta = self.meta(job)?;
        let (workers, chunk) = (meta.workers, meta.chunk);
        let mut per_lane: Vec<Vec<(usize, Slot)>> = vec![Vec::new(); self.lanes.len()];
        for (w, shard) in changed {
            ensure!(*w < workers, "migrate: worker id {w} out of range");
            per_lane[*w / chunk].push((*w, Slot::stage_shard(shard, p)));
        }
        let targets: Vec<usize> =
            (0..self.lanes.len()).filter(|&i| !per_lane[i].is_empty()).collect();
        let mut sent = vec![false; self.lanes.len()];
        let mut err: Option<anyhow::Error> = None;
        for &i in &targets {
            let slots = std::mem::take(&mut per_lane[i]);
            match self.lanes[i].tx.send(Command::Migrate { job, slots }) {
                Ok(()) => sent[i] = true,
                Err(_) => {
                    err.get_or_insert_with(|| anyhow!("pool lane {i} is gone (thread exited)"));
                }
            }
        }
        for &i in &targets {
            if sent[i] && self.lanes[i].ack.recv().is_err() {
                err.get_or_insert_with(|| anyhow!("pool lane {i} died mid-migration"));
            }
        }
        match err {
            None => Ok(()),
            Some(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    // ------------------------------------------- deferred (pipelined) dispatch

    /// Fan one full-gradient round for `job` out to the lanes **without
    /// waiting for their acknowledgements** — the pipelined round loop's
    /// dispatch half. The sent-mask is queued on `deferred`; the acks
    /// are consumed later by [`WorkerPool::drain_deferred_to`] (or by
    /// the drain-first guard of the next blocking dispatch). Until then
    /// the lanes own live clones of `sink`, so the caller must observe
    /// the round through the sink's shared state
    /// ([`Collector::wait_cancelled_snapshot`](super::stream::Collector::wait_cancelled_snapshot))
    /// rather than `into_collected`.
    pub fn grad_deferred_for(
        &mut self,
        job: usize,
        w: &[f64],
        sink: &GradCollector,
    ) -> Result<()> {
        ensure!(
            !self.poisoned,
            "worker pool poisoned by a failed reconfigure; rebuild the engine"
        );
        let workers = self.meta(job)?.workers;
        ensure!(sink.workers() == workers, "sink worker count mismatch for job {job}");
        sink.tag_job(job);
        // the slab hands out a *fresh* buffer whenever earlier rounds'
        // buffers are still pinned by lane clones — which is exactly the
        // pipelined steady state, so depth > 1 degrades gracefully to
        // one allocation per in-flight round
        let w: Arc<[f64]> = self.wbuf.acquire(w);
        let mut sent = self.mask_spares.pop().unwrap_or_default();
        sent.clear();
        sent.resize(self.lanes.len(), false);
        let mut err: Option<anyhow::Error> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            let cmd = Command::Grad {
                job,
                w: w.clone(),
                sink: sink.clone_for_lane(i),
                only: None,
                skip_parked: true,
            };
            match lane.tx.send(cmd) {
                Ok(()) => sent[i] = true,
                Err(_) => {
                    err.get_or_insert_with(|| anyhow!("pool lane {i} is gone (thread exited)"));
                }
            }
        }
        // queue the mask even on partial failure: the lanes that *were*
        // sent to will ack, and those acks must still be drained in order
        self.deferred.push_back(sent);
        match err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Rounds dispatched with [`WorkerPool::grad_deferred_for`] whose
    /// acks have not been drained yet.
    pub fn deferred_depth(&self) -> usize {
        self.deferred.len()
    }

    /// Drain deferred rounds (oldest first) until at most `max` remain
    /// in flight — the pipelined loop's bounded reorder window. Blocks
    /// on each drained round's remaining lane acks; by the time a round
    /// is drained, every lane has dropped its sink clones, so the
    /// caller's handle is sole owner again.
    pub fn drain_deferred_to(&mut self, max: usize) -> Result<()> {
        let mut err: Option<anyhow::Error> = None;
        while self.deferred.len() > max {
            let sent = self.deferred.pop_front().expect("len checked");
            for (i, was_sent) in sent.iter().enumerate() {
                if *was_sent && self.lanes[i].ack.recv().is_err() {
                    err.get_or_insert_with(|| anyhow!("pool lane {i} died mid-round"));
                }
            }
            self.mask_spares.push(sent);
        }
        match err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Drain every deferred round (the pipeline flush).
    pub fn drain_deferred(&mut self) -> Result<()> {
        self.drain_deferred_to(0)
    }

    // ---------------------------------------- job-0 compatibility surface

    /// Stream one full-gradient round into `sink` (job 0).
    pub fn grad_streamed(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        self.grad_streamed_for(0, w, sink)
    }

    /// Deferred full-gradient round (job 0; see
    /// [`WorkerPool::grad_deferred_for`]).
    pub fn grad_deferred(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        self.grad_deferred_for(0, w, sink)
    }

    /// Stream one mini-batch gradient round into `sink` (job 0).
    pub fn grad_batch_streamed(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
        sink: &GradCollector,
    ) -> Result<()> {
        self.grad_batch_streamed_for(0, w, plan, sink)
    }

    /// Stream one line-search round into `sink` (job 0).
    pub fn curv_streamed(&mut self, d: &[f64], sink: &CurvCollector) -> Result<()> {
        self.curv_streamed_for(0, d, sink)
    }

    /// One worker's `(g_i, f_i)` (job 0; ignores the parked flag).
    pub fn grad_one(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.grad_one_for(0, worker, w)
    }

    /// One worker's mini-batch gradient over explicit row segments (job 0).
    pub fn grad_batch_one(
        &mut self,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        self.grad_batch_one_for(0, worker, w, segs)
    }

    /// One worker's `‖X̃_i d‖²` (job 0; ignores the parked flag).
    pub fn curv_one(&mut self, worker: usize, d: &[f64]) -> Result<f64> {
        self.curv_one_for(0, worker, d)
    }

    /// All workers' `(g_i, f_i)` in worker order (job 0).
    pub fn grad_all(&mut self, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
        self.grad_all_for(0, w)
    }

    /// All workers' line-search terms in worker order (job 0).
    pub fn curv_all(&mut self, d: &[f64]) -> Result<Vec<f64>> {
        self.curv_all_for(0, d)
    }

    /// Park or unpark one worker (job 0; see the crash-park invariant).
    pub fn set_parked(&mut self, worker: usize, parked: bool) {
        self.set_parked_for(0, worker, parked);
    }

    /// Replace the staged problem in place (job 0): every lane receives
    /// its new slot range (park flags reset), keeping the resident
    /// threads. The worker count may change; the lane count never does.
    pub fn reconfigure(&mut self, prob: &EncodedProblem) -> Result<()> {
        self.stage_job(0, prob)
    }

    /// Swap individual workers' resident shards in place (job 0) — the
    /// rebalancer's migration handoff (see [`WorkerPool::migrate_for`]).
    pub fn migrate(&mut self, p: usize, changed: &[(usize, WorkerShard)]) -> Result<()> {
        self.migrate_for(0, p, changed)
    }
}

fn spawn_lane(index: usize, st: LaneState) -> Lane {
    let (tx, rx) = mpsc::channel();
    let (ack_tx, ack_rx) = mpsc::channel();
    let handle = std::thread::Builder::new()
        .name(format!("codedopt-pool-{index}"))
        .spawn(move || lane_main(st, rx, ack_tx))
        .expect("spawning pool lane thread");
    Lane { tx, ack: ack_rx, handle: Some(handle) }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for lane in &self.lanes {
            let _ = lane.tx.send(Command::Shutdown);
        }
        for lane in &mut self.lanes {
            if let Some(h) = lane.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::EncoderKind;
    use crate::problem::QuadProblem;

    fn pool(threads: usize) -> (EncodedProblem, WorkerPool) {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let p = WorkerPool::new(&enc, threads);
        (enc, p)
    }

    #[test]
    fn streamed_matches_per_worker_bitwise() {
        let (_, mut p) = pool(3);
        let w = vec![0.4; 6];
        let sink = GradCollector::collect_all(8);
        p.grad_streamed(&w, &sink).unwrap();
        let got = sink.into_collected();
        for i in 0..8 {
            let (g1, f1) = p.grad_one(i, &w).unwrap();
            let ((g2, f2), _) = got.responses[i].clone().unwrap();
            assert_eq!(f1.to_bits(), f2.to_bits(), "worker {i}");
            for (a, b) in g1.iter().zip(&g2) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i}");
            }
        }
    }

    #[test]
    fn spawn_count_is_constant_across_rounds() {
        let (_, mut p) = pool(4);
        let before = p.spawn_count();
        assert_eq!(before as usize, p.size());
        let w = vec![0.1; 6];
        for _ in 0..20 {
            let sink = GradCollector::collect_all(8);
            p.grad_streamed(&w, &sink).unwrap();
            sink.into_collected();
        }
        assert_eq!(p.spawn_count(), before, "round dispatch must never spawn");
    }

    #[test]
    fn parked_workers_skip_round_fanout_but_answer_direct_calls() {
        let (_, mut p) = pool(2);
        p.set_parked(3, true);
        assert_eq!(p.parked().iter().filter(|&&x| x).count(), 1);
        let w = vec![0.2; 6];
        let sink = GradCollector::collect_all(8);
        p.grad_streamed(&w, &sink).unwrap();
        let got = sink.into_collected();
        assert!(got.responses[3].is_none(), "parked worker delivered in a round");
        assert_eq!(got.delivery_order.len(), 7);
        // direct call still computes (staging/debug surface)
        assert!(p.grad_one(3, &w).is_ok());
        // unpark: the worker rejoins with its resident shard
        p.set_parked(3, false);
        let sink = GradCollector::collect_all(8);
        p.grad_streamed(&w, &sink).unwrap();
        assert!(sink.into_collected().responses[3].is_some());
    }

    #[test]
    fn curv_and_batch_rounds_flow_through_the_pool() {
        let (enc, mut p) = pool(0);
        let d = vec![-0.3; 6];
        let sink = CurvCollector::collect_all(8);
        p.curv_streamed(&d, &sink).unwrap();
        let got = sink.into_collected();
        assert!(got.responses.iter().all(|r| r.is_some()));
        let mut rng = crate::rng::Pcg64::seeded(11);
        let plan = enc.sample_batch(0.4, &mut rng);
        let w = vec![0.1; 6];
        let sink = GradCollector::collect_all(8);
        p.grad_batch_streamed(&w, &plan, &sink).unwrap();
        let got = sink.into_collected();
        for i in 0..8 {
            let ((gs, fs), _) = got.responses[i].clone().unwrap();
            let (gb, fb) = p.grad_batch_one(i, &w, &plan.segments[i]).unwrap();
            assert_eq!(fs.to_bits(), fb.to_bits(), "worker {i}");
            for (a, b) in gs.iter().zip(&gb) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i}");
            }
        }
    }

    #[test]
    fn reconfigure_swaps_the_staged_problem_in_place() {
        let (_, mut p) = pool(3);
        let spawned = p.spawn_count();
        let prob2 = QuadProblem::synthetic_gaussian(48, 5, 0.1, 9);
        let enc2 = EncodedProblem::encode(&prob2, EncoderKind::Identity, 1.0, 6, 0).unwrap();
        p.set_parked(2, true);
        p.reconfigure(&enc2).unwrap();
        assert_eq!(p.workers(), 6);
        assert_eq!(p.spawn_count(), spawned, "reconfigure must reuse resident lanes");
        assert!(p.parked().iter().all(|&x| !x), "reconfigure resets park flags");
        let w = vec![0.3; 5];
        let mut fresh = WorkerPool::new(&enc2, 3);
        let a = p.grad_all(&w).unwrap();
        let b = fresh.grad_all(&w).unwrap();
        for ((ga, fa), (gb, fb)) in a.iter().zip(&b) {
            assert_eq!(fa.to_bits(), fb.to_bits());
            for (x, y) in ga.iter().zip(gb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn migrate_swaps_shards_without_respawn_and_keeps_park_flags() {
        let (enc, mut p) = pool(3);
        let spawned = p.spawn_count();
        p.set_parked(5, true);
        // hand-build a "migration": give worker 1 worker 6's shard
        let changed = vec![(1usize, enc.shards[6].clone())];
        p.migrate(enc.p(), &changed).unwrap();
        assert_eq!(p.spawn_count(), spawned, "migration must never spawn");
        assert_eq!(p.workers(), 8, "migration must not change the worker count");
        assert!(p.parked()[5], "migration must preserve park flags");
        let w = vec![0.25; 6];
        let (g1, f1) = p.grad_one(1, &w).unwrap();
        let (g6, f6) = p.grad_one(6, &w).unwrap();
        assert_eq!(f1.to_bits(), f6.to_bits(), "worker 1 should now hold worker 6's shard");
        for (a, b) in g1.iter().zip(&g6) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // untouched workers still answer with their original shards, and
        // the parked worker still skips round fan-out
        let sink = GradCollector::collect_all(8);
        p.grad_streamed(&w, &sink).unwrap();
        let got = sink.into_collected();
        assert!(got.responses[5].is_none());
        assert!(got.responses[0].is_some());
    }

    #[test]
    fn migrate_rejects_out_of_range_worker() {
        let (enc, mut p) = pool(2);
        assert!(p.migrate(enc.p(), &[(99, enc.shards[0].clone())]).is_err());
    }

    #[test]
    fn first_k_sink_cancels_round_fanout() {
        // single lane => deterministic serial walk: first 3 admitted, the
        // rest skipped entirely (no response recorded)
        let (_, mut p) = pool(1);
        let w = vec![0.1; 6];
        let sink = GradCollector::first_k(8, 3, vec![true; 8]);
        p.grad_streamed(&w, &sink).unwrap();
        let got = sink.into_collected();
        assert_eq!(got.admitted, vec![0, 1, 2]);
        for i in 3..8 {
            assert!(got.responses[i].is_none(), "worker {i} should have been cancelled");
        }
    }

    #[test]
    fn deferred_round_snapshot_matches_streamed_bitwise() {
        let (_, mut p) = pool(1);
        let w = vec![0.4; 6];
        // blocking reference round
        let sink = GradCollector::first_k(8, 3, vec![true; 8]);
        p.grad_streamed(&w, &sink).unwrap();
        let reference = sink.into_collected();
        // deferred round observed through the snapshot instead
        let sink = GradCollector::first_k(8, 3, vec![true; 8]);
        p.grad_deferred(&w, &sink).unwrap();
        assert_eq!(p.deferred_depth(), 1);
        let snap = sink.wait_cancelled_snapshot();
        assert_eq!(snap.admitted, reference.admitted);
        for i in &snap.admitted {
            let ((gs, fs), _) = snap.responses[*i].clone().unwrap();
            let ((gr, fr), _) = reference.responses[*i].clone().unwrap();
            assert_eq!(fs.to_bits(), fr.to_bits(), "worker {i}");
            for (a, b) in gs.iter().zip(&gr) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i}");
            }
        }
        p.drain_deferred().unwrap();
        assert_eq!(p.deferred_depth(), 0);
    }

    #[test]
    fn blocking_dispatch_drains_deferred_acks_first() {
        // a deferred round left in flight must not desynchronize the ack
        // FIFO: the next blocking round drains it and both stay correct
        let (_, mut p) = pool(2);
        let w = vec![0.2; 6];
        let deferred_sink = GradCollector::first_k(8, 2, vec![true; 8]);
        p.grad_deferred(&w, &deferred_sink).unwrap();
        let _ = deferred_sink.wait_cancelled_snapshot();
        let sink = GradCollector::collect_all(8);
        p.grad_streamed(&w, &sink).unwrap();
        assert_eq!(p.deferred_depth(), 0, "blocking dispatch must drain deferred rounds");
        let got = sink.into_collected();
        assert_eq!(got.delivery_order.len(), 8);
        // the deferred sink is sole-owned again after the drain
        let d = deferred_sink.into_collected();
        assert_eq!(d.admitted.len(), 2);
    }

    #[test]
    fn drain_deferred_to_keeps_a_bounded_window() {
        let (_, mut p) = pool(1);
        let w = vec![0.1; 6];
        let mut sinks = Vec::new();
        for _ in 0..3 {
            let sink = GradCollector::first_k(8, 1, vec![true; 8]);
            p.grad_deferred(&w, &sink).unwrap();
            let _ = sink.wait_cancelled_snapshot();
            sinks.push(sink);
        }
        assert_eq!(p.deferred_depth(), 3);
        p.drain_deferred_to(1).unwrap();
        assert_eq!(p.deferred_depth(), 1);
        p.drain_deferred().unwrap();
        assert_eq!(p.deferred_depth(), 0);
        for sink in sinks {
            assert_eq!(sink.into_collected().admitted.len(), 1);
        }
    }

    // ------------------------------------------------ multi-tenant tests

    fn two_probs() -> (EncodedProblem, EncodedProblem) {
        let p1 = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let p2 = QuadProblem::synthetic_gaussian(48, 5, 0.1, 9);
        (
            EncodedProblem::encode(&p1, EncoderKind::Hadamard, 2.0, 8, 2).unwrap(),
            EncodedProblem::encode(&p2, EncoderKind::Identity, 1.0, 6, 0).unwrap(),
        )
    }

    #[test]
    fn two_jobs_share_lanes_and_route_independently() {
        let (enc1, enc2) = two_probs();
        let mut p = WorkerPool::with_lanes(3);
        let spawned = p.spawn_count();
        p.stage_job(1, &enc1).unwrap();
        p.stage_job(2, &enc2).unwrap();
        assert_eq!(p.spawn_count(), spawned, "staging a job must never spawn");
        assert_eq!(p.staged_jobs(), vec![1, 2]);
        assert_eq!(p.workers_for(1), Some(8));
        assert_eq!(p.workers_for(2), Some(6));
        // each job's per-worker answers match a fresh single-tenant pool
        let (w1, w2) = (vec![0.4; 6], vec![0.3; 5]);
        let mut solo1 = WorkerPool::new(&enc1, 3);
        let mut solo2 = WorkerPool::new(&enc2, 3);
        for i in 0..8 {
            let (ga, fa) = p.grad_one_for(1, i, &w1).unwrap();
            let (gb, fb) = solo1.grad_one(i, &w1).unwrap();
            assert_eq!(fa.to_bits(), fb.to_bits(), "job 1 worker {i}");
            for (x, y) in ga.iter().zip(&gb) {
                assert_eq!(x.to_bits(), y.to_bits(), "job 1 worker {i}");
            }
        }
        for i in 0..6 {
            let (ga, fa) = p.grad_one_for(2, i, &w2).unwrap();
            let (gb, fb) = solo2.grad_one(i, &w2).unwrap();
            assert_eq!(fa.to_bits(), fb.to_bits(), "job 2 worker {i}");
            for (x, y) in ga.iter().zip(&gb) {
                assert_eq!(x.to_bits(), y.to_bits(), "job 2 worker {i}");
            }
        }
    }

    #[test]
    fn per_job_park_masks_are_independent() {
        let (enc1, enc2) = two_probs();
        let mut p = WorkerPool::with_lanes(2);
        p.stage_job(1, &enc1).unwrap();
        p.stage_job(2, &enc2).unwrap();
        p.set_parked_for(1, 3, true);
        assert_eq!(p.parked_count_for(1), 1);
        assert_eq!(p.parked_count_for(2), 0, "job 2's mask must be untouched");
        let sink = GradCollector::collect_all(8);
        p.grad_streamed_for(1, &vec![0.2; 6], &sink).unwrap();
        assert!(sink.into_collected().responses[3].is_none());
        let sink = GradCollector::collect_all(6);
        p.grad_streamed_for(2, &vec![0.2; 5], &sink).unwrap();
        assert!(
            sink.into_collected().responses[3].is_some(),
            "job 2's worker 3 must still answer its rounds"
        );
    }

    // ------------------------------------------------ buffer-slab tests

    #[test]
    fn slab_reuses_the_same_arc_once_the_round_trip_completes() {
        let mut slab = BufferPool::new();
        let a = slab.acquire(&[1.0, 2.0, 3.0]);
        let ptr = Arc::as_ptr(&a);
        drop(a); // all outside refs gone: next acquire must recycle
        let b = slab.acquire(&[4.0, 5.0, 6.0]);
        assert_eq!(Arc::as_ptr(&b), ptr, "round-tripped buffer must be recycled in place");
        assert_eq!(&b[..], &[4.0, 5.0, 6.0]);
        assert_eq!(slab.stats(), (1, 1));
    }

    #[test]
    fn slab_allocates_fresh_while_buffers_are_pinned() {
        let mut slab = BufferPool::new();
        let a = slab.acquire(&[1.0; 4]);
        // `a` still alive (a lane still holds its clone): no recycling
        let b = slab.acquire(&[2.0; 4]);
        assert_ne!(Arc::as_ptr(&a), Arc::as_ptr(&b));
        assert_eq!(slab.stats(), (0, 2));
    }

    #[test]
    fn slab_retires_stale_length_buffers_on_problem_swap() {
        let mut slab = BufferPool::new();
        drop(slab.acquire(&[1.0; 4]));
        let b = slab.acquire(&[2.0; 6]);
        assert_eq!(b.len(), 6);
        assert_eq!(slab.stats(), (0, 2), "a stale-length buffer must not be reused");
        drop(b);
        assert_eq!(slab.acquire(&[3.0; 6]).len(), 6);
        assert_eq!(slab.stats(), (1, 2));
    }

    #[test]
    fn blocking_rounds_recycle_broadcast_buffers_at_depth_one() {
        let (_, mut p) = pool(2);
        let w = vec![0.1; 6];
        for _ in 0..5 {
            let sink = GradCollector::collect_all(8);
            p.grad_streamed(&w, &sink).unwrap();
            sink.into_collected();
        }
        let (reused, fresh) = p.broadcast_buffer_stats();
        assert_eq!(fresh, 1, "depth-1 steady state allocates one broadcast buffer ever");
        assert_eq!(reused, 4);
    }

    #[test]
    fn pipelined_rounds_fall_back_to_fresh_buffers() {
        let (_, mut p) = pool(1);
        let w = vec![0.2; 6];
        let mut sinks = Vec::new();
        for _ in 0..4 {
            let sink = GradCollector::first_k(8, 1, vec![true; 8]);
            p.grad_deferred(&w, &sink).unwrap();
            let _ = sink.wait_cancelled_snapshot();
            sinks.push(sink);
        }
        // a single-lane pool acks each round as soon as its lane finishes,
        // so *some* reuse may still occur; what must hold is that the slab
        // never blocked dispatch and served every round
        let (reused, fresh) = p.broadcast_buffer_stats();
        assert_eq!(reused + fresh, 4);
        assert!(fresh >= 1);
        p.drain_deferred().unwrap();
        for sink in sinks {
            assert_eq!(sink.into_collected().admitted.len(), 1);
        }
    }

    #[test]
    fn gram_slot_matches_gemv_slot_closely() {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.05, 7);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let gram_enc = enc.clone().with_grad_mode(GradMode::Gram).unwrap();
        let mut pg = WorkerPool::new(&enc, 2);
        let mut pm = WorkerPool::new(&gram_enc, 2);
        let w = vec![0.3; 6];
        for i in 0..8 {
            let (g1, f1) = pg.grad_one(i, &w).unwrap();
            let (g2, f2) = pm.grad_one(i, &w).unwrap();
            assert!((f1 - f2).abs() <= 1e-9 * f1.abs().max(1.0), "worker {i}: f {f1} vs {f2}");
            for (a, b) in g1.iter().zip(&g2) {
                assert!((a - b).abs() <= 1e-9 * a.abs().max(1.0), "worker {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn retire_frees_the_job_and_keeps_siblings() {
        let (enc1, enc2) = two_probs();
        let mut p = WorkerPool::with_lanes(2);
        p.stage_job(1, &enc1).unwrap();
        p.stage_job(2, &enc2).unwrap();
        p.retire(1).unwrap();
        assert_eq!(p.staged_jobs(), vec![2]);
        assert!(p.grad_one_for(1, 0, &vec![0.1; 6]).is_err(), "retired job must not dispatch");
        assert!(p.grad_one_for(2, 0, &vec![0.1; 5]).is_ok(), "sibling job must survive");
        assert!(p.retire(1).is_err(), "double retire is an error");
    }
}
