//! Temporal execution: the k-deep pipelined round loop.
//!
//! Every scheme in [`encoding`](crate::encoding) codes *within* a round;
//! this module (with [`encoding::temporal`](crate::encoding::temporal))
//! is the *across*-round half of temporal coding: a [`PipelinedStepper`]
//! keeps up to `depth` gradient rounds' straggler tails in flight on the
//! job-id'd pool protocol, retiring rounds strictly in order with a
//! bounded reorder window.
//!
//! # What actually overlaps
//!
//! Synchronous first-k optimization is a serial recurrence: iterate
//! `w_{t+1}` needs round `t`'s aggregated gradient, so round `t+1`'s
//! *dispatch* cannot precede round `t`'s *admission*. What it does not
//! need is round `t`'s stragglers: once the k-th eligible response has
//! landed, the admitted set and every admitted payload are final, and the
//! cancelled tail (workers that will notice the flag late, lanes that
//! still owe their acknowledgements) is pure bookkeeping. The pipelined
//! loop therefore retires a round at its **k-th admission** — blocking on
//! the collector's cancellation condvar
//! ([`Collector::wait_cancelled_snapshot`]) instead of the pool's ack
//! drain — and defers up to `depth - 1` rounds' ack drains into the
//! background while the optimizer's next iteration runs leader-side math
//! and dispatches round `t+1`.
//!
//! # Why depth 1 is bitwise-serial
//!
//! At depth 1 the stepper never defers anything: the cluster keeps
//! `pipeline_depth == 1`, every round runs the historical blocking
//! measured arm, and the wrapped [`JobStep`] executes exactly the rounds
//! of the solo path — pinned by `rust/tests/temporal_equivalence.rs`. At
//! any depth the *admitted set* is computed by the identical first-k
//! delivery-order rule, so virtual-clock traces (whose admission is post
//! hoc over a collect-all gather and never deferred at all) are
//! byte-identical at every depth.
//!
//! [`Collector::wait_cancelled_snapshot`]: super::stream::Collector::wait_cancelled_snapshot

use crate::cluster::Cluster;
use crate::optim::{JobStep, RunOutput, SteppedOptimizer};
use crate::problem::EncodedProblem;
use anyhow::{ensure, Result};

/// A [`JobStep`] adapter that runs its inner stepper with up to `depth`
/// rounds' straggler tails in flight (see the module docs). Depth 1 is
/// structurally the serial stepper: the cluster's pipeline depth stays 1
/// and the flush is a no-op.
///
/// The stepper restores the cluster to blocking semantics
/// (`pipeline_depth = 1`, pipeline drained) when its run finishes or
/// errors, so a cluster shared across jobs (the serve runtime) never
/// leaks pipelining into a neighbor's rounds.
pub struct PipelinedStepper {
    inner: Option<Box<dyn JobStep>>,
    depth: usize,
    finished: bool,
}

impl PipelinedStepper {
    /// Wrap `inner` at pipeline depth `depth` (≥ 1; 1 = serial).
    pub fn new(inner: Box<dyn JobStep>, depth: usize) -> Result<Self> {
        ensure!(depth >= 1, "pipeline depth must be at least 1, got {depth}");
        Ok(PipelinedStepper { inner: Some(inner), depth, finished: false })
    }

    /// The configured pipeline depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Flush the pipeline and restore blocking round semantics on the
    /// cluster. Idempotent; called automatically when the inner stepper
    /// finishes or errors.
    fn rewind(&mut self, cluster: &mut Cluster) -> Result<()> {
        cluster.set_pipeline_depth(1);
        cluster.drain_pipeline()
    }
}

impl JobStep for PipelinedStepper {
    fn step(&mut self, prob: &EncodedProblem, cluster: &mut Cluster) -> Result<bool> {
        ensure!(!self.finished, "step called on a finished pipelined run");
        cluster.set_pipeline_depth(self.depth);
        let inner = self.inner.as_mut().expect("inner stepper present until output");
        match inner.step(prob, cluster) {
            Ok(true) => Ok(true),
            Ok(false) => {
                self.finished = true;
                self.rewind(cluster)?;
                Ok(false)
            }
            Err(e) => {
                self.finished = true;
                // the inner error is the primary failure; a drain error
                // here means a lane died too, which the pool reports on
                // the next dispatch anyway
                let _ = self.rewind(cluster);
                Err(e)
            }
        }
    }

    fn output(mut self: Box<Self>) -> RunOutput {
        self.inner.take().expect("output called once").output()
    }
}

/// Run `opt` for `iters` iterations from `w0` with a `depth`-deep
/// pipelined round loop — the pipelined counterpart of
/// [`Optimizer::run_from`](crate::optim::Optimizer::run_from), sharing
/// its stepper code path (so depth 1 is the serial run, structurally).
pub fn run_pipelined(
    opt: &dyn SteppedOptimizer,
    prob: &EncodedProblem,
    cluster: &mut Cluster,
    iters: usize,
    w0: Option<Vec<f64>>,
    depth: usize,
) -> Result<RunOutput> {
    let inner = opt.stepper(prob, cluster.config().wait_for, iters, w0)?;
    let mut stepper = PipelinedStepper::new(inner, depth)?;
    while stepper.step(prob, cluster)? {}
    Ok(Box::new(stepper).output())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClockMode, ClusterConfig, DelayModel};
    use crate::encoding::EncoderKind;
    use crate::optim::{CodedGd, GdConfig, Optimizer};
    use crate::problem::QuadProblem;
    use crate::runtime::NativeEngine;

    fn setup(clock: ClockMode) -> (EncodedProblem, Cluster) {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: 6,
            delay: DelayModel::Exp { mean_ms: 5.0 },
            clock,
            ms_per_mflop: 0.5,
            seed: 7,
        };
        let c = Cluster::new(&enc, eng, cfg).unwrap();
        (enc, c)
    }

    fn gd() -> CodedGd {
        CodedGd::new(GdConfig { epsilon: Some(0.4), ..GdConfig::default() })
    }

    #[test]
    fn depth_one_is_bitwise_the_serial_run() {
        let (enc, mut c1) = setup(ClockMode::Virtual);
        let serial = gd().run(&enc, &mut c1, 12).unwrap();
        let (_, mut c2) = setup(ClockMode::Virtual);
        let piped = run_pipelined(&gd(), &enc, &mut c2, 12, None, 1).unwrap();
        assert_eq!(serial.trace.to_csv(), piped.trace.to_csv());
        for (a, b) in serial.w.iter().zip(&piped.w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn virtual_clock_traces_are_depth_invariant() {
        let (enc, mut c1) = setup(ClockMode::Virtual);
        let d1 = run_pipelined(&gd(), &enc, &mut c1, 12, None, 1).unwrap();
        for depth in [2, 4] {
            let (_, mut c) = setup(ClockMode::Virtual);
            let dk = run_pipelined(&gd(), &enc, &mut c, 12, None, depth).unwrap();
            assert_eq!(d1.trace.to_csv(), dk.trace.to_csv(), "depth {depth} virtual trace differs");
        }
    }

    #[test]
    fn measured_pipeline_admits_k_per_round_and_drains() {
        let (enc, mut c) = setup(ClockMode::Measured);
        let out = run_pipelined(&gd(), &enc, &mut c, 10, None, 3).unwrap();
        assert_eq!(out.trace.records.len(), 10);
        for r in &out.trace.records {
            assert_eq!(r.responders, 6, "every pipelined round admits exactly k");
            assert!(r.compute_ms.is_finite());
        }
        // the run handed the cluster back in blocking state
        assert_eq!(c.pipeline_depth(), 1);
        // a fresh blocking round runs cleanly (nothing left in flight)
        let (_, round) = c.grad_round(&out.w).unwrap();
        assert_eq!(round.admitted.len(), 6);
    }

    #[test]
    fn rejects_zero_depth() {
        let (enc, mut c) = setup(ClockMode::Virtual);
        assert!(run_pipelined(&gd(), &enc, &mut c, 3, None, 0).is_err());
    }
}
