//! Elastic load-aware shard rebalancing (ROADMAP item 2).
//!
//! The paper's redundancy lets each round proceed with *any* first-k
//! subset of workers, so a straggler costs wall-clock only when it is
//! persistently in the admitted set's critical path. This module closes
//! the loop: an online per-worker speed model (EWMA over the round's
//! `compute_ms`, normalized by each worker's flop count so the estimate
//! is a *rate* in ms/mflop, placement-independent) feeds a cost-model
//! resharder that migrates encoded block-rows from predicted-slow
//! workers to fast ones — **lazily**, at most one move per round,
//! because the code already covers the slow worker while the move is in
//! flight.
//!
//! Determinism contract: under the virtual clock every observation is a
//! deterministic function of the scenario script and the flop model, and
//! the planner consumes **no randomness** — ties break on the lowest
//! worker index and moves are accepted only on a *strict* lexicographic
//! improvement of the sorted-descending predicted-finish-time vector. A
//! scenario run therefore reproduces the exact same migration schedule
//! (and trace) on every replay, which `rebalance_equivalence.rs` pins.
//!
//! The resharder is legal only for the count-normalized schemes
//! ([`Scheme::Coded`] / [`Scheme::Uncoded`]), whose leader-side
//! aggregation depends on the responder *count*, not on which rows live
//! where. Replication and gradient coding dedup by `partition_id`, so
//! moving rows between their workers would change the estimator;
//! [`Rebalancer::new`] rejects them.

use crate::linalg::DataMat;
use crate::problem::{pad_bucket, Scheme, WorkerShard};
use anyhow::{bail, ensure, Result};
use std::fmt;

/// `--rebalance` policy: `off` or `ewma:ALPHA:THRESHOLD`.
///
/// `ALPHA ∈ (0, 1]` is the EWMA smoothing weight on new observations;
/// `THRESHOLD ≥ 1` is the imbalance trigger — a move is considered only
/// when the slowest predicted finish time exceeds `THRESHOLD ×` the
/// fastest. Parse ↔ Display round-trips exactly (the config contract
/// shared with `DelayModel`/`LrSchedule`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RebalanceConfig {
    /// Static placement (the default): no speed model, no migrations.
    Off,
    /// EWMA speed model + lazy resharder.
    Ewma {
        /// Smoothing weight on each new rate observation, in `(0, 1]`.
        alpha: f64,
        /// Trigger ratio `t_max / t_min` above which a move is planned
        /// (`≥ 1`).
        threshold: f64,
    },
}

impl RebalanceConfig {
    /// Parse the `--rebalance` grammar: `off` | `ewma:ALPHA:THRESHOLD`.
    /// Each variant takes exactly its listed fields (extra fields are
    /// rejected, like `DelayModel::parse`).
    pub fn parse(s: &str) -> Result<RebalanceConfig> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize| -> Result<f64> {
            parts[i]
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--rebalance {s:?}: {:?} is not a number", parts[i]))
        };
        let expect = |n: usize| -> Result<()> {
            ensure!(
                parts.len() == n,
                "--rebalance {s:?}: '{}' takes exactly {} field(s), got {}",
                parts[0],
                n - 1,
                parts.len() - 1
            );
            Ok(())
        };
        match parts[0] {
            "off" => {
                expect(1)?;
                Ok(RebalanceConfig::Off)
            }
            "ewma" => {
                expect(3)?;
                let alpha = num(1)?;
                let threshold = num(2)?;
                ensure!(
                    alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
                    "--rebalance {s:?}: alpha must be in (0, 1], got {alpha}"
                );
                ensure!(
                    threshold.is_finite() && threshold >= 1.0,
                    "--rebalance {s:?}: threshold must be >= 1, got {threshold}"
                );
                Ok(RebalanceConfig::Ewma { alpha, threshold })
            }
            other => bail!("unknown rebalance policy {other:?} (off|ewma:ALPHA:THRESHOLD)"),
        }
    }
}

impl fmt::Display for RebalanceConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RebalanceConfig::Off => write!(f, "off"),
            RebalanceConfig::Ewma { alpha, threshold } => write!(f, "ewma:{alpha}:{threshold}"),
        }
    }
}

/// Online per-worker speed estimate: an exponentially weighted moving
/// average of observed compute *rates* (ms per mflop).
///
/// A worker with no observation yet has no estimate; the first
/// observation seeds the average directly. Rounds in which a worker is
/// parked/crashed produce **no** observation and leave its estimate
/// untouched — the park/unpark-gap contract the unit tests pin.
#[derive(Clone, Debug)]
pub struct EwmaSpeedModel {
    alpha: f64,
    rates: Vec<Option<f64>>,
}

impl EwmaSpeedModel {
    /// Fresh model over `workers` workers with smoothing weight `alpha`.
    pub fn new(alpha: f64, workers: usize) -> Self {
        EwmaSpeedModel { alpha, rates: vec![None; workers] }
    }

    /// Fold one observed rate (ms/mflop) into worker `w`'s estimate.
    pub fn observe(&mut self, w: usize, rate: f64) {
        debug_assert!(rate.is_finite() && rate >= 0.0, "bad rate observation {rate}");
        self.rates[w] = Some(match self.rates[w] {
            None => rate,
            Some(prev) => self.alpha * rate + (1.0 - self.alpha) * prev,
        });
    }

    /// Current estimate for worker `w` (`None` until first observed).
    pub fn estimate(&self, w: usize) -> Option<f64> {
        self.rates[w]
    }

    /// Worker count the model covers.
    pub fn workers(&self) -> usize {
        self.rates.len()
    }
}

/// One planned block-row move: `rows` tail rows of worker `from`'s shard
/// appended to worker `to`'s shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MovePlan {
    /// Donor (predicted-slowest) worker.
    pub from: usize,
    /// Recipient (predicted-fastest) worker.
    pub to: usize,
    /// Encoded block-rows moved (the donor's tail rows).
    pub rows: usize,
}

impl fmt::Display for MovePlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "migrate:{}>{}:{}", self.from, self.to, self.rows)
    }
}

/// Speed model + cost model + authoritative shard placement.
///
/// The rebalancer owns the leader's copy of every shard: [`apply`]
/// rebuilds the donor/recipient shards (band split, vstack, re-pad to
/// the AOT bucket) and returns them for the engine to swap in via
/// `EngineSession::migrate_shards`.
///
/// [`apply`]: Rebalancer::apply
pub struct Rebalancer {
    threshold: f64,
    model: EwmaSpeedModel,
    shards: Vec<WorkerShard>,
}

/// Predicted per-round madds of a shard holding `rows_real` real rows
/// whose combined real-row madds are `real_madds`: dense pays the full
/// pad bucket (zero rows still multiply), CSR pays only the nnz.
fn shard_madds(sparse: bool, cols: usize, rows_real: usize, real_madds: f64) -> f64 {
    if sparse {
        real_madds
    } else {
        (pad_bucket(rows_real) * cols) as f64
    }
}

/// Per-row madds prefix over the *real* rows: `prefix[j]` = madds of the
/// first `j` real rows (dense: `j·cols`; CSR: nnz of rows `0..j`).
fn real_madds_prefix(shard: &WorkerShard) -> Vec<f64> {
    let mut prefix = Vec::with_capacity(shard.rows_real + 1);
    prefix.push(0.0);
    match &shard.x {
        DataMat::Dense(m) => {
            for j in 1..=shard.rows_real {
                prefix.push((j * m.cols()) as f64);
            }
        }
        DataMat::Csr(c) => {
            let mut acc = 0.0;
            for i in 0..shard.rows_real {
                acc += c.row(i).0.len() as f64;
                prefix.push(acc);
            }
        }
        DataMat::DenseF32(m) => {
            for j in 1..=shard.rows_real {
                prefix.push((j * m.cols()) as f64);
            }
        }
        DataMat::CsrF32(c) => {
            let mut acc = 0.0;
            for i in 0..shard.rows_real {
                acc += c.row(i).0.len() as f64;
                prefix.push(acc);
            }
        }
    }
    prefix
}

/// `a < b` lexicographically on equal-length f64 vectors.
fn lex_less(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return true;
        }
        if x > y {
            return false;
        }
    }
    false
}

fn sorted_desc(mut v: Vec<f64>) -> Vec<f64> {
    v.sort_by(|a, b| b.partial_cmp(a).expect("finish times are finite"));
    v
}

impl Rebalancer {
    /// Build over the scheme's initial placement. Rejects schemes whose
    /// aggregation dedups by `partition_id` (replication, gradient
    /// coding): moving rows between their workers changes the estimator.
    pub fn new(
        scheme: Scheme,
        shards: Vec<WorkerShard>,
        alpha: f64,
        threshold: f64,
    ) -> Result<Self> {
        match scheme {
            Scheme::Coded | Scheme::Uncoded => {}
            Scheme::Replicated { .. } | Scheme::GradientCoded { .. } => bail!(
                "--rebalance: scheme {scheme:?} aggregates by partition identity; \
                 shard migration is only legal for the count-normalized \
                 coded/uncoded schemes"
            ),
            Scheme::SeqCoded { .. } | Scheme::StochCoded => bail!(
                "--rebalance: temporal scheme {scheme:?} places a row's home and \
                 backup copies on distinct buddies; migrating rows could co-locate \
                 them and void the burst tolerance"
            ),
        }
        ensure!(!shards.is_empty(), "rebalancer needs at least one shard");
        ensure!(
            alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
            "rebalance alpha must be in (0, 1]"
        );
        ensure!(
            threshold.is_finite() && threshold >= 1.0,
            "rebalance threshold must be >= 1"
        );
        let m = shards.len();
        Ok(Rebalancer { threshold, model: EwmaSpeedModel::new(alpha, m), shards })
    }

    /// Fold one round's observation for worker `w`: `compute_ms` over
    /// `mflops` of work. Non-finite or zero-work observations are
    /// dropped (a parked worker reports none at all).
    pub fn observe(&mut self, w: usize, compute_ms: f64, mflops: f64) {
        if compute_ms.is_finite() && compute_ms >= 0.0 && mflops > 0.0 {
            self.model.observe(w, compute_ms / mflops);
        }
    }

    /// Current speed estimate (ms/mflop) for worker `w`.
    pub fn estimate(&self, w: usize) -> Option<f64> {
        self.model.estimate(w)
    }

    /// The authoritative current placement.
    pub fn shards(&self) -> &[WorkerShard] {
        &self.shards
    }

    /// Plan at most one lazy move. `eligible[w]` marks workers the
    /// caller considers placeable (alive under the scenario script);
    /// only eligible workers *with* speed estimates participate.
    ///
    /// Trigger: `t_max > threshold · t_min` over predicted finish times
    /// `t_w = rate_w · madds_w`. Donor = argmax, recipient = argmin
    /// (ties → lowest index). The returned δ is the tail-row count whose
    /// move minimizes the sorted-descending finish-time vector
    /// lexicographically; `None` when no δ is a strict improvement.
    pub fn plan(&self, eligible: &[bool]) -> Option<MovePlan> {
        assert_eq!(eligible.len(), self.shards.len(), "eligibility mask size mismatch");
        let parts: Vec<usize> = (0..self.shards.len())
            .filter(|&w| eligible[w] && self.model.estimate(w).is_some())
            .collect();
        if parts.len() < 2 {
            return None;
        }
        let finish = |w: usize, madds: f64| self.model.estimate(w).unwrap() * madds;
        let cur_madds: Vec<f64> = parts
            .iter()
            .map(|&w| {
                let s = &self.shards[w];
                let prefix = real_madds_prefix(s);
                shard_madds(s.x.is_sparse(), s.x.cols(), s.rows_real, prefix[s.rows_real])
            })
            .collect();
        let t: Vec<f64> = parts.iter().zip(&cur_madds).map(|(&w, &c)| finish(w, c)).collect();
        // the observe() guard drops zero-work and non-finite samples, so
        // every estimate — and hence every predicted finish — is finite;
        // a NaN here would silently disable sorted_desc's comparator and
        // corrupt the lexicographic objective
        for (&w, ti) in parts.iter().zip(&t) {
            assert!(ti.is_finite(), "non-finite predicted finish for worker {w}: {ti}");
        }
        let (mut hi, mut lo) = (0usize, 0usize);
        for i in 1..t.len() {
            if t[i] > t[hi] {
                hi = i;
            }
            if t[i] < t[lo] {
                lo = i;
            }
        }
        if !(t[hi] > self.threshold * t[lo]) {
            return None;
        }
        let (donor, recip) = (parts[hi], parts[lo]);
        if donor == recip {
            return None;
        }
        let d = &self.shards[donor];
        let r = &self.shards[recip];
        let d_prefix = real_madds_prefix(d);
        let r_real_madds = real_madds_prefix(r)[r.rows_real];
        let cur_vec = sorted_desc(t.clone());
        let mut best: Option<(Vec<f64>, usize)> = None;
        // full δ-scan: the donor keeps >= 1 real row
        for delta in 1..d.rows_real {
            let keep = d.rows_real - delta;
            let moved = d_prefix[d.rows_real] - d_prefix[keep];
            let d_madds = shard_madds(d.x.is_sparse(), d.x.cols(), keep, d_prefix[keep]);
            let r_madds = shard_madds(
                r.x.is_sparse(),
                r.x.cols(),
                r.rows_real + delta,
                r_real_madds + moved,
            );
            let cand: Vec<f64> = parts
                .iter()
                .enumerate()
                .map(|(i, &w)| {
                    if w == donor {
                        finish(w, d_madds)
                    } else if w == recip {
                        finish(w, r_madds)
                    } else {
                        t[i]
                    }
                })
                .collect();
            let cand = sorted_desc(cand);
            let better_than_best = match &best {
                None => true,
                Some((b, _)) => lex_less(&cand, b),
            };
            if better_than_best {
                best = Some((cand, delta));
            }
        }
        match best {
            Some((vec, delta)) if lex_less(&vec, &cur_vec) => {
                Some(MovePlan { from: donor, to: recip, rows: delta })
            }
            _ => None,
        }
    }

    /// Execute a planned move on the leader's placement: split the
    /// donor's tail band off, append it to the recipient, re-pad both to
    /// their AOT buckets, splice the target vectors. Returns the two
    /// rebuilt `(worker, shard)` pairs for the engine to swap in.
    pub fn apply(&mut self, plan: MovePlan) -> Vec<(usize, WorkerShard)> {
        let d = &self.shards[plan.from];
        assert!(plan.rows >= 1 && plan.rows < d.rows_real, "bad move plan {plan}");
        let keep = d.rows_real - plan.rows;
        let band_x = d.x.row_band(keep, d.rows_real);
        let band_y = d.y[keep..d.rows_real].to_vec();
        let new_dx = d.x.row_band(0, keep).pad_rows(pad_bucket(keep));
        let mut new_dy = d.y[0..keep].to_vec();
        new_dy.resize(pad_bucket(keep), 0.0);
        let donor = WorkerShard {
            x: new_dx,
            y: new_dy,
            rows_real: keep,
            partition_id: d.partition_id,
            // the resolved grad mode is sticky across migrations: the
            // engine rebuilds the Gram cache when it restages the shard,
            // but auto's cost-model choice is made once, at encode time
            grad_mode: d.grad_mode,
        };
        let r = &self.shards[plan.to];
        let r_rows = r.rows_real + plan.rows;
        let new_rx =
            DataMat::vstack(&[&r.x.row_band(0, r.rows_real), &band_x]).pad_rows(pad_bucket(r_rows));
        let mut new_ry = r.y[0..r.rows_real].to_vec();
        new_ry.extend_from_slice(&band_y);
        new_ry.resize(pad_bucket(r_rows), 0.0);
        let recip = WorkerShard {
            x: new_rx,
            y: new_ry,
            rows_real: r_rows,
            partition_id: r.partition_id,
            grad_mode: r.grad_mode,
        };
        self.shards[plan.from] = donor.clone();
        self.shards[plan.to] = recip.clone();
        vec![(plan.from, donor), (plan.to, recip)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn rebalance_grammar_parses_and_displays() {
        assert_eq!(RebalanceConfig::parse("off").unwrap(), RebalanceConfig::Off);
        let c = RebalanceConfig::parse("ewma:0.5:2").unwrap();
        assert_eq!(c, RebalanceConfig::Ewma { alpha: 0.5, threshold: 2.0 });
        assert_eq!(RebalanceConfig::parse(&c.to_string()).unwrap(), c);
        assert_eq!(RebalanceConfig::Off.to_string(), "off");
    }

    #[test]
    fn rebalance_grammar_rejects_malformed() {
        for bad in [
            "", ":", "on", "off:1", "ewma", "ewma:0.5", "ewma:0.5:2:9", "ewma:abc:2",
            "ewma:0.5:abc", "ewma:0:2", "ewma:1.5:2", "ewma:0.5:0.5", "ewma:-0.1:2",
            "ewma:0.5:-3", "ewma:nan:2", "ewma:0.5:inf",
        ] {
            assert!(RebalanceConfig::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn ewma_matches_hand_computed_sequence_with_gaps() {
        let mut m = EwmaSpeedModel::new(0.5, 2);
        assert_eq!(m.estimate(0), None);
        m.observe(0, 2.0); // first observation seeds directly
        assert_eq!(m.estimate(0), Some(2.0));
        m.observe(0, 4.0); // 0.5*4 + 0.5*2
        assert_eq!(m.estimate(0), Some(3.0));
        // park gap: no observation => estimate untouched
        assert_eq!(m.estimate(0), Some(3.0));
        m.observe(0, 1.0); // unpark: 0.5*1 + 0.5*3
        assert_eq!(m.estimate(0), Some(2.0));
        // the other worker never observed anything
        assert_eq!(m.estimate(1), None);
    }

    #[test]
    fn ewma_alpha_one_tracks_last_observation() {
        let mut m = EwmaSpeedModel::new(1.0, 1);
        for r in [5.0, 1.0, 9.0] {
            m.observe(0, r);
            assert_eq!(m.estimate(0), Some(r));
        }
    }

    fn dense_shard(rows_real: usize, cols: usize, fill: f64) -> WorkerShard {
        let x = Mat::from_fn(rows_real, cols, |_, _| fill).pad_rows(pad_bucket(rows_real));
        let mut y = vec![fill; rows_real];
        y.resize(pad_bucket(rows_real), 0.0);
        WorkerShard {
            x: x.into(),
            y,
            rows_real,
            partition_id: 0,
            grad_mode: crate::linalg::GradMode::Gemv,
        }
    }

    fn rebalancer(shards: Vec<WorkerShard>, threshold: f64) -> Rebalancer {
        Rebalancer::new(Scheme::Coded, shards, 0.5, threshold).unwrap()
    }

    #[test]
    fn rejects_partition_dedup_schemes() {
        let shards = vec![dense_shard(8, 4, 1.0)];
        assert!(Rebalancer::new(Scheme::Replicated { partitions: 2 }, shards.clone(), 0.5, 2.0)
            .is_err());
        assert!(
            Rebalancer::new(Scheme::GradientCoded { groups: 2 }, shards.clone(), 0.5, 2.0).is_err()
        );
        assert!(Rebalancer::new(
            Scheme::SeqCoded { window: 4, burst: 1 },
            shards.clone(),
            0.5,
            2.0
        )
        .is_err());
        assert!(Rebalancer::new(Scheme::StochCoded, shards.clone(), 0.5, 2.0).is_err());
        assert!(Rebalancer::new(Scheme::Uncoded, shards, 0.5, 2.0).is_ok());
    }

    #[test]
    fn zero_work_and_nonfinite_observations_never_poison_the_ewma() {
        // regression: a parked-then-resumed worker can report a round with
        // mflops == 0; compute_ms / 0 is inf (or NaN at 0/0) and a single
        // such sample would poison the EWMA forever
        let shards = vec![dense_shard(24, 4, 1.0), dense_shard(24, 4, 2.0)];
        let mut rb = rebalancer(shards, 1.5);
        rb.observe(0, 10.0, 0.0); // zero-work round: dropped
        assert_eq!(rb.estimate(0), None);
        rb.observe(0, f64::INFINITY, 10.0); // non-finite sample: dropped
        rb.observe(0, f64::NAN, 10.0);
        rb.observe(0, -1.0, 10.0); // negative clock: dropped
        assert_eq!(rb.estimate(0), None);
        rb.observe(0, 10.0, 10.0); // first valid sample seeds cleanly
        assert_eq!(rb.estimate(0), Some(1.0));
        rb.observe(0, 0.0, 0.0); // 0/0 after seeding: still dropped
        assert_eq!(rb.estimate(0), Some(1.0));
        // and the planner's finish vector stays finite end to end
        rb.observe(1, 30.0, 10.0);
        let plan = rb.plan(&[true, true]).expect("imbalance should still trigger");
        assert_eq!((plan.from, plan.to), (1, 0));
    }

    #[test]
    fn no_plan_without_trigger_or_estimates() {
        let shards = vec![dense_shard(16, 4, 1.0), dense_shard(16, 4, 2.0)];
        let mut rb = rebalancer(shards, 2.0);
        // no estimates at all
        assert_eq!(rb.plan(&[true, true]), None);
        // only one estimate
        rb.observe(0, 8.0, 16.0);
        assert_eq!(rb.plan(&[true, true]), None);
        // both estimated but balanced: ratio 1 <= threshold 2
        rb.observe(1, 8.0, 16.0);
        assert_eq!(rb.plan(&[true, true]), None);
        // imbalance present but the slow worker is ineligible
        rb.observe(1, 80.0, 16.0);
        assert_eq!(rb.plan(&[true, false]), None);
    }

    #[test]
    fn plans_move_from_slow_to_fast_and_applies_it() {
        // two dense 24-row shards (bucket 32); worker 1 is 3x slower
        let shards = vec![dense_shard(24, 4, 1.0), dense_shard(24, 4, 2.0)];
        let mut rb = rebalancer(shards, 1.5);
        rb.observe(0, 10.0, 10.0); // rate 1
        rb.observe(1, 30.0, 10.0); // rate 3
        let plan = rb.plan(&[true, true]).expect("imbalance should trigger a move");
        assert_eq!((plan.from, plan.to), (1, 0));
        assert!(plan.rows >= 1 && plan.rows < 24);
        assert_eq!(plan.to_string(), format!("migrate:1>0:{}", plan.rows));
        let changed = rb.apply(plan);
        assert_eq!(changed.len(), 2);
        let (dw, donor) = (&changed[0].0, &changed[0].1);
        let (rw, recip) = (&changed[1].0, &changed[1].1);
        assert_eq!((*dw, *rw), (1, 0));
        assert_eq!(donor.rows_real, 24 - plan.rows);
        assert_eq!(recip.rows_real, 24 + plan.rows);
        // re-padded to the AOT buckets, y length matches x rows
        assert_eq!(donor.x.rows(), pad_bucket(donor.rows_real));
        assert_eq!(recip.x.rows(), pad_bucket(recip.rows_real));
        assert_eq!(donor.y.len(), donor.x.rows());
        assert_eq!(recip.y.len(), recip.x.rows());
        // the moved band landed with its values: recipient's appended
        // real rows carry the donor's fill value (2.0)
        assert_eq!(recip.x.get(24, 0), 2.0);
        assert_eq!(recip.y[24], 2.0);
        // and the placement is conserved: total real rows unchanged
        assert_eq!(donor.rows_real + recip.rows_real, 48);
    }

    #[test]
    fn planner_is_deterministic_across_replays() {
        let make = || {
            let shards =
                vec![dense_shard(24, 4, 1.0), dense_shard(24, 4, 2.0), dense_shard(24, 4, 3.0)];
            let mut rb = rebalancer(shards, 1.5);
            rb.observe(0, 10.0, 10.0);
            rb.observe(1, 30.0, 10.0);
            rb.observe(2, 11.0, 10.0);
            let mut plans = Vec::new();
            while let Some(p) = rb.plan(&[true, true, true]) {
                plans.push(p);
                rb.apply(p);
                if plans.len() > 16 {
                    break; // deadlock guard: the strict-improvement gate should stop us
                }
            }
            plans
        };
        let a = make();
        let b = make();
        assert_eq!(a, b, "replay produced a different migration schedule");
        assert!(!a.is_empty());
        assert!(a.len() <= 16, "planner failed to converge");
    }

    #[test]
    fn tied_slow_workers_still_converge_via_lexicographic_objective() {
        // a rack of two equally slow workers + one fast: a plain
        // max-improvement gate would deadlock (moving rows off one slow
        // worker leaves the max at the other); the sorted-vector
        // objective keeps making strict progress
        let shards =
            vec![dense_shard(24, 4, 1.0), dense_shard(24, 4, 2.0), dense_shard(24, 4, 3.0)];
        let mut rb = rebalancer(shards, 1.5);
        rb.observe(0, 10.0, 10.0); // fast
        rb.observe(1, 40.0, 10.0); // slow (tied)
        rb.observe(2, 40.0, 10.0); // slow (tied)
        let first = rb.plan(&[true, true, true]).expect("tied rack should still trigger");
        assert_eq!(first.to, 0);
        assert_eq!(first.from, 1, "ties must break on the lowest worker index");
        rb.apply(first);
        let second = rb.plan(&[true, true, true]).expect("second slow worker moves next");
        assert_eq!(second.from, 2);
    }

    #[test]
    fn sparse_shards_move_nnz_not_pad_rows() {
        use crate::linalg::CsrMat;
        let csr = |rows_real: usize, fill: f64| -> WorkerShard {
            let dense = Mat::from_fn(rows_real, 4, |i, j| {
                if (i + j) % 2 == 0 {
                    fill
                } else {
                    0.0
                }
            });
            let x = CsrMat::from_dense(&dense).pad_rows(pad_bucket(rows_real));
            let mut y = vec![fill; rows_real];
            y.resize(pad_bucket(rows_real), 0.0);
            WorkerShard {
                x: x.into(),
                y,
                rows_real,
                partition_id: 0,
                grad_mode: crate::linalg::GradMode::Gemv,
            }
        };
        let mut rb = rebalancer(vec![csr(24, 1.0), csr(24, 2.0)], 1.5);
        rb.observe(0, 10.0, 10.0);
        rb.observe(1, 30.0, 10.0);
        let plan = rb.plan(&[true, true]).expect("sparse imbalance should trigger");
        let changed = rb.apply(plan);
        for (_, s) in &changed {
            assert!(s.x.is_sparse(), "migration must preserve the CSR backend");
            assert_eq!(s.y.len(), s.x.rows());
        }
    }
}
