//! Streaming first-k gather plumbing: the response channel between the
//! worker-side compute engines and the leader's admission logic.
//!
//! The batch-synchronous path (`worker_grad_all`) computes every worker's
//! response before the leader sees any of them, so per-worker completion
//! times are invisible and stragglers cannot be cancelled. The streaming
//! path inverts that: the leader hands the engine a [`Collector`], the
//! engine delivers each worker's response **as it completes** (resident
//! pool lanes on the native engine — see [`pool`](super::pool)), and the
//! collector applies the admission policy *at delivery time*:
//!
//! * [`Collector::collect_all`] — admit everything; used by
//!   [`ClockMode::Virtual`](crate::cluster::ClockMode) rounds, which need
//!   all responses so the deterministic post-hoc arrival sampling stays
//!   byte-identical to the historical batch path.
//! * [`Collector::first_k`] — admit the first `k` eligible responses in
//!   true arrival order and flip the round's cancellation flag the moment
//!   the k-th lands, so workers that have not yet started their shard
//!   skip it entirely (the paper's "drop their updates upon arrival",
//!   upgraded to "don't even compute them").
//!
//! Engines observe cancellation through [`Collector::is_cancelled`]; a
//! worker that checks the flag after the k-th admission returns without
//! computing, and its slot reports no measured compute time.
//!
//! A `Collector` is a cheap **shared handle**: cloning it produces
//! another handle onto the same round's state, which is how the
//! persistent worker pool ships one sink to many resident threads without
//! borrowing the leader's stack. [`Collector::into_collected`] requires
//! the handle being consumed to be the last one alive — engines must drop
//! every clone before returning from a streamed call (the pool waits for
//! per-lane acknowledgements that are sent only after the lane's handle
//! is dropped).
//!
//! In multi-tenant serving (see [`serve`](super::serve)) many jobs share
//! one pool, so a leaked clone must be attributable: the pool tags each
//! sink with the round's **job id** ([`Collector::tag_job`]) and hands
//! lanes lane-registered clones ([`Collector::clone_for_lane`]). A
//! sole-owner violation then panics naming the job and the lanes whose
//! handles are still alive instead of a generic message.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Admission policy a [`Collector`] applies as responses land.
enum Admission {
    /// Admit every delivery (virtual-clock rounds).
    All,
    /// Admit the first `k` deliveries whose worker is `eligible` (finite
    /// injected delay), then cancel the rest.
    FirstK {
        /// Number of responses the leader waits for.
        k: usize,
        /// Per-worker eligibility mask (failed workers never count).
        eligible: Vec<bool>,
    },
}

/// Per-worker state the collector accumulates.
struct Inner<T> {
    /// Response payload + measured compute time (ms), indexed by worker.
    responses: Vec<Option<(T, f64)>>,
    /// Workers in true delivery order (every delivery, admitted or not).
    delivery_order: Vec<usize>,
    /// Admitted workers in admission order (`FirstK` only; empty for
    /// `All`, where admission is decided post hoc by the caller).
    admitted: Vec<usize>,
    admission: Admission,
    /// Recycled payloads donated by [`Collector::rearm_all`] /
    /// [`Collector::rearm_first_k`] from the previous round's responses,
    /// served back out through [`Collector::take_spare`] so a
    /// steady-state deliverer (the pool's gradient lanes) can refill a
    /// previous round's buffer instead of allocating a fresh one.
    /// Capped at `workers` entries.
    spares: Vec<T>,
}

/// The round state every [`Collector`] handle points at.
struct Shared<T> {
    inner: Mutex<Inner<T>>,
    cancel: AtomicBool,
    /// Signalled (under the `inner` lock) when the cancellation flag
    /// flips, so a leader blocked in
    /// [`Collector::wait_cancelled_snapshot`] wakes exactly at the k-th
    /// admission instead of polling.
    cancelled_cv: Condvar,
    workers: usize,
    first_k: bool,
    /// Job this round belongs to (0 for single-tenant engines; retagged
    /// by the pool's per-job dispatch so leak diagnostics name the job).
    job: AtomicUsize,
    /// Pool lanes currently holding a registered clone of this sink.
    live_lanes: Mutex<Vec<usize>>,
}

/// Thread-safe streamed-response sink handed to
/// [`ComputeEngine::worker_grad_streamed`](crate::runtime::ComputeEngine::worker_grad_streamed).
///
/// `T` is the per-worker payload: `(Vec<f64>, f64)` for gradient rounds
/// (gradient, local objective), `f64` for line-search rounds.
///
/// Cloning produces another handle onto the same round (see the module
/// docs); the round's results are extracted once with
/// [`Collector::into_collected`], which panics if any clone is still
/// alive.
pub struct Collector<T> {
    shared: Arc<Shared<T>>,
    /// Lane this handle is registered to, if it was minted with
    /// [`Collector::clone_for_lane`]; anonymous handles carry `None`.
    lane: Option<usize>,
}

impl<T> Clone for Collector<T> {
    fn clone(&self) -> Self {
        Collector { shared: Arc::clone(&self.shared), lane: None }
    }
}

impl<T> Drop for Collector<T> {
    fn drop(&mut self) {
        if let Some(lane) = self.lane {
            let mut lanes = self.shared.live_lanes.lock().expect("collector poisoned");
            if let Some(pos) = lanes.iter().position(|&l| l == lane) {
                lanes.swap_remove(pos);
            }
        }
    }
}

/// Everything a finished round's collector observed, by worker.
pub struct Collected<T> {
    /// `(payload, compute_ms)` per worker; `None` if the worker was
    /// cancelled (or the engine failed to deliver it).
    pub responses: Vec<Option<(T, f64)>>,
    /// Workers in true delivery order.
    pub delivery_order: Vec<usize>,
    /// Admitted workers in admission order (first-k collectors only).
    pub admitted: Vec<usize>,
}

impl<T> Collector<T> {
    fn from_parts(admission: Admission, workers: usize, first_k: bool, k_cap: usize) -> Self {
        Collector {
            shared: Arc::new(Shared {
                inner: Mutex::new(Inner {
                    responses: (0..workers).map(|_| None).collect(),
                    delivery_order: Vec::with_capacity(workers),
                    admitted: Vec::with_capacity(k_cap),
                    admission,
                    spares: Vec::new(),
                }),
                cancel: AtomicBool::new(false),
                cancelled_cv: Condvar::new(),
                workers,
                first_k,
                job: AtomicUsize::new(0),
                live_lanes: Mutex::new(Vec::new()),
            }),
            lane: None,
        }
    }

    /// Collector that admits every response and never cancels.
    pub fn collect_all(workers: usize) -> Self {
        Collector::from_parts(Admission::All, workers, false, 0)
    }

    /// Collector that admits the first `k` eligible responses in delivery
    /// order and cancels the round once the k-th lands. `eligible[i]`
    /// false marks worker `i` as failed this round (infinite injected
    /// delay): its response, if any, is recorded but never admitted.
    pub fn first_k(workers: usize, k: usize, eligible: Vec<bool>) -> Self {
        assert_eq!(eligible.len(), workers, "eligibility mask length mismatch");
        let k_eff = k.min(eligible.iter().filter(|&&e| e).count());
        let c = Collector::from_parts(
            Admission::FirstK { k: k_eff, eligible },
            workers,
            true,
            k_eff,
        );
        if k_eff == 0 {
            // nothing can ever be admitted (all workers failed)
            c.shared.cancel.store(true, Ordering::Release);
        }
        c
    }

    /// Tag this round's shared state with the job it serves. The pool's
    /// per-job dispatch calls this before fanning the sink out to its
    /// lanes, so a leak caught by [`Collector::into_collected`] is
    /// attributed to the right tenant.
    pub fn tag_job(&self, job: usize) {
        self.shared.job.store(job, Ordering::Relaxed);
    }

    /// Job id this round is tagged with (0 until [`Collector::tag_job`]).
    pub fn job(&self) -> usize {
        self.shared.job.load(Ordering::Relaxed)
    }

    /// Clone this handle for pool lane `lane`, registering the lane in
    /// the round's live-handle set. The registration is released by the
    /// clone's `Drop`, so any lane whose handle outlives the streamed
    /// call is named by the sole-owner panic.
    pub fn clone_for_lane(&self, lane: usize) -> Self {
        self.shared.live_lanes.lock().expect("collector poisoned").push(lane);
        Collector { shared: Arc::clone(&self.shared), lane: Some(lane) }
    }

    /// Worker count this collector expects.
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// True when admission happens at delivery time (first-k sinks), so
    /// per-worker delivery order, timing, and cancellation are
    /// load-bearing. False for collect-all sinks, where an engine may use
    /// its fastest batch path (e.g. the XLA engine's single-broadcast
    /// `GradAll`) and deliver everything at the end.
    pub fn streaming_admission(&self) -> bool {
        self.shared.first_k
    }

    /// True once the admission policy no longer needs more responses.
    /// Workers should check this before starting (or between phases of)
    /// their shard computation and bail out if set.
    pub fn is_cancelled(&self) -> bool {
        self.shared.cancel.load(Ordering::Acquire)
    }

    /// Deliver worker `worker`'s response with its measured compute time.
    /// Called by engine worker threads; safe from any thread. Deliveries
    /// after cancellation are still recorded (the leader "drops their
    /// updates upon arrival") but never admitted.
    pub fn deliver(&self, worker: usize, payload: T, compute_ms: f64) {
        let mut guard = self.shared.inner.lock().expect("collector poisoned");
        let inner = &mut *guard;
        assert!(worker < self.shared.workers, "worker id {worker} out of range");
        assert!(inner.responses[worker].is_none(), "duplicate delivery for worker {worker}");
        inner.responses[worker] = Some((payload, compute_ms));
        inner.delivery_order.push(worker);
        if let Admission::FirstK { k, ref eligible } = inner.admission {
            if eligible[worker] && inner.admitted.len() < k {
                inner.admitted.push(worker);
                if inner.admitted.len() == k {
                    // Flag and wake while still holding the inner lock:
                    // a waiter in `wait_cancelled_snapshot` re-checks the
                    // flag under the same lock, so this wakeup cannot be
                    // missed.
                    self.shared.cancel.store(true, Ordering::Release);
                    self.shared.cancelled_cv.notify_all();
                }
            }
        }
    }

    /// Block until the round's cancellation flag flips (the k-th eligible
    /// response landed — or nothing ever can, because every worker
    /// failed), then snapshot what the collector has observed *at that
    /// moment*. First-k sinks only: collect-all sinks never cancel, so
    /// waiting on one would hang forever.
    ///
    /// This is the pipelined round loop's retirement point: the leader
    /// learns the admitted set the instant admission closes, while lane
    /// handles may still be alive delivering straggler responses (those
    /// land in the shared state after the snapshot and are recorded but
    /// never admitted — exactly the serial path's "drop their updates
    /// upon arrival" semantics, observed earlier). The admitted set and
    /// every admitted payload are final at cancellation time, so the
    /// snapshot is deterministic wherever the serial path is.
    pub fn wait_cancelled_snapshot(&self) -> Collected<T>
    where
        T: Clone,
    {
        assert!(
            self.shared.first_k,
            "wait_cancelled_snapshot requires a first-k collector \
             (a collect-all sink never cancels)"
        );
        let mut guard = self.shared.inner.lock().expect("collector poisoned");
        while !self.shared.cancel.load(Ordering::Acquire) {
            guard = self.shared.cancelled_cv.wait(guard).expect("collector poisoned");
        }
        Collected {
            responses: guard.responses.clone(),
            delivery_order: guard.delivery_order.clone(),
            admitted: guard.admitted.clone(),
        }
    }

    /// Reset a collect-all collector for a new round, recycling the
    /// previous round's payloads into the spare bin
    /// ([`Collector::take_spare`]). Panics if any lane-registered clone
    /// is still alive — rearming under an in-flight round would corrupt
    /// it, which is exactly why the pipelined round loop (depth > 1,
    /// straggler tails still settling) builds fresh collectors instead
    /// of reusing one.
    ///
    /// After a warmup round has sized the inner vectors, a
    /// rearm → dispatch → [`Collector::visit_responses`] round performs
    /// no heap allocation in the collector (asserted by the
    /// `alloc_regression` suite and reported by `fig_dispatch`).
    pub fn rearm_all(&self) {
        assert!(!self.shared.first_k, "rearm_all requires a collect-all collector");
        self.rearm_inner(None);
        self.shared.cancel.store(false, Ordering::Release);
    }

    /// Reset a first-k collector for a new round with a fresh admission
    /// target and eligibility mask (copied into the retained buffer — no
    /// allocation once capacity exists). Same recycling and sole-use
    /// contract as [`Collector::rearm_all`]; like
    /// [`Collector::first_k`], an all-failed mask pre-cancels the round.
    pub fn rearm_first_k(&self, k: usize, eligible: &[bool]) {
        assert!(self.shared.first_k, "rearm_first_k requires a first-k collector");
        assert_eq!(eligible.len(), self.shared.workers, "eligibility mask length mismatch");
        let k_eff = k.min(eligible.iter().filter(|&&e| e).count());
        self.rearm_inner(Some((k_eff, eligible)));
        self.shared.cancel.store(k_eff == 0, Ordering::Release);
    }

    fn rearm_inner(&self, first_k: Option<(usize, &[bool])>) {
        {
            let lanes = self.shared.live_lanes.lock().expect("collector poisoned");
            assert!(
                lanes.is_empty(),
                "collector rearmed while lanes {:?} still hold clones \
                 (the previous round has not finished)",
                *lanes
            );
        }
        let mut guard = self.shared.inner.lock().expect("collector poisoned");
        let inner = &mut *guard;
        let workers = self.shared.workers;
        for slot in inner.responses.iter_mut() {
            if let Some((payload, _)) = slot.take() {
                if inner.spares.len() < workers {
                    inner.spares.push(payload);
                }
            }
        }
        inner.responses.resize_with(workers, || None);
        inner.delivery_order.clear();
        inner.admitted.clear();
        match (first_k, &mut inner.admission) {
            (Some((k, eligible)), Admission::FirstK { k: kk, eligible: el }) => {
                *kk = k;
                el.clear();
                el.extend_from_slice(eligible);
            }
            (None, Admission::All) => {}
            _ => unreachable!("admission kind is fixed at construction"),
        }
    }

    /// Pop a payload recycled by the last rearm. Deliverers that can
    /// refill a buffer (the pool's gradient lanes) call this before
    /// allocating; an empty bin (first rounds, or a consuming extraction
    /// took the payloads away) just means a fresh allocation this round.
    pub fn take_spare(&self) -> Option<T> {
        self.shared.inner.lock().expect("collector poisoned").spares.pop()
    }

    /// Visit every delivered response in worker order without moving the
    /// payloads — the zero-allocation read of a finished reusable round
    /// (the payloads stay in place for the next rearm to recycle).
    pub fn visit_responses(&self, mut f: impl FnMut(usize, &T, f64)) {
        let guard = self.shared.inner.lock().expect("collector poisoned");
        for (w, slot) in guard.responses.iter().enumerate() {
            if let Some((payload, ms)) = slot {
                f(w, payload, *ms);
            }
        }
    }

    /// Extract the finished round's observations while keeping the
    /// handle alive for a future rearm — the reusable-collector
    /// counterpart of [`Collector::into_collected`]. Panics (like the
    /// consuming form) if a lane-registered clone is still alive. The
    /// payloads move out to the caller, so the next rearm finds nothing
    /// to recycle — use [`Collector::visit_responses`] when the round's
    /// buffers should stay resident.
    pub fn drain_collected(&self) -> Collected<T> {
        {
            let lanes = self.shared.live_lanes.lock().expect("collector poisoned");
            assert!(
                lanes.is_empty(),
                "collector drained while lanes {:?} still hold clones",
                *lanes
            );
        }
        let mut guard = self.shared.inner.lock().expect("collector poisoned");
        let inner = &mut *guard;
        let responses = std::mem::take(&mut inner.responses);
        Collected {
            responses,
            delivery_order: std::mem::take(&mut inner.delivery_order),
            admitted: std::mem::take(&mut inner.admitted),
        }
    }

    /// Consume the collector after the engine call returns. Panics if any
    /// clone of this handle is still alive — a streamed engine call must
    /// drop every handle it shipped to its workers before returning. The
    /// panic names the round's job id and any lanes still registered, so
    /// a clone leaked across a job boundary in the multi-tenant pool is
    /// attributable from the message alone.
    pub fn into_collected(self) -> Collected<T> {
        // Net out this handle's own refcount (running its Drop, which
        // releases its lane registration if it has one) before testing
        // sole ownership.
        let shared = Arc::clone(&self.shared);
        drop(self);
        let shared = match Arc::try_unwrap(shared) {
            Ok(s) => s,
            Err(shared) => {
                let job = shared.job.load(Ordering::Relaxed);
                let mut lanes =
                    shared.live_lanes.lock().expect("collector poisoned").clone();
                lanes.sort_unstable();
                panic!(
                    "collector for job {job} consumed while other handles are alive \
                     (lanes {lanes:?} still hold clones; an anonymous handle if the \
                     list is empty — the engine leaked a sink clone past its \
                     streamed call)"
                );
            }
        };
        let inner = shared.inner.into_inner().expect("collector poisoned");
        Collected {
            responses: inner.responses,
            delivery_order: inner.delivery_order,
            admitted: inner.admitted,
        }
    }
}

/// Collector for gradient rounds: payload is `(gradient, local objective)`.
pub type GradCollector = Collector<(Vec<f64>, f64)>;
/// Collector for line-search rounds: payload is `‖X̃_i d‖²`.
pub type CurvCollector = Collector<f64>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_all_never_cancels() {
        let c: Collector<u32> = Collector::collect_all(3);
        for i in [2usize, 0, 1] {
            assert!(!c.is_cancelled());
            c.deliver(i, i as u32, 1.0);
        }
        let got = c.into_collected();
        assert_eq!(got.delivery_order, vec![2, 0, 1]);
        assert!(got.admitted.is_empty());
        assert!(got.responses.iter().all(|r| r.is_some()));
    }

    #[test]
    fn first_k_cancels_after_kth_eligible() {
        let c: Collector<u32> = Collector::first_k(4, 2, vec![true; 4]);
        c.deliver(3, 0, 1.0);
        assert!(!c.is_cancelled());
        c.deliver(1, 0, 1.0);
        assert!(c.is_cancelled());
        // late delivery is recorded but not admitted
        c.deliver(0, 0, 1.0);
        let got = c.into_collected();
        assert_eq!(got.admitted, vec![3, 1]);
        assert_eq!(got.delivery_order, vec![3, 1, 0]);
    }

    #[test]
    fn ineligible_workers_never_admitted() {
        let c: Collector<u32> = Collector::first_k(3, 2, vec![true, false, true]);
        c.deliver(1, 0, 1.0); // failed worker responds — ignored
        assert!(!c.is_cancelled());
        c.deliver(0, 0, 1.0);
        c.deliver(2, 0, 1.0);
        let got = c.into_collected();
        assert_eq!(got.admitted, vec![0, 2]);
    }

    #[test]
    fn all_failed_cancels_immediately() {
        let c: Collector<u32> = Collector::first_k(2, 2, vec![false, false]);
        assert!(c.is_cancelled());
    }

    #[test]
    fn k_capped_by_eligible_count() {
        // k = 3 but only 1 eligible: cancel after that one
        let c: Collector<u32> = Collector::first_k(3, 3, vec![false, true, false]);
        c.deliver(1, 7, 0.5);
        assert!(c.is_cancelled());
        assert_eq!(c.into_collected().admitted, vec![1]);
    }

    #[test]
    #[should_panic(expected = "duplicate delivery")]
    fn duplicate_delivery_panics() {
        let c: Collector<u32> = Collector::collect_all(2);
        c.deliver(0, 1, 0.1);
        c.deliver(0, 2, 0.1);
    }

    #[test]
    fn clones_share_round_state() {
        // the pool's dispatch shape: deliveries through clones land in the
        // original handle's state, and into_collected works once the
        // clones are dropped
        let c: Collector<u32> = Collector::first_k(3, 2, vec![true; 3]);
        let h1 = c.clone();
        let h2 = c.clone();
        h1.deliver(2, 20, 0.1);
        h2.deliver(0, 10, 0.2);
        assert!(c.is_cancelled(), "k-th delivery through a clone must cancel");
        drop(h1);
        drop(h2);
        let got = c.into_collected();
        assert_eq!(got.admitted, vec![2, 0]);
        assert_eq!(got.responses[0].as_ref().unwrap().0, 10);
    }

    #[test]
    #[should_panic(expected = "other handles are alive")]
    fn into_collected_panics_while_clones_live() {
        let c: Collector<u32> = Collector::collect_all(1);
        let _leaked = c.clone();
        let _ = c.into_collected();
    }

    #[test]
    fn lane_clone_drop_releases_registration() {
        let c: Collector<u32> = Collector::collect_all(2);
        c.tag_job(4);
        let h = c.clone_for_lane(1);
        h.deliver(0, 9, 0.1);
        drop(h);
        // the lane registration is gone, so consumption succeeds
        let got = c.into_collected();
        assert_eq!(got.responses[0].as_ref().unwrap().0, 9);
    }

    /// The multi-job clone-leak regression (satellite of ISSUE 7): a lane
    /// handle leaked past the streamed call must be attributed to its job…
    #[test]
    #[should_panic(expected = "collector for job 7")]
    fn leaked_lane_clone_names_the_job() {
        let c: Collector<u32> = Collector::collect_all(2);
        c.tag_job(7);
        let _leaked = c.clone_for_lane(3);
        let _ = c.into_collected();
    }

    /// …and to the lane that held it.
    #[test]
    #[should_panic(expected = "lanes [3]")]
    fn leaked_lane_clone_names_the_lane() {
        let c: Collector<u32> = Collector::collect_all(2);
        c.tag_job(7);
        let _leaked = c.clone_for_lane(3);
        let _ = c.into_collected();
    }

    #[test]
    fn wait_snapshot_returns_at_kth_admission() {
        let c: Collector<u32> = Collector::first_k(4, 2, vec![true; 4]);
        let h = c.clone();
        let deliverer = std::thread::spawn(move || {
            h.deliver(3, 30, 1.0);
            h.deliver(1, 10, 2.0);
            // straggler lands after cancellation; still recorded in the
            // shared state, but the snapshot may or may not see it
            h.deliver(0, 0, 9.0);
        });
        let snap = c.wait_cancelled_snapshot();
        assert_eq!(snap.admitted, vec![3, 1]);
        assert_eq!(snap.responses[3].as_ref().unwrap().0, 30);
        assert_eq!(snap.responses[1].as_ref().unwrap().0, 10);
        deliverer.join().unwrap();
        // the consuming extraction still sees every delivery
        let full = c.into_collected();
        assert_eq!(full.admitted, vec![3, 1]);
        assert_eq!(full.delivery_order, vec![3, 1, 0]);
    }

    #[test]
    fn wait_snapshot_immediate_when_precancelled() {
        // all workers failed: first_k pre-cancels at construction, so the
        // wait must return immediately with an empty admitted set
        let c: Collector<u32> = Collector::first_k(2, 2, vec![false, false]);
        let snap = c.wait_cancelled_snapshot();
        assert!(snap.admitted.is_empty());
        assert!(snap.responses.iter().all(|r| r.is_none()));
    }

    #[test]
    #[should_panic(expected = "requires a first-k collector")]
    fn wait_snapshot_rejects_collect_all() {
        let c: Collector<u32> = Collector::collect_all(2);
        let _ = c.wait_cancelled_snapshot();
    }

    #[test]
    fn rearm_all_recycles_payloads_into_spares() {
        let c: Collector<Vec<f64>> = Collector::collect_all(2);
        c.deliver(0, vec![1.0, 2.0], 0.1);
        c.deliver(1, vec![3.0, 4.0], 0.2);
        assert!(c.take_spare().is_none(), "spares appear only at rearm");
        c.rearm_all();
        // both payloads recycled; round state reset for fresh deliveries
        let mut spares = [c.take_spare().unwrap(), c.take_spare().unwrap()];
        spares.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
        assert_eq!(spares, [vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert!(c.take_spare().is_none());
        c.deliver(0, vec![9.0], 0.3);
        let got = c.into_collected();
        assert_eq!(got.delivery_order, vec![0]);
        assert_eq!(got.responses[0].as_ref().unwrap().0, vec![9.0]);
        assert!(got.responses[1].is_none());
    }

    #[test]
    fn rearm_first_k_resets_admission_and_mask() {
        let c: Collector<u32> = Collector::first_k(3, 2, vec![true; 3]);
        c.deliver(0, 1, 0.1);
        c.deliver(1, 2, 0.1);
        assert!(c.is_cancelled());
        // new round: tighter k, worker 0 failed this time
        c.rearm_first_k(1, &[false, true, true]);
        assert!(!c.is_cancelled());
        c.deliver(0, 3, 0.1); // ineligible: recorded, not admitted
        assert!(!c.is_cancelled());
        c.deliver(2, 4, 0.1);
        assert!(c.is_cancelled());
        let got = c.into_collected();
        assert_eq!(got.admitted, vec![2]);
        assert_eq!(got.delivery_order, vec![0, 2]);
    }

    #[test]
    fn rearm_first_k_all_failed_precancels() {
        let c: Collector<u32> = Collector::first_k(2, 2, vec![true; 2]);
        c.rearm_first_k(2, &[false, false]);
        assert!(c.is_cancelled());
    }

    #[test]
    #[should_panic(expected = "rearmed while lanes")]
    fn rearm_panics_while_lane_clone_alive() {
        let c: Collector<u32> = Collector::collect_all(2);
        let _lane = c.clone_for_lane(1);
        c.rearm_all();
    }

    #[test]
    #[should_panic(expected = "requires a collect-all collector")]
    fn rearm_all_rejects_first_k_collector() {
        let c: Collector<u32> = Collector::first_k(2, 1, vec![true; 2]);
        c.rearm_all();
    }

    #[test]
    fn drain_collected_keeps_handle_reusable() {
        let c: Collector<u32> = Collector::first_k(2, 1, vec![true; 2]);
        c.deliver(1, 7, 0.5);
        let got = c.drain_collected();
        assert_eq!(got.admitted, vec![1]);
        // drained payloads left nothing to recycle, but the handle rearms
        c.rearm_first_k(1, &[true, true]);
        assert!(c.take_spare().is_none());
        c.deliver(0, 8, 0.1);
        let got = c.drain_collected();
        assert_eq!(got.admitted, vec![0]);
        assert_eq!(got.responses[0].as_ref().unwrap().0, 8);
    }

    #[test]
    fn visit_responses_reads_in_worker_order_without_moving() {
        let c: Collector<u32> = Collector::collect_all(3);
        c.deliver(2, 20, 0.2);
        c.deliver(0, 10, 0.1);
        let mut seen = Vec::new();
        c.visit_responses(|w, v, ms| seen.push((w, *v, ms)));
        assert_eq!(seen, vec![(0, 10, 0.1), (2, 20, 0.2)]);
        // payloads stayed in place: the consuming read still sees them
        let got = c.into_collected();
        assert_eq!(got.delivery_order, vec![2, 0]);
        assert_eq!(got.responses[2].as_ref().unwrap().0, 20);
    }
}
