//! Compute engines: who actually executes the per-worker math.
//!
//! The coordinator is engine-agnostic. Two engines implement the same
//! [`ComputeEngine`] contract:
//!
//! * [`NativeEngine`] — pure-Rust fused kernels (`Mat::fused_grad`),
//!   multithreaded across workers. Default for simulation-scale runs and
//!   the deterministic test suite.
//! * [`XlaEngine`] — the production path: loads the HLO-text artifacts the
//!   Python L2/L1 layers AOT-compiled (`make artifacts`), compiles them on
//!   the PJRT CPU client once, stages each worker's shard as persistent
//!   device buffers, and executes per round. Python never runs here.
//!
//! Artifacts are shape-specialized; the partitioner pads shards to
//! power-of-two row buckets (exact no-op padding) so a small artifact set
//! covers every experiment. [`artifacts::Manifest`] indexes them.

pub mod artifacts;
pub mod native;
pub mod xla_engine;

pub use artifacts::Manifest;
pub use native::NativeEngine;
pub use xla_engine::XlaEngine;

use crate::problem::EncodedProblem;
use anyhow::Result;

/// Engine selector for CLI/config surfaces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust fused kernels.
    Native,
    /// PJRT execution of the AOT HLO artifacts.
    Xla,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Ok(EngineKind::Native),
            "xla" | "pjrt" => Ok(EngineKind::Xla),
            other => anyhow::bail!("unknown engine kind {other:?}"),
        }
    }
}

/// Executes worker-side compute for an [`EncodedProblem`].
///
/// The contract mirrors the L2 graphs:
/// * `worker_grad`: `(g_i, f_i) = (X̃_iᵀ(X̃_i w − ỹ_i), ‖X̃_i w − ỹ_i‖²)`
/// * `linesearch`: `q_i = ‖X̃_i d‖²`
///
/// `worker_grad_all` computes all m workers for one broadcast `w` — the
/// shape the synchronous round actually needs — and is the hook engines
/// use for cross-worker parallelism.
pub trait ComputeEngine: Send {
    /// Human-readable engine name for logs/metrics.
    fn name(&self) -> &'static str;

    /// Gradient + local objective for one worker.
    fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)>;

    /// `‖X̃_i d‖²` for one worker.
    fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64>;

    /// All workers for one broadcast (default: serial loop).
    fn worker_grad_all(&mut self, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
        (0..self.workers()).map(|i| self.worker_grad(i, w)).collect()
    }

    /// All workers' line-search terms (default: serial loop).
    fn linesearch_all(&mut self, d: &[f64]) -> Result<Vec<f64>> {
        (0..self.workers()).map(|i| self.linesearch(i, d)).collect()
    }

    /// Worker count.
    fn workers(&self) -> usize;
}

/// Build an engine over the problem's shards.
pub fn build_engine(kind: EngineKind, prob: &EncodedProblem) -> Result<Box<dyn ComputeEngine>> {
    Ok(match kind {
        EngineKind::Native => Box::new(NativeEngine::new(prob)),
        EngineKind::Xla => Box::new(XlaEngine::new(prob, artifacts::default_dir())?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("native").unwrap(), EngineKind::Native);
        assert_eq!(EngineKind::parse("XLA").unwrap(), EngineKind::Xla);
        assert!(EngineKind::parse("gpu").is_err());
    }
}
