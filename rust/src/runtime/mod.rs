//! Compute engines: who actually executes the per-worker math.
//!
//! The coordinator is engine-agnostic. Two engines implement the same
//! [`ComputeEngine`] contract:
//!
//! * [`NativeEngine`] — pure-Rust fused kernels (`Mat::fused_grad`) over
//!   the persistent shard-owning [`WorkerPool`] (see [`pool`]): resident
//!   threads spawned once per run, zero per-round spawns. Default for
//!   simulation-scale runs and the deterministic test suite.
//! * [`XlaEngine`] — the production path: loads the HLO-text artifacts the
//!   Python L2/L1 layers AOT-compiled (`make artifacts`), compiles them on
//!   the PJRT CPU client once, stages each worker's shard as persistent
//!   device buffers, and executes per round. Python never runs here.
//!
//! Artifacts are shape-specialized; the partitioner pads shards to
//! power-of-two row buckets (exact no-op padding) so a small artifact set
//! covers every experiment. [`artifacts::Manifest`] indexes them.
//!
//! Both engines also expose the **streaming** surface
//! ([`ComputeEngine::worker_grad_streamed`]): responses are delivered
//! through a [`stream::Collector`] as each worker finishes, which is what
//! the cluster's event-driven first-k gather and straggler cancellation
//! run on (see [`stream`]).
//!
//! Engines with resident per-run state additionally expose an
//! [`EngineSession`] through [`ComputeEngine::session`]: parking (the
//! crash-park invariant) and in-place problem reconfiguration. The
//! default is `None` — stateless engines, and the fail-fast [`XlaEngine`]
//! stub, opt out and callers fall back to the historical rebuild paths.
//!
//! Multi-tenant serving lives in [`serve`]: a [`JobServer`] interleaves
//! many jobs' rounds over one shared [`WorkerPool`], each job dispatching
//! through its own [`serve::JobEngine`] view of the pool.

pub mod artifacts;
pub mod native;
pub mod pool;
pub mod rebalance;
pub mod serve;
pub mod stream;
pub mod temporal;
pub mod xla_engine;

pub use artifacts::Manifest;
pub use native::NativeEngine;
pub use pool::WorkerPool;
pub use rebalance::{EwmaSpeedModel, MovePlan, RebalanceConfig, Rebalancer};
pub use serve::{
    EncodedShardCache, JobEngine, JobServer, JobSpec, SchedJob, Scheduler, ServeOptimizer,
    ServeOutcome, ServePolicy,
};
pub use stream::{Collected, Collector, CurvCollector, GradCollector};
pub use temporal::{run_pipelined, PipelinedStepper};
pub use xla_engine::XlaEngine;

// The engines are storage-oblivious through `linalg::DataMat`: the native
// engine's fused kernels dispatch per shard, the XLA engine requires
// dense shards and fails fast on CSR (see `xla_engine` docs).

use crate::problem::{BatchPlan, EncodedProblem};
use anyhow::Result;

/// Engine selector for CLI/config surfaces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust fused kernels.
    Native,
    /// PJRT execution of the AOT HLO artifacts.
    Xla,
}

impl EngineKind {
    /// Parse the CLI forms `native`/`rust` and `xla`/`pjrt`.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Ok(EngineKind::Native),
            "xla" | "pjrt" => Ok(EngineKind::Xla),
            other => anyhow::bail!("unknown engine kind {other:?}"),
        }
    }
}

/// Executes worker-side compute for an [`EncodedProblem`].
///
/// The contract mirrors the L2 graphs:
/// * `worker_grad`: `(g_i, f_i) = (X̃_iᵀ(X̃_i w − ỹ_i), ‖X̃_i w − ỹ_i‖²)`
/// * `linesearch`: `q_i = ‖X̃_i d‖²`
///
/// `worker_grad_all` computes all m workers for one broadcast `w` — the
/// batch-synchronous shape — while `worker_grad_streamed` delivers each
/// worker's response through a [`Collector`] **as it completes**, with a
/// per-worker measured compute time, honoring the collector's
/// cancellation flag. The streamed surface is what the cluster's
/// first-k gather actually runs on; the batch surface remains the
/// reference implementation and the bench baseline.
pub trait ComputeEngine: Send {
    /// Human-readable engine name for logs/metrics.
    fn name(&self) -> &'static str;

    /// Gradient + local objective for one worker.
    fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)>;

    /// `‖X̃_i d‖²` for one worker.
    fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64>;

    /// All workers for one broadcast (default: serial loop).
    fn worker_grad_all(&mut self, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
        (0..self.workers()).map(|i| self.worker_grad(i, w)).collect()
    }

    /// All workers' line-search terms (default: serial loop).
    fn linesearch_all(&mut self, d: &[f64]) -> Result<Vec<f64>> {
        (0..self.workers()).map(|i| self.linesearch(i, d)).collect()
    }

    /// Stream one gradient round into `sink`: compute each worker's
    /// `(g_i, f_i)`, deliver it with the worker's own measured compute
    /// time (wall-clock ms), and skip workers once
    /// [`Collector::is_cancelled`] is set. Returns when every worker has
    /// either delivered or been cancelled.
    ///
    /// Default: serial loop with per-worker timing and a cancellation
    /// check between workers (correct for any engine; no cross-worker
    /// parallelism). [`NativeEngine`] overrides this with one command per
    /// resident pool lane (zero per-round spawns; see [`pool`]).
    fn worker_grad_streamed(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        for i in 0..self.workers() {
            if sink.is_cancelled() {
                break;
            }
            let t0 = std::time::Instant::now();
            let (g, f) = self.worker_grad(i, w)?;
            sink.deliver(i, (g, f), t0.elapsed().as_secs_f64() * 1e3);
        }
        Ok(())
    }

    /// Mini-batch gradient + local objective for one worker, restricted to
    /// the row segments `segs` of that worker's shard (one round's slice of
    /// a [`BatchPlan`]): `(g_i, f_i)` over rows `∪ segs` only.
    ///
    /// Engines whose staged compute is full-shard-shaped only (the XLA
    /// engine's AOT artifacts are fixed-shape) may not support this; the
    /// default implementation errors, and the stochastic optimizers
    /// surface that error at the first batch round. [`NativeEngine`]
    /// overrides it with the range-restricted fused kernel.
    fn worker_grad_batch(
        &mut self,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        let _ = (worker, w, segs);
        anyhow::bail!(
            "engine {:?} does not support mini-batch gradient rounds \
             (use --engine native for --optimizer sgd with batch-frac < 1)",
            self.name()
        )
    }

    /// Stream one mini-batch gradient round into `sink`: the batch
    /// counterpart of [`ComputeEngine::worker_grad_streamed`], delivering
    /// each worker's [`ComputeEngine::worker_grad_batch`] result with its
    /// measured compute time and honoring the collector's cancellation
    /// flag. `plan` must cover exactly [`ComputeEngine::workers`] workers.
    ///
    /// Default: serial loop (correct for any engine that implements
    /// `worker_grad_batch`); [`NativeEngine`] overrides this with one
    /// command per resident pool lane, mirroring its full-gradient
    /// streaming fan-out.
    fn worker_grad_batch_streamed(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
        sink: &GradCollector,
    ) -> Result<()> {
        for i in 0..self.workers() {
            if sink.is_cancelled() {
                break;
            }
            let t0 = std::time::Instant::now();
            let (g, f) = self.worker_grad_batch(i, w, &plan.segments[i])?;
            sink.deliver(i, (g, f), t0.elapsed().as_secs_f64() * 1e3);
        }
        Ok(())
    }

    /// Stream one line-search round into `sink`; the streamed counterpart
    /// of [`ComputeEngine::linesearch_all`], with the same contract as
    /// [`ComputeEngine::worker_grad_streamed`].
    fn linesearch_streamed(&mut self, d: &[f64], sink: &CurvCollector) -> Result<()> {
        for i in 0..self.workers() {
            if sink.is_cancelled() {
                break;
            }
            let t0 = std::time::Instant::now();
            let q = self.linesearch(i, d)?;
            sink.deliver(i, q, t0.elapsed().as_secs_f64() * 1e3);
        }
        Ok(())
    }

    /// Dispatch one gradient round into `sink` **without waiting for the
    /// engine's internal fan-out to settle** — the pipelined round
    /// loop's dispatch half. The caller observes the round through the
    /// sink's shared state
    /// ([`Collector::wait_cancelled_snapshot`](stream::Collector::wait_cancelled_snapshot))
    /// and later retires the dispatch with
    /// [`ComputeEngine::drain_dispatch_to`].
    ///
    /// Default: the blocking streamed call (every engine is trivially
    /// correct at pipeline depth 1 semantics — the dispatch is fully
    /// settled on return and the drain is a no-op). [`NativeEngine`]
    /// overrides this with the pool's deferred fan-out so the leader can
    /// retire a round at its k-th admission while straggler lanes are
    /// still delivering.
    fn worker_grad_dispatch(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        self.worker_grad_streamed(w, sink)
    }

    /// Block until at most `max_in_flight` dispatches issued through
    /// [`ComputeEngine::worker_grad_dispatch`] remain unsettled — the
    /// pipelined loop's bounded reorder window. Default: no-op (the
    /// default dispatch is already settled on return).
    fn drain_dispatch_to(&mut self, max_in_flight: usize) -> Result<()> {
        let _ = max_in_flight;
        Ok(())
    }

    /// Block until every outstanding dispatch is settled (pipeline
    /// flush). After this, every sink handed to
    /// [`ComputeEngine::worker_grad_dispatch`] is sole-owned again.
    fn drain_dispatch(&mut self) -> Result<()> {
        self.drain_dispatch_to(0)
    }

    /// Worker count.
    fn workers(&self) -> usize;

    /// The engine's stateful per-run session, if it keeps resident
    /// worker state ([`NativeEngine`]'s persistent pool does; the
    /// default — inherited by the XLA engine and any stateless mock —
    /// is `None`, and callers fall back to the historical behavior:
    /// crashed workers compute discarded responses, and problem swaps
    /// rebuild the engine).
    fn session(&mut self) -> Option<&mut dyn EngineSession> {
        None
    }
}

/// Stateful session surface for engines with resident per-run workers
/// (the persistent [`WorkerPool`]). Obtained via
/// [`ComputeEngine::session`]; every method is a command to the resident
/// state, never a respawn.
pub trait EngineSession {
    /// Park (`true`) or unpark (`false`) one worker: a parked worker's
    /// shard and scratch stay resident but round fan-out skips it — the
    /// crash-park invariant the cluster maps scenario `crash:`/`leave:`
    /// events onto (and `recover:`/`join:` reverses). Infallible: a dead
    /// lane surfaces on the next round dispatch instead.
    fn set_parked(&mut self, worker: usize, parked: bool);

    /// Number of currently parked workers.
    fn parked_count(&self) -> usize;

    /// Swap the staged problem in place, keeping the resident threads
    /// (park flags reset, worker count may change). Engines whose staged
    /// state cannot be swapped return an error and the caller rebuilds.
    fn reconfigure(&mut self, prob: &EncodedProblem) -> Result<()>;

    /// Swap individual workers' shards in place — the rebalancer's
    /// migration handoff. Unlike [`EngineSession::reconfigure`] this
    /// keeps park flags, worker count, and every untouched lane exactly
    /// as they are (no respawn: `spawn_count` stays constant). Engines
    /// without per-shard swap support return an error (the default).
    fn migrate_shards(&mut self, changed: &[(usize, crate::problem::WorkerShard)]) -> Result<()> {
        let _ = changed;
        anyhow::bail!("this engine does not support in-place shard migration")
    }

    /// Total OS threads this engine ever spawned (monotonic; constant
    /// across rounds once the pool is up — the zero-per-round-spawn
    /// invariant, asserted by `rust/tests/pool_equivalence.rs`).
    fn spawn_count(&self) -> u64;
}

/// Build an engine over the problem's shards (native engine at its
/// default pool size — available parallelism).
pub fn build_engine(kind: EngineKind, prob: &EncodedProblem) -> Result<Box<dyn ComputeEngine>> {
    build_engine_with(kind, prob, 0)
}

/// [`build_engine`] with an explicit pool size for the native engine's
/// resident worker pool (`0` = available parallelism — the default). The
/// XLA engine ignores `threads`: its parallelism lives inside PJRT.
pub fn build_engine_with(
    kind: EngineKind,
    prob: &EncodedProblem,
    threads: usize,
) -> Result<Box<dyn ComputeEngine>> {
    Ok(match kind {
        EngineKind::Native => {
            let eng = NativeEngine::new(prob);
            Box::new(if threads > 0 { eng.with_threads(threads) } else { eng })
        }
        EngineKind::Xla => Box::new(XlaEngine::new(prob, artifacts::default_dir())?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("native").unwrap(), EngineKind::Native);
        assert_eq!(EngineKind::parse("XLA").unwrap(), EngineKind::Xla);
        assert!(EngineKind::parse("gpu").is_err());
    }
}
