//! PJRT execution of the AOT HLO artifacts — the production hot path.
//!
//! The `xla` crate's handles wrap raw PJRT pointers and are not `Send`,
//! so the engine runs a dedicated **service thread** that owns the PJRT
//! CPU client, the compiled executables (one per distinct shard shape),
//! and each worker's staged device buffers (`X̃_i`, `ỹ_i` uploaded once at
//! startup — only the broadcast `w`/`d` crosses the host↔device boundary
//! per round). The public [`XlaEngine`] is a `Send` handle speaking a
//! small request/reply protocol over mpsc channels; the coordinator uses
//! it exactly like the native engine.
//!
//! Artifact resolution: each shard's (padded) row count is rounded up to
//! the smallest manifest bucket for its `p`; zero-row padding is exact for
//! both outputs. Missing shapes are a hard startup error (fail fast, not
//! mid-run).
//!
//! **Storage:** the artifacts are dense-shaped, so the engine stages
//! dense shards only and fails fast at construction when the encoded
//! problem holds CSR shards (`--storage sparse` is a native-engine
//! feature; batch-shaped sparse artifacts are a listed follow-up).
//!
//! **Mini-batch rounds:** the AOT artifacts are fixed full-shard shapes,
//! so the engine inherits the trait's failing default for
//! `worker_grad_batch`/`worker_grad_batch_streamed` — `CodedSgd` with
//! `batch_frac < 1` needs `--engine native` (or batch-shaped artifacts, a
//! listed follow-up). `batch_frac = 1` takes the full-gradient round path
//! and runs on either engine.

//!
//! **Sessions:** the engine inherits the `None` default of
//! [`ComputeEngine::session`](super::ComputeEngine::session) — it has no parkable resident workers (PJRT owns its device
//! state) and no in-place reconfiguration, so scenario crashes keep the
//! historical compute-and-discard behavior and problem swaps rebuild the
//! engine. The feature-gated stub below keeps failing fast at
//! construction either way.
//!
//! **Feature gating:** the PJRT bindings (the `xla` crate) are not
//! available in the offline build environment, so the real engine is
//! compiled only with `--features xla` — which additionally requires
//! adding the vendored `xla` crate to `[dependencies]` (see the feature
//! comment in `rust/Cargo.toml`). Without it, [`XlaEngine`] is a stub
//! with the same construction signature that fails fast with a clear
//! error; every non-XLA code path (the whole tier-1 test suite) builds
//! and runs unchanged.

#[cfg(feature = "xla")]
mod imp {
    use crate::runtime::artifacts::Manifest;
    use crate::runtime::stream::{CurvCollector, GradCollector};
    use crate::runtime::ComputeEngine;
    use crate::problem::EncodedProblem;
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::mpsc;

    enum Request {
        Grad { worker: usize, w: Vec<f32> },
        /// Broadcast round: stage `w` once, run every worker (§Perf iter. 4).
        GradAll { w: Vec<f32> },
        Linesearch { worker: usize, d: Vec<f32> },
        LinesearchAll { d: Vec<f32> },
        Shutdown,
    }

    enum Reply {
        Grad(Result<(Vec<f64>, f64)>),
        GradAll(Result<Vec<(Vec<f64>, f64)>>),
        Linesearch(Result<f64>),
        LinesearchAll(Result<Vec<f64>>),
    }

    /// `Send` handle to the PJRT service thread.
    pub struct XlaEngine {
        tx: mpsc::Sender<Request>,
        rx: mpsc::Receiver<Reply>,
        workers: usize,
        p: usize,
        handle: Option<std::thread::JoinHandle<()>>,
    }

    /// Per-worker staged data living on the service thread.
    struct StagedWorker {
        x_buf: xla::PjRtBuffer,
        y_buf: xla::PjRtBuffer,
        /// (rows_bucket, p) — key into the executable maps.
        shape: (usize, usize),
    }

    struct Service {
        client: xla::PjRtClient,
        grad_exes: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
        ls_exes: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
        staged: Vec<StagedWorker>,
        p: usize,
    }

    impl Service {
        fn build(
            shards: Vec<(Vec<f32>, Vec<f32>, usize)>, // (x row-major, y, rows_bucket)
            p: usize,
            manifest: &Manifest,
        ) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            let mut grad_exes = HashMap::new();
            let mut ls_exes = HashMap::new();
            let mut staged = Vec::with_capacity(shards.len());
            for (x, y, rows) in &shards {
                let shape = (*rows, p);
                if !grad_exes.contains_key(&shape) {
                    let grad_path = manifest
                        .find("worker_grad", shape)
                        .with_context(|| format!("no worker_grad artifact for shape {shape:?}"))?;
                    let ls_path = manifest
                        .find("linesearch", shape)
                        .with_context(|| format!("no linesearch artifact for shape {shape:?}"))?;
                    grad_exes.insert(shape, compile(&client, &grad_path)?);
                    ls_exes.insert(shape, compile(&client, &ls_path)?);
                }
                let x_buf = client
                    .buffer_from_host_buffer::<f32>(x, &[*rows, p], None)
                    .map_err(|e| anyhow!("staging X: {e:?}"))?;
                let y_buf = client
                    .buffer_from_host_buffer::<f32>(y, &[*rows, 1], None)
                    .map_err(|e| anyhow!("staging y: {e:?}"))?;
                staged.push(StagedWorker { x_buf, y_buf, shape });
            }
            Ok(Service { client, grad_exes, ls_exes, staged, p })
        }

        fn grad(&self, worker: usize, w: &[f32]) -> Result<(Vec<f64>, f64)> {
            let w_buf = self
                .client
                .buffer_from_host_buffer::<f32>(w, &[self.p, 1], None)
                .map_err(|e| anyhow!("staging w: {e:?}"))?;
            self.grad_with_buf(worker, &w_buf)
        }

        /// One worker's gradient against an already-staged broadcast buffer.
        fn grad_with_buf(&self, worker: usize, w_buf: &xla::PjRtBuffer) -> Result<(Vec<f64>, f64)> {
            let sw = &self.staged[worker];
            let exe = &self.grad_exes[&sw.shape];
            let outs = exe
                .execute_b(&[&sw.x_buf, &sw.y_buf, w_buf])
                .map_err(|e| anyhow!("execute worker_grad: {e:?}"))?;
            let lit = outs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}"))?;
            let (g_lit, f_lit) = lit.to_tuple2().map_err(|e| anyhow!("tuple: {e:?}"))?;
            let g32 = g_lit.to_vec::<f32>().map_err(|e| anyhow!("g readback: {e:?}"))?;
            let f32v = f_lit.to_vec::<f32>().map_err(|e| anyhow!("f readback: {e:?}"))?;
            Ok((g32.iter().map(|&v| v as f64).collect(), f32v[0] as f64))
        }

        /// Broadcast gradient round: upload `w` once, execute all workers.
        fn grad_all(&self, w: &[f32]) -> Result<Vec<(Vec<f64>, f64)>> {
            let w_buf = self
                .client
                .buffer_from_host_buffer::<f32>(w, &[self.p, 1], None)
                .map_err(|e| anyhow!("staging w: {e:?}"))?;
            (0..self.staged.len()).map(|i| self.grad_with_buf(i, &w_buf)).collect()
        }

        fn linesearch(&self, worker: usize, d: &[f32]) -> Result<f64> {
            let d_buf = self
                .client
                .buffer_from_host_buffer::<f32>(d, &[self.p, 1], None)
                .map_err(|e| anyhow!("staging d: {e:?}"))?;
            self.linesearch_with_buf(worker, &d_buf)
        }

        fn linesearch_with_buf(&self, worker: usize, d_buf: &xla::PjRtBuffer) -> Result<f64> {
            let sw = &self.staged[worker];
            let exe = &self.ls_exes[&sw.shape];
            let outs = exe
                .execute_b(&[&sw.x_buf, d_buf])
                .map_err(|e| anyhow!("execute linesearch: {e:?}"))?;
            let lit = outs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}"))?;
            let q_lit = lit.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
            let q = q_lit.to_vec::<f32>().map_err(|e| anyhow!("q readback: {e:?}"))?;
            Ok(q[0] as f64)
        }

        fn linesearch_all(&self, d: &[f32]) -> Result<Vec<f64>> {
            let d_buf = self
                .client
                .buffer_from_host_buffer::<f32>(d, &[self.p, 1], None)
                .map_err(|e| anyhow!("staging d: {e:?}"))?;
            (0..self.staged.len()).map(|i| self.linesearch_with_buf(i, &d_buf)).collect()
        }
    }

    fn compile(client: &xla::PjRtClient, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .with_context(|| format!("non-UTF8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
    }

    impl XlaEngine {
        /// Stage the problem's shards and compile its artifacts.
        ///
        /// Fails fast if `dir` has no manifest or lacks a shape bucket for any
        /// shard (`make artifacts` regenerates them).
        pub fn new(prob: &EncodedProblem, dir: PathBuf) -> Result<Self> {
            let manifest = Manifest::load(&dir)?;
            let p = prob.p();
            // Round every shard up to its artifact bucket (zero-pad = exact).
            // The AOT artifacts are dense-shaped: CSR shards fail fast here
            // (re-encode with --storage dense, or use the native engine).
            let mut shards = Vec::with_capacity(prob.shards.len());
            for (i, s) in prob.shards.iter().enumerate() {
                let dense = s.x.as_dense().ok_or_else(|| {
                    anyhow!(
                        "worker {i}: XLA engine requires dense f64 shard storage \
                         (shards are CSR or f32; re-encode with --storage dense \
                         --precision f64, or use --engine native)"
                    )
                })?;
                let rows = dense.rows();
                let bucket = manifest.grad_bucket(rows, p).with_context(|| {
                    format!(
                        "worker {i}: no worker_grad artifact bucket for rows={rows}, p={p} \
                         (available: {:?}) — extend python/compile/aot.py shapes",
                        manifest.grad_shapes()
                    )
                })?;
                let padded = dense.pad_rows(bucket);
                let mut y32: Vec<f32> = s.y.iter().map(|&v| v as f32).collect();
                y32.resize(bucket, 0.0);
                shards.push((padded.to_f32(), y32, bucket));
            }
            if manifest.find("linesearch", (shards[0].2, p)).is_none() {
                bail!("manifest lacks linesearch artifacts for p={p}");
            }

            let (tx, service_rx) = mpsc::channel::<Request>();
            let (service_tx, rx) = mpsc::channel::<Reply>();
            let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
            let workers = shards.len();
            let manifest_clone = manifest.clone();
            let handle = std::thread::Builder::new()
                .name("xla-service".into())
                .spawn(move || {
                    let service = match Service::build(shards, p, &manifest_clone) {
                        Ok(s) => {
                            let _ = init_tx.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = init_tx.send(Err(e));
                            return;
                        }
                    };
                    while let Ok(req) = service_rx.recv() {
                        match req {
                            Request::Grad { worker, w } => {
                                let _ = service_tx.send(Reply::Grad(service.grad(worker, &w)));
                            }
                            Request::GradAll { w } => {
                                let _ = service_tx.send(Reply::GradAll(service.grad_all(&w)));
                            }
                            Request::Linesearch { worker, d } => {
                                let _ =
                                    service_tx.send(Reply::Linesearch(service.linesearch(worker, &d)));
                            }
                            Request::LinesearchAll { d } => {
                                let _ = service_tx
                                    .send(Reply::LinesearchAll(service.linesearch_all(&d)));
                            }
                            Request::Shutdown => break,
                        }
                    }
                })
                .context("spawning xla service thread")?;
            init_rx
                .recv()
                .context("xla service thread died during init")??;
            Ok(XlaEngine { tx, rx, workers, p, handle: Some(handle) })
        }
    }

    impl ComputeEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
            let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
            self.tx
                .send(Request::Grad { worker, w: w32 })
                .map_err(|_| anyhow!("xla service thread gone"))?;
            match self.rx.recv().map_err(|_| anyhow!("xla service thread gone"))? {
                Reply::Grad(r) => r,
                _ => bail!("protocol error: unexpected reply type"),
            }
        }

        fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64> {
            let d32: Vec<f32> = d.iter().map(|&v| v as f32).collect();
            self.tx
                .send(Request::Linesearch { worker, d: d32 })
                .map_err(|_| anyhow!("xla service thread gone"))?;
            match self.rx.recv().map_err(|_| anyhow!("xla service thread gone"))? {
                Reply::Linesearch(r) => r,
                _ => bail!("protocol error: unexpected reply type"),
            }
        }

        fn worker_grad_all(&mut self, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
            let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
            self.tx
                .send(Request::GradAll { w: w32 })
                .map_err(|_| anyhow!("xla service thread gone"))?;
            match self.rx.recv().map_err(|_| anyhow!("xla service thread gone"))? {
                Reply::GradAll(r) => r,
                _ => bail!("protocol error: unexpected reply type"),
            }
        }

        fn linesearch_all(&mut self, d: &[f64]) -> Result<Vec<f64>> {
            let d32: Vec<f32> = d.iter().map(|&v| v as f32).collect();
            self.tx
                .send(Request::LinesearchAll { d: d32 })
                .map_err(|_| anyhow!("xla service thread gone"))?;
            match self.rx.recv().map_err(|_| anyhow!("xla service thread gone"))? {
                Reply::LinesearchAll(r) => r,
                _ => bail!("protocol error: unexpected reply type"),
            }
        }

        /// Collect-all sinks take the `GradAll` broadcast path (`w` is
        /// staged on device once for all workers — §Perf iter. 4) with
        /// the batch time attributed evenly; first-k sinks stream one
        /// worker per service round trip so true per-worker timing and
        /// cancellation apply.
        fn worker_grad_streamed(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
            if !sink.streaming_admission() {
                let t0 = std::time::Instant::now();
                let all = self.worker_grad_all(w)?;
                let per = t0.elapsed().as_secs_f64() * 1e3 / all.len().max(1) as f64;
                for (i, resp) in all.into_iter().enumerate() {
                    sink.deliver(i, resp, per);
                }
                return Ok(());
            }
            for i in 0..self.workers {
                if sink.is_cancelled() {
                    break;
                }
                let t0 = std::time::Instant::now();
                let (g, f) = self.worker_grad(i, w)?;
                sink.deliver(i, (g, f), t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok(())
        }

        /// Same batch-vs-streaming split as
        /// [`XlaEngine::worker_grad_streamed`], for line-search rounds.
        fn linesearch_streamed(&mut self, d: &[f64], sink: &CurvCollector) -> Result<()> {
            if !sink.streaming_admission() {
                let t0 = std::time::Instant::now();
                let all = self.linesearch_all(d)?;
                let per = t0.elapsed().as_secs_f64() * 1e3 / all.len().max(1) as f64;
                for (i, q) in all.into_iter().enumerate() {
                    sink.deliver(i, q, per);
                }
                return Ok(());
            }
            for i in 0..self.workers {
                if sink.is_cancelled() {
                    break;
                }
                let t0 = std::time::Instant::now();
                let q = self.linesearch(i, d)?;
                sink.deliver(i, q, t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok(())
        }

        fn workers(&self) -> usize {
            self.workers
        }
    }

    impl Drop for XlaEngine {
        fn drop(&mut self) {
            let _ = self.tx.send(Request::Shutdown);
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
        }
    }

    impl XlaEngine {
        /// Problem dimension p.
        pub fn dim(&self) -> usize {
            self.p
        }
    }
}

#[cfg(feature = "xla")]
pub use imp::XlaEngine;

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::problem::EncodedProblem;
    use crate::runtime::ComputeEngine;
    use anyhow::{bail, Result};
    use std::path::PathBuf;

    /// Stub XLA engine compiled when the `xla` feature is off: keeps the
    /// construction signature so callers (CLI `--engine xla`, benches,
    /// integration tests) compile, but always fails at `new` — the
    /// PJRT bindings are not linked into this build.
    pub struct XlaEngine {
        _private: (),
    }

    impl XlaEngine {
        /// Always errors: this binary was built without `--features xla`.
        pub fn new(_prob: &EncodedProblem, dir: PathBuf) -> Result<Self> {
            bail!(
                "XlaEngine unavailable: built without the `xla` feature \
                 (artifacts dir {dir:?}); rebuild with `--features xla` \
                 and a vendored `xla` crate, or use `--engine native`"
            )
        }

        /// Problem dimension p (API parity with the real engine).
        pub fn dim(&self) -> usize {
            unreachable!("stub XlaEngine cannot be constructed")
        }
    }

    impl ComputeEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-stub"
        }

        fn worker_grad(&mut self, _worker: usize, _w: &[f64]) -> Result<(Vec<f64>, f64)> {
            unreachable!("stub XlaEngine cannot be constructed")
        }

        fn linesearch(&mut self, _worker: usize, _d: &[f64]) -> Result<f64> {
            unreachable!("stub XlaEngine cannot be constructed")
        }

        fn workers(&self) -> usize {
            unreachable!("stub XlaEngine cannot be constructed")
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaEngine;
