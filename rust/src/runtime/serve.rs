//! Multi-tenant serving: many optimization jobs over one resident
//! [`WorkerPool`].
//!
//! The paper's obliviousness contract means a coded cluster never needs
//! to know *which* problem a round belongs to — so one resident fleet can
//! host many ridge/MF jobs at once, the way a deployed parameter server
//! would (ROADMAP item 3). The pieces here:
//!
//! * [`JobServer`] — admits [`JobSpec`]s, stages each job's shards onto a
//!   shared pool ([`WorkerPool::stage_job`]), and interleaves the jobs'
//!   rounds one at a time under an admission [`Scheduler`]. Each job owns
//!   a private [`Cluster`] (its own delay RNG, scenario, park mirror) and
//!   a [`JobStep`] (its own iterate/trace), so under
//!   [`ClockMode::Virtual`](crate::cluster::ClockMode::Virtual) **any**
//!   serial interleaving produces per-job traces bitwise-identical to
//!   running each job alone — the determinism contract pinned by
//!   `rust/tests/serve_equivalence.rs`.
//! * [`ServePolicy`] — the `--serve-policy` grammar
//!   (`fifo | fair | priority:N`, strict parse ↔ Display round-trip like
//!   every other grammar in the repo).
//! * [`EncodedShardCache`] — encode-once cache for hyperparameter sweeps
//!   and repeated queries, keyed by the raw data's fingerprint plus every
//!   parameter the encoding depends on. `k` (the wait-for count) is
//!   deliberately **not** part of the key: encoding fixes `S` and the
//!   shard layout, while `k` only affects round admission — so a sweep
//!   over `k` is all cache hits.
//! * [`JobEngine`] — a per-job [`ComputeEngine`] view of the shared pool:
//!   every dispatch carries the job id, so rounds, park masks, and
//!   migrations route to the job's own slots.
//!
//! Fairness: under [`ServePolicy::Fair`] the scheduler round-robins over
//! unfinished jobs, so no job's dispatched-round count ever trails the
//! leader by more than one full sweep (a seeded property test in
//! `rust/tests/grammar_properties.rs` pins this).

use super::pool::WorkerPool;
use super::stream::{CurvCollector, GradCollector};
use super::{ComputeEngine, EngineSession};
use crate::cluster::{Cluster, ClusterConfig, Scenario};
use crate::encoding::EncoderKind;
use crate::linalg::{DataMat, GradMode, Precision, StorageKind};
use crate::optim::{
    CodedGd, CodedLbfgs, CodedSgd, GdConfig, JobStep, LbfgsConfig, RunOutput, SgdConfig,
    SteppedOptimizer,
};
use crate::problem::{BatchPlan, EncodedProblem, QuadProblem};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};
use std::time::Instant;

// ---------------------------------------------------------------------------
// ServePolicy
// ---------------------------------------------------------------------------

/// Admission-scheduling policy for a [`JobServer`] (`--serve-policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServePolicy {
    /// Run jobs to completion in submission order.
    Fifo,
    /// Round-robin one round per unfinished job (fair share).
    Fair,
    /// Strict priority with `classes` classes: class 0 is served first;
    /// a job's class is its [`JobSpec::priority`] clamped to
    /// `classes - 1`. Ties run in submission order to completion.
    Priority {
        /// Number of priority classes (≥ 1).
        classes: usize,
    },
}

impl ServePolicy {
    /// Parse the CLI/config grammar. This table is the single source of
    /// truth for `--serve-policy`:
    ///
    /// | variant | form | example |
    /// |---------|------|---------|
    /// | [`ServePolicy::Fifo`] | `fifo` | `fifo` |
    /// | [`ServePolicy::Fair`] | `fair` | `fair` |
    /// | [`ServePolicy::Priority`] | `priority:N` | `priority:3` |
    ///
    /// Anything else — unknown names, missing/extra fields, non-numeric
    /// or zero class counts — is rejected with a descriptive error.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        let head = parts[0].to_ascii_lowercase();
        match (head.as_str(), parts.len()) {
            ("fifo", 1) => Ok(ServePolicy::Fifo),
            ("fair", 1) => Ok(ServePolicy::Fair),
            ("priority", 2) => {
                let classes: usize = parts[1]
                    .parse()
                    .map_err(|e| anyhow!("serve policy {s:?}: class count: {e}"))?;
                ensure!(classes >= 1, "serve policy {s:?}: class count must be >= 1");
                Ok(ServePolicy::Priority { classes })
            }
            ("priority", 1) => {
                bail!("serve policy {s:?}: priority needs a class count (priority:N)")
            }
            _ => bail!("unknown serve policy {s:?} (fifo | fair | priority:N)"),
        }
    }
}

impl fmt::Display for ServePolicy {
    /// Canonical form; round-trips through [`ServePolicy::parse`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServePolicy::Fifo => write!(f, "fifo"),
            ServePolicy::Fair => write!(f, "fair"),
            ServePolicy::Priority { classes } => write!(f, "priority:{classes}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

/// One job's scheduling view (see [`Scheduler::next`]).
#[derive(Clone, Copy, Debug)]
pub struct SchedJob {
    /// The job has no rounds left (never picked again).
    pub done: bool,
    /// Priority class (only [`ServePolicy::Priority`] reads it).
    pub class: usize,
}

/// Pure admission scheduler: picks which unfinished job runs its next
/// round. Extracted from [`JobServer`] so the fairness property test can
/// drive it directly with synthetic job sets, no compute attached.
#[derive(Debug)]
pub struct Scheduler {
    policy: ServePolicy,
    /// Index the last round went to (fair round-robin cursor).
    last: Option<usize>,
}

impl Scheduler {
    /// A scheduler for `policy`, cursor at the start.
    pub fn new(policy: ServePolicy) -> Self {
        Scheduler { policy, last: None }
    }

    /// The policy this scheduler applies.
    pub fn policy(&self) -> ServePolicy {
        self.policy
    }

    /// Pick the next job index to run one round, or `None` when every job
    /// is done. Deterministic: a fixed `jobs` sequence always yields the
    /// same schedule (part of the serial-interleaving determinism
    /// contract).
    pub fn next(&mut self, jobs: &[SchedJob]) -> Option<usize> {
        let pick = match self.policy {
            ServePolicy::Fifo => jobs.iter().position(|j| !j.done),
            ServePolicy::Fair => {
                let n = jobs.len();
                if n == 0 {
                    None
                } else {
                    let start = self.last.map_or(0, |l| (l + 1) % n);
                    (0..n).map(|i| (start + i) % n).find(|&i| !jobs[i].done)
                }
            }
            ServePolicy::Priority { classes } => jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| !j.done)
                .min_by_key(|(i, j)| (j.class.min(classes - 1), *i))
                .map(|(i, _)| i),
        };
        if pick.is_some() {
            self.last = pick;
        }
        pick
    }
}

// ---------------------------------------------------------------------------
// EncodedShardCache
// ---------------------------------------------------------------------------

/// Cache key: everything [`EncodedProblem::encode_stored_prec`] depends
/// on. The fingerprint digests the raw data (`n`, `p`, `λ`, every matrix
/// and label entry, bit-exact); the rest are the encoding parameters plus
/// the shard precision and the requested grad mode. Grad mode is a key
/// component because [`EncodedProblem::with_grad_mode`] changes the
/// per-shard resolution (and therefore what engines stage — a Gram-mode
/// entry must never alias a gemv-mode one, and vice versa). `k` is
/// deliberately excluded — see the module docs.
type CacheKey = (u64, &'static str, u64, usize, u64, String, &'static str, &'static str);

/// Encode-once cache for served jobs: hyperparameter sweeps and repeated
/// queries over the same data reuse one [`EncodedProblem`] (shared via
/// `Arc`, so cached hits also skip the shard clone).
#[derive(Default)]
pub struct EncodedShardCache {
    map: HashMap<CacheKey, Arc<EncodedProblem>>,
    encodes: u64,
    hits: u64,
}

/// FNV-1a over a byte slice (seeded with the running hash).
fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

/// Bit-exact digest of a raw problem: two problems share a fingerprint
/// iff every data bit (and `λ`) matches, so a cache hit can never serve
/// the wrong shards.
pub fn fingerprint(prob: &QuadProblem) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv1a(&mut h, &(prob.x.rows() as u64).to_le_bytes());
    fnv1a(&mut h, &(prob.x.cols() as u64).to_le_bytes());
    fnv1a(&mut h, &prob.lambda.to_bits().to_le_bytes());
    match &prob.x {
        DataMat::Dense(m) => {
            for v in m.data() {
                fnv1a(&mut h, &v.to_bits().to_le_bytes());
            }
        }
        DataMat::Csr(c) => {
            for i in 0..prob.x.rows() {
                let (cols, vals) = c.row(i);
                for &j in cols {
                    fnv1a(&mut h, &j.to_le_bytes());
                }
                for v in vals {
                    fnv1a(&mut h, &v.to_bits().to_le_bytes());
                }
            }
        }
        DataMat::DenseF32(m) => {
            for i in 0..m.rows() {
                for v in m.row(i) {
                    fnv1a(&mut h, &v.to_bits().to_le_bytes());
                }
            }
        }
        DataMat::CsrF32(c) => {
            for i in 0..prob.x.rows() {
                let (cols, vals) = c.row(i);
                for &j in cols {
                    fnv1a(&mut h, &j.to_le_bytes());
                }
                for v in vals {
                    fnv1a(&mut h, &v.to_bits().to_le_bytes());
                }
            }
        }
    }
    for v in &prob.y {
        fnv1a(&mut h, &v.to_bits().to_le_bytes());
    }
    h
}

impl EncodedShardCache {
    /// An empty cache.
    pub fn new() -> Self {
        EncodedShardCache::default()
    }

    /// The encoded problem for `(prob, kind, beta, m, seed, storage)`
    /// at the default f64 shard precision, encoding at most once per
    /// distinct key.
    pub fn get_or_encode(
        &mut self,
        prob: &QuadProblem,
        kind: EncoderKind,
        beta: f64,
        m: usize,
        seed: u64,
        storage: StorageKind,
    ) -> Result<Arc<EncodedProblem>> {
        self.get_or_encode_prec(prob, kind, beta, m, seed, storage, Precision::F64)
    }

    /// As [`get_or_encode`](Self::get_or_encode), with an explicit shard
    /// precision. f64 and f32 encodes of the same problem are distinct
    /// cache entries (the f32 shards are narrowed copies, not views).
    /// Serves the default [`GradMode::Gemv`] resolution.
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_encode_prec(
        &mut self,
        prob: &QuadProblem,
        kind: EncoderKind,
        beta: f64,
        m: usize,
        seed: u64,
        storage: StorageKind,
        precision: Precision,
    ) -> Result<Arc<EncodedProblem>> {
        self.get_or_encode_mode(prob, kind, beta, m, seed, storage, precision, GradMode::Gemv)
    }

    /// As [`get_or_encode_prec`](Self::get_or_encode_prec), with an
    /// explicit worker-gradient strategy. Distinct grad modes of the same
    /// encode are distinct cache entries: a `gram`-keyed entry carries
    /// per-shard Gram resolution (and stages a `p×p` cache per shard in
    /// the engine), so it must never be served to a `gemv` request.
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_encode_mode(
        &mut self,
        prob: &QuadProblem,
        kind: EncoderKind,
        beta: f64,
        m: usize,
        seed: u64,
        storage: StorageKind,
        precision: Precision,
        grad_mode: GradMode,
    ) -> Result<Arc<EncodedProblem>> {
        let key: CacheKey = (
            fingerprint(prob),
            kind.label(),
            beta.to_bits(),
            m,
            seed,
            storage.to_string(),
            precision.label(),
            grad_mode.label(),
        );
        if let Some(enc) = self.map.get(&key) {
            self.hits += 1;
            return Ok(Arc::clone(enc));
        }
        let enc = EncodedProblem::encode_stored_prec(prob, kind, beta, m, seed, storage, precision)?
            .with_grad_mode(grad_mode)?;
        let enc = Arc::new(enc);
        self.encodes += 1;
        self.map.insert(key, Arc::clone(&enc));
        Ok(enc)
    }

    /// Number of actual encodes performed (cache misses).
    pub fn encodes(&self) -> u64 {
        self.encodes
    }

    /// Number of cache hits served.
    pub fn hits(&self) -> u64 {
        self.hits
    }
}

// ---------------------------------------------------------------------------
// JobEngine
// ---------------------------------------------------------------------------

/// A per-job [`ComputeEngine`] view of a shared [`WorkerPool`]: every
/// dispatch carries the job id, so rounds, park masks, and shard
/// migrations touch only this job's slots. Cheap to mint — the pool and
/// its resident lanes are shared behind the mutex.
pub struct JobEngine {
    pool: Arc<Mutex<WorkerPool>>,
    job: usize,
    p: usize,
    workers: usize,
}

impl JobEngine {
    /// Stage `prob` as job `job` on `pool` and return its engine view.
    pub fn stage(
        pool: Arc<Mutex<WorkerPool>>,
        job: usize,
        prob: &EncodedProblem,
    ) -> Result<JobEngine> {
        pool.lock().expect("serve pool lock poisoned").stage_job(job, prob)?;
        Ok(JobEngine { pool, job, p: prob.p(), workers: prob.m() })
    }

    /// The job id this engine routes to.
    pub fn job(&self) -> usize {
        self.job
    }

    fn pool(&self) -> std::sync::MutexGuard<'_, WorkerPool> {
        self.pool.lock().expect("serve pool lock poisoned")
    }
}

impl ComputeEngine for JobEngine {
    fn name(&self) -> &'static str {
        "serve-pool"
    }

    fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let job = self.job;
        self.pool().grad_one_for(job, worker, w)
    }

    fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64> {
        let job = self.job;
        self.pool().curv_one_for(job, worker, d)
    }

    fn worker_grad_all(&mut self, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
        let job = self.job;
        self.pool().grad_all_for(job, w)
    }

    fn linesearch_all(&mut self, d: &[f64]) -> Result<Vec<f64>> {
        let job = self.job;
        self.pool().curv_all_for(job, d)
    }

    fn worker_grad_streamed(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        let job = self.job;
        self.pool().grad_streamed_for(job, w, sink)
    }

    fn worker_grad_batch(
        &mut self,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        let job = self.job;
        self.pool().grad_batch_one_for(job, worker, w, segs)
    }

    fn worker_grad_batch_streamed(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
        sink: &GradCollector,
    ) -> Result<()> {
        let job = self.job;
        self.pool().grad_batch_streamed_for(job, w, plan, sink)
    }

    fn linesearch_streamed(&mut self, d: &[f64], sink: &CurvCollector) -> Result<()> {
        let job = self.job;
        self.pool().curv_streamed_for(job, d, sink)
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn session(&mut self) -> Option<&mut dyn EngineSession> {
        Some(self)
    }
}

impl EngineSession for JobEngine {
    fn set_parked(&mut self, worker: usize, parked: bool) {
        let job = self.job;
        self.pool().set_parked_for(job, worker, parked);
    }

    fn parked_count(&self) -> usize {
        self.pool().parked_count_for(self.job)
    }

    fn reconfigure(&mut self, prob: &EncodedProblem) -> Result<()> {
        let job = self.job;
        self.pool().stage_job(job, prob)?;
        self.p = prob.p();
        self.workers = prob.m();
        Ok(())
    }

    fn migrate_shards(&mut self, changed: &[(usize, crate::problem::WorkerShard)]) -> Result<()> {
        let (job, p) = (self.job, self.p);
        self.pool().migrate_for(job, p, changed)
    }

    fn spawn_count(&self) -> u64 {
        self.pool().spawn_count()
    }
}

// ---------------------------------------------------------------------------
// JobServer
// ---------------------------------------------------------------------------

/// Which optimizer a served job runs (the stepping-capable subset; FISTA
/// keeps a monolithic loop and is not served).
#[derive(Clone)]
pub enum ServeOptimizer {
    /// [`CodedGd`] with this config.
    Gd(GdConfig),
    /// [`CodedLbfgs`] with this config.
    Lbfgs(LbfgsConfig),
    /// [`CodedSgd`] with this config.
    Sgd(SgdConfig),
}

impl ServeOptimizer {
    /// Short label for tables/CSV names.
    pub fn label(&self) -> &'static str {
        match self {
            ServeOptimizer::Gd(_) => "gd",
            ServeOptimizer::Lbfgs(_) => "lbfgs",
            ServeOptimizer::Sgd(_) => "sgd",
        }
    }

    /// Build the job's round stepper (see [`SteppedOptimizer::stepper`]).
    pub fn stepper(
        &self,
        prob: &EncodedProblem,
        wait_for: usize,
        iters: usize,
        w0: Option<Vec<f64>>,
    ) -> Result<Box<dyn JobStep>> {
        match self {
            ServeOptimizer::Gd(cfg) => {
                CodedGd::new(cfg.clone()).stepper(prob, wait_for, iters, w0)
            }
            ServeOptimizer::Lbfgs(cfg) => {
                CodedLbfgs::new(cfg.clone()).stepper(prob, wait_for, iters, w0)
            }
            ServeOptimizer::Sgd(cfg) => {
                CodedSgd::new(cfg.clone()).stepper(prob, wait_for, iters, w0)
            }
        }
    }
}

/// Everything one served job needs: the (possibly cache-shared) encoded
/// problem, its private cluster config, the optimizer, and an optional
/// fault scenario scoped to this job alone.
pub struct JobSpec {
    /// Encoded problem (share via [`EncodedShardCache`] when sweeping).
    pub enc: Arc<EncodedProblem>,
    /// Per-job cluster config (its own delay RNG stream via `seed`).
    pub cluster: ClusterConfig,
    /// Optimizer + config.
    pub optimizer: ServeOptimizer,
    /// Iteration budget.
    pub iters: usize,
    /// Warm start (zeros if `None`).
    pub w0: Option<Vec<f64>>,
    /// Fault scenario scoped to this job (siblings never see it).
    pub scenario: Option<Scenario>,
    /// Priority class hint ([`ServePolicy::Priority`] only; 0 = highest).
    pub priority: usize,
}

/// One finished job's result.
pub struct ServeOutcome {
    /// Job id (as returned by [`JobServer::submit`]).
    pub job: usize,
    /// Final iterate + per-iteration trace (bitwise-identical to a solo
    /// run of the same spec under the virtual clock).
    pub output: RunOutput,
    /// Rounds this job was dispatched.
    pub rounds: usize,
    /// Wall-clock latency from [`JobServer::run`] start to this job's
    /// completion (the bench's p50/p99 source; 0 for empty jobs).
    pub wall_ms: f64,
}

/// One admitted job's runtime state.
struct ActiveJob {
    id: usize,
    priority: usize,
    enc: Arc<EncodedProblem>,
    cluster: Cluster,
    step: Option<Box<dyn JobStep>>,
    done: bool,
    rounds: usize,
    output: Option<RunOutput>,
    wall_ms: f64,
}

/// Hosts many concurrent optimization jobs on one resident [`WorkerPool`]
/// (module docs have the full contract).
pub struct JobServer {
    pool: Arc<Mutex<WorkerPool>>,
    scheduler: Scheduler,
    jobs: Vec<ActiveJob>,
    /// Job id of every dispatched round, in dispatch order.
    schedule: Vec<usize>,
    next_id: usize,
}

impl JobServer {
    /// A server over an existing shared pool.
    pub fn new(pool: Arc<Mutex<WorkerPool>>, policy: ServePolicy) -> Self {
        JobServer {
            pool,
            scheduler: Scheduler::new(policy),
            jobs: Vec::new(),
            schedule: Vec::new(),
            next_id: 1,
        }
    }

    /// A server over a fresh job-less pool with `threads` resident lanes
    /// (`0` = available parallelism).
    pub fn with_lanes(threads: usize, policy: ServePolicy) -> Self {
        JobServer::new(Arc::new(Mutex::new(WorkerPool::with_lanes(threads))), policy)
    }

    /// The shared pool (for staging siblings or inspecting spawn counts).
    pub fn pool(&self) -> Arc<Mutex<WorkerPool>> {
        Arc::clone(&self.pool)
    }

    /// Admit a job: stage its shards on the shared pool, build its
    /// private cluster and stepper, and queue it for scheduling. Returns
    /// the job id. A zero-iteration job completes (and its shards retire)
    /// immediately.
    pub fn submit(&mut self, spec: JobSpec) -> Result<usize> {
        let id = self.next_id;
        self.next_id += 1;
        let engine = JobEngine::stage(Arc::clone(&self.pool), id, &spec.enc)?;
        let mut cluster = Cluster::new(&spec.enc, Box::new(engine), spec.cluster.clone())?;
        if let Some(scenario) = spec.scenario {
            cluster.set_scenario(scenario)?;
        }
        let step = spec.optimizer.stepper(&spec.enc, spec.cluster.wait_for, spec.iters, spec.w0)?;
        let mut job = ActiveJob {
            id,
            priority: spec.priority,
            enc: spec.enc,
            cluster,
            step: Some(step),
            done: false,
            rounds: 0,
            output: None,
            wall_ms: 0.0,
        };
        if spec.iters == 0 {
            job.done = true;
            job.output = Some(job.step.take().expect("fresh stepper").output());
            self.pool.lock().expect("serve pool lock poisoned").retire(id)?;
        }
        self.jobs.push(job);
        Ok(id)
    }

    /// Run every admitted job to completion, one round at a time under
    /// the scheduler, retiring each job's shards as it finishes. Returns
    /// the outcomes in submission order and clears the job queue (the
    /// server and its pool stay usable for the next batch).
    pub fn run(&mut self) -> Result<Vec<ServeOutcome>> {
        let t0 = Instant::now();
        loop {
            let view: Vec<SchedJob> =
                self.jobs.iter().map(|j| SchedJob { done: j.done, class: j.priority }).collect();
            let Some(idx) = self.scheduler.next(&view) else { break };
            let job = &mut self.jobs[idx];
            let step = job.step.as_mut().expect("scheduled job has a stepper");
            let more = step.step(&job.enc, &mut job.cluster)?;
            self.schedule.push(job.id);
            job.rounds += 1;
            if !more {
                job.done = true;
                job.output = Some(job.step.take().expect("stepper present").output());
                job.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                self.pool.lock().expect("serve pool lock poisoned").retire(job.id)?;
            }
        }
        Ok(self
            .jobs
            .drain(..)
            .map(|j| ServeOutcome {
                job: j.id,
                output: j.output.expect("every drained job finished"),
                rounds: j.rounds,
                wall_ms: j.wall_ms,
            })
            .collect())
    }

    /// Job id of every dispatched round so far, in dispatch order (the
    /// serial interleaving the determinism contract quantifies over).
    pub fn schedule(&self) -> &[usize] {
        &self.schedule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClockMode, DelayModel};

    #[test]
    fn policy_parse_display_round_trip() {
        for s in ["fifo", "fair", "priority:1", "priority:4"] {
            let p = ServePolicy::parse(s).unwrap();
            assert_eq!(ServePolicy::parse(&p.to_string()).unwrap(), p);
            assert_eq!(p.to_string(), s);
        }
        assert_eq!(ServePolicy::parse("FIFO").unwrap(), ServePolicy::Fifo);
    }

    #[test]
    fn policy_rejects_malformed() {
        for bad in
            ["", ":", "fifo:1", "fair:2", "priority", "priority:", "priority:0", "priority:x"]
        {
            assert!(ServePolicy::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn fifo_runs_jobs_to_completion_in_order() {
        let mut s = Scheduler::new(ServePolicy::Fifo);
        let mut jobs = vec![SchedJob { done: false, class: 0 }; 2];
        assert_eq!(s.next(&jobs), Some(0));
        assert_eq!(s.next(&jobs), Some(0));
        jobs[0].done = true;
        assert_eq!(s.next(&jobs), Some(1));
        jobs[1].done = true;
        assert_eq!(s.next(&jobs), None);
    }

    #[test]
    fn fair_round_robins_and_skips_done() {
        let mut s = Scheduler::new(ServePolicy::Fair);
        let mut jobs = vec![SchedJob { done: false, class: 0 }; 3];
        assert_eq!(s.next(&jobs), Some(0));
        assert_eq!(s.next(&jobs), Some(1));
        assert_eq!(s.next(&jobs), Some(2));
        assert_eq!(s.next(&jobs), Some(0));
        jobs[1].done = true;
        assert_eq!(s.next(&jobs), Some(2));
        assert_eq!(s.next(&jobs), Some(0));
    }

    #[test]
    fn priority_serves_lower_class_first() {
        let mut s = Scheduler::new(ServePolicy::Priority { classes: 2 });
        let mut jobs = vec![
            SchedJob { done: false, class: 1 },
            SchedJob { done: false, class: 0 },
            // class clamps to classes - 1, tying with job 0
            SchedJob { done: false, class: 7 },
        ];
        assert_eq!(s.next(&jobs), Some(1));
        jobs[1].done = true;
        assert_eq!(s.next(&jobs), Some(0), "ties run in submission order");
    }

    #[test]
    fn cache_encodes_once_per_key() {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.05, 3);
        let mut cache = EncodedShardCache::new();
        let a = cache
            .get_or_encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2, StorageKind::Dense)
            .unwrap();
        let b = cache
            .get_or_encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2, StorageKind::Dense)
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second identical request must share the Arc");
        assert_eq!((cache.encodes(), cache.hits()), (1, 1));
        // a different encoding parameter is a different key
        cache
            .get_or_encode(&prob, EncoderKind::Hadamard, 2.0, 8, 3, StorageKind::Dense)
            .unwrap();
        assert_eq!((cache.encodes(), cache.hits()), (2, 1));
        // regression: both entry points must key identically — a
        // get_or_encode after a get_or_encode_prec(F64) of the same
        // request is a *hit* on the same Arc, never a second encode
        let c = cache
            .get_or_encode_prec(
                &prob,
                EncoderKind::Hadamard,
                2.0,
                8,
                2,
                StorageKind::Dense,
                Precision::F64,
            )
            .unwrap();
        assert!(
            Arc::ptr_eq(&a, &c),
            "get_or_encode and get_or_encode_prec(F64) diverged on the cache key"
        );
        assert_eq!((cache.encodes(), cache.hits()), (2, 2));
        // while an F32 encode of the same request is a distinct entry
        let f32_enc = cache
            .get_or_encode_prec(
                &prob,
                EncoderKind::Hadamard,
                2.0,
                8,
                2,
                StorageKind::Dense,
                Precision::F32,
            )
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &f32_enc));
        assert_eq!((cache.encodes(), cache.hits()), (3, 2));
        // a different problem (one bit of data) is a different key
        let mut prob2 = prob.clone();
        prob2.y[0] += 1e-9;
        assert_ne!(fingerprint(&prob), fingerprint(&prob2));
    }

    #[test]
    fn cache_keys_gram_and_gemv_entries_separately() {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.05, 3);
        let mut cache = EncodedShardCache::new();
        let gemv = cache
            .get_or_encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2, StorageKind::Dense)
            .unwrap();
        let gram = cache
            .get_or_encode_mode(
                &prob,
                EncoderKind::Hadamard,
                2.0,
                8,
                2,
                StorageKind::Dense,
                Precision::F64,
                GradMode::Gram,
            )
            .unwrap();
        assert!(
            !Arc::ptr_eq(&gemv, &gram),
            "a gram-keyed entry must never alias the gemv entry of the same encode"
        );
        assert_eq!((cache.encodes(), cache.hits()), (2, 0));
        assert_eq!(gemv.grad_mode, GradMode::Gemv);
        assert_eq!(gram.grad_mode, GradMode::Gram);
        assert!(gram.shards.iter().all(|s| s.grad_mode == GradMode::Gram));
        assert!(
            gram.shard_mem_bytes() > gemv.shard_mem_bytes(),
            "gram entries must report their cache in shard_mem_bytes"
        );
        // and each repeat request hits its own entry
        let gram2 = cache
            .get_or_encode_mode(
                &prob,
                EncoderKind::Hadamard,
                2.0,
                8,
                2,
                StorageKind::Dense,
                Precision::F64,
                GradMode::Gram,
            )
            .unwrap();
        assert!(Arc::ptr_eq(&gram, &gram2));
        assert_eq!((cache.encodes(), cache.hits()), (2, 1));
    }

    #[test]
    fn served_gd_job_matches_solo_run() {
        use crate::optim::Optimizer;
        use crate::runtime::NativeEngine;
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.05, 3);
        let enc =
            Arc::new(EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap());
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: 6,
            delay: DelayModel::Exp { mean_ms: 10.0 },
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5,
            seed: 11,
        };
        let mut server = JobServer::with_lanes(2, ServePolicy::Fifo);
        let id = server
            .submit(JobSpec {
                enc: Arc::clone(&enc),
                cluster: cfg.clone(),
                optimizer: ServeOptimizer::Gd(GdConfig { epsilon: Some(0.3), ..Default::default() }),
                iters: 10,
                w0: None,
                scenario: None,
                priority: 0,
            })
            .unwrap();
        let outcomes = server.run().unwrap();
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].job, id);
        assert_eq!(outcomes[0].rounds, 10);
        assert_eq!(server.schedule(), vec![id; 10]);
        let gd = CodedGd::new(GdConfig { epsilon: Some(0.3), ..Default::default() });
        let eng = Box::new(NativeEngine::new(&enc));
        let mut solo = Cluster::new(&enc, eng, cfg).unwrap();
        let solo_out = gd.run(&enc, &mut solo, 10).unwrap();
        assert_eq!(outcomes[0].output.trace.to_csv(), solo_out.trace.to_csv());
        for (a, b) in outcomes[0].output.w.iter().zip(&solo_out.w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_job_completes_at_submit() {
        let prob = QuadProblem::synthetic_gaussian(32, 4, 0.0, 1);
        let enc =
            Arc::new(EncodedProblem::encode(&prob, EncoderKind::Identity, 1.0, 4, 0).unwrap());
        let mut server = JobServer::with_lanes(1, ServePolicy::Fair);
        server
            .submit(JobSpec {
                enc,
                cluster: ClusterConfig {
                    workers: 4,
                    wait_for: 4,
                    delay: DelayModel::None,
                    clock: ClockMode::Virtual,
                    ms_per_mflop: 0.5,
                    seed: 0,
                },
                optimizer: ServeOptimizer::Gd(GdConfig { epsilon: Some(0.0), ..Default::default() }),
                iters: 0,
                w0: None,
                scenario: None,
                priority: 0,
            })
            .unwrap();
        let outcomes = server.run().unwrap();
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].rounds, 0);
        assert!(outcomes[0].output.trace.records.is_empty());
        assert!(server.schedule().is_empty());
    }
}
