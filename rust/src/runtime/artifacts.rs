//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` plus one
//! `<name>.hlo.txt` per shape-specialized executable. This module parses
//! the manifest (with the in-tree JSON parser — no serde offline) and
//! resolves the artifact for a requested kind/shape.

use crate::config::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One artifact entry (shape-specialized HLO text program).
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    /// Unique artifact name (e.g. `worker_grad_r32_p64`).
    pub name: String,
    /// Artifact kind: `worker_grad`, `linesearch`, or `fwht`.
    pub kind: String,
    /// File name relative to the manifest directory.
    pub file: String,
    /// worker_grad / linesearch: (rows, p); fwht: (n, cols).
    pub dims: (usize, usize),
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// All artifact entries.
    pub entries: Vec<Entry>,
}

/// Default artifact directory: `$CODEDOPT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("CODEDOPT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        match v.get("format").and_then(Json::as_str) {
            Some("hlo-text-v1") => {}
            other => bail!("unsupported manifest format {other:?}"),
        }
        let Some(arr) = v.get("entries").and_then(Json::as_arr) else {
            bail!("manifest.json: missing entries array");
        };
        let mut entries = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| format!("entry {i}: missing name"))?
                .to_string();
            let kind = e
                .get("kind")
                .and_then(Json::as_str)
                .with_context(|| format!("entry {i}: missing kind"))?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("entry {i}: missing file"))?
                .to_string();
            let dims = match kind.as_str() {
                "worker_grad" | "linesearch" => (
                    e.get("rows").and_then(Json::as_usize).context("rows")?,
                    e.get("p").and_then(Json::as_usize).context("p")?,
                ),
                "fwht" => (
                    e.get("n").and_then(Json::as_usize).context("n")?,
                    e.get("cols").and_then(Json::as_usize).context("cols")?,
                ),
                other => bail!("entry {i}: unknown kind {other:?}"),
            };
            entries.push(Entry { name, kind, file, dims });
        }
        Ok(Manifest { dir, entries })
    }

    /// Artifact path for an exact kind + dims match.
    pub fn find(&self, kind: &str, dims: (usize, usize)) -> Option<PathBuf> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.dims == dims)
            .map(|e| self.dir.join(&e.file))
    }

    /// Smallest worker_grad row bucket that fits `rows` at dimension `p`
    /// (shards are zero-padded up to it). None if no bucket covers it.
    pub fn grad_bucket(&self, rows: usize, p: usize) -> Option<usize> {
        self.entries
            .iter()
            .filter(|e| e.kind == "worker_grad" && e.dims.1 == p && e.dims.0 >= rows)
            .map(|e| e.dims.0)
            .min()
    }

    /// All (rows, p) worker_grad shapes available.
    pub fn grad_shapes(&self) -> Vec<(usize, usize)> {
        self.entries
            .iter()
            .filter(|e| e.kind == "worker_grad")
            .map(|e| e.dims)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("codedopt-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "entries": [
        {"name": "worker_grad_r8_p4", "kind": "worker_grad", "file": "worker_grad_r8_p4.hlo.txt", "rows": 8, "p": 4},
        {"name": "worker_grad_r32_p4", "kind": "worker_grad", "file": "worker_grad_r32_p4.hlo.txt", "rows": 32, "p": 4},
        {"name": "linesearch_r8_p4", "kind": "linesearch", "file": "linesearch_r8_p4.hlo.txt", "rows": 8, "p": 4},
        {"name": "fwht_n64_c8", "kind": "fwht", "file": "fwht_n64_c8.hlo.txt", "n": 64, "cols": 8}
      ]
    }"#;

    #[test]
    fn load_and_query() {
        let dir = tmpdir("load");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 4);
        assert!(m.find("worker_grad", (8, 4)).is_some());
        assert!(m.find("worker_grad", (16, 4)).is_none());
        assert_eq!(m.grad_bucket(5, 4), Some(8));
        assert_eq!(m.grad_bucket(9, 4), Some(32));
        assert_eq!(m.grad_bucket(33, 4), None);
        assert_eq!(m.grad_bucket(8, 5), None);
        assert_eq!(m.grad_shapes().len(), 2);
    }

    #[test]
    fn missing_manifest_is_friendly_error() {
        let dir = tmpdir("missing");
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "err: {err}");
    }

    #[test]
    fn rejects_unknown_format() {
        let dir = tmpdir("badformat");
        write_manifest(&dir, r#"{"format": "v999", "entries": []}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let dir = tmpdir("badkind");
        write_manifest(
            &dir,
            r#"{"format": "hlo-text-v1", "entries": [{"name": "x", "kind": "mystery", "file": "x", "rows": 1, "p": 1}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }
}
