//! Pure-Rust compute engine: a thin client of the persistent
//! [`WorkerPool`](super::pool::WorkerPool).
//!
//! Historically this engine re-entered `std::thread::scope` for every
//! round (five spawn sites); it is now stateless glue: construction
//! stages the shards, the first dispatch moves them into a resident
//! worker pool (one spawn, ever), and every [`ComputeEngine`] method is a
//! command dispatch to the pool's shard-owning lanes. Round semantics —
//! per-worker timing, delivery order within a lane, cancellation checks
//! before each shard — are identical to the scoped-spawn engine, which is
//! pinned bit-for-bit by `rust/tests/pool_equivalence.rs`.
//!
//! The engine also implements the stateful [`EngineSession`] surface:
//! scenario crashes park resident workers instead of wasting their
//! compute, and [`EngineSession::reconfigure`] swaps the staged problem
//! without respawning threads (the MF trainer reuses one pool across
//! thousands of subproblem solves).

use super::pool::{Slot, WorkerPool};
use super::stream::{CurvCollector, GradCollector};
use super::{ComputeEngine, EngineSession};
use crate::problem::{BatchPlan, EncodedProblem};
use anyhow::Result;

/// Staged-or-running pool state. Staging is lazy so `with_threads` can
/// size the pool before any thread exists, and so the many short-lived
/// engines constructed by tests/benches spawn nothing until first use.
enum State {
    /// Shards staged, pool not yet spawned.
    Staged { slots: Vec<Slot>, threads: usize },
    /// Resident pool running.
    Running(WorkerPool),
}

/// Fused-kernel engine over the persistent worker pool.
pub struct NativeEngine {
    state: State,
    p: usize,
    workers: usize,
}

impl NativeEngine {
    /// Stage every shard of `prob` (data + preallocated scratch buffers).
    /// The pool itself spawns on first dispatch.
    pub fn new(prob: &EncodedProblem) -> Self {
        NativeEngine {
            state: State::Staged { slots: Slot::stage(prob), threads: 0 },
            p: prob.p(),
            workers: prob.m(),
        }
    }

    /// Cap the pool size (at most `min(threads, m)` lanes; `0` =
    /// available parallelism, the same sentinel [`WorkerPool::new`] and
    /// the `--threads` flag use). Must be called before the first dispatch —
    /// the pool spawns once and its lane count is fixed for the engine's
    /// lifetime.
    pub fn with_threads(mut self, threads: usize) -> Self {
        match &mut self.state {
            State::Staged { threads: t, .. } => *t = threads,
            State::Running(_) => {
                panic!("with_threads must be called before the engine's first dispatch")
            }
        }
        self
    }

    /// The resident pool, spawning it from the staged shards on first use.
    fn pool(&mut self) -> &mut WorkerPool {
        if let State::Staged { slots, threads } = &mut self.state {
            let pool = WorkerPool::from_slots(std::mem::take(slots), *threads);
            self.state = State::Running(pool);
        }
        match &mut self.state {
            State::Running(pool) => pool,
            State::Staged { .. } => unreachable!("pool just spawned"),
        }
    }

    /// Problem dimension p.
    pub fn dim(&self) -> usize {
        self.p
    }

    /// Resident lane count (spawns the pool if still staged).
    pub fn pool_size(&mut self) -> usize {
        self.pool().size()
    }

    /// Broadcast-slab acquisition counters `(reused, fresh)` — how many
    /// round broadcasts recycled a reclaimed `Arc<[f64]>` vs allocated
    /// one (spawns the pool if still staged; benches read this to pin
    /// the steady-state recycling rate).
    pub fn broadcast_buffer_stats(&mut self) -> (u64, u64) {
        self.pool().broadcast_buffer_stats()
    }
}

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.pool().grad_one(worker, w)
    }

    fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64> {
        self.pool().curv_one(worker, d)
    }

    fn worker_grad_all(&mut self, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
        self.pool().grad_all(w)
    }

    fn linesearch_all(&mut self, d: &[f64]) -> Result<Vec<f64>> {
        self.pool().curv_all(d)
    }

    /// One pool command per resident lane; each lane walks its owned
    /// shard range, timing and delivering every worker individually and
    /// checking the cancellation flag before each shard (the exact
    /// semantics of the historical one-scoped-thread-per-chunk fan-out,
    /// minus the per-round spawns).
    fn worker_grad_streamed(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        self.pool().grad_streamed(w, sink)
    }

    /// Deferred pool fan-out: the lanes receive the round's commands but
    /// their acknowledgements are queued instead of awaited, so the
    /// leader can retire the round at its k-th admission
    /// (`wait_cancelled_snapshot`) while straggler lanes finish in the
    /// background. Retired by [`ComputeEngine::drain_dispatch_to`].
    fn worker_grad_dispatch(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        self.pool().grad_deferred(w, sink)
    }

    fn drain_dispatch_to(&mut self, max_in_flight: usize) -> Result<()> {
        self.pool().drain_deferred_to(max_in_flight)
    }

    fn worker_grad_batch(
        &mut self,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        self.pool().grad_batch_one(worker, w, segs)
    }

    /// Streamed mini-batch gradient rounds; same dispatch shape as
    /// [`ComputeEngine::worker_grad_streamed`], with each lane running
    /// the range-restricted fused kernel over its [`BatchPlan`] segments.
    fn worker_grad_batch_streamed(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
        sink: &GradCollector,
    ) -> Result<()> {
        self.pool().grad_batch_streamed(w, plan, sink)
    }

    /// Streamed line-search rounds; same dispatch shape as
    /// [`ComputeEngine::worker_grad_streamed`].
    fn linesearch_streamed(&mut self, d: &[f64], sink: &CurvCollector) -> Result<()> {
        self.pool().curv_streamed(d, sink)
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn session(&mut self) -> Option<&mut dyn EngineSession> {
        Some(self)
    }
}

impl EngineSession for NativeEngine {
    fn set_parked(&mut self, worker: usize, parked: bool) {
        self.pool().set_parked(worker, parked);
    }

    fn parked_count(&self) -> usize {
        match &self.state {
            State::Staged { .. } => 0,
            State::Running(pool) => pool.parked().iter().filter(|&&x| x).count(),
        }
    }

    fn reconfigure(&mut self, prob: &EncodedProblem) -> Result<()> {
        // swap the staged state first: a failed swap (dead lane) must not
        // leave the engine advertising the new problem's dimensions
        match &mut self.state {
            State::Staged { slots, .. } => *slots = Slot::stage(prob),
            State::Running(pool) => pool.reconfigure(prob)?,
        }
        self.p = prob.p();
        self.workers = prob.m();
        Ok(())
    }

    fn spawn_count(&self) -> u64 {
        match &self.state {
            State::Staged { .. } => 0,
            State::Running(pool) => pool.spawn_count(),
        }
    }

    fn migrate_shards(&mut self, changed: &[(usize, crate::problem::WorkerShard)]) -> Result<()> {
        let p = self.p;
        match &mut self.state {
            State::Staged { slots, .. } => {
                for (w, shard) in changed {
                    anyhow::ensure!(*w < slots.len(), "migrate: worker id {w} out of range");
                    slots[*w] = Slot::stage_shard(shard, p);
                }
                Ok(())
            }
            State::Running(pool) => pool.migrate(p, changed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::EncoderKind;
    use crate::linalg;
    use crate::problem::QuadProblem;

    fn engine() -> (EncodedProblem, NativeEngine) {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let eng = NativeEngine::new(&enc);
        (enc, eng)
    }

    #[test]
    fn grad_matches_direct_computation() {
        let (enc, mut eng) = engine();
        let w = vec![0.3; 6];
        for i in 0..8 {
            let (g, f) = eng.worker_grad(i, &w).unwrap();
            let s = &enc.shards[i];
            let resid = linalg::sub(&s.x.gemv(&w), &s.y);
            let g_ref = s.x.gemv_t(&resid);
            let f_ref = linalg::dot(&resid, &resid);
            assert!((f - f_ref).abs() < 1e-10);
            for (a, b) in g.iter().zip(&g_ref) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn parallel_all_matches_serial() {
        let (_, mut eng) = engine();
        let w = vec![0.1; 6];
        let par = eng.worker_grad_all(&w).unwrap();
        let ser: Vec<_> = (0..8).map(|i| eng.worker_grad(i, &w).unwrap()).collect();
        assert_eq!(par.len(), ser.len());
        for ((gp, fp), (gs, fs)) in par.iter().zip(&ser) {
            assert!((fp - fs).abs() < 1e-12);
            for (a, b) in gp.iter().zip(gs) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn linesearch_matches_direct() {
        let (enc, mut eng) = engine();
        let d = vec![-0.2; 6];
        let all = eng.linesearch_all(&d).unwrap();
        for i in 0..8 {
            let xd = enc.shards[i].x.gemv(&d);
            assert!((all[i] - linalg::dot(&xd, &xd)).abs() < 1e-10);
        }
    }

    #[test]
    fn single_thread_mode_works() {
        let (_, eng) = engine();
        let mut eng = eng.with_threads(1);
        let w = vec![0.4; 6];
        let out = eng.worker_grad_all(&w).unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(eng.pool_size(), 1);
    }

    #[test]
    fn streamed_payloads_match_batch_bitwise() {
        let (_, mut eng) = engine();
        let w = vec![0.7; 6];
        let batch = eng.worker_grad_all(&w).unwrap();
        let sink = GradCollector::collect_all(8);
        eng.worker_grad_streamed(&w, &sink).unwrap();
        let got = sink.into_collected();
        assert_eq!(got.delivery_order.len(), 8);
        for (i, (gb, fb)) in batch.iter().enumerate() {
            let (ref payload, ms) = *got.responses[i].as_ref().unwrap();
            let (gs, fs) = payload;
            assert_eq!(fs.to_bits(), fb.to_bits(), "worker {i} objective differs");
            assert_eq!(gs.len(), gb.len());
            for (a, b) in gs.iter().zip(gb) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i} gradient differs");
            }
            assert!(ms >= 0.0);
        }
    }

    #[test]
    fn batch_grad_full_segments_match_full_grad_bitwise() {
        let (enc, mut eng) = engine();
        let w = vec![0.2; 6];
        for i in 0..8 {
            let (g_full, f_full) = eng.worker_grad(i, &w).unwrap();
            let rows = enc.shards[i].rows_real;
            let (g_b, f_b) = eng.worker_grad_batch(i, &w, &[(0, rows)]).unwrap();
            // real rows only vs padded full shard: padding rows are exact
            // zero contributions, so the sums agree to machine identity
            assert_eq!(f_full.to_bits(), f_b.to_bits(), "worker {i}");
            for (a, b) in g_full.iter().zip(&g_b) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i}");
            }
        }
    }

    #[test]
    fn batch_streamed_matches_per_worker_batch() {
        let (enc, mut eng) = engine();
        let w = vec![-0.4; 6];
        let mut rng = crate::rng::Pcg64::seeded(11);
        let plan = enc.sample_batch(0.4, &mut rng);
        let expected: Vec<_> = (0..8)
            .map(|i| eng.worker_grad_batch(i, &w, &plan.segments[i]).unwrap())
            .collect();
        let sink = GradCollector::collect_all(8);
        eng.worker_grad_batch_streamed(&w, &plan, &sink).unwrap();
        let got = sink.into_collected();
        for (i, (ge, fe)) in expected.iter().enumerate() {
            let (ref payload, ms) = *got.responses[i].as_ref().unwrap();
            let (gs, fs) = payload;
            assert_eq!(fs.to_bits(), fe.to_bits(), "worker {i}");
            for (a, b) in gs.iter().zip(ge) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i}");
            }
            assert!(ms >= 0.0);
        }
    }

    #[test]
    fn sparse_shards_match_dense_engine_bitwise() {
        // storage obliviousness at the engine boundary: identical worker
        // payloads, dense vs CSR shards, down to the last bit
        use crate::linalg::StorageKind;
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let make = |storage| {
            EncodedProblem::encode_stored(&prob, EncoderKind::Identity, 1.0, 8, 2, storage)
                .unwrap()
        };
        let (dense_enc, sparse_enc) = (make(StorageKind::Dense), make(StorageKind::Sparse));
        assert!(sparse_enc.shards.iter().all(|s| s.x.is_sparse()));
        let mut ed = NativeEngine::new(&dense_enc);
        let mut es = NativeEngine::new(&sparse_enc);
        let w = vec![0.3; 6];
        for i in 0..8 {
            let (gd, fd) = ed.worker_grad(i, &w).unwrap();
            let (gs, fs) = es.worker_grad(i, &w).unwrap();
            assert_eq!(fd.to_bits(), fs.to_bits(), "worker {i} objective");
            for (a, b) in gd.iter().zip(&gs) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i} gradient");
            }
            let qd = ed.linesearch(i, &w).unwrap();
            let qs = es.linesearch(i, &w).unwrap();
            assert_eq!(qd.to_bits(), qs.to_bits(), "worker {i} curvature");
            let rows = dense_enc.shards[i].rows_real;
            let (gbd, fbd) = ed.worker_grad_batch(i, &w, &[(2, rows.min(5))]).unwrap();
            let (gbs, fbs) = es.worker_grad_batch(i, &w, &[(2, rows.min(5))]).unwrap();
            assert_eq!(fbd.to_bits(), fbs.to_bits(), "worker {i} batch objective");
            for (a, b) in gbd.iter().zip(&gbs) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i} batch gradient");
            }
        }
    }

    #[test]
    fn streamed_linesearch_matches_batch_bitwise() {
        let (_, mut eng) = engine();
        let d = vec![-0.3; 6];
        let batch = eng.linesearch_all(&d).unwrap();
        let sink = CurvCollector::collect_all(8);
        eng.linesearch_streamed(&d, &sink).unwrap();
        let got = sink.into_collected();
        for (i, qb) in batch.iter().enumerate() {
            let (qs, _) = got.responses[i].unwrap();
            assert_eq!(qs.to_bits(), qb.to_bits(), "worker {i} curvature differs");
        }
    }

    #[test]
    fn session_parks_and_reconfigures_in_place() {
        let (_, mut eng) = engine();
        let w = vec![0.1; 6];
        eng.worker_grad_all(&w).unwrap();
        let spawned = {
            let sess = eng.session().expect("native engine has a session");
            sess.set_parked(5, true);
            assert_eq!(sess.parked_count(), 1);
            sess.spawn_count()
        };
        assert!(spawned > 0);
        let sink = GradCollector::collect_all(8);
        eng.worker_grad_streamed(&w, &sink).unwrap();
        assert!(sink.into_collected().responses[5].is_none());
        // reconfigure onto a different problem, keeping the threads
        let prob2 = QuadProblem::synthetic_gaussian(48, 5, 0.1, 4);
        let enc2 = EncodedProblem::encode(&prob2, EncoderKind::Identity, 1.0, 4, 0).unwrap();
        eng.session().unwrap().reconfigure(&enc2).unwrap();
        assert_eq!(eng.workers(), 4);
        assert_eq!(eng.dim(), 5);
        assert_eq!(eng.session().unwrap().spawn_count(), spawned);
        let mut fresh = NativeEngine::new(&enc2);
        let w2 = vec![0.2; 5];
        let a = eng.worker_grad_all(&w2).unwrap();
        let b = fresh.worker_grad_all(&w2).unwrap();
        for ((ga, fa), (gb, fb)) in a.iter().zip(&b) {
            assert_eq!(fa.to_bits(), fb.to_bits());
            for (x, y) in ga.iter().zip(gb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn session_migrates_shards_in_both_states_without_respawn() {
        let (enc, mut eng) = engine();
        let w = vec![0.2; 6];
        // staged state: migrate before the pool exists
        eng.session().unwrap().migrate_shards(&[(0, enc.shards[7].clone())]).unwrap();
        let (g0, f0) = eng.worker_grad(0, &w).unwrap();
        let (g7, f7) = eng.worker_grad(7, &w).unwrap();
        assert_eq!(f0.to_bits(), f7.to_bits());
        for (a, b) in g0.iter().zip(&g7) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // running state: migrate through the resident pool, no respawn
        let spawned = eng.session().unwrap().spawn_count();
        assert!(spawned > 0);
        eng.session().unwrap().migrate_shards(&[(2, enc.shards[1].clone())]).unwrap();
        assert_eq!(eng.session().unwrap().spawn_count(), spawned);
        let (g2, f2) = eng.worker_grad(2, &w).unwrap();
        let (g1, f1) = eng.worker_grad(1, &w).unwrap();
        assert_eq!(f2.to_bits(), f1.to_bits());
        for (a, b) in g2.iter().zip(&g1) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn staged_engine_spawns_nothing_until_first_dispatch() {
        let (_, mut eng) = engine();
        assert_eq!(eng.session().unwrap().spawn_count(), 0, "staging must not spawn");
        eng.worker_grad(0, &[0.0; 6]).unwrap();
        assert!(eng.session().unwrap().spawn_count() > 0);
    }
}
