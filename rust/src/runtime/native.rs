//! Pure-Rust compute engine: the fused worker kernels on std threads.
//!
//! Two fan-out shapes:
//! * `worker_grad_all` / `linesearch_all` — batch: shards are chunked over
//!   a bounded thread pool, all results returned together.
//! * `worker_grad_streamed` / `linesearch_streamed` — streaming: one
//!   scoped thread per worker shard (capped at the engine's thread
//!   bound), each delivering into the round's
//!   [`Collector`](super::stream::Collector) the moment a shard finishes,
//!   with that worker's own wall-clock compute time; threads observe the
//!   collector's cancellation flag and skip remaining shards once the
//!   leader has admitted k responses.

use super::stream::{CurvCollector, GradCollector};
use super::ComputeEngine;
use crate::linalg::{self, DataMat};
use crate::problem::{BatchPlan, EncodedProblem};
use anyhow::Result;

/// One worker's staged data + scratch (no allocation on the hot path).
/// The shard keeps whatever storage backend the partitioner produced —
/// the fused kernels are storage-dispatched inside [`DataMat`].
struct Slot {
    x: DataMat,
    y: Vec<f64>,
    grad_buf: Vec<f64>,
    resid_buf: Vec<f64>,
}

/// Fused-kernel engine; `worker_grad_all` fans out over std threads.
pub struct NativeEngine {
    slots: Vec<Slot>,
    p: usize,
    threads: usize,
}

impl NativeEngine {
    /// Stage every shard of `prob` (data + preallocated scratch buffers).
    pub fn new(prob: &EncodedProblem) -> Self {
        let p = prob.p();
        let slots = prob
            .shards
            .iter()
            .map(|s| Slot {
                x: s.x.clone(),
                y: s.y.clone(),
                grad_buf: vec![0.0; p],
                resid_buf: vec![0.0; s.x.rows()],
            })
            .collect();
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NativeEngine { slots, p, threads }
    }

    /// Cap the fan-out thread count (bench/tuning hook).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }
}

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn worker_grad(&mut self, worker: usize, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let slot = &mut self.slots[worker];
        let f = slot.x.fused_grad(w, &slot.y, &mut slot.grad_buf, &mut slot.resid_buf);
        Ok((slot.grad_buf.clone(), f))
    }

    fn linesearch(&mut self, worker: usize, d: &[f64]) -> Result<f64> {
        let slot = &mut self.slots[worker];
        slot.x.gemv_into(d, &mut slot.resid_buf);
        Ok(linalg::dot(&slot.resid_buf, &slot.resid_buf))
    }

    fn worker_grad_all(&mut self, w: &[f64]) -> Result<Vec<(Vec<f64>, f64)>> {
        let threads = self.threads.min(self.slots.len()).max(1);
        if threads == 1 {
            return (0..self.slots.len()).map(|i| self.worker_grad(i, w)).collect();
        }
        let mut out: Vec<(Vec<f64>, f64)> = Vec::with_capacity(self.slots.len());
        let chunk = self.slots.len().div_ceil(threads);
        let results: Vec<Vec<(Vec<f64>, f64)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .slots
                .chunks_mut(chunk)
                .map(|slots| {
                    scope.spawn(move || {
                        slots
                            .iter_mut()
                            .map(|slot| {
                                let f = slot.x.fused_grad(
                                    w,
                                    &slot.y,
                                    &mut slot.grad_buf,
                                    &mut slot.resid_buf,
                                );
                                (slot.grad_buf.clone(), f)
                            })
                            .collect()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        for r in results {
            out.extend(r);
        }
        Ok(out)
    }

    fn linesearch_all(&mut self, d: &[f64]) -> Result<Vec<f64>> {
        let threads = self.threads.min(self.slots.len()).max(1);
        if threads == 1 {
            return (0..self.slots.len()).map(|i| self.linesearch(i, d)).collect();
        }
        let chunk = self.slots.len().div_ceil(threads);
        let results: Vec<Vec<f64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .slots
                .chunks_mut(chunk)
                .map(|slots| {
                    scope.spawn(move || {
                        slots
                            .iter_mut()
                            .map(|slot| {
                                slot.x.gemv_into(d, &mut slot.resid_buf);
                                linalg::dot(&slot.resid_buf, &slot.resid_buf)
                            })
                            .collect()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        Ok(results.into_iter().flatten().collect())
    }

    /// One scoped thread per worker shard, capped at the engine's thread
    /// bound ([`NativeEngine::with_threads`]): with fewer threads than
    /// shards, each thread walks a contiguous shard range, still timing
    /// and delivering every worker individually and checking the
    /// cancellation flag before each shard.
    fn worker_grad_streamed(&mut self, w: &[f64], sink: &GradCollector) -> Result<()> {
        let threads = self.threads.min(self.slots.len()).max(1);
        let chunk = self.slots.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for (ci, slots) in self.slots.chunks_mut(chunk).enumerate() {
                scope.spawn(move || {
                    for (j, slot) in slots.iter_mut().enumerate() {
                        if sink.is_cancelled() {
                            return;
                        }
                        let t0 = std::time::Instant::now();
                        let f = slot.x.fused_grad(
                            w,
                            &slot.y,
                            &mut slot.grad_buf,
                            &mut slot.resid_buf,
                        );
                        let ms = t0.elapsed().as_secs_f64() * 1e3;
                        sink.deliver(ci * chunk + j, (slot.grad_buf.clone(), f), ms);
                    }
                });
            }
        });
        Ok(())
    }

    fn worker_grad_batch(
        &mut self,
        worker: usize,
        w: &[f64],
        segs: &[(usize, usize)],
    ) -> Result<(Vec<f64>, f64)> {
        let slot = &mut self.slots[worker];
        slot.grad_buf.fill(0.0);
        let mut f = 0.0;
        for &(lo, hi) in segs {
            f += slot
                .x
                .fused_grad_range(w, &slot.y, &mut slot.grad_buf, &mut slot.resid_buf, lo, hi);
        }
        Ok((slot.grad_buf.clone(), f))
    }

    /// Streamed mini-batch gradient rounds; same fan-out shape as
    /// [`ComputeEngine::worker_grad_streamed`], with each worker running
    /// the range-restricted fused kernel over its [`BatchPlan`] segments.
    fn worker_grad_batch_streamed(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
        sink: &GradCollector,
    ) -> Result<()> {
        assert_eq!(plan.workers(), self.slots.len(), "batch plan worker count mismatch");
        let threads = self.threads.min(self.slots.len()).max(1);
        let chunk = self.slots.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for (ci, slots) in self.slots.chunks_mut(chunk).enumerate() {
                scope.spawn(move || {
                    for (j, slot) in slots.iter_mut().enumerate() {
                        if sink.is_cancelled() {
                            return;
                        }
                        let wid = ci * chunk + j;
                        let t0 = std::time::Instant::now();
                        slot.grad_buf.fill(0.0);
                        let mut f = 0.0;
                        for &(lo, hi) in &plan.segments[wid] {
                            f += slot.x.fused_grad_range(
                                w,
                                &slot.y,
                                &mut slot.grad_buf,
                                &mut slot.resid_buf,
                                lo,
                                hi,
                            );
                        }
                        let ms = t0.elapsed().as_secs_f64() * 1e3;
                        sink.deliver(wid, (slot.grad_buf.clone(), f), ms);
                    }
                });
            }
        });
        Ok(())
    }

    /// Streamed line-search rounds; same fan-out shape as
    /// [`ComputeEngine::worker_grad_streamed`].
    fn linesearch_streamed(&mut self, d: &[f64], sink: &CurvCollector) -> Result<()> {
        let threads = self.threads.min(self.slots.len()).max(1);
        let chunk = self.slots.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for (ci, slots) in self.slots.chunks_mut(chunk).enumerate() {
                scope.spawn(move || {
                    for (j, slot) in slots.iter_mut().enumerate() {
                        if sink.is_cancelled() {
                            return;
                        }
                        let t0 = std::time::Instant::now();
                        slot.x.gemv_into(d, &mut slot.resid_buf);
                        let q = linalg::dot(&slot.resid_buf, &slot.resid_buf);
                        let ms = t0.elapsed().as_secs_f64() * 1e3;
                        sink.deliver(ci * chunk + j, q, ms);
                    }
                });
            }
        });
        Ok(())
    }

    fn workers(&self) -> usize {
        self.slots.len()
    }
}

impl NativeEngine {
    /// Problem dimension p.
    pub fn dim(&self) -> usize {
        self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::EncoderKind;
    use crate::problem::QuadProblem;

    fn engine() -> (EncodedProblem, NativeEngine) {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let eng = NativeEngine::new(&enc);
        (enc, eng)
    }

    #[test]
    fn grad_matches_direct_computation() {
        let (enc, mut eng) = engine();
        let w = vec![0.3; 6];
        for i in 0..8 {
            let (g, f) = eng.worker_grad(i, &w).unwrap();
            let s = &enc.shards[i];
            let resid = linalg::sub(&s.x.gemv(&w), &s.y);
            let g_ref = s.x.gemv_t(&resid);
            let f_ref = linalg::dot(&resid, &resid);
            assert!((f - f_ref).abs() < 1e-10);
            for (a, b) in g.iter().zip(&g_ref) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn parallel_all_matches_serial() {
        let (_, mut eng) = engine();
        let w = vec![0.1; 6];
        let par = eng.worker_grad_all(&w).unwrap();
        let ser: Vec<_> = (0..8).map(|i| eng.worker_grad(i, &w).unwrap()).collect();
        assert_eq!(par.len(), ser.len());
        for ((gp, fp), (gs, fs)) in par.iter().zip(&ser) {
            assert!((fp - fs).abs() < 1e-12);
            for (a, b) in gp.iter().zip(gs) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn linesearch_matches_direct() {
        let (enc, mut eng) = engine();
        let d = vec![-0.2; 6];
        let all = eng.linesearch_all(&d).unwrap();
        for i in 0..8 {
            let xd = enc.shards[i].x.gemv(&d);
            assert!((all[i] - linalg::dot(&xd, &xd)).abs() < 1e-10);
        }
    }

    #[test]
    fn single_thread_mode_works() {
        let (_, eng) = engine();
        let mut eng = eng.with_threads(1);
        let w = vec![0.4; 6];
        let out = eng.worker_grad_all(&w).unwrap();
        assert_eq!(out.len(), 8);
    }

    #[test]
    fn streamed_payloads_match_batch_bitwise() {
        let (_, mut eng) = engine();
        let w = vec![0.7; 6];
        let batch = eng.worker_grad_all(&w).unwrap();
        let sink = GradCollector::collect_all(8);
        eng.worker_grad_streamed(&w, &sink).unwrap();
        let got = sink.into_collected();
        assert_eq!(got.delivery_order.len(), 8);
        for (i, (gb, fb)) in batch.iter().enumerate() {
            let (ref payload, ms) = *got.responses[i].as_ref().unwrap();
            let (gs, fs) = payload;
            assert_eq!(fs.to_bits(), fb.to_bits(), "worker {i} objective differs");
            assert_eq!(gs.len(), gb.len());
            for (a, b) in gs.iter().zip(gb) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i} gradient differs");
            }
            assert!(ms >= 0.0);
        }
    }

    #[test]
    fn batch_grad_full_segments_match_full_grad_bitwise() {
        let (enc, mut eng) = engine();
        let w = vec![0.2; 6];
        for i in 0..8 {
            let (g_full, f_full) = eng.worker_grad(i, &w).unwrap();
            let rows = enc.shards[i].rows_real;
            let (g_b, f_b) = eng.worker_grad_batch(i, &w, &[(0, rows)]).unwrap();
            // real rows only vs padded full shard: padding rows are exact
            // zero contributions, so the sums agree to machine identity
            assert_eq!(f_full.to_bits(), f_b.to_bits(), "worker {i}");
            for (a, b) in g_full.iter().zip(&g_b) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i}");
            }
        }
    }

    #[test]
    fn batch_streamed_matches_per_worker_batch() {
        let (enc, mut eng) = engine();
        let w = vec![-0.4; 6];
        let mut rng = crate::rng::Pcg64::seeded(11);
        let plan = enc.sample_batch(0.4, &mut rng);
        let expected: Vec<_> = (0..8)
            .map(|i| eng.worker_grad_batch(i, &w, &plan.segments[i]).unwrap())
            .collect();
        let sink = GradCollector::collect_all(8);
        eng.worker_grad_batch_streamed(&w, &plan, &sink).unwrap();
        let got = sink.into_collected();
        for (i, (ge, fe)) in expected.iter().enumerate() {
            let (ref payload, ms) = *got.responses[i].as_ref().unwrap();
            let (gs, fs) = payload;
            assert_eq!(fs.to_bits(), fe.to_bits(), "worker {i}");
            for (a, b) in gs.iter().zip(ge) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i}");
            }
            assert!(ms >= 0.0);
        }
    }

    #[test]
    fn sparse_shards_match_dense_engine_bitwise() {
        // storage obliviousness at the engine boundary: identical worker
        // payloads, dense vs CSR shards, down to the last bit
        use crate::linalg::StorageKind;
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let make = |storage| {
            EncodedProblem::encode_stored(&prob, EncoderKind::Identity, 1.0, 8, 2, storage)
                .unwrap()
        };
        let (dense_enc, sparse_enc) = (make(StorageKind::Dense), make(StorageKind::Sparse));
        assert!(sparse_enc.shards.iter().all(|s| s.x.is_sparse()));
        let mut ed = NativeEngine::new(&dense_enc);
        let mut es = NativeEngine::new(&sparse_enc);
        let w = vec![0.3; 6];
        for i in 0..8 {
            let (gd, fd) = ed.worker_grad(i, &w).unwrap();
            let (gs, fs) = es.worker_grad(i, &w).unwrap();
            assert_eq!(fd.to_bits(), fs.to_bits(), "worker {i} objective");
            for (a, b) in gd.iter().zip(&gs) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i} gradient");
            }
            let qd = ed.linesearch(i, &w).unwrap();
            let qs = es.linesearch(i, &w).unwrap();
            assert_eq!(qd.to_bits(), qs.to_bits(), "worker {i} curvature");
            let rows = dense_enc.shards[i].rows_real;
            let (gbd, fbd) = ed.worker_grad_batch(i, &w, &[(2, rows.min(5))]).unwrap();
            let (gbs, fbs) = es.worker_grad_batch(i, &w, &[(2, rows.min(5))]).unwrap();
            assert_eq!(fbd.to_bits(), fbs.to_bits(), "worker {i} batch objective");
            for (a, b) in gbd.iter().zip(&gbs) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {i} batch gradient");
            }
        }
    }

    #[test]
    fn streamed_linesearch_matches_batch_bitwise() {
        let (_, mut eng) = engine();
        let d = vec![-0.3; 6];
        let batch = eng.linesearch_all(&d).unwrap();
        let sink = CurvCollector::collect_all(8);
        eng.linesearch_streamed(&d, &sink).unwrap();
        let got = sink.into_collected();
        for (i, qb) in batch.iter().enumerate() {
            let (qs, _) = got.responses[i].unwrap();
            assert_eq!(qs.to_bits(), qb.to_bits(), "worker {i} curvature differs");
        }
    }
}
