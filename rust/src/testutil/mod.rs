//! Seeded property-testing helpers (proptest is unavailable offline).
//!
//! [`property`] runs a closure over `cases` pseudo-random inputs drawn
//! from a seeded generator; on failure it reports the case index and seed
//! so the exact input reproduces with zero flakiness. This is the
//! mechanism behind the coordinator-invariant property tests in
//! `rust/tests/`.

use crate::rng::Pcg64;

/// Run `f(case_rng)` for `cases` independent seeded cases; panics with the
/// failing case's seed on error.
pub fn property(name: &str, cases: usize, mut f: impl FnMut(&mut Pcg64)) {
    for case in 0..cases {
        let seed = 0x9d5f_0000 + case as u64;
        let mut rng = Pcg64::new(seed, 0x7e57);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Uniform integer in [lo, hi] (inclusive) — shorthand for case generation.
pub fn gen_range(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    assert!(hi >= lo);
    lo + rng.next_below((hi - lo + 1) as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes_when_invariant_holds() {
        property("addition commutes", 20, |rng| {
            let a = rng.next_f64();
            let b = rng.next_f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn property_reports_failing_case() {
        property("always fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn gen_range_is_inclusive() {
        let mut rng = Pcg64::seeded(0);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..500 {
            let v = gen_range(&mut rng, 2, 4);
            assert!((2..=4).contains(&v));
            seen_lo |= v == 2;
            seen_hi |= v == 4;
        }
        assert!(seen_lo && seen_hi);
    }
}
