//! Row-major dense matrix with cache-blocked, multi-threaded products.
//!
//! [`Mat`] is the single dense container used across the system: raw data,
//! encoded shards, encoding matrices, Gram matrices. The products that sit
//! on the optimization hot path are:
//!
//! * [`Mat::gemv`] / [`Mat::gemv_t`] — the worker gradient
//!   `Xᵀ(Xw − y)` is one `gemv` + one `gemv_t` per worker per iteration;
//! * [`Mat::matmul`] — encode-time `S·X` for dense encoders and the
//!   `S_Aᵀ S_A` Gram matrices for the spectrum figures.
//!
//! GEMM uses i-k-j loop order (unit-stride inner loop), 64×256 L1/L2
//! blocking, and std::thread row-band parallelism above a size threshold.

use std::fmt;

/// Dense row-major `rows × cols` matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

/// Below this many multiply-adds, threading overhead dominates — stay serial.
const PAR_FLOP_THRESHOLD: usize = 1 << 20;

pub(crate) fn n_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Mat {
    // ---------------------------------------------------------- constructors

    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// From a row-major buffer (length must be `rows * cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// From a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// A column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    // ---------------------------------------------------------------- access

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element `(i, j)`.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Set element `(i, j)`.
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Full row-major buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major buffer.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the row-major buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// f32 copy of the buffer (for PJRT literals).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    // ------------------------------------------------------------- reshaping

    /// New matrix from a subset of rows (in the given order).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            assert!(i < self.rows, "select_rows: index {i} out of range");
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// New matrix from a subset of columns (in the given order).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (c, &j) in idx.iter().enumerate() {
                assert!(j < self.cols, "select_cols: index {j} out of range");
                dst[c] = src[j];
            }
        }
        out
    }

    /// Contiguous row band `[lo, hi)` as a new matrix.
    pub fn row_band(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows, "row_band: bad range {lo}..{hi}");
        Mat {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// Stack matrices vertically.
    pub fn vstack(blocks: &[&Mat]) -> Mat {
        assert!(!blocks.is_empty(), "vstack: empty input");
        let cols = blocks[0].cols;
        assert!(blocks.iter().all(|b| b.cols == cols), "vstack: column mismatch");
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            data.extend_from_slice(&b.data);
        }
        Mat { rows, cols, data }
    }

    /// Zero-pad to `new_rows` rows (exact no-op for gradient/objective).
    pub fn pad_rows(&self, new_rows: usize) -> Mat {
        assert!(new_rows >= self.rows, "pad_rows: cannot shrink");
        let mut data = self.data.clone();
        data.resize(new_rows * self.cols, 0.0);
        Mat { rows: new_rows, cols: self.cols, data }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    // ------------------------------------------------------------- elementwise

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "add: shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "sub: shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `alpha * self`.
    pub fn scaled(&self, alpha: f64) -> Mat {
        let data = self.data.iter().map(|a| alpha * a).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place scale.
    pub fn scale_inplace(&mut self, alpha: f64) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    // --------------------------------------------------------------- products

    /// Matrix–vector product `self * x`.
    pub fn gemv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "gemv: dimension mismatch");
        let mut y = vec![0.0; self.rows];
        self.gemv_into(x, &mut y);
        y
    }

    /// `y = self * x` into a caller buffer (no allocation on the hot path).
    ///
    /// Cache-blocked by row pairs (§Perf iteration 5): two dot products
    /// share one pass over `x`, halving `x`-traffic, while each row keeps
    /// the exact mod-4 accumulation order of [`super::dot`] — per-row
    /// results are bit-identical to the historical per-row kernel (which
    /// is also what the CSR mirror, `storage::CsrMat::gemv_into`,
    /// reproduces). Dispatches to [`gemv_into_simd`] under
    /// `--features simd` (bitwise-identical lanes, pinned by
    /// `tests/kernel_equivalence.rs`).
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        if cfg!(feature = "simd") {
            gemv_into_simd(self, x, y)
        } else {
            gemv_into_scalar(self, x, y)
        }
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn gemv_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "gemv_t: dimension mismatch");
        let mut y = vec![0.0; self.cols];
        self.gemv_t_into(x, &mut y);
        y
    }

    /// `y = selfᵀ x` into a caller buffer. Row-major friendly scatter,
    /// folded two rows per pass over `y` (§Perf iteration 5 — halves
    /// `y`-traffic, same shape as the fused kernel's paired rank-1
    /// update, which is also what the CSR mirror reproduces).
    /// Dispatches to [`gemv_t_into_simd`] under `--features simd`.
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        if cfg!(feature = "simd") {
            gemv_t_into_simd(self, x, y)
        } else {
            gemv_t_into_scalar(self, x, y)
        }
    }

    /// Fused worker gradient: `g = selfᵀ(self·w − y)`, returning
    /// `(g, ||self·w − y||²)`. This is the Rust mirror of the L1 Pallas
    /// kernel (`python/compile/kernels/coded_grad.py`): one pass over the
    /// rows, residual never fully materialized.
    ///
    /// Rows are processed in pairs (§Perf iteration 2): the two dot
    /// products share one pass over `w` and the two rank-1 updates share
    /// one pass over `g`, cutting hot-loop memory traffic from `3p` to
    /// `2p` doubles per row.
    pub fn fused_grad(&self, w: &[f64], y: &[f64], g: &mut [f64], resid_buf: &mut [f64]) -> f64 {
        g.fill(0.0);
        self.fused_grad_range(w, y, g, resid_buf, 0, self.rows)
    }

    /// Row-restricted, **accumulating** variant of [`Mat::fused_grad`]:
    /// processes only rows `[lo, hi)` and adds their contribution into `g`
    /// (which is *not* zeroed — callers compose multiple disjoint ranges,
    /// e.g. the two segments of a wrap-around mini-batch block, and must
    /// clear `g` themselves before the first call). Returns the partial
    /// objective `Σ_{i∈[lo,hi)} (x_iᵀw − y_i)²`.
    ///
    /// For `(lo, hi) = (0, rows)` the arithmetic (pairing, summation
    /// order) is identical to the historical full-shard kernel, which is
    /// what keeps the batch path bit-compatible at batch fraction 1.
    pub fn fused_grad_range(
        &self,
        w: &[f64],
        y: &[f64],
        g: &mut [f64],
        resid_buf: &mut [f64],
        lo: usize,
        hi: usize,
    ) -> f64 {
        if cfg!(feature = "simd") {
            fused_grad_range_simd(self, w, y, g, resid_buf, lo, hi)
        } else {
            fused_grad_range_scalar(self, w, y, g, resid_buf, lo, hi)
        }
    }

    /// Matrix product `self * other`, blocked and threaded.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul: inner dimension mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        let flops = m * k * n;
        let threads = if flops >= PAR_FLOP_THRESHOLD { n_threads().min(m) } else { 1 };
        if threads <= 1 {
            gemm_block(&self.data, &other.data, &mut out.data, 0, m, k, n);
        } else {
            let band = m.div_ceil(threads);
            let a = &self.data;
            let b = &other.data;
            // split the output into disjoint row bands, one thread each
            let chunks: Vec<(usize, &mut [f64])> = {
                let mut v = Vec::new();
                let mut rest: &mut [f64] = &mut out.data;
                let mut lo = 0;
                while lo < m {
                    let hi = (lo + band).min(m);
                    let (head, tail) = rest.split_at_mut((hi - lo) * n);
                    v.push((lo, head));
                    rest = tail;
                    lo = hi;
                }
                v
            };
            std::thread::scope(|s| {
                for (lo, chunk) in chunks {
                    s.spawn(move || {
                        let rows = chunk.len() / n;
                        gemm_band(a, b, chunk, lo, rows, k, n);
                    });
                }
            });
        }
        out
    }

    /// Gram matrix `selfᵀ * self`: rank-k update on the upper triangle
    /// only (half the flops of the historical `transpose().matmul(self)`,
    /// no transpose allocation), threaded over triangle-area-balanced
    /// column bands, then mirrored into the lower triangle — so the
    /// result is exactly symmetric by construction.
    pub fn gram(&self) -> Mat {
        if cfg!(feature = "simd") {
            gram_simd(self)
        } else {
            gram_scalar(self)
        }
    }

    /// Largest eigenvalue of `selfᵀ self` by power iteration (this is
    /// `M = λ_max(XᵀX)` in the step-size rule of Theorem 1).
    pub fn spectral_bound(&self, iters: usize, seed: u64) -> f64 {
        super::spectral_power_iteration(
            self.rows,
            self.cols,
            iters,
            seed,
            |v, out| self.gemv_into(v, out),
            |v, out| self.gemv_t_into(v, out),
        )
    }
}

/// Serial GEMM over a row band `[row_lo, row_lo + rows)` of the output.
/// i-k-j order: unit stride over both B and C rows; 64×256 cache blocking;
/// k unrolled by 2 so each pass over the C row folds two B rows
/// (§Perf iteration 3 — halves C-row traffic).
fn gemm_band(a: &[f64], b: &[f64], c_band: &mut [f64], row_lo: usize, rows: usize, k: usize, n: usize) {
    const BK: usize = 64;
    const BJ: usize = 256;
    for kb in (0..k).step_by(BK) {
        let kmax = (kb + BK).min(k);
        for jb in (0..n).step_by(BJ) {
            let jmax = (jb + BJ).min(n);
            for i in 0..rows {
                let a_row = &a[(row_lo + i) * k..(row_lo + i + 1) * k];
                let c_row = &mut c_band[i * n..(i + 1) * n];
                let mut kk = kb;
                while kk + 1 < kmax {
                    let aik0 = a_row[kk];
                    let aik1 = a_row[kk + 1];
                    if aik0 == 0.0 && aik1 == 0.0 {
                        kk += 2;
                        continue; // encode matrices are often sparse-ish
                    }
                    let b0 = &b[kk * n..kk * n + n];
                    let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
                    for j in jb..jmax {
                        c_row[j] += aik0 * b0[j] + aik1 * b1[j];
                    }
                    kk += 2;
                }
                if kk < kmax {
                    let aik = a_row[kk];
                    if aik != 0.0 {
                        let b_row = &b[kk * n..(kk + 1) * n];
                        for j in jb..jmax {
                            c_row[j] += aik * b_row[j];
                        }
                    }
                }
            }
        }
    }
}

fn gemm_block(a: &[f64], b: &[f64], c: &mut [f64], row_lo: usize, rows: usize, k: usize, n: usize) {
    gemm_band(a, b, c, row_lo, rows, k, n);
}

// ---------------------------------------------------------------------------
// Hot-kernel implementations: scalar reference + SIMD lane bundles
// ---------------------------------------------------------------------------
//
// Both variants of every kernel are compiled in every build; the public
// `Mat` methods dispatch on `cfg!(feature = "simd")` and
// `linalg::kernels` re-exports both so one test binary can pin them
// bitwise against each other. The SIMD bodies hold the scalar kernels'
// unrolled accumulators in `F64x4`/`F64x2` lane bundles: every
// accumulator lane sees the same j-increasing add sequence and every
// horizontal sum reduces left-to-right in the scalar order, so the f64
// results are bitwise-identical by construction (elementwise update
// loops are chunked by 4, which never changes any single element's
// operation sequence).

use super::{F64x2, F64x4};

/// Scalar reference row-paired GEMV (the historical [`Mat::gemv_into`] body).
pub fn gemv_into_scalar(m: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.cols, "gemv: dimension mismatch");
    assert_eq!(y.len(), m.rows, "gemv: output mismatch");
    let n = m.cols;
    let chunks = n / 4;
    let mut i = 0;
    while i + 1 < m.rows {
        let r0 = &m.data[i * n..(i + 1) * n];
        let r1 = &m.data[(i + 1) * n..(i + 2) * n];
        let mut a0 = [0.0f64; 4];
        let mut a1 = [0.0f64; 4];
        for c in 0..chunks {
            let j = c * 4;
            a0[0] += r0[j] * x[j];
            a0[1] += r0[j + 1] * x[j + 1];
            a0[2] += r0[j + 2] * x[j + 2];
            a0[3] += r0[j + 3] * x[j + 3];
            a1[0] += r1[j] * x[j];
            a1[1] += r1[j + 1] * x[j + 1];
            a1[2] += r1[j + 2] * x[j + 2];
            a1[3] += r1[j + 3] * x[j + 3];
        }
        let mut s0 = a0[0] + a0[1] + a0[2] + a0[3];
        let mut s1 = a1[0] + a1[1] + a1[2] + a1[3];
        for j in chunks * 4..n {
            s0 += r0[j] * x[j];
            s1 += r1[j] * x[j];
        }
        y[i] = s0;
        y[i + 1] = s1;
        i += 2;
    }
    if i < m.rows {
        y[i] = super::dot_scalar(m.row(i), x);
    }
}

/// Lane-bundle row-paired GEMV: the scalar kernel's `a0`/`a1` accumulator
/// arrays held in [`F64x4`] — bitwise-identical per row.
pub fn gemv_into_simd(m: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.cols, "gemv: dimension mismatch");
    assert_eq!(y.len(), m.rows, "gemv: output mismatch");
    let n = m.cols;
    let chunks = n / 4;
    let mut i = 0;
    while i + 1 < m.rows {
        let r0 = &m.data[i * n..(i + 1) * n];
        let r1 = &m.data[(i + 1) * n..(i + 2) * n];
        let mut a0 = F64x4::zero();
        let mut a1 = F64x4::zero();
        for c in 0..chunks {
            let j = c * 4;
            let xv = F64x4::load(&x[j..j + 4]);
            a0.mul_acc(F64x4::load(&r0[j..j + 4]), xv);
            a1.mul_acc(F64x4::load(&r1[j..j + 4]), xv);
        }
        let mut s0 = a0.hsum();
        let mut s1 = a1.hsum();
        for j in chunks * 4..n {
            s0 += r0[j] * x[j];
            s1 += r1[j] * x[j];
        }
        y[i] = s0;
        y[i + 1] = s1;
        i += 2;
    }
    if i < m.rows {
        y[i] = super::dot_simd(m.row(i), x);
    }
}

/// Scalar reference transposed GEMV (the historical [`Mat::gemv_t_into`] body).
pub fn gemv_t_into_scalar(m: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.rows, "gemv_t: dimension mismatch");
    assert_eq!(y.len(), m.cols, "gemv_t: output mismatch");
    y.fill(0.0);
    let n = m.cols;
    let mut i = 0;
    while i + 1 < m.rows {
        let (x0, x1) = (x[i], x[i + 1]);
        let r0 = &m.data[i * n..(i + 1) * n];
        let r1 = &m.data[(i + 1) * n..(i + 2) * n];
        for ((yj, &a), &b) in y.iter_mut().zip(r0).zip(r1) {
            *yj += x0 * a + x1 * b;
        }
        i += 2;
    }
    if i < m.rows {
        super::axpy(x[i], m.row(i), y);
    }
}

/// Lane-chunked transposed GEMV. The scatter update is elementwise per
/// output element (`y[j] += x0·r0[j] + x1·r1[j]`), so chunking `y` by 4
/// lanes never reorders any element's adds — bitwise-identical.
pub fn gemv_t_into_simd(m: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.rows, "gemv_t: dimension mismatch");
    assert_eq!(y.len(), m.cols, "gemv_t: output mismatch");
    y.fill(0.0);
    let n = m.cols;
    let chunks = n / 4;
    let mut i = 0;
    while i + 1 < m.rows {
        let (x0, x1) = (x[i], x[i + 1]);
        let r0 = &m.data[i * n..(i + 1) * n];
        let r1 = &m.data[(i + 1) * n..(i + 2) * n];
        for c in 0..chunks {
            let j = c * 4;
            let ys = &mut y[j..j + 4];
            let a = &r0[j..j + 4];
            let b = &r1[j..j + 4];
            ys[0] += x0 * a[0] + x1 * b[0];
            ys[1] += x0 * a[1] + x1 * b[1];
            ys[2] += x0 * a[2] + x1 * b[2];
            ys[3] += x0 * a[3] + x1 * b[3];
        }
        for j in chunks * 4..n {
            y[j] += x0 * r0[j] + x1 * r1[j];
        }
        i += 2;
    }
    if i < m.rows {
        super::axpy(x[i], m.row(i), y);
    }
}

/// Scalar reference fused gradient over rows `[lo, hi)` (the historical
/// [`Mat::fused_grad_range`] body).
#[allow(clippy::too_many_arguments)]
pub fn fused_grad_range_scalar(
    m: &Mat,
    w: &[f64],
    y: &[f64],
    g: &mut [f64],
    resid_buf: &mut [f64],
    lo: usize,
    hi: usize,
) -> f64 {
    assert_eq!(w.len(), m.cols, "fused_grad: w mismatch");
    assert_eq!(y.len(), m.rows, "fused_grad: y mismatch");
    assert_eq!(g.len(), m.cols, "fused_grad: g mismatch");
    assert_eq!(resid_buf.len(), m.rows, "fused_grad: buffer mismatch");
    assert!(lo <= hi && hi <= m.rows, "fused_grad_range: bad range {lo}..{hi}");
    let mut f = 0.0;
    let mut i = lo;
    while i + 1 < hi {
        let row0 = m.row(i);
        let row1 = &m.data[(i + 1) * m.cols..(i + 2) * m.cols];
        // paired dot: one pass over w
        let (mut d0a, mut d0b, mut d1a, mut d1b) = (0.0, 0.0, 0.0, 0.0);
        let chunks = m.cols / 2;
        for c in 0..chunks {
            let j = 2 * c;
            d0a += row0[j] * w[j];
            d0b += row0[j + 1] * w[j + 1];
            d1a += row1[j] * w[j];
            d1b += row1[j + 1] * w[j + 1];
        }
        let mut r0 = d0a + d0b;
        let mut r1 = d1a + d1b;
        if m.cols % 2 == 1 {
            let j = m.cols - 1;
            r0 += row0[j] * w[j];
            r1 += row1[j] * w[j];
        }
        r0 -= y[i];
        r1 -= y[i + 1];
        resid_buf[i] = r0;
        resid_buf[i + 1] = r1;
        f += r0 * r0 + r1 * r1;
        // paired rank-1 update: one pass over g
        for ((gj, &a), &b) in g.iter_mut().zip(row0).zip(row1) {
            *gj += r0 * a + r1 * b;
        }
        i += 2;
    }
    if i < hi {
        let row = m.row(i);
        let r = super::dot_scalar(row, w) - y[i];
        resid_buf[i] = r;
        f += r * r;
        super::axpy(r, row, g);
    }
    f
}

/// Lane-bundle fused gradient: the even/odd pair accumulators
/// (`d0a`/`d0b`, `d1a`/`d1b`) held in [`F64x2`] (hsum = even + odd, the
/// scalar order), rank-1 update lane-chunked by 4 (elementwise per `g[j]`)
/// — bitwise-identical to [`fused_grad_range_scalar`].
#[allow(clippy::too_many_arguments)]
pub fn fused_grad_range_simd(
    m: &Mat,
    w: &[f64],
    y: &[f64],
    g: &mut [f64],
    resid_buf: &mut [f64],
    lo: usize,
    hi: usize,
) -> f64 {
    assert_eq!(w.len(), m.cols, "fused_grad: w mismatch");
    assert_eq!(y.len(), m.rows, "fused_grad: y mismatch");
    assert_eq!(g.len(), m.cols, "fused_grad: g mismatch");
    assert_eq!(resid_buf.len(), m.rows, "fused_grad: buffer mismatch");
    assert!(lo <= hi && hi <= m.rows, "fused_grad_range: bad range {lo}..{hi}");
    let mut f = 0.0;
    let mut i = lo;
    while i + 1 < hi {
        let row0 = m.row(i);
        let row1 = &m.data[(i + 1) * m.cols..(i + 2) * m.cols];
        let mut d0 = F64x2::zero();
        let mut d1 = F64x2::zero();
        let chunks = m.cols / 2;
        for c in 0..chunks {
            let j = 2 * c;
            let wv = F64x2::load(&w[j..j + 2]);
            d0.mul_acc(F64x2::load(&row0[j..j + 2]), wv);
            d1.mul_acc(F64x2::load(&row1[j..j + 2]), wv);
        }
        let mut r0 = d0.hsum();
        let mut r1 = d1.hsum();
        if m.cols % 2 == 1 {
            let j = m.cols - 1;
            r0 += row0[j] * w[j];
            r1 += row1[j] * w[j];
        }
        r0 -= y[i];
        r1 -= y[i + 1];
        resid_buf[i] = r0;
        resid_buf[i + 1] = r1;
        f += r0 * r0 + r1 * r1;
        let chunks4 = m.cols / 4;
        for c in 0..chunks4 {
            let j = c * 4;
            let gs = &mut g[j..j + 4];
            let a = &row0[j..j + 4];
            let b = &row1[j..j + 4];
            gs[0] += r0 * a[0] + r1 * b[0];
            gs[1] += r0 * a[1] + r1 * b[1];
            gs[2] += r0 * a[2] + r1 * b[2];
            gs[3] += r0 * a[3] + r1 * b[3];
        }
        for j in chunks4 * 4..m.cols {
            g[j] += r0 * row0[j] + r1 * row1[j];
        }
        i += 2;
    }
    if i < hi {
        let row = m.row(i);
        let r = super::dot_simd(row, w) - y[i];
        resid_buf[i] = r;
        f += r * r;
        super::axpy(r, row, g);
    }
    f
}

/// Shared Gram scaffolding (triangle-balanced thread bands + mirror);
/// the per-band rank-k update is the pluggable kernel.
fn gram_with(m: &Mat, syrk: fn(&[f64], usize, usize, usize, usize, &mut [f64])) -> Mat {
    let (n, p) = (m.rows, m.cols);
    let mut g = Mat::zeros(p, p);
    if p == 0 || n == 0 {
        return g;
    }
    let flops = n * p * (p + 1) / 2;
    let threads = if flops >= PAR_FLOP_THRESHOLD { n_threads().min(p) } else { 1 };
    // band cut points with roughly equal upper-triangle area
    let mut cuts = vec![0usize];
    if threads > 1 {
        let per = (p * (p + 1) / 2).div_ceil(threads);
        let mut acc = 0usize;
        for j in 0..p {
            acc += p - j;
            if acc >= per && j + 1 < p {
                cuts.push(j + 1);
                acc = 0;
            }
        }
    }
    cuts.push(p);
    let a = &m.data;
    // split g into disjoint row bands [cuts[b], cuts[b+1]), one thread each
    let bands: Vec<(usize, usize, &mut [f64])> = {
        let mut v = Vec::with_capacity(cuts.len() - 1);
        let mut rest: &mut [f64] = &mut g.data;
        for b in 0..cuts.len() - 1 {
            let (jlo, jhi) = (cuts[b], cuts[b + 1]);
            let (head, tail) = rest.split_at_mut((jhi - jlo) * p);
            v.push((jlo, jhi, head));
            rest = tail;
        }
        v
    };
    std::thread::scope(|s| {
        for (jlo, jhi, band) in bands {
            s.spawn(move || syrk(a, n, p, jlo, jhi, band));
        }
    });
    // mirror the computed upper triangle into the lower one
    for i in 0..p {
        for j in i + 1..p {
            let v = g.data[i * p + j];
            g.data[j * p + i] = v;
        }
    }
    g
}

/// Scalar reference Gram matrix (the historical [`Mat::gram`] body).
pub fn gram_scalar(m: &Mat) -> Mat {
    gram_with(m, syrk_band_scalar)
}

/// Gram matrix with the lane-chunked rank-k update. Each output element
/// `G[j][l]` still accumulates over rows `i` in the same order (the
/// chunking is across output columns), so the result is
/// bitwise-identical to [`gram_scalar`].
pub fn gram_simd(m: &Mat) -> Mat {
    gram_with(m, syrk_band_simd)
}

/// Upper-triangle rank-k update for [`Mat::gram`]: accumulates
/// `G[j][l] += A[i][j]·A[i][l]` for `l ≥ j`, `j ∈ [jlo, jhi)`, over all
/// rows `i` — unit stride over both the data row and the output row, with
/// the zero-skip that makes sparse-ish encode matrices cheap.
fn syrk_band_scalar(a: &[f64], n_rows: usize, p: usize, jlo: usize, jhi: usize, out: &mut [f64]) {
    for i in 0..n_rows {
        let row = &a[i * p..(i + 1) * p];
        for j in jlo..jhi {
            let aij = row[j];
            if aij == 0.0 {
                continue;
            }
            let base = (j - jlo) * p;
            let dst = &mut out[base + j..base + p];
            for (d, &s) in dst.iter_mut().zip(&row[j..]) {
                *d += aij * s;
            }
        }
    }
}

/// [`syrk_band_scalar`] with the inner axpy chunked into 4-wide lanes
/// (elementwise per output element → bitwise-identical).
fn syrk_band_simd(a: &[f64], n_rows: usize, p: usize, jlo: usize, jhi: usize, out: &mut [f64]) {
    for i in 0..n_rows {
        let row = &a[i * p..(i + 1) * p];
        for j in jlo..jhi {
            let aij = row[j];
            if aij == 0.0 {
                continue;
            }
            let base = (j - jlo) * p;
            let dst = &mut out[base + j..base + p];
            let src = &row[j..];
            let len = dst.len();
            let chunks = len / 4;
            for c in 0..chunks {
                let t = c * 4;
                let d = &mut dst[t..t + 4];
                let s = &src[t..t + 4];
                d[0] += aij * s[0];
                d[1] += aij * s[1];
                d[2] += aij * s[2];
                d[3] += aij * s[3];
            }
            for t in chunks * 4..len {
                dst[t] += aij * src[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.next_gaussian())
    }

    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for kk in 0..a.cols() {
                    s += a.get(i, kk) * b.get(kk, j);
                }
                out.set(i, j, s);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive_small() {
        let mut rng = Pcg64::seeded(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 13), (64, 64, 64)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let c = a.matmul(&b);
            assert!(c.max_abs_diff(&matmul_naive(&a, &b)) < 1e-10);
        }
    }

    #[test]
    fn matmul_matches_naive_threaded() {
        let mut rng = Pcg64::seeded(2);
        // large enough to cross PAR_FLOP_THRESHOLD
        let a = random_mat(&mut rng, 150, 120);
        let b = random_mat(&mut rng, 120, 130);
        let c = a.matmul(&b);
        assert!(c.max_abs_diff(&matmul_naive(&a, &b)) < 1e-9);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seeded(3);
        let a = random_mat(&mut rng, 20, 20);
        assert!(a.matmul(&Mat::eye(20)).max_abs_diff(&a) < 1e-14);
        assert!(Mat::eye(20).matmul(&a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn gemv_consistent_with_matmul() {
        let mut rng = Pcg64::seeded(4);
        let a = random_mat(&mut rng, 12, 7);
        let x: Vec<f64> = (0..7).map(|_| rng.next_gaussian()).collect();
        let y = a.gemv(&x);
        let xm = Mat::col_vec(&x);
        let ym = a.matmul(&xm);
        for i in 0..12 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_t_consistent_with_transpose() {
        let mut rng = Pcg64::seeded(5);
        let a = random_mat(&mut rng, 9, 14);
        let x: Vec<f64> = (0..9).map(|_| rng.next_gaussian()).collect();
        let y1 = a.gemv_t(&x);
        let y2 = a.transpose().gemv(&x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn fused_grad_matches_composition() {
        let mut rng = Pcg64::seeded(6);
        let a = random_mat(&mut rng, 30, 8);
        let w: Vec<f64> = (0..8).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..30).map(|_| rng.next_gaussian()).collect();
        let mut g = vec![0.0; 8];
        let mut buf = vec![0.0; 30];
        let f = a.fused_grad(&w, &y, &mut g, &mut buf);
        let resid = crate::linalg::sub(&a.gemv(&w), &y);
        let g_ref = a.gemv_t(&resid);
        let f_ref = crate::linalg::dot(&resid, &resid);
        assert!((f - f_ref).abs() < 1e-10);
        for (u, v) in g.iter().zip(&g_ref) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn fused_grad_range_full_matches_fused_grad_bitwise() {
        let mut rng = Pcg64::seeded(16);
        let a = random_mat(&mut rng, 27, 9);
        let w: Vec<f64> = (0..9).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..27).map(|_| rng.next_gaussian()).collect();
        let mut g1 = vec![0.0; 9];
        let mut g2 = vec![0.0; 9];
        let mut b1 = vec![0.0; 27];
        let mut b2 = vec![0.0; 27];
        let f1 = a.fused_grad(&w, &y, &mut g1, &mut b1);
        g2.fill(0.0);
        let f2 = a.fused_grad_range(&w, &y, &mut g2, &mut b2, 0, 27);
        assert_eq!(f1.to_bits(), f2.to_bits());
        for (u, v) in g1.iter().zip(&g2) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn fused_grad_range_segments_compose() {
        // two disjoint ranges accumulate to the same gradient as the
        // row-subset computed directly
        let mut rng = Pcg64::seeded(17);
        let a = random_mat(&mut rng, 20, 5);
        let w: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..20).map(|_| rng.next_gaussian()).collect();
        let mut g = vec![0.0; 5];
        let mut buf = vec![0.0; 20];
        let f = a.fused_grad_range(&w, &y, &mut g, &mut buf, 14, 20)
            + a.fused_grad_range(&w, &y, &mut g, &mut buf, 0, 3);
        // reference: rows {14..20, 0..3} as an explicit submatrix
        let idx: Vec<usize> = (14..20).chain(0..3).collect();
        let sub = a.select_rows(&idx);
        let ys: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
        let resid = crate::linalg::sub(&sub.gemv(&w), &ys);
        let g_ref = sub.gemv_t(&resid);
        let f_ref = crate::linalg::dot(&resid, &resid);
        assert!((f - f_ref).abs() < 1e-10);
        for (u, v) in g.iter().zip(&g_ref) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "bad range")]
    fn fused_grad_range_rejects_bad_range() {
        let a = Mat::zeros(4, 2);
        let mut g = vec![0.0; 2];
        let mut buf = vec![0.0; 4];
        a.fused_grad_range(&[0.0; 2], &[0.0; 4], &mut g, &mut buf, 2, 6);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(7);
        let a = random_mat(&mut rng, 23, 41);
        assert!(a.transpose().transpose().max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn select_rows_and_cols() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 10 + j) as f64);
        let r = a.select_rows(&[2, 0]);
        assert_eq!(r.row(0), &[20.0, 21.0, 22.0]);
        assert_eq!(r.row(1), &[0.0, 1.0, 2.0]);
        let c = a.select_cols(&[1]);
        assert_eq!(c.col(0), vec![1.0, 11.0, 21.0, 31.0]);
    }

    #[test]
    fn vstack_and_row_band_roundtrip() {
        let a = Mat::from_fn(3, 2, |i, j| (i + j) as f64);
        let b = Mat::from_fn(2, 2, |i, j| (i * j) as f64);
        let s = Mat::vstack(&[&a, &b]);
        assert_eq!(s.rows(), 5);
        assert!(s.row_band(0, 3).max_abs_diff(&a) < 1e-15);
        assert!(s.row_band(3, 5).max_abs_diff(&b) < 1e-15);
    }

    #[test]
    fn pad_rows_preserves_gradient() {
        let mut rng = Pcg64::seeded(8);
        let a = random_mat(&mut rng, 10, 4);
        let w: Vec<f64> = (0..4).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..10).map(|_| rng.next_gaussian()).collect();
        let ap = a.pad_rows(16);
        let mut yp = y.clone();
        yp.resize(16, 0.0);
        let mut g1 = vec![0.0; 4];
        let mut g2 = vec![0.0; 4];
        let mut b1 = vec![0.0; 10];
        let mut b2 = vec![0.0; 16];
        let f1 = a.fused_grad(&w, &y, &mut g1, &mut b1);
        let f2 = ap.fused_grad(&w, &yp, &mut g2, &mut b2);
        assert!((f1 - f2).abs() < 1e-12);
        for (u, v) in g1.iter().zip(&g2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn spectral_bound_on_known_matrix() {
        // X = diag(1, 2, 3) => lambda_max(X^T X) = 9
        let x = Mat::from_fn(3, 3, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let m = x.spectral_bound(200, 0);
        assert!((m - 9.0).abs() < 1e-6, "got {m}");
    }

    #[test]
    fn gram_matches_transpose_matmul() {
        let mut rng = Pcg64::seeded(18);
        // 200×128 crosses PAR_FLOP_THRESHOLD → threaded triangle bands
        for &(r, c) in &[(5usize, 3usize), (40, 17), (200, 128)] {
            let a = random_mat(&mut rng, r, c);
            let g = a.gram();
            let g_ref = a.transpose().matmul(&a);
            assert!(g.max_abs_diff(&g_ref) < 1e-9, "{r}x{c}");
            // exactly symmetric by construction (mirrored triangle)
            for i in 0..c {
                for j in 0..c {
                    assert_eq!(g.get(i, j).to_bits(), g.get(j, i).to_bits());
                }
            }
        }
    }

    #[test]
    fn gemv_paired_rows_match_per_row_dot_bitwise() {
        // the row-paired kernel must keep each row's historical
        // accumulation order — this is the dense half of the bitwise
        // storage-equivalence contract
        let mut rng = Pcg64::seeded(19);
        for &(r, c) in &[(1usize, 7usize), (8, 13), (9, 4), (2, 1), (5, 16)] {
            let a = random_mat(&mut rng, r, c);
            let x: Vec<f64> = (0..c).map(|_| rng.next_gaussian()).collect();
            let y = a.gemv(&x);
            for (i, yi) in y.iter().enumerate() {
                assert_eq!(yi.to_bits(), crate::linalg::dot(a.row(i), &x).to_bits());
            }
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Pcg64::seeded(9);
        let a = random_mat(&mut rng, 15, 6);
        let g = a.gram();
        for i in 0..6 {
            assert!(g.get(i, i) >= 0.0);
            for j in 0..6 {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-10);
            }
        }
    }

    #[test]
    #[should_panic(expected = "inner dimension mismatch")]
    fn matmul_shape_mismatch_panics() {
        Mat::zeros(2, 3).matmul(&Mat::zeros(2, 3));
    }
}
