//! Symmetric eigensolver: Householder tridiagonalization + implicit-shift QL.
//!
//! Needed by (a) the spectrum experiments (Figures 2–3 plot the eigenvalue
//! distribution of `S_Aᵀ S_A` for each encoder) and (b) the ETF
//! constructions, which factor a projection Gram matrix `P = F ᵀF` through
//! its eigendecomposition. This is the classical `tred2`/`tql2` pair
//! (Numerical Recipes / EISPACK lineage), O(n³), ample for the `n ≤ 4096`
//! matrices the experiments use.

use crate::linalg::Mat;

/// Eigenvalues of a symmetric matrix, ascending. Panics if not square;
/// symmetry is the caller's contract (only the values are used).
pub fn sym_eigenvalues(a: &Mat) -> Vec<f64> {
    let (mut d, mut e, _) = tridiagonalize(a, false);
    ql_implicit(&mut d, &mut e, None);
    d.sort_by(|x, y| x.partial_cmp(y).unwrap());
    d
}

/// Full eigendecomposition `A = V diag(d) Vᵀ` of a symmetric matrix.
/// Returns `(d, V)` with eigenvalues ascending and eigenvectors as the
/// *columns* of `V`, orthonormal.
pub fn sym_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    let (mut d, mut e, mut v) = tridiagonalize(a, true);
    {
        let vmat = v.as_mut().unwrap();
        ql_implicit(&mut d, &mut e, Some(vmat));
    }
    let mut v = v.unwrap();
    // sort ascending, permuting columns accordingly
    let n = d.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let d_sorted: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let v_sorted = v.select_cols(&order);
    v = v_sorted;
    (d_sorted, v)
}

/// Householder reduction to tridiagonal form (tred2).
/// Returns `(d, e, V)`: diagonal, subdiagonal (e[0] unused), and the
/// accumulated orthogonal transform if `want_vectors`.
fn tridiagonalize(a: &Mat, want_vectors: bool) -> (Vec<f64>, Vec<f64>, Option<Mat>) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "sym_eigen: matrix must be square");
    // work on a copy, row-major
    let mut z: Vec<f64> = a.data().to_vec();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];

    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 0 {
            for k in 0..=l {
                scale += z[i * n + k].abs();
            }
            if scale == 0.0 {
                e[i] = z[i * n + l];
            } else {
                for k in 0..=l {
                    z[i * n + k] /= scale;
                    h += z[i * n + k] * z[i * n + k];
                }
                let mut f = z[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    if want_vectors {
                        z[j * n + i] = z[i * n + j] / h;
                    }
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[j * n + k] * z[i * n + k];
                    }
                    for k in j + 1..=l {
                        g += z[k * n + j] * z[i * n + k];
                    }
                    e[j] = g / h;
                    f += e[j] * z[i * n + j];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        z[j * n + k] -= f * e[k] + g * z[i * n + k];
                    }
                }
            }
        } else {
            e[i] = z[i * n + l];
        }
        d[i] = h;
    }

    if want_vectors {
        d[0] = 0.0;
    }
    e[0] = 0.0;

    for i in 0..n {
        if want_vectors {
            let l = i; // columns 0..i already transformed
            if d[i] != 0.0 {
                for j in 0..l {
                    let mut g = 0.0;
                    for k in 0..l {
                        g += z[i * n + k] * z[k * n + j];
                    }
                    for k in 0..l {
                        z[k * n + j] -= g * z[k * n + i];
                    }
                }
            }
            d[i] = z[i * n + i];
            z[i * n + i] = 1.0;
            for j in 0..l {
                z[j * n + i] = 0.0;
                z[i * n + j] = 0.0;
            }
        } else {
            d[i] = z[i * n + i];
        }
    }

    let v = if want_vectors { Some(Mat::from_vec(n, n, z)) } else { None };
    (d, e, v)
}

/// Implicit-shift QL on a tridiagonal (tql2). `d` = diagonal, `e` =
/// subdiagonal with `e[0]` unused. If `v` is given, accumulates the
/// rotations into its columns (so its columns end as eigenvectors).
fn ql_implicit(d: &mut [f64], e: &mut [f64], mut v: Option<&mut Mat>) {
    let n = d.len();
    if n == 0 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    // Absolute deflation floor: rank-deficient matrices have runs of
    // (near-)zero diagonal entries for which the classical relative test
    // `|e[m]| <= eps (|d[m]|+|d[m+1]|)` never fires; anchor it to the
    // overall matrix scale instead.
    let scale = d
        .iter()
        .map(|x| x.abs())
        .chain(e.iter().map(|x| x.abs()))
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    let floor = f64::EPSILON * scale;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small subdiagonal element
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd + floor {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "ql_implicit: too many iterations");
            // form shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                if let Some(vm) = v.as_deref_mut() {
                    let nn = vm.rows();
                    for k in 0..nn {
                        f = vm.get(k, i + 1);
                        let vki = vm.get(k, i);
                        vm.set(k, i + 1, s * vki + c * f);
                        vm.set(k, i, c * vki - s * f);
                    }
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn random_sym(rng: &mut Pcg64, n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.next_gaussian());
        b.add(&b.transpose()).scaled(0.5)
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i as f64) - 1.5 } else { 0.0 });
        let ev = sym_eigenvalues(&a);
        let expected = [-1.5, -0.5, 0.5, 1.5];
        for (x, y) in ev.iter().zip(&expected) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let ev = sym_eigenvalues(&a);
        assert!((ev[0] - 1.0).abs() < 1e-12 && (ev[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_det_preserved() {
        let mut rng = Pcg64::seeded(1);
        for &n in &[3usize, 8, 25] {
            let a = random_sym(&mut rng, n);
            let ev = sym_eigenvalues(&a);
            let trace: f64 = (0..n).map(|i| a.get(i, i)).sum();
            let ev_sum: f64 = ev.iter().sum();
            assert!((trace - ev_sum).abs() < 1e-8 * trace.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn decomposition_reconstructs() {
        let mut rng = Pcg64::seeded(2);
        for &n in &[2usize, 5, 16, 40] {
            let a = random_sym(&mut rng, n);
            let (d, v) = sym_eigen(&a);
            // A V = V diag(d)
            let av = a.matmul(&v);
            let vd = Mat::from_fn(n, n, |i, j| v.get(i, j) * d[j]);
            assert!(av.max_abs_diff(&vd) < 1e-8, "n={n}");
            // V orthonormal
            let vtv = v.gram();
            assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn eigenvalues_sorted_ascending() {
        let mut rng = Pcg64::seeded(3);
        let a = random_sym(&mut rng, 30);
        let ev = sym_eigenvalues(&a);
        for w in ev.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn psd_gram_has_nonnegative_spectrum() {
        let mut rng = Pcg64::seeded(4);
        let b = Mat::from_fn(20, 8, |_, _| rng.next_gaussian());
        let ev = sym_eigenvalues(&b.gram());
        assert!(ev.iter().all(|&x| x > -1e-9));
    }

    #[test]
    fn values_match_vectors_path() {
        let mut rng = Pcg64::seeded(5);
        let a = random_sym(&mut rng, 12);
        let ev1 = sym_eigenvalues(&a);
        let (ev2, _) = sym_eigen(&a);
        for (x, y) in ev1.iter().zip(&ev2) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn rank_deficient_projection() {
        // P = v v^T / ||v||^2 has eigenvalues {1, 0, 0}
        let v = [1.0, 2.0, 2.0];
        let n2 = 9.0;
        let p = Mat::from_fn(3, 3, |i, j| v[i] * v[j] / n2);
        let ev = sym_eigenvalues(&p);
        assert!(ev[0].abs() < 1e-12 && ev[1].abs() < 1e-12 && (ev[2] - 1.0).abs() < 1e-12);
    }
}
