//! Cholesky factorization and SPD solves.
//!
//! Used for (a) the local `n < 500` ridge subproblems in the matrix-
//! factorization experiment (the paper uses `numpy.linalg.solve` there —
//! §5) and (b) small exact solves in tests (closed-form least squares to
//! validate the iterative solvers against).

use crate::linalg::Mat;

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
///
/// `A` must be symmetric positive definite; returns `None` if a
/// non-positive pivot is hit (not SPD / numerically singular).
pub fn cholesky_factor(a: &Mat) -> Option<Mat> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky: matrix must be square");
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` given the Cholesky factor `L` of `A`.
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n, "cholesky_solve: rhs mismatch");
    // forward: L z = b
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.get(i, k) * z[k];
        }
        z[i] = s / l.get(i, i);
    }
    // backward: Lᵀ x = z
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l.get(k, i) * x[k];
        }
        x[i] = s / l.get(i, i);
    }
    x
}

/// One-shot SPD solve `A x = b`; returns `None` if `A` is not SPD.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    cholesky_factor(a).map(|l| cholesky_solve(&l, b))
}

/// Normal-equations ridge solve given the precomputed Gram matrix and
/// right-hand side: solves `(G + λ n I) w = rhs` with `G = XᵀX` and
/// `rhs = Xᵀy`. This is the single home of the ridge convention
/// `f(w) = (1/2n)||Xw−y||² + (λ/2)||w||²` (stationarity
/// `(1/n)Xᵀ(Xw−y) + λw = 0`) — both the dense [`ridge_exact`] and the
/// storage-generic `QuadProblem::exact_solution` delegate here.
pub fn ridge_solve_normal(mut gram: Mat, rhs: &[f64], lambda: f64, n: f64) -> Option<Vec<f64>> {
    for i in 0..gram.rows() {
        let v = gram.get(i, i) + lambda * n;
        gram.set(i, i, v);
    }
    solve_spd(&gram, rhs)
}

/// Closed-form ridge solve: `(XᵀX + λ n I) w = Xᵀ y` (see
/// [`ridge_solve_normal`] for the convention).
pub fn ridge_exact(x: &Mat, y: &[f64], lambda: f64) -> Option<Vec<f64>> {
    ridge_solve_normal(x.gram(), &x.gemv_t(y), lambda, x.rows() as f64)
}

/// Pivoted Cholesky of a PSD matrix: `P A Pᵀ ≈ L Lᵀ` truncated at
/// numerical rank. Returns `L` as an `n × rank` matrix **in the original
/// (unpermuted) row order**, i.e. `A ≈ L Lᵀ` exactly for PSD `A`.
///
/// Used by the ETF constructions (§4 / DESIGN.md): the equiangular Gram
/// matrix `G = (I + C/√q)/2` is an exact projection of rank `n/2`; its
/// pivoted Cholesky rows are the frame vectors (`G = L Lᵀ`, rows of `L`
/// the φᵢ), and for a projection `LᵀL = I` automatically, which makes
/// `S = √β L` a tight frame.
pub fn pivoted_cholesky(a: &Mat, tol: f64) -> Mat {
    let n = a.rows();
    assert_eq!(n, a.cols(), "pivoted_cholesky: matrix must be square");
    let mut diag: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
    let mut perm: Vec<usize> = (0..n).collect();
    // l_rows[i] holds the i-th row of L in permuted order, built column by column
    let mut l = Mat::zeros(n, n);
    let mut rank = 0;
    let thresh = tol * diag.iter().cloned().fold(0.0, f64::max).max(1e-300);
    for k in 0..n {
        // find pivot
        let (piv, &dmax) = diag[k..]
            .iter()
            .enumerate()
            .max_by(|(_, x), (_, y)| x.partial_cmp(y).unwrap())
            .map(|(i, v)| (i + k, v))
            .unwrap();
        if dmax <= thresh {
            break;
        }
        perm.swap(k, piv);
        diag.swap(k, piv);
        // swap already-computed L rows
        for j in 0..k {
            let (a_, b_) = (l.get(k, j), l.get(piv, j));
            l.set(k, j, b_);
            l.set(piv, j, a_);
        }
        let lkk = dmax.sqrt();
        l.set(k, k, lkk);
        for i in k + 1..n {
            let mut s = a.get(perm[i], perm[k]);
            for j in 0..k {
                s -= l.get(i, j) * l.get(k, j);
            }
            let v = s / lkk;
            l.set(i, k, v);
            diag[i] -= v * v;
        }
        rank += 1;
    }
    // un-permute rows and truncate columns at rank
    let mut out = Mat::zeros(n, rank);
    for i in 0..n {
        for j in 0..rank {
            out.set(perm[i], j, l.get(i, j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.next_gaussian());
        let mut a = b.gram();
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f64); // well conditioned
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Pcg64::seeded(1);
        for &n in &[1usize, 2, 5, 20] {
            let a = random_spd(&mut rng, n);
            let l = cholesky_factor(&a).expect("SPD");
            let recon = l.matmul(&l.transpose());
            assert!(recon.max_abs_diff(&a) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn solve_matches_identity() {
        let b = vec![3.0, -1.0, 2.0];
        let x = solve_spd(&Mat::eye(3), &b).unwrap();
        for (u, v) in x.iter().zip(&b) {
            assert!((u - v).abs() < 1e-14);
        }
    }

    #[test]
    fn solve_random_system() {
        let mut rng = Pcg64::seeded(2);
        let n = 12;
        let a = random_spd(&mut rng, n);
        let x_true: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.gemv(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky_factor(&a).is_none());
    }

    #[test]
    fn pivoted_cholesky_full_rank_reconstructs() {
        let mut rng = Pcg64::seeded(10);
        let a = random_spd(&mut rng, 10);
        let l = pivoted_cholesky(&a, 1e-12);
        assert_eq!(l.cols(), 10);
        assert!(l.matmul(&l.transpose()).max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn pivoted_cholesky_low_rank() {
        // rank-3 PSD from a 12x3 factor
        let mut rng = Pcg64::seeded(11);
        let b = Mat::from_fn(12, 3, |_, _| rng.next_gaussian());
        let a = b.matmul(&b.transpose());
        let l = pivoted_cholesky(&a, 1e-10);
        assert_eq!(l.cols(), 3, "numerical rank");
        assert!(l.matmul(&l.transpose()).max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn pivoted_cholesky_projection_has_orthonormal_columns() {
        // For projection G, L^T L = I (the tight-frame property the ETF
        // constructions rely on). Build G as V_1 V_1^T from a random
        // orthonormal basis.
        let mut rng = Pcg64::seeded(12);
        let b = Mat::from_fn(8, 8, |_, _| rng.next_gaussian());
        let (_, v) = crate::linalg::sym_eigen(&b.add(&b.transpose()));
        let v1 = v.select_cols(&[0, 1, 2, 3]);
        let g = v1.matmul(&v1.transpose());
        let l = pivoted_cholesky(&g, 1e-10);
        assert_eq!(l.cols(), 4);
        assert!(l.gram().max_abs_diff(&Mat::eye(4)) < 1e-8);
    }

    #[test]
    fn ridge_exact_satisfies_stationarity() {
        let mut rng = Pcg64::seeded(3);
        let (n, p) = (40, 6);
        let x = Mat::from_fn(n, p, |_, _| rng.next_gaussian());
        let y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let lambda = 0.05;
        let w = ridge_exact(&x, &y, lambda).unwrap();
        // grad = (1/n) X^T (Xw - y) + lambda w == 0
        let resid = crate::linalg::sub(&x.gemv(&w), &y);
        let mut grad = x.gemv_t(&resid);
        for (gi, wi) in grad.iter_mut().zip(&w) {
            *gi = *gi / n as f64 + lambda * wi;
        }
        assert!(crate::linalg::norm2(&grad) < 1e-9);
    }
}
