//! Linear algebra substrate (f64): dense row-major and sparse CSR.
//!
//! The paper's system needs: blocked/threaded GEMM and GEMV for the worker
//! hot path ([`mat`]), a compressed-sparse-rows backend with the same
//! fused-kernel surface so encoded shards of sparse design matrices never
//! densify ([`storage`]), the Fast Walsh–Hadamard Transform for the
//! fast-transform encoders ([`fwht`]), Cholesky solves for the local
//! (`n < 500`) matrix-factorization subproblems ([`chol`]), and a symmetric
//! eigensolver for the `S_Aᵀ S_A` spectrum figures ([`eig`]).
//!
//! Everything is self-contained std-only Rust: no BLAS, no external crates
//! (the offline build environment has none) — the GEMM microkernel is
//! cache-blocked and multi-threaded, which is enough to drive every
//! experiment in the paper at the reduced scales we run.

pub mod chol;
pub mod eig;
pub mod fwht;
pub mod mat;
pub mod storage;

pub use chol::{
    cholesky_factor, cholesky_solve, pivoted_cholesky, ridge_exact, ridge_solve_normal, solve_spd,
};
pub use eig::{sym_eigenvalues, sym_eigen};
pub use fwht::{fwht_inplace, fwht_columns};
pub use mat::Mat;
pub use storage::{CsrMat, DataMat, StorageKind};

/// Euclidean norm of a vector.
pub fn norm2(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

/// Power iteration for `λ_max(XᵀX)` over any `(gemv, gemv_t)` pair — the
/// shared core of [`Mat::spectral_bound`] and `DataMat::spectral_bound`
/// (one implementation keeps the two storage backends' results
/// bit-identical by construction).
pub(crate) fn spectral_power_iteration(
    rows: usize,
    cols: usize,
    iters: usize,
    seed: u64,
    mut gemv: impl FnMut(&[f64], &mut [f64]),
    mut gemv_t: impl FnMut(&[f64], &mut [f64]),
) -> f64 {
    let mut rng = crate::rng::Pcg64::seeded(seed);
    let mut v: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
    let norm = norm2(&v);
    scale(1.0 / norm, &mut v);
    let mut lambda = 0.0;
    let mut xv = vec![0.0; rows];
    let mut xtxv = vec![0.0; cols];
    for _ in 0..iters {
        gemv(&v, &mut xv);
        gemv_t(&xv, &mut xtxv);
        lambda = dot(&v, &xtxv);
        let n = norm2(&xtxv);
        if n == 0.0 {
            return 0.0;
        }
        for (vi, xi) in v.iter_mut().zip(&xtxv) {
            *vi = xi / n;
        }
    }
    lambda
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    // 4-way unrolled accumulation: measurably faster than naive fold and
    // more accurate than a single serial accumulator.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Elementwise `a - b` into a fresh vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "sub: length mismatch");
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn norm2_pythagorean() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_length_mismatch_panics() {
        dot(&[1.0], &[1.0, 2.0]);
    }
}
