//! Linear algebra substrate (f64): dense row-major and sparse CSR.
//!
//! The paper's system needs: blocked/threaded GEMM and GEMV for the worker
//! hot path ([`mat`]), a compressed-sparse-rows backend with the same
//! fused-kernel surface so encoded shards of sparse design matrices never
//! densify ([`storage`]), the Fast Walsh–Hadamard Transform for the
//! fast-transform encoders ([`fwht`]), Cholesky solves for the local
//! (`n < 500`) matrix-factorization subproblems ([`chol`]), and a symmetric
//! eigensolver for the `S_Aᵀ S_A` spectrum figures ([`eig`]).
//!
//! Everything is self-contained std-only Rust: no BLAS, no external crates
//! (the offline build environment has none) — the GEMM microkernel is
//! cache-blocked and multi-threaded, which is enough to drive every
//! experiment in the paper at the reduced scales we run.

pub mod chol;
pub mod eig;
pub mod fwht;
pub mod mat;
pub mod storage;

pub use chol::{
    cholesky_factor, cholesky_solve, pivoted_cholesky, ridge_exact, ridge_solve_normal, solve_spd,
};
pub use eig::{sym_eigenvalues, sym_eigen};
pub use fwht::{fwht_inplace, fwht_columns};
pub use mat::Mat;
pub use storage::{CsrMat, CsrMatF32, DataMat, GradMode, MatF32, Precision, StorageKind};

/// The kernel-equivalence testing surface: both compiled implementations
/// of every hot kernel, regardless of whether the `simd` cargo feature is
/// on. The public `Mat`/`CsrMat` methods dispatch to exactly one of these
/// per build; `rust/tests/kernel_equivalence.rs` pins the two bitwise
/// against each other in *both* builds, which is what lets the `simd`
/// feature ship without touching a single golden trace.
pub mod kernels {
    pub use super::mat::{
        fused_grad_range_scalar as mat_fused_grad_range_scalar,
        fused_grad_range_simd as mat_fused_grad_range_simd,
        gemv_into_scalar as mat_gemv_into_scalar, gemv_into_simd as mat_gemv_into_simd,
        gemv_t_into_scalar as mat_gemv_t_into_scalar, gemv_t_into_simd as mat_gemv_t_into_simd,
        gram_scalar as mat_gram_scalar, gram_simd as mat_gram_simd,
    };
    pub use super::storage::{
        csr_fused_grad_range_scalar, csr_fused_grad_range_simd, csr_gemv_into_scalar,
        csr_gemv_into_simd, csr_gemv_t_into_scalar, csr_gemv_t_into_simd,
    };
    pub use super::{dot_scalar, dot_simd};

    /// Whether this build's public kernel surface dispatches to the SIMD
    /// lane implementations (`--features simd`) or the scalar reference.
    pub fn simd_active() -> bool {
        cfg!(feature = "simd")
    }
}

// ---------------------------------------------------------------------------
// SIMD lane bundles
// ---------------------------------------------------------------------------
//
// Stable-Rust "portable SIMD": fixed-width lane arrays with `#[inline(always)]`
// elementwise ops, shaped so LLVM's autovectorizer maps each bundle onto one
// vector register (4×f64 = AVX2 ymm / 2×NEON q, 2×f64 = SSE2 xmm / NEON q).
// The horizontal sums reduce lanes in the *same left-to-right order* as the
// scalar kernels' unrolled accumulators, which is the whole bitwise contract:
// a lane bundle is just the scalar kernel's accumulator array made explicit.

/// 4-wide f64 lane bundle (mirrors the mod-4 accumulators of [`dot`]).
#[derive(Clone, Copy)]
pub(crate) struct F64x4(pub(crate) [f64; 4]);

impl F64x4 {
    #[inline(always)]
    pub(crate) fn zero() -> Self {
        F64x4([0.0; 4])
    }

    #[inline(always)]
    pub(crate) fn load(s: &[f64]) -> Self {
        F64x4([s[0], s[1], s[2], s[3]])
    }

    /// `self[l] += a[l] * b[l]` per lane.
    #[inline(always)]
    pub(crate) fn mul_acc(&mut self, a: F64x4, b: F64x4) {
        self.0[0] += a.0[0] * b.0[0];
        self.0[1] += a.0[1] * b.0[1];
        self.0[2] += a.0[2] * b.0[2];
        self.0[3] += a.0[3] * b.0[3];
    }

    /// Left-associated lane sum — the exact reduction order of the scalar
    /// kernels' `acc[0] + acc[1] + acc[2] + acc[3]`.
    #[inline(always)]
    pub(crate) fn hsum(self) -> f64 {
        self.0[0] + self.0[1] + self.0[2] + self.0[3]
    }
}

/// 2-wide f64 lane bundle (mirrors the even/odd pair accumulators of the
/// fused gradient kernel).
#[derive(Clone, Copy)]
pub(crate) struct F64x2(pub(crate) [f64; 2]);

impl F64x2 {
    #[inline(always)]
    pub(crate) fn zero() -> Self {
        F64x2([0.0; 2])
    }

    #[inline(always)]
    pub(crate) fn load(s: &[f64]) -> Self {
        F64x2([s[0], s[1]])
    }

    /// `self[l] += a[l] * b[l]` per lane.
    #[inline(always)]
    pub(crate) fn mul_acc(&mut self, a: F64x2, b: F64x2) {
        self.0[0] += a.0[0] * b.0[0];
        self.0[1] += a.0[1] * b.0[1];
    }

    /// Left-associated lane sum (`d_even + d_odd`).
    #[inline(always)]
    pub(crate) fn hsum(self) -> f64 {
        self.0[0] + self.0[1]
    }
}

/// Euclidean norm of a vector.
pub fn norm2(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

/// Power iteration for `λ_max(XᵀX)` over any `(gemv, gemv_t)` pair — the
/// shared core of [`Mat::spectral_bound`] and `DataMat::spectral_bound`
/// (one implementation keeps the two storage backends' results
/// bit-identical by construction).
pub(crate) fn spectral_power_iteration(
    rows: usize,
    cols: usize,
    iters: usize,
    seed: u64,
    mut gemv: impl FnMut(&[f64], &mut [f64]),
    mut gemv_t: impl FnMut(&[f64], &mut [f64]),
) -> f64 {
    let mut rng = crate::rng::Pcg64::seeded(seed);
    let mut v: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
    let norm = norm2(&v);
    scale(1.0 / norm, &mut v);
    let mut lambda = 0.0;
    let mut xv = vec![0.0; rows];
    let mut xtxv = vec![0.0; cols];
    for _ in 0..iters {
        gemv(&v, &mut xv);
        gemv_t(&xv, &mut xtxv);
        lambda = dot(&v, &xtxv);
        let n = norm2(&xtxv);
        if n == 0.0 {
            return 0.0;
        }
        for (vi, xi) in v.iter_mut().zip(&xtxv) {
            *vi = xi / n;
        }
    }
    lambda
}

/// Dot product. Dispatches to the lane-bundle kernel under
/// `--features simd`, the scalar reference otherwise; both produce
/// bitwise-identical results (same mod-4 accumulation classes, same
/// left-associated lane reduction).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    if cfg!(feature = "simd") {
        dot_simd(a, b)
    } else {
        dot_scalar(a, b)
    }
}

/// Scalar reference dot product: 4-way unrolled accumulation —
/// measurably faster than naive fold and more accurate than a single
/// serial accumulator.
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Lane-bundle dot product: the 4 unrolled accumulators of
/// [`dot_scalar`] held in one [`F64x4`], so each accumulator lane sees
/// the same `j`-increasing sequence of adds and the horizontal sum
/// reduces in the same left-to-right order — bitwise-identical by
/// construction.
pub fn dot_simd(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let mut acc = F64x4::zero();
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc.mul_acc(F64x4::load(&a[j..j + 4]), F64x4::load(&b[j..j + 4]));
    }
    let mut s = acc.hsum();
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Elementwise `a - b` into a fresh vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "sub: length mismatch");
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// `out ← a − b`, reusing `out`'s allocation (scratch-friendly [`sub`]).
pub fn sub_into(a: &[f64], b: &[f64], out: &mut Vec<f64>) {
    assert_eq!(a.len(), b.len(), "sub_into: length mismatch");
    out.clear();
    out.extend(a.iter().zip(b).map(|(x, y)| x - y));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn norm2_pythagorean() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_length_mismatch_panics() {
        dot(&[1.0], &[1.0, 2.0]);
    }

    #[test]
    fn dot_simd_bitwise_matches_scalar() {
        for len in [0usize, 1, 3, 4, 7, 16, 37, 128] {
            let a: Vec<f64> = (0..len).map(|i| (i as f64 * 0.37).sin() * 1e3).collect();
            let b: Vec<f64> = (0..len).map(|i| (i as f64 * 0.61).cos()).collect();
            assert_eq!(dot_scalar(&a, &b).to_bits(), dot_simd(&a, &b).to_bits());
        }
    }
}
