//! Pluggable data-matrix storage: dense row-major vs compressed sparse
//! rows behind one [`DataMat`] surface.
//!
//! The paper's headline experiment is MovieLens matrix factorization —
//! sparse data — yet a dense [`Mat`] shard of an identity- or
//! replication-encoded sparse design matrix wastes `O(rows·p)` memory and
//! compute on structural zeros. [`CsrMat`] stores only the nonzeros and
//! implements the *same full fused-kernel surface* the worker hot path
//! needs (`gemv`, `gemv_t`, `fused_grad`, `fused_grad_range`, `gram`), so
//! every optimizer runs unchanged on either backend: coding-obliviousness
//! extends to storage.
//!
//! **Bitwise contract.** The CSR kernels *mirror the dense accumulation
//! order exactly* (the even/odd paired accumulators of the fused kernel,
//! the mod-4 accumulators of [`dot`](super::dot), the row-pair folded
//! scatter of `gemv_t`). A structural zero contributes `±0.0` to an
//! accumulator, and under round-to-nearest a partial sum of nonzero
//! products can never be `-0.0`, so skipping zeros is a bitwise no-op:
//! dense and CSR kernels return **identical bits** on the same data.
//! That is what lets `--storage sparse` reproduce the dense virtual-clock
//! optimizer trace bit for bit (`rust/tests/storage_equivalence.rs`)
//! while the simulated flop cost drops to the nnz-proportional truth.

use super::Mat;
use anyhow::{bail, Result};
use std::fmt;

// ---------------------------------------------------------------------------
// StorageKind
// ---------------------------------------------------------------------------

/// Shard storage backend selector (CLI/config surface: `--storage`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StorageKind {
    /// Dense row-major `Mat` shards (the historical representation).
    Dense,
    /// CSR shards; only valid where the encoding scheme preserves
    /// sparsity (identity / replication / gradient coding — fast
    /// transforms and random ensembles densify by construction).
    Sparse,
    /// Keep the input representation: sparse data stays CSR where the
    /// scheme allows it, dense data stays dense. The default.
    Auto,
}

impl StorageKind {
    /// Parse the CLI forms `dense`, `sparse`/`csr`, `auto`.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" => StorageKind::Dense,
            "sparse" | "csr" => StorageKind::Sparse,
            "auto" => StorageKind::Auto,
            other => bail!("unknown storage kind {other:?} (dense|sparse|auto)"),
        })
    }

    /// Canonical CLI/table label.
    pub fn label(&self) -> &'static str {
        match self {
            StorageKind::Dense => "dense",
            StorageKind::Sparse => "sparse",
            StorageKind::Auto => "auto",
        }
    }
}

impl fmt::Display for StorageKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------------
// CsrMat
// ---------------------------------------------------------------------------

/// Compressed-sparse-rows `rows × cols` matrix of `f64`.
///
/// Per row, column indices are strictly increasing and every stored value
/// is nonzero (both enforced by the constructors) — the invariants the
/// bitwise kernel mirror relies on.
#[derive(Clone, PartialEq)]
pub struct CsrMat {
    rows: usize,
    cols: usize,
    /// Row start offsets into `col_idx`/`vals`; length `rows + 1`.
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f64>,
}

impl fmt::Debug for CsrMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CsrMat({}x{}, nnz={})", self.rows, self.cols, self.nnz())
    }
}

impl CsrMat {
    /// Build from raw CSR arrays. Panics unless `row_ptr` is a valid
    /// monotone offset array, per-row columns are strictly increasing and
    /// in range, and every value is nonzero.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        vals: Vec<f64>,
    ) -> Self {
        assert_eq!(row_ptr.len(), rows + 1, "from_raw: row_ptr length");
        assert_eq!(col_idx.len(), vals.len(), "from_raw: col/val length mismatch");
        assert_eq!(*row_ptr.last().unwrap_or(&0), col_idx.len(), "from_raw: row_ptr end");
        assert!(cols <= u32::MAX as usize, "from_raw: too many columns for u32 indices");
        for i in 0..rows {
            let (lo, hi) = (row_ptr[i], row_ptr[i + 1]);
            assert!(lo <= hi, "from_raw: row_ptr not monotone at row {i}");
            for t in lo..hi {
                assert!((col_idx[t] as usize) < cols, "from_raw: column out of range");
                assert!(vals[t] != 0.0, "from_raw: explicit zero stored at row {i}");
                if t + 1 < hi {
                    assert!(col_idx[t] < col_idx[t + 1], "from_raw: columns not sorted in row {i}");
                }
            }
        }
        CsrMat { rows, cols, row_ptr, col_idx, vals }
    }

    /// Compress a dense matrix (drops exact zeros, keeps everything else).
    pub fn from_dense(m: &Mat) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        row_ptr.push(0);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for i in 0..rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        assert!(cols <= u32::MAX as usize, "from_dense: too many columns for u32 indices");
        CsrMat { rows, cols, row_ptr, col_idx, vals }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-nonzero count.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Fraction of entries stored (`nnz / (rows·cols)`; 0 for empty shapes).
    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Resident bytes of the three CSR arrays.
    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<u32>()
            + self.vals.len() * std::mem::size_of::<f64>()
    }

    /// Row `i` as `(column indices, values)` slices.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    /// Element `(i, j)` (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(t) => vals[t],
            Err(_) => 0.0,
        }
    }

    /// Expand to a dense [`Mat`].
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let dst = out.row_mut(i);
            for (c, v) in cols.iter().zip(vals) {
                dst[*c as usize] = *v;
            }
        }
        out
    }

    /// Contiguous row band `[lo, hi)` as a new CSR matrix.
    pub fn row_band(&self, lo: usize, hi: usize) -> CsrMat {
        assert!(lo <= hi && hi <= self.rows, "row_band: bad range {lo}..{hi}");
        let (plo, phi) = (self.row_ptr[lo], self.row_ptr[hi]);
        let row_ptr = self.row_ptr[lo..=hi].iter().map(|p| p - plo).collect();
        CsrMat {
            rows: hi - lo,
            cols: self.cols,
            row_ptr,
            col_idx: self.col_idx[plo..phi].to_vec(),
            vals: self.vals[plo..phi].to_vec(),
        }
    }

    /// Zero-pad to `new_rows` rows (empty rows; exact no-op for
    /// gradient/objective, mirroring [`Mat::pad_rows`]).
    pub fn pad_rows(&self, new_rows: usize) -> CsrMat {
        assert!(new_rows >= self.rows, "pad_rows: cannot shrink");
        let mut out = self.clone();
        out.row_ptr.resize(new_rows + 1, *self.row_ptr.last().unwrap());
        out.rows = new_rows;
        out
    }

    /// Stack matrices vertically (mirroring [`Mat::vstack`]): row order is
    /// block order, nnz structure is concatenated unchanged.
    pub fn vstack(blocks: &[&CsrMat]) -> CsrMat {
        assert!(!blocks.is_empty(), "vstack: empty input");
        let cols = blocks[0].cols;
        assert!(blocks.iter().all(|b| b.cols == cols), "vstack: column mismatch");
        let rows = blocks.iter().map(|b| b.rows).sum();
        let nnz = blocks.iter().map(|b| b.vals.len()).sum();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        row_ptr.push(0usize);
        for b in blocks {
            let base = *row_ptr.last().unwrap();
            row_ptr.extend(b.row_ptr[1..].iter().map(|p| p + base));
            col_idx.extend_from_slice(&b.col_idx);
            vals.extend_from_slice(&b.vals);
        }
        CsrMat { rows, cols, row_ptr, col_idx, vals }
    }

    // ------------------------------------------------------------- products
    //
    // Every kernel below mirrors its dense counterpart's accumulation
    // order (see the module docs for why skipping structural zeros is a
    // bitwise no-op).

    /// Matrix–vector product `self * x`.
    pub fn gemv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.gemv_into(x, &mut y);
        y
    }

    /// `y = self * x`; per-row accumulation mirrors [`dot`](super::dot).
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "gemv: dimension mismatch");
        assert_eq!(y.len(), self.rows, "gemv: output mismatch");
        for (i, yi) in y.iter_mut().enumerate() {
            let (cols, vals) = self.row(i);
            *yi = row_dot4(cols, vals, x, self.cols);
        }
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn gemv_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.gemv_t_into(x, &mut y);
        y
    }

    /// `y = selfᵀ x`; mirrors the dense row-pair folded scatter.
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "gemv_t: dimension mismatch");
        assert_eq!(y.len(), self.cols, "gemv_t: output mismatch");
        y.fill(0.0);
        let mut i = 0;
        while i + 1 < self.rows {
            scatter_pair(self.row(i), self.row(i + 1), x[i], x[i + 1], y);
            i += 2;
        }
        if i < self.rows {
            scatter1(x[i], self.row(i), y);
        }
    }

    /// Fused worker gradient `(g, ‖self·w − y‖²)` — the CSR mirror of
    /// [`Mat::fused_grad`]: identical pairing, identical bits.
    pub fn fused_grad(&self, w: &[f64], y: &[f64], g: &mut [f64], resid_buf: &mut [f64]) -> f64 {
        g.fill(0.0);
        self.fused_grad_range(w, y, g, resid_buf, 0, self.rows)
    }

    /// Row-restricted accumulating fused gradient — the CSR mirror of
    /// [`Mat::fused_grad_range`] (same contract: `g` not zeroed, callers
    /// compose disjoint ranges).
    pub fn fused_grad_range(
        &self,
        w: &[f64],
        y: &[f64],
        g: &mut [f64],
        resid_buf: &mut [f64],
        lo: usize,
        hi: usize,
    ) -> f64 {
        assert_eq!(w.len(), self.cols, "fused_grad: w mismatch");
        assert_eq!(y.len(), self.rows, "fused_grad: y mismatch");
        assert_eq!(g.len(), self.cols, "fused_grad: g mismatch");
        assert_eq!(resid_buf.len(), self.rows, "fused_grad: buffer mismatch");
        assert!(lo <= hi && hi <= self.rows, "fused_grad_range: bad range {lo}..{hi}");
        let mut f = 0.0;
        let mut i = lo;
        while i + 1 < hi {
            let r0 = self.row(i);
            let r1 = self.row(i + 1);
            let mut res0 = row_dot2(r0.0, r0.1, w, self.cols);
            let mut res1 = row_dot2(r1.0, r1.1, w, self.cols);
            res0 -= y[i];
            res1 -= y[i + 1];
            resid_buf[i] = res0;
            resid_buf[i + 1] = res1;
            f += res0 * res0 + res1 * res1;
            scatter_pair(r0, r1, res0, res1, g);
            i += 2;
        }
        if i < hi {
            let (cols, vals) = self.row(i);
            let r = row_dot4(cols, vals, w, self.cols) - y[i];
            resid_buf[i] = r;
            f += r * r;
            scatter1(r, (cols, vals), g);
        }
        f
    }

    /// Gram matrix `selfᵀ self` as a dense `cols × cols` matrix
    /// (rank-1 row updates over the upper triangle, then mirrored).
    pub fn gram(&self) -> Mat {
        let p = self.cols;
        let mut g = Mat::zeros(p, p);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for a in 0..cols.len() {
                let ja = cols[a] as usize;
                let va = vals[a];
                let grow = g.row_mut(ja);
                for b in a..cols.len() {
                    grow[cols[b] as usize] += va * vals[b];
                }
            }
        }
        for i in 0..p {
            for j in i + 1..p {
                let v = g.get(i, j);
                g.set(j, i, v);
            }
        }
        g
    }
}

// ---------------------------------------------------------------------------
// Mirrored row kernels
// ---------------------------------------------------------------------------

/// Sparse row dot mirroring [`dot`](super::dot)'s mod-4 accumulators:
/// entries with `col < 4·(n_cols/4)` fold into `acc[col % 4]` in column
/// order, the (≤3) tail columns add serially after the accumulator sum.
fn row_dot4(cols: &[u32], vals: &[f64], w: &[f64], n_cols: usize) -> f64 {
    let lim = (n_cols / 4) * 4;
    let mut acc = [0.0f64; 4];
    let mut t = 0;
    while t < cols.len() && (cols[t] as usize) < lim {
        let c = cols[t] as usize;
        acc[c % 4] += vals[t] * w[c];
        t += 1;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    while t < cols.len() {
        let c = cols[t] as usize;
        s += vals[t] * w[c];
        t += 1;
    }
    s
}

/// Sparse row dot mirroring the fused kernel's even/odd pair accumulators
/// (`d_a` even columns, `d_b` odd columns below `2·(n_cols/2)`, single
/// tail column added after the accumulator sum).
fn row_dot2(cols: &[u32], vals: &[f64], w: &[f64], n_cols: usize) -> f64 {
    let lim = (n_cols / 2) * 2;
    let (mut da, mut db) = (0.0f64, 0.0f64);
    let mut t = 0;
    while t < cols.len() && (cols[t] as usize) < lim {
        let c = cols[t] as usize;
        if c % 2 == 0 {
            da += vals[t] * w[c];
        } else {
            db += vals[t] * w[c];
        }
        t += 1;
    }
    let mut s = da + db;
    while t < cols.len() {
        let c = cols[t] as usize;
        s += vals[t] * w[c];
        t += 1;
    }
    s
}

/// `out[j] += coef * row[j]` over the stored entries (the dense kernel's
/// axpy restricted to nonzeros — a bitwise no-op elsewhere).
fn scatter1(coef: f64, row: (&[u32], &[f64]), out: &mut [f64]) {
    let (cols, vals) = row;
    for (c, v) in cols.iter().zip(vals) {
        out[*c as usize] += coef * v;
    }
}

/// `out[j] += c0·a_j + c1·b_j` merged over two sorted sparse rows,
/// evaluating the *same two-term expression* as the dense pair update
/// (with an explicit zero for the absent side) so the bits match.
fn scatter_pair(r0: (&[u32], &[f64]), r1: (&[u32], &[f64]), c0: f64, c1: f64, out: &mut [f64]) {
    let zero = 0.0f64;
    let (cols0, vals0) = r0;
    let (cols1, vals1) = r1;
    let (mut p, mut q) = (0, 0);
    while p < cols0.len() && q < cols1.len() {
        let (ca, cb) = (cols0[p], cols1[q]);
        if ca < cb {
            out[ca as usize] += c0 * vals0[p] + c1 * zero;
            p += 1;
        } else if cb < ca {
            out[cb as usize] += c0 * zero + c1 * vals1[q];
            q += 1;
        } else {
            out[ca as usize] += c0 * vals0[p] + c1 * vals1[q];
            p += 1;
            q += 1;
        }
    }
    while p < cols0.len() {
        out[cols0[p] as usize] += c0 * vals0[p] + c1 * zero;
        p += 1;
    }
    while q < cols1.len() {
        out[cols1[q] as usize] += c0 * zero + c1 * vals1[q];
        q += 1;
    }
}

// ---------------------------------------------------------------------------
// DataMat
// ---------------------------------------------------------------------------

/// A data matrix behind one of the two storage backends. This is the type
/// the encoded shards, the raw problem, and the compute engines hold —
/// the whole stack above the kernels is storage-oblivious.
#[derive(Clone, Debug, PartialEq)]
pub enum DataMat {
    /// Dense row-major storage.
    Dense(Mat),
    /// Compressed sparse rows.
    Csr(CsrMat),
}

impl From<Mat> for DataMat {
    fn from(m: Mat) -> Self {
        DataMat::Dense(m)
    }
}

impl From<CsrMat> for DataMat {
    fn from(m: CsrMat) -> Self {
        DataMat::Csr(m)
    }
}

impl DataMat {
    /// Row count.
    pub fn rows(&self) -> usize {
        match self {
            DataMat::Dense(m) => m.rows(),
            DataMat::Csr(m) => m.rows(),
        }
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        match self {
            DataMat::Dense(m) => m.cols(),
            DataMat::Csr(m) => m.cols(),
        }
    }

    /// True for CSR storage.
    pub fn is_sparse(&self) -> bool {
        matches!(self, DataMat::Csr(_))
    }

    /// The backend actually in use (never [`StorageKind::Auto`]).
    pub fn storage(&self) -> StorageKind {
        match self {
            DataMat::Dense(_) => StorageKind::Dense,
            DataMat::Csr(_) => StorageKind::Sparse,
        }
    }

    /// Multiply-adds one `gemv`-shaped pass over this matrix costs — the
    /// virtual-clock flop model's unit. Dense kernels touch every entry
    /// (`rows·cols`); CSR kernels touch only the stored nonzeros.
    pub fn gemv_madds(&self) -> f64 {
        match self {
            DataMat::Dense(m) => (m.rows() * m.cols()) as f64,
            DataMat::Csr(m) => m.nnz() as f64,
        }
    }

    /// Resident bytes of the payload arrays.
    pub fn mem_bytes(&self) -> usize {
        match self {
            DataMat::Dense(m) => m.rows() * m.cols() * std::mem::size_of::<f64>(),
            DataMat::Csr(m) => m.mem_bytes(),
        }
    }

    /// Element `(i, j)`.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            DataMat::Dense(m) => m.get(i, j),
            DataMat::Csr(m) => m.get(i, j),
        }
    }

    /// Borrow the dense matrix, if this is dense (the XLA staging path —
    /// AOT artifacts are dense-shaped and must fail fast on CSR).
    pub fn as_dense(&self) -> Option<&Mat> {
        match self {
            DataMat::Dense(m) => Some(m),
            DataMat::Csr(_) => None,
        }
    }

    /// Dense copy (expands CSR).
    pub fn to_dense(&self) -> Mat {
        match self {
            DataMat::Dense(m) => m.clone(),
            DataMat::Csr(m) => m.to_dense(),
        }
    }

    /// CSR copy (compresses dense).
    pub fn to_csr(&self) -> CsrMat {
        match self {
            DataMat::Dense(m) => CsrMat::from_dense(m),
            DataMat::Csr(m) => m.clone(),
        }
    }

    /// Convert into the requested backend ([`StorageKind::Auto`] keeps
    /// the current one). Conversion is value-exact in both directions.
    pub fn into_storage(self, storage: StorageKind) -> DataMat {
        match (storage, self) {
            (StorageKind::Auto, x) => x,
            (StorageKind::Dense, DataMat::Csr(c)) => DataMat::Dense(c.to_dense()),
            (StorageKind::Dense, x) => x,
            (StorageKind::Sparse, DataMat::Dense(d)) => DataMat::Csr(CsrMat::from_dense(&d)),
            (StorageKind::Sparse, x) => x,
        }
    }

    /// Contiguous row band `[lo, hi)` in the same backend.
    pub fn row_band(&self, lo: usize, hi: usize) -> DataMat {
        match self {
            DataMat::Dense(m) => DataMat::Dense(m.row_band(lo, hi)),
            DataMat::Csr(m) => DataMat::Csr(m.row_band(lo, hi)),
        }
    }

    /// Zero-pad to `new_rows` rows in the same backend (exact no-op for
    /// gradient/objective either way).
    pub fn pad_rows(&self, new_rows: usize) -> DataMat {
        match self {
            DataMat::Dense(m) => DataMat::Dense(m.pad_rows(new_rows)),
            DataMat::Csr(m) => DataMat::Csr(m.pad_rows(new_rows)),
        }
    }

    /// Stack matrices vertically, preserving the common backend. All
    /// blocks must share one backend: shards of an encoded problem always
    /// do (mixed input is a hard error, not a silent densification).
    pub fn vstack(blocks: &[&DataMat]) -> DataMat {
        assert!(!blocks.is_empty(), "vstack: empty input");
        if blocks.iter().all(|b| b.is_sparse()) {
            let csr: Vec<&CsrMat> = blocks
                .iter()
                .map(|b| match b {
                    DataMat::Csr(m) => m,
                    DataMat::Dense(_) => unreachable!(),
                })
                .collect();
            DataMat::Csr(CsrMat::vstack(&csr))
        } else if blocks.iter().all(|b| !b.is_sparse()) {
            let dense: Vec<&Mat> = blocks
                .iter()
                .map(|b| match b {
                    DataMat::Dense(m) => m,
                    DataMat::Csr(_) => unreachable!(),
                })
                .collect();
            DataMat::Dense(Mat::vstack(&dense))
        } else {
            panic!("vstack: mixed dense/CSR blocks");
        }
    }

    /// Max `|a_ij − b_ij|` across backends.
    pub fn max_abs_diff(&self, other: &DataMat) -> f64 {
        assert_eq!((self.rows(), self.cols()), (other.rows(), other.cols()));
        let mut d = 0.0f64;
        for i in 0..self.rows() {
            for j in 0..self.cols() {
                d = d.max((self.get(i, j) - other.get(i, j)).abs());
            }
        }
        d
    }

    /// Matrix–vector product `self * x`.
    pub fn gemv(&self, x: &[f64]) -> Vec<f64> {
        match self {
            DataMat::Dense(m) => m.gemv(x),
            DataMat::Csr(m) => m.gemv(x),
        }
    }

    /// `y = self * x` into a caller buffer.
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            DataMat::Dense(m) => m.gemv_into(x, y),
            DataMat::Csr(m) => m.gemv_into(x, y),
        }
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn gemv_t(&self, x: &[f64]) -> Vec<f64> {
        match self {
            DataMat::Dense(m) => m.gemv_t(x),
            DataMat::Csr(m) => m.gemv_t(x),
        }
    }

    /// `y = selfᵀ x` into a caller buffer.
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            DataMat::Dense(m) => m.gemv_t_into(x, y),
            DataMat::Csr(m) => m.gemv_t_into(x, y),
        }
    }

    /// Fused worker gradient; see [`Mat::fused_grad`].
    pub fn fused_grad(&self, w: &[f64], y: &[f64], g: &mut [f64], resid_buf: &mut [f64]) -> f64 {
        match self {
            DataMat::Dense(m) => m.fused_grad(w, y, g, resid_buf),
            DataMat::Csr(m) => m.fused_grad(w, y, g, resid_buf),
        }
    }

    /// Row-restricted accumulating fused gradient; see
    /// [`Mat::fused_grad_range`].
    pub fn fused_grad_range(
        &self,
        w: &[f64],
        y: &[f64],
        g: &mut [f64],
        resid_buf: &mut [f64],
        lo: usize,
        hi: usize,
    ) -> f64 {
        match self {
            DataMat::Dense(m) => m.fused_grad_range(w, y, g, resid_buf, lo, hi),
            DataMat::Csr(m) => m.fused_grad_range(w, y, g, resid_buf, lo, hi),
        }
    }

    /// Gram matrix `selfᵀ self` (always dense `cols × cols`).
    pub fn gram(&self) -> Mat {
        match self {
            DataMat::Dense(m) => m.gram(),
            DataMat::Csr(m) => m.gram(),
        }
    }

    /// Largest eigenvalue of `selfᵀ self` by power iteration — the same
    /// shared implementation as [`Mat::spectral_bound`] (and, via the
    /// mirrored kernels, the same bits) on either backend.
    pub fn spectral_bound(&self, iters: usize, seed: u64) -> f64 {
        super::spectral_power_iteration(
            self.rows(),
            self.cols(),
            iters,
            seed,
            |v, out| self.gemv_into(v, out),
            |v, out| self.gemv_t_into(v, out),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| {
            if rng.next_f64() < density {
                rng.next_gaussian()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn from_dense_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let d = random_sparse(&mut rng, 13, 9, 0.3);
        let s = CsrMat::from_dense(&d);
        assert_eq!(s.rows(), 13);
        assert_eq!(s.cols(), 9);
        assert!(s.to_dense().max_abs_diff(&d) == 0.0);
        for i in 0..13 {
            for j in 0..9 {
                assert_eq!(s.get(i, j), d.get(i, j));
            }
        }
    }

    #[test]
    fn nnz_and_density_and_memory() {
        let d = Mat::from_fn(4, 5, |i, j| if (i + j) % 2 == 0 { 1.0 } else { 0.0 });
        let s = CsrMat::from_dense(&d);
        assert_eq!(s.nnz(), 10);
        assert!((s.density() - 0.5).abs() < 1e-15);
        assert!(s.mem_bytes() > 0);
        // MovieLens-shaped shard: 3 nnz per row, wide — CSR far smaller
        let wide = Mat::from_fn(64, 400, |i, j| if j == i || j == 399 { 1.0 } else { 0.0 });
        let sw = CsrMat::from_dense(&wide);
        assert!(sw.mem_bytes() * 10 < 64 * 400 * 8);
    }

    #[test]
    fn row_band_and_pad_rows() {
        let mut rng = Pcg64::seeded(2);
        let d = random_sparse(&mut rng, 10, 6, 0.4);
        let s = CsrMat::from_dense(&d);
        let band = s.row_band(3, 8);
        assert!(band.to_dense().max_abs_diff(&d.row_band(3, 8)) == 0.0);
        let padded = s.pad_rows(16);
        assert_eq!(padded.rows(), 16);
        assert_eq!(padded.nnz(), s.nnz());
        for j in 0..6 {
            assert_eq!(padded.get(12, j), 0.0);
        }
    }

    #[test]
    fn gemv_matches_dense_bitwise() {
        let mut rng = Pcg64::seeded(3);
        for &(r, c, den) in &[(1usize, 1usize, 1.0), (7, 5, 0.5), (20, 17, 0.2), (9, 33, 0.05)] {
            let d = random_sparse(&mut rng, r, c, den);
            let s = CsrMat::from_dense(&d);
            let x: Vec<f64> = (0..c).map(|_| rng.next_gaussian()).collect();
            let yd = d.gemv(&x);
            let ys = s.gemv(&x);
            for (a, b) in yd.iter().zip(&ys) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn gemv_t_matches_dense_bitwise() {
        let mut rng = Pcg64::seeded(4);
        for &(r, c, den) in &[(6usize, 4usize, 0.6), (11, 8, 0.3), (16, 3, 0.2)] {
            let d = random_sparse(&mut rng, r, c, den);
            let s = CsrMat::from_dense(&d);
            let x: Vec<f64> = (0..r).map(|_| rng.next_gaussian()).collect();
            let yd = d.gemv_t(&x);
            let ys = s.gemv_t(&x);
            for (a, b) in yd.iter().zip(&ys) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn fused_grad_matches_dense_bitwise() {
        let mut rng = Pcg64::seeded(5);
        for &(r, c, den) in &[(12usize, 7usize, 0.4), (25, 10, 0.15), (8, 2, 0.9)] {
            let d = random_sparse(&mut rng, r, c, den);
            let s = CsrMat::from_dense(&d);
            let w: Vec<f64> = (0..c).map(|_| rng.next_gaussian()).collect();
            let y: Vec<f64> = (0..r).map(|_| rng.next_gaussian()).collect();
            let (mut gd, mut gs) = (vec![0.0; c], vec![0.0; c]);
            let (mut bd, mut bs) = (vec![0.0; r], vec![0.0; r]);
            let fd = d.fused_grad(&w, &y, &mut gd, &mut bd);
            let fs = s.fused_grad(&w, &y, &mut gs, &mut bs);
            assert_eq!(fd.to_bits(), fs.to_bits());
            for (a, b) in gd.iter().zip(&gs) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in bd.iter().zip(&bs) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn gram_matches_dense() {
        let mut rng = Pcg64::seeded(6);
        let d = random_sparse(&mut rng, 20, 8, 0.35);
        let s = CsrMat::from_dense(&d);
        assert!(s.gram().max_abs_diff(&d.gram()) < 1e-12);
    }

    #[test]
    fn empty_rows_and_columns_are_handled() {
        // rows 2 and 5 fully empty; column 1 never touched
        let d = Mat::from_fn(7, 4, |i, j| {
            if i == 2 || i == 5 || j == 1 {
                0.0
            } else {
                (i * 4 + j + 1) as f64
            }
        });
        let s = CsrMat::from_dense(&d);
        let w = vec![0.5, -1.0, 2.0, 0.25];
        let y = vec![0.1; 7];
        let (mut gd, mut gs) = (vec![0.0; 4], vec![0.0; 4]);
        let (mut bd, mut bs) = (vec![0.0; 7], vec![0.0; 7]);
        let fd = d.fused_grad(&w, &y, &mut gd, &mut bd);
        let fs = s.fused_grad(&w, &y, &mut gs, &mut bs);
        assert_eq!(fd.to_bits(), fs.to_bits());
        for (a, b) in gd.iter().zip(&gs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn datamat_storage_conversions() {
        let mut rng = Pcg64::seeded(7);
        let d = random_sparse(&mut rng, 9, 5, 0.3);
        let dm: DataMat = d.clone().into();
        assert!(!dm.is_sparse());
        assert_eq!(dm.storage(), StorageKind::Dense);
        let sp = dm.clone().into_storage(StorageKind::Sparse);
        assert!(sp.is_sparse());
        assert_eq!(sp.to_dense().max_abs_diff(&d), 0.0);
        let back = sp.clone().into_storage(StorageKind::Dense);
        assert!(!back.is_sparse());
        assert_eq!(sp.into_storage(StorageKind::Auto).storage(), StorageKind::Sparse);
        assert_eq!(back.max_abs_diff(&dm), 0.0);
    }

    #[test]
    fn datamat_flop_model_is_nnz_proportional() {
        let d = Mat::from_fn(8, 10, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let dense: DataMat = d.clone().into();
        let sparse: DataMat = CsrMat::from_dense(&d).into();
        assert_eq!(dense.gemv_madds(), 80.0);
        assert_eq!(sparse.gemv_madds(), 8.0);
        assert!(sparse.mem_bytes() < dense.mem_bytes());
    }

    #[test]
    fn spectral_bound_matches_across_backends() {
        let mut rng = Pcg64::seeded(8);
        let d = random_sparse(&mut rng, 24, 6, 0.4);
        let dense: DataMat = d.clone().into();
        let sparse: DataMat = CsrMat::from_dense(&d).into();
        let a = dense.spectral_bound(40, 3);
        let b = sparse.spectral_bound(40, 3);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn storage_kind_parse_roundtrip() {
        for kind in [StorageKind::Dense, StorageKind::Sparse, StorageKind::Auto] {
            assert_eq!(StorageKind::parse(kind.label()).unwrap(), kind);
        }
        assert_eq!(StorageKind::parse("csr").unwrap(), StorageKind::Sparse);
        assert!(StorageKind::parse("ram").is_err());
    }

    #[test]
    #[should_panic(expected = "columns not sorted")]
    fn from_raw_rejects_unsorted() {
        CsrMat::from_raw(1, 4, vec![0, 2], vec![2, 1], vec![1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "explicit zero")]
    fn from_raw_rejects_stored_zero() {
        CsrMat::from_raw(1, 4, vec![0, 1], vec![0], vec![0.0]);
    }
}
