//! Pluggable data-matrix storage: dense row-major vs compressed sparse
//! rows behind one [`DataMat`] surface.
//!
//! The paper's headline experiment is MovieLens matrix factorization —
//! sparse data — yet a dense [`Mat`] shard of an identity- or
//! replication-encoded sparse design matrix wastes `O(rows·p)` memory and
//! compute on structural zeros. [`CsrMat`] stores only the nonzeros and
//! implements the *same full fused-kernel surface* the worker hot path
//! needs (`gemv`, `gemv_t`, `fused_grad`, `fused_grad_range`, `gram`), so
//! every optimizer runs unchanged on either backend: coding-obliviousness
//! extends to storage.
//!
//! **Bitwise contract.** The CSR kernels *mirror the dense accumulation
//! order exactly* (the even/odd paired accumulators of the fused kernel,
//! the mod-4 accumulators of [`dot`](super::dot), the row-pair folded
//! scatter of `gemv_t`). A structural zero contributes `±0.0` to an
//! accumulator, and under round-to-nearest a partial sum of nonzero
//! products can never be `-0.0`, so skipping zeros is a bitwise no-op:
//! dense and CSR kernels return **identical bits** on the same data.
//! That is what lets `--storage sparse` reproduce the dense virtual-clock
//! optimizer trace bit for bit (`rust/tests/storage_equivalence.rs`)
//! while the simulated flop cost drops to the nnz-proportional truth.

use super::Mat;
use anyhow::{bail, Result};
use std::fmt;

// ---------------------------------------------------------------------------
// StorageKind
// ---------------------------------------------------------------------------

/// Shard storage backend selector (CLI/config surface: `--storage`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StorageKind {
    /// Dense row-major `Mat` shards (the historical representation).
    Dense,
    /// CSR shards; only valid where the encoding scheme preserves
    /// sparsity (identity / replication / gradient coding — fast
    /// transforms and random ensembles densify by construction).
    Sparse,
    /// Keep the input representation: sparse data stays CSR where the
    /// scheme allows it, dense data stays dense. The default.
    Auto,
}

impl StorageKind {
    /// Parse the CLI forms `dense`, `sparse`/`csr`, `auto`.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" => StorageKind::Dense,
            "sparse" | "csr" => StorageKind::Sparse,
            "auto" => StorageKind::Auto,
            other => bail!("unknown storage kind {other:?} (dense|sparse|auto)"),
        })
    }

    /// Canonical CLI/table label.
    pub fn label(&self) -> &'static str {
        match self {
            StorageKind::Dense => "dense",
            StorageKind::Sparse => "sparse",
            StorageKind::Auto => "auto",
        }
    }
}

impl fmt::Display for StorageKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------------
// Precision
// ---------------------------------------------------------------------------

/// Shard numeric precision selector (CLI/config surface: `--precision`).
///
/// Under [`Precision::F32`] workers hold encoded shards in f32 and compute
/// shard gradients in f32, while the leader keeps accumulating gradients
/// and taking optimizer steps in f64 — mixed precision in the sense that
/// Theorem 1's approximation-neighborhood guarantee tolerates: the worker
/// rounding error lands inside the same controllable neighborhood the
/// encoding already converges to (pinned by the convergence-quality test
/// in `rust/tests/kernel_equivalence.rs`). Shard memory and bandwidth
/// halve; the virtual flop model is adjusted accordingly
/// ([`DataMat::gemv_madds`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full f64 everywhere (the historical mode; bit-for-bit traces).
    #[default]
    F64,
    /// f32 shard storage + worker compute, f64 leader accumulation.
    F32,
}

impl Precision {
    /// Parse the CLI forms `f64`, `f32`.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f64" => Precision::F64,
            "f32" => Precision::F32,
            other => bail!("unknown precision {other:?} (f64|f32)"),
        })
    }

    /// Canonical CLI/table label.
    pub fn label(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------------
// GradMode
// ---------------------------------------------------------------------------

/// Worker-gradient evaluation strategy (CLI/config surface: `--grad-mode`).
///
/// A ridge worker gradient factors as `g = G·w − c` with `G = X̃ᵀX̃` and
/// `c = X̃ᵀỹ` fixed for the life of the shard, and the local objective as
/// `f = wᵀGw − 2wᵀc + ỹᵀỹ` — so a worker can trade `O(2·nnz)` madds per
/// round (two passes over the shard) for `O(p²)` madds against a
/// precomputed Gram cache, at `p²` extra resident doubles. [`GradMode`]
/// selects that trade per run; `Auto` resolves it per *shard* from the
/// madd cost model (`p² < 2·nnz`).
///
/// The Gram path reassociates the accumulation, so it carries a numeric
/// (≤ 1e-9 final iterate) pin rather than the bitwise pin of the default
/// `Gemv` mode — see DESIGN.md "Steady-state memory & the Gram fast path".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum GradMode {
    /// Recompute `X̃ᵀ(X̃w − ỹ)` from the shard every round (the
    /// historical mode; bit-for-bit traces, works on every backend).
    #[default]
    Gemv,
    /// Serve gradients from a per-shard Gram cache (`G = X̃ᵀX̃`,
    /// `c = X̃ᵀỹ` precomputed at staging): one symmetric f64 gemv per
    /// round. Dense f64 shards only.
    Gram,
    /// Per shard: `Gram` iff the cost model favors it (`p² < 2·nnz`) and
    /// the shard is dense f64, else `Gemv`.
    Auto,
}

impl GradMode {
    /// Parse the CLI forms `gemv`, `gram`, `auto`.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "gemv" => GradMode::Gemv,
            "gram" => GradMode::Gram,
            "auto" => GradMode::Auto,
            other => bail!("unknown grad mode {other:?} (gemv|gram|auto)"),
        })
    }

    /// Canonical CLI/table label.
    pub fn label(&self) -> &'static str {
        match self {
            GradMode::Gemv => "gemv",
            GradMode::Gram => "gram",
            GradMode::Auto => "auto",
        }
    }
}

impl fmt::Display for GradMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------------
// CsrMat
// ---------------------------------------------------------------------------

/// Compressed-sparse-rows `rows × cols` matrix of `f64`.
///
/// Per row, column indices are strictly increasing and every stored value
/// is nonzero (both enforced by the constructors) — the invariants the
/// bitwise kernel mirror relies on.
#[derive(Clone, PartialEq)]
pub struct CsrMat {
    rows: usize,
    cols: usize,
    /// Row start offsets into `col_idx`/`vals`; length `rows + 1`.
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f64>,
}

impl fmt::Debug for CsrMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CsrMat({}x{}, nnz={})", self.rows, self.cols, self.nnz())
    }
}

impl CsrMat {
    /// Build from raw CSR arrays. Panics unless `row_ptr` is a valid
    /// monotone offset array, per-row columns are strictly increasing and
    /// in range, and every value is nonzero.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        vals: Vec<f64>,
    ) -> Self {
        assert_eq!(row_ptr.len(), rows + 1, "from_raw: row_ptr length");
        assert_eq!(col_idx.len(), vals.len(), "from_raw: col/val length mismatch");
        assert_eq!(*row_ptr.last().unwrap_or(&0), col_idx.len(), "from_raw: row_ptr end");
        assert!(cols <= u32::MAX as usize, "from_raw: too many columns for u32 indices");
        for i in 0..rows {
            let (lo, hi) = (row_ptr[i], row_ptr[i + 1]);
            assert!(lo <= hi, "from_raw: row_ptr not monotone at row {i}");
            for t in lo..hi {
                assert!((col_idx[t] as usize) < cols, "from_raw: column out of range");
                assert!(vals[t] != 0.0, "from_raw: explicit zero stored at row {i}");
                if t + 1 < hi {
                    assert!(col_idx[t] < col_idx[t + 1], "from_raw: columns not sorted in row {i}");
                }
            }
        }
        CsrMat { rows, cols, row_ptr, col_idx, vals }
    }

    /// Compress a dense matrix (drops exact zeros, keeps everything else).
    pub fn from_dense(m: &Mat) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        row_ptr.push(0);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for i in 0..rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        assert!(cols <= u32::MAX as usize, "from_dense: too many columns for u32 indices");
        CsrMat { rows, cols, row_ptr, col_idx, vals }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-nonzero count.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Fraction of entries stored (`nnz / (rows·cols)`; 0 for empty shapes).
    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Resident bytes of the three CSR arrays.
    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<u32>()
            + self.vals.len() * std::mem::size_of::<f64>()
    }

    /// Row `i` as `(column indices, values)` slices.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    /// Element `(i, j)` (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(t) => vals[t],
            Err(_) => 0.0,
        }
    }

    /// Expand to a dense [`Mat`].
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let dst = out.row_mut(i);
            for (c, v) in cols.iter().zip(vals) {
                dst[*c as usize] = *v;
            }
        }
        out
    }

    /// Contiguous row band `[lo, hi)` as a new CSR matrix.
    pub fn row_band(&self, lo: usize, hi: usize) -> CsrMat {
        assert!(lo <= hi && hi <= self.rows, "row_band: bad range {lo}..{hi}");
        let (plo, phi) = (self.row_ptr[lo], self.row_ptr[hi]);
        let row_ptr = self.row_ptr[lo..=hi].iter().map(|p| p - plo).collect();
        CsrMat {
            rows: hi - lo,
            cols: self.cols,
            row_ptr,
            col_idx: self.col_idx[plo..phi].to_vec(),
            vals: self.vals[plo..phi].to_vec(),
        }
    }

    /// Zero-pad to `new_rows` rows (empty rows; exact no-op for
    /// gradient/objective, mirroring [`Mat::pad_rows`]).
    pub fn pad_rows(&self, new_rows: usize) -> CsrMat {
        assert!(new_rows >= self.rows, "pad_rows: cannot shrink");
        let mut out = self.clone();
        out.row_ptr.resize(new_rows + 1, *self.row_ptr.last().unwrap());
        out.rows = new_rows;
        out
    }

    /// Stack matrices vertically (mirroring [`Mat::vstack`]): row order is
    /// block order, nnz structure is concatenated unchanged.
    pub fn vstack(blocks: &[&CsrMat]) -> CsrMat {
        assert!(!blocks.is_empty(), "vstack: empty input");
        let cols = blocks[0].cols;
        assert!(blocks.iter().all(|b| b.cols == cols), "vstack: column mismatch");
        let rows = blocks.iter().map(|b| b.rows).sum();
        let nnz = blocks.iter().map(|b| b.vals.len()).sum();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        row_ptr.push(0usize);
        for b in blocks {
            let base = *row_ptr.last().unwrap();
            row_ptr.extend(b.row_ptr[1..].iter().map(|p| p + base));
            col_idx.extend_from_slice(&b.col_idx);
            vals.extend_from_slice(&b.vals);
        }
        CsrMat { rows, cols, row_ptr, col_idx, vals }
    }

    // ------------------------------------------------------------- products
    //
    // Every kernel below mirrors its dense counterpart's accumulation
    // order (see the module docs for why skipping structural zeros is a
    // bitwise no-op).

    /// Matrix–vector product `self * x`.
    pub fn gemv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.gemv_into(x, &mut y);
        y
    }

    /// `y = self * x`; per-row accumulation mirrors [`dot`](super::dot).
    /// Dispatches to the 4-way-unrolled entry loop under
    /// `--features simd` (same sequential accumulation-class order →
    /// bitwise-identical; gather kernels vectorize through ILP, not lanes).
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        if cfg!(feature = "simd") {
            csr_gemv_into_simd(self, x, y)
        } else {
            csr_gemv_into_scalar(self, x, y)
        }
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn gemv_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.gemv_t_into(x, &mut y);
        y
    }

    /// `y = selfᵀ x`; mirrors the dense row-pair folded scatter.
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        if cfg!(feature = "simd") {
            csr_gemv_t_into_simd(self, x, y)
        } else {
            csr_gemv_t_into_scalar(self, x, y)
        }
    }

    /// Fused worker gradient `(g, ‖self·w − y‖²)` — the CSR mirror of
    /// [`Mat::fused_grad`]: identical pairing, identical bits.
    pub fn fused_grad(&self, w: &[f64], y: &[f64], g: &mut [f64], resid_buf: &mut [f64]) -> f64 {
        g.fill(0.0);
        self.fused_grad_range(w, y, g, resid_buf, 0, self.rows)
    }

    /// Row-restricted accumulating fused gradient — the CSR mirror of
    /// [`Mat::fused_grad_range`] (same contract: `g` not zeroed, callers
    /// compose disjoint ranges).
    pub fn fused_grad_range(
        &self,
        w: &[f64],
        y: &[f64],
        g: &mut [f64],
        resid_buf: &mut [f64],
        lo: usize,
        hi: usize,
    ) -> f64 {
        if cfg!(feature = "simd") {
            csr_fused_grad_range_simd(self, w, y, g, resid_buf, lo, hi)
        } else {
            csr_fused_grad_range_scalar(self, w, y, g, resid_buf, lo, hi)
        }
    }

    /// Gram matrix `selfᵀ self` as a dense `cols × cols` matrix
    /// (rank-1 row updates over the upper triangle, then mirrored).
    pub fn gram(&self) -> Mat {
        let p = self.cols;
        let mut g = Mat::zeros(p, p);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for a in 0..cols.len() {
                let ja = cols[a] as usize;
                let va = vals[a];
                let grow = g.row_mut(ja);
                for b in a..cols.len() {
                    grow[cols[b] as usize] += va * vals[b];
                }
            }
        }
        for i in 0..p {
            for j in i + 1..p {
                let v = g.get(i, j);
                g.set(j, i, v);
            }
        }
        g
    }
}

// ---------------------------------------------------------------------------
// Mirrored row kernels — scalar reference + unrolled ("simd") variants
// ---------------------------------------------------------------------------
//
// CSR products are gather kernels: each stored entry folds into an
// accumulation class chosen by its *column* (`col % 4` / `col % 2`), so a
// lane-bundle rewrite would reorder the per-class add sequence and break
// the bitwise dense≡sparse contract. The `simd`-feature variants instead
// 4-way unroll the entry loop — the operation sequence is untouched
// (bitwise-identical by construction), but the index/load work of four
// entries overlaps, which is where gather throughput actually comes from.
// Both variants of every kernel are compiled in every build and exposed
// through `linalg::kernels` for the equivalence suite.

/// Scalar reference CSR GEMV (per-row [`row_dot4`]).
pub fn csr_gemv_into_scalar(m: &CsrMat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.cols, "gemv: dimension mismatch");
    assert_eq!(y.len(), m.rows, "gemv: output mismatch");
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = m.row(i);
        *yi = row_dot4(cols, vals, x, m.cols);
    }
}

/// Unrolled CSR GEMV (per-row [`row_dot4_x4`]) — bitwise-identical.
pub fn csr_gemv_into_simd(m: &CsrMat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.cols, "gemv: dimension mismatch");
    assert_eq!(y.len(), m.rows, "gemv: output mismatch");
    for (i, yi) in y.iter_mut().enumerate() {
        let (cols, vals) = m.row(i);
        *yi = row_dot4_x4(cols, vals, x, m.cols);
    }
}

/// Scalar reference CSR transposed GEMV (row-pair folded scatter).
pub fn csr_gemv_t_into_scalar(m: &CsrMat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.rows, "gemv_t: dimension mismatch");
    assert_eq!(y.len(), m.cols, "gemv_t: output mismatch");
    y.fill(0.0);
    let mut i = 0;
    while i + 1 < m.rows {
        scatter_pair(m.row(i), m.row(i + 1), x[i], x[i + 1], y);
        i += 2;
    }
    if i < m.rows {
        scatter1(x[i], m.row(i), y);
    }
}

/// Unrolled CSR transposed GEMV: same merged pair scatter (its order is
/// data-dependent and must not change), odd-row tail via the unrolled
/// single-row scatter — bitwise-identical.
pub fn csr_gemv_t_into_simd(m: &CsrMat, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.rows, "gemv_t: dimension mismatch");
    assert_eq!(y.len(), m.cols, "gemv_t: output mismatch");
    y.fill(0.0);
    let mut i = 0;
    while i + 1 < m.rows {
        scatter_pair(m.row(i), m.row(i + 1), x[i], x[i + 1], y);
        i += 2;
    }
    if i < m.rows {
        scatter1_x4(x[i], m.row(i), y);
    }
}

/// Scalar reference CSR fused gradient over rows `[lo, hi)` (the
/// historical [`CsrMat::fused_grad_range`] body).
#[allow(clippy::too_many_arguments)]
pub fn csr_fused_grad_range_scalar(
    m: &CsrMat,
    w: &[f64],
    y: &[f64],
    g: &mut [f64],
    resid_buf: &mut [f64],
    lo: usize,
    hi: usize,
) -> f64 {
    assert_eq!(w.len(), m.cols, "fused_grad: w mismatch");
    assert_eq!(y.len(), m.rows, "fused_grad: y mismatch");
    assert_eq!(g.len(), m.cols, "fused_grad: g mismatch");
    assert_eq!(resid_buf.len(), m.rows, "fused_grad: buffer mismatch");
    assert!(lo <= hi && hi <= m.rows, "fused_grad_range: bad range {lo}..{hi}");
    let mut f = 0.0;
    let mut i = lo;
    while i + 1 < hi {
        let r0 = m.row(i);
        let r1 = m.row(i + 1);
        let mut res0 = row_dot2(r0.0, r0.1, w, m.cols);
        let mut res1 = row_dot2(r1.0, r1.1, w, m.cols);
        res0 -= y[i];
        res1 -= y[i + 1];
        resid_buf[i] = res0;
        resid_buf[i + 1] = res1;
        f += res0 * res0 + res1 * res1;
        scatter_pair(r0, r1, res0, res1, g);
        i += 2;
    }
    if i < hi {
        let (cols, vals) = m.row(i);
        let r = row_dot4(cols, vals, w, m.cols) - y[i];
        resid_buf[i] = r;
        f += r * r;
        scatter1(r, (cols, vals), g);
    }
    f
}

/// Unrolled CSR fused gradient ([`row_dot2_x4`]/[`row_dot4_x4`] dots,
/// shared pair scatter) — bitwise-identical.
#[allow(clippy::too_many_arguments)]
pub fn csr_fused_grad_range_simd(
    m: &CsrMat,
    w: &[f64],
    y: &[f64],
    g: &mut [f64],
    resid_buf: &mut [f64],
    lo: usize,
    hi: usize,
) -> f64 {
    assert_eq!(w.len(), m.cols, "fused_grad: w mismatch");
    assert_eq!(y.len(), m.rows, "fused_grad: y mismatch");
    assert_eq!(g.len(), m.cols, "fused_grad: g mismatch");
    assert_eq!(resid_buf.len(), m.rows, "fused_grad: buffer mismatch");
    assert!(lo <= hi && hi <= m.rows, "fused_grad_range: bad range {lo}..{hi}");
    let mut f = 0.0;
    let mut i = lo;
    while i + 1 < hi {
        let r0 = m.row(i);
        let r1 = m.row(i + 1);
        let mut res0 = row_dot2_x4(r0.0, r0.1, w, m.cols);
        let mut res1 = row_dot2_x4(r1.0, r1.1, w, m.cols);
        res0 -= y[i];
        res1 -= y[i + 1];
        resid_buf[i] = res0;
        resid_buf[i + 1] = res1;
        f += res0 * res0 + res1 * res1;
        scatter_pair(r0, r1, res0, res1, g);
        i += 2;
    }
    if i < hi {
        let (cols, vals) = m.row(i);
        let r = row_dot4_x4(cols, vals, w, m.cols) - y[i];
        resid_buf[i] = r;
        f += r * r;
        scatter1_x4(r, (cols, vals), g);
    }
    f
}

/// Sparse row dot mirroring [`dot`](super::dot)'s mod-4 accumulators:
/// entries with `col < 4·(n_cols/4)` fold into `acc[col % 4]` in column
/// order, the (≤3) tail columns add serially after the accumulator sum.
fn row_dot4(cols: &[u32], vals: &[f64], w: &[f64], n_cols: usize) -> f64 {
    let lim = (n_cols / 4) * 4;
    let mut acc = [0.0f64; 4];
    let mut t = 0;
    while t < cols.len() && (cols[t] as usize) < lim {
        let c = cols[t] as usize;
        acc[c % 4] += vals[t] * w[c];
        t += 1;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    while t < cols.len() {
        let c = cols[t] as usize;
        s += vals[t] * w[c];
        t += 1;
    }
    s
}

/// Sparse row dot mirroring the fused kernel's even/odd pair accumulators
/// (`d_a` even columns, `d_b` odd columns below `2·(n_cols/2)`, single
/// tail column added after the accumulator sum).
fn row_dot2(cols: &[u32], vals: &[f64], w: &[f64], n_cols: usize) -> f64 {
    let lim = (n_cols / 2) * 2;
    let (mut da, mut db) = (0.0f64, 0.0f64);
    let mut t = 0;
    while t < cols.len() && (cols[t] as usize) < lim {
        let c = cols[t] as usize;
        if c % 2 == 0 {
            da += vals[t] * w[c];
        } else {
            db += vals[t] * w[c];
        }
        t += 1;
    }
    let mut s = da + db;
    while t < cols.len() {
        let c = cols[t] as usize;
        s += vals[t] * w[c];
        t += 1;
    }
    s
}

/// `out[j] += coef * row[j]` over the stored entries (the dense kernel's
/// axpy restricted to nonzeros — a bitwise no-op elsewhere).
fn scatter1(coef: f64, row: (&[u32], &[f64]), out: &mut [f64]) {
    let (cols, vals) = row;
    for (c, v) in cols.iter().zip(vals) {
        out[*c as usize] += coef * v;
    }
}

/// `out[j] += c0·a_j + c1·b_j` merged over two sorted sparse rows,
/// evaluating the *same two-term expression* as the dense pair update
/// (with an explicit zero for the absent side) so the bits match.
fn scatter_pair(r0: (&[u32], &[f64]), r1: (&[u32], &[f64]), c0: f64, c1: f64, out: &mut [f64]) {
    let zero = 0.0f64;
    let (cols0, vals0) = r0;
    let (cols1, vals1) = r1;
    let (mut p, mut q) = (0, 0);
    while p < cols0.len() && q < cols1.len() {
        let (ca, cb) = (cols0[p], cols1[q]);
        if ca < cb {
            out[ca as usize] += c0 * vals0[p] + c1 * zero;
            p += 1;
        } else if cb < ca {
            out[cb as usize] += c0 * zero + c1 * vals1[q];
            q += 1;
        } else {
            out[ca as usize] += c0 * vals0[p] + c1 * vals1[q];
            p += 1;
            q += 1;
        }
    }
    while p < cols0.len() {
        out[cols0[p] as usize] += c0 * vals0[p] + c1 * zero;
        p += 1;
    }
    while q < cols1.len() {
        out[cols1[q] as usize] += c0 * zero + c1 * vals1[q];
        q += 1;
    }
}

/// [`row_dot4`] with the entry loop unrolled by 4. Entries still fold
/// into `acc[col % 4]` strictly in storage order — the unrolled body is
/// the same four sequential statements, so the bits cannot differ; the
/// win is overlapped index decode + gather loads. `partition_point` (the
/// columns are strictly increasing) finds the accumulator/tail boundary
/// the scalar loop discovers incrementally.
fn row_dot4_x4(cols: &[u32], vals: &[f64], w: &[f64], n_cols: usize) -> f64 {
    let lim = (n_cols / 4) * 4;
    let split = cols.partition_point(|&c| (c as usize) < lim);
    let mut acc = [0.0f64; 4];
    let mut t = 0;
    while t + 4 <= split {
        let c0 = cols[t] as usize;
        let c1 = cols[t + 1] as usize;
        let c2 = cols[t + 2] as usize;
        let c3 = cols[t + 3] as usize;
        acc[c0 % 4] += vals[t] * w[c0];
        acc[c1 % 4] += vals[t + 1] * w[c1];
        acc[c2 % 4] += vals[t + 2] * w[c2];
        acc[c3 % 4] += vals[t + 3] * w[c3];
        t += 4;
    }
    while t < split {
        let c = cols[t] as usize;
        acc[c % 4] += vals[t] * w[c];
        t += 1;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    while t < cols.len() {
        let c = cols[t] as usize;
        s += vals[t] * w[c];
        t += 1;
    }
    s
}

/// [`row_dot2`] with the entry loop unrolled by 4 (same even/odd
/// accumulation classes in storage order — bitwise-identical).
fn row_dot2_x4(cols: &[u32], vals: &[f64], w: &[f64], n_cols: usize) -> f64 {
    let lim = (n_cols / 2) * 2;
    let split = cols.partition_point(|&c| (c as usize) < lim);
    let (mut da, mut db) = (0.0f64, 0.0f64);
    let mut t = 0;
    while t + 4 <= split {
        for u in t..t + 4 {
            let c = cols[u] as usize;
            if c % 2 == 0 {
                da += vals[u] * w[c];
            } else {
                db += vals[u] * w[c];
            }
        }
        t += 4;
    }
    while t < split {
        let c = cols[t] as usize;
        if c % 2 == 0 {
            da += vals[t] * w[c];
        } else {
            db += vals[t] * w[c];
        }
        t += 1;
    }
    let mut s = da + db;
    while t < cols.len() {
        let c = cols[t] as usize;
        s += vals[t] * w[c];
        t += 1;
    }
    s
}

/// [`scatter1`] with the entry loop unrolled by 4 (each output element
/// gets exactly one identical add — bitwise-identical).
fn scatter1_x4(coef: f64, row: (&[u32], &[f64]), out: &mut [f64]) {
    let (cols, vals) = row;
    let chunks = cols.len() / 4;
    for ch in 0..chunks {
        let t = ch * 4;
        out[cols[t] as usize] += coef * vals[t];
        out[cols[t + 1] as usize] += coef * vals[t + 1];
        out[cols[t + 2] as usize] += coef * vals[t + 2];
        out[cols[t + 3] as usize] += coef * vals[t + 3];
    }
    for t in chunks * 4..cols.len() {
        out[cols[t] as usize] += coef * vals[t];
    }
}

// ---------------------------------------------------------------------------
// f32 mixed-precision containers
// ---------------------------------------------------------------------------
//
// Shard-only storage for `--precision f32`: the matrices live in f32 and
// the worker kernels accumulate in f32 (8-wide accumulator classes — twice
// the lanes of the f64 kernels in the same vector width), but every kernel
// keeps the f64 slice signatures of its `Mat`/`CsrMat` counterpart. The
// iterate `w` is narrowed once per call, the local gradient is accumulated
// in an f32 scratch and widened *once* at the end, and residuals/objective
// are widened immediately — so the pool, engines, and optimizers need no
// protocol changes, and the leader-side f64 accumulation the mixed-
// precision contract promises happens exactly where it always did.

/// `a·b` with 8-wide f32 accumulator classes (pairwise lane reduction —
/// there is no bitwise contract to preserve on the f32 path, so the
/// reduction tree favors accuracy and vector width).
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let j = i * 8;
        for l in 0..8 {
            acc[l] += a[j + l] * b[j + l];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for j in chunks * 8..a.len() {
        s += a[j] * b[j];
    }
    s
}

fn narrow(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// Dense row-major `rows × cols` matrix of `f32` — the `--precision f32`
/// shard payload ([`DataMat::DenseF32`]). Half the bytes and memory
/// traffic of [`Mat`] on the same shape.
#[derive(Clone, PartialEq)]
pub struct MatF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for MatF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatF32({}x{})", self.rows, self.cols)
    }
}

impl MatF32 {
    /// Narrow a dense f64 matrix (round-to-nearest per entry).
    pub fn from_f64(m: &Mat) -> Self {
        MatF32 { rows: m.rows(), cols: m.cols(), data: m.data().iter().map(|&v| v as f32).collect() }
    }

    /// Widen back to f64 (exact — every f32 is representable).
    pub fn to_f64(&self) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|&v| v as f64).collect())
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element `(i, j)`, widened.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] as f64
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Resident payload bytes.
    pub fn mem_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Contiguous row band `[lo, hi)` as a new matrix.
    pub fn row_band(&self, lo: usize, hi: usize) -> MatF32 {
        assert!(lo <= hi && hi <= self.rows, "row_band: bad range {lo}..{hi}");
        MatF32 {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// Zero-pad to `new_rows` rows (exact no-op for gradient/objective).
    pub fn pad_rows(&self, new_rows: usize) -> MatF32 {
        assert!(new_rows >= self.rows, "pad_rows: cannot shrink");
        let mut data = self.data.clone();
        data.resize(new_rows * self.cols, 0.0);
        MatF32 { rows: new_rows, cols: self.cols, data }
    }

    /// Stack matrices vertically.
    pub fn vstack(blocks: &[&MatF32]) -> MatF32 {
        assert!(!blocks.is_empty(), "vstack: empty input");
        let cols = blocks[0].cols;
        assert!(blocks.iter().all(|b| b.cols == cols), "vstack: column mismatch");
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            data.extend_from_slice(&b.data);
        }
        MatF32 { rows, cols, data }
    }

    /// `y = self * x` (f32 row dots, widened per element).
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "gemv: dimension mismatch");
        assert_eq!(y.len(), self.rows, "gemv: output mismatch");
        let xf = narrow(x);
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = dot_f32(self.row(i), &xf) as f64;
        }
    }

    /// `y = selfᵀ x` (f32 scatter into an f32 scratch, widened once).
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "gemv_t: dimension mismatch");
        assert_eq!(y.len(), self.cols, "gemv_t: output mismatch");
        let mut yf = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let xi = x[i] as f32;
            if xi == 0.0 {
                continue;
            }
            for (yj, &a) in yf.iter_mut().zip(self.row(i)) {
                *yj += xi * a;
            }
        }
        for (yo, &v) in y.iter_mut().zip(&yf) {
            *yo = v as f64;
        }
    }

    /// Fused worker gradient in f32; see [`Mat::fused_grad`].
    pub fn fused_grad(&self, w: &[f64], y: &[f64], g: &mut [f64], resid_buf: &mut [f64]) -> f64 {
        g.fill(0.0);
        self.fused_grad_range(w, y, g, resid_buf, 0, self.rows)
    }

    /// Row-restricted accumulating fused gradient; same composition
    /// contract as [`Mat::fused_grad_range`] (the f32 scratch is local to
    /// one call, its widened contribution is *added* into `g`).
    pub fn fused_grad_range(
        &self,
        w: &[f64],
        y: &[f64],
        g: &mut [f64],
        resid_buf: &mut [f64],
        lo: usize,
        hi: usize,
    ) -> f64 {
        assert_eq!(w.len(), self.cols, "fused_grad: w mismatch");
        assert_eq!(y.len(), self.rows, "fused_grad: y mismatch");
        assert_eq!(g.len(), self.cols, "fused_grad: g mismatch");
        assert_eq!(resid_buf.len(), self.rows, "fused_grad: buffer mismatch");
        assert!(lo <= hi && hi <= self.rows, "fused_grad_range: bad range {lo}..{hi}");
        let wf = narrow(w);
        let mut gf = vec![0.0f32; self.cols];
        let mut f = 0.0f64;
        for i in lo..hi {
            let row = self.row(i);
            let r = dot_f32(row, &wf) - y[i] as f32;
            let rd = r as f64;
            resid_buf[i] = rd;
            f += rd * rd;
            for (gj, &a) in gf.iter_mut().zip(row) {
                *gj += r * a;
            }
        }
        for (go, &v) in g.iter_mut().zip(&gf) {
            *go += v as f64;
        }
        f
    }

    /// Gram matrix `selfᵀ self`, widened to f64 (cold path — spectrum
    /// figures and step-size bounds, not the per-round worker loop).
    pub fn gram(&self) -> Mat {
        self.to_f64().gram()
    }
}

/// Compressed-sparse-rows `rows × cols` matrix of `f32` — the
/// `--precision f32` sparse shard payload ([`DataMat::CsrF32`]).
///
/// Unlike [`CsrMat`], stored values *may* be zero: narrowing can round a
/// tiny f64 to `0.0f32`, and silently dropping those entries would change
/// the nnz structure (and the nnz-proportional flop model) between the
/// two precisions of the same shard. This container is kernel-only, so no
/// invariant depends on nonzero values; [`CsrMatF32::to_f64`] drops them
/// when widening back into the invariant-carrying [`CsrMat`].
#[derive(Clone, PartialEq)]
pub struct CsrMatF32 {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
}

impl fmt::Debug for CsrMatF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CsrMatF32({}x{}, nnz={})", self.rows, self.cols, self.nnz())
    }
}

impl CsrMatF32 {
    /// Narrow an f64 CSR matrix (structure preserved entry-for-entry).
    pub fn from_f64(m: &CsrMat) -> Self {
        CsrMatF32 {
            rows: m.rows(),
            cols: m.cols(),
            row_ptr: m.row_ptr.clone(),
            col_idx: m.col_idx.clone(),
            vals: m.vals.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Compress a dense f32 matrix (drops exact zeros).
    pub fn from_dense_f32(m: &MatF32) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        row_ptr.push(0);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for i in 0..rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatF32 { rows, cols, row_ptr, col_idx, vals }
    }

    /// Widen back to an f64 [`CsrMat`], dropping any entries narrowing
    /// rounded to zero (restores the no-stored-zeros invariant).
    pub fn to_f64(&self) -> CsrMat {
        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::with_capacity(self.col_idx.len());
        let mut vals = Vec::with_capacity(self.vals.len());
        for i in 0..self.rows {
            let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
            for t in lo..hi {
                if self.vals[t] != 0.0 {
                    col_idx.push(self.col_idx[t]);
                    vals.push(self.vals[t] as f64);
                }
            }
            row_ptr.push(col_idx.len());
        }
        CsrMat::from_raw(self.rows, self.cols, row_ptr, col_idx, vals)
    }

    /// Expand to a dense [`MatF32`].
    pub fn to_dense_f32(&self) -> MatF32 {
        let mut data = vec![0.0f32; self.rows * self.cols];
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                data[i * self.cols + *c as usize] = *v;
            }
        }
        MatF32 { rows: self.rows, cols: self.cols, data }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-entry count.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Resident bytes of the three CSR arrays.
    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<u32>()
            + self.vals.len() * std::mem::size_of::<f32>()
    }

    /// Row `i` as `(column indices, values)` slices.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    /// Element `(i, j)`, widened (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(t) => vals[t] as f64,
            Err(_) => 0.0,
        }
    }

    /// Contiguous row band `[lo, hi)` as a new CSR matrix.
    pub fn row_band(&self, lo: usize, hi: usize) -> CsrMatF32 {
        assert!(lo <= hi && hi <= self.rows, "row_band: bad range {lo}..{hi}");
        let (plo, phi) = (self.row_ptr[lo], self.row_ptr[hi]);
        let row_ptr = self.row_ptr[lo..=hi].iter().map(|p| p - plo).collect();
        CsrMatF32 {
            rows: hi - lo,
            cols: self.cols,
            row_ptr,
            col_idx: self.col_idx[plo..phi].to_vec(),
            vals: self.vals[plo..phi].to_vec(),
        }
    }

    /// Zero-pad to `new_rows` rows (empty rows).
    pub fn pad_rows(&self, new_rows: usize) -> CsrMatF32 {
        assert!(new_rows >= self.rows, "pad_rows: cannot shrink");
        let mut out = self.clone();
        out.row_ptr.resize(new_rows + 1, *self.row_ptr.last().unwrap());
        out.rows = new_rows;
        out
    }

    /// Stack matrices vertically.
    pub fn vstack(blocks: &[&CsrMatF32]) -> CsrMatF32 {
        assert!(!blocks.is_empty(), "vstack: empty input");
        let cols = blocks[0].cols;
        assert!(blocks.iter().all(|b| b.cols == cols), "vstack: column mismatch");
        let rows = blocks.iter().map(|b| b.rows).sum();
        let nnz = blocks.iter().map(|b| b.vals.len()).sum();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        row_ptr.push(0usize);
        for b in blocks {
            let base = *row_ptr.last().unwrap();
            row_ptr.extend(b.row_ptr[1..].iter().map(|p| p + base));
            col_idx.extend_from_slice(&b.col_idx);
            vals.extend_from_slice(&b.vals);
        }
        CsrMatF32 { rows, cols, row_ptr, col_idx, vals }
    }

    /// `y = self * x` (sequential f32 row dots, widened per element).
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "gemv: dimension mismatch");
        assert_eq!(y.len(), self.rows, "gemv: output mismatch");
        let xf = narrow(x);
        for (i, yi) in y.iter_mut().enumerate() {
            let (cols, vals) = self.row(i);
            let mut s = 0.0f32;
            for (c, v) in cols.iter().zip(vals) {
                s += v * xf[*c as usize];
            }
            *yi = s as f64;
        }
    }

    /// `y = selfᵀ x` (f32 scatter into an f32 scratch, widened once).
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "gemv_t: dimension mismatch");
        assert_eq!(y.len(), self.cols, "gemv_t: output mismatch");
        let mut yf = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let xi = x[i] as f32;
            if xi == 0.0 {
                continue;
            }
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                yf[*c as usize] += xi * v;
            }
        }
        for (yo, &v) in y.iter_mut().zip(&yf) {
            *yo = v as f64;
        }
    }

    /// Fused worker gradient in f32; see [`Mat::fused_grad`].
    pub fn fused_grad(&self, w: &[f64], y: &[f64], g: &mut [f64], resid_buf: &mut [f64]) -> f64 {
        g.fill(0.0);
        self.fused_grad_range(w, y, g, resid_buf, 0, self.rows)
    }

    /// Row-restricted accumulating fused gradient in f32; same
    /// composition contract as [`Mat::fused_grad_range`].
    pub fn fused_grad_range(
        &self,
        w: &[f64],
        y: &[f64],
        g: &mut [f64],
        resid_buf: &mut [f64],
        lo: usize,
        hi: usize,
    ) -> f64 {
        assert_eq!(w.len(), self.cols, "fused_grad: w mismatch");
        assert_eq!(y.len(), self.rows, "fused_grad: y mismatch");
        assert_eq!(g.len(), self.cols, "fused_grad: g mismatch");
        assert_eq!(resid_buf.len(), self.rows, "fused_grad: buffer mismatch");
        assert!(lo <= hi && hi <= self.rows, "fused_grad_range: bad range {lo}..{hi}");
        let wf = narrow(w);
        let mut gf = vec![0.0f32; self.cols];
        let mut f = 0.0f64;
        for i in lo..hi {
            let (cols, vals) = self.row(i);
            let mut s = 0.0f32;
            for (c, v) in cols.iter().zip(vals) {
                s += v * wf[*c as usize];
            }
            let r = s - y[i] as f32;
            let rd = r as f64;
            resid_buf[i] = rd;
            f += rd * rd;
            for (c, v) in cols.iter().zip(vals) {
                gf[*c as usize] += r * v;
            }
        }
        for (go, &v) in g.iter_mut().zip(&gf) {
            *go += v as f64;
        }
        f
    }

    /// Gram matrix `selfᵀ self`, widened to f64 (cold path).
    pub fn gram(&self) -> Mat {
        self.to_dense_f32().gram()
    }
}

// ---------------------------------------------------------------------------
// DataMat
// ---------------------------------------------------------------------------

/// A data matrix behind one of the two storage backends. This is the type
/// the encoded shards, the raw problem, and the compute engines hold —
/// the whole stack above the kernels is storage-oblivious.
#[derive(Clone, Debug, PartialEq)]
pub enum DataMat {
    /// Dense row-major storage.
    Dense(Mat),
    /// Compressed sparse rows.
    Csr(CsrMat),
    /// Dense f32 shard storage (`--precision f32`).
    DenseF32(MatF32),
    /// CSR f32 shard storage (`--precision f32`).
    CsrF32(CsrMatF32),
}

impl From<Mat> for DataMat {
    fn from(m: Mat) -> Self {
        DataMat::Dense(m)
    }
}

impl From<CsrMat> for DataMat {
    fn from(m: CsrMat) -> Self {
        DataMat::Csr(m)
    }
}

impl DataMat {
    /// Row count.
    pub fn rows(&self) -> usize {
        match self {
            DataMat::Dense(m) => m.rows(),
            DataMat::Csr(m) => m.rows(),
            DataMat::DenseF32(m) => m.rows(),
            DataMat::CsrF32(m) => m.rows(),
        }
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        match self {
            DataMat::Dense(m) => m.cols(),
            DataMat::Csr(m) => m.cols(),
            DataMat::DenseF32(m) => m.cols(),
            DataMat::CsrF32(m) => m.cols(),
        }
    }

    /// True for CSR storage (either precision).
    pub fn is_sparse(&self) -> bool {
        matches!(self, DataMat::Csr(_) | DataMat::CsrF32(_))
    }

    /// The backend actually in use (never [`StorageKind::Auto`]).
    pub fn storage(&self) -> StorageKind {
        match self {
            DataMat::Dense(_) | DataMat::DenseF32(_) => StorageKind::Dense,
            DataMat::Csr(_) | DataMat::CsrF32(_) => StorageKind::Sparse,
        }
    }

    /// The numeric precision of the payload.
    pub fn precision(&self) -> Precision {
        match self {
            DataMat::Dense(_) | DataMat::Csr(_) => Precision::F64,
            DataMat::DenseF32(_) | DataMat::CsrF32(_) => Precision::F32,
        }
    }

    /// Multiply-adds one `gemv`-shaped pass over this matrix costs — the
    /// virtual-clock flop model's unit. Dense kernels touch every entry
    /// (`rows·cols`); CSR kernels touch only the stored nonzeros. The
    /// kernels are memory-bound, so f32 passes are discounted by byte
    /// traffic: a dense f32 row moves half the bytes (`× 1/2`), a CSR f32
    /// entry moves 8 bytes (4 value + 4 index) against f64's 12 (`× 2/3`).
    pub fn gemv_madds(&self) -> f64 {
        match self {
            DataMat::Dense(m) => (m.rows() * m.cols()) as f64,
            DataMat::Csr(m) => m.nnz() as f64,
            DataMat::DenseF32(m) => (m.rows() * m.cols()) as f64 * 0.5,
            DataMat::CsrF32(m) => m.nnz() as f64 * (2.0 / 3.0),
        }
    }

    /// Resident bytes of the payload arrays.
    pub fn mem_bytes(&self) -> usize {
        match self {
            DataMat::Dense(m) => m.rows() * m.cols() * std::mem::size_of::<f64>(),
            DataMat::Csr(m) => m.mem_bytes(),
            DataMat::DenseF32(m) => m.mem_bytes(),
            DataMat::CsrF32(m) => m.mem_bytes(),
        }
    }

    /// Element `(i, j)` (widened for f32 backends).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            DataMat::Dense(m) => m.get(i, j),
            DataMat::Csr(m) => m.get(i, j),
            DataMat::DenseF32(m) => m.get(i, j),
            DataMat::CsrF32(m) => m.get(i, j),
        }
    }

    /// Borrow the dense f64 matrix, if that is what this is (the XLA
    /// staging path — AOT artifacts are dense f64-shaped and must fail
    /// fast on CSR and on f32 shards alike).
    pub fn as_dense(&self) -> Option<&Mat> {
        match self {
            DataMat::Dense(m) => Some(m),
            _ => None,
        }
    }

    /// Dense f64 copy (expands CSR, widens f32).
    pub fn to_dense(&self) -> Mat {
        match self {
            DataMat::Dense(m) => m.clone(),
            DataMat::Csr(m) => m.to_dense(),
            DataMat::DenseF32(m) => m.to_f64(),
            DataMat::CsrF32(m) => m.to_dense_f32().to_f64(),
        }
    }

    /// CSR f64 copy (compresses dense, widens f32).
    pub fn to_csr(&self) -> CsrMat {
        match self {
            DataMat::Dense(m) => CsrMat::from_dense(m),
            DataMat::Csr(m) => m.clone(),
            DataMat::DenseF32(m) => CsrMat::from_dense(&m.to_f64()),
            DataMat::CsrF32(m) => m.to_f64(),
        }
    }

    /// Convert into the requested backend ([`StorageKind::Auto`] keeps
    /// the current one), preserving the precision. Conversion is
    /// value-exact in both directions within a precision.
    pub fn into_storage(self, storage: StorageKind) -> DataMat {
        match (storage, self) {
            (StorageKind::Auto, x) => x,
            (StorageKind::Dense, DataMat::Csr(c)) => DataMat::Dense(c.to_dense()),
            (StorageKind::Dense, DataMat::CsrF32(c)) => DataMat::DenseF32(c.to_dense_f32()),
            (StorageKind::Dense, x) => x,
            (StorageKind::Sparse, DataMat::Dense(d)) => DataMat::Csr(CsrMat::from_dense(&d)),
            (StorageKind::Sparse, DataMat::DenseF32(d)) => {
                DataMat::CsrF32(CsrMatF32::from_dense_f32(&d))
            }
            (StorageKind::Sparse, x) => x,
        }
    }

    /// Convert into the requested precision, preserving the backend.
    /// Narrowing rounds each entry to nearest f32; widening is exact
    /// (modulo dropping CSR entries that had rounded to zero).
    pub fn to_precision(self, precision: Precision) -> DataMat {
        match (precision, self) {
            (Precision::F32, DataMat::Dense(m)) => DataMat::DenseF32(MatF32::from_f64(&m)),
            (Precision::F32, DataMat::Csr(m)) => DataMat::CsrF32(CsrMatF32::from_f64(&m)),
            (Precision::F64, DataMat::DenseF32(m)) => DataMat::Dense(m.to_f64()),
            (Precision::F64, DataMat::CsrF32(m)) => DataMat::Csr(m.to_f64()),
            (_, x) => x,
        }
    }

    /// Contiguous row band `[lo, hi)` in the same backend.
    pub fn row_band(&self, lo: usize, hi: usize) -> DataMat {
        match self {
            DataMat::Dense(m) => DataMat::Dense(m.row_band(lo, hi)),
            DataMat::Csr(m) => DataMat::Csr(m.row_band(lo, hi)),
            DataMat::DenseF32(m) => DataMat::DenseF32(m.row_band(lo, hi)),
            DataMat::CsrF32(m) => DataMat::CsrF32(m.row_band(lo, hi)),
        }
    }

    /// Zero-pad to `new_rows` rows in the same backend (exact no-op for
    /// gradient/objective either way).
    pub fn pad_rows(&self, new_rows: usize) -> DataMat {
        match self {
            DataMat::Dense(m) => DataMat::Dense(m.pad_rows(new_rows)),
            DataMat::Csr(m) => DataMat::Csr(m.pad_rows(new_rows)),
            DataMat::DenseF32(m) => DataMat::DenseF32(m.pad_rows(new_rows)),
            DataMat::CsrF32(m) => DataMat::CsrF32(m.pad_rows(new_rows)),
        }
    }

    /// Stack matrices vertically, preserving the common backend and
    /// precision. All blocks must share one variant: shards of an encoded
    /// problem always do (mixed input is a hard error, not a silent
    /// densification or widening).
    pub fn vstack(blocks: &[&DataMat]) -> DataMat {
        assert!(!blocks.is_empty(), "vstack: empty input");
        match blocks[0] {
            DataMat::Dense(_) => {
                let parts: Vec<&Mat> = blocks
                    .iter()
                    .map(|b| match b {
                        DataMat::Dense(m) => m,
                        _ => panic!("vstack: mixed dense/CSR blocks"),
                    })
                    .collect();
                DataMat::Dense(Mat::vstack(&parts))
            }
            DataMat::Csr(_) => {
                let parts: Vec<&CsrMat> = blocks
                    .iter()
                    .map(|b| match b {
                        DataMat::Csr(m) => m,
                        _ => panic!("vstack: mixed dense/CSR blocks"),
                    })
                    .collect();
                DataMat::Csr(CsrMat::vstack(&parts))
            }
            DataMat::DenseF32(_) => {
                let parts: Vec<&MatF32> = blocks
                    .iter()
                    .map(|b| match b {
                        DataMat::DenseF32(m) => m,
                        _ => panic!("vstack: mixed dense/CSR blocks"),
                    })
                    .collect();
                DataMat::DenseF32(MatF32::vstack(&parts))
            }
            DataMat::CsrF32(_) => {
                let parts: Vec<&CsrMatF32> = blocks
                    .iter()
                    .map(|b| match b {
                        DataMat::CsrF32(m) => m,
                        _ => panic!("vstack: mixed dense/CSR blocks"),
                    })
                    .collect();
                DataMat::CsrF32(CsrMatF32::vstack(&parts))
            }
        }
    }

    /// Max `|a_ij − b_ij|` across backends.
    pub fn max_abs_diff(&self, other: &DataMat) -> f64 {
        assert_eq!((self.rows(), self.cols()), (other.rows(), other.cols()));
        let mut d = 0.0f64;
        for i in 0..self.rows() {
            for j in 0..self.cols() {
                d = d.max((self.get(i, j) - other.get(i, j)).abs());
            }
        }
        d
    }

    /// Matrix–vector product `self * x`.
    pub fn gemv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows()];
        self.gemv_into(x, &mut y);
        y
    }

    /// `y = self * x` into a caller buffer.
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            DataMat::Dense(m) => m.gemv_into(x, y),
            DataMat::Csr(m) => m.gemv_into(x, y),
            DataMat::DenseF32(m) => m.gemv_into(x, y),
            DataMat::CsrF32(m) => m.gemv_into(x, y),
        }
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn gemv_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols()];
        self.gemv_t_into(x, &mut y);
        y
    }

    /// `y = selfᵀ x` into a caller buffer.
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            DataMat::Dense(m) => m.gemv_t_into(x, y),
            DataMat::Csr(m) => m.gemv_t_into(x, y),
            DataMat::DenseF32(m) => m.gemv_t_into(x, y),
            DataMat::CsrF32(m) => m.gemv_t_into(x, y),
        }
    }

    /// Fused worker gradient; see [`Mat::fused_grad`].
    pub fn fused_grad(&self, w: &[f64], y: &[f64], g: &mut [f64], resid_buf: &mut [f64]) -> f64 {
        match self {
            DataMat::Dense(m) => m.fused_grad(w, y, g, resid_buf),
            DataMat::Csr(m) => m.fused_grad(w, y, g, resid_buf),
            DataMat::DenseF32(m) => m.fused_grad(w, y, g, resid_buf),
            DataMat::CsrF32(m) => m.fused_grad(w, y, g, resid_buf),
        }
    }

    /// Row-restricted accumulating fused gradient; see
    /// [`Mat::fused_grad_range`].
    pub fn fused_grad_range(
        &self,
        w: &[f64],
        y: &[f64],
        g: &mut [f64],
        resid_buf: &mut [f64],
        lo: usize,
        hi: usize,
    ) -> f64 {
        match self {
            DataMat::Dense(m) => m.fused_grad_range(w, y, g, resid_buf, lo, hi),
            DataMat::Csr(m) => m.fused_grad_range(w, y, g, resid_buf, lo, hi),
            DataMat::DenseF32(m) => m.fused_grad_range(w, y, g, resid_buf, lo, hi),
            DataMat::CsrF32(m) => m.fused_grad_range(w, y, g, resid_buf, lo, hi),
        }
    }

    /// Gram matrix `selfᵀ self` (always dense f64 `cols × cols`).
    pub fn gram(&self) -> Mat {
        match self {
            DataMat::Dense(m) => m.gram(),
            DataMat::Csr(m) => m.gram(),
            DataMat::DenseF32(m) => m.gram(),
            DataMat::CsrF32(m) => m.gram(),
        }
    }

    /// Largest eigenvalue of `selfᵀ self` by power iteration — the same
    /// shared implementation as [`Mat::spectral_bound`] (and, via the
    /// mirrored kernels, the same bits) on either backend.
    pub fn spectral_bound(&self, iters: usize, seed: u64) -> f64 {
        super::spectral_power_iteration(
            self.rows(),
            self.cols(),
            iters,
            seed,
            |v, out| self.gemv_into(v, out),
            |v, out| self.gemv_t_into(v, out),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| {
            if rng.next_f64() < density {
                rng.next_gaussian()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn from_dense_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let d = random_sparse(&mut rng, 13, 9, 0.3);
        let s = CsrMat::from_dense(&d);
        assert_eq!(s.rows(), 13);
        assert_eq!(s.cols(), 9);
        assert!(s.to_dense().max_abs_diff(&d) == 0.0);
        for i in 0..13 {
            for j in 0..9 {
                assert_eq!(s.get(i, j), d.get(i, j));
            }
        }
    }

    #[test]
    fn nnz_and_density_and_memory() {
        let d = Mat::from_fn(4, 5, |i, j| if (i + j) % 2 == 0 { 1.0 } else { 0.0 });
        let s = CsrMat::from_dense(&d);
        assert_eq!(s.nnz(), 10);
        assert!((s.density() - 0.5).abs() < 1e-15);
        assert!(s.mem_bytes() > 0);
        // MovieLens-shaped shard: 3 nnz per row, wide — CSR far smaller
        let wide = Mat::from_fn(64, 400, |i, j| if j == i || j == 399 { 1.0 } else { 0.0 });
        let sw = CsrMat::from_dense(&wide);
        assert!(sw.mem_bytes() * 10 < 64 * 400 * 8);
    }

    #[test]
    fn row_band_and_pad_rows() {
        let mut rng = Pcg64::seeded(2);
        let d = random_sparse(&mut rng, 10, 6, 0.4);
        let s = CsrMat::from_dense(&d);
        let band = s.row_band(3, 8);
        assert!(band.to_dense().max_abs_diff(&d.row_band(3, 8)) == 0.0);
        let padded = s.pad_rows(16);
        assert_eq!(padded.rows(), 16);
        assert_eq!(padded.nnz(), s.nnz());
        for j in 0..6 {
            assert_eq!(padded.get(12, j), 0.0);
        }
    }

    #[test]
    fn gemv_matches_dense_bitwise() {
        let mut rng = Pcg64::seeded(3);
        for &(r, c, den) in &[(1usize, 1usize, 1.0), (7, 5, 0.5), (20, 17, 0.2), (9, 33, 0.05)] {
            let d = random_sparse(&mut rng, r, c, den);
            let s = CsrMat::from_dense(&d);
            let x: Vec<f64> = (0..c).map(|_| rng.next_gaussian()).collect();
            let yd = d.gemv(&x);
            let ys = s.gemv(&x);
            for (a, b) in yd.iter().zip(&ys) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn gemv_t_matches_dense_bitwise() {
        let mut rng = Pcg64::seeded(4);
        for &(r, c, den) in &[(6usize, 4usize, 0.6), (11, 8, 0.3), (16, 3, 0.2)] {
            let d = random_sparse(&mut rng, r, c, den);
            let s = CsrMat::from_dense(&d);
            let x: Vec<f64> = (0..r).map(|_| rng.next_gaussian()).collect();
            let yd = d.gemv_t(&x);
            let ys = s.gemv_t(&x);
            for (a, b) in yd.iter().zip(&ys) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn fused_grad_matches_dense_bitwise() {
        let mut rng = Pcg64::seeded(5);
        for &(r, c, den) in &[(12usize, 7usize, 0.4), (25, 10, 0.15), (8, 2, 0.9)] {
            let d = random_sparse(&mut rng, r, c, den);
            let s = CsrMat::from_dense(&d);
            let w: Vec<f64> = (0..c).map(|_| rng.next_gaussian()).collect();
            let y: Vec<f64> = (0..r).map(|_| rng.next_gaussian()).collect();
            let (mut gd, mut gs) = (vec![0.0; c], vec![0.0; c]);
            let (mut bd, mut bs) = (vec![0.0; r], vec![0.0; r]);
            let fd = d.fused_grad(&w, &y, &mut gd, &mut bd);
            let fs = s.fused_grad(&w, &y, &mut gs, &mut bs);
            assert_eq!(fd.to_bits(), fs.to_bits());
            for (a, b) in gd.iter().zip(&gs) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in bd.iter().zip(&bs) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn gram_matches_dense() {
        let mut rng = Pcg64::seeded(6);
        let d = random_sparse(&mut rng, 20, 8, 0.35);
        let s = CsrMat::from_dense(&d);
        assert!(s.gram().max_abs_diff(&d.gram()) < 1e-12);
    }

    #[test]
    fn empty_rows_and_columns_are_handled() {
        // rows 2 and 5 fully empty; column 1 never touched
        let d = Mat::from_fn(7, 4, |i, j| {
            if i == 2 || i == 5 || j == 1 {
                0.0
            } else {
                (i * 4 + j + 1) as f64
            }
        });
        let s = CsrMat::from_dense(&d);
        let w = vec![0.5, -1.0, 2.0, 0.25];
        let y = vec![0.1; 7];
        let (mut gd, mut gs) = (vec![0.0; 4], vec![0.0; 4]);
        let (mut bd, mut bs) = (vec![0.0; 7], vec![0.0; 7]);
        let fd = d.fused_grad(&w, &y, &mut gd, &mut bd);
        let fs = s.fused_grad(&w, &y, &mut gs, &mut bs);
        assert_eq!(fd.to_bits(), fs.to_bits());
        for (a, b) in gd.iter().zip(&gs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn datamat_storage_conversions() {
        let mut rng = Pcg64::seeded(7);
        let d = random_sparse(&mut rng, 9, 5, 0.3);
        let dm: DataMat = d.clone().into();
        assert!(!dm.is_sparse());
        assert_eq!(dm.storage(), StorageKind::Dense);
        let sp = dm.clone().into_storage(StorageKind::Sparse);
        assert!(sp.is_sparse());
        assert_eq!(sp.to_dense().max_abs_diff(&d), 0.0);
        let back = sp.clone().into_storage(StorageKind::Dense);
        assert!(!back.is_sparse());
        assert_eq!(sp.into_storage(StorageKind::Auto).storage(), StorageKind::Sparse);
        assert_eq!(back.max_abs_diff(&dm), 0.0);
    }

    #[test]
    fn datamat_flop_model_is_nnz_proportional() {
        let d = Mat::from_fn(8, 10, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let dense: DataMat = d.clone().into();
        let sparse: DataMat = CsrMat::from_dense(&d).into();
        assert_eq!(dense.gemv_madds(), 80.0);
        assert_eq!(sparse.gemv_madds(), 8.0);
        assert!(sparse.mem_bytes() < dense.mem_bytes());
    }

    #[test]
    fn spectral_bound_matches_across_backends() {
        let mut rng = Pcg64::seeded(8);
        let d = random_sparse(&mut rng, 24, 6, 0.4);
        let dense: DataMat = d.clone().into();
        let sparse: DataMat = CsrMat::from_dense(&d).into();
        let a = dense.spectral_bound(40, 3);
        let b = sparse.spectral_bound(40, 3);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn storage_kind_parse_roundtrip() {
        for kind in [StorageKind::Dense, StorageKind::Sparse, StorageKind::Auto] {
            assert_eq!(StorageKind::parse(kind.label()).unwrap(), kind);
        }
        assert_eq!(StorageKind::parse("csr").unwrap(), StorageKind::Sparse);
        assert!(StorageKind::parse("ram").is_err());
    }

    #[test]
    #[should_panic(expected = "columns not sorted")]
    fn from_raw_rejects_unsorted() {
        CsrMat::from_raw(1, 4, vec![0, 2], vec![2, 1], vec![1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "explicit zero")]
    fn from_raw_rejects_stored_zero() {
        CsrMat::from_raw(1, 4, vec![0, 1], vec![0], vec![0.0]);
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::F64, Precision::F32] {
            assert_eq!(Precision::parse(p.label()).unwrap(), p);
            assert_eq!(format!("{p}"), p.label());
        }
        assert_eq!(Precision::parse("F32").unwrap(), Precision::F32);
        assert!(Precision::parse("f16").is_err());
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn to_precision_roundtrip_preserves_backend() {
        let mut rng = Pcg64::seeded(21);
        let d = random_sparse(&mut rng, 12, 7, 0.4);
        for dm in [DataMat::Dense(d.clone()), DataMat::Csr(CsrMat::from_dense(&d))] {
            let narrow = dm.clone().to_precision(Precision::F32);
            assert_eq!(narrow.precision(), Precision::F32);
            assert_eq!(narrow.storage(), dm.storage());
            assert_eq!(narrow.rows(), dm.rows());
            // every f64 here is a small Gaussian — f32 round-trip error
            // is bounded by the relative epsilon
            let back = narrow.clone().to_precision(Precision::F64);
            assert_eq!(back.precision(), Precision::F64);
            assert!(back.max_abs_diff(&dm) < 1e-6);
            // already-narrow conversion is a no-op
            assert_eq!(narrow.clone().to_precision(Precision::F32), narrow);
        }
    }

    #[test]
    fn f32_shards_halve_dense_memory() {
        let d = Mat::from_fn(32, 16, |i, j| (i + j + 1) as f64);
        let dense = DataMat::Dense(d.clone());
        let dense32 = dense.clone().to_precision(Precision::F32);
        assert_eq!(dense32.mem_bytes() * 2, dense.mem_bytes());
        let sparse = DataMat::Csr(CsrMat::from_dense(&d));
        let sparse32 = sparse.clone().to_precision(Precision::F32);
        assert!(sparse32.mem_bytes() < sparse.mem_bytes());
    }

    #[test]
    fn f32_flop_model_discounts() {
        let d = Mat::from_fn(8, 10, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let dense32 = DataMat::Dense(d.clone()).to_precision(Precision::F32);
        assert_eq!(dense32.gemv_madds(), 40.0); // rows·cols / 2
        let sparse32 = DataMat::Csr(CsrMat::from_dense(&d)).to_precision(Precision::F32);
        assert!((sparse32.gemv_madds() - 8.0 * 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f32_kernels_approximate_f64() {
        let mut rng = Pcg64::seeded(22);
        for &(r, c, den) in &[(16usize, 9usize, 1.0), (21, 6, 0.3)] {
            let d = random_sparse(&mut rng, r, c, den);
            let w: Vec<f64> = (0..c).map(|_| rng.next_gaussian()).collect();
            let y: Vec<f64> = (0..r).map(|_| rng.next_gaussian()).collect();
            let f64_ref = DataMat::Dense(d.clone());
            let mut g_ref = vec![0.0; c];
            let mut b_ref = vec![0.0; r];
            let f_ref = f64_ref.fused_grad(&w, &y, &mut g_ref, &mut b_ref);
            for narrow in [
                DataMat::Dense(d.clone()).to_precision(Precision::F32),
                DataMat::Csr(CsrMat::from_dense(&d)).to_precision(Precision::F32),
            ] {
                let mut g = vec![0.0; c];
                let mut b = vec![0.0; r];
                let f = narrow.fused_grad(&w, &y, &mut g, &mut b);
                assert!((f - f_ref).abs() < 1e-3 * (1.0 + f_ref.abs()), "{narrow:?}");
                for (a, bb) in g.iter().zip(&g_ref) {
                    assert!((a - bb).abs() < 1e-3 * (1.0 + bb.abs()), "{narrow:?}");
                }
                // gemv / gemv_t agree to f32 tolerance too
                let yv = narrow.gemv(&w);
                let yv_ref = f64_ref.gemv(&w);
                for (a, bb) in yv.iter().zip(&yv_ref) {
                    assert!((a - bb).abs() < 1e-3 * (1.0 + bb.abs()));
                }
                let xt: Vec<f64> = (0..r).map(|i| y[i]).collect();
                let tv = narrow.gemv_t(&xt);
                let tv_ref = f64_ref.gemv_t(&xt);
                for (a, bb) in tv.iter().zip(&tv_ref) {
                    assert!((a - bb).abs() < 1e-3 * (1.0 + bb.abs()));
                }
            }
        }
    }

    #[test]
    fn f32_fused_grad_range_composes() {
        let mut rng = Pcg64::seeded(23);
        let d = random_sparse(&mut rng, 14, 5, 0.6);
        let w: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..14).map(|_| rng.next_gaussian()).collect();
        let narrow = DataMat::Dense(d).to_precision(Precision::F32);
        let mut g_full = vec![0.0; 5];
        let mut b_full = vec![0.0; 14];
        let f_full = narrow.fused_grad(&w, &y, &mut g_full, &mut b_full);
        let mut g = vec![0.0; 5];
        let mut b = vec![0.0; 14];
        let f = narrow.fused_grad_range(&w, &y, &mut g, &mut b, 0, 9)
            + narrow.fused_grad_range(&w, &y, &mut g, &mut b, 9, 14);
        // split point lands mid-f32-accumulation, so allow f32 noise
        assert!((f - f_full).abs() < 1e-5 * (1.0 + f_full.abs()));
        for (a, bb) in g.iter().zip(&g_full) {
            assert!((a - bb).abs() < 1e-4 * (1.0 + bb.abs()));
        }
    }

    #[test]
    fn csr_f32_keeps_rounded_zero_entries_and_drops_on_widen() {
        // 1e-200 rounds to 0.0f32: the narrow container keeps the entry
        // (structure — and the flop model — must match the f64 shard),
        // widening back drops it to restore CsrMat's invariant
        let d = Mat::from_fn(2, 3, |i, j| if i == 0 && j == 1 { 1e-200 } else { (j + 1) as f64 });
        let s = CsrMat::from_dense(&d);
        let narrow = CsrMatF32::from_f64(&s);
        assert_eq!(narrow.nnz(), s.nnz());
        let back = narrow.to_f64();
        assert_eq!(back.nnz(), s.nnz() - 1);
        // kernels on the zero-carrying container still work
        let mut g = vec![0.0; 3];
        let mut b = vec![0.0; 2];
        let f = narrow.fused_grad(&[1.0, 1.0, 1.0], &[0.0, 0.0], &mut g, &mut b);
        assert!(f.is_finite());
    }

    #[test]
    fn vstack_rejects_mixed_precision() {
        let d = Mat::from_fn(2, 2, |_, _| 1.0);
        let a = DataMat::Dense(d.clone());
        let b = DataMat::Dense(d).to_precision(Precision::F32);
        let r = std::panic::catch_unwind(|| DataMat::vstack(&[&a, &b]));
        assert!(r.is_err());
    }
}
