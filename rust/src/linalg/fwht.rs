//! Fast Walsh–Hadamard Transform — the O(N log N) encode path of the
//! fast-transform codes (§4 "Fast transforms", Appendix D).
//!
//! Unnormalized Sylvester ordering, matching the L1 Pallas kernel
//! (`python/compile/kernels/fwht.py`); callers apply `1/sqrt(N)` for the
//! orthonormal/tight-frame scaling.
//!
//! **Blocking & threading.** [`fwht_columns`] is the serve-mode cold-path
//! cost every `EncodedShardCache` miss pays, so it is cache-blocked and
//! multithreaded:
//!
//! * *Column panels (L2 blocking):* all `log2(n)` butterfly stages run
//!   over one panel of columns before moving to the next, so a panel's
//!   working set (`n · panel` doubles) stays resident across stages
//!   instead of streaming the whole `n × c` buffer `log2(n)` times.
//! * *Recursive halving (threads):* every stage `h < n/2` operates inside
//!   aligned blocks of `2h ≤ n/2` rows that never cross the buffer
//!   midpoint, so those stages are exactly "transform the top half" and
//!   "transform the bottom half" — two independent jobs for
//!   `std::thread::scope`. The final `h = n/2` stage is one elementwise
//!   butterfly between the two aligned halves, parallelized over
//!   disjoint row chunks.
//!
//! Neither transformation changes any element's operation sequence (each
//! element is read and written exactly once per stage; there are no
//! cross-thread accumulators), so the blocked/threaded transform is
//! **bitwise-identical** to the historical stage-major loop — pinned by
//! the tests below and relied on by the Hadamard-encode golden traces.

use super::mat::n_threads;

/// Below this many butterfly element-ops (`n · c · log2 n`), threading
/// overhead dominates — stay serial.
const PAR_BUTTERFLY_THRESHOLD: usize = 1 << 20;

/// Column-panel size target: keep `n · panel` doubles around L2-sized.
const L2_BYTES: usize = 256 * 1024;

/// In-place N-point WHT of a vector. `v.len()` must be a positive power
/// of two (a 0-point transform is undefined in the Sylvester family —
/// rejected explicitly rather than by the confusing historical
/// `0.is_power_of_two()` failure).
pub fn fwht_inplace(v: &mut [f64]) {
    let n = v.len();
    assert!(n > 0 && n.is_power_of_two(), "FWHT length must be a positive power of two, got {n}");
    let mut h = 1;
    while h < n {
        for block in (0..n).step_by(2 * h) {
            for i in block..block + h {
                let (a, b) = (v[i], v[i + h]);
                v[i] = a + b;
                v[i + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// WHT applied to every column of a row-major `n × c` matrix buffer.
///
/// Works column-block-wise directly on the row-major layout: for each
/// butterfly stage the partner rows are `i` and `i + h`, and the add/sub
/// runs vectorized across the panel — this is the CPU analog of the
/// Pallas kernel's stride-permuted VPU stages and is much faster than
/// transposing or gathering per-column. See the module docs for the
/// panel/threading scheme and the bitwise-identity argument.
///
/// `n` must be a positive power of two; `c = 0` is an explicit no-op
/// (zero columns to transform — the shape is still validated).
pub fn fwht_columns(data: &mut [f64], n: usize, c: usize) {
    assert!(n > 0 && n.is_power_of_two(), "FWHT length must be a positive power of two, got {n}");
    assert_eq!(data.len(), n * c, "fwht_columns: buffer mismatch");
    if c == 0 {
        return;
    }
    let stages = n.trailing_zeros() as usize;
    let work = n * c * stages.max(1);
    let threads = if work >= PAR_BUTTERFLY_THRESHOLD { n_threads().min(n / 2).max(1) } else { 1 };
    if threads <= 1 {
        fwht_columns_serial(data, n, c);
    } else {
        fwht_columns_rec(data, n, c, threads);
    }
}

/// Serial transform with L2-sized column panels: all stages run per
/// panel. Panels partition the columns and each column's butterflies are
/// independent of every other column, so the element-op sequence — and
/// therefore the bits — match the unblocked stage-major loop.
fn fwht_columns_serial(data: &mut [f64], n: usize, c: usize) {
    let panel = (L2_BYTES / (std::mem::size_of::<f64>() * n)).clamp(1, c);
    let mut j0 = 0;
    while j0 < c {
        let j1 = (j0 + panel).min(c);
        fwht_columns_panel(data, n, c, j0, j1);
        j0 = j1;
    }
}

/// All `log2(n)` butterfly stages over columns `[j0, j1)` only.
fn fwht_columns_panel(data: &mut [f64], n: usize, c: usize, j0: usize, j1: usize) {
    let mut h = 1;
    while h < n {
        for block in (0..n).step_by(2 * h) {
            for i in block..block + h {
                let (top, bot) = data.split_at_mut((i + h) * c);
                let a_row = &mut top[i * c..(i + 1) * c];
                let b_row = &mut bot[..c];
                for j in j0..j1 {
                    let (a, b) = (a_row[j], b_row[j]);
                    a_row[j] = a + b;
                    b_row[j] = a - b;
                }
            }
        }
        h *= 2;
    }
}

/// Recursive halving: transform the two halves (in parallel when the
/// thread budget allows), then run the final `h = n/2` combine stage.
/// Stages `h < n/2` never cross the midpoint (blocks of `2h` rows start
/// at multiples of `2h`, and `n/2` is such a multiple), so this computes
/// the exact same operation sequence as the serial stage-major loop.
fn fwht_columns_rec(data: &mut [f64], n: usize, c: usize, threads: usize) {
    if threads <= 1 || n < 2 {
        fwht_columns_serial(data, n, c);
        return;
    }
    let half = n / 2;
    {
        let (top, bot) = data.split_at_mut(half * c);
        let t_top = threads / 2;
        let t_bot = threads - t_top;
        std::thread::scope(|s| {
            s.spawn(move || fwht_columns_rec(top, half, c, t_top));
            fwht_columns_rec(bot, half, c, t_bot);
        });
    }
    combine_halves(data, n, c, threads);
}

/// The final `h = n/2` butterfly: elementwise over the two aligned
/// halves, parallelized over disjoint row chunks (each element is
/// touched by exactly one thread — no accumulation, no reordering).
fn combine_halves(data: &mut [f64], n: usize, c: usize, threads: usize) {
    let half = n / 2;
    let (top, bot) = data.split_at_mut(half * c);
    let rows_per = half.div_ceil(threads.max(1)).max(1);
    std::thread::scope(|s| {
        let mut top_rest: &mut [f64] = top;
        let mut bot_rest: &mut [f64] = bot;
        while !top_rest.is_empty() {
            let take = (rows_per * c).min(top_rest.len());
            let (t_chunk, t_tail) = top_rest.split_at_mut(take);
            let (b_chunk, b_tail) = bot_rest.split_at_mut(take);
            top_rest = t_tail;
            bot_rest = b_tail;
            s.spawn(move || {
                for (a, b) in t_chunk.iter_mut().zip(b_chunk.iter_mut()) {
                    let (x, y) = (*a, *b);
                    *a = x + y;
                    *b = x - y;
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    /// Dense Sylvester Hadamard H_n (test oracle).
    pub fn hadamard_dense(n: usize) -> Mat {
        assert!(n.is_power_of_two());
        let mut h = Mat::from_vec(1, 1, vec![1.0]);
        while h.rows() < n {
            let m = h.rows();
            let mut next = Mat::zeros(2 * m, 2 * m);
            for i in 0..m {
                for j in 0..m {
                    let v = h.get(i, j);
                    next.set(i, j, v);
                    next.set(i, j + m, v);
                    next.set(i + m, j, v);
                    next.set(i + m, j + m, -v);
                }
            }
            h = next;
        }
        h
    }

    #[test]
    fn matches_dense_hadamard() {
        let mut rng = Pcg64::seeded(1);
        for &n in &[1usize, 2, 4, 8, 32, 128] {
            let mut v: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let expected = hadamard_dense(n).gemv(&v);
            fwht_inplace(&mut v);
            for (a, b) in v.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn involution_property() {
        let mut rng = Pcg64::seeded(2);
        let n = 64;
        let orig: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut v = orig.clone();
        fwht_inplace(&mut v);
        fwht_inplace(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - n as f64 * b).abs() < 1e-9);
        }
    }

    #[test]
    fn parseval() {
        let mut rng = Pcg64::seeded(3);
        let n = 256;
        let orig: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut v = orig.clone();
        fwht_inplace(&mut v);
        let e_in: f64 = orig.iter().map(|x| x * x).sum();
        let e_out: f64 = v.iter().map(|x| x * x).sum();
        assert!((e_out - n as f64 * e_in).abs() < 1e-7 * e_out.max(1.0));
    }

    #[test]
    fn columns_variant_matches_per_column() {
        let mut rng = Pcg64::seeded(4);
        let (n, c) = (32, 5);
        let m = Mat::from_fn(n, c, |_, _| rng.next_gaussian());
        let mut buf = m.data().to_vec();
        fwht_columns(&mut buf, n, c);
        for j in 0..c {
            let mut col = m.col(j);
            fwht_inplace(&mut col);
            for i in 0..n {
                assert!((buf[i * c + j] - col[i]).abs() < 1e-9);
            }
        }
    }

    /// The historical stage-major loop, kept as the bitwise oracle for
    /// the blocked/threaded rewrite.
    fn fwht_columns_reference(data: &mut [f64], n: usize, c: usize) {
        let mut h = 1;
        while h < n {
            for block in (0..n).step_by(2 * h) {
                for i in block..block + h {
                    let (top, bot) = data.split_at_mut((i + h) * c);
                    let a_row = &mut top[i * c..(i + 1) * c];
                    let b_row = &mut bot[..c];
                    for j in 0..c {
                        let (a, b) = (a_row[j], b_row[j]);
                        a_row[j] = a + b;
                        b_row[j] = a - b;
                    }
                }
            }
            h *= 2;
        }
    }

    #[test]
    fn panelled_serial_matches_reference_bitwise() {
        let mut rng = Pcg64::seeded(5);
        // shapes straddling one panel, several panels, and odd columns
        for &(n, c) in &[(1usize, 3usize), (64, 1), (256, 7), (1024, 40)] {
            let orig: Vec<f64> = (0..n * c).map(|_| rng.next_gaussian()).collect();
            let mut blocked = orig.clone();
            let mut reference = orig.clone();
            fwht_columns_serial(&mut blocked, n, c);
            fwht_columns_reference(&mut reference, n, c);
            for (a, b) in blocked.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{c}");
            }
        }
    }

    #[test]
    fn threaded_recursion_matches_serial_bitwise() {
        let mut rng = Pcg64::seeded(6);
        for &(n, c, threads) in &[(256usize, 9usize, 2usize), (512, 16, 4), (1024, 5, 8)] {
            let orig: Vec<f64> = (0..n * c).map(|_| rng.next_gaussian()).collect();
            let mut par = orig.clone();
            let mut ser = orig.clone();
            fwht_columns_rec(&mut par, n, c, threads);
            fwht_columns_serial(&mut ser, n, c);
            for (a, b) in par.iter().zip(&ser) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{c}x{threads}");
            }
        }
    }

    #[test]
    fn public_path_above_threshold_matches_reference_bitwise() {
        // 2048·64·11 ≈ 1.4M element-ops > PAR_BUTTERFLY_THRESHOLD: the
        // public entry point takes the threaded path on multi-core hosts
        let mut rng = Pcg64::seeded(7);
        let (n, c) = (2048, 64);
        let orig: Vec<f64> = (0..n * c).map(|_| rng.next_gaussian()).collect();
        let mut fast = orig.clone();
        let mut reference = orig.clone();
        fwht_columns(&mut fast, n, c);
        fwht_columns_reference(&mut reference, n, c);
        for (a, b) in fast.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn zero_columns_is_a_validated_noop() {
        let mut empty: Vec<f64> = Vec::new();
        fwht_columns(&mut empty, 8, 0); // must not panic
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        fwht_inplace(&mut [1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "positive power of two, got 0")]
    fn rejects_zero_length_inplace() {
        fwht_inplace(&mut []);
    }

    #[test]
    #[should_panic(expected = "positive power of two, got 0")]
    fn rejects_zero_length_columns() {
        fwht_columns(&mut [], 0, 3);
    }

    #[test]
    #[should_panic(expected = "buffer mismatch")]
    fn rejects_buffer_mismatch() {
        fwht_columns(&mut [1.0, 2.0, 3.0], 4, 1);
    }
}
