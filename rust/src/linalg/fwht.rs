//! Fast Walsh–Hadamard Transform — the O(N log N) encode path of the
//! fast-transform codes (§4 "Fast transforms", Appendix D).
//!
//! Unnormalized Sylvester ordering, matching the L1 Pallas kernel
//! (`python/compile/kernels/fwht.py`); callers apply `1/sqrt(N)` for the
//! orthonormal/tight-frame scaling.

/// In-place N-point WHT of a vector. `v.len()` must be a power of two.
pub fn fwht_inplace(v: &mut [f64]) {
    let n = v.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        for block in (0..n).step_by(2 * h) {
            for i in block..block + h {
                let (a, b) = (v[i], v[i + h]);
                v[i] = a + b;
                v[i + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// WHT applied to every column of a row-major `n × c` matrix buffer.
///
/// Works column-block-wise directly on the row-major layout: for each
/// butterfly stage the partner rows are `i` and `i + h`, and the add/sub
/// runs vectorized across the full row — this is the CPU analog of the
/// Pallas kernel's stride-permuted VPU stages and is much faster than
/// transposing or gathering per-column.
pub fn fwht_columns(data: &mut [f64], n: usize, c: usize) {
    assert_eq!(data.len(), n * c, "fwht_columns: buffer mismatch");
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        for block in (0..n).step_by(2 * h) {
            for i in block..block + h {
                let (top, bot) = data.split_at_mut((i + h) * c);
                let a_row = &mut top[i * c..(i + 1) * c];
                let b_row = &mut bot[..c];
                for j in 0..c {
                    let (a, b) = (a_row[j], b_row[j]);
                    a_row[j] = a + b;
                    b_row[j] = a - b;
                }
            }
        }
        h *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    /// Dense Sylvester Hadamard H_n (test oracle).
    pub fn hadamard_dense(n: usize) -> Mat {
        assert!(n.is_power_of_two());
        let mut h = Mat::from_vec(1, 1, vec![1.0]);
        while h.rows() < n {
            let m = h.rows();
            let mut next = Mat::zeros(2 * m, 2 * m);
            for i in 0..m {
                for j in 0..m {
                    let v = h.get(i, j);
                    next.set(i, j, v);
                    next.set(i, j + m, v);
                    next.set(i + m, j, v);
                    next.set(i + m, j + m, -v);
                }
            }
            h = next;
        }
        h
    }

    #[test]
    fn matches_dense_hadamard() {
        let mut rng = Pcg64::seeded(1);
        for &n in &[1usize, 2, 4, 8, 32, 128] {
            let mut v: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let expected = hadamard_dense(n).gemv(&v);
            fwht_inplace(&mut v);
            for (a, b) in v.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn involution_property() {
        let mut rng = Pcg64::seeded(2);
        let n = 64;
        let orig: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut v = orig.clone();
        fwht_inplace(&mut v);
        fwht_inplace(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - n as f64 * b).abs() < 1e-9);
        }
    }

    #[test]
    fn parseval() {
        let mut rng = Pcg64::seeded(3);
        let n = 256;
        let orig: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut v = orig.clone();
        fwht_inplace(&mut v);
        let e_in: f64 = orig.iter().map(|x| x * x).sum();
        let e_out: f64 = v.iter().map(|x| x * x).sum();
        assert!((e_out - n as f64 * e_in).abs() < 1e-7 * e_out.max(1.0));
    }

    #[test]
    fn columns_variant_matches_per_column() {
        let mut rng = Pcg64::seeded(4);
        let (n, c) = (32, 5);
        let m = Mat::from_fn(n, c, |_, _| rng.next_gaussian());
        let mut buf = m.data().to_vec();
        fwht_columns(&mut buf, n, c);
        for j in 0..c {
            let mut col = m.col(j);
            fwht_inplace(&mut col);
            for i in 0..n {
                assert!((buf[i * c + j] - col[i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        fwht_inplace(&mut [1.0, 2.0, 3.0]);
    }
}
