//! Problem assembly: the raw quadratic problem (eq. (1)) and its encoded,
//! partitioned form (eq. (2) + Figure 1 right).
//!
//! [`QuadProblem`] is the ground truth `f(w) = (1/2n)‖Xw−y‖² + (λ/2)‖w‖²`
//! the convergence guarantees are stated against. [`EncodedProblem`] is
//! what the cluster actually stores: `m` worker shards of `(S_iX, S_iy)`,
//! plus the aggregation rules the leader applies to first-k responses —
//! including the replication scheme's fastest-copy-per-partition dedup
//! (§5) and the uncoded baseline's subsample rescaling.
//!
//! Both the raw design matrix and every shard live behind
//! [`DataMat`] — dense row-major or CSR — and a [`StorageKind`] threads
//! through the `*_stored` encode constructors: row-selection schemes
//! (identity, replication, gradient coding) preserve CSR storage, the
//! transform/random families densify by construction, and requesting
//! `--storage sparse` from a densifying family is a hard error. The
//! optimizers, the cluster, and the aggregation rules never look at the
//! backend: coding-obliviousness extends to storage.

use crate::encoding::EncoderKind;
use crate::linalg::{self, DataMat, GradMode, Mat, Precision, StorageKind};
use crate::rng::Pcg64;
use anyhow::{bail, ensure, Result};

/// The original (uncoded) regularized least-squares problem, eq. (1):
/// `f(w) = (1/2n)‖Xw − y‖² + (λ/2)‖w‖²`.
#[derive(Clone)]
pub struct QuadProblem {
    /// Design matrix `X` (n x p), dense or CSR.
    pub x: DataMat,
    /// Targets `y` (length n).
    pub y: Vec<f64>,
    /// Ridge coefficient λ (0 for plain least squares).
    pub lambda: f64,
}

impl QuadProblem {
    /// Assemble from parts — accepts a dense [`Mat`], a
    /// [`CsrMat`](crate::linalg::CsrMat), or a [`DataMat`] (panics on
    /// row/length mismatch).
    pub fn new(x: impl Into<DataMat>, y: Vec<f64>, lambda: f64) -> Self {
        let x = x.into();
        assert_eq!(x.rows(), y.len(), "QuadProblem: X rows != y length");
        QuadProblem { x, y, lambda }
    }

    /// The paper's synthetic ridge workload (§5): `X_ij ~ N(0,1)`,
    /// `y_i ~ N(0, p)`.
    pub fn synthetic_gaussian(n: usize, p: usize, lambda: f64, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0x9e0);
        let x = Mat::from_fn(n, p, |_, _| rng.next_gaussian());
        let sp = (p as f64).sqrt();
        let y = (0..n).map(|_| sp * rng.next_gaussian()).collect();
        QuadProblem { x: x.into(), y, lambda }
    }

    /// A well-conditioned planted problem: `y = Xw* + noise` — useful in
    /// tests where a known solution neighborhood matters.
    pub fn planted(n: usize, p: usize, lambda: f64, noise: f64, seed: u64) -> (Self, Vec<f64>) {
        let mut rng = Pcg64::new(seed, 0x91a);
        let x = Mat::from_fn(n, p, |_, _| rng.next_gaussian());
        let w_star: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
        let mut y = x.gemv(&w_star);
        for yi in &mut y {
            *yi += noise * rng.next_gaussian();
        }
        (QuadProblem { x: x.into(), y, lambda }, w_star)
    }

    /// Sample count n.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Dimension p.
    pub fn p(&self) -> usize {
        self.x.cols()
    }

    /// True objective `f(w)`.
    pub fn objective(&self, w: &[f64]) -> f64 {
        let resid = linalg::sub(&self.x.gemv(w), &self.y);
        let n = self.n() as f64;
        linalg::dot(&resid, &resid) / (2.0 * n)
            + 0.5 * self.lambda * linalg::dot(w, w)
    }

    /// True gradient `∇f(w) = (1/n)Xᵀ(Xw−y) + λw`.
    pub fn grad(&self, w: &[f64]) -> Vec<f64> {
        let resid = linalg::sub(&self.x.gemv(w), &self.y);
        let mut g = self.x.gemv_t(&resid);
        let n = self.n() as f64;
        for (gi, wi) in g.iter_mut().zip(w) {
            *gi = *gi / n + self.lambda * wi;
        }
        g
    }

    /// Closed-form optimum via Cholesky on the normal equations
    /// `(XᵀX + λ n I) w = Xᵀy`, on either storage backend (the Gram
    /// matrix is dense `p × p` regardless; the ridge convention lives in
    /// [`ridge_solve_normal`](crate::linalg::ridge_solve_normal)).
    pub fn exact_solution(&self) -> Option<Vec<f64>> {
        linalg::ridge_solve_normal(
            self.x.gram(),
            &self.x.gemv_t(&self.y),
            self.lambda,
            self.x.rows() as f64,
        )
    }

    /// `M = λ_max((1/n)XᵀX) + λ` — the smoothness constant in Theorem 1's
    /// step-size rule (power iteration).
    pub fn smoothness(&self) -> f64 {
        self.x.spectral_bound(60, 0xb0) / self.n() as f64 + self.lambda
    }
}

/// Which aggregation semantics the leader applies (§2 / §5 baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Coded: every worker holds `S_i X`; first-k responses are averaged
    /// with the `1/(c·η·n)` normalization.
    Coded,
    /// Replication: `partitions = m/β` raw partitions, each stored on β
    /// workers; the leader uses the fastest copy of each partition.
    Replicated { partitions: usize },
    /// Uncoded `S = I`: one raw partition per worker; first-k responses
    /// give a rescaled-subsample gradient.
    Uncoded,
    /// Gradient coding (Tandon et al., the paper's ref. [20]) with the
    /// fractional-repetition construction: `groups = m/(s+1)` worker
    /// groups, each group's workers all store the same `s+1` partitions
    /// and report their *sum*; the leader needs one responder per group
    /// for the **exact** gradient (tolerates any `s` stragglers at
    /// redundancy `β = s+1`). The comparator the paper's intro argues
    /// against: exactness costs redundancy linear in the straggler count.
    GradientCoded { groups: usize },
    /// Sequential (temporal) gradient coding, `--scheme seq:W:B`: worker
    /// home blocks split into `W` window slots, the first `B` mirrored on
    /// a buddy at weight `1/√2` — a unit-tight frame (`SᵀS = I`), so the
    /// leader aggregates exactly like [`Scheme::Coded`] with
    /// `gram_scale = 1` (see [`encoding::temporal`](crate::encoding::temporal)).
    SeqCoded { window: usize, burst: usize },
    /// Stochastic (temporal) gradient coding, `--scheme stoch:Q`: every
    /// raw row backed on a random buddy with probability `q`. Aggregated
    /// like [`Scheme::Coded`] with the *realized* duplication as
    /// `gram_scale` — unbiased over the backup draws, approximate per
    /// realization.
    StochCoded,
}

/// One worker's stored shard (already encoded + zero-padded).
#[derive(Clone)]
pub struct WorkerShard {
    /// Encoded rows (padded to `rows_padded`) × p, dense or CSR.
    pub x: DataMat,
    /// Encoded targets, length = `x.rows()`.
    pub y: Vec<f64>,
    /// Rows before zero-padding (diagnostics only — padding is exact).
    pub rows_real: usize,
    /// Which raw partition this shard replicates (replication scheme);
    /// equals the worker index otherwise.
    pub partition_id: usize,
    /// Resolved worker-gradient strategy for *this* shard (never
    /// [`GradMode::Auto`] — `Auto` requests are resolved per shard at
    /// [`EncodedProblem::with_grad_mode`] time from the madd cost model).
    /// Engines read this at staging time to decide whether to build the
    /// Gram cache.
    pub grad_mode: GradMode,
}

/// The encoded, partitioned problem the cluster serves (Figure 1, right).
#[derive(Clone)]
pub struct EncodedProblem {
    /// Per-worker encoded shards (length m).
    pub shards: Vec<WorkerShard>,
    /// Aggregation semantics the leader applies.
    pub scheme: Scheme,
    /// Encoder family that produced the shards.
    pub kind: EncoderKind,
    /// Effective redundancy `rows_out / n`.
    pub beta: f64,
    /// `c` with `SᵀS = c·I` — the gradient normalization constant.
    pub gram_scale: f64,
    /// Shard storage backend actually in use (never
    /// [`StorageKind::Auto`] — `Auto` requests are resolved at encode
    /// time from the input representation and the scheme).
    pub storage: StorageKind,
    /// Worker-shard arithmetic precision. Encoding itself always runs in
    /// f64; [`Precision::F32`] narrows the *stored* shards afterwards, so
    /// workers compute in f32 while the leader (aggregation, step, true
    /// objective on `raw`) stays f64 throughout.
    pub precision: Precision,
    /// Requested worker-gradient strategy (`--grad-mode`; default
    /// [`GradMode::Gemv`], the bitwise-pinned historical path). The
    /// *resolved* per-shard answer lives on [`WorkerShard::grad_mode`];
    /// this field records the request for reporting and cache keys.
    pub grad_mode: GradMode,
    /// Raw problem (kept for true-objective evaluation in traces).
    pub raw: QuadProblem,
}

/// Round shard rows up to a power of two (≥ 8) so they match the AOT
/// artifact buckets; zero rows are exact no-ops for gradient + objective.
pub fn pad_bucket(rows: usize) -> usize {
    rows.next_power_of_two().max(8)
}

/// Resolve the storage kind an encoded problem records: explicit requests
/// pass through, `Auto` reports what the shards actually hold.
fn resolved_storage(shards: &[WorkerShard], requested: StorageKind) -> StorageKind {
    match requested {
        StorageKind::Auto => {
            if shards.iter().any(|s| s.x.is_sparse()) {
                StorageKind::Sparse
            } else {
                StorageKind::Dense
            }
        }
        explicit => explicit,
    }
}

/// Resolve a requested [`GradMode`] for one shard. `Auto` compares the
/// per-round madd cost of the two strategies — `p²` for the symmetric
/// Gram gemv vs `2·nnz` for the two shard passes of the fused kernel —
/// and only ever picks `Gram` on a dense f64 shard (the cache is dense
/// f64 by construction, so sparse or narrowed shards gain nothing).
fn resolve_grad_mode(requested: GradMode, x: &DataMat) -> GradMode {
    match requested {
        GradMode::Gemv => GradMode::Gemv,
        GradMode::Gram => GradMode::Gram,
        GradMode::Auto => {
            let p = x.cols();
            let dense_f64 = !x.is_sparse() && x.precision() == Precision::F64;
            if dense_f64 && p * p < 2 * x.rows() * x.cols() {
                GradMode::Gram
            } else {
                GradMode::Gemv
            }
        }
    }
}

/// Narrow fully-built (encoded, padded, storage-resolved) shards to the
/// requested precision. `ỹ` stays f64 — it is leader-visible state (the
/// residual subtraction widens per-entry), and its footprint is one
/// column against the `p`-wide `X̃` payload.
fn shards_to_precision(shards: Vec<WorkerShard>, precision: Precision) -> Vec<WorkerShard> {
    shards
        .into_iter()
        .map(|WorkerShard { x, y, rows_real, partition_id, grad_mode }| WorkerShard {
            x: x.to_precision(precision),
            y,
            rows_real,
            partition_id,
            grad_mode,
        })
        .collect()
}

/// One round's mini-batch plan: which rows of each worker's shard that
/// worker computes its gradient on (the stochastic-coded-optimization
/// subsystem's sampling unit).
///
/// Sampling is **coding-oblivious**: the plan is pure row indices into the
/// already-encoded shards, so it composes with every encoding scheme —
/// workers never see `S`, and the leader's normalization
/// ([`EncodedProblem::aggregate_grad_batch`]) is the only place the
/// subsample size enters.
///
/// Each worker's block is a *circular* contiguous row-block of its
/// `rows_real` real rows (padding rows are never sampled): a uniformly
/// random start offset plus a fixed length, wrapping around the shard end.
/// Circularity is what makes every row's inclusion probability exactly
/// `b_i / rows_real` — the property the unbiasedness guarantee (and its
/// property test) rests on. A wrapped block is represented as two
/// half-open `(lo, hi)` segments.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// Per-worker row segments (1 segment, or 2 when the circular block
    /// wraps), half-open `(lo, hi)` ranges into the shard's real rows.
    pub segments: Vec<Vec<(usize, usize)>>,
}

impl BatchPlan {
    /// Worker count the plan covers.
    pub fn workers(&self) -> usize {
        self.segments.len()
    }

    /// Sampled row count `b_i` for one worker.
    pub fn rows(&self, worker: usize) -> usize {
        self.segments[worker].iter().map(|&(lo, hi)| hi - lo).sum()
    }
}

impl EncodedProblem {
    /// Encode `prob` with the given family and distribute over `m` workers,
    /// keeping the input storage representation ([`StorageKind::Auto`]).
    ///
    /// * Coded families split the `βn` encoded rows into `m` near-equal
    ///   contiguous blocks.
    /// * `EncoderKind::Identity` produces the uncoded scheme (β forced 1).
    /// * `EncoderKind::Replication` splits the raw rows into `m/β`
    ///   partitions and places copy `c` of partition `j` on worker
    ///   `c·m/β + j` (copies live on distinct workers, as in §5).
    pub fn encode(
        prob: &QuadProblem,
        kind: EncoderKind,
        beta: f64,
        m: usize,
        seed: u64,
    ) -> Result<Self> {
        Self::encode_stored(prob, kind, beta, m, seed, StorageKind::Auto)
    }

    /// [`EncodedProblem::encode`] with an explicit shard [`StorageKind`]:
    /// `Dense` forces dense shards, `Sparse` forces CSR (and errors for
    /// families that densify — every scheme except identity/replication),
    /// `Auto` keeps whatever the scheme produces from the input.
    pub fn encode_stored(
        prob: &QuadProblem,
        kind: EncoderKind,
        beta: f64,
        m: usize,
        seed: u64,
        storage: StorageKind,
    ) -> Result<Self> {
        Self::encode_stored_prec(prob, kind, beta, m, seed, storage, Precision::F64)
    }

    /// [`EncodedProblem::encode_stored`] with an explicit shard
    /// [`Precision`]. The encode itself (transform, padding, storage
    /// resolution) always runs in f64; `Precision::F32` narrows the
    /// finished shards, halving `X̃` memory and letting workers run the
    /// f32 kernels while the leader stays f64.
    pub fn encode_stored_prec(
        prob: &QuadProblem,
        kind: EncoderKind,
        beta: f64,
        m: usize,
        seed: u64,
        storage: StorageKind,
        precision: Precision,
    ) -> Result<Self> {
        ensure!(m >= 1, "need at least one worker");
        let n = prob.n();

        match kind {
            EncoderKind::Replication => {
                let b = beta.round() as usize;
                ensure!(b >= 1, "replication beta must round to >= 1");
                ensure!(
                    m % b == 0,
                    "replication: m={m} must be divisible by beta={b}"
                );
                let partitions = m / b;
                ensure!(n >= partitions, "fewer rows than partitions");
                let part = crate::encoding::spectrum::partition_rows(n, partitions);
                let mut shards = Vec::with_capacity(m);
                for _copy in 0..b {
                    for (j, &(lo, hi)) in part.iter().enumerate() {
                        let xs = prob.x.row_band(lo, hi);
                        let mut ys = prob.y[lo..hi].to_vec();
                        let rows_real = xs.rows();
                        let padded = pad_bucket(rows_real);
                        let xs = xs.pad_rows(padded).into_storage(storage);
                        ys.resize(padded, 0.0);
                        shards.push(WorkerShard {
                            x: xs,
                            y: ys,
                            rows_real,
                            partition_id: j,
                            grad_mode: GradMode::Gemv,
                        });
                    }
                }
                let storage = resolved_storage(&shards, storage);
                let shards = shards_to_precision(shards, precision);
                Ok(EncodedProblem {
                    shards,
                    scheme: Scheme::Replicated { partitions },
                    kind,
                    beta: b as f64,
                    gram_scale: 1.0, // per-partition gradients are raw-scale
                    storage,
                    precision,
                    grad_mode: GradMode::Gemv,
                    raw: prob.clone(),
                })
            }
            _ => {
                let enc = kind.build(n, beta, seed)?;
                Self::encode_with_stored_prec(prob, enc.as_ref(), kind, m, storage, precision)
            }
        }
    }

    /// Gradient-coding baseline (paper ref. [20], fractional repetition):
    /// tolerate any `s` stragglers with the **exact** gradient, at storage
    /// redundancy `β = s+1`.
    ///
    /// Workers are split into `m/(s+1)` groups; every worker in group `g`
    /// stores the concatenation of group `g`'s `s+1` raw partitions (so its
    /// response is the *sum* of their gradients), and the leader dedups one
    /// response per group. With `k ≥ m − s`, every group is guaranteed a
    /// responder, so the aggregate equals the full gradient exactly.
    pub fn encode_gradient_coding(
        prob: &QuadProblem,
        s: usize,
        m: usize,
        seed: u64,
    ) -> Result<Self> {
        Self::encode_gradient_coding_stored(prob, s, m, seed, StorageKind::Auto)
    }

    /// [`EncodedProblem::encode_gradient_coding`] with an explicit shard
    /// [`StorageKind`] (row selection preserves sparsity, so all three
    /// kinds are valid here).
    pub fn encode_gradient_coding_stored(
        prob: &QuadProblem,
        s: usize,
        m: usize,
        seed: u64,
        storage: StorageKind,
    ) -> Result<Self> {
        Self::encode_gradient_coding_stored_prec(prob, s, m, seed, storage, Precision::F64)
    }

    /// [`EncodedProblem::encode_gradient_coding_stored`] with an explicit
    /// shard [`Precision`] (shards are narrowed after padding, exactly as
    /// in [`EncodedProblem::encode_stored_prec`]).
    pub fn encode_gradient_coding_stored_prec(
        prob: &QuadProblem,
        s: usize,
        m: usize,
        _seed: u64,
        storage: StorageKind,
        precision: Precision,
    ) -> Result<Self> {
        ensure!(m >= 1, "need at least one worker");
        let rep = s + 1;
        ensure!(
            m % rep == 0,
            "gradient coding: m={m} must be divisible by s+1={rep}"
        );
        let groups = m / rep;
        let n = prob.n();
        ensure!(n >= groups, "fewer rows than groups");
        // group g owns the contiguous row range part[g]
        let part = crate::encoding::spectrum::partition_rows(n, groups);
        let mut shards = Vec::with_capacity(m);
        for _copy in 0..rep {
            for (g, &(lo, hi)) in part.iter().enumerate() {
                let xs = prob.x.row_band(lo, hi);
                let mut ys = prob.y[lo..hi].to_vec();
                let rows_real = xs.rows();
                let padded = pad_bucket(rows_real);
                let xs = xs.pad_rows(padded).into_storage(storage);
                ys.resize(padded, 0.0);
                shards.push(WorkerShard {
                    x: xs,
                    y: ys,
                    rows_real,
                    partition_id: g,
                    grad_mode: GradMode::Gemv,
                });
            }
        }
        let storage = resolved_storage(&shards, storage);
        let shards = shards_to_precision(shards, precision);
        Ok(EncodedProblem {
            shards,
            scheme: Scheme::GradientCoded { groups },
            kind: EncoderKind::Replication, // closest CLI label; scheme disambiguates
            beta: rep as f64,
            gram_scale: 1.0,
            storage,
            precision,
            grad_mode: GradMode::Gemv,
            raw: prob.clone(),
        })
    }

    /// Temporal gradient coding (`--scheme seq:W:B | stoch:Q`): encode
    /// with one of the [`encoding::temporal`](crate::encoding::temporal)
    /// row-selection codes and shard at the code's **worker boundaries**
    /// (each worker gets its home copies plus the backups it hosts for
    /// its buddies — not a blind `partition_rows` split, which would put
    /// a row's two copies on the same worker and void the redundancy).
    ///
    /// `scheme` must be `Seq` or `Stoch`; `TemporalScheme::None` is the
    /// caller's signal to use the ordinary within-round constructors.
    pub fn encode_temporal(
        prob: &QuadProblem,
        scheme: crate::encoding::temporal::TemporalScheme,
        m: usize,
        seed: u64,
    ) -> Result<Self> {
        Self::encode_temporal_stored_prec(prob, scheme, m, seed, StorageKind::Auto, Precision::F64)
    }

    /// [`EncodedProblem::encode_temporal`] with explicit shard
    /// [`StorageKind`] and [`Precision`] (same conventions as
    /// [`EncodedProblem::encode_stored_prec`]: encoding runs in f64, the
    /// finished shards are narrowed; `Sparse` is rejected because the
    /// temporal codes' scaled-row gather densifies).
    pub fn encode_temporal_stored_prec(
        prob: &QuadProblem,
        scheme: crate::encoding::temporal::TemporalScheme,
        m: usize,
        seed: u64,
        storage: StorageKind,
        precision: Precision,
    ) -> Result<Self> {
        use crate::encoding::temporal::{
            SequentialGradientCoding, StochasticGradientCoding, TemporalScheme,
        };
        ensure!(m >= 1, "need at least one worker");
        if storage == StorageKind::Sparse {
            bail!("--storage sparse: temporal codes densify encoded rows; use dense|auto");
        }
        let n = prob.n();
        type TemporalParts = (Box<dyn crate::encoding::Encoder>, Vec<(usize, usize)>, Scheme);
        let (enc, boundaries, out_scheme): TemporalParts =
            match scheme {
                TemporalScheme::None => {
                    bail!("encode_temporal called with scheme none; use EncodedProblem::encode")
                }
                TemporalScheme::Seq { window, burst } => {
                    let e = SequentialGradientCoding::new(n, m, window, burst)?;
                    let b = e.worker_boundaries().to_vec();
                    (Box::new(e), b, Scheme::SeqCoded { window, burst })
                }
                TemporalScheme::Stoch { q } => {
                    let e = StochasticGradientCoding::new(n, m, q, seed)?;
                    let b = e.worker_boundaries().to_vec();
                    (Box::new(e), b, Scheme::StochCoded)
                }
            };
        let y_mat = Mat::col_vec(&prob.y);
        let sx = enc.encode_data(&prob.x);
        let sy_mat = enc.encode(&y_mat);
        let sy: Vec<f64> = (0..sy_mat.rows()).map(|i| sy_mat.get(i, 0)).collect();
        let shards: Vec<WorkerShard> = boundaries
            .iter()
            .enumerate()
            .map(|(i, &(lo, hi))| {
                let xs = sx.row_band(lo, hi);
                let mut ys = sy[lo..hi].to_vec();
                let rows_real = xs.rows();
                let padded = pad_bucket(rows_real);
                let xs = xs.pad_rows(padded).into_storage(storage);
                ys.resize(padded, 0.0);
                WorkerShard {
                    x: xs,
                    y: ys,
                    rows_real,
                    partition_id: i,
                    grad_mode: GradMode::Gemv,
                }
            })
            .collect();
        let storage = resolved_storage(&shards, storage);
        let shards = shards_to_precision(shards, precision);
        Ok(EncodedProblem {
            shards,
            scheme: out_scheme,
            kind: EncoderKind::Replication, // closest CLI label; scheme disambiguates
            beta: enc.beta(),
            gram_scale: enc.gram_scale(),
            storage,
            precision,
            grad_mode: GradMode::Gemv,
            raw: prob.clone(),
        })
    }

    /// Encode with a pre-built encoder (the §5 "bank" path: matrix
    /// factorization reuses one encoder per padded-size bucket instead of
    /// rebuilding ETFs per subproblem). `encoder.rows_in()` must equal
    /// `prob.n()`; pad the problem rows first if needed.
    pub fn encode_with(
        prob: &QuadProblem,
        enc: &dyn crate::encoding::Encoder,
        kind: EncoderKind,
        m: usize,
    ) -> Result<Self> {
        Self::encode_with_stored(prob, enc, kind, m, StorageKind::Auto)
    }

    /// [`EncodedProblem::encode_with`] with an explicit shard
    /// [`StorageKind`]. `Sparse` is rejected unless the encoder preserves
    /// sparsity — a transform/random family would silently densify and
    /// the CSR wrapper would cost *more* than dense, so it is a hard
    /// error instead.
    pub fn encode_with_stored(
        prob: &QuadProblem,
        enc: &dyn crate::encoding::Encoder,
        kind: EncoderKind,
        m: usize,
        storage: StorageKind,
    ) -> Result<Self> {
        Self::encode_with_stored_prec(prob, enc, kind, m, storage, Precision::F64)
    }

    /// [`EncodedProblem::encode_with_stored`] with an explicit shard
    /// [`Precision`]: the encoder runs in f64 and the finished shards are
    /// narrowed, so `S` and the partitioning are bit-identical across
    /// precisions and only the stored payload differs.
    pub fn encode_with_stored_prec(
        prob: &QuadProblem,
        enc: &dyn crate::encoding::Encoder,
        kind: EncoderKind,
        m: usize,
        storage: StorageKind,
        precision: Precision,
    ) -> Result<Self> {
        ensure!(m >= 1, "need at least one worker");
        ensure!(
            enc.rows_in() == prob.n(),
            "encoder built for n={} but problem has n={}",
            enc.rows_in(),
            prob.n()
        );
        ensure!(
            kind != EncoderKind::Replication,
            "replication does not go through encode_with"
        );
        if storage == StorageKind::Sparse && !enc.preserves_sparsity() {
            bail!(
                "--storage sparse: encoder family '{}' densifies encoded rows; \
                 use identity/replication, or --storage dense|auto",
                enc.name()
            );
        }
        let y_mat = Mat::col_vec(&prob.y);
        let sx = enc.encode_data(&prob.x);
        let sy_mat = enc.encode(&y_mat);
        let sy: Vec<f64> = (0..sy_mat.rows()).map(|i| sy_mat.get(i, 0)).collect();
        let rows_out = enc.rows_out();
        ensure!(rows_out >= m, "fewer encoded rows than workers");
        let part = crate::encoding::spectrum::partition_rows(rows_out, m);
        let shards: Vec<WorkerShard> = part
            .iter()
            .enumerate()
            .map(|(i, &(lo, hi))| {
                let xs = sx.row_band(lo, hi);
                let mut ys = sy[lo..hi].to_vec();
                let rows_real = xs.rows();
                let padded = pad_bucket(rows_real);
                let xs = xs.pad_rows(padded).into_storage(storage);
                ys.resize(padded, 0.0);
                WorkerShard {
                    x: xs,
                    y: ys,
                    rows_real,
                    partition_id: i,
                    grad_mode: GradMode::Gemv,
                }
            })
            .collect();
        let scheme = if kind == EncoderKind::Identity {
            Scheme::Uncoded
        } else {
            Scheme::Coded
        };
        let storage = resolved_storage(&shards, storage);
        let shards = shards_to_precision(shards, precision);
        Ok(EncodedProblem {
            shards,
            scheme,
            kind,
            beta: enc.beta(),
            gram_scale: enc.gram_scale(),
            storage,
            precision,
            grad_mode: GradMode::Gemv,
            raw: prob.clone(),
        })
    }

    /// Worker/shard count m.
    pub fn m(&self) -> usize {
        self.shards.len()
    }

    /// Problem dimension p.
    pub fn p(&self) -> usize {
        self.raw.p()
    }

    /// Raw (pre-encoding) sample count n.
    pub fn n_raw(&self) -> usize {
        self.raw.n()
    }

    /// Total resident bytes across all shards (`X̃` payload arrays plus
    /// the `ỹ` vectors) — the memory axis the storage backends trade on.
    /// Shards resolved to [`GradMode::Gram`] also count their engine-side
    /// cache (`G` is p×p, `c` is p, plus the scalar `ỹᵀỹ`): the cache is
    /// built at staging time, but it is this encoding that mandates it,
    /// so the trade shows up here.
    pub fn shard_mem_bytes(&self) -> usize {
        let p = self.p();
        let gram_bytes = (p * p + p + 1) * std::mem::size_of::<f64>();
        self.shards
            .iter()
            .map(|s| {
                s.x.mem_bytes()
                    + s.y.len() * std::mem::size_of::<f64>()
                    + if s.grad_mode == GradMode::Gram { gram_bytes } else { 0 }
            })
            .sum()
    }

    /// Select the worker-gradient evaluation strategy (`--grad-mode`;
    /// default [`GradMode::Gemv`]) and resolve it per shard.
    ///
    /// * `Gemv` — the historical bitwise-pinned path; a no-op.
    /// * `Gram` — every shard serves `g = G·w − c` from a staged Gram
    ///   cache. Requires dense f64 shards: CSR and f32 shards are hard
    ///   errors naming the offending axis (a CSR Gram cache is dense
    ///   anyway, and an f32 source would break the ≤1e-9 numeric pin).
    /// * `Auto` — per shard, `Gram` iff `p² < 2·nnz` on a dense f64
    ///   shard (the madd cost model), else `Gemv`.
    ///
    /// Engines read the resolved [`WorkerShard::grad_mode`] when staging
    /// shards and build the cache there, so call this *before* handing
    /// the encoding to an engine.
    pub fn with_grad_mode(mut self, mode: GradMode) -> Result<Self> {
        if mode == GradMode::Gram {
            if let Some(s) = self.shards.iter().find(|s| s.x.is_sparse()) {
                bail!(
                    "--grad-mode gram needs dense shards, but worker {} holds CSR: \
                     its Gram cache G = X̃ᵀX̃ would be dense anyway — use \
                     --storage dense, or --grad-mode gemv|auto",
                    s.partition_id
                );
            }
            ensure!(
                self.precision == Precision::F64,
                "--grad-mode gram needs f64 shards: the cache accumulates in f64 and \
                 an f32 source would break the ≤1e-9 equivalence pin — use \
                 --precision f64, or --grad-mode gemv|auto"
            );
        }
        self.grad_mode = mode;
        for s in &mut self.shards {
            s.grad_mode = resolve_grad_mode(mode, &s.x);
        }
        Ok(self)
    }

    /// Count of *distinct* data contributions in a responder set: distinct
    /// partitions for replication, responder count otherwise.
    fn effective_responders(&self, responders: &[usize]) -> Vec<usize> {
        match self.scheme {
            Scheme::Replicated { partitions } | Scheme::GradientCoded { groups: partitions } => {
                let mut seen = vec![false; partitions];
                let mut keep = Vec::new();
                for &wid in responders {
                    let pid = self.shards[wid].partition_id;
                    if !seen[pid] {
                        seen[pid] = true;
                        keep.push(wid);
                    }
                }
                keep
            }
            _ => responders.to_vec(),
        }
    }

    /// Leader-side gradient aggregation over first-k responses (§2):
    /// returns `(∇̂f(w), f̂(w))` — the descent-driving estimate of the
    /// *raw* gradient/objective, ridge term included.
    ///
    /// `responses` holds `(worker_id, g_i, f_i)` with
    /// `g_i = X̃_iᵀ(X̃_i w − ỹ_i)` and `f_i = ‖X̃_i w − ỹ_i‖²` in arrival
    /// order; only the entries the gather policy admitted should be passed.
    pub fn aggregate_grad(
        &self,
        w: &[f64],
        responses: &[(usize, Vec<f64>, f64)],
    ) -> (Vec<f64>, f64) {
        let mut g = Vec::new();
        let f_est = self.aggregate_grad_into(w, responses, &mut g);
        (g, f_est)
    }

    /// [`EncodedProblem::aggregate_grad`] writing the gradient into a
    /// caller-held buffer (resized to `p`, then zeroed) and returning
    /// `f̂(w)` — the steady-state form that lets an optimizer stepper
    /// keep one gradient scratch vector for a whole run instead of
    /// allocating per round.
    pub fn aggregate_grad_into(
        &self,
        w: &[f64],
        responses: &[(usize, Vec<f64>, f64)],
        g: &mut Vec<f64>,
    ) -> f64 {
        let p = self.p();
        g.clear();
        g.resize(p, 0.0);
        let mut f = 0.0;
        match self.scheme {
            Scheme::Replicated { .. } | Scheme::GradientCoded { .. } => {
                // partition dedup needs per-round scratch; replication-
                // style schemes keep the allocating path
                let responders: Vec<usize> = responses.iter().map(|r| r.0).collect();
                let used = self.effective_responders(&responders);
                let scale = self.gradient_scale(&used);
                for (wid, gi, fi) in responses {
                    if used.contains(wid) {
                        linalg::axpy(scale, gi, g);
                        f += scale * fi;
                    }
                }
            }
            _ => {
                // identity responder set: every response is used and the
                // scale depends only on the count, so the steady-state
                // round aggregates with no heap traffic (same arithmetic
                // order as the scratch path — bitwise-pinned traces are
                // unaffected)
                let eta = responses.len() as f64 / self.m() as f64;
                let scale = if eta == 0.0 {
                    0.0
                } else {
                    1.0 / (self.gram_scale * eta * self.n_raw() as f64)
                };
                for (_, gi, fi) in responses {
                    linalg::axpy(scale, gi, g);
                    f += scale * fi;
                }
            }
        }
        let lambda = self.raw.lambda;
        for (gi, wi) in g.iter_mut().zip(w) {
            *gi += lambda * wi;
        }
        0.5 * f + 0.5 * lambda * linalg::dot(w, w)
    }

    /// Sample one round's block-row mini-batch plan: every worker gets a
    /// circular contiguous block of `⌈batch_frac · rows_real⌉` of its real
    /// rows at a uniformly random offset (so each row's inclusion
    /// probability is exactly `b_i / rows_real`). `batch_frac = 1`
    /// deterministically yields the full shard `(0, rows_real)` without
    /// consuming randomness — the full-batch plan is the full gradient
    /// round, bit for bit.
    ///
    /// The RNG is the caller's (normally [`CodedSgd`]'s seeded batch
    /// stream); draws are consumed in worker-index order, which is part of
    /// the reproducibility contract.
    ///
    /// [`CodedSgd`]: crate::optim::CodedSgd
    pub fn sample_batch(&self, batch_frac: f64, rng: &mut Pcg64) -> BatchPlan {
        assert!(
            batch_frac > 0.0 && batch_frac <= 1.0,
            "batch_frac must be in (0, 1], got {batch_frac}"
        );
        let segments = self
            .shards
            .iter()
            .map(|s| {
                let rows = s.rows_real;
                debug_assert!(rows >= 1, "shard with no real rows");
                let b = ((batch_frac * rows as f64).ceil() as usize).clamp(1, rows);
                if b == rows {
                    vec![(0, rows)]
                } else {
                    let start = rng.next_below(rows as u64) as usize;
                    if start + b <= rows {
                        vec![(start, start + b)]
                    } else {
                        vec![(start, rows), (0, start + b - rows)]
                    }
                }
            })
            .collect();
        BatchPlan { segments }
    }

    /// Leader-side aggregation of mini-batch gradient responses — the
    /// batch counterpart of [`EncodedProblem::aggregate_grad`], with the
    /// scheme-aware normalization extended by the per-worker subsample
    /// factor: each worker's term is scaled by `rows_real_i / b_i` before
    /// the usual scheme scale, i.e. `1/(c·η·n·b)` overall for the
    /// coded/uncoded schemes at uniform batch fraction `b`.
    ///
    /// With [`BatchPlan`]'s circular blocks this makes the estimate
    /// **unbiased** over the sampling RNG, conditional on the responder
    /// set: `E[ĝ_batch | A] = ĝ_full(A)` (pinned by a seeded property
    /// test). At `batch_frac = 1` every factor is 1 and this reduces to
    /// `aggregate_grad` exactly.
    pub fn aggregate_grad_batch(
        &self,
        w: &[f64],
        responses: &[(usize, Vec<f64>, f64)],
        plan: &BatchPlan,
    ) -> (Vec<f64>, f64) {
        let mut g = Vec::new();
        let f_est = self.aggregate_grad_batch_into(w, responses, plan, &mut g);
        (g, f_est)
    }

    /// [`EncodedProblem::aggregate_grad_batch`] writing into a
    /// caller-held buffer, like [`EncodedProblem::aggregate_grad_into`].
    pub fn aggregate_grad_batch_into(
        &self,
        w: &[f64],
        responses: &[(usize, Vec<f64>, f64)],
        plan: &BatchPlan,
        g: &mut Vec<f64>,
    ) -> f64 {
        let p = self.p();
        g.clear();
        g.resize(p, 0.0);
        let mut f = 0.0;
        let responders: Vec<usize> = responses.iter().map(|r| r.0).collect();
        let used = self.effective_responders(&responders);
        let scale = self.gradient_scale(&used);
        for (wid, gi, fi) in responses {
            if used.contains(wid) {
                let b = plan.rows(*wid);
                // hard assert: a hand-built empty plan would otherwise
                // divide by zero and silently poison the gradient with NaN
                assert!(b >= 1, "aggregate_grad_batch: empty batch for worker {wid}");
                let unbias = self.shards[*wid].rows_real as f64 / b as f64;
                linalg::axpy(scale * unbias, gi, g);
                f += scale * unbias * fi;
            }
        }
        let lambda = self.raw.lambda;
        for (gi, wi) in g.iter_mut().zip(w) {
            *gi += lambda * wi;
        }
        0.5 * f + 0.5 * lambda * linalg::dot(w, w)
    }

    /// Overlap gradient-difference aggregation for L-BFGS (§3): given
    /// `Δg_i = g_i(w_t) − g_i(w_{t−1})` from the workers in
    /// `A_t ∩ A_{t−1}`, estimates `r_t ≈ ∇f(w_t) − ∇f(w_{t−1})`
    /// (ridge curvature `λ·u_t` included). This is the paper's `r_t`
    /// re-expressed in our `SᵀS = c·I` normalization.
    pub fn aggregate_grad_diff(&self, u: &[f64], diffs: &[(usize, Vec<f64>)]) -> Vec<f64> {
        let mut r = Vec::new();
        self.aggregate_grad_diff_into(u, diffs, &mut r);
        r
    }

    /// [`EncodedProblem::aggregate_grad_diff`] writing into a
    /// caller-held buffer (resized to `p`, then zeroed).
    pub fn aggregate_grad_diff_into(
        &self,
        u: &[f64],
        diffs: &[(usize, Vec<f64>)],
        r: &mut Vec<f64>,
    ) {
        r.clear();
        r.resize(self.p(), 0.0);
        let responders: Vec<usize> = diffs.iter().map(|d| d.0).collect();
        let used = self.effective_responders(&responders);
        let scale = self.gradient_scale(&used);
        for (wid, dg) in diffs {
            if used.contains(wid) {
                linalg::axpy(scale, dg, r);
            }
        }
        for (ri, ui) in r.iter_mut().zip(u) {
            *ri += self.raw.lambda * ui;
        }
    }

    /// Line-search curvature aggregation (eq. (3) denominator): combines
    /// per-worker `q_i = ‖X̃_i d‖²` from the `D_t` responders into the
    /// estimate of `dᵀ∇²f d = (1/n)‖Xd‖² + λ‖d‖²`.
    pub fn aggregate_curvature(&self, d: &[f64], responses: &[(usize, f64)]) -> f64 {
        let responders: Vec<usize> = responses.iter().map(|r| r.0).collect();
        let used = self.effective_responders(&responders);
        let scale = self.gradient_scale(&used);
        let mut q = 0.0;
        for (wid, qi) in responses {
            if used.contains(wid) {
                q += scale * qi;
            }
        }
        q + self.raw.lambda * linalg::dot(d, d)
    }

    /// Normalization applied to summed worker terms so the estimate is on
    /// the raw-gradient scale `1/n · Xᵀ(...)`:
    /// * Coded / Uncoded: `1/(c·η·n)` with `η = |A|/m` (`c = 1` uncoded).
    /// * Replication: `1/(rows covered by distinct partitions)`.
    fn gradient_scale(&self, used: &[usize]) -> f64 {
        match self.scheme {
            Scheme::Replicated { .. } | Scheme::GradientCoded { .. } => {
                let rows: usize = used.iter().map(|&w| self.shards[w].rows_real).sum();
                if rows == 0 {
                    0.0
                } else {
                    1.0 / rows as f64
                }
            }
            _ => {
                let eta = used.len() as f64 / self.m() as f64;
                if eta == 0.0 {
                    0.0
                } else {
                    1.0 / (self.gram_scale * eta * self.n_raw() as f64)
                }
            }
        }
    }

    /// Property-(4) ε estimate for a given η, by sampled spectra (used to
    /// pick the GD step size and the L-BFGS back-off ν).
    pub fn estimate_epsilon(&self, k: usize, trials: usize, seed: u64) -> Result<f64> {
        ensure!(k >= 1 && k <= self.m(), "bad k");
        ensure!(
            !matches!(
                self.scheme,
                Scheme::Replicated { .. } | Scheme::SeqCoded { .. } | Scheme::StochCoded
            ),
            "epsilon estimation applies to coded/uncoded schemes"
        );
        // rebuild the encoder to materialize S (shards don't keep it)
        let enc = self.kind.build(self.n_raw(), self.beta, seed)?;
        let s = enc.materialize();
        let stats = crate::encoding::spectrum::sample_spectrum(
            &s,
            self.m(),
            k,
            trials,
            seed ^ 0xe51,
            enc.gram_scale(),
        );
        Ok(stats.epsilon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_problem() -> QuadProblem {
        QuadProblem::synthetic_gaussian(64, 8, 0.05, 42)
    }

    #[test]
    fn objective_and_grad_consistent() {
        let prob = small_problem();
        let mut rng = Pcg64::seeded(1);
        let w: Vec<f64> = (0..8).map(|_| rng.next_gaussian()).collect();
        // finite difference check
        let g = prob.grad(&w);
        let eps = 1e-6;
        for j in 0..8 {
            let mut wp = w.clone();
            wp[j] += eps;
            let mut wm = w.clone();
            wm[j] -= eps;
            let fd = (prob.objective(&wp) - prob.objective(&wm)) / (2.0 * eps);
            assert!((fd - g[j]).abs() < 1e-5, "coord {j}: fd {fd} vs g {}", g[j]);
        }
    }

    #[test]
    fn exact_solution_zeroes_gradient() {
        let prob = small_problem();
        let w = prob.exact_solution().unwrap();
        assert!(linalg::norm2(&prob.grad(&w)) < 1e-9);
    }

    #[test]
    fn smoothness_upper_bounds_rayleigh() {
        let prob = small_problem();
        let m = prob.smoothness();
        let mut rng = Pcg64::seeded(3);
        for _ in 0..5 {
            let v: Vec<f64> = (0..8).map(|_| rng.next_gaussian()).collect();
            let xv = prob.x.gemv(&v);
            let r = linalg::dot(&xv, &xv) / prob.n() as f64 / linalg::dot(&v, &v) + prob.lambda;
            assert!(r <= m * 1.001, "rayleigh {r} > M {m}");
        }
    }

    #[test]
    fn coded_full_participation_matches_true_gradient() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 7).unwrap();
        let mut rng = Pcg64::seeded(5);
        let w: Vec<f64> = (0..8).map(|_| rng.next_gaussian()).collect();
        // all workers respond
        let responses: Vec<(usize, Vec<f64>, f64)> = enc
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut g = vec![0.0; 8];
                let mut buf = vec![0.0; s.x.rows()];
                let f = s.x.fused_grad(&w, &s.y, &mut g, &mut buf);
                (i, g, f)
            })
            .collect();
        let (g_est, f_est) = enc.aggregate_grad(&w, &responses);
        let g_true = prob.grad(&w);
        let f_true = prob.objective(&w);
        for (a, b) in g_est.iter().zip(&g_true) {
            assert!((a - b).abs() < 1e-8, "grad mismatch {a} vs {b}");
        }
        assert!((f_est - f_true).abs() < 1e-8, "obj {f_est} vs {f_true}");
    }

    #[test]
    fn uncoded_full_participation_matches_true_gradient() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Identity, 1.0, 8, 0).unwrap();
        let w = vec![0.1; 8];
        let responses: Vec<(usize, Vec<f64>, f64)> = enc
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut g = vec![0.0; 8];
                let mut buf = vec![0.0; s.x.rows()];
                let f = s.x.fused_grad(&w, &s.y, &mut g, &mut buf);
                (i, g, f)
            })
            .collect();
        let (g_est, _) = enc.aggregate_grad(&w, &responses);
        let g_true = prob.grad(&w);
        for (a, b) in g_est.iter().zip(&g_true) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn replication_dedups_partitions() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Replication, 2.0, 8, 0).unwrap();
        assert_eq!(enc.m(), 8);
        assert_eq!(enc.scheme, Scheme::Replicated { partitions: 4 });
        // worker i and i+4 hold the same partition
        for j in 0..4 {
            assert_eq!(enc.shards[j].partition_id, enc.shards[j + 4].partition_id);
            assert!(enc.shards[j].x.max_abs_diff(&enc.shards[j + 4].x) < 1e-15);
        }
        let w = vec![0.05; 8];
        let compute = |i: usize| {
            let s = &enc.shards[i];
            let mut g = vec![0.0; 8];
            let mut buf = vec![0.0; s.x.rows()];
            let f = s.x.fused_grad(&w, &s.y, &mut g, &mut buf);
            (i, g, f)
        };
        // both copies of partitions 0..4 respond: dedup must make the
        // estimate equal the full true gradient
        let responses: Vec<_> = (0..8).map(compute).collect();
        let (g_est, _) = enc.aggregate_grad(&w, &responses);
        let g_true = prob.grad(&w);
        for (a, b) in g_est.iter().zip(&g_true) {
            assert!((a - b).abs() < 1e-9, "dedup: {a} vs {b}");
        }
        // only copies of partitions {0,1} respond → partial but consistent
        let partial: Vec<_> = [0usize, 4, 1, 5].iter().map(|&i| compute(i)).collect();
        let (g_part, _) = enc.aggregate_grad(&w, &partial);
        assert!(linalg::norm2(&g_part) > 0.0);
    }

    #[test]
    fn coded_subset_estimate_is_close() {
        // with a tight code and eta = 3/4, the gradient estimate should be
        // near (not equal to) the true gradient
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 3).unwrap();
        let w = vec![0.2; 8];
        let responses: Vec<(usize, Vec<f64>, f64)> = (0..6)
            .map(|i| {
                let s = &enc.shards[i];
                let mut g = vec![0.0; 8];
                let mut buf = vec![0.0; s.x.rows()];
                let f = s.x.fused_grad(&w, &s.y, &mut g, &mut buf);
                (i, g, f)
            })
            .collect();
        let (g_est, _) = enc.aggregate_grad(&w, &responses);
        let g_true = prob.grad(&w);
        let rel = linalg::norm2(&linalg::sub(&g_est, &g_true)) / linalg::norm2(&g_true);
        assert!(rel < 0.8, "relative grad error {rel}");
        assert!(rel > 1e-6, "subset estimate should not be exact");
    }

    #[test]
    fn curvature_aggregation_full_matches_truth() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 11).unwrap();
        let d = vec![0.3; 8];
        let responses: Vec<(usize, f64)> = enc
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let xd = s.x.gemv(&d);
                (i, linalg::dot(&xd, &xd))
            })
            .collect();
        let q = enc.aggregate_curvature(&d, &responses);
        let xd = prob.x.gemv(&d);
        let q_true = linalg::dot(&xd, &xd) / prob.n() as f64 + prob.lambda * linalg::dot(&d, &d);
        assert!((q - q_true).abs() < 1e-8, "{q} vs {q_true}");
    }

    #[test]
    fn shards_are_padded_to_buckets() {
        let prob = QuadProblem::synthetic_gaussian(100, 4, 0.0, 0);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Gaussian, 2.0, 7, 0).unwrap();
        for s in &enc.shards {
            assert!(s.x.rows().is_power_of_two() && s.x.rows() >= 8);
            assert_eq!(s.x.rows(), s.y.len());
            assert!(s.rows_real <= s.x.rows());
        }
    }

    #[test]
    fn gradient_coding_exact_under_any_s_stragglers() {
        // FRC with s=2, m=6 (2 groups of 3): ANY 4 responders contain at
        // least one member of each group => exact gradient, every subset.
        let prob = small_problem();
        let (s, m) = (2usize, 6usize);
        let enc = EncodedProblem::encode_gradient_coding(&prob, s, m, 0).unwrap();
        assert_eq!(enc.scheme, Scheme::GradientCoded { groups: 2 });
        assert!((enc.beta - 3.0).abs() < 1e-12);
        let w = vec![0.15; 8];
        let mut all = Vec::new();
        for shard in &enc.shards {
            let mut g = vec![0.0; 8];
            let mut buf = vec![0.0; shard.x.rows()];
            let f = shard.x.fused_grad(&w, &shard.y, &mut g, &mut buf);
            all.push((g, f));
        }
        let g_true = prob.grad(&w);
        // every (m - s)-subset of responders decodes exactly
        for drop_a in 0..m {
            for drop_b in drop_a + 1..m {
                let responders: Vec<(usize, Vec<f64>, f64)> = (0..m)
                    .filter(|&i| i != drop_a && i != drop_b)
                    .map(|i| (i, all[i].0.clone(), all[i].1))
                    .collect();
                let (g_est, _) = enc.aggregate_grad(&w, &responders);
                for (a, b) in g_est.iter().zip(&g_true) {
                    assert!(
                        (a - b).abs() < 1e-9,
                        "GC not exact dropping {{{drop_a},{drop_b}}}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn gradient_coding_requires_divisibility() {
        let prob = small_problem();
        assert!(EncodedProblem::encode_gradient_coding(&prob, 2, 8, 0).is_err());
        assert!(EncodedProblem::encode_gradient_coding(&prob, 1, 8, 0).is_ok());
    }

    #[test]
    fn seq_coded_full_participation_matches_true_gradient() {
        use crate::encoding::temporal::TemporalScheme;
        let prob = small_problem();
        let scheme = TemporalScheme::Seq { window: 4, burst: 2 };
        let enc = EncodedProblem::encode_temporal(&prob, scheme, 8, 0).unwrap();
        assert_eq!(enc.scheme, Scheme::SeqCoded { window: 4, burst: 2 });
        assert_eq!(enc.gram_scale, 1.0);
        assert!((enc.beta - 1.5).abs() < 1e-12, "beta {}", enc.beta);
        let w = vec![0.15; 8];
        let responses: Vec<(usize, Vec<f64>, f64)> = enc
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut g = vec![0.0; 8];
                let mut buf = vec![0.0; s.x.rows()];
                let f = s.x.fused_grad(&w, &s.y, &mut g, &mut buf);
                (i, g, f)
            })
            .collect();
        // SᵀS = I: all m responders recover the exact raw gradient
        let (g_est, _) = enc.aggregate_grad(&w, &responses);
        let g_true = prob.grad(&w);
        for (a, b) in g_est.iter().zip(&g_true) {
            assert!((a - b).abs() < 1e-9, "seq full-k: {a} vs {b}");
        }
    }

    #[test]
    fn stoch_coded_is_seeded_and_scales_by_realized_duplication() {
        use crate::encoding::temporal::TemporalScheme;
        let prob = small_problem();
        let scheme = TemporalScheme::Stoch { q: 0.5 };
        let a = EncodedProblem::encode_temporal(&prob, scheme, 8, 3).unwrap();
        let b = EncodedProblem::encode_temporal(&prob, scheme, 8, 3).unwrap();
        assert_eq!(a.scheme, Scheme::StochCoded);
        assert_eq!(a.gram_scale, b.gram_scale, "same seed, same realized code");
        assert_eq!(a.beta, a.gram_scale, "stoch gram_scale is the realized beta");
        assert!(a.beta > 1.0 && a.beta < 2.0);
        // q = 1 duplicates every row on a distinct buddy: the realized
        // code is a (permuted, worker-disjoint) 2x replication, exact at
        // full participation under the 1/(c·η·n) normalization
        let full =
            EncodedProblem::encode_temporal(&prob, TemporalScheme::Stoch { q: 1.0 }, 8, 3).unwrap();
        assert!((full.beta - 2.0).abs() < 1e-12);
        let w = vec![0.15; 8];
        let responses: Vec<(usize, Vec<f64>, f64)> = full
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut g = vec![0.0; 8];
                let mut buf = vec![0.0; s.x.rows()];
                let f = s.x.fused_grad(&w, &s.y, &mut g, &mut buf);
                (i, g, f)
            })
            .collect();
        let (g_est, _) = full.aggregate_grad(&w, &responses);
        let g_true = prob.grad(&w);
        for (x, y) in g_est.iter().zip(&g_true) {
            assert!((x - y).abs() < 1e-9, "stoch q=1 full-k: {x} vs {y}");
        }
    }

    #[test]
    fn temporal_encode_rejects_none_scheme_and_sparse_storage() {
        use crate::encoding::temporal::TemporalScheme;
        let prob = small_problem();
        assert!(EncodedProblem::encode_temporal(&prob, TemporalScheme::None, 8, 0).is_err());
        assert!(EncodedProblem::encode_temporal_stored_prec(
            &prob,
            TemporalScheme::Seq { window: 4, burst: 1 },
            8,
            0,
            StorageKind::Sparse,
            Precision::F64,
        )
        .is_err());
        // epsilon estimation has no meaning for the stand-in kind label
        let scheme = TemporalScheme::Seq { window: 4, burst: 1 };
        let enc = EncodedProblem::encode_temporal(&prob, scheme, 8, 0).unwrap();
        assert!(enc.estimate_epsilon(6, 2, 0).is_err());
    }

    #[test]
    fn gradient_coding_redundancy_grows_with_tolerance() {
        // the paper's argument against ref. [20]: beta = s+1
        let prob = small_problem();
        for s in [1usize, 3] {
            let enc = EncodedProblem::encode_gradient_coding(&prob, s, 8, 0).unwrap();
            assert!((enc.beta - (s + 1) as f64).abs() < 1e-12);
            // per-worker storage grows linearly in s
            let rows: usize = enc.shards[0].rows_real;
            assert_eq!(rows, 64 * (s + 1) / 8);
        }
    }

    #[test]
    fn replication_requires_divisibility() {
        let prob = small_problem();
        assert!(EncodedProblem::encode(&prob, EncoderKind::Replication, 3.0, 8, 0).is_err());
    }

    #[test]
    fn batch_plan_blocks_are_circular_and_sized() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 3).unwrap();
        let mut rng = Pcg64::seeded(9);
        for _ in 0..50 {
            let plan = enc.sample_batch(0.3, &mut rng);
            assert_eq!(plan.workers(), 8);
            for (i, segs) in plan.segments.iter().enumerate() {
                let rows = enc.shards[i].rows_real;
                let want = ((0.3 * rows as f64).ceil() as usize).clamp(1, rows);
                assert_eq!(plan.rows(i), want, "worker {i}");
                assert!(segs.len() <= 2, "worker {i}: {} segments", segs.len());
                for &(lo, hi) in segs {
                    assert!(lo < hi && hi <= rows, "worker {i}: bad segment {lo}..{hi}");
                }
            }
        }
    }

    #[test]
    fn batch_plan_full_fraction_is_deterministic_full_shard() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 3).unwrap();
        let mut rng = Pcg64::seeded(1);
        let before = rng.clone().next_u64();
        let plan = enc.sample_batch(1.0, &mut rng);
        // no randomness consumed at batch_frac = 1
        assert_eq!(rng.next_u64(), before);
        for (i, segs) in plan.segments.iter().enumerate() {
            assert_eq!(segs, &[(0, enc.shards[i].rows_real)]);
        }
    }

    #[test]
    fn batch_aggregation_at_full_fraction_matches_aggregate_grad() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 7).unwrap();
        let w = vec![0.3; 8];
        let responses: Vec<(usize, Vec<f64>, f64)> = enc
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut g = vec![0.0; 8];
                let mut buf = vec![0.0; s.x.rows()];
                let f = s.x.fused_grad(&w, &s.y, &mut g, &mut buf);
                (i, g, f)
            })
            .collect();
        let mut rng = Pcg64::seeded(0);
        let plan = enc.sample_batch(1.0, &mut rng);
        let (g_full, f_full) = enc.aggregate_grad(&w, &responses);
        let (g_batch, f_batch) = enc.aggregate_grad_batch(&w, &responses, &plan);
        assert_eq!(f_full.to_bits(), f_batch.to_bits());
        for (a, b) in g_full.iter().zip(&g_batch) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn batch_gradient_is_unbiased_in_expectation() {
        // coded scheme, full participation: the full aggregate equals the
        // true gradient exactly, so the mean over sampled plans must
        // approach it (the integration suite runs the larger version).
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 5).unwrap();
        let w = vec![0.2; 8];
        let mut rng = Pcg64::seeded(77);
        let trials = 2000;
        let mut mean = vec![0.0; 8];
        for _ in 0..trials {
            let plan = enc.sample_batch(0.5, &mut rng);
            let responses: Vec<(usize, Vec<f64>, f64)> = enc
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut g = vec![0.0; 8];
                    let mut buf = vec![0.0; s.x.rows()];
                    let mut f = 0.0;
                    for &(lo, hi) in &plan.segments[i] {
                        f += s.x.fused_grad_range(&w, &s.y, &mut g, &mut buf, lo, hi);
                    }
                    (i, g, f)
                })
                .collect();
            let (g, _) = enc.aggregate_grad_batch(&w, &responses, &plan);
            linalg::axpy(1.0 / trials as f64, &g, &mut mean);
        }
        let g_true = prob.grad(&w);
        let rel = linalg::norm2(&linalg::sub(&mean, &g_true)) / linalg::norm2(&g_true);
        assert!(rel < 0.05, "batch gradient biased: rel err {rel}");
    }

    #[test]
    #[should_panic(expected = "batch_frac")]
    fn sample_batch_rejects_bad_fraction() {
        let prob = small_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Identity, 1.0, 4, 0).unwrap();
        let mut rng = Pcg64::seeded(0);
        enc.sample_batch(0.0, &mut rng);
    }

    /// A MovieLens-shaped sparse design: one-hot user/item indicators
    /// plus an intercept — 3 nnz per row, hundreds of columns.
    fn sparse_problem() -> QuadProblem {
        let (users, items, n) = (24usize, 16usize, 64usize);
        let p = users + items + 1;
        let mut row_ptr = vec![0usize];
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let mut y = Vec::new();
        for r in 0..n {
            cols.push((r % users) as u32);
            cols.push((users + (r * 7) % items) as u32);
            cols.push((p - 1) as u32);
            vals.extend_from_slice(&[1.0, 1.0, 1.0]);
            row_ptr.push(cols.len());
            y.push(1.0 + (r % 5) as f64);
        }
        QuadProblem::new(crate::linalg::CsrMat::from_raw(n, p, row_ptr, cols, vals), y, 0.1)
    }

    #[test]
    fn sparse_storage_preserved_by_row_selection_schemes() {
        let prob = sparse_problem();
        for kind in [EncoderKind::Identity, EncoderKind::Replication] {
            let enc = EncodedProblem::encode(&prob, kind, 2.0, 8, 0).unwrap();
            assert_eq!(enc.storage, StorageKind::Sparse, "{kind}: auto should keep CSR");
            assert!(enc.shards.iter().all(|s| s.x.is_sparse()));
            let dense = EncodedProblem::encode_stored(
                &prob,
                kind,
                2.0,
                8,
                0,
                StorageKind::Dense,
            )
            .unwrap();
            assert_eq!(dense.storage, StorageKind::Dense);
            assert!(
                enc.shard_mem_bytes() < dense.shard_mem_bytes() / 4,
                "{kind}: CSR shards should be far smaller ({} vs {})",
                enc.shard_mem_bytes(),
                dense.shard_mem_bytes()
            );
            // same values either way
            for (a, b) in enc.shards.iter().zip(&dense.shards) {
                assert_eq!(a.x.max_abs_diff(&b.x), 0.0);
                assert_eq!(a.y, b.y);
            }
        }
    }

    #[test]
    fn f32_encode_narrows_shards_and_matches_f64_structure() {
        let prob = small_problem();
        for kind in [EncoderKind::Hadamard, EncoderKind::Identity, EncoderKind::Replication] {
            let f64e = EncodedProblem::encode(&prob, kind, 2.0, 8, 3).unwrap();
            let f32e = EncodedProblem::encode_stored_prec(
                &prob,
                kind,
                2.0,
                8,
                3,
                StorageKind::Auto,
                Precision::F32,
            )
            .unwrap();
            assert_eq!(f64e.precision, Precision::F64);
            assert_eq!(f32e.precision, Precision::F32);
            assert_eq!(f64e.storage, f32e.storage, "{kind}: storage resolution must agree");
            // same partitioning + padding; X̃ payload halves, ỹ stays f64
            for (a, b) in f64e.shards.iter().zip(&f32e.shards) {
                assert_eq!(a.rows_real, b.rows_real);
                assert_eq!(a.partition_id, b.partition_id);
                assert_eq!(a.x.rows(), b.x.rows());
                assert_eq!(a.y, b.y);
                assert_eq!(b.x.precision(), Precision::F32);
                assert!(a.x.max_abs_diff(&b.x) < 1e-4, "{kind}: narrowing drifted too far");
            }
            assert!(
                f32e.shard_mem_bytes() < f64e.shard_mem_bytes(),
                "{kind}: f32 shards must be smaller"
            );
        }
    }

    #[test]
    fn f32_sparse_shards_keep_csr_backend() {
        let prob = sparse_problem();
        let enc = EncodedProblem::encode_stored_prec(
            &prob,
            EncoderKind::Identity,
            1.0,
            8,
            0,
            StorageKind::Auto,
            Precision::F32,
        )
        .unwrap();
        assert_eq!(enc.storage, StorageKind::Sparse);
        assert!(enc.shards.iter().all(|s| s.x.is_sparse()));
        assert!(enc.shards.iter().all(|s| s.x.precision() == Precision::F32));
    }

    #[test]
    fn transform_schemes_densify_sparse_input_under_auto() {
        let prob = sparse_problem();
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 1).unwrap();
        assert_eq!(enc.storage, StorageKind::Dense);
        assert!(enc.shards.iter().all(|s| !s.x.is_sparse()));
    }

    #[test]
    fn sparse_storage_rejected_for_densifying_schemes() {
        let prob = small_problem();
        for kind in [EncoderKind::Hadamard, EncoderKind::Gaussian, EncoderKind::Dft] {
            let r = EncodedProblem::encode_stored(&prob, kind, 2.0, 8, 0, StorageKind::Sparse);
            assert!(r.is_err(), "{kind}: sparse storage should be rejected");
        }
        // row-selection schemes accept it even for dense data
        assert!(EncodedProblem::encode_stored(
            &prob,
            EncoderKind::Identity,
            1.0,
            8,
            0,
            StorageKind::Sparse
        )
        .is_ok());
    }

    #[test]
    fn sparse_raw_problem_solves_and_differentiates() {
        // objective/gradient/exact solution all run on CSR raw storage
        let prob = sparse_problem();
        let w_hat = prob.exact_solution().unwrap();
        assert!(linalg::norm2(&prob.grad(&w_hat)) < 1e-8);
        let dense = QuadProblem::new(prob.x.to_dense(), prob.y.clone(), prob.lambda);
        let w_dense = dense.exact_solution().unwrap();
        for (a, b) in w_hat.iter().zip(&w_dense) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn gradient_coding_preserves_sparse_storage() {
        let prob = sparse_problem();
        let enc = EncodedProblem::encode_gradient_coding(&prob, 1, 8, 0).unwrap();
        assert_eq!(enc.storage, StorageKind::Sparse);
        assert!(enc.shards.iter().all(|s| s.x.is_sparse()));
    }

    #[test]
    fn planted_problem_solution_is_near_truth() {
        let (prob, w_star) = QuadProblem::planted(200, 6, 0.0, 0.01, 9);
        let w_hat = prob.exact_solution().unwrap();
        let rel = linalg::norm2(&linalg::sub(&w_hat, &w_star)) / linalg::norm2(&w_star);
        assert!(rel < 0.05, "planted recovery rel err {rel}");
    }
}
