//! Ratings data: sparse store, train/test split, and the synthetic
//! MovieLens-1M-compatible generator.
//!
//! The paper evaluates on MovieLens-1M (6040 users × 3952 movies, ~1M
//! ratings, 1–5 stars). That dataset isn't available in this offline
//! environment, so we generate a statistically compatible substitute
//! (DESIGN.md §3): a planted low-rank + bias model
//! `R_ij = clamp(round(μ + u_i + v_j + x_iᵀy_j + noise), 1, 5)` observed
//! on a power-law sampled (user, movie) pattern that matches ML-1M's
//! heavy-tailed per-user/per-movie activity and global mean ≈ 3.58. The
//! experiment measures *relative robustness of encodings* inside the
//! alternating-ridge solver, which depends on the subproblem structure
//! (row counts, sparsity pattern, conditioning) — all preserved.

use crate::linalg::CsrMat;
use crate::rng::Pcg64;

/// One observed rating.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rating {
    /// User index (0-based).
    pub user: u32,
    /// Item index (0-based).
    pub item: u32,
    /// Star rating (1-5).
    pub value: f32,
}

/// Sparse ratings with per-user and per-item adjacency.
#[derive(Clone, Debug, Default)]
pub struct Ratings {
    /// Number of users (index space, not distinct raters).
    pub n_users: usize,
    /// Number of items.
    pub n_items: usize,
    /// All observed ratings.
    pub entries: Vec<Rating>,
    /// entry indices by user / by item (built by `reindex`)
    by_user: Vec<Vec<u32>>,
    by_item: Vec<Vec<u32>>,
}

impl Ratings {
    /// Build the store and its per-user/per-item adjacency.
    pub fn new(n_users: usize, n_items: usize, entries: Vec<Rating>) -> Self {
        let mut r = Ratings { n_users, n_items, entries, by_user: vec![], by_item: vec![] };
        r.reindex();
        r
    }

    fn reindex(&mut self) {
        self.by_user = vec![Vec::new(); self.n_users];
        self.by_item = vec![Vec::new(); self.n_items];
        for (idx, e) in self.entries.iter().enumerate() {
            self.by_user[e.user as usize].push(idx as u32);
            self.by_item[e.item as usize].push(idx as u32);
        }
    }

    /// Number of observed ratings.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no ratings are stored.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entry indices rated by `user`.
    pub fn user_entries(&self, user: usize) -> &[u32] {
        &self.by_user[user]
    }

    /// Entry indices rating `item`.
    pub fn item_entries(&self, item: usize) -> &[u32] {
        &self.by_item[item]
    }

    /// Global mean rating.
    pub fn mean(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        self.entries.iter().map(|e| e.value as f64).sum::<f64>() / self.len() as f64
    }

    /// Sparse one-hot regression design over the ratings store, built
    /// **directly as CSR** — the dense equivalent is never materialized.
    ///
    /// Row per observed rating with exactly three unit entries: the user
    /// indicator, the item indicator (offset by `n_users`), and a shared
    /// intercept column; targets are the raw star values, so ridge over
    /// this design fits the biased model `r ≈ u_i + v_j + μ` (the linear
    /// part of eq. (8)). `p = n_users + n_items + 1` makes the dense form
    /// quadratic waste at ML-1M scale (~10⁴ columns × 10⁶ rows), which is
    /// exactly the workload the CSR storage backend exists for; users or
    /// items with no ratings leave structurally empty columns.
    pub fn to_design(&self) -> (CsrMat, Vec<f64>) {
        let p = self.n_users + self.n_items + 1;
        let n = self.len();
        let mut row_ptr = Vec::with_capacity(n + 1);
        row_ptr.push(0);
        let mut col_idx = Vec::with_capacity(3 * n);
        let mut vals = Vec::with_capacity(3 * n);
        let mut y = Vec::with_capacity(n);
        for e in &self.entries {
            col_idx.push(e.user);
            col_idx.push(self.n_users as u32 + e.item);
            col_idx.push((p - 1) as u32);
            vals.extend_from_slice(&[1.0, 1.0, 1.0]);
            row_ptr.push(col_idx.len());
            y.push(e.value as f64);
        }
        (CsrMat::from_raw(n, p, row_ptr, col_idx, vals), y)
    }

    /// Random split into (train, test) with `test_frac` withheld (the
    /// paper's 80/20 protocol).
    pub fn split(&self, test_frac: f64, seed: u64) -> (Ratings, Ratings) {
        let mut rng = Pcg64::new(seed, 0x5b11);
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let n_test = (self.len() as f64 * test_frac).round() as usize;
        let test_set: std::collections::HashSet<usize> =
            idx[..n_test].iter().copied().collect();
        let mut train = Vec::with_capacity(self.len() - n_test);
        let mut test = Vec::with_capacity(n_test);
        for (i, e) in self.entries.iter().enumerate() {
            if test_set.contains(&i) {
                test.push(*e);
            } else {
                train.push(*e);
            }
        }
        (
            Ratings::new(self.n_users, self.n_items, train),
            Ratings::new(self.n_users, self.n_items, test),
        )
    }
}

/// Synthetic-ML1M generator parameters.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// Number of users to generate.
    pub n_users: usize,
    /// Number of items to generate.
    pub n_items: usize,
    /// Target number of observed ratings.
    pub n_ratings: usize,
    /// Planted latent dimension.
    pub rank: usize,
    /// Global mean μ (ML-1M ≈ 3.58).
    pub mu: f64,
    /// Std of planted user/item biases.
    pub bias_std: f64,
    /// Std of latent factors (per coordinate).
    pub factor_std: f64,
    /// Observation-noise std before rounding.
    pub noise_std: f64,
    /// Power-law exponent for user/item popularity (≈0.8 matches ML-1M's
    /// activity skew).
    pub popularity_alpha: f64,
    /// Generator seed.
    pub seed: u64,
}

impl SyntheticConfig {
    /// Full ML-1M-scale config.
    pub fn ml1m(seed: u64) -> Self {
        SyntheticConfig {
            n_users: 6040,
            n_items: 3952,
            n_ratings: 1_000_209,
            rank: 8,
            mu: 3.58,
            bias_std: 0.35,
            factor_std: 0.25,
            noise_std: 0.6,
            popularity_alpha: 0.8,
            seed,
        }
    }

    /// Scaled-down config for tests/benches (same shape, ~1/50 size).
    pub fn small(seed: u64) -> Self {
        SyntheticConfig {
            n_users: 240,
            n_items: 160,
            n_ratings: 8_000,
            rank: 6,
            mu: 3.58,
            bias_std: 0.35,
            factor_std: 0.25,
            noise_std: 0.6,
            popularity_alpha: 0.8,
            seed,
        }
    }
}

/// Zipf-ish popularity sampler: index ∝ 1/(rank+1)^alpha via inverse-CDF
/// over precomputed cumulative weights.
struct Popularity {
    cdf: Vec<f64>,
}

impl Popularity {
    fn new(n: usize, alpha: f64, rng: &mut Pcg64) -> Self {
        // random permutation so "popular" ids are scattered, as in ML-1M
        let perm = rng.permutation(n);
        let mut w = vec![0.0; n];
        for (rank, &id) in perm.iter().enumerate() {
            w[id] = 1.0 / ((rank + 1) as f64).powf(alpha);
        }
        let total: f64 = w.iter().sum();
        let mut acc = 0.0;
        let cdf = w
            .iter()
            .map(|x| {
                acc += x / total;
                acc
            })
            .collect();
        Popularity { cdf }
    }

    fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|probe| probe.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Generate the synthetic ratings dataset.
pub fn synthetic_movielens(cfg: &SyntheticConfig) -> Ratings {
    let mut rng = Pcg64::new(cfg.seed, 0x3117);
    // planted model
    let u_bias: Vec<f64> = (0..cfg.n_users).map(|_| cfg.bias_std * rng.next_gaussian()).collect();
    let v_bias: Vec<f64> = (0..cfg.n_items).map(|_| cfg.bias_std * rng.next_gaussian()).collect();
    let x: Vec<f64> = (0..cfg.n_users * cfg.rank)
        .map(|_| cfg.factor_std * rng.next_gaussian())
        .collect();
    let y: Vec<f64> = (0..cfg.n_items * cfg.rank)
        .map(|_| cfg.factor_std * rng.next_gaussian())
        .collect();
    let user_pop = Popularity::new(cfg.n_users, cfg.popularity_alpha, &mut rng);
    let item_pop = Popularity::new(cfg.n_items, cfg.popularity_alpha, &mut rng);

    let mut seen = std::collections::HashSet::with_capacity(cfg.n_ratings * 2);
    let mut entries = Vec::with_capacity(cfg.n_ratings);
    let mut attempts = 0usize;
    while entries.len() < cfg.n_ratings && attempts < cfg.n_ratings * 30 {
        attempts += 1;
        let ui = user_pop.sample(&mut rng);
        let vi = item_pop.sample(&mut rng);
        let key = (ui as u64) << 32 | vi as u64;
        if !seen.insert(key) {
            continue;
        }
        let dot: f64 = (0..cfg.rank)
            .map(|r| x[ui * cfg.rank + r] * y[vi * cfg.rank + r])
            .sum();
        let raw = cfg.mu + u_bias[ui] + v_bias[vi] + dot + cfg.noise_std * rng.next_gaussian();
        let val = raw.round().clamp(1.0, 5.0) as f32;
        entries.push(Rating { user: ui as u32, item: vi as u32, value: val });
    }
    Ratings::new(cfg.n_users, cfg.n_items, entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_hits_target_size_and_range() {
        let r = synthetic_movielens(&SyntheticConfig::small(1));
        assert!(r.len() >= 7_500, "got {} ratings", r.len());
        for e in &r.entries {
            assert!((1.0..=5.0).contains(&e.value));
            assert!((e.user as usize) < r.n_users);
            assert!((e.item as usize) < r.n_items);
        }
    }

    #[test]
    fn global_mean_is_ml1m_like() {
        let r = synthetic_movielens(&SyntheticConfig::small(2));
        let m = r.mean();
        assert!((3.2..=3.9).contains(&m), "mean {m}");
    }

    #[test]
    fn popularity_is_heavy_tailed() {
        let r = synthetic_movielens(&SyntheticConfig::small(3));
        let mut counts: Vec<usize> = (0..r.n_users).map(|u| r.user_entries(u).len()).collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top_decile: usize = counts[..r.n_users / 10].iter().sum();
        let total: usize = counts.iter().sum();
        assert!(
            top_decile as f64 > 0.25 * total as f64,
            "top 10% of users hold {} of {} ratings — not skewed",
            top_decile,
            total
        );
    }

    #[test]
    fn design_is_csr_with_three_unit_entries_per_row() {
        let r = synthetic_movielens(&SyntheticConfig::small(8));
        let (design, y) = r.to_design();
        assert_eq!(design.rows(), r.len());
        assert_eq!(design.cols(), r.n_users + r.n_items + 1);
        assert_eq!(design.nnz(), 3 * r.len());
        assert_eq!(y.len(), r.len());
        for (i, e) in r.entries.iter().enumerate().take(200) {
            assert_eq!(design.get(i, e.user as usize), 1.0);
            assert_eq!(design.get(i, r.n_users + e.item as usize), 1.0);
            assert_eq!(design.get(i, design.cols() - 1), 1.0);
            assert_eq!(y[i], e.value as f64);
        }
        // memory: CSR is an order of magnitude below dense for this shape
        let dense_bytes = design.rows() * design.cols() * 8;
        assert!(design.mem_bytes() * 10 < dense_bytes);
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let r = synthetic_movielens(&SyntheticConfig::small(4));
        let (train, test) = r.split(0.2, 7);
        assert_eq!(train.len() + test.len(), r.len());
        assert!((test.len() as f64 / r.len() as f64 - 0.2).abs() < 0.01);
        // adjacency rebuilt correctly
        let total_by_user: usize = (0..train.n_users).map(|u| train.user_entries(u).len()).sum();
        assert_eq!(total_by_user, train.len());
    }

    #[test]
    fn split_is_deterministic() {
        let r = synthetic_movielens(&SyntheticConfig::small(5));
        let (a, _) = r.split(0.2, 9);
        let (b, _) = r.split(0.2, 9);
        assert_eq!(a.entries, b.entries);
    }

    #[test]
    fn adjacency_indexes_match_entries() {
        let r = synthetic_movielens(&SyntheticConfig::small(6));
        for u in 0..r.n_users {
            for &ei in r.user_entries(u) {
                assert_eq!(r.entries[ei as usize].user as usize, u);
            }
        }
        for v in 0..r.n_items.min(50) {
            for &ei in r.item_entries(v) {
                assert_eq!(r.entries[ei as usize].item as usize, v);
            }
        }
    }
}
