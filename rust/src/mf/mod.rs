//! Matrix-factorization application (§5, MovieLens experiment).
//!
//! Alternating minimization over the biased MF objective (eq. (8)):
//! user/item ridge subproblems solved either locally (Cholesky, small
//! instances — the paper uses `numpy.linalg.solve` under `n < 500`) or
//! **distributedly with coded L-BFGS** over the straggler cluster. The
//! encoding matrices come from a per-size bank ([`bank::EncoderBank`]),
//! mirroring the paper's pre-built `{S_n}` bank.

pub mod bank;
pub mod data;
pub mod solver;

pub use bank::EncoderBank;
pub use data::{synthetic_movielens, Rating, Ratings, SyntheticConfig};
pub use solver::{train, MfConfig, MfModel, MfOutput};
