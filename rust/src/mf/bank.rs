//! Encoder bank: one pre-built encoding matrix per padded-size bucket.
//!
//! The paper (§5): "To reduce overhead, we create a bank of encoding
//! matrices {S_n} … and then given a problem instance, subsample the
//! columns of the appropriate matrix S_n to match the dimensions." We do
//! the equivalent with power-of-two row buckets: a subproblem with `r`
//! rows is zero-padded to `bucket = 2^⌈log₂ r⌉` (exact for gradients) and
//! encoded with the cached `S_bucket`. ETF construction cost (pivoted
//! Cholesky of the signature Gram) is thus paid once per bucket, not per
//! subproblem — this is what makes coded MF's encode overhead amortizable
//! (Fig. 6 runtimes include it).

use crate::encoding::{Encoder, EncoderKind};
use anyhow::Result;
use std::collections::HashMap;

/// Per-bucket encoder cache for one (kind, β, seed) family.
pub struct EncoderBank {
    kind: EncoderKind,
    beta: f64,
    seed: u64,
    min_bucket: usize,
    cache: HashMap<usize, Box<dyn Encoder>>,
}

impl EncoderBank {
    /// Empty bank for one `(kind, beta, seed)` family.
    pub fn new(kind: EncoderKind, beta: f64, seed: u64) -> Self {
        EncoderBank { kind, beta, seed, min_bucket: 8, cache: HashMap::new() }
    }

    /// The encoder family this bank builds.
    pub fn kind(&self) -> EncoderKind {
        self.kind
    }

    /// Bucket size a problem with `rows` raw rows pads to.
    pub fn bucket_for(&self, rows: usize) -> usize {
        rows.next_power_of_two().max(self.min_bucket)
    }

    /// The encoder for `rows` raw rows (builds + caches the bucket's S).
    pub fn get(&mut self, rows: usize) -> Result<&dyn Encoder> {
        let bucket = self.bucket_for(rows);
        if !self.cache.contains_key(&bucket) {
            let enc = self.kind.build(bucket, self.beta, self.seed ^ bucket as u64)?;
            self.cache.insert(bucket, enc);
        }
        Ok(self.cache.get(&bucket).unwrap().as_ref())
    }

    /// Number of distinct buckets built so far (amortization diagnostic).
    pub fn built(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rounding() {
        let bank = EncoderBank::new(EncoderKind::Gaussian, 2.0, 0);
        assert_eq!(bank.bucket_for(3), 8);
        assert_eq!(bank.bucket_for(8), 8);
        assert_eq!(bank.bucket_for(9), 16);
        assert_eq!(bank.bucket_for(600), 1024);
    }

    #[test]
    fn encoders_are_cached_per_bucket() {
        let mut bank = EncoderBank::new(EncoderKind::Hadamard, 2.0, 1);
        let _ = bank.get(10).unwrap();
        let _ = bank.get(12).unwrap(); // same bucket (16)
        let _ = bank.get(20).unwrap(); // bucket 32
        assert_eq!(bank.built(), 2);
    }

    #[test]
    fn banked_encoder_matches_requested_bucket() {
        let mut bank = EncoderBank::new(EncoderKind::Gaussian, 2.0, 2);
        let enc = bank.get(100).unwrap();
        assert_eq!(enc.rows_in(), 128);
        assert!(enc.beta() >= 2.0);
    }

    #[test]
    fn distinct_buckets_have_distinct_seeds() {
        let mut bank = EncoderBank::new(EncoderKind::Gaussian, 2.0, 3);
        let s8 = bank.get(8).unwrap().materialize();
        let s16 = bank.get(16).unwrap().materialize();
        // different sizes, trivially different; check the 8-bucket isn't a
        // prefix of the 16-bucket (independent draws)
        let sub = s16.row_band(0, 16).select_cols(&(0..8).collect::<Vec<_>>());
        assert!(s8.max_abs_diff(&sub) > 1e-6);
    }
}
