//! Alternating-minimization MF trainer with the coded distributed ridge
//! subsolver (§5 of the paper, eq. (8)).
//!
//! Model: `R_ij ≈ μ + u_i + v_j + x_iᵀ y_j`; the paper fixes μ (=3),
//! embedding p (=15), λ (=10). Each half-step solves, per user i (resp.
//! item j), the ridge problem over that row's observed ratings with
//! design rows `[y_jᵀ, 1]` and targets `R_ij − v_j − μ`. Instances with
//! at least `dist_threshold` rows go to the straggler cluster via coded
//! L-BFGS (first-k gather, exp-delay injection — exactly the paper's
//! simulation); smaller ones are solved locally by Cholesky. Simulated
//! cluster time accumulates into [`MfOutput::sim_ms`], which is what the
//! Fig. 6 runtime bench reports.
//!
//! The distributed solves are **multi-tenant**: each half-step queues its
//! distributed instances as jobs on one resident
//! [`JobServer`](crate::runtime::JobServer) (fair round interleaving over
//! a single shared worker pool) and applies the results at the half-step
//! boundary. Within a half-step the subproblems are independent — user
//! solves read only the item factors and vice versa — so the deferred
//! application is exactly the sequential semantics, and under the virtual
//! clock each job's iterates are bitwise-identical to a
//! one-cluster-per-solve run (each job keeps its own `sub_seed` delay
//! stream).

use super::bank::EncoderBank;
use super::data::Ratings;
use crate::cluster::{ClockMode, ClusterConfig, DelayModel};
use crate::config::Json;
use crate::encoding::EncoderKind;
use crate::linalg::{self, Mat, Precision, StorageKind};
use crate::optim::LbfgsConfig;
use crate::problem::{EncodedProblem, QuadProblem};
use crate::runtime::{JobServer, JobSpec, ServeOptimizer, ServePolicy};
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;

/// MF training configuration (defaults = the paper's §5 settings).
#[derive(Clone, Debug)]
pub struct MfConfig {
    /// Embedding dimension p (paper: 15; the solve dimension is p+1).
    pub embed: usize,
    /// Regularizer λ on the eq.-(8) scale (paper: 10).
    pub lambda: f64,
    /// Fixed global bias μ (paper: 3).
    pub mu: f64,
    /// Alternating epochs (paper: 5).
    pub epochs: usize,
    /// Cluster size m and first-k wait.
    pub m: usize,
    /// Responses the leader waits for per round (k ≤ m).
    pub k: usize,
    /// Encoding scheme + redundancy for the distributed solves.
    pub encoder: EncoderKind,
    /// Redundancy factor β for the encoder.
    pub beta: f64,
    /// Subproblems with ≥ this many rows are solved distributedly
    /// (paper: 500 at ML-1M scale).
    pub dist_threshold: usize,
    /// L-BFGS iterations per distributed subproblem.
    pub lbfgs_iters: usize,
    /// Straggler model for the cluster (paper: exp(10ms)).
    pub delay: DelayModel,
    /// Virtual-clock cost constant (ms per MFLOP).
    pub ms_per_mflop: f64,
    /// Clock mode for the distributed subsolver clusters:
    /// [`ClockMode::Virtual`] for reproducible simulated runtimes (the
    /// Fig. 6 bench), [`ClockMode::Measured`] for per-worker wall-clock
    /// timing with straggler cancellation.
    pub clock: ClockMode,
    /// Row cap per subproblem (rare popular-item outliers are subsampled
    /// to keep ETF bank sizes bounded; recorded in `MfOutput::capped`).
    pub max_rows: usize,
    /// Lane count for the shared worker pool every distributed subsolve
    /// job runs on (0 = available parallelism, the default).
    pub threads: usize,
    /// Shard storage backend for the distributed subproblem encodes
    /// ([`StorageKind::Auto`] keeps the ALS design matrices dense — their
    /// rows are embedding vectors; `Sparse` is honored where the scheme
    /// allows it).
    pub storage: StorageKind,
    /// Worker-shard arithmetic precision for the distributed subsolves
    /// ([`Precision::F32`] narrows the encoded shards; the leader-side
    /// ALS updates, aggregation, and RMSE stay f64).
    pub precision: Precision,
    /// Master seed for data/cluster randomness.
    pub seed: u64,
}

impl Default for MfConfig {
    fn default() -> Self {
        MfConfig {
            embed: 15,
            lambda: 10.0,
            mu: 3.0,
            epochs: 5,
            m: 8,
            k: 4,
            encoder: EncoderKind::Hadamard,
            beta: 2.0,
            dist_threshold: 64,
            lbfgs_iters: 8,
            delay: DelayModel::Exp { mean_ms: 10.0 },
            ms_per_mflop: 0.5,
            clock: ClockMode::Virtual,
            max_rows: 2048,
            threads: 0,
            storage: StorageKind::Auto,
            precision: Precision::F64,
            seed: 0,
        }
    }
}

impl MfConfig {
    /// Serialize to the JSON config form; round-trips through
    /// [`MfConfig::from_json`] (seeds above 2⁵³ are not representable in
    /// JSON numbers). Encoder, delay model, clock, and storage use their
    /// CLI string grammars.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"embed\": {}, \"lambda\": {}, \"mu\": {}, \"epochs\": {}, \
             \"m\": {}, \"k\": {}, \"encoder\": \"{}\", \"beta\": {}, \
             \"dist_threshold\": {}, \"lbfgs_iters\": {}, \"delay\": \"{}\", \
             \"ms_per_mflop\": {}, \"clock\": \"{}\", \"max_rows\": {}, \
             \"threads\": {}, \"storage\": \"{}\", \"precision\": \"{}\", \"seed\": {}}}",
            self.embed,
            self.lambda,
            self.mu,
            self.epochs,
            self.m,
            self.k,
            self.encoder,
            self.beta,
            self.dist_threshold,
            self.lbfgs_iters,
            self.delay,
            self.ms_per_mflop,
            self.clock,
            self.max_rows,
            self.threads,
            self.storage,
            self.precision,
            self.seed
        )
    }

    /// Deserialize from a parsed JSON object. Missing keys keep their
    /// defaults; present keys must have the right type, and the string
    /// fields must satisfy their CLI parse grammars.
    pub fn from_json(j: &Json) -> Result<Self> {
        ensure!(matches!(j, Json::Obj(_)), "mf config: expected a JSON object");
        let mut cfg = MfConfig::default();
        let num = |key: &str| -> Result<Option<f64>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_f64()
                    .map(Some)
                    .ok_or_else(|| anyhow!("mf config: {key} must be a number")),
            }
        };
        let count = |key: &str| -> Result<Option<usize>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_usize()
                    .map(Some)
                    .ok_or_else(|| anyhow!("mf config: {key} must be a nonnegative integer")),
            }
        };
        let text = |key: &str| -> Result<Option<&str>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_str()
                    .map(Some)
                    .ok_or_else(|| anyhow!("mf config: {key} must be a string")),
            }
        };
        if let Some(x) = count("embed")? {
            cfg.embed = x;
        }
        if let Some(x) = num("lambda")? {
            cfg.lambda = x;
        }
        if let Some(x) = num("mu")? {
            cfg.mu = x;
        }
        if let Some(x) = count("epochs")? {
            cfg.epochs = x;
        }
        if let Some(x) = count("m")? {
            cfg.m = x;
        }
        if let Some(x) = count("k")? {
            cfg.k = x;
        }
        if let Some(s) = text("encoder")? {
            cfg.encoder = EncoderKind::parse(s)?;
        }
        if let Some(x) = num("beta")? {
            cfg.beta = x;
        }
        if let Some(x) = count("dist_threshold")? {
            cfg.dist_threshold = x;
        }
        if let Some(x) = count("lbfgs_iters")? {
            cfg.lbfgs_iters = x;
        }
        if let Some(s) = text("delay")? {
            cfg.delay = DelayModel::parse(s)?;
        }
        if let Some(x) = num("ms_per_mflop")? {
            cfg.ms_per_mflop = x;
        }
        if let Some(s) = text("clock")? {
            cfg.clock = ClockMode::parse(s)?;
        }
        if let Some(x) = count("max_rows")? {
            cfg.max_rows = x;
        }
        if let Some(x) = count("threads")? {
            cfg.threads = x;
        }
        if let Some(s) = text("storage")? {
            cfg.storage = StorageKind::parse(s)?;
        }
        if let Some(s) = text("precision")? {
            cfg.precision = Precision::parse(s)?;
        }
        if let Some(x) = count("seed")? {
            cfg.seed = x as u64;
        }
        Ok(cfg)
    }
}

/// Learned factors/biases.
#[derive(Clone, Debug)]
pub struct MfModel {
    /// User factors, `n_users × p`.
    pub x: Mat,
    /// User biases.
    pub u: Vec<f64>,
    /// Item factors, `n_items × p`.
    pub y: Mat,
    /// Item biases.
    pub v: Vec<f64>,
    /// Fixed global bias μ.
    pub mu: f64,
}

impl MfModel {
    /// Predicted rating `μ + u_i + v_j + x_iᵀ y_j`.
    pub fn predict(&self, user: usize, item: usize) -> f64 {
        self.mu
            + self.u[user]
            + self.v[item]
            + linalg::dot(self.x.row(user), self.y.row(item))
    }

    /// RMSE over a ratings set.
    pub fn rmse(&self, ratings: &Ratings) -> f64 {
        if ratings.is_empty() {
            return 0.0;
        }
        let se: f64 = ratings
            .entries
            .iter()
            .map(|e| {
                let d = self.predict(e.user as usize, e.item as usize) - e.value as f64;
                d * d
            })
            .sum();
        (se / ratings.len() as f64).sqrt()
    }
}

/// Training output: model + per-epoch RMSE curves + simulated runtime.
#[derive(Clone, Debug)]
pub struct MfOutput {
    /// Learned model after the final epoch.
    pub model: MfModel,
    /// Train-set RMSE after each epoch.
    pub train_rmse: Vec<f64>,
    /// Test-set RMSE after each epoch.
    pub test_rmse: Vec<f64>,
    /// Total simulated cluster time (ms), distributed solves only.
    pub sim_ms: f64,
    /// Simulated time attributed to local solves + encoding (ms).
    pub local_ms: f64,
    /// Distributed / local solve counts.
    pub dist_solves: usize,
    /// Subproblems solved locally by Cholesky.
    pub local_solves: usize,
    /// Subproblems that hit the `max_rows` cap.
    pub capped: usize,
}

impl MfOutput {
    /// Total simulated wall time in ms.
    pub fn total_ms(&self) -> f64 {
        self.sim_ms + self.local_ms
    }
}

/// Solve one small ridge subproblem locally by Cholesky; returns
/// `(w, modeled_ms)` (the paper's numpy.linalg.solve path).
fn solve_local(a: Mat, t: Vec<f64>, lambda_abs: f64, cfg: &MfConfig) -> Result<(Vec<f64>, f64)> {
    let rows = a.rows();
    let dim = a.cols();
    // QuadProblem convention: f = (1/2n)||Aw-t||^2 + (l/2)||w||^2 matches
    // eq. (8)'s ||Aw-t||^2 + lambda ||w||^2 when l = lambda_abs / n.
    let lam = lambda_abs / rows as f64;
    let prob = QuadProblem::new(a, t, lam);
    let w = prob
        .exact_solution()
        .ok_or_else(|| anyhow::anyhow!("local ridge solve failed (not SPD?)"))?;
    // virtual cost: forming A^T A (r*d^2) + Cholesky (d^3/3) madds
    let mflops = (rows as f64 * (dim * dim) as f64 + (dim * dim * dim) as f64 / 3.0) / 1e6;
    Ok((w, mflops * cfg.ms_per_mflop))
}

/// One deferred distributed subsolve: the entity slot it updates, the
/// padded subproblem (for the ALS block-descent guard), and its warm
/// start.
struct Pending {
    slot: usize,
    prob: QuadProblem,
    warm: Vec<f64>,
}

/// The run's resident multi-tenant subsolver: every distributed ALS
/// instance in a half-step is submitted as a job on one shared
/// [`JobServer`] (fair round interleaving over a single resident worker
/// pool — one set of OS threads for the entire training run), then the
/// batch runs and results are applied at the half-step boundary.
struct DistBatch {
    server: JobServer,
    pending: Vec<Pending>,
}

impl DistBatch {
    fn new(cfg: &MfConfig) -> Self {
        DistBatch {
            server: JobServer::with_lanes(cfg.threads, ServePolicy::Fair),
            pending: Vec::new(),
        }
    }

    /// Queue one distributed solve (`rows >= dist_threshold`). Capping,
    /// padding, and encoding happen here, at queue time, so the
    /// [`EncoderBank`] sees the same request order as a sequential run.
    #[allow(clippy::too_many_arguments)]
    fn queue(
        &mut self,
        a: Mat,
        t: Vec<f64>,
        lambda_abs: f64,
        warm: Vec<f64>,
        slot: usize,
        cfg: &MfConfig,
        bank: &mut EncoderBank,
        sub_seed: u64,
        capped: &mut usize,
    ) -> Result<()> {
        let (a, t) = if a.rows() > cfg.max_rows {
            *capped += 1;
            let keep: Vec<usize> = (0..cfg.max_rows).collect(); // deterministic prefix
            (a.select_rows(&keep), t[..cfg.max_rows].to_vec())
        } else {
            (a, t)
        };
        let rows = a.rows();
        let bucket = bank.bucket_for(rows);
        let a_pad = a.pad_rows(bucket);
        let mut t_pad = t;
        t_pad.resize(bucket, 0.0);
        // lambda on the padded problem: same absolute regularizer
        let lam_pad = lambda_abs / bucket as f64;
        let prob = QuadProblem::new(a_pad, t_pad, lam_pad);

        let enc = match cfg.encoder {
            EncoderKind::Replication => EncodedProblem::encode_stored_prec(
                &prob,
                cfg.encoder,
                cfg.beta,
                cfg.m,
                sub_seed,
                cfg.storage,
                cfg.precision,
            )?,
            _ => {
                let bank_kind = bank.kind();
                let encoder = bank.get(rows)?;
                EncodedProblem::encode_with_stored_prec(
                    &prob,
                    encoder,
                    bank_kind,
                    cfg.m,
                    cfg.storage,
                    cfg.precision,
                )?
            }
        };
        self.server.submit(JobSpec {
            enc: Arc::new(enc),
            cluster: ClusterConfig {
                workers: cfg.m,
                wait_for: cfg.k,
                delay: cfg.delay.clone(),
                clock: cfg.clock,
                ms_per_mflop: cfg.ms_per_mflop,
                seed: sub_seed,
            },
            optimizer: ServeOptimizer::Lbfgs(LbfgsConfig {
                // MF runs pick ν from a fixed mild ε (re-estimating
                // spectra per subproblem would dominate runtime; the
                // paper banks S for the same reason)
                epsilon: Some(0.25),
                ..Default::default()
            }),
            iters: cfg.lbfgs_iters,
            w0: Some(warm.clone()),
            scenario: None,
            priority: 0,
        })?;
        self.pending.push(Pending { slot, prob, warm });
        Ok(())
    }

    /// Run the queued batch and hand each accepted iterate to
    /// `apply(slot, w)`; accumulates simulated time and solve counts.
    fn drain(&mut self, out: &mut MfOutput, mut apply: impl FnMut(usize, &[f64])) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let outcomes = self.server.run()?;
        for (p, o) in self.pending.drain(..).zip(outcomes) {
            // ALS block-descent guard: accept the distributed solve only
            // if it improved this block's true subproblem objective;
            // otherwise keep the warm start. Coded solves pass this
            // essentially always; it stops the uncoded k≪m scheme's
            // occasional diverging solve from destroying the whole model
            // (it still converges far more slowly — the Fig. 5 story).
            let w = if p.prob.objective(&o.output.w) <= p.prob.objective(&p.warm) {
                o.output.w
            } else {
                p.warm
            };
            apply(p.slot, &w);
            out.sim_ms += o.output.trace.total_sim_ms();
            out.dist_solves += 1;
        }
        Ok(())
    }
}

/// Train the MF model with coded distributed alternating minimization.
pub fn train(train_set: &Ratings, test_set: &Ratings, cfg: &MfConfig) -> Result<MfOutput> {
    ensure!(cfg.k >= 1 && cfg.k <= cfg.m, "need 1 <= k <= m");
    ensure!(cfg.epochs >= 1, "need at least one epoch");
    // validate the storage/encoder combination up front: discovering it
    // mid-epoch (at the first subproblem that crosses dist_threshold)
    // would throw away all prior ALS work — and a run whose subproblems
    // all stay local would silently never honor the flag at all
    ensure!(
        cfg.storage != StorageKind::Sparse
            || matches!(cfg.encoder, EncoderKind::Identity | EncoderKind::Replication),
        "--storage sparse requires a sparsity-preserving encoder \
         (uncoded/replication); '{}' densifies encoded rows",
        cfg.encoder
    );
    let p = cfg.embed;
    let dim = p + 1; // [factors, bias]
    let mut rng = crate::rng::Pcg64::new(cfg.seed, 0x3f);

    // init: small random factors, zero biases
    let mut model = MfModel {
        x: Mat::from_fn(train_set.n_users, p, |_, _| 0.1 * rng.next_gaussian()),
        u: vec![0.0; train_set.n_users],
        y: Mat::from_fn(train_set.n_items, p, |_, _| 0.1 * rng.next_gaussian()),
        v: vec![0.0; train_set.n_items],
        mu: cfg.mu,
    };

    let mut bank = EncoderBank::new(cfg.encoder, cfg.beta, cfg.seed);
    // one resident multi-tenant job server for the whole run: every
    // half-step's distributed solves run as concurrent jobs on its pool
    let mut batch = DistBatch::new(cfg);
    let mut out = MfOutput {
        model: model.clone(),
        train_rmse: Vec::new(),
        test_rmse: Vec::new(),
        sim_ms: 0.0,
        local_ms: 0.0,
        dist_solves: 0,
        local_solves: 0,
        capped: 0,
    };

    for epoch in 0..cfg.epochs {
        // ---- user half-step: solve w_i = [x_i; u_i] for every user ----
        for user in 0..train_set.n_users {
            let idx = train_set.user_entries(user);
            if idx.is_empty() {
                continue;
            }
            let rows = idx.len();
            let mut a = Mat::zeros(rows, dim);
            let mut t = vec![0.0; rows];
            for (r, &ei) in idx.iter().enumerate() {
                let e = &train_set.entries[ei as usize];
                let item = e.item as usize;
                a.row_mut(r)[..p].copy_from_slice(model.y.row(item));
                a.row_mut(r)[p] = 1.0;
                t[r] = e.value as f64 - model.v[item] - cfg.mu;
            }
            let sub_seed = cfg.seed ^ (epoch as u64) << 40 ^ (user as u64) << 1;
            if rows < cfg.dist_threshold {
                let (w, ms) = solve_local(a, t, cfg.lambda, cfg)?;
                model.x.row_mut(user).copy_from_slice(&w[..p]);
                model.u[user] = w[p];
                out.local_ms += ms;
                out.local_solves += 1;
            } else {
                let mut warm = model.x.row(user).to_vec();
                warm.push(model.u[user]);
                batch.queue(
                    a, t, cfg.lambda, warm, user, cfg, &mut bank, sub_seed, &mut out.capped,
                )?;
            }
        }
        // apply the half-step's distributed solves (user solves are
        // mutually independent: they read only item factors/biases)
        let (x, u) = (&mut model.x, &mut model.u);
        batch.drain(&mut out, |user, w| {
            x.row_mut(user).copy_from_slice(&w[..p]);
            u[user] = w[p];
        })?;

        // ---- item half-step: solve w_j = [y_j; v_j] for every item ----
        for item in 0..train_set.n_items {
            let idx = train_set.item_entries(item);
            if idx.is_empty() {
                continue;
            }
            let rows = idx.len();
            let mut a = Mat::zeros(rows, dim);
            let mut t = vec![0.0; rows];
            for (r, &ei) in idx.iter().enumerate() {
                let e = &train_set.entries[ei as usize];
                let user = e.user as usize;
                a.row_mut(r)[..p].copy_from_slice(model.x.row(user));
                a.row_mut(r)[p] = 1.0;
                t[r] = e.value as f64 - model.u[user] - cfg.mu;
            }
            let sub_seed = cfg.seed ^ (epoch as u64) << 40 ^ 0x8000_0000 ^ (item as u64) << 1;
            if rows < cfg.dist_threshold {
                let (w, ms) = solve_local(a, t, cfg.lambda, cfg)?;
                model.y.row_mut(item).copy_from_slice(&w[..p]);
                model.v[item] = w[p];
                out.local_ms += ms;
                out.local_solves += 1;
            } else {
                let mut warm = model.y.row(item).to_vec();
                warm.push(model.v[item]);
                batch.queue(
                    a, t, cfg.lambda, warm, item, cfg, &mut bank, sub_seed, &mut out.capped,
                )?;
            }
        }
        // apply the item half-step's distributed solves
        let (y, v) = (&mut model.y, &mut model.v);
        batch.drain(&mut out, |item, w| {
            y.row_mut(item).copy_from_slice(&w[..p]);
            v[item] = w[p];
        })?;

        out.train_rmse.push(model.rmse(train_set));
        out.test_rmse.push(model.rmse(test_set));
    }

    out.model = model;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mf::data::{synthetic_movielens, SyntheticConfig};

    fn tiny_cfg(encoder: EncoderKind, k: usize) -> MfConfig {
        MfConfig {
            embed: 6,
            lambda: 5.0,
            mu: 3.58,
            epochs: 2,
            m: 4,
            k,
            encoder,
            beta: 2.0,
            dist_threshold: 48,
            lbfgs_iters: 6,
            max_rows: 512,
            seed: 1,
            ..Default::default()
        }
    }

    #[test]
    fn training_reduces_rmse() {
        let all = synthetic_movielens(&SyntheticConfig::small(10));
        let (tr, te) = all.split(0.2, 3);
        let out = train(&tr, &te, &tiny_cfg(EncoderKind::Hadamard, 4)).unwrap();
        // RMSE after training well below the ~1.1 std of raw ratings
        let final_train = *out.train_rmse.last().unwrap();
        let final_test = *out.test_rmse.last().unwrap();
        assert!(final_train < 0.95, "train rmse {final_train}");
        assert!(final_test < 1.15, "test rmse {final_test}");
        // epochs don't increase train RMSE much
        assert!(out.train_rmse.last().unwrap() <= &(out.train_rmse[0] + 1e-9));
    }

    #[test]
    fn mixes_local_and_distributed_solves() {
        let all = synthetic_movielens(&SyntheticConfig::small(11));
        let (tr, te) = all.split(0.2, 4);
        let out = train(&tr, &te, &tiny_cfg(EncoderKind::Gaussian, 3)).unwrap();
        assert!(out.local_solves > 0, "expected local solves");
        assert!(out.dist_solves > 0, "expected distributed solves");
        assert!(out.sim_ms > 0.0 && out.local_ms > 0.0);
    }

    #[test]
    fn perfect_k_equals_m_is_most_accurate() {
        let all = synthetic_movielens(&SyntheticConfig::small(12));
        let (tr, te) = all.split(0.2, 5);
        let out_perfect = train(&tr, &te, &tiny_cfg(EncoderKind::Hadamard, 4)).unwrap();
        let out_k1 = train(&tr, &te, &tiny_cfg(EncoderKind::Hadamard, 1)).unwrap();
        // k = m should do at least as well as k = 1 on train fit
        assert!(
            out_perfect.train_rmse.last().unwrap() <= &(out_k1.train_rmse.last().unwrap() + 0.05),
            "perfect {} vs k=1 {}",
            out_perfect.train_rmse.last().unwrap(),
            out_k1.train_rmse.last().unwrap()
        );
    }

    #[test]
    fn smaller_k_gives_smaller_simulated_runtime() {
        let all = synthetic_movielens(&SyntheticConfig::small(13));
        let (tr, te) = all.split(0.2, 6);
        let out_k1 = train(&tr, &te, &tiny_cfg(EncoderKind::Hadamard, 1)).unwrap();
        let out_k4 = train(&tr, &te, &tiny_cfg(EncoderKind::Hadamard, 4)).unwrap();
        assert!(
            out_k1.sim_ms < out_k4.sim_ms,
            "k=1 sim {} not below k=4 sim {}",
            out_k1.sim_ms,
            out_k4.sim_ms
        );
    }

    #[test]
    fn replication_scheme_trains() {
        let all = synthetic_movielens(&SyntheticConfig::small(14));
        let (tr, te) = all.split(0.2, 7);
        let out = train(&tr, &te, &tiny_cfg(EncoderKind::Replication, 2)).unwrap();
        assert!(out.train_rmse.last().unwrap().is_finite());
        assert!(*out.train_rmse.last().unwrap() < 1.2);
    }

    #[test]
    fn sparse_storage_with_densifying_encoder_fails_at_config_time() {
        let all = synthetic_movielens(&SyntheticConfig::small(17));
        let (tr, te) = all.split(0.2, 9);
        let bad = MfConfig {
            storage: StorageKind::Sparse,
            ..tiny_cfg(EncoderKind::Hadamard, 3)
        };
        assert!(train(&tr, &te, &bad).is_err(), "should fail before any ALS work");
        // the sparsity-preserving scheme is accepted
        let ok = MfConfig {
            storage: StorageKind::Sparse,
            ..tiny_cfg(EncoderKind::Replication, 3)
        };
        assert!(train(&tr, &te, &ok).is_ok());
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = MfConfig {
            embed: 9,
            lambda: 3.5,
            epochs: 2,
            m: 6,
            k: 3,
            encoder: EncoderKind::PaleyEtf,
            beta: 2.0,
            delay: DelayModel::HeteroExp { mean_ms: 8.0, factors: vec![1.0, 2.5] },
            clock: ClockMode::Measured,
            threads: 4,
            storage: StorageKind::Sparse,
            precision: Precision::F32,
            seed: 71,
            ..Default::default()
        };
        let back = MfConfig::from_json(&Json::parse(&cfg.to_json()).unwrap()).unwrap();
        assert_eq!(back.embed, 9);
        assert_eq!(back.lambda, 3.5);
        assert_eq!(back.encoder, EncoderKind::PaleyEtf);
        assert_eq!(back.delay, cfg.delay);
        assert_eq!(back.clock, ClockMode::Measured);
        assert_eq!(back.threads, 4);
        assert_eq!(back.storage, StorageKind::Sparse);
        assert_eq!(back.precision, Precision::F32);
        assert_eq!(back.seed, 71);
        // defaults survive for absent keys; bad fields are rejected
        let partial = MfConfig::from_json(&Json::parse("{\"threads\": 2}").unwrap()).unwrap();
        assert_eq!(partial.threads, 2);
        assert_eq!(partial.embed, MfConfig::default().embed);
        for bad in [
            "{\"storage\": \"ram\"}",
            "{\"precision\": \"f16\"}",
            "{\"encoder\": \"bogus\"}",
            "{\"delay\": \"warp:1\"}",
            "{\"threads\": -1}",
            "[1, 2]",
        ] {
            assert!(
                MfConfig::from_json(&Json::parse(bad).unwrap()).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn thread_cap_is_deterministic() {
        // same training result at any fan-out width (threading is pure
        // parallelism, never a numerics knob)
        let all = synthetic_movielens(&SyntheticConfig::small(16));
        let (tr, te) = all.split(0.2, 8);
        let base = tiny_cfg(EncoderKind::Hadamard, 3);
        let one = train(&tr, &te, &MfConfig { threads: 1, ..base.clone() }).unwrap();
        let many = train(&tr, &te, &MfConfig { threads: 4, ..base }).unwrap();
        for (a, b) in one.train_rmse.iter().zip(&many.train_rmse) {
            assert_eq!(a.to_bits(), b.to_bits(), "thread cap changed the trained model");
        }
        assert_eq!(one.dist_solves, many.dist_solves);
    }

    #[test]
    fn resident_engine_reuse_is_deterministic() {
        // one shared job-server pool hosts every distributed solve
        // (batched per half-step); two identical runs must produce
        // bitwise-identical models and simulated times
        let all = synthetic_movielens(&SyntheticConfig::small(18));
        let (tr, te) = all.split(0.2, 10);
        let cfg = tiny_cfg(EncoderKind::Hadamard, 3);
        let a = train(&tr, &te, &cfg).unwrap();
        let b = train(&tr, &te, &cfg).unwrap();
        assert!(a.dist_solves > 1, "fixture must exercise engine reuse");
        for (x, y) in a.train_rmse.iter().zip(&b.train_rmse) {
            assert_eq!(x.to_bits(), y.to_bits(), "reused pool changed the model");
        }
        assert_eq!(a.sim_ms.to_bits(), b.sim_ms.to_bits());
        assert_eq!(a.dist_solves, b.dist_solves);
    }

    #[test]
    fn rmse_of_constant_mu_model_matches_std() {
        // sanity: untrained model (zero factors/biases) RMSE ≈ rating std
        let all = synthetic_movielens(&SyntheticConfig::small(15));
        let model = MfModel {
            x: Mat::zeros(all.n_users, 4),
            u: vec![0.0; all.n_users],
            y: Mat::zeros(all.n_items, 4),
            v: vec![0.0; all.n_items],
            mu: all.mean(),
        };
        let rmse = model.rmse(&all);
        assert!((0.6..=1.4).contains(&rmse), "rmse {rmse}");
    }
}
