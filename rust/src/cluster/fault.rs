//! Deterministic fault-injection scenarios: scripted per-round,
//! per-worker events layered over the [`Cluster`]'s delay models.
//!
//! Every [`DelayModel`](super::DelayModel) draws i.i.d. random delays, so
//! i.i.d. stragglers are the *only* regime the simulator could exercise —
//! yet the paper's central claim is convergence "using an arbitrarily
//! varying subset of the nodes at each iteration", and the adversarial /
//! correlated regimes (rotating worst-case stragglers, rack-wide slowdowns,
//! crash-recover churn) are exactly what the authors' JMLR follow-up and
//! the gradient-coding literature stress. A [`Scenario`] closes that gap:
//! a deterministic script of [`FaultEvent`]s plus an optional
//! [`AdmitPolicy`] that forces an exact admitted-subset sequence,
//! attached to a cluster via
//! [`Cluster::set_scenario`](super::Cluster::set_scenario).
//!
//! Scenarios come from a small text DSL (one `--scenario` flag) or from
//! JSON via [`config::Json`](crate::config::Json), and both forms
//! round-trip: `parse(x.to_string()) == x` and
//! `from_json(parse(to_json())) == x`. Under
//! [`ClockMode::Virtual`](super::ClockMode) a scenario run is bit-for-bit
//! replayable from the scenario string alone (pinned by
//! `rust/tests/fault_scenarios.rs`).
//!
//! # DSL grammar
//!
//! A scenario is `;`-separated sections; each section is either a
//! `,`-separated event list or a single `admit:` clause (at most one):
//!
//! | atom | meaning |
//! |------|---------|
//! | `crash:W@R` | worker `W` fail-stops from round `R` (never responds) |
//! | `recover:W@R` | worker `W` rejoins at round `R` (also clears its slow factor) |
//! | `leave:W@R` / `join:W@R` | membership churn — same effect as crash/recover, distinct trace label |
//! | `slow:W:F@R` | worker `W`'s delay is multiplied by `F` from round `R` (slow-onset: chain several) |
//! | `rack:LO-HI:F@R` | correlated rack-wide straggling — workers `LO..=HI` all slowed by `F` from round `R` |
//! | `admit:rotate:K` | iteration `t` admits exactly `{(t+j) mod m : j < K}` — the adversarial rotating-(m−K) worst case; `K` may be the literal `k` (the cluster's `wait_for`). The window slides once per optimizer iteration (see [`RoundKind`]), so an L-BFGS line-search round reuses its gradient round's window |
//! | `admit:fixed:W.W...` | every round admits exactly the listed workers (`.`-separated) |
//! | `admit:cycle:SET/SET...` | round `t` admits exactly `SET[t mod len]`, each set `.`-separated |
//!
//! Example: `crash:3@10,recover:3@25;admit:rotate:k`.
//!
//! Rounds are **cluster rounds** (each gradient, mini-batch, or
//! line-search round advances the script by one), 0-based from the moment
//! the scenario is attached. Crash events override everything: a crashed
//! worker never responds even when an `admit:` clause lists it (the
//! admitted set shrinks — the defined empty-round behavior when everyone
//! is gone). Slow factors scale the *virtual* arrival schedule (compute
//! cost model); under the measured clock they are ignored like all
//! injected delay magnitudes, while crash/admit scripting still applies
//! through response eligibility and cancellation.

use crate::config::Json;
use anyhow::{anyhow, bail, ensure, Result};
use std::fmt;

/// One scripted event: something that happens to one worker (or one rack
/// of workers) at the start of a specific round.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// `crash:W@R` — fail-stop: the worker never responds from round `R`.
    Crash {
        /// Worker index.
        worker: usize,
        /// 0-based cluster round the event fires at.
        round: u64,
    },
    /// `recover:W@R` — the worker responds again (slow factor reset).
    Recover {
        /// Worker index.
        worker: usize,
        /// 0-based cluster round the event fires at.
        round: u64,
    },
    /// `leave:W@R` — membership churn; same effect as crash, distinct
    /// label in the event-annotated trace.
    Leave {
        /// Worker index.
        worker: usize,
        /// 0-based cluster round the event fires at.
        round: u64,
    },
    /// `join:W@R` — membership churn; same effect as recover.
    Join {
        /// Worker index.
        worker: usize,
        /// 0-based cluster round the event fires at.
        round: u64,
    },
    /// `slow:W:F@R` — the worker's injected delay (and virtual arrival
    /// cost) is multiplied by `F` from round `R` until recover/join or a
    /// later `slow:` overwrites it. Chain several with increasing `F` for
    /// slow-onset degradation.
    Slow {
        /// Worker index.
        worker: usize,
        /// Delay multiplier (finite, > 0; 1 restores nominal speed).
        factor: f64,
        /// 0-based cluster round the event fires at.
        round: u64,
    },
    /// `rack:LO-HI:F@R` — correlated straggling: every worker in
    /// `LO..=HI` is slowed by `F` from round `R`.
    Rack {
        /// First worker of the rack (inclusive).
        lo: usize,
        /// Last worker of the rack (inclusive).
        hi: usize,
        /// Delay multiplier applied to the whole rack.
        factor: f64,
        /// 0-based cluster round the event fires at.
        round: u64,
    },
}

impl FaultEvent {
    /// The 0-based cluster round this event fires at.
    pub fn round(&self) -> u64 {
        match self {
            FaultEvent::Crash { round, .. }
            | FaultEvent::Recover { round, .. }
            | FaultEvent::Leave { round, .. }
            | FaultEvent::Join { round, .. }
            | FaultEvent::Slow { round, .. }
            | FaultEvent::Rack { round, .. } => *round,
        }
    }

    /// Parse one event atom of the DSL (grammar table in the module docs).
    pub fn parse(s: &str) -> Result<Self> {
        let (kind, rest) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("fault event {s:?}: expected KIND:...@ROUND"))?;
        let at = |body: &str| -> Result<(String, u64)> {
            let (head, round) = body
                .rsplit_once('@')
                .ok_or_else(|| anyhow!("fault event {s:?}: missing @ROUND"))?;
            let round = round
                .parse::<u64>()
                .map_err(|e| anyhow!("fault event {s:?}: round: {e}"))?;
            Ok((head.to_string(), round))
        };
        let worker = |tok: &str| -> Result<usize> {
            tok.parse::<usize>()
                .map_err(|e| anyhow!("fault event {s:?}: worker: {e}"))
        };
        let factor = |tok: &str| -> Result<f64> {
            let f = tok
                .parse::<f64>()
                .map_err(|e| anyhow!("fault event {s:?}: factor: {e}"))?;
            ensure!(
                f.is_finite() && f > 0.0,
                "fault event {s:?}: factor must be positive and finite"
            );
            Ok(f)
        };
        match kind {
            "crash" | "recover" | "leave" | "join" => {
                let (w, round) = at(rest)?;
                let worker = worker(&w)?;
                Ok(match kind {
                    "crash" => FaultEvent::Crash { worker, round },
                    "recover" => FaultEvent::Recover { worker, round },
                    "leave" => FaultEvent::Leave { worker, round },
                    _ => FaultEvent::Join { worker, round },
                })
            }
            "slow" => {
                let (body, round) = at(rest)?;
                let (w, f) = body
                    .split_once(':')
                    .ok_or_else(|| anyhow!("fault event {s:?}: expected slow:W:F@R"))?;
                Ok(FaultEvent::Slow { worker: worker(w)?, factor: factor(f)?, round })
            }
            "rack" => {
                let (body, round) = at(rest)?;
                let (range, f) = body
                    .split_once(':')
                    .ok_or_else(|| anyhow!("fault event {s:?}: expected rack:LO-HI:F@R"))?;
                let (lo, hi) = range
                    .split_once('-')
                    .ok_or_else(|| anyhow!("fault event {s:?}: expected worker range LO-HI"))?;
                let (lo, hi) = (worker(lo)?, worker(hi)?);
                ensure!(lo <= hi, "fault event {s:?}: range must have LO <= HI");
                Ok(FaultEvent::Rack { lo, hi, factor: factor(f)?, round })
            }
            other => bail!(
                "unknown fault event kind {other:?} \
                 (crash|recover|leave|join|slow|rack)"
            ),
        }
    }
}

impl fmt::Display for FaultEvent {
    /// Emits the exact [`FaultEvent::parse`] grammar (round-trip contract).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultEvent::Crash { worker, round } => write!(f, "crash:{worker}@{round}"),
            FaultEvent::Recover { worker, round } => write!(f, "recover:{worker}@{round}"),
            FaultEvent::Leave { worker, round } => write!(f, "leave:{worker}@{round}"),
            FaultEvent::Join { worker, round } => write!(f, "join:{worker}@{round}"),
            FaultEvent::Slow { worker, factor, round } => {
                write!(f, "slow:{worker}:{factor}@{round}")
            }
            FaultEvent::Rack { lo, hi, factor, round } => {
                write!(f, "rack:{lo}-{hi}:{factor}@{round}")
            }
        }
    }
}

/// How the leader's admitted set is decided each round.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum AdmitPolicy {
    /// The cluster's normal first-k-by-arrival gather (no override).
    #[default]
    FirstK,
    /// Iteration `t` admits exactly `{(t + j) mod m : j < K}` — the
    /// rotating window whose complement is the adversarial rotating-(m−K)
    /// straggler set from Theorem 1's "arbitrarily varying subset" claim.
    /// The window slides once per *optimizer iteration*
    /// ([`RoundKind::Iteration`]), not per dispatch: an L-BFGS iteration's
    /// line-search round reuses its gradient round's window, so Theorem 1's
    /// worst case rotates at the rate the theorem states it in.
    Rotate {
        /// Window size; `None` is the literal `k` (resolved to the
        /// cluster's `wait_for` when the scenario is attached).
        k: Option<usize>,
    },
    /// Every round admits exactly this worker set.
    Fixed {
        /// The scripted admitted set.
        workers: Vec<usize>,
    },
    /// Round `t` admits exactly `sets[t mod sets.len()]`.
    Cycle {
        /// The scripted admitted-set sequence, cycled.
        sets: Vec<Vec<usize>>,
    },
}

fn parse_id_list(s: &str, ctx: &str) -> Result<Vec<usize>> {
    ensure!(!s.is_empty(), "{ctx}: empty worker list");
    s.split('.')
        .map(|tok| tok.parse::<usize>().map_err(|e| anyhow!("{ctx}: worker {tok:?}: {e}")))
        .collect()
}

impl AdmitPolicy {
    /// Parse the clause body after `admit:` (grammar in the module docs).
    pub fn parse(s: &str) -> Result<Self> {
        match s.split_once(':') {
            None if s == "first-k" => Ok(AdmitPolicy::FirstK),
            Some(("rotate", "k")) => Ok(AdmitPolicy::Rotate { k: None }),
            Some(("rotate", tok)) => {
                let k = tok
                    .parse::<usize>()
                    .map_err(|e| anyhow!("admit:rotate:{tok}: {e}"))?;
                ensure!(k >= 1, "admit:rotate: window must be >= 1");
                Ok(AdmitPolicy::Rotate { k: Some(k) })
            }
            Some(("fixed", tok)) => {
                Ok(AdmitPolicy::Fixed { workers: parse_id_list(tok, "admit:fixed")? })
            }
            Some(("cycle", tok)) => {
                let sets = tok
                    .split('/')
                    .map(|set| parse_id_list(set, "admit:cycle"))
                    .collect::<Result<Vec<_>>>()?;
                ensure!(!sets.is_empty(), "admit:cycle: no sets");
                Ok(AdmitPolicy::Cycle { sets })
            }
            _ => bail!(
                "unknown admit policy {s:?} \
                 (first-k | rotate:K|k | fixed:W.W... | cycle:SET/SET...)"
            ),
        }
    }
}

impl fmt::Display for AdmitPolicy {
    /// Emits the exact [`AdmitPolicy::parse`] grammar.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmitPolicy::FirstK => write!(f, "first-k"),
            AdmitPolicy::Rotate { k: None } => write!(f, "rotate:k"),
            AdmitPolicy::Rotate { k: Some(k) } => write!(f, "rotate:{k}"),
            AdmitPolicy::Fixed { workers } => {
                write!(f, "fixed:")?;
                for (i, w) in workers.iter().enumerate() {
                    if i > 0 {
                        write!(f, ".")?;
                    }
                    write!(f, "{w}")?;
                }
                Ok(())
            }
            AdmitPolicy::Cycle { sets } => {
                write!(f, "cycle:")?;
                for (i, set) in sets.iter().enumerate() {
                    if i > 0 {
                        write!(f, "/")?;
                    }
                    for (j, w) in set.iter().enumerate() {
                        if j > 0 {
                            write!(f, ".")?;
                        }
                        write!(f, "{w}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// A complete deterministic scenario: the event script plus the
/// admitted-set policy. Attach with
/// [`Cluster::set_scenario`](super::Cluster::set_scenario).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Scenario {
    /// Scripted events, applied at the start of their round in list order
    /// (later events win on conflicts within one round).
    pub events: Vec<FaultEvent>,
    /// Admitted-set policy ([`AdmitPolicy::FirstK`] = no override).
    pub admit: AdmitPolicy,
}

impl Scenario {
    /// Parse the full DSL (`;`-separated sections; see the module docs).
    pub fn parse(s: &str) -> Result<Self> {
        ensure!(!s.trim().is_empty(), "empty scenario");
        let mut events = Vec::new();
        let mut admit: Option<AdmitPolicy> = None;
        for section in s.split(';') {
            let section = section.trim();
            ensure!(!section.is_empty(), "scenario {s:?}: empty section");
            if let Some(body) = section.strip_prefix("admit:") {
                ensure!(admit.is_none(), "scenario {s:?}: multiple admit clauses");
                admit = Some(AdmitPolicy::parse(body)?);
            } else {
                for atom in section.split(',') {
                    let atom = atom.trim();
                    ensure!(!atom.is_empty(), "scenario {s:?}: empty event");
                    events.push(FaultEvent::parse(atom)?);
                }
            }
        }
        Ok(Scenario { events, admit: admit.unwrap_or_default() })
    }

    /// Check every referenced worker index against a cluster of `m`
    /// workers (also rejects duplicate ids inside one admitted set and
    /// `rotate` windows wider than the cluster).
    pub fn validate(&self, m: usize) -> Result<()> {
        let check = |w: usize| -> Result<()> {
            ensure!(w < m, "scenario references worker {w} but the cluster has {m}");
            Ok(())
        };
        for e in &self.events {
            match e {
                FaultEvent::Crash { worker, .. }
                | FaultEvent::Recover { worker, .. }
                | FaultEvent::Leave { worker, .. }
                | FaultEvent::Join { worker, .. }
                | FaultEvent::Slow { worker, .. } => check(*worker)?,
                FaultEvent::Rack { lo, hi, .. } => {
                    check(*lo)?;
                    check(*hi)?;
                }
            }
        }
        let check_set = |set: &[usize]| -> Result<()> {
            ensure!(!set.is_empty(), "admit: empty worker set");
            let mut seen = vec![false; m];
            for &w in set {
                check(w)?;
                ensure!(!seen[w], "admit: duplicate worker {w} in one set");
                seen[w] = true;
            }
            Ok(())
        };
        match &self.admit {
            AdmitPolicy::FirstK => {}
            AdmitPolicy::Rotate { k } => {
                if let Some(k) = k {
                    ensure!(
                        *k >= 1 && *k <= m,
                        "admit:rotate:{k} window must be in 1..={m}"
                    );
                }
            }
            AdmitPolicy::Fixed { workers } => check_set(workers)?,
            AdmitPolicy::Cycle { sets } => {
                for set in sets {
                    check_set(set)?;
                }
            }
        }
        Ok(())
    }

    /// Serialize to the JSON config form; round-trips through
    /// [`Scenario::from_json`]. Event atoms and the admit clause reuse
    /// the DSL grammar inside JSON strings, so the two surfaces cannot
    /// drift apart.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\"events\": [");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push('"');
            s.push_str(&e.to_string());
            s.push('"');
        }
        s.push_str(&format!("], \"admit\": \"{}\"}}", self.admit));
        s
    }

    /// Deserialize from a parsed JSON object: `events` is an optional
    /// array of event-atom strings, `admit` an optional admit-clause
    /// string (both in the DSL grammar).
    pub fn from_json(j: &Json) -> Result<Self> {
        ensure!(matches!(j, Json::Obj(_)), "scenario: expected a JSON object");
        let mut out = Scenario::default();
        if let Some(v) = j.get("events") {
            let Json::Arr(items) = v else {
                bail!("scenario: events must be an array of strings");
            };
            for item in items {
                let atom = item
                    .as_str()
                    .ok_or_else(|| anyhow!("scenario: events entries must be strings"))?;
                out.events.push(FaultEvent::parse(atom)?);
            }
        }
        if let Some(v) = j.get("admit") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("scenario: admit must be a string"))?;
            out.admit = AdmitPolicy::parse(s)?;
        }
        Ok(out)
    }
}

impl fmt::Display for Scenario {
    /// Emits the exact [`Scenario::parse`] DSL (the `admit:` clause is
    /// omitted for the default first-k policy).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{e}")?;
        }
        if self.admit != AdmitPolicy::FirstK {
            if !self.events.is_empty() {
                write!(f, ";")?;
            }
            write!(f, "admit:{}", self.admit)?;
        }
        Ok(())
    }
}

/// What the scenario dictates for one specific round, consumed by the
/// cluster's round machinery.
#[derive(Clone, Debug)]
pub struct RoundScript {
    /// Labels of the events that fired at the start of this round (the
    /// event-annotated-trace payload; empty on quiet rounds).
    pub labels: Vec<String>,
    /// Per-worker crashed mask after applying this round's events.
    pub crashed: Vec<bool>,
    /// Per-worker delay multipliers after applying this round's events.
    pub slow: Vec<f64>,
    /// Exact admitted-set override (`None` = normal first-k gather).
    /// Crashed / failed workers listed here are dropped by the cluster —
    /// the admitted set shrinks rather than deadlocking.
    pub admit: Option<Vec<usize>>,
}

impl RoundScript {
    /// Whether (and at what scripted delay multiplier) worker `w` is
    /// observable by the speed model this round: `None` while crashed
    /// (parked workers produce no observation — their estimate freezes),
    /// `Some(slow[w])` otherwise. This is the single deterministic
    /// gate through which the rebalancer consumes the `slow:`/`rack:`
    /// scenario masks: under the virtual clock the factor is already
    /// folded into `Round.compute_ms`, so callers use only the
    /// `Some`/`None` shape and read the rate from the round itself.
    pub fn speed_observation(&self, w: usize) -> Option<f64> {
        if w >= self.crashed.len() || self.crashed[w] {
            None
        } else {
            Some(self.slow[w])
        }
    }
}

/// What kind of cluster round is being staged, from the scenario's point
/// of view. Events always fire on the *cluster round* counter (every
/// dispatch — gradient, mini-batch, or line-search — advances it by one,
/// as the module docs state), but [`AdmitPolicy::Rotate`]'s window slides
/// on the *iteration phase*: only [`RoundKind::Iteration`] rounds advance
/// it. Without this split, L-BFGS's line-search round would slide the
/// Theorem-1 rotating worst case twice per optimizer iteration — the
/// adversary the theorem bounds rotates per iteration, not per dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundKind {
    /// The round that opens an optimizer iteration (gradient or
    /// mini-batch gradient dispatch). Advances the rotation phase.
    Iteration,
    /// An auxiliary dispatch inside the same iteration (line search).
    /// Consumes a cluster round (events still fire) but leaves the
    /// rotation phase where the iteration's gradient round put it.
    Auxiliary,
}

/// The runtime state of an attached scenario: the script plus the
/// current crashed/slow masks and the round counter.
#[derive(Clone, Debug)]
pub struct ScenarioState {
    scenario: Scenario,
    m: usize,
    /// Resolved rotate window (0 when the policy is not `Rotate`).
    rotate_k: usize,
    crashed: Vec<bool>,
    slow: Vec<f64>,
    round: u64,
    /// Iteration phase: how many [`RoundKind::Iteration`] rounds have
    /// begun. Drives the `Rotate` window; `round` drives everything else.
    phase: u64,
}

impl ScenarioState {
    /// Validate `scenario` against a cluster of `m` workers waiting for
    /// `wait_for` responses, and stage it at round 0.
    pub fn new(scenario: Scenario, m: usize, wait_for: usize) -> Result<Self> {
        scenario.validate(m)?;
        let rotate_k = match scenario.admit {
            AdmitPolicy::Rotate { k } => k.unwrap_or(wait_for).min(m),
            _ => 0,
        };
        Ok(ScenarioState {
            scenario,
            m,
            rotate_k,
            crashed: vec![false; m],
            slow: vec![1.0; m],
            round: 0,
            phase: 0,
        })
    }

    /// The scenario this state runs.
    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    /// Re-resolve the literal-`k` rotate window against a new `wait_for`
    /// (called when the cluster's k changes between runs, e.g. η sweeps
    /// reusing one staged cluster). Explicit `rotate:K` windows are
    /// unaffected.
    pub fn set_wait_for(&mut self, wait_for: usize) {
        if let AdmitPolicy::Rotate { k } = self.scenario.admit {
            self.rotate_k = k.unwrap_or(wait_for).min(self.m);
        }
    }

    /// 0-based index of the next round to run.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Apply this round's events and return the round's script; advances
    /// the round counter (and, for [`RoundKind::Iteration`] rounds, the
    /// rotation phase). Called once per cluster round, in round order.
    pub fn begin_round(&mut self, kind: RoundKind) -> RoundScript {
        let t = self.round;
        let phase = self.phase;
        let mut labels = Vec::new();
        for e in &self.scenario.events {
            if e.round() != t {
                continue;
            }
            labels.push(e.to_string());
            match *e {
                FaultEvent::Crash { worker, .. } | FaultEvent::Leave { worker, .. } => {
                    self.crashed[worker] = true;
                }
                FaultEvent::Recover { worker, .. } | FaultEvent::Join { worker, .. } => {
                    self.crashed[worker] = false;
                    self.slow[worker] = 1.0;
                }
                FaultEvent::Slow { worker, factor, .. } => self.slow[worker] = factor,
                FaultEvent::Rack { lo, hi, factor, .. } => {
                    for w in lo..=hi {
                        self.slow[w] = factor;
                    }
                }
            }
        }
        let admit = match &self.scenario.admit {
            AdmitPolicy::FirstK => None,
            AdmitPolicy::Rotate { .. } => Some(
                (0..self.rotate_k).map(|j| (phase as usize + j) % self.m).collect(),
            ),
            AdmitPolicy::Fixed { workers } => Some(workers.clone()),
            AdmitPolicy::Cycle { sets } => Some(sets[(t as usize) % sets.len()].clone()),
        };
        self.round += 1;
        if kind == RoundKind::Iteration {
            self.phase += 1;
        }
        RoundScript {
            labels,
            crashed: self.crashed.clone(),
            slow: self.slow.clone(),
            admit,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_parse_and_display_round_trip() {
        for s in [
            "crash:3@10",
            "recover:3@25",
            "leave:0@0",
            "join:7@100",
            "slow:2:4.5@12",
            "rack:0-3:8@40",
        ] {
            let e = FaultEvent::parse(s).unwrap();
            assert_eq!(e.to_string(), s);
            assert_eq!(FaultEvent::parse(&e.to_string()).unwrap(), e);
        }
    }

    #[test]
    fn event_parse_rejects_malformed() {
        for bad in [
            "", "crash", "crash:3", "crash:x@1", "crash:3@", "crash:3@x", "slow:2@5",
            "slow:2:0@5", "slow:2:-1@5", "slow:2:inf@5", "rack:3:2@5", "rack:5-2:2@5",
            "rack:0-3@5", "explode:1@2", "crash:-1@2",
        ] {
            assert!(FaultEvent::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn admit_parse_and_display_round_trip() {
        for s in ["first-k", "rotate:k", "rotate:4", "fixed:0.2.5", "cycle:0.1/2.3/4"] {
            let a = AdmitPolicy::parse(s).unwrap();
            assert_eq!(a.to_string(), s);
            assert_eq!(AdmitPolicy::parse(&a.to_string()).unwrap(), a);
        }
    }

    #[test]
    fn admit_parse_rejects_malformed() {
        for bad in [
            "", "rotate", "rotate:0", "rotate:x", "fixed:", "fixed:a.b", "cycle:",
            "cycle:/", "lottery:3", "first-k:2",
        ] {
            assert!(AdmitPolicy::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn scenario_parse_and_display_round_trip() {
        for s in [
            "crash:3@10,recover:3@25;admit:rotate:k",
            "slow:1:2@0,slow:1:8@10,rack:4-7:3@20",
            "admit:fixed:0.1.2",
            "leave:2@5,join:2@9;admit:cycle:0.1/2.3",
            "crash:0@1",
        ] {
            let sc = Scenario::parse(s).unwrap();
            assert_eq!(sc.to_string(), s, "display drifted for {s:?}");
            assert_eq!(Scenario::parse(&sc.to_string()).unwrap(), sc);
        }
    }

    #[test]
    fn scenario_parse_rejects_malformed() {
        for bad in [
            "", " ", ";", "crash:1@2,", "crash:1@2,,recover:1@3", ";admit:rotate:k",
            "admit:rotate:k;admit:fixed:1", "crash:1@2;", "admit:warp:3",
        ] {
            assert!(Scenario::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn scenario_json_round_trip() {
        for s in [
            "crash:3@10,recover:3@25;admit:rotate:k",
            "rack:0-1:5@4",
            "admit:cycle:0.1/2.3",
        ] {
            let sc = Scenario::parse(s).unwrap();
            let back = Scenario::from_json(&Json::parse(&sc.to_json()).unwrap()).unwrap();
            assert_eq!(back, sc, "json round trip for {s:?}");
        }
        // empty object = default scenario
        let empty = Scenario::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(empty, Scenario::default());
    }

    #[test]
    fn scenario_json_rejects_malformed() {
        for bad in [
            "[1]",
            "{\"events\": \"crash:1@2\"}",
            "{\"events\": [3]}",
            "{\"events\": [\"bogus:1@2\"]}",
            "{\"admit\": 7}",
            "{\"admit\": \"warp\"}",
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Scenario::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn validate_checks_worker_bounds() {
        let sc = Scenario::parse("crash:8@1").unwrap();
        assert!(sc.validate(8).is_err());
        assert!(sc.validate(9).is_ok());
        assert!(Scenario::parse("rack:2-9:2@1").unwrap().validate(8).is_err());
        assert!(Scenario::parse("admit:fixed:0.0").unwrap().validate(8).is_err());
        assert!(Scenario::parse("admit:rotate:9").unwrap().validate(8).is_err());
        assert!(Scenario::parse("admit:cycle:1/8").unwrap().validate(8).is_err());
    }

    #[test]
    fn state_machine_applies_crash_recover_and_slow() {
        let sc = Scenario::parse("slow:1:4@0,crash:2@1,recover:2@3,slow:1:8@2").unwrap();
        let mut st = ScenarioState::new(sc, 4, 4).unwrap();
        let r0 = st.begin_round(RoundKind::Iteration);
        assert_eq!(r0.labels, vec!["slow:1:4@0"]);
        assert_eq!(r0.slow, vec![1.0, 4.0, 1.0, 1.0]);
        assert_eq!(r0.crashed, vec![false; 4]);
        let r1 = st.begin_round(RoundKind::Iteration);
        assert_eq!(r1.labels, vec!["crash:2@1"]);
        assert!(r1.crashed[2]);
        assert_eq!(r1.slow[1], 4.0, "slow factor persists");
        let r2 = st.begin_round(RoundKind::Iteration);
        assert_eq!(r2.slow[1], 8.0, "slow-onset: later event overwrites");
        assert!(r2.crashed[2], "crash persists");
        let r3 = st.begin_round(RoundKind::Iteration);
        assert!(!r3.crashed[2], "recover clears crash");
        let r4 = st.begin_round(RoundKind::Iteration);
        assert!(r4.labels.is_empty(), "quiet round has no labels");
        assert_eq!(st.round(), 5);
    }

    #[test]
    fn recover_resets_slow_factor() {
        let sc = Scenario::parse("rack:0-2:6@0,recover:1@2").unwrap();
        let mut st = ScenarioState::new(sc, 4, 4).unwrap();
        assert_eq!(st.begin_round(RoundKind::Iteration).slow, vec![6.0, 6.0, 6.0, 1.0]);
        st.begin_round(RoundKind::Iteration);
        assert_eq!(st.begin_round(RoundKind::Iteration).slow, vec![6.0, 1.0, 6.0, 1.0]);
    }

    #[test]
    fn rotate_window_rotates_and_wraps() {
        let sc = Scenario::parse("admit:rotate:3").unwrap();
        let mut st = ScenarioState::new(sc, 4, 4).unwrap();
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![0, 1, 2]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![1, 2, 3]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![2, 3, 0]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![3, 0, 1]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn rotate_window_holds_across_auxiliary_rounds() {
        // An L-BFGS iteration is gradient (Iteration) + line search
        // (Auxiliary): the rotation window must slide once per
        // iteration, while events and the Cycle policy still advance on
        // every cluster round.
        let sc = Scenario::parse("crash:3@1;admit:rotate:3").unwrap();
        let mut st = ScenarioState::new(sc, 4, 4).unwrap();
        let g0 = st.begin_round(RoundKind::Iteration);
        assert_eq!(g0.admit.unwrap(), vec![0, 1, 2]);
        let ls0 = st.begin_round(RoundKind::Auxiliary);
        assert_eq!(ls0.admit.unwrap(), vec![0, 1, 2], "line search reuses the window");
        assert_eq!(ls0.labels, vec!["crash:3@1"], "events still fire per cluster round");
        let g1 = st.begin_round(RoundKind::Iteration);
        assert_eq!(g1.admit.unwrap(), vec![1, 2, 3], "next iteration slides once");
        assert_eq!(st.begin_round(RoundKind::Auxiliary).admit.unwrap(), vec![1, 2, 3]);
        assert_eq!(st.round(), 4, "every dispatch consumed a cluster round");
    }

    #[test]
    fn cycle_policy_advances_per_cluster_round() {
        // Cycle is an exact per-round script: auxiliary rounds consume
        // sets too (unchanged, unlike Rotate's per-iteration phase).
        let mut st =
            ScenarioState::new(Scenario::parse("admit:cycle:0.1/2.3").unwrap(), 4, 4).unwrap();
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![0, 1]);
        assert_eq!(st.begin_round(RoundKind::Auxiliary).admit.unwrap(), vec![2, 3]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![0, 1]);
    }

    #[test]
    fn rotate_k_literal_resolves_to_wait_for() {
        let sc = Scenario::parse("admit:rotate:k").unwrap();
        let mut st = ScenarioState::new(sc, 8, 6).unwrap();
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap().len(), 6);
    }

    #[test]
    fn fixed_and_cycle_policies() {
        let mut st =
            ScenarioState::new(Scenario::parse("admit:fixed:1.3").unwrap(), 4, 4).unwrap();
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![1, 3]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![1, 3]);
        let mut st =
            ScenarioState::new(Scenario::parse("admit:cycle:0.1/2.3").unwrap(), 4, 4).unwrap();
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![0, 1]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![2, 3]);
        assert_eq!(st.begin_round(RoundKind::Iteration).admit.unwrap(), vec![0, 1]);
    }

    #[test]
    fn first_k_policy_gives_no_override() {
        let mut st =
            ScenarioState::new(Scenario::parse("crash:0@0").unwrap(), 4, 3).unwrap();
        assert!(st.begin_round(RoundKind::Iteration).admit.is_none());
    }
}
