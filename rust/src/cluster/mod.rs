//! Simulated leader/worker cluster with streaming first-k-of-m gather —
//! the distributed substrate the paper runs on (Figure 1).
//!
//! The paper's two testbeds are (a) a 32-node EC2 cluster with natural
//! network stragglers and (b) a 32-core machine with **injected**
//! `Δ ~ exp(10ms)` delays (§5, MovieLens experiment). We implement (b)
//! directly, with a family of delay models ([`DelayModel`]): per round,
//! every worker computes its shard task, each response is assigned
//! `arrival = compute_time + sampled delay`, and the leader admits the
//! **first k** responses (`A_t`). Late responses are dropped (the paper's
//! "drop their updates upon arrival" option).
//!
//! Rounds are **event-driven**: the engine streams each worker's response
//! into the round's [`Collector`](crate::runtime::Collector) the moment
//! that worker finishes (resident shard-owning pool lanes on the native
//! engine — spawned once per run, never per round; see
//! [`runtime::pool`](crate::runtime::pool)), and the two clocks differ in
//! how the leader consumes that stream:
//!
//! * [`ClockMode::Virtual`] — compute time comes from a deterministic
//!   flop-cost model and admission is decided post hoc from the sampled
//!   arrival schedule; fully reproducible (tests, convergence figures).
//!   Byte-identical to the historical batch-synchronous gather: same RNG
//!   stream, same admitted set, same `elapsed_ms`.
//! * [`ClockMode::Measured`] — each worker's compute time is its **own
//!   wall-clock measurement**, admission follows true arrival order, and
//!   the k-th admission flips the round's cancellation flag so workers
//!   that have not started yet skip their shard entirely (runtime figures
//!   with a real engine in the loop). Injected delay *magnitudes* belong
//!   to the virtual simulator and are ignored here; only fail-stop events
//!   (infinite delay) carry over.
//!
//! The cluster is engine-agnostic ([`ComputeEngine`]): the same rounds run
//! on the native Rust kernels or the PJRT/XLA artifacts.

pub mod fault;

pub use fault::{AdmitPolicy, FaultEvent, RoundKind, RoundScript, Scenario, ScenarioState};

use crate::problem::{BatchPlan, EncodedProblem};
use crate::rng::Pcg64;
use crate::runtime::{
    Collected, ComputeEngine, CurvCollector, EngineSession, GradCollector, RebalanceConfig,
    Rebalancer,
};
use anyhow::{ensure, Result};

/// Straggler delay model (per worker, per round), milliseconds.
#[derive(Clone, Debug, PartialEq)]
pub enum DelayModel {
    /// No injected delay (all workers equally fast).
    None,
    /// Constant delay for every worker.
    Constant {
        /// Delay applied to every worker, ms.
        ms: f64,
    },
    /// i.i.d. exponential — the paper's MovieLens model (`exp(10ms)`).
    Exp {
        /// Mean of the exponential, ms.
        mean_ms: f64,
    },
    /// Shifted exponential: `shift + exp(mean)`; classic straggler model.
    ShiftedExp {
        /// Deterministic shift, ms.
        shift_ms: f64,
        /// Mean of the exponential part, ms.
        mean_ms: f64,
    },
    /// Heavy-tailed Pareto(scale, shape).
    Pareto {
        /// Pareto scale (minimum delay), ms.
        scale_ms: f64,
        /// Pareto tail exponent (smaller = heavier tail).
        shape: f64,
    },
    /// Exponential with a per-worker fail-stop probability: a failed
    /// worker never responds that round (delay = ∞).
    ExpWithFailures {
        /// Mean of the exponential, ms.
        mean_ms: f64,
        /// Per-round probability a worker never responds.
        p_fail: f64,
    },
    /// Heterogeneous: exponential whose mean is `mean_ms * factor[i]`
    /// (persistent slow nodes).
    HeteroExp {
        /// Base mean, ms.
        mean_ms: f64,
        /// Per-worker multipliers, cycled if shorter than the worker count.
        factors: Vec<f64>,
    },
}

impl DelayModel {
    /// Sample worker `i`'s injected delay for one round.
    pub fn sample(&self, rng: &mut Pcg64, worker: usize) -> f64 {
        match self {
            DelayModel::None => 0.0,
            DelayModel::Constant { ms } => *ms,
            DelayModel::Exp { mean_ms } => rng.next_exp(*mean_ms),
            DelayModel::ShiftedExp { shift_ms, mean_ms } => shift_ms + rng.next_exp(*mean_ms),
            DelayModel::Pareto { scale_ms, shape } => rng.next_pareto(*scale_ms, *shape),
            DelayModel::ExpWithFailures { mean_ms, p_fail } => {
                if rng.next_f64() < *p_fail {
                    f64::INFINITY
                } else {
                    rng.next_exp(*mean_ms)
                }
            }
            DelayModel::HeteroExp { mean_ms, factors } => {
                let f = factors.get(worker % factors.len().max(1)).copied().unwrap_or(1.0);
                rng.next_exp(mean_ms * f)
            }
        }
    }

    /// Parse a delay model from its CLI form. This table is the single
    /// source of truth for the grammar (used by `codedopt ridge --delay`,
    /// `codedopt mf --delay`, and the bench/config surfaces):
    ///
    /// | variant | form | example |
    /// |---------|------|---------|
    /// | [`DelayModel::None`] | `none` | `none` |
    /// | [`DelayModel::Constant`] | `const:MS` | `const:3` |
    /// | [`DelayModel::Exp`] | `exp:MEAN_MS` | `exp:10` |
    /// | [`DelayModel::ShiftedExp`] | `shifted:SHIFT_MS:MEAN_MS` | `shifted:5:10` |
    /// | [`DelayModel::Pareto`] | `pareto:SCALE_MS:SHAPE` | `pareto:2:1.5` |
    /// | [`DelayModel::ExpWithFailures`] | `expfail:MEAN_MS:P_FAIL` | `expfail:10:0.05` |
    /// | [`DelayModel::HeteroExp`] | `hetero:MEAN_MS:F1,F2,...` | `hetero:10:1,1,4` |
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize| -> Result<f64> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("delay model {s:?}: missing field {i}"))?
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("delay model {s:?}: {e}"))
        };
        // exact arity per variant: extra fields are malformed, not ignored
        let expect = |n: usize| -> Result<()> {
            ensure!(
                parts.len() == n,
                "delay model {s:?}: wrong field count (got {}, want {})",
                parts.len() - 1,
                n - 1
            );
            Ok(())
        };
        Ok(match parts[0] {
            "none" => {
                expect(1)?;
                DelayModel::None
            }
            "const" => {
                expect(2)?;
                DelayModel::Constant { ms: num(1)? }
            }
            "exp" => {
                expect(2)?;
                DelayModel::Exp { mean_ms: num(1)? }
            }
            "shifted" => {
                expect(3)?;
                DelayModel::ShiftedExp { shift_ms: num(1)?, mean_ms: num(2)? }
            }
            "pareto" => {
                expect(3)?;
                DelayModel::Pareto { scale_ms: num(1)?, shape: num(2)? }
            }
            "expfail" => {
                expect(3)?;
                DelayModel::ExpWithFailures { mean_ms: num(1)?, p_fail: num(2)? }
            }
            "hetero" => {
                expect(3)?;
                let mean_ms = num(1)?;
                let factors = parts
                    .get(2)
                    .ok_or_else(|| anyhow::anyhow!("delay model {s:?}: missing factor list"))?
                    .split(',')
                    .map(|f| {
                        f.trim()
                            .parse::<f64>()
                            .map_err(|e| anyhow::anyhow!("delay model {s:?}: factor {f:?}: {e}"))
                    })
                    .collect::<Result<Vec<f64>>>()?;
                ensure!(!factors.is_empty(), "delay model {s:?}: empty factor list");
                DelayModel::HeteroExp { mean_ms, factors }
            }
            other => anyhow::bail!("unknown delay model {other:?}"),
        })
    }
}

impl std::fmt::Display for DelayModel {
    /// Emits the exact [`DelayModel::parse`] grammar, so
    /// `parse(x.to_string()) == x` — the config/JSON round-trip contract.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DelayModel::None => write!(f, "none"),
            DelayModel::Constant { ms } => write!(f, "const:{ms}"),
            DelayModel::Exp { mean_ms } => write!(f, "exp:{mean_ms}"),
            DelayModel::ShiftedExp { shift_ms, mean_ms } => {
                write!(f, "shifted:{shift_ms}:{mean_ms}")
            }
            DelayModel::Pareto { scale_ms, shape } => write!(f, "pareto:{scale_ms}:{shape}"),
            DelayModel::ExpWithFailures { mean_ms, p_fail } => {
                write!(f, "expfail:{mean_ms}:{p_fail}")
            }
            DelayModel::HeteroExp { mean_ms, factors } => {
                write!(f, "hetero:{mean_ms}:")?;
                for (i, x) in factors.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                Ok(())
            }
        }
    }
}

/// How the per-round compute time entering the clock is obtained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockMode {
    /// Deterministic flop-cost model (reproducible); injected delays are
    /// added to the modeled compute times to form the arrival schedule.
    Virtual,
    /// Per-worker wall-clock measurement taken inside each worker's
    /// streamed computation (distinct times for unequal shards), with
    /// straggler cancellation once the k-th response is admitted. Real
    /// timing only: injected delay magnitudes are ignored (the hardware
    /// provides the stragglers); fail-stop events still apply.
    Measured,
}

impl ClockMode {
    /// Parse the CLI forms `virtual`/`sim` and `measured`/`wall`.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "virtual" | "sim" => Ok(ClockMode::Virtual),
            "measured" | "wall" => Ok(ClockMode::Measured),
            other => anyhow::bail!("unknown clock mode {other:?} (virtual|measured)"),
        }
    }

    /// Canonical CLI/config label (round-trips through
    /// [`ClockMode::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            ClockMode::Virtual => "virtual",
            ClockMode::Measured => "measured",
        }
    }
}

impl std::fmt::Display for ClockMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Leader gather policy. `FirstK` is the paper's scheme; `WaitAll`
/// (k = m) is the "perfect"/batch baseline in Figure 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatherPolicy {
    /// Admit the first `k` responses.
    FirstK(usize),
    /// Wait for every worker (the k = m baseline).
    WaitAll,
}

impl GatherPolicy {
    /// The effective k for a cluster of `m` workers.
    pub fn k(&self, m: usize) -> usize {
        match self {
            GatherPolicy::FirstK(k) => (*k).min(m),
            GatherPolicy::WaitAll => m,
        }
    }
}

/// Cluster configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker count m (must match the encoded problem's shard count).
    pub workers: usize,
    /// k — responses the leader waits for per round.
    pub wait_for: usize,
    /// Injected straggler delay model.
    pub delay: DelayModel,
    /// Clock source for per-worker compute times.
    pub clock: ClockMode,
    /// Virtual-clock compute cost in ms per million multiply-adds.
    pub ms_per_mflop: f64,
    /// Seed for the delay-sampling RNG.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: 8,
            wait_for: 8,
            delay: DelayModel::Exp { mean_ms: 10.0 },
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5, // ~2 GFLOP/s per worker — m1.small-ish
            seed: 0,
        }
    }
}

/// Outcome of one synchronous round.
#[derive(Clone, Debug)]
pub struct Round {
    /// Admitted workers `A_t` (`|A_t| = k` unless failures left fewer
    /// responders). Under [`ClockMode::Virtual`] these are the k smallest
    /// sampled arrivals in arrival order; under [`ClockMode::Measured`]
    /// they are the first k responses in true delivery order.
    pub admitted: Vec<usize>,
    /// Arrivals `(worker, arrival_ms)` sorted by arrival time. Virtual
    /// rounds list every non-failed worker with
    /// `arrival = compute + injected delay`; measured rounds list only
    /// workers that actually computed (cancelled stragglers never produce
    /// an arrival), with `arrival =` that worker's measured compute time —
    /// injected delay magnitudes never enter measured timing.
    pub arrivals: Vec<(usize, f64)>,
    /// Simulated round duration: the k-th (last admitted) arrival time.
    pub elapsed_ms: f64,
    /// Workers that never responded (failures).
    pub failed: Vec<usize>,
    /// Per-worker compute time (ms), indexed by worker id: the flop-model
    /// cost under [`ClockMode::Virtual`], the worker's own wall-clock
    /// measurement under [`ClockMode::Measured`]. `NaN` for workers that
    /// were cancelled before computing.
    pub compute_ms: Vec<f64>,
    /// Scenario events that fired at the start of this round (their
    /// [`FaultEvent`] DSL labels) — the event-annotated-trace payload.
    /// Empty when no scenario is attached or the round was quiet.
    pub events: Vec<String>,
    /// Shard migrations the rebalancer executed at the **end** of this
    /// round (`migrate:FROM>TO:ROWS` labels). Empty unless a rebalancer
    /// is attached and its trigger fired — so `--rebalance off` rounds
    /// carry a byte-identical trace.
    pub migrations: Vec<String>,
}

impl Round {
    /// Mean per-worker compute time over the admitted set (ms) — the
    /// per-iteration `compute_ms` summary the traces/CSVs record. Cancelled
    /// workers (`NaN` slots) are never admitted, so the mean is over
    /// finite values; 0 on an empty admitted set.
    pub fn admitted_compute_ms(&self) -> f64 {
        if self.admitted.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.admitted.iter().map(|&w| self.compute_ms[w]).sum();
        sum / self.admitted.len() as f64
    }
}

/// Per-round gradient responses from the admitted set, arrival-ordered.
pub type GradResponses = Vec<(usize, Vec<f64>, f64)>;
/// Per-round line-search responses from the admitted set.
pub type CurvResponses = Vec<(usize, f64)>;

/// The simulated cluster: an engine plus the straggler/round machinery.
pub struct Cluster {
    cfg: ClusterConfig,
    engine: Box<dyn ComputeEngine>,
    rng: Pcg64,
    /// Flop cost per worker per gradient round (for the virtual clock).
    grad_mflops: Vec<f64>,
    ls_mflops: Vec<f64>,
    /// Padded row count per shard (scales the virtual flop model down to
    /// the sampled rows in mini-batch rounds).
    shard_rows: Vec<usize>,
    /// Attached deterministic fault scenario, advanced one step per round.
    scenario: Option<ScenarioState>,
    /// Attached elastic rebalancer (speed model + resharder), fed one
    /// observation batch per successful round; `None` = static placement.
    rebalancer: Option<Rebalancer>,
    /// Leader-side mirror of the engine-session park flags (scenario
    /// crash masks pushed to the resident worker pool; all-false when the
    /// engine has no session).
    parked: Vec<bool>,
    /// Pipelined-dispatch depth for measured-clock gradient rounds: the
    /// leader retires a round at its k-th admission and leaves up to
    /// `pipeline_depth - 1` rounds' straggler tails settling in the
    /// engine. `1` (the default) is the fully blocking historical path.
    /// Virtual-clock rounds ignore this entirely — their admission is
    /// post hoc over a collect-all gather, so there is no tail to
    /// overlap and traces stay byte-identical at every depth.
    pipeline_depth: usize,
    /// Rounds whose delay schedule has been sampled — must track
    /// `rounds_run` exactly (see [`Cluster::sample_delays`]).
    delay_rounds: u64,
    /// Persistent collect-all gradient sink, rearmed across virtual-clock
    /// rounds (lazily built on the first such round). Blocking rounds own
    /// their sink again by drain time, so the collector's inner vectors —
    /// response slots, delivery order, admitted list — keep their
    /// capacity round over round instead of being reallocated.
    grad_all_sink: Option<GradCollector>,
    /// Persistent first-k gradient sink for *blocking* measured rounds.
    /// Pipelined rounds (depth > 1) never use it: their straggler lanes
    /// keep collector clones alive past the round, which violates the
    /// sole-owner precondition of `rearm_first_k` — each pipelined round
    /// builds a fresh collector instead (recycling is a depth-1 luxury).
    grad_firstk_sink: Option<GradCollector>,
    /// Persistent collect-all line-search sink (virtual clock).
    curv_all_sink: Option<CurvCollector>,
    /// Persistent first-k line-search sink (measured clock; line-search
    /// rounds are never pipelined).
    curv_firstk_sink: Option<CurvCollector>,
    /// Reusable eligibility-mask scratch for measured-round admission
    /// (filled in place by [`Cluster::scripted_eligibility_into`]).
    eligible_buf: Vec<bool>,
    /// Accumulated simulated time.
    pub sim_ms: f64,
    /// Rounds executed so far (gradient + line-search).
    pub rounds_run: u64,
}

/// Virtual-clock flop model for one shard, per storage backend:
/// `(grad_mflops, ls_mflops)`. A gradient round is two gemv-shaped
/// passes (2 flops per touched multiply-add), a line-search round is
/// one. `DataMat::gemv_madds` is `rows·cols` for dense shards —
/// identical to the historical model, bit for bit — and `nnz` for CSR
/// shards, so sparse storage is not just a memory win: the straggler
/// simulation charges each worker the flops its kernel actually
/// executes. A shard resolved to [`GradMode::Gram`] serves its gradient
/// from the staged `p×p` cache instead of re-reading the shard, so its
/// gradient cost is the `p²` madds of one symmetric gemv — the same cost
/// model `GradMode::Auto` picks by, keeping the virtual clock honest
/// about the fast path. Line search always runs the gemv kernels, so
/// `ls_mflops` never changes. Shared by [`Cluster::new`] and the
/// rebalancer's post-migration refresh, so a migrated worker's simulated
/// compute cost tracks its new shard exactly.
///
/// [`GradMode::Gram`]: crate::linalg::GradMode::Gram
/// [`GradMode::Auto`]: crate::linalg::GradMode::Auto
fn shard_flops(s: &crate::problem::WorkerShard) -> (f64, f64) {
    let grad = match s.grad_mode {
        crate::linalg::GradMode::Gram => {
            let p = s.x.cols() as f64;
            p * p * 2.0 / 1e6
        }
        _ => 2.0 * s.x.gemv_madds() * 2.0 / 1e6,
    };
    (grad, 2.0 * s.x.gemv_madds() / 1e6)
}

impl Cluster {
    /// Build over an encoded problem with the given engine.
    pub fn new(
        prob: &EncodedProblem,
        engine: Box<dyn ComputeEngine>,
        cfg: ClusterConfig,
    ) -> Result<Self> {
        ensure!(
            cfg.workers == prob.m(),
            "config workers {} != problem shards {}",
            cfg.workers,
            prob.m()
        );
        ensure!(
            cfg.wait_for >= 1 && cfg.wait_for <= cfg.workers,
            "wait_for must be in 1..=workers"
        );
        ensure!(
            engine.workers() == prob.m(),
            "engine workers {} != problem shards {}",
            engine.workers(),
            prob.m()
        );
        let grad_mflops = prob.shards.iter().map(|s| shard_flops(s).0).collect();
        let ls_mflops = prob.shards.iter().map(|s| shard_flops(s).1).collect();
        let shard_rows = prob.shards.iter().map(|s| s.x.rows()).collect();
        let rng = Pcg64::new(cfg.seed, 0xc105);
        let parked = vec![false; cfg.workers];
        Ok(Cluster {
            cfg,
            engine,
            rng,
            grad_mflops,
            ls_mflops,
            shard_rows,
            scenario: None,
            rebalancer: None,
            parked,
            pipeline_depth: 1,
            delay_rounds: 0,
            grad_all_sink: None,
            grad_firstk_sink: None,
            curv_all_sink: None,
            curv_firstk_sink: None,
            eligible_buf: Vec::new(),
            sim_ms: 0.0,
            rounds_run: 0,
        })
    }

    /// The active configuration.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Attach a deterministic fault scenario (validated against this
    /// cluster's worker count; `admit:rotate:k`'s literal `k` resolves to
    /// the current `wait_for`). The script starts at round 0 and advances
    /// one step per cluster round — gradient, mini-batch, and line-search
    /// rounds all count (so L-BFGS consumes two scenario rounds per
    /// iteration). Scenario scripting layers **on top of** the configured
    /// [`DelayModel`]: the delay RNG is consumed identically with or
    /// without a scenario, which is what makes scenario runs bit-for-bit
    /// replayable under [`ClockMode::Virtual`] without perturbing
    /// scenario-free runs.
    pub fn set_scenario(&mut self, scenario: Scenario) -> Result<()> {
        self.scenario =
            Some(ScenarioState::new(scenario, self.cfg.workers, self.cfg.wait_for)?);
        Ok(())
    }

    /// Detach the scenario (subsequent rounds run the plain delay model;
    /// any scenario-parked engine workers are unparked).
    pub fn clear_scenario(&mut self) {
        self.scenario = None;
        self.sync_parked(None);
    }

    /// The attached scenario state, if any.
    pub fn scenario(&self) -> Option<&ScenarioState> {
        self.scenario.as_ref()
    }

    /// Attach (or detach, with [`RebalanceConfig::Off`]) the elastic
    /// rebalancer over this cluster's encoded problem. The rebalancer
    /// observes every successful round's per-worker `compute_ms /
    /// mflops` rate, and at the end of each **gradient** round may
    /// migrate one block-row band from the predicted-slowest worker to
    /// the fastest via the engine session's in-place shard handoff —
    /// lazily, because the code already covers the straggler while the
    /// move happens.
    ///
    /// Requires an engine with a resident [`EngineSession`] (the native
    /// pool): migration is a per-lane shard swap, not a rebuild. The
    /// scheme must be count-normalized ([`Rebalancer::new`] rejects
    /// replication / gradient coding), and mini-batch rounds refuse to
    /// run with a rebalancer attached (their aggregation reads static
    /// per-worker row counts).
    pub fn set_rebalancer(&mut self, prob: &EncodedProblem, cfg: RebalanceConfig) -> Result<()> {
        match cfg {
            RebalanceConfig::Off => {
                self.rebalancer = None;
                Ok(())
            }
            RebalanceConfig::Ewma { alpha, threshold } => {
                ensure!(
                    prob.shards.len() == self.cfg.workers,
                    "rebalancer problem has {} shards, cluster has {} workers",
                    prob.shards.len(),
                    self.cfg.workers
                );
                ensure!(
                    self.engine.session().is_some(),
                    "--rebalance requires an engine with a resident worker session \
                     (use --engine native)"
                );
                self.rebalancer =
                    Some(Rebalancer::new(prob.scheme, prob.shards.clone(), alpha, threshold)?);
                Ok(())
            }
        }
    }

    /// The attached rebalancer, if any (tests inspect its placement).
    pub fn rebalancer(&self) -> Option<&Rebalancer> {
        self.rebalancer.as_ref()
    }

    /// Override k between runs (η sweeps reuse the staged cluster). An
    /// attached scenario's `admit:rotate:k` window follows the new k.
    pub fn set_wait_for(&mut self, k: usize) {
        assert!(k >= 1 && k <= self.cfg.workers);
        self.cfg.wait_for = k;
        if let Some(sc) = &mut self.scenario {
            sc.set_wait_for(k);
        }
    }

    /// Set the pipelined-dispatch depth (see the `pipeline_depth` field
    /// docs). Depth 1 restores the fully blocking round loop; any depth
    /// is admission-equivalent to depth 1 — the pipeline only overlaps
    /// straggler tails *after* a round's admission has closed.
    pub fn set_pipeline_depth(&mut self, depth: usize) {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        self.pipeline_depth = depth;
    }

    /// The active pipelined-dispatch depth.
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Flush every in-flight pipelined dispatch (the end-of-run
    /// barrier). No-op at depth 1 or when nothing is outstanding.
    pub fn drain_pipeline(&mut self) -> Result<()> {
        self.engine.drain_dispatch()
    }

    /// Sample this round's injected delays. **This is the single place
    /// the delay RNG is consumed**, and its order is the reproducibility
    /// contract: exactly once per cluster round, at round start (before
    /// any scenario scripting or engine dispatch), workers drawn in index
    /// order `0..m`. The resident worker pool never touches this RNG —
    /// compute threads have no delay state at all — and the
    /// `debug_assert!` makes any future caller that resamples out of
    /// round order (a second draw within one round, or a draw after the
    /// round ran) fail loudly in debug/test builds.
    fn sample_delays(&mut self) -> Vec<f64> {
        debug_assert_eq!(
            self.delay_rounds, self.rounds_run,
            "delay RNG sampled out of round order: the schedule must be drawn exactly once \
             per round, at round start, in worker-index order"
        );
        self.delay_rounds += 1;
        (0..self.cfg.workers)
            .map(|i| self.cfg.delay.sample(&mut self.rng, i))
            .collect()
    }

    /// Start one round: sample the delay schedule (always, so the RNG
    /// stream is scenario-independent), advance the scenario script, fold
    /// scripted crashes into the schedule as fail-stop (infinite) delays
    /// — the one scenario effect shared by both clock modes — and push
    /// the crash mask to the engine session so resident pool workers park
    /// instead of computing responses the leader would discard. `kind`
    /// tells the scenario whether this dispatch opens an optimizer
    /// iteration (gradient / mini-batch) or rides inside one (line
    /// search): events fire on every cluster round regardless, but the
    /// `admit:rotate` window slides only on iteration rounds.
    fn stage_round(&mut self, kind: RoundKind) -> (Vec<f64>, Option<RoundScript>) {
        let mut delays = self.sample_delays();
        let script = self.scenario.as_mut().map(|s| s.begin_round(kind));
        if let Some(sc) = &script {
            for (i, d) in delays.iter_mut().enumerate() {
                if sc.crashed[i] {
                    *d = f64::INFINITY;
                }
            }
        }
        self.sync_parked(script.as_ref().map(|s| s.crashed.as_slice()));
        (delays, script)
    }

    /// Track the scenario's crash mask in the engine session's park
    /// flags: a scripted crash/leave parks the resident worker (its lane
    /// thread and staged shard survive), recover/join unparks it. Parking
    /// is compute-skipping only — admission already excludes crashed
    /// workers through the delay/eligibility masks, so traces are
    /// identical whether or not the engine has a session (engines without
    /// one keep the historical compute-and-discard behavior).
    fn sync_parked(&mut self, crashed: Option<&[bool]>) {
        let Cluster { engine, parked, .. } = self;
        let Some(session) = engine.session() else {
            return;
        };
        for (i, was) in parked.iter_mut().enumerate() {
            let want = crashed.is_some_and(|c| c[i]);
            if *was != want {
                session.set_parked(i, want);
                *was = want;
            }
        }
    }

    /// Read-only view of the engine's stateful session, if it has one
    /// (resident-pool diagnostics: park flags, spawn counts).
    /// Deliberately immutable: the cluster's scenario machinery owns the
    /// park flags while a run is live (a caller parking workers behind
    /// its back would desync the crash mask from admission), and
    /// reconfiguration belongs between runs — take the engine back with
    /// [`Cluster::into_engine`] to mutate its session.
    pub fn engine_session(&mut self) -> Option<&dyn EngineSession> {
        // demote the engine's mutable session handle to a shared view
        self.engine.session().map(|session| &*session)
    }

    /// Tear down the cluster and hand back its engine for reuse (any
    /// scenario-parked workers are unparked first). With a pool-backed
    /// engine this is what lets one set of resident threads serve many
    /// consecutive runs — reconfigure via
    /// [`EngineSession::reconfigure`], then build a fresh `Cluster`
    /// around the same box.
    pub fn into_engine(self) -> Box<dyn ComputeEngine> {
        let Cluster { mut engine, parked, .. } = self;
        if parked.iter().any(|&p| p) {
            if let Some(session) = engine.session() {
                for (i, p) in parked.iter().enumerate() {
                    if *p {
                        session.set_parked(i, false);
                    }
                }
            }
        }
        engine
    }

    /// Apply a script's slow factors to a virtual round's schedule: a
    /// slowed worker's modeled compute *and* injected delay both stretch,
    /// so degradation shows up in `compute_ms` and in the arrival order.
    /// (Measured rounds ignore slow factors, like all injected delay
    /// magnitudes — the hardware provides the timing there.)
    fn apply_virtual_script(
        compute: &mut [f64],
        delays: &mut [f64],
        script: Option<&RoundScript>,
    ) {
        if let Some(sc) = script {
            for i in 0..compute.len() {
                compute[i] *= sc.slow[i];
                delays[i] *= sc.slow[i];
            }
        }
    }

    /// Measured-mode eligibility under a script: a worker can be admitted
    /// iff it has not failed this round and — when an `admit:` override is
    /// active — it is in the scripted set. Returns the mask plus the
    /// admission count k (the scripted set size under an override, so the
    /// collector's cancellation flag flips exactly when the scripted
    /// responders have all delivered).
    /// Associated (not `&self`) so round impls can fill the cluster's own
    /// `eligible_buf` scratch while other fields stay borrowed; writes the
    /// mask in place and returns k.
    fn scripted_eligibility_into(
        wait_for: usize,
        delays: &[f64],
        script: Option<&RoundScript>,
        eligible: &mut Vec<bool>,
    ) -> usize {
        let admit = script.and_then(|s| s.admit.as_deref());
        eligible.clear();
        eligible.extend(
            delays
                .iter()
                .enumerate()
                .map(|(i, d)| d.is_finite() && admit.map_or(true, |set| set.contains(&i))),
        );
        match admit {
            None => wait_for,
            Some(_) => eligible.iter().filter(|&&e| e).count(),
        }
    }

    /// Virtual-clock round: deterministic post-hoc admission over the
    /// sampled arrival schedule `arrival_i = compute_i + delay_i`. With no
    /// `admit_override` this is the historical first-k batch gather, byte
    /// for byte; with one, the admitted set is exactly the scripted
    /// workers that responded (arrival order preserved), and the round
    /// lasts until the last of them arrives.
    fn virtual_round(
        &self,
        compute_ms: Vec<f64>,
        delays: &[f64],
        admit_override: Option<&[usize]>,
    ) -> Round {
        let m = self.cfg.workers;
        let mut arrivals: Vec<(usize, f64)> = Vec::with_capacity(m);
        let mut failed = Vec::new();
        for (i, &delay) in delays.iter().enumerate() {
            if delay.is_finite() {
                arrivals.push((i, compute_ms[i] + delay));
            } else {
                failed.push(i);
            }
        }
        arrivals.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let (admitted, elapsed_ms) = match admit_override {
            None => {
                let k = self.cfg.wait_for.min(arrivals.len());
                let admitted: Vec<usize> = arrivals[..k].iter().map(|&(w, _)| w).collect();
                let elapsed =
                    arrivals.get(k.saturating_sub(1)).map(|&(_, t)| t).unwrap_or(0.0);
                (admitted, elapsed)
            }
            Some(set) => {
                let mut admitted = Vec::with_capacity(set.len());
                let mut elapsed = 0.0f64;
                for &(w, t) in &arrivals {
                    if set.contains(&w) {
                        admitted.push(w);
                        elapsed = elapsed.max(t);
                    }
                }
                (admitted, elapsed)
            }
        };
        Round {
            admitted,
            arrivals,
            elapsed_ms,
            failed,
            compute_ms,
            events: Vec::new(),
            migrations: Vec::new(),
        }
    }

    /// Measured-clock round record from a finished first-k collector:
    /// admission already happened in delivery order, and all timing is
    /// the workers' own measurements. Injected delay *magnitudes* are a
    /// virtual-clock concept and do not enter measured timing (mixing
    /// them in would let a delay that never influenced admission dominate
    /// the round duration); only fail-stop events (infinite delay) apply.
    fn measured_round<T>(collected: &Collected<T>, delays: &[f64]) -> Round {
        let m = delays.len();
        let compute_ms: Vec<f64> = (0..m)
            .map(|i| collected.responses[i].as_ref().map(|r| r.1).unwrap_or(f64::NAN))
            .collect();
        let mut arrivals: Vec<(usize, f64)> = Vec::new();
        let mut failed = Vec::new();
        for (i, &delay) in delays.iter().enumerate() {
            if !delay.is_finite() {
                failed.push(i);
            } else if compute_ms[i].is_finite() {
                arrivals.push((i, compute_ms[i]));
            }
        }
        arrivals.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let admitted = collected.admitted.clone();
        let elapsed_ms = admitted.iter().map(|&w| compute_ms[w]).fold(0.0, f64::max);
        Round {
            admitted,
            arrivals,
            elapsed_ms,
            failed,
            compute_ms,
            events: Vec::new(),
            migrations: Vec::new(),
        }
    }

    /// Extract the admitted workers' payloads in admitted order.
    fn take_admitted<T>(round: &Round, collected: Collected<T>) -> Result<Vec<(usize, T)>> {
        let mut responses = collected.responses;
        round
            .admitted
            .iter()
            .map(|&wid| {
                responses[wid]
                    .take()
                    .map(|(payload, _)| (wid, payload))
                    .ok_or_else(|| {
                        anyhow::anyhow!("engine delivered no response for admitted worker {wid}")
                    })
            })
            .collect()
    }

    /// Snapshot of the round-advancing state taken before a round runs,
    /// restored if the round errors out. An erroring round is thereby
    /// **transactional**: the delay RNG, the scenario script position,
    /// and the out-of-order guard all rewind, so a retry replays the
    /// exact same scripted round instead of silently skipping it — and
    /// the guard in [`Cluster::sample_delays`] cannot mask the original
    /// engine error with a spurious debug panic. (The engine park flags
    /// are deliberately *not* rewound: the `parked` mirror stays in sync
    /// with the engine, and the retried round re-derives the same masks.)
    fn round_snapshot(&self) -> (Pcg64, Option<ScenarioState>, u64) {
        (self.rng.clone(), self.scenario.clone(), self.delay_rounds)
    }

    fn unwind_failed_round<T>(
        &mut self,
        snapshot: (Pcg64, Option<ScenarioState>, u64),
        res: Result<T>,
    ) -> Result<T> {
        if res.is_err() {
            let (rng, scenario, delay_rounds) = snapshot;
            self.rng = rng;
            self.scenario = scenario;
            self.delay_rounds = delay_rounds;
        }
        res
    }

    /// Feed one finished round's per-worker rate observations into the
    /// attached rebalancer (no-op without one). The gate is fully
    /// deterministic: worker `w` is observed iff the scenario script let
    /// it respond this round ([`RoundScript::speed_observation`]; every
    /// worker when no scenario is attached) and its `compute_ms` is a
    /// finite measurement over positive flops. Under the virtual clock
    /// the script's slow factor is already folded into `compute_ms`, so
    /// the speed model sees exactly the scripted degradation — bit for
    /// bit on every replay. Crashed/cancelled workers (`NaN` or no
    /// observation) leave their estimates frozen.
    fn observe_speeds(&mut self, round: &Round, script: Option<&RoundScript>, ls_round: bool) {
        let Some(rb) = self.rebalancer.as_mut() else {
            return;
        };
        let mflops = if ls_round { &self.ls_mflops } else { &self.grad_mflops };
        for w in 0..round.compute_ms.len() {
            let allowed = match script {
                Some(sc) => sc.speed_observation(w).is_some(),
                None => true,
            };
            if allowed {
                rb.observe(w, round.compute_ms[w], mflops[w]);
            }
        }
    }

    /// End-of-round elastic rebalance hook (gradient rounds only):
    /// refresh the speed model, plan at most one lazy block-row move,
    /// execute it through the engine session's in-place shard handoff
    /// ([`EngineSession::migrate_shards`] — no respawn, park flags
    /// kept), refresh the two touched workers' flop model, and record
    /// the move in the round's `migrations` trace.
    ///
    /// Runs strictly **after** the round succeeded and consumes no
    /// randomness, so the delay-RNG stream and scenario script position
    /// are placement-independent: `--rebalance off` runs stay
    /// byte-identical, and rebalanced scenario runs replay the exact
    /// same migration schedule. A failed handoff errors the round (the
    /// pool poisons itself), which the transactional round wrapper
    /// surfaces before `rounds_run` advances.
    fn rebalance_after_round(
        &mut self,
        round: &mut Round,
        script: Option<&RoundScript>,
    ) -> Result<()> {
        self.observe_speeds(round, script, false);
        let Some(rb) = self.rebalancer.as_ref() else {
            return Ok(());
        };
        let eligible: Vec<bool> = (0..self.cfg.workers)
            .map(|w| script.map_or(true, |sc| sc.speed_observation(w).is_some()))
            .collect();
        let Some(plan) = rb.plan(&eligible) else {
            return Ok(());
        };
        let changed = self
            .rebalancer
            .as_mut()
            .expect("rebalancer checked above")
            .apply(plan);
        let session = self
            .engine
            .session()
            .expect("set_rebalancer requires an engine session");
        session.migrate_shards(&changed)?;
        for (w, s) in &changed {
            let (grad, ls) = shard_flops(s);
            self.grad_mflops[*w] = grad;
            self.ls_mflops[*w] = ls;
            self.shard_rows[*w] = s.x.rows();
        }
        round.migrations.push(plan.to_string());
        Ok(())
    }

    /// One gradient round: broadcast `w`, workers stream `(g_i, f_i)`
    /// responses, leader admits the first k (or exactly the scripted set
    /// when a [`Scenario`] with an `admit:` policy is attached). Returns
    /// the admitted responses (admitted order) and the round record;
    /// advances the simulated clock.
    pub fn grad_round(&mut self, w: &[f64]) -> Result<(GradResponses, Round)> {
        let snapshot = self.round_snapshot();
        let res = self.grad_round_impl(w);
        self.unwind_failed_round(snapshot, res)
    }

    fn grad_round_impl(&mut self, w: &[f64]) -> Result<(GradResponses, Round)> {
        let m = self.cfg.workers;
        let (mut delays, script) = self.stage_round(RoundKind::Iteration);
        let (responses, mut round) = match self.cfg.clock {
            ClockMode::Virtual => {
                let sink = match self.grad_all_sink.take() {
                    Some(s) => {
                        s.rearm_all();
                        s
                    }
                    None => GradCollector::collect_all(m),
                };
                self.engine.worker_grad_streamed(w, &sink)?;
                let collected = sink.drain_collected();
                self.grad_all_sink = Some(sink);
                let mut compute: Vec<f64> =
                    self.grad_mflops.iter().map(|f| f * self.cfg.ms_per_mflop).collect();
                Self::apply_virtual_script(&mut compute, &mut delays, script.as_ref());
                let admit = script.as_ref().and_then(|s| s.admit.as_deref());
                let round = self.virtual_round(compute, &delays, admit);
                (Self::take_admitted(&round, collected)?, round)
            }
            ClockMode::Measured if self.pipeline_depth > 1 => {
                // Pipelined round: dispatch without awaiting the engine's
                // fan-out, retire at the k-th admission (the Condvar
                // snapshot), and leave up to depth-1 rounds' straggler
                // tails settling behind us. The admitted set and every
                // admitted payload are final at cancellation time, so
                // this arm is admission-identical to the blocking arm
                // below — only *when* straggler acks are reaped differs.
                // The collector is built fresh every round: straggler
                // lanes of earlier rounds may still hold clones, so the
                // sole-owner rearm precondition can never be met here —
                // pipelining trades collector recycling for overlap.
                let mut eligible = std::mem::take(&mut self.eligible_buf);
                let k = Self::scripted_eligibility_into(
                    self.cfg.wait_for,
                    &delays,
                    script.as_ref(),
                    &mut eligible,
                );
                let sink = GradCollector::first_k(m, k, eligible.clone());
                self.eligible_buf = eligible;
                self.engine.worker_grad_dispatch(w, &sink)?;
                let collected = sink.wait_cancelled_snapshot();
                drop(sink); // our handle; lane clones die as lanes finish
                self.engine.drain_dispatch_to(self.pipeline_depth - 1)?;
                let round = Self::measured_round(&collected, &delays);
                (Self::take_admitted(&round, collected)?, round)
            }
            ClockMode::Measured => {
                let mut eligible = std::mem::take(&mut self.eligible_buf);
                let k = Self::scripted_eligibility_into(
                    self.cfg.wait_for,
                    &delays,
                    script.as_ref(),
                    &mut eligible,
                );
                let sink = match self.grad_firstk_sink.take() {
                    Some(s) => {
                        s.rearm_first_k(k, &eligible);
                        s
                    }
                    None => GradCollector::first_k(m, k, eligible.clone()),
                };
                self.eligible_buf = eligible;
                self.engine.worker_grad_streamed(w, &sink)?;
                let collected = sink.drain_collected();
                self.grad_firstk_sink = Some(sink);
                let round = Self::measured_round(&collected, &delays);
                (Self::take_admitted(&round, collected)?, round)
            }
        };
        self.rebalance_after_round(&mut round, script.as_ref())?;
        if let Some(sc) = script {
            round.events = sc.labels;
        }
        let responses: GradResponses =
            responses.into_iter().map(|(wid, (g, f))| (wid, g, f)).collect();
        self.sim_ms += round.elapsed_ms;
        self.rounds_run += 1;
        Ok((responses, round))
    }

    /// One mini-batch gradient round: broadcast `w`, each worker streams
    /// `(g_i, f_i)` computed over its [`BatchPlan`] row segments, leader
    /// admits the first k. Same round machinery as
    /// [`Cluster::grad_round`] — identical delay-RNG consumption, both
    /// clock modes — except the virtual-clock flop model is scaled to the
    /// sampled rows (`b_i / rows_i` of the full-shard cost), so smaller
    /// batches finish proportionally faster on the simulated clock too.
    pub fn grad_batch_round(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
    ) -> Result<(GradResponses, Round)> {
        let snapshot = self.round_snapshot();
        let res = self.grad_batch_round_impl(w, plan);
        self.unwind_failed_round(snapshot, res)
    }

    fn grad_batch_round_impl(
        &mut self,
        w: &[f64],
        plan: &BatchPlan,
    ) -> Result<(GradResponses, Round)> {
        let m = self.cfg.workers;
        ensure!(
            plan.workers() == m,
            "batch plan covers {} workers, cluster has {m}",
            plan.workers()
        );
        ensure!(
            self.rebalancer.is_none(),
            "mini-batch rounds do not support elastic rebalancing: batch aggregation \
             reads the static per-worker row counts (run --rebalance off with --optimizer sgd)"
        );
        let (mut delays, script) = self.stage_round(RoundKind::Iteration);
        let (responses, mut round) = match self.cfg.clock {
            ClockMode::Virtual => {
                let sink = match self.grad_all_sink.take() {
                    Some(s) => {
                        s.rearm_all();
                        s
                    }
                    None => GradCollector::collect_all(m),
                };
                self.engine.worker_grad_batch_streamed(w, plan, &sink)?;
                let collected = sink.drain_collected();
                self.grad_all_sink = Some(sink);
                let mut compute: Vec<f64> = (0..m)
                    .map(|i| {
                        let frac = plan.rows(i) as f64 / self.shard_rows[i] as f64;
                        self.grad_mflops[i] * frac * self.cfg.ms_per_mflop
                    })
                    .collect();
                Self::apply_virtual_script(&mut compute, &mut delays, script.as_ref());
                let admit = script.as_ref().and_then(|s| s.admit.as_deref());
                let round = self.virtual_round(compute, &delays, admit);
                (Self::take_admitted(&round, collected)?, round)
            }
            ClockMode::Measured => {
                let mut eligible = std::mem::take(&mut self.eligible_buf);
                let k = Self::scripted_eligibility_into(
                    self.cfg.wait_for,
                    &delays,
                    script.as_ref(),
                    &mut eligible,
                );
                let sink = match self.grad_firstk_sink.take() {
                    Some(s) => {
                        s.rearm_first_k(k, &eligible);
                        s
                    }
                    None => GradCollector::first_k(m, k, eligible.clone()),
                };
                self.eligible_buf = eligible;
                self.engine.worker_grad_batch_streamed(w, plan, &sink)?;
                let collected = sink.drain_collected();
                self.grad_firstk_sink = Some(sink);
                let round = Self::measured_round(&collected, &delays);
                (Self::take_admitted(&round, collected)?, round)
            }
        };
        if let Some(sc) = script {
            round.events = sc.labels;
        }
        let responses: GradResponses =
            responses.into_iter().map(|(wid, (g, f))| (wid, g, f)).collect();
        self.sim_ms += round.elapsed_ms;
        self.rounds_run += 1;
        Ok((responses, round))
    }

    /// One line-search round over a fresh first-k set `D_t` (eq. (3)).
    /// Advances the scenario script like every other round.
    pub fn linesearch_round(&mut self, d: &[f64]) -> Result<(CurvResponses, Round)> {
        let snapshot = self.round_snapshot();
        let res = self.linesearch_round_impl(d);
        self.unwind_failed_round(snapshot, res)
    }

    fn linesearch_round_impl(&mut self, d: &[f64]) -> Result<(CurvResponses, Round)> {
        let m = self.cfg.workers;
        let (mut delays, script) = self.stage_round(RoundKind::Auxiliary);
        let (responses, mut round) = match self.cfg.clock {
            ClockMode::Virtual => {
                let sink = match self.curv_all_sink.take() {
                    Some(s) => {
                        s.rearm_all();
                        s
                    }
                    None => CurvCollector::collect_all(m),
                };
                self.engine.linesearch_streamed(d, &sink)?;
                let collected = sink.drain_collected();
                self.curv_all_sink = Some(sink);
                let mut compute: Vec<f64> =
                    self.ls_mflops.iter().map(|f| f * self.cfg.ms_per_mflop).collect();
                Self::apply_virtual_script(&mut compute, &mut delays, script.as_ref());
                let admit = script.as_ref().and_then(|s| s.admit.as_deref());
                let round = self.virtual_round(compute, &delays, admit);
                (Self::take_admitted(&round, collected)?, round)
            }
            ClockMode::Measured => {
                let mut eligible = std::mem::take(&mut self.eligible_buf);
                let k = Self::scripted_eligibility_into(
                    self.cfg.wait_for,
                    &delays,
                    script.as_ref(),
                    &mut eligible,
                );
                let sink = match self.curv_firstk_sink.take() {
                    Some(s) => {
                        s.rearm_first_k(k, &eligible);
                        s
                    }
                    None => CurvCollector::first_k(m, k, eligible.clone()),
                };
                self.eligible_buf = eligible;
                self.engine.linesearch_streamed(d, &sink)?;
                let collected = sink.drain_collected();
                self.curv_firstk_sink = Some(sink);
                let round = Self::measured_round(&collected, &delays);
                (Self::take_admitted(&round, collected)?, round)
            }
        };
        // Line-search rounds feed the speed model (the straggler pattern
        // is visible here too) but never migrate: one lazy move per
        // gradient round is the rebalancer's whole cadence.
        self.observe_speeds(&round, script.as_ref(), true);
        if let Some(sc) = script {
            round.events = sc.labels;
        }
        self.sim_ms += round.elapsed_ms;
        self.rounds_run += 1;
        Ok((responses, round))
    }

    /// Engine name (metrics/labels).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::EncoderKind;
    use crate::problem::{QuadProblem, Scheme, WorkerShard};
    use crate::runtime::NativeEngine;

    fn cluster(k: usize, delay: DelayModel, seed: u64) -> (EncodedProblem, Cluster) {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: k,
            delay,
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5,
            seed,
        };
        let c = Cluster::new(&enc, eng, cfg).unwrap();
        (enc, c)
    }

    #[test]
    fn first_k_gather_admits_exactly_k() {
        let (_, mut c) = cluster(5, DelayModel::Exp { mean_ms: 10.0 }, 3);
        let w = vec![0.1; 6];
        for _ in 0..10 {
            let (responses, round) = c.grad_round(&w).unwrap();
            assert_eq!(round.admitted.len(), 5);
            assert_eq!(responses.len(), 5);
            // admitted are the k smallest arrivals
            let kth = round.arrivals[4].1;
            for &(_, t) in &round.arrivals[5..] {
                assert!(t >= kth);
            }
            assert_eq!(round.elapsed_ms, kth);
        }
        assert_eq!(c.rounds_run, 10);
        assert!(c.sim_ms > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = vec![0.2; 6];
        let (_, mut c1) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        let (_, mut c2) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        for _ in 0..5 {
            let (r1, round1) = c1.grad_round(&w).unwrap();
            let (r2, round2) = c2.grad_round(&w).unwrap();
            assert_eq!(round1.admitted, round2.admitted);
            assert_eq!(round1.elapsed_ms, round2.elapsed_ms);
            for (a, b) in r1.iter().zip(&r2) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.2, b.2);
            }
        }
    }

    #[test]
    fn different_seeds_give_different_straggler_sets() {
        let w = vec![0.2; 6];
        let (_, mut c1) = cluster(3, DelayModel::Exp { mean_ms: 10.0 }, 1);
        let (_, mut c2) = cluster(3, DelayModel::Exp { mean_ms: 10.0 }, 2);
        let mut any_diff = false;
        for _ in 0..10 {
            let (_, round1) = c1.grad_round(&w).unwrap();
            let (_, round2) = c2.grad_round(&w).unwrap();
            if round1.admitted != round2.admitted {
                any_diff = true;
            }
        }
        assert!(any_diff);
    }

    #[test]
    fn no_delay_means_zero_wait_spread() {
        let (_, mut c) = cluster(8, DelayModel::None, 0);
        let (_, round) = c.grad_round(&[0.0; 6]).unwrap();
        // all arrivals equal compute time; k = m admits everyone
        assert_eq!(round.admitted.len(), 8);
        assert!(round.failed.is_empty());
    }

    #[test]
    fn failures_shrink_admitted_set() {
        let (_, mut c) = cluster(8, DelayModel::ExpWithFailures { mean_ms: 1.0, p_fail: 0.5 }, 5);
        let mut saw_failure = false;
        for _ in 0..20 {
            let (responses, round) = c.grad_round(&[0.0; 6]).unwrap();
            assert_eq!(responses.len(), round.admitted.len());
            assert!(round.admitted.len() + round.failed.len() <= 8);
            if !round.failed.is_empty() {
                saw_failure = true;
                assert!(round.admitted.len() < 8);
            }
        }
        assert!(saw_failure);
    }

    #[test]
    fn smaller_k_gives_smaller_round_time() {
        // E[k-th order statistic] grows with k — the Fig. 4-right effect
        let w = vec![0.1; 6];
        let mut t_small = 0.0;
        let mut t_large = 0.0;
        let (_, mut c_small) = cluster(2, DelayModel::Exp { mean_ms: 10.0 }, 11);
        let (_, mut c_large) = cluster(8, DelayModel::Exp { mean_ms: 10.0 }, 11);
        for _ in 0..50 {
            t_small += c_small.grad_round(&w).unwrap().1.elapsed_ms;
            t_large += c_large.grad_round(&w).unwrap().1.elapsed_ms;
        }
        assert!(
            t_small < t_large * 0.8,
            "k=2 time {t_small:.1} not well below k=8 time {t_large:.1}"
        );
    }

    #[test]
    fn linesearch_round_uses_fresh_subset() {
        let (_, mut c) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 13);
        let w = vec![0.1; 6];
        let d = vec![-0.1; 6];
        let (_, ra) = c.grad_round(&w).unwrap();
        let (_, rd) = c.linesearch_round(&d).unwrap();
        assert_eq!(ra.admitted.len(), 4);
        assert_eq!(rd.admitted.len(), 4);
        // not guaranteed different, but the rng must have advanced
        assert_eq!(c.rounds_run, 2);
    }

    #[test]
    fn batch_round_admits_k_and_scales_virtual_compute() {
        let (enc, mut c) = cluster(5, DelayModel::None, 3);
        let w = vec![0.1; 6];
        let mut rng = crate::rng::Pcg64::seeded(4);
        let plan = enc.sample_batch(0.25, &mut rng);
        let (_, full_round) = c.grad_round(&w).unwrap();
        let (responses, round) = c.grad_batch_round(&w, &plan).unwrap();
        assert_eq!(round.admitted.len(), 5);
        assert_eq!(responses.len(), 5);
        // quarter batch => quarter virtual compute time per worker
        for i in 0..8 {
            let frac = plan.rows(i) as f64 / enc.shards[i].x.rows() as f64;
            assert!(
                (round.compute_ms[i] - full_round.compute_ms[i] * frac).abs() < 1e-12,
                "worker {i}: {} vs {} * {frac}",
                round.compute_ms[i],
                full_round.compute_ms[i]
            );
        }
        assert_eq!(c.rounds_run, 2);
    }

    #[test]
    fn batch_round_full_plan_matches_grad_round_payloads() {
        let w = vec![0.3; 6];
        let (enc, mut c1) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 9);
        let (_, mut c2) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 9);
        let mut rng = crate::rng::Pcg64::seeded(0);
        let plan = enc.sample_batch(1.0, &mut rng);
        let (r1, round1) = c1.grad_round(&w).unwrap();
        let (r2, round2) = c2.grad_batch_round(&w, &plan).unwrap();
        assert_eq!(round1.admitted, round2.admitted);
        for ((wa, ga, fa), (wb, gb, fb)) in r1.iter().zip(&r2) {
            assert_eq!(wa, wb);
            assert_eq!(fa.to_bits(), fb.to_bits());
            for (x, y) in ga.iter().zip(gb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn batch_round_rejects_mismatched_plan() {
        let (_, mut c) = cluster(4, DelayModel::None, 1);
        let plan = BatchPlan { segments: vec![vec![(0, 4)]; 3] };
        assert!(c.grad_batch_round(&[0.0; 6], &plan).is_err());
    }

    #[test]
    fn admitted_compute_ms_summarizes_round() {
        let (_, mut c) = cluster(8, DelayModel::None, 0);
        let (_, round) = c.grad_round(&[0.0; 6]).unwrap();
        let mean = round.admitted_compute_ms();
        assert!(mean > 0.0 && mean.is_finite());
        // equal shards => the mean equals any single worker's time
        assert!((mean - round.compute_ms[0]).abs() < 1e-12);
    }

    #[test]
    fn delay_model_parsing() {
        assert_eq!(DelayModel::parse("none").unwrap(), DelayModel::None);
        assert_eq!(DelayModel::parse("exp:10").unwrap(), DelayModel::Exp { mean_ms: 10.0 });
        assert_eq!(
            DelayModel::parse("shifted:5:10").unwrap(),
            DelayModel::ShiftedExp { shift_ms: 5.0, mean_ms: 10.0 }
        );
        assert_eq!(
            DelayModel::parse("expfail:10:0.05").unwrap(),
            DelayModel::ExpWithFailures { mean_ms: 10.0, p_fail: 0.05 }
        );
        assert_eq!(
            DelayModel::parse("hetero:10:1,1,4").unwrap(),
            DelayModel::HeteroExp { mean_ms: 10.0, factors: vec![1.0, 1.0, 4.0] }
        );
        assert!(DelayModel::parse("hetero:10:").is_err());
        assert!(DelayModel::parse("hetero:10").is_err());
        assert!(DelayModel::parse("bogus:1").is_err());
        assert!(DelayModel::parse("exp").is_err());
        // exact arity: trailing fields are malformed, not silently ignored
        assert!(DelayModel::parse("none:1").is_err());
        assert!(DelayModel::parse("exp:10:99").is_err());
        assert!(DelayModel::parse("const:3:4").is_err());
        assert!(DelayModel::parse("shifted:5:10:1").is_err());
        assert!(DelayModel::parse("expfail:10:0.05:0").is_err());
        assert!(DelayModel::parse("hetero:10:1,2:3").is_err());
    }

    #[test]
    fn delay_model_display_roundtrip() {
        for model in [
            DelayModel::None,
            DelayModel::Constant { ms: 3.5 },
            DelayModel::Exp { mean_ms: 10.0 },
            DelayModel::ShiftedExp { shift_ms: 5.0, mean_ms: 10.0 },
            DelayModel::Pareto { scale_ms: 2.0, shape: 1.5 },
            DelayModel::ExpWithFailures { mean_ms: 10.0, p_fail: 0.05 },
            DelayModel::HeteroExp { mean_ms: 10.0, factors: vec![1.0, 1.0, 4.0] },
        ] {
            assert_eq!(DelayModel::parse(&model.to_string()).unwrap(), model);
        }
    }

    #[test]
    fn virtual_flop_model_is_nnz_proportional_for_sparse_shards() {
        // identical data, two storages: the sparse cluster's virtual
        // compute (and hence round time) must be nnz-proportional
        use crate::linalg::{CsrMat, StorageKind};
        let n = 64usize;
        let p = 33usize;
        // 2 nnz per row → nnz/dense ratio = 2/p
        let mut row_ptr = vec![0usize];
        let (mut cols, mut vals, mut y) = (Vec::new(), Vec::new(), Vec::new());
        for r in 0..n {
            cols.push((r % (p - 1)) as u32);
            cols.push((p - 1) as u32);
            vals.extend_from_slice(&[1.0, 1.0]);
            row_ptr.push(cols.len());
            y.push(1.0);
        }
        let prob = QuadProblem::new(CsrMat::from_raw(n, p, row_ptr, cols, vals), y, 0.0);
        let w0 = vec![0.0; p];
        let round_time = |storage: StorageKind| -> f64 {
            let enc =
                EncodedProblem::encode_stored(&prob, EncoderKind::Identity, 1.0, 4, 0, storage)
                    .unwrap();
            let eng = Box::new(NativeEngine::new(&enc));
            let cfg = ClusterConfig {
                workers: 4,
                wait_for: 4,
                delay: DelayModel::None,
                clock: ClockMode::Virtual,
                ms_per_mflop: 0.5,
                seed: 0,
            };
            let mut c = Cluster::new(&enc, eng, cfg).unwrap();
            c.grad_round(&w0).unwrap().1.elapsed_ms
        };
        let dense_ms = round_time(StorageKind::Dense);
        let sparse_ms = round_time(StorageKind::Sparse);
        assert!(sparse_ms > 0.0);
        let ratio = sparse_ms / dense_ms;
        let expect = 2.0 / p as f64;
        assert!(
            (ratio - expect).abs() < 1e-9,
            "sparse/dense virtual time ratio {ratio} != nnz ratio {expect}"
        );
    }

    #[test]
    fn clock_mode_parsing() {
        assert_eq!(ClockMode::parse("virtual").unwrap(), ClockMode::Virtual);
        assert_eq!(ClockMode::parse("Measured").unwrap(), ClockMode::Measured);
        assert_eq!(ClockMode::parse("wall").unwrap(), ClockMode::Measured);
        assert!(ClockMode::parse("atomic").is_err());
    }

    #[test]
    fn rejects_mismatched_config() {
        let prob = QuadProblem::synthetic_gaussian(32, 4, 0.0, 0);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Identity, 1.0, 4, 0).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig { workers: 8, wait_for: 4, ..Default::default() };
        assert!(Cluster::new(&enc, eng, cfg).is_err());
    }

    #[test]
    fn virtual_round_reports_flop_model_compute_times() {
        let (_, mut c) = cluster(8, DelayModel::None, 0);
        let (_, round) = c.grad_round(&[0.0; 6]).unwrap();
        assert_eq!(round.compute_ms.len(), 8);
        // equal shards => equal virtual compute times, matching the model
        for (i, &t) in round.compute_ms.iter().enumerate() {
            assert!(t.is_finite() && t > 0.0, "worker {i}: bad virtual time {t}");
            assert!((t - round.compute_ms[0]).abs() < 1e-15);
        }
    }

    /// Two shards whose row counts differ by ~4000×: the measured clock
    /// must attribute each worker its own wall-clock time, not the
    /// historical uniform mean share.
    #[test]
    fn measured_clock_gives_nonuniform_times_for_unequal_shards() {
        let (rows_small, rows_big, p) = (8usize, 32768usize, 64usize);
        let prob = QuadProblem::synthetic_gaussian(rows_small + rows_big, p, 0.0, 1);
        let shards = vec![
            WorkerShard {
                x: prob.x.row_band(0, rows_small),
                y: prob.y[..rows_small].to_vec(),
                rows_real: rows_small,
                partition_id: 0,
                grad_mode: crate::linalg::GradMode::Gemv,
            },
            WorkerShard {
                x: prob.x.row_band(rows_small, rows_small + rows_big),
                y: prob.y[rows_small..].to_vec(),
                rows_real: rows_big,
                partition_id: 1,
                grad_mode: crate::linalg::GradMode::Gemv,
            },
        ];
        let enc = EncodedProblem {
            shards,
            scheme: Scheme::Uncoded,
            kind: EncoderKind::Identity,
            beta: 1.0,
            gram_scale: 1.0,
            storage: crate::linalg::StorageKind::Dense,
            precision: crate::linalg::Precision::F64,
            grad_mode: crate::linalg::GradMode::Gemv,
            raw: prob,
        };
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig {
            workers: 2,
            wait_for: 2,
            delay: DelayModel::None,
            clock: ClockMode::Measured,
            ms_per_mflop: 0.5,
            seed: 0,
        };
        let mut c = Cluster::new(&enc, eng, cfg).unwrap();
        let w0 = vec![0.1; p];
        let (responses, round) = c.grad_round(&w0).unwrap();
        assert_eq!(responses.len(), 2);
        let (small, big) = (round.compute_ms[0], round.compute_ms[1]);
        assert!(small.is_finite() && big.is_finite(), "times: {small} vs {big}");
        assert_ne!(small, big, "mean-share regression: uniform measured times");
        assert!(
            big > small * 1.5,
            "4096x larger shard should measure clearly slower: small {small} ms, big {big} ms"
        );
        // the round clock advanced by the measured (not virtual) time
        assert!(round.elapsed_ms >= big);
    }

    /// Measured mode with a serial (default-impl) engine: cancellation is
    /// deterministic — workers after the k-th are skipped entirely.
    #[test]
    fn measured_round_cancels_stragglers() {
        struct SerialMock {
            p: usize,
            m: usize,
        }
        impl ComputeEngine for SerialMock {
            fn name(&self) -> &'static str {
                "serial-mock"
            }
            fn worker_grad(&mut self, worker: usize, _w: &[f64]) -> Result<(Vec<f64>, f64)> {
                Ok((vec![worker as f64; self.p], worker as f64))
            }
            fn linesearch(&mut self, worker: usize, _d: &[f64]) -> Result<f64> {
                Ok(worker as f64)
            }
            fn workers(&self) -> usize {
                self.m
            }
        }
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let eng = Box::new(SerialMock { p: 6, m: 8 });
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: 3,
            delay: DelayModel::None,
            clock: ClockMode::Measured,
            ms_per_mflop: 0.5,
            seed: 0,
        };
        let mut c = Cluster::new(&enc, eng, cfg).unwrap();
        let (responses, round) = c.grad_round(&[0.0; 6]).unwrap();
        // serial delivery order is 0, 1, 2 — then the round cancels
        assert_eq!(round.admitted, vec![0, 1, 2]);
        assert_eq!(responses.len(), 3);
        for (i, (wid, g, f)) in responses.iter().enumerate() {
            assert_eq!(*wid, i);
            assert_eq!(*f, i as f64);
            assert!(g.iter().all(|&x| x == i as f64));
        }
        // cancelled workers never computed: no compute time, no arrival
        for w in 3..8 {
            assert!(round.compute_ms[w].is_nan(), "worker {w} should be cancelled");
        }
        assert_eq!(round.arrivals.len(), 3);
        assert!(round.failed.is_empty());
    }

    /// Attaching a scenario must not perturb a run it does not touch:
    /// same delay-RNG stream, same admitted sets, same round times.
    #[test]
    fn inert_scenario_is_bitwise_invisible() {
        let w = vec![0.2; 6];
        let (_, mut plain) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        let (_, mut scripted) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        // events all fire far beyond the horizon; default first-k policy
        scripted.set_scenario(Scenario::parse("crash:0@1000").unwrap()).unwrap();
        for _ in 0..8 {
            let (r1, round1) = plain.grad_round(&w).unwrap();
            let (r2, round2) = scripted.grad_round(&w).unwrap();
            assert_eq!(round1.admitted, round2.admitted);
            assert_eq!(round1.elapsed_ms.to_bits(), round2.elapsed_ms.to_bits());
            assert!(round2.events.is_empty());
            for (a, b) in r1.iter().zip(&r2) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.2.to_bits(), b.2.to_bits());
            }
        }
    }

    #[test]
    fn scenario_crash_and_recover_script_the_responders() {
        let (_, mut c) = cluster(8, DelayModel::None, 0);
        c.set_scenario(Scenario::parse("crash:3@2,recover:3@4").unwrap()).unwrap();
        let w = vec![0.1; 6];
        for t in 0..6 {
            let (_, round) = c.grad_round(&w).unwrap();
            if (2..4).contains(&t) {
                assert_eq!(round.failed, vec![3], "round {t}");
                assert_eq!(round.admitted.len(), 7, "round {t}");
                assert!(!round.admitted.contains(&3), "round {t}");
            } else {
                assert!(round.failed.is_empty(), "round {t}");
                assert_eq!(round.admitted.len(), 8, "round {t}");
            }
            if t == 2 {
                assert_eq!(round.events, vec!["crash:3@2"]);
            } else if t == 4 {
                assert_eq!(round.events, vec!["recover:3@4"]);
            } else {
                assert!(round.events.is_empty(), "round {t}");
            }
        }
    }

    #[test]
    fn scenario_slow_factor_pushes_worker_out_of_admitted() {
        // equal shards + constant delay: ties resolve in worker order, so
        // worker 7 is normally outside k = 7 only by index. Slowing worker
        // 0 by 10x must push *it* out instead and stretch the round.
        let (_, mut base) = cluster(7, DelayModel::Constant { ms: 2.0 }, 0);
        let (_, mut slow) = cluster(7, DelayModel::Constant { ms: 2.0 }, 0);
        slow.set_scenario(Scenario::parse("slow:0:10@0").unwrap()).unwrap();
        let w = vec![0.1; 6];
        let (_, r_base) = base.grad_round(&w).unwrap();
        let (_, r_slow) = slow.grad_round(&w).unwrap();
        assert!(r_base.admitted.contains(&0));
        assert!(!r_slow.admitted.contains(&0), "slowed worker still admitted");
        assert!(r_slow.compute_ms[0] > r_base.compute_ms[0] * 9.0);
        assert!(r_slow.elapsed_ms >= r_base.elapsed_ms);
    }

    #[test]
    fn scenario_rack_event_slows_the_whole_range() {
        let (_, mut c) = cluster(4, DelayModel::Constant { ms: 1.0 }, 0);
        c.set_scenario(Scenario::parse("rack:4-7:25@0").unwrap()).unwrap();
        let (_, round) = c.grad_round(&[0.1; 6]).unwrap();
        // the rack (4..=7) arrives strictly after the healthy half
        assert_eq!(round.admitted, vec![0, 1, 2, 3]);
        for w in 4..8 {
            assert!(round.compute_ms[w] > round.compute_ms[0] * 20.0, "worker {w}");
        }
    }

    #[test]
    fn scenario_admit_rotate_forces_exact_rotating_subsets() {
        let (_, mut c) = cluster(8, DelayModel::Exp { mean_ms: 10.0 }, 3);
        c.set_scenario(Scenario::parse("admit:rotate:3").unwrap()).unwrap();
        let w = vec![0.1; 6];
        for t in 0..10usize {
            let (responses, round) = c.grad_round(&w).unwrap();
            let mut want: Vec<usize> = (0..3).map(|j| (t + j) % 8).collect();
            want.sort_unstable();
            let mut got = round.admitted.clone();
            got.sort_unstable();
            assert_eq!(got, want, "round {t}");
            assert_eq!(responses.len(), 3);
            // the round lasts until the last scripted responder arrives
            let latest = round
                .arrivals
                .iter()
                .filter(|a| round.admitted.contains(&a.0))
                .map(|a| a.1)
                .fold(0.0, f64::max);
            assert_eq!(round.elapsed_ms.to_bits(), latest.to_bits());
        }
    }

    #[test]
    fn scenario_rotate_k_follows_set_wait_for() {
        let (_, mut c) = cluster(6, DelayModel::None, 0);
        c.set_scenario(Scenario::parse("admit:rotate:k").unwrap()).unwrap();
        let (_, r) = c.grad_round(&[0.0; 6]).unwrap();
        assert_eq!(r.admitted.len(), 6);
        // an η sweep reusing the staged cluster re-resolves the window
        c.set_wait_for(2);
        let (_, r) = c.grad_round(&[0.0; 6]).unwrap();
        assert_eq!(r.admitted.len(), 2);
    }

    #[test]
    fn scenario_admit_fixed_drops_crashed_members() {
        let (_, mut c) = cluster(8, DelayModel::None, 0);
        c.set_scenario(Scenario::parse("crash:2@1;admit:fixed:1.2.5").unwrap()).unwrap();
        let (_, r0) = c.grad_round(&[0.0; 6]).unwrap();
        let mut got = r0.admitted.clone();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 5]);
        // after the crash the scripted set shrinks instead of deadlocking
        let (_, r1) = c.grad_round(&[0.0; 6]).unwrap();
        let mut got = r1.admitted.clone();
        got.sort_unstable();
        assert_eq!(got, vec![1, 5]);
        assert_eq!(r1.failed, vec![2]);
    }

    #[test]
    fn scenario_measured_mode_admits_exactly_the_scripted_set() {
        let (_, mut c) = cluster(8, DelayModel::None, 0);
        c.cfg.clock = ClockMode::Measured;
        c.set_scenario(Scenario::parse("admit:cycle:0.3/6.7").unwrap()).unwrap();
        for want in [vec![0usize, 3], vec![6, 7], vec![0, 3]] {
            let (responses, round) = c.grad_round(&[0.0; 6]).unwrap();
            let mut got = round.admitted.clone();
            got.sort_unstable();
            assert_eq!(got, want);
            assert_eq!(responses.len(), 2);
        }
    }

    #[test]
    fn scenario_replays_bit_for_bit_under_virtual_clock() {
        let dsl = "slow:1:4@1,crash:5@3,recover:5@6;admit:rotate:k";
        let run = || -> Vec<(Vec<usize>, u64, Vec<String>)> {
            let (_, mut c) = cluster(5, DelayModel::Exp { mean_ms: 10.0 }, 9);
            c.set_scenario(Scenario::parse(dsl).unwrap()).unwrap();
            let w = vec![0.2; 6];
            (0..10)
                .map(|_| {
                    let (_, r) = c.grad_round(&w).unwrap();
                    (r.admitted, r.elapsed_ms.to_bits(), r.events)
                })
                .collect()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn scenario_rejects_out_of_range_workers() {
        let (_, mut c) = cluster(4, DelayModel::None, 0);
        assert!(c.set_scenario(Scenario::parse("crash:8@0").unwrap()).is_err());
        assert!(c.set_scenario(Scenario::parse("admit:rotate:9").unwrap()).is_err());
        assert!(c.set_scenario(Scenario::parse("crash:7@0").unwrap()).is_ok());
        c.clear_scenario();
        assert!(c.scenario().is_none());
    }

    /// Scenario crashes must park the resident pool worker (thread and
    /// shard stay; fan-out skips it) and recover must unpark it — the
    /// crash-park invariant, observed through the engine session.
    #[test]
    fn scenario_crash_parks_engine_worker_and_recover_rejoins() {
        let (_, mut c) = cluster(4, DelayModel::None, 0);
        c.set_scenario(Scenario::parse("crash:3@1,leave:1@1,recover:3@3,join:1@4").unwrap())
            .unwrap();
        let w = vec![0.1; 6];
        let expect_parked = [0usize, 2, 2, 1, 0, 0];
        for (t, want) in expect_parked.iter().enumerate() {
            let (_, round) = c.grad_round(&w).unwrap();
            let got = c.engine_session().expect("native engine session").parked_count();
            assert_eq!(got, *want, "round {t}: parked count");
            assert_eq!(round.failed.len(), *want, "round {t}: failed count");
        }
        // detaching the scenario unparks everyone
        c.set_scenario(Scenario::parse("crash:0@0").unwrap()).unwrap();
        c.grad_round(&w).unwrap();
        assert_eq!(c.engine_session().unwrap().parked_count(), 1);
        c.clear_scenario();
        assert_eq!(c.engine_session().unwrap().parked_count(), 0);
    }

    /// Round dispatch must never spawn threads: the pool spawns once, on
    /// the first round, and the count stays put over every round shape.
    #[test]
    fn round_dispatch_never_spawns_after_pool_startup() {
        let (enc, mut c) = cluster(5, DelayModel::Exp { mean_ms: 10.0 }, 3);
        let w = vec![0.1; 6];
        c.grad_round(&w).unwrap();
        let spawned = c.engine_session().unwrap().spawn_count();
        assert!(spawned > 0);
        let mut rng = crate::rng::Pcg64::seeded(2);
        let plan = enc.sample_batch(0.5, &mut rng);
        for _ in 0..5 {
            c.grad_round(&w).unwrap();
            c.grad_batch_round(&w, &plan).unwrap();
            c.linesearch_round(&w).unwrap();
        }
        assert_eq!(c.engine_session().unwrap().spawn_count(), spawned);
    }

    /// `into_engine` hands the resident pool back for the next run:
    /// rounds through the recycled engine match a fresh engine's bitwise.
    #[test]
    fn into_engine_recycles_the_pool_across_runs() {
        let (enc, mut c1) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        let w = vec![0.2; 6];
        for _ in 0..3 {
            c1.grad_round(&w).unwrap();
        }
        let engine = c1.into_engine();
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: 4,
            delay: DelayModel::Exp { mean_ms: 10.0 },
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5,
            seed: 7,
        };
        let mut recycled = Cluster::new(&enc, engine, cfg).unwrap();
        let (_, mut fresh) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        for _ in 0..4 {
            let (ra, round_a) = recycled.grad_round(&w).unwrap();
            let (rb, round_b) = fresh.grad_round(&w).unwrap();
            assert_eq!(round_a.admitted, round_b.admitted);
            assert_eq!(round_a.elapsed_ms.to_bits(), round_b.elapsed_ms.to_bits());
            for (a, b) in ra.iter().zip(&rb) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.2.to_bits(), b.2.to_bits());
            }
        }
    }

    /// The debug-build guard on the delay RNG: drawing a second schedule
    /// within one round (out of round order) must fail loudly.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "delay RNG sampled out of round order")]
    fn delay_rng_out_of_round_order_sampling_is_caught() {
        let (_, mut c) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 0);
        let _ = c.sample_delays();
        let _ = c.sample_delays();
    }

    /// Measured mode respects fail-stop workers: their responses are
    /// never admitted even when they deliver first.
    #[test]
    fn measured_round_excludes_failed_workers() {
        let (_, mut c) = cluster(8, DelayModel::ExpWithFailures { mean_ms: 1.0, p_fail: 0.5 }, 5);
        c.cfg.clock = ClockMode::Measured;
        let mut saw_failure = false;
        for _ in 0..10 {
            let (responses, round) = c.grad_round(&[0.0; 6]).unwrap();
            assert_eq!(responses.len(), round.admitted.len());
            for wid in &round.admitted {
                assert!(!round.failed.contains(wid), "failed worker {wid} admitted");
            }
            saw_failure |= !round.failed.is_empty();
        }
        assert!(saw_failure);
    }

    /// A scripted slow worker must trigger a migration off it, annotate
    /// the round trace, shrink its virtual compute, conserve total real
    /// rows, and never respawn a pool thread.
    #[test]
    fn rebalancer_migrates_off_scripted_slow_worker() {
        let (enc, mut c) = cluster(8, DelayModel::None, 0);
        let total_rows: usize = enc.shards.iter().map(|s| s.rows_real).sum();
        c.set_rebalancer(&enc, RebalanceConfig::parse("ewma:1:1.5").unwrap()).unwrap();
        c.set_scenario(Scenario::parse("slow:2:3@0").unwrap()).unwrap();
        let w = vec![0.1; 6];
        let (_, r0) = c.grad_round(&w).unwrap();
        // round 0 observes the 3x rate and migrates at round end
        assert!(!r0.migrations.is_empty(), "slow worker should trigger a move");
        assert!(r0.migrations[0].starts_with("migrate:2>"), "donor must be the slow worker");
        let spawned = c.engine_session().unwrap().spawn_count();
        let mut migrated_rounds = 1;
        let mut last_donor_ms = r0.compute_ms[2];
        for _ in 1..6 {
            let (responses, r) = c.grad_round(&w).unwrap();
            assert_eq!(responses.len(), 8);
            migrated_rounds += usize::from(!r.migrations.is_empty());
            // the donor's shard only ever shrinks, so its virtual
            // compute (slow factor included) never grows back
            assert!(r.compute_ms[2] <= last_donor_ms + 1e-12);
            last_donor_ms = r.compute_ms[2];
        }
        assert!(migrated_rounds >= 1);
        // migration is a lane-local shard swap: zero new threads
        assert_eq!(c.engine_session().unwrap().spawn_count(), spawned);
        // placement conserved: every real row still lives somewhere
        let placed: usize = c.rebalancer().unwrap().shards().iter().map(|s| s.rows_real).sum();
        assert_eq!(placed, total_rows);
        assert!(c.rebalancer().unwrap().shards()[2].rows_real < total_rows / 8);
    }

    /// An attached-but-quiet rebalancer (trigger never fires) must leave
    /// the trace bitwise identical to the static-placement cluster.
    #[test]
    fn quiet_rebalancer_is_bitwise_invisible() {
        let w = vec![0.2; 6];
        let (_, mut plain) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        let (enc, mut balanced) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        balanced
            .set_rebalancer(&enc, RebalanceConfig::Ewma { alpha: 0.5, threshold: 1e9 })
            .unwrap();
        for _ in 0..6 {
            let (r1, round1) = plain.grad_round(&w).unwrap();
            let (r2, round2) = balanced.grad_round(&w).unwrap();
            assert!(round2.migrations.is_empty());
            assert_eq!(round1.admitted, round2.admitted);
            assert_eq!(round1.elapsed_ms.to_bits(), round2.elapsed_ms.to_bits());
            for (a, b) in r1.iter().zip(&r2) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.2.to_bits(), b.2.to_bits());
            }
        }
    }

    /// Mini-batch aggregation reads static per-worker row counts, so a
    /// batch round with a rebalancer attached must refuse to run.
    #[test]
    fn batch_round_rejects_attached_rebalancer() {
        let (enc, mut c) = cluster(8, DelayModel::None, 0);
        c.set_rebalancer(&enc, RebalanceConfig::Ewma { alpha: 0.5, threshold: 2.0 }).unwrap();
        let mut rng = crate::rng::Pcg64::seeded(3);
        let plan = enc.sample_batch(0.5, &mut rng);
        let err = c.grad_batch_round(&[0.0; 6], &plan).unwrap_err();
        assert!(err.to_string().contains("rebalanc"), "unexpected error: {err}");
        // detaching restores batch rounds
        c.set_rebalancer(&enc, RebalanceConfig::Off).unwrap();
        assert!(c.rebalancer().is_none());
        c.grad_batch_round(&[0.0; 6], &plan).unwrap();
    }
}
