//! Simulated leader/worker cluster with first-k-of-m gather — the
//! distributed substrate the paper runs on (Figure 1).
//!
//! The paper's two testbeds are (a) a 32-node EC2 cluster with natural
//! network stragglers and (b) a 32-core machine with **injected**
//! `Δ ~ exp(10ms)` delays (§5, MovieLens experiment). We implement (b)
//! directly, with a family of delay models ([`DelayModel`]): per round,
//! every worker computes its shard task, each response is assigned
//! `arrival = compute_time + sampled delay`, and the leader admits the
//! **first k** arrivals (`A_t`); the round's simulated duration is the
//! k-th arrival time. Late responses are dropped (the paper's
//! "drop their updates upon arrival" option).
//!
//! Two clocks:
//! * [`ClockMode::Virtual`] — compute time from a deterministic flop-cost
//!   model; fully reproducible (tests, convergence figures).
//! * [`ClockMode::Measured`] — compute time measured on the wall clock
//!   (runtime figures with a real engine in the loop).
//!
//! The cluster is engine-agnostic ([`ComputeEngine`]): the same rounds run
//! on the native Rust kernels or the PJRT/XLA artifacts.

use crate::problem::EncodedProblem;
use crate::rng::Pcg64;
use crate::runtime::ComputeEngine;
use anyhow::{ensure, Result};

/// Straggler delay model (per worker, per round), milliseconds.
#[derive(Clone, Debug, PartialEq)]
pub enum DelayModel {
    /// No injected delay (all workers equally fast).
    None,
    /// Constant delay for every worker.
    Constant { ms: f64 },
    /// i.i.d. exponential — the paper's MovieLens model (`exp(10ms)`).
    Exp { mean_ms: f64 },
    /// Shifted exponential: `shift + exp(mean)`; classic straggler model.
    ShiftedExp { shift_ms: f64, mean_ms: f64 },
    /// Heavy-tailed Pareto(scale, shape).
    Pareto { scale_ms: f64, shape: f64 },
    /// Exponential with a per-worker fail-stop probability: a failed
    /// worker never responds that round (delay = ∞).
    ExpWithFailures { mean_ms: f64, p_fail: f64 },
    /// Heterogeneous: exponential whose mean is `mean_ms * factor[i]`
    /// (persistent slow nodes).
    HeteroExp { mean_ms: f64, factors: Vec<f64> },
}

impl DelayModel {
    /// Sample worker `i`'s injected delay for one round.
    pub fn sample(&self, rng: &mut Pcg64, worker: usize) -> f64 {
        match self {
            DelayModel::None => 0.0,
            DelayModel::Constant { ms } => *ms,
            DelayModel::Exp { mean_ms } => rng.next_exp(*mean_ms),
            DelayModel::ShiftedExp { shift_ms, mean_ms } => shift_ms + rng.next_exp(*mean_ms),
            DelayModel::Pareto { scale_ms, shape } => rng.next_pareto(*scale_ms, *shape),
            DelayModel::ExpWithFailures { mean_ms, p_fail } => {
                if rng.next_f64() < *p_fail {
                    f64::INFINITY
                } else {
                    rng.next_exp(*mean_ms)
                }
            }
            DelayModel::HeteroExp { mean_ms, factors } => {
                let f = factors.get(worker % factors.len().max(1)).copied().unwrap_or(1.0);
                rng.next_exp(mean_ms * f)
            }
        }
    }

    /// Parse CLI forms like `exp:10`, `shifted:5:10`, `pareto:2:1.5`,
    /// `expfail:10:0.05`, `const:3`, `none`.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize| -> Result<f64> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("delay model {s:?}: missing field {i}"))?
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("delay model {s:?}: {e}"))
        };
        Ok(match parts[0] {
            "none" => DelayModel::None,
            "const" => DelayModel::Constant { ms: num(1)? },
            "exp" => DelayModel::Exp { mean_ms: num(1)? },
            "shifted" => DelayModel::ShiftedExp { shift_ms: num(1)?, mean_ms: num(2)? },
            "pareto" => DelayModel::Pareto { scale_ms: num(1)?, shape: num(2)? },
            "expfail" => DelayModel::ExpWithFailures { mean_ms: num(1)?, p_fail: num(2)? },
            other => anyhow::bail!("unknown delay model {other:?}"),
        })
    }
}

/// How the per-round compute time entering the clock is obtained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockMode {
    /// Deterministic flop-cost model (reproducible).
    Virtual,
    /// Wall-clock measurement of the engine call.
    Measured,
}

/// Leader gather policy. `FirstK` is the paper's scheme; `WaitAll`
/// (k = m) is the "perfect"/batch baseline in Figure 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatherPolicy {
    FirstK(usize),
    WaitAll,
}

impl GatherPolicy {
    pub fn k(&self, m: usize) -> usize {
        match self {
            GatherPolicy::FirstK(k) => (*k).min(m),
            GatherPolicy::WaitAll => m,
        }
    }
}

/// Cluster configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker count m (must match the encoded problem's shard count).
    pub workers: usize,
    /// k — responses the leader waits for per round.
    pub wait_for: usize,
    pub delay: DelayModel,
    pub clock: ClockMode,
    /// Virtual-clock compute cost in ms per million multiply-adds.
    pub ms_per_mflop: f64,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: 8,
            wait_for: 8,
            delay: DelayModel::Exp { mean_ms: 10.0 },
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5, // ~2 GFLOP/s per worker — m1.small-ish
            seed: 0,
        }
    }
}

/// Outcome of one synchronous round.
#[derive(Clone, Debug)]
pub struct Round {
    /// Admitted workers `A_t` in arrival order (`|A_t| = k` unless
    /// failures left fewer responders).
    pub admitted: Vec<usize>,
    /// All finite arrivals `(worker, arrival_ms)`, sorted.
    pub arrivals: Vec<(usize, f64)>,
    /// Simulated round duration: the k-th arrival time.
    pub elapsed_ms: f64,
    /// Workers that never responded (failures).
    pub failed: Vec<usize>,
}

/// Per-round gradient responses from the admitted set, arrival-ordered.
pub type GradResponses = Vec<(usize, Vec<f64>, f64)>;
/// Per-round line-search responses from the admitted set.
pub type CurvResponses = Vec<(usize, f64)>;

/// The simulated cluster: an engine plus the straggler/round machinery.
pub struct Cluster {
    cfg: ClusterConfig,
    engine: Box<dyn ComputeEngine>,
    rng: Pcg64,
    /// Flop cost per worker per gradient round (for the virtual clock).
    grad_mflops: Vec<f64>,
    ls_mflops: Vec<f64>,
    /// Accumulated simulated time.
    pub sim_ms: f64,
    pub rounds_run: u64,
}

impl Cluster {
    /// Build over an encoded problem with the given engine.
    pub fn new(
        prob: &EncodedProblem,
        engine: Box<dyn ComputeEngine>,
        cfg: ClusterConfig,
    ) -> Result<Self> {
        ensure!(
            cfg.workers == prob.m(),
            "config workers {} != problem shards {}",
            cfg.workers,
            prob.m()
        );
        ensure!(
            cfg.wait_for >= 1 && cfg.wait_for <= cfg.workers,
            "wait_for must be in 1..=workers"
        );
        ensure!(
            engine.workers() == prob.m(),
            "engine workers {} != problem shards {}",
            engine.workers(),
            prob.m()
        );
        let grad_mflops = prob
            .shards
            .iter()
            .map(|s| 2.0 * s.x.rows() as f64 * s.x.cols() as f64 * 2.0 / 1e6)
            .collect();
        let ls_mflops = prob
            .shards
            .iter()
            .map(|s| 2.0 * s.x.rows() as f64 * s.x.cols() as f64 / 1e6)
            .collect();
        let rng = Pcg64::new(cfg.seed, 0xc105);
        Ok(Cluster {
            cfg,
            engine,
            rng,
            grad_mflops,
            ls_mflops,
            sim_ms: 0.0,
            rounds_run: 0,
        })
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Override k between runs (η sweeps reuse the staged cluster).
    pub fn set_wait_for(&mut self, k: usize) {
        assert!(k >= 1 && k <= self.cfg.workers);
        self.cfg.wait_for = k;
    }

    /// Sample one round's arrival schedule and admit the first k.
    fn gather(&mut self, compute_ms: &[f64]) -> Round {
        let m = self.cfg.workers;
        let mut arrivals: Vec<(usize, f64)> = Vec::with_capacity(m);
        let mut failed = Vec::new();
        for i in 0..m {
            let delay = self.cfg.delay.sample(&mut self.rng, i);
            if delay.is_finite() {
                arrivals.push((i, compute_ms[i] + delay));
            } else {
                failed.push(i);
            }
        }
        arrivals.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let k = self.cfg.wait_for.min(arrivals.len());
        let admitted: Vec<usize> = arrivals[..k].iter().map(|&(w, _)| w).collect();
        let elapsed_ms = arrivals.get(k.saturating_sub(1)).map(|&(_, t)| t).unwrap_or(0.0);
        Round { admitted, arrivals, elapsed_ms, failed }
    }

    fn compute_times(&mut self, mflops: &[f64], measured_ms: Option<f64>) -> Vec<f64> {
        match self.cfg.clock {
            ClockMode::Virtual => mflops.iter().map(|f| f * self.cfg.ms_per_mflop).collect(),
            ClockMode::Measured => {
                // All workers computed inside one engine batch; attribute the
                // mean per-worker share to each (the engine parallelizes).
                let per = measured_ms.unwrap_or(0.0) / self.cfg.workers.max(1) as f64;
                vec![per; self.cfg.workers]
            }
        }
    }

    /// One gradient round: broadcast `w`, all workers compute
    /// `(g_i, f_i)`, leader admits first k. Returns the admitted responses
    /// (arrival order) and the round record; advances the simulated clock.
    pub fn grad_round(&mut self, w: &[f64]) -> Result<(GradResponses, Round)> {
        let t0 = std::time::Instant::now();
        let all = self.engine.worker_grad_all(w)?;
        let measured = t0.elapsed().as_secs_f64() * 1e3;
        let compute = self.compute_times(&self.grad_mflops.clone(), Some(measured));
        let round = self.gather(&compute);
        let responses: GradResponses = round
            .admitted
            .iter()
            .map(|&i| {
                let (g, f) = all[i].clone();
                (i, g, f)
            })
            .collect();
        self.sim_ms += round.elapsed_ms;
        self.rounds_run += 1;
        Ok((responses, round))
    }

    /// One line-search round over a fresh first-k set `D_t` (eq. (3)).
    pub fn linesearch_round(&mut self, d: &[f64]) -> Result<(CurvResponses, Round)> {
        let t0 = std::time::Instant::now();
        let all = self.engine.linesearch_all(d)?;
        let measured = t0.elapsed().as_secs_f64() * 1e3;
        let compute = self.compute_times(&self.ls_mflops.clone(), Some(measured));
        let round = self.gather(&compute);
        let responses: CurvResponses =
            round.admitted.iter().map(|&i| (i, all[i])).collect();
        self.sim_ms += round.elapsed_ms;
        self.rounds_run += 1;
        Ok((responses, round))
    }

    /// Engine name (metrics/labels).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::EncoderKind;
    use crate::problem::QuadProblem;
    use crate::runtime::NativeEngine;

    fn cluster(k: usize, delay: DelayModel, seed: u64) -> (EncodedProblem, Cluster) {
        let prob = QuadProblem::synthetic_gaussian(64, 6, 0.0, 1);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Hadamard, 2.0, 8, 2).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig {
            workers: 8,
            wait_for: k,
            delay,
            clock: ClockMode::Virtual,
            ms_per_mflop: 0.5,
            seed,
        };
        let c = Cluster::new(&enc, eng, cfg).unwrap();
        (enc, c)
    }

    #[test]
    fn first_k_gather_admits_exactly_k() {
        let (_, mut c) = cluster(5, DelayModel::Exp { mean_ms: 10.0 }, 3);
        let w = vec![0.1; 6];
        for _ in 0..10 {
            let (responses, round) = c.grad_round(&w).unwrap();
            assert_eq!(round.admitted.len(), 5);
            assert_eq!(responses.len(), 5);
            // admitted are the k smallest arrivals
            let kth = round.arrivals[4].1;
            for &(_, t) in &round.arrivals[5..] {
                assert!(t >= kth);
            }
            assert_eq!(round.elapsed_ms, kth);
        }
        assert_eq!(c.rounds_run, 10);
        assert!(c.sim_ms > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = vec![0.2; 6];
        let (_, mut c1) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        let (_, mut c2) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 7);
        for _ in 0..5 {
            let (r1, round1) = c1.grad_round(&w).unwrap();
            let (r2, round2) = c2.grad_round(&w).unwrap();
            assert_eq!(round1.admitted, round2.admitted);
            assert_eq!(round1.elapsed_ms, round2.elapsed_ms);
            for (a, b) in r1.iter().zip(&r2) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.2, b.2);
            }
        }
    }

    #[test]
    fn different_seeds_give_different_straggler_sets() {
        let w = vec![0.2; 6];
        let (_, mut c1) = cluster(3, DelayModel::Exp { mean_ms: 10.0 }, 1);
        let (_, mut c2) = cluster(3, DelayModel::Exp { mean_ms: 10.0 }, 2);
        let mut any_diff = false;
        for _ in 0..10 {
            let (_, round1) = c1.grad_round(&w).unwrap();
            let (_, round2) = c2.grad_round(&w).unwrap();
            if round1.admitted != round2.admitted {
                any_diff = true;
            }
        }
        assert!(any_diff);
    }

    #[test]
    fn no_delay_means_zero_wait_spread() {
        let (_, mut c) = cluster(8, DelayModel::None, 0);
        let (_, round) = c.grad_round(&vec![0.0; 6]).unwrap();
        // all arrivals equal compute time; k = m admits everyone
        assert_eq!(round.admitted.len(), 8);
        assert!(round.failed.is_empty());
    }

    #[test]
    fn failures_shrink_admitted_set() {
        let (_, mut c) = cluster(8, DelayModel::ExpWithFailures { mean_ms: 1.0, p_fail: 0.5 }, 5);
        let mut saw_failure = false;
        for _ in 0..20 {
            let (responses, round) = c.grad_round(&vec![0.0; 6]).unwrap();
            assert_eq!(responses.len(), round.admitted.len());
            assert!(round.admitted.len() + round.failed.len() <= 8);
            if !round.failed.is_empty() {
                saw_failure = true;
                assert!(round.admitted.len() < 8);
            }
        }
        assert!(saw_failure);
    }

    #[test]
    fn smaller_k_gives_smaller_round_time() {
        // E[k-th order statistic] grows with k — the Fig. 4-right effect
        let w = vec![0.1; 6];
        let mut t_small = 0.0;
        let mut t_large = 0.0;
        let (_, mut c_small) = cluster(2, DelayModel::Exp { mean_ms: 10.0 }, 11);
        let (_, mut c_large) = cluster(8, DelayModel::Exp { mean_ms: 10.0 }, 11);
        for _ in 0..50 {
            t_small += c_small.grad_round(&w).unwrap().1.elapsed_ms;
            t_large += c_large.grad_round(&w).unwrap().1.elapsed_ms;
        }
        assert!(
            t_small < t_large * 0.8,
            "k=2 time {t_small:.1} not well below k=8 time {t_large:.1}"
        );
    }

    #[test]
    fn linesearch_round_uses_fresh_subset() {
        let (_, mut c) = cluster(4, DelayModel::Exp { mean_ms: 10.0 }, 13);
        let w = vec![0.1; 6];
        let d = vec![-0.1; 6];
        let (_, ra) = c.grad_round(&w).unwrap();
        let (_, rd) = c.linesearch_round(&d).unwrap();
        assert_eq!(ra.admitted.len(), 4);
        assert_eq!(rd.admitted.len(), 4);
        // not guaranteed different, but the rng must have advanced
        assert_eq!(c.rounds_run, 2);
    }

    #[test]
    fn delay_model_parsing() {
        assert_eq!(DelayModel::parse("none").unwrap(), DelayModel::None);
        assert_eq!(DelayModel::parse("exp:10").unwrap(), DelayModel::Exp { mean_ms: 10.0 });
        assert_eq!(
            DelayModel::parse("shifted:5:10").unwrap(),
            DelayModel::ShiftedExp { shift_ms: 5.0, mean_ms: 10.0 }
        );
        assert_eq!(
            DelayModel::parse("expfail:10:0.05").unwrap(),
            DelayModel::ExpWithFailures { mean_ms: 10.0, p_fail: 0.05 }
        );
        assert!(DelayModel::parse("bogus:1").is_err());
        assert!(DelayModel::parse("exp").is_err());
    }

    #[test]
    fn rejects_mismatched_config() {
        let prob = QuadProblem::synthetic_gaussian(32, 4, 0.0, 0);
        let enc = EncodedProblem::encode(&prob, EncoderKind::Identity, 1.0, 4, 0).unwrap();
        let eng = Box::new(NativeEngine::new(&enc));
        let cfg = ClusterConfig { workers: 8, wait_for: 4, ..Default::default() };
        assert!(Cluster::new(&enc, eng, cfg).is_err());
    }
}
