fn main() { codedopt::cli::main_entry(); }
