//! Run instrumentation: per-iteration traces, summary statistics, CSV
//! emission for the figure-regeneration benches.

use std::fmt::Write as _;

/// One optimizer iteration's record.
#[derive(Clone, Debug)]
pub struct IterRecord {
    /// Iteration index (0-based).
    pub iter: usize,
    /// True objective f(w) on the *raw* problem (what the paper plots).
    pub f_true: f64,
    /// Leader-side encoded objective estimate.
    pub f_est: f64,
    /// Norm of the aggregated gradient estimate.
    pub grad_norm: f64,
    /// Step size taken.
    pub alpha: f64,
    /// |A_t| actually admitted.
    pub responders: usize,
    /// Simulated cluster time at the *end* of this iteration (ms).
    pub sim_ms: f64,
    /// Mean per-worker compute time over the admitted set for this
    /// iteration's gradient round (ms) — [`Round::admitted_compute_ms`]
    /// (the flop-model cost under the virtual clock, measured wall-clock
    /// under the measured clock).
    ///
    /// [`Round::admitted_compute_ms`]: crate::cluster::Round::admitted_compute_ms
    pub compute_ms: f64,
    /// Scenario events that fired on this iteration's gradient round
    /// ([`Round::events`] labels joined with `|`; empty when no scenario
    /// is attached or the round was quiet) — the event-annotated trace.
    ///
    /// [`Round::events`]: crate::cluster::Round::events
    pub events: String,
    /// Shard migrations the rebalancer executed on this iteration's
    /// rounds ([`Round::migrations`] labels joined with `|`; empty with
    /// `--rebalance off` or when the trigger stayed quiet). Shares the
    /// CSV `events` cell so the 9-column header is unchanged.
    ///
    /// [`Round::migrations`]: crate::cluster::Round::migrations
    pub migrations: String,
}

/// Full run trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Per-iteration records, in order.
    pub records: Vec<IterRecord>,
}

impl Trace {
    /// Append one iteration's record.
    pub fn push(&mut self, rec: IterRecord) {
        self.records.push(rec);
    }

    /// Number of recorded iterations.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True if nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Final true objective (NaN on an empty trace).
    pub fn last_objective(&self) -> f64 {
        self.records.last().map(|r| r.f_true).unwrap_or(f64::NAN)
    }

    /// Best (minimum) true objective over the run.
    pub fn best_objective(&self) -> f64 {
        self.records.iter().map(|r| r.f_true).fold(f64::INFINITY, f64::min)
    }

    /// Total simulated time at the end of the run (ms).
    pub fn total_sim_ms(&self) -> f64 {
        self.records.last().map(|r| r.sim_ms).unwrap_or(0.0)
    }

    /// Objective-vs-time series (the Figure 4-left axes).
    pub fn objective_series(&self) -> Vec<(f64, f64)> {
        self.records.iter().map(|r| (r.sim_ms, r.f_true)).collect()
    }

    /// True iff the objective sequence is (numerically) diverging —
    /// used to report the uncoded scheme's failure mode in Fig. 4.
    pub fn diverged(&self) -> bool {
        match (self.records.first(), self.records.last()) {
            (Some(a), Some(b)) => !b.f_true.is_finite() || b.f_true > 10.0 * a.f_true.max(1e-12),
            _ => false,
        }
    }

    /// CSV with header; columns match [`IterRecord`]. The `events` column
    /// holds the `|`-joined fault-event labels (never commas, so the CSV
    /// stays unquoted); migration labels are merged into the same cell
    /// after the events, so a migration-free trace is byte-identical to
    /// the pre-rebalancer format.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "iter,f_true,f_est,grad_norm,alpha,responders,sim_ms,compute_ms,events\n",
        );
        for r in &self.records {
            let cell = match (r.events.is_empty(), r.migrations.is_empty()) {
                (_, true) => r.events.clone(),
                (true, false) => r.migrations.clone(),
                (false, false) => format!("{}|{}", r.events, r.migrations),
            };
            let _ = writeln!(
                s,
                "{},{:.10e},{:.10e},{:.6e},{:.6e},{},{:.4},{:.4},{}",
                r.iter,
                r.f_true,
                r.f_est,
                r.grad_norm,
                r.alpha,
                r.responders,
                r.sim_ms,
                r.compute_ms,
                cell
            );
        }
        s
    }
}

/// Streaming mean/min/max/std accumulator for bench summaries.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty accumulator.
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observation count.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (0 below two observations).
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Wall-clock stopwatch (bench harness helper).
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    /// Elapsed milliseconds since `start`.
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, f: f64, t: f64) -> IterRecord {
        IterRecord {
            iter,
            f_true: f,
            f_est: f,
            grad_norm: 0.0,
            alpha: 0.1,
            responders: 4,
            sim_ms: t,
            compute_ms: 1.5,
            events: String::new(),
            migrations: String::new(),
        }
    }

    #[test]
    fn trace_accessors() {
        let mut t = Trace::default();
        t.push(rec(0, 10.0, 5.0));
        t.push(rec(1, 3.0, 11.0));
        t.push(rec(2, 4.0, 18.0));
        assert_eq!(t.len(), 3);
        assert_eq!(t.last_objective(), 4.0);
        assert_eq!(t.best_objective(), 3.0);
        assert_eq!(t.total_sim_ms(), 18.0);
        assert!(!t.diverged());
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("iter,"));
    }

    #[test]
    fn csv_carries_the_events_column() {
        let mut t = Trace::default();
        t.push(rec(0, 1.0, 1.0));
        let mut annotated = rec(1, 0.9, 2.0);
        annotated.events = "crash:3@1|slow:0:4@1".to_string();
        t.push(annotated);
        let mut migrated = rec(2, 0.8, 3.0);
        migrated.migrations = "migrate:2>0:8".to_string();
        t.push(migrated);
        let mut both = rec(3, 0.7, 4.0);
        both.events = "rack:0-2:4@3".to_string();
        both.migrations = "migrate:1>3:4".to_string();
        t.push(both);
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].ends_with(",events"));
        assert!(lines[1].ends_with(','), "quiet round has an empty events cell");
        assert!(lines[2].ends_with(",crash:3@1|slow:0:4@1"));
        // migrations share the events cell: alone, and after the events
        assert!(lines[3].ends_with(",migrate:2>0:8"));
        assert!(lines[4].ends_with(",rack:0-2:4@3|migrate:1>3:4"));
        // one comma-delimited cell per header column, every row
        let cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), cols, "ragged row {line:?}");
        }
    }

    #[test]
    fn divergence_detection() {
        let mut t = Trace::default();
        t.push(rec(0, 1.0, 1.0));
        t.push(rec(1, 1e6, 2.0));
        assert!(t.diverged());
        let mut t2 = Trace::default();
        t2.push(rec(0, 1.0, 1.0));
        t2.push(rec(1, f64::NAN, 2.0));
        assert!(t2.diverged());
    }

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }
}
