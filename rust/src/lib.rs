//! # codedopt — straggler mitigation in distributed optimization through data encoding
//!
//! A full-system reproduction of Karakus, Sun, Yin, Diggavi (NIPS 2017).
//!
//! The library implements the paper's *encoded distributed optimization*
//! framework as a three-layer stack:
//!
//! * **L3 (this crate)** — the coordination system: a leader/worker
//!   gradient-aggregation runtime with **first-k-of-m gather** ([`cluster`]),
//!   the coding-oblivious batch algorithms (gradient descent and
//!   overlap-L-BFGS with exact line search, [`optim`]), the encoding-matrix
//!   library (ETFs, fast transforms, random matrices, [`encoding`]), the
//!   encoded-problem assembly ([`problem`]), and the MovieLens-style
//!   matrix-factorization application ([`mf`]).
//! * **L2/L1 (python/, build-time only)** — the per-worker compute graph
//!   (JAX) and its fused Pallas kernels, AOT-lowered to HLO text artifacts
//!   that [`runtime::XlaEngine`] loads and executes through PJRT. Python
//!   never runs on the request path.
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for the
//! reproduced figures/tables.

#![warn(missing_docs)]

pub mod cli;
pub mod cluster;
pub mod config;
pub mod encoding;
pub mod linalg;
pub mod metrics;
pub mod mf;
pub mod optim;
pub mod problem;
pub mod rng;
pub mod runtime;
pub mod testutil;

/// Convenience re-exports for the common experiment-driving surface.
pub mod prelude {
    pub use crate::cluster::{
        AdmitPolicy, ClockMode, Cluster, ClusterConfig, DelayModel, FaultEvent, GatherPolicy,
        Round, Scenario, ScenarioState,
    };
    pub use crate::config::{Config, Json};
    pub use crate::encoding::{Encoder, EncoderKind};
    pub use crate::linalg::{CsrMat, DataMat, Mat, StorageKind};
    pub use crate::optim::{
        CodedFista, CodedGd, CodedLbfgs, CodedSgd, FistaConfig, GdConfig, JobStep, LbfgsConfig,
        LrSchedule, Optimizer, Prox, RunOutput, SgdConfig, SteppedOptimizer, Trace,
    };
    pub use crate::problem::{BatchPlan, EncodedProblem, QuadProblem, Scheme};
    pub use crate::runtime::{
        build_engine, build_engine_with, ComputeEngine, CurvCollector, EncodedShardCache,
        EngineKind, EngineSession, GradCollector, JobServer, JobSpec, NativeEngine, ServeOptimizer,
        ServePolicy, WorkerPool, XlaEngine,
    };
}
