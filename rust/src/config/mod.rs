//! Experiment configuration: a small typed layer over key=value files and
//! CLI-style overrides (serde/clap are unavailable in the offline build).
//!
//! Format: one `key = value` per line, `#` comments, sections ignored
//! (`[section]` headers allowed for readability). Values: int, float,
//! bool, string. Every experiment binary accepts `--config <file>` plus
//! `key=value` overrides; see `examples/` and `rust/benches/`.

pub mod json;

pub use json::Json;

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A flat, ordered key→value config map with typed getters.
#[derive(Debug, Clone, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    /// Empty config.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse the key=value format (see module docs).
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value, got {raw:?}", lineno + 1);
            };
            map.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(Config { map })
    }

    /// Load and parse a config file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        Self::parse(&text)
    }

    /// Apply `key=value` override strings (CLI tail arguments).
    pub fn apply_overrides<'a>(&mut self, overrides: impl IntoIterator<Item = &'a str>) -> Result<()> {
        for o in overrides {
            let Some((k, v)) = o.split_once('=') else {
                bail!("override {o:?}: expected key=value");
            };
            self.set(k.trim(), v.trim());
        }
        Ok(())
    }

    /// Set (or overwrite) one key.
    pub fn set(&mut self, key: &str, value: &str) {
        self.map.insert(key.to_string(), value.to_string());
    }

    /// Raw string value for `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    /// `key` as usize, or `default` when absent; errors on non-integers.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not an integer")),
        }
    }

    /// `key` as f64, or `default` when absent; errors on non-numbers.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not a number")),
        }
    }

    /// `key` as u64, or `default` when absent; errors on non-integers.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not an integer")),
        }
    }

    /// `key` as bool (`true/1/yes` vs `false/0/no`), or `default`.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.map.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config {key}={v}: not a bool"),
        }
    }

    /// `key` as a string, or `default` when absent.
    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.map.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// All keys, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_typed_getters() {
        let cfg = Config::parse(
            "# experiment\n[cluster]\nworkers = 32\nwait_for=12\nbeta = 2.0\nencoder = \"hadamard\"\nvirtual = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get_usize("workers", 0).unwrap(), 32);
        assert_eq!(cfg.get_usize("wait_for", 0).unwrap(), 12);
        assert_eq!(cfg.get_f64("beta", 0.0).unwrap(), 2.0);
        assert_eq!(cfg.get_str("encoder", ""), "hadamard");
        assert!(cfg.get_bool("virtual", false).unwrap());
        assert_eq!(cfg.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn overrides_win() {
        let mut cfg = Config::parse("a = 1\nb = 2\n").unwrap();
        cfg.apply_overrides(["a=10", "c=3"]).unwrap();
        assert_eq!(cfg.get_usize("a", 0).unwrap(), 10);
        assert_eq!(cfg.get_usize("b", 0).unwrap(), 2);
        assert_eq!(cfg.get_usize("c", 0).unwrap(), 3);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("key without equals\n").is_err());
        let cfg = Config::parse("x = abc\n").unwrap();
        assert!(cfg.get_usize("x", 0).is_err());
    }
}
