//! Minimal JSON parser (objects, arrays, strings, numbers, bools, null).
//!
//! The offline build environment has no serde; this covers the two JSON
//! surfaces the system needs — the AOT `artifacts/manifest.json` and
//! experiment config files. Strict enough to reject malformed input with
//! a position-annotated error; no extensions (no comments, no trailing
//! commas).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (key-sorted).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing characters).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -------- typed accessors (None on type mismatch / missing key) --------

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a nonnegative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected character {:?} at byte {}", c as char, self.i),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let text = std::str::from_utf8(&self.b[start..])?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "format": "hlo-text-v1",
            "entries": [
                {"name": "worker_grad_r8_p4", "kind": "worker_grad", "rows": 8, "p": 4, "file": "a.hlo.txt"},
                {"name": "fwht_n64_c8", "kind": "fwht", "n": 64, "cols": 8, "file": "b.hlo.txt"}
            ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text-v1");
        let entries = v.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].get("rows").unwrap().as_usize().unwrap(), 8);
        assert_eq!(entries[1].get("n").unwrap().as_usize().unwrap(), 64);
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64().unwrap(), -150.0);
        assert!(Json::parse("true").unwrap().as_bool().unwrap());
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap().as_str().unwrap(), "a\nb");
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[1, [2, 3], []]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_arr().unwrap().len(), 2);
        assert_eq!(a[2].as_arr().unwrap().len(), 0);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str().unwrap(), "A");
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}
