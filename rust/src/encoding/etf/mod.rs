//! Equiangular tight frames (§4 "Tight frames", Appendix D).
//!
//! An ETF meets the Welch bound (Prop. 1): its `βn` unit-norm rows have the
//! minimum possible pairwise coherence, making every row-submatrix
//! `S_AᵀS_A` as close to (a multiple of) the identity as a frame can — the
//! paper's numerical evidence (Figs. 2–3) shows ETFs satisfy property (4)
//! with smaller ε than Gaussian at equal β.
//!
//! The Paley and Hadamard ETFs are built from their *signature/Gram*
//! matrices: a symmetric conference (or Hadamard) matrix `C` with
//! `C² = qI` gives a projection `G = (I + C/√q)/2` of rank `n/2` whose
//! entries have constant off-diagonal magnitude. A pivoted Cholesky
//! `G = L Lᵀ` yields the frame vectors as rows of `L` (for a projection,
//! `LᵀL = I` automatically, so `S = √2·L` satisfies `SᵀS = 2I`: a tight
//! frame with β = 2). Target dimensions that don't match a construction
//! size are handled the way the paper does (§5): build the next larger
//! bank matrix and subsample its columns — a column subset of a tight
//! frame matrix is still tight (`S_JᵀS_J` is a principal submatrix of
//! `βI`).

pub mod hadamard_etf;
pub mod paley;
pub mod steiner;

use crate::linalg::{pivoted_cholesky, Mat};
use crate::rng::Pcg64;

/// Factor a projection-Gram signature matrix into a tight-frame encoding
/// matrix and subsample to `n` columns: returns `(S, c)` with `S` of shape
/// `(g.rows()) × n`, unit-norm rows (before subsampling), and
/// `SᵀS = c·I_n` where `c = 1/G_ii` (2 for the classical constant-1/2
/// diagonal; `2√N/(√N ± 1)` for the regular-Hadamard two-graph Grams).
///
/// `g` must be a projection (G² = G) with constant diagonal and rank ≥ n;
/// `seed` drives the column subsampling.
pub(crate) fn frame_from_projection_gram(g: &Mat, n: usize, seed: u64) -> (Mat, f64) {
    let dim = g.rows();
    let gd: f64 = (0..dim).map(|i| g.get(i, i)).sum::<f64>() / dim as f64;
    assert!(gd > 0.0, "projection Gram must have positive diagonal");
    let c = 1.0 / gd;
    let l = pivoted_cholesky(g, 1e-9);
    let d = l.cols();
    assert!(
        d >= n,
        "ETF construction rank {d} smaller than requested dimension {n}"
    );
    let s_full = l.scaled(c.sqrt());
    if d == n {
        return (s_full, c);
    }
    let mut rng = Pcg64::new(seed, 0xe7f);
    let mut cols = rng.sample_indices(d, n);
    cols.sort_unstable();
    (s_full.select_cols(&cols), c)
}

/// Coherence `max_{i≠j} |⟨φ_i, φ_j⟩| / (||φ_i|| ||φ_j||)` of the rows of S.
/// (Test/diagnostic helper: ETFs meet the Welch bound here.)
pub fn row_coherence(s: &Mat) -> f64 {
    let m = s.rows();
    let mut max_c: f64 = 0.0;
    let norms: Vec<f64> = (0..m).map(|i| crate::linalg::norm2(s.row(i))).collect();
    for i in 0..m {
        for j in 0..i {
            let c = crate::linalg::dot(s.row(i), s.row(j)).abs() / (norms[i] * norms[j]);
            max_c = max_c.max(c);
        }
    }
    max_c
}

/// Welch lower bound on coherence for `m` unit vectors in dimension `d`.
pub fn welch_bound(m: usize, d: usize) -> f64 {
    (((m - d) as f64) / ((d * (m - 1)) as f64)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welch_bound_matches_paper_form() {
        // Prop. 1: for a tight frame of n*beta vectors in R^n,
        // omega >= sqrt((beta-1)/(2*n*beta-1))... with m = beta*n, d = n:
        // sqrt((m-d)/(d(m-1))) = sqrt(n(beta-1) / (n(n*beta-1))).
        let (n, beta) = (10usize, 2usize);
        let m = n * beta;
        let got = welch_bound(m, n);
        let expect = (((beta - 1) * n) as f64 / ((n * (m - 1)) as f64)).sqrt();
        assert!((got - expect).abs() < 1e-12);
    }

    #[test]
    fn projection_factor_is_tight() {
        // projection onto a random 6-dim subspace of R^12
        let mut rng = crate::rng::Pcg64::seeded(1);
        let b = Mat::from_fn(12, 12, |_, _| rng.next_gaussian());
        let (_, v) = crate::linalg::sym_eigen(&b.add(&b.transpose()));
        let v1 = v.select_cols(&[0, 1, 2, 3, 4, 5]);
        let g = v1.matmul(&v1.transpose());
        let (s, c) = frame_from_projection_gram(&g, 6, 0);
        assert!(s.gram().max_abs_diff(&Mat::eye(6).scaled(c)) < 1e-7);
        // subsampled: still tight at the same scale
        let (s4, c4) = frame_from_projection_gram(&g, 4, 0);
        assert!((c - c4).abs() < 1e-12);
        assert!(s4.gram().max_abs_diff(&Mat::eye(4).scaled(c)) < 1e-7);
    }
}
