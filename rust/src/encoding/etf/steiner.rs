//! Steiner ETF — Appendix D construction (Fickus–Mixon–Tremain 2012).
//!
//! `v` a power of two; `V ∈ {0,1}^{v × v(v−1)/2}` the incidence matrix of
//! all 2-element subsets of `{1..v}` (each column has exactly two 1s, each
//! row exactly `v−1`). Each `1` in row `i` is replaced by a distinct
//! non-constant column of the Hadamard matrix `H_v` and the result scaled
//! by `1/√(v−1)`, giving `S ∈ R^{v² × v(v−1)/2}` with unit-norm rows,
//! redundancy `β = 2v/(v−1)`, and — because distinct Hadamard columns are
//! orthogonal within each block-row — `SᵀS = β·I` exactly (tight).
//!
//! Two fast paths from the appendix are implemented:
//!  * **block-local FWHT encode**: block `i`'s slab of `S·X` equals the
//!    `v`-point FWHT of a `v × p` buffer holding the rows of `X` indexed
//!    by row-`i`'s support, placed at their assigned Hadamard-column
//!    positions (`O(v² log v · p / v)` total instead of dense `O(v³ p)`).
//!  * **post-encode row shuffle**: the appendix notes performance improves
//!    markedly when rows of `SX` are shuffled so stragglers don't knock
//!    out structured row groups; we shuffle with the encoder's seed.

use crate::encoding::Encoder;
use crate::linalg::fwht::fwht_columns;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use anyhow::{ensure, Result};

/// Steiner ETF encoder (Appendix D), β = 2v/(v−1) ≈ 2.
pub struct SteinerEtfEncoder {
    n: usize,
    v: usize,
    /// support[i] = the (v−1) input-row indices with V[i, col] = 1, in the
    /// order their Hadamard columns h_2.. are assigned; entries ≥ n are
    /// padding (the appendix's "append zero rows" dimension fix).
    support: Vec<Vec<usize>>,
    /// post-encode row permutation (shuffle fix from the appendix)
    perm: Vec<usize>,
}

/// Column index of the 2-subset {a, b} (a < b) in colex/appendix order:
/// subsets are grouped by their smaller element, matching the B₁/B₂ index
/// sets of Appendix D.
fn pair_col(a: usize, b: usize, v: usize) -> usize {
    debug_assert!(a < b && b < v);
    // number of pairs with smaller element < a:  sum_{j<a} (v-1-j)
    a * (2 * v - 1 - a) / 2 + (b - a - 1)
}

impl SteinerEtfEncoder {
    /// Build the smallest Steiner-system ETF (Appendix D) covering `n`
    /// columns (`seed` drives the column subsample).
    pub fn new(n: usize, seed: u64) -> Result<Self> {
        ensure!(n >= 1, "Steiner ETF needs n >= 1");
        // smallest power-of-two v with v(v-1)/2 >= n
        let mut v = 2usize;
        while v * (v - 1) / 2 < n {
            v *= 2;
        }
        ensure!(v >= 2, "internal: bad v");
        // row i's support: all pairs containing i => columns pair_col(min,max)
        // Hadamard columns h_2..h_v assigned in ascending partner order.
        let support: Vec<Vec<usize>> = (0..v)
            .map(|i| {
                (0..v)
                    .filter(|&j| j != i)
                    .map(|j| pair_col(i.min(j), i.max(j), v))
                    .collect()
            })
            .collect();
        let mut rng = Pcg64::new(seed, 0x57e1);
        let perm = rng.permutation(v * v);
        Ok(SteinerEtfEncoder { n, v, support, perm })
    }

    /// Construction order `v` (block count and block height).
    pub fn v(&self) -> usize {
        self.v
    }
}

impl Encoder for SteinerEtfEncoder {
    fn name(&self) -> &'static str {
        "steiner"
    }

    fn rows_in(&self) -> usize {
        self.n
    }

    fn rows_out(&self) -> usize {
        self.v * self.v
    }

    fn encode(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        let (v, p) = (self.v, x.cols());
        let scale = 1.0 / ((v - 1) as f64).sqrt();
        let mut out = Mat::zeros(v * v, p);
        // block i: FWHT of a v×p buffer with x-rows at positions 1.. (h_2..h_v
        // are Hadamard columns 1..v-1 in Sylvester indexing; position 0 — the
        // all-ones column h_1 — stays empty, matching the appendix example).
        let mut buf = vec![0.0; v * p];
        for (i, sup) in self.support.iter().enumerate() {
            buf.fill(0.0);
            for (slot, &col_idx) in sup.iter().enumerate() {
                if col_idx < self.n {
                    buf[(slot + 1) * p..(slot + 2) * p].copy_from_slice(x.row(col_idx));
                }
            }
            fwht_columns(&mut buf, v, p);
            for r in 0..v {
                let dst = out.row_mut(self.perm[i * v + r]);
                for j in 0..p {
                    dst[j] = scale * buf[r * p + j];
                }
            }
        }
        out
    }

    fn materialize(&self) -> Mat {
        self.encode(&Mat::eye(self.n))
    }

    fn gram_scale(&self) -> f64 {
        // construction tightness: SᵀS = (2v/(v−1))·I, preserved under the
        // padding-column drop (principal submatrix of a scaled identity)
        2.0 * self.v as f64 / (self.v as f64 - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::etf::row_coherence;

    #[test]
    fn pair_col_enumerates_all_pairs() {
        let v = 8;
        let mut seen = vec![false; v * (v - 1) / 2];
        for a in 0..v {
            for b in a + 1..v {
                let c = pair_col(a, b, v);
                assert!(!seen[c], "duplicate column {c}");
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn full_size_tight_and_unit_rows() {
        // v = 4: n = 6 exactly, beta = 8/3
        let enc = SteinerEtfEncoder::new(6, 0).unwrap();
        assert_eq!(enc.v(), 4);
        assert_eq!(enc.rows_out(), 16);
        let s = enc.materialize();
        let beta = enc.beta(); // 16/6 = 8/3
        assert!(s.gram().max_abs_diff(&Mat::eye(6).scaled(beta)) < 1e-9);
        for i in 0..16 {
            assert!((crate::linalg::norm2(s.row(i)) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn equiangularity_full_size() {
        // all non-zero pairwise inner products have the same magnitude
        let enc = SteinerEtfEncoder::new(6, 0).unwrap();
        let s = enc.materialize();
        let m = s.rows();
        let mut mags = vec![];
        for i in 0..m {
            for j in 0..i {
                let ip = crate::linalg::dot(s.row(i), s.row(j)).abs();
                if ip > 1e-9 {
                    mags.push(ip);
                }
            }
        }
        let first = mags[0];
        assert!(mags.iter().all(|&x| (x - first).abs() < 1e-9),
            "Steiner ETF: non-constant angles");
        assert!(row_coherence(&s) > 0.0);
    }

    #[test]
    fn padded_dimension_still_tight() {
        // n = 5 < 6 = v(v-1)/2: one padding column dropped
        let enc = SteinerEtfEncoder::new(5, 1).unwrap();
        let s = enc.materialize();
        let beta_col = 2.0 * enc.v() as f64 / (enc.v() as f64 - 1.0);
        assert!(s.gram().max_abs_diff(&Mat::eye(5).scaled(beta_col)) < 1e-9);
    }

    #[test]
    fn shuffle_is_seeded_permutation() {
        let mut rng = Pcg64::seeded(5);
        let x = Mat::from_fn(6, 2, |_, _| rng.next_gaussian());
        let a = SteinerEtfEncoder::new(6, 3).unwrap().encode(&x);
        let b = SteinerEtfEncoder::new(6, 3).unwrap().encode(&x);
        assert!(a.max_abs_diff(&b) < 1e-15, "deterministic");
        let c = SteinerEtfEncoder::new(6, 4).unwrap().encode(&x);
        // same multiset of rows, different order
        assert!(a.max_abs_diff(&c) > 1e-9);
        let mut na: Vec<f64> = (0..a.rows()).map(|i| crate::linalg::norm2(a.row(i))).collect();
        let mut nc: Vec<f64> = (0..c.rows()).map(|i| crate::linalg::norm2(c.row(i))).collect();
        na.sort_by(|x, y| x.partial_cmp(y).unwrap());
        nc.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (u, w) in na.iter().zip(&nc) {
            assert!((u - w).abs() < 1e-9);
        }
    }

    #[test]
    fn larger_construction_scales() {
        // v = 8 => n up to 28, rows 64, beta = 16/7
        let enc = SteinerEtfEncoder::new(28, 0).unwrap();
        assert_eq!(enc.v(), 8);
        let s = enc.materialize();
        assert!(s.gram().max_abs_diff(&Mat::eye(28).scaled(enc.beta())) < 1e-9);
    }
}
