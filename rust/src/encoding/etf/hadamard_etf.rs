//! Hadamard ETF (§4; cf. Szöllősi 2013, Goethals–Seidel regular two-graphs).
//!
//! Real ETFs arise from **regular symmetric Hadamard matrices with constant
//! diagonal** (RSHCD). We build one by Kronecker powers of the order-4 seed
//! `A = J₄ − 2I` (symmetric Hadamard, constant diagonal −1, row sum 2):
//! `H = A^{⊗k}` has order `N = 4^k`, is symmetric with `H² = N·I` and
//! constant diagonal `d = (−1)^k`.
//!
//! The zero-diagonal signature `C = H − dI` satisfies
//! `C² = (N−1)I − 2dC`, so its eigenvalues are `−d ± √N` and
//!
//! `G = (C + (d + √N) I) / (2√N)`
//!
//! is a projection of rank `(N + d√N)/2` with constant diagonal
//! `(d+√N)/(2√N)` and constant off-diagonal magnitude `1/(2√N)` — an
//! equiangular Gram. Factoring it gives `N` unit-norm frame vectors in
//! `R^{(N+d√N)/2}`: an ETF with redundancy `β = 2√N/(√N+d) ≈ 2`.
//!
//! (Distinct from the *fast-transform* Hadamard encoder, which subsamples
//! a Sylvester matrix directly — the paper makes the same distinction.)
//!
//! Arbitrary `n`: smallest Kronecker power whose rank ≥ n, then
//! column-subsample (bank approach, §5) — tightness is preserved exactly.

use super::frame_from_projection_gram;
use crate::encoding::Encoder;
use crate::linalg::Mat;

/// Regular-Hadamard two-graph ETF encoder (β ≈ 2).
pub struct HadamardEtfEncoder {
    n: usize,
    s: Mat,
    gram_scale: f64,
}

/// RSHCD of order `4^k`: Kronecker power of `J₄ − 2I`.
/// Symmetric, entries ±1, `H² = N·I`, constant diagonal `(−1)^k`.
pub(crate) fn rshcd(k: u32) -> Mat {
    assert!(k >= 1, "need at least one Kronecker factor");
    let seed = Mat::from_fn(4, 4, |i, j| if i == j { -1.0 } else { 1.0 });
    let mut h = seed.clone();
    for _ in 1..k {
        h = kron(&h, &seed);
    }
    h
}

/// Kronecker product `a ⊗ b`.
pub(crate) fn kron(a: &Mat, b: &Mat) -> Mat {
    let (ar, ac, br, bc) = (a.rows(), a.cols(), b.rows(), b.cols());
    Mat::from_fn(ar * br, ac * bc, |i, j| {
        a.get(i / br, j / bc) * b.get(i % br, j % bc)
    })
}

/// Rank of the `+(−d+√N)`-eigenspace projection for order `N = 4^k`.
pub(crate) fn construction_rank(k: u32) -> usize {
    let n = 4usize.pow(k);
    let d = if k % 2 == 0 { 1i64 } else { -1i64 };
    ((n as i64 + d * (n as f64).sqrt() as i64) / 2) as usize
}

impl HadamardEtfEncoder {
    /// Build the smallest Sylvester-Hadamard projection ETF covering `n`
    /// columns (`seed` drives the column subsample).
    pub fn new(n: usize, seed: u64) -> Self {
        // smallest Kronecker power with rank >= n
        let mut k = 1u32;
        while construction_rank(k) < n {
            k += 1;
        }
        let h = rshcd(k);
        let big_n = h.rows();
        let d = if k % 2 == 0 { 1.0 } else { -1.0 };
        let sq = (big_n as f64).sqrt();
        // G = (C + (d + sqrt(N)) I)/(2 sqrt(N)),  C = H - dI
        let g = Mat::from_fn(big_n, big_n, |i, j| {
            if i == j {
                (d + sq) / (2.0 * sq)
            } else {
                h.get(i, j) / (2.0 * sq)
            }
        });
        let (s, gram_scale) = frame_from_projection_gram(&g, n, seed);
        HadamardEtfEncoder { n, s, gram_scale }
    }
}

impl Encoder for HadamardEtfEncoder {
    fn name(&self) -> &'static str {
        "hadamard-etf"
    }

    fn rows_in(&self) -> usize {
        self.n
    }

    fn rows_out(&self) -> usize {
        self.s.rows()
    }

    fn encode(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        self.s.matmul(x)
    }

    fn materialize(&self) -> Mat {
        self.s.clone()
    }

    fn gram_scale(&self) -> f64 {
        self.gram_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::etf::{row_coherence, welch_bound};

    #[test]
    fn rshcd_identities() {
        for k in 1..=3u32 {
            let h = rshcd(k);
            let n = h.rows();
            assert_eq!(n, 4usize.pow(k));
            assert!(h.max_abs_diff(&h.transpose()) < 1e-15, "symmetric");
            let d = if k % 2 == 0 { 1.0 } else { -1.0 };
            for i in 0..n {
                assert_eq!(h.get(i, i), d, "constant diagonal");
            }
            let hh = h.matmul(&h.transpose());
            assert!(hh.max_abs_diff(&Mat::eye(n).scaled(n as f64)) < 1e-9);
            // regular: constant row sum = ±2^k
            let rs: Vec<f64> = (0..n).map(|i| h.row(i).iter().sum()).collect();
            assert!(rs.iter().all(|&s| (s - rs[0]).abs() < 1e-12), "regular");
            assert!((rs[0].abs() - (n as f64).sqrt()).abs() < 1e-12);
        }
    }

    #[test]
    fn full_size_is_equiangular_tight() {
        // k=2: N=16, rank 10 — full ETF of 16 vectors in R^10
        let n = construction_rank(2); // 10
        let enc = HadamardEtfEncoder::new(n, 0);
        let s = enc.materialize();
        assert_eq!(s.rows(), 16);
        let c = enc.gram_scale(); // 2*4/(4+1) = 1.6
        assert!((c - 1.6).abs() < 1e-9);
        assert!(s.gram().max_abs_diff(&Mat::eye(n).scaled(c)) < 1e-7);
        for i in 0..16 {
            assert!((crate::linalg::norm2(s.row(i)) - 1.0).abs() < 1e-7);
        }
        let coh = row_coherence(&s);
        let wb = welch_bound(16, 10);
        assert!((coh - wb).abs() < 1e-6, "coherence {coh} vs welch {wb}");
    }

    #[test]
    fn subsampled_still_tight_at_construction_scale() {
        let enc = HadamardEtfEncoder::new(24, 1);
        let s = enc.materialize();
        assert_eq!(s.rows(), 64); // k=3: rank 28 >= 24
        let c = enc.gram_scale(); // 2*8/(8-1) = 16/7
        assert!((c - 16.0 / 7.0).abs() < 1e-9);
        assert!(s.gram().max_abs_diff(&Mat::eye(24).scaled(c)) < 1e-7);
        assert!(enc.beta() > 2.0);
    }

    #[test]
    fn construction_rank_values() {
        assert_eq!(construction_rank(1), 1);   // N=4,  d=-1: (4-2)/2
        assert_eq!(construction_rank(2), 10);  // N=16, d=+1: (16+4)/2
        assert_eq!(construction_rank(3), 28);  // N=64, d=-1: (64-8)/2
        assert_eq!(construction_rank(4), 136); // N=256,d=+1: (256+16)/2
    }
}
