//! Paley equiangular tight frame (§4; Paley 1933, Goethals–Seidel 1967).
//!
//! For a prime `q ≡ 1 (mod 4)` the Paley construction gives a symmetric
//! conference matrix `C` of order `q+1` (zero diagonal, ±1 off-diagonal,
//! `C Cᵀ = q I`) from the quadratic-residue character of GF(q). Its
//! `+√q`-eigenspace projection `G = (I + C/√q)/2` has rank `(q+1)/2` and
//! constant off-diagonal magnitude `1/(2√q)` — an equiangular Gram — so
//! the factored frame is a `(q+1)`-vector ETF in `R^{(q+1)/2}` with β = 2,
//! meeting the Welch bound.
//!
//! Arbitrary `n`: pick the smallest valid `q` with `(q+1)/2 ≥ n` and
//! column-subsample (the paper's bank-of-matrices approach, §5).

use super::frame_from_projection_gram;
use crate::encoding::Encoder;
use crate::linalg::Mat;
use anyhow::{ensure, Result};

/// Paley-conference-matrix ETF encoder (β ≈ 2).
pub struct PaleyEtfEncoder {
    n: usize,
    s: Mat,
    gram_scale: f64,
}

/// Deterministic Miller–Rabin for u64 (enough witnesses for < 3.3e24).
pub(crate) fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n % p == 0 {
            return n == p;
        }
    }
    let mut d = n - 1;
    let mut r = 0;
    while d % 2 == 0 {
        d /= 2;
        r += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = mod_pow(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..r - 1 {
            x = mod_mul(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

fn mod_mul(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

fn mod_pow(mut base: u64, mut exp: u64, m: u64) -> u64 {
    let mut acc = 1u64;
    base %= m;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mod_mul(acc, base, m);
        }
        base = mod_mul(base, base, m);
        exp >>= 1;
    }
    acc
}

/// Smallest prime `q ≡ 1 (mod 4)` with `q >= lo`.
pub(crate) fn next_paley_prime(lo: u64) -> u64 {
    let mut q = lo.max(5);
    // align to 1 mod 4
    q += (4 - (q % 4) + 1) % 4;
    while !is_prime(q) {
        q += 4;
    }
    q
}

/// Quadratic character χ(a) over GF(q): +1 residue, −1 non-residue, 0 at 0.
fn quadratic_character(a: u64, q: u64) -> f64 {
    if a % q == 0 {
        return 0.0;
    }
    let e = mod_pow(a % q, (q - 1) / 2, q);
    if e == 1 {
        1.0
    } else {
        -1.0
    }
}

/// Symmetric Paley conference matrix of order `q + 1` (q ≡ 1 mod 4 prime).
pub(crate) fn paley_conference(q: u64) -> Mat {
    let n = (q + 1) as usize;
    let mut c = Mat::zeros(n, n);
    // index 0 = ∞, indices 1..=q correspond to field elements 0..q-1
    for j in 1..n {
        c.set(0, j, 1.0);
        c.set(j, 0, 1.0);
    }
    for i in 1..n {
        for j in 1..n {
            if i != j {
                let diff = ((i as i64 - j as i64).rem_euclid(q as i64)) as u64;
                c.set(i, j, quadratic_character(diff, q));
            }
        }
    }
    c
}

impl PaleyEtfEncoder {
    /// Build the smallest Paley conference-matrix ETF covering `n`
    /// columns (`seed` drives the column subsample).
    pub fn new(n: usize, seed: u64) -> Result<Self> {
        ensure!(n >= 2, "Paley ETF needs n >= 2, got {n}");
        // need rank (q+1)/2 >= n  =>  q >= 2n - 1
        let q = next_paley_prime((2 * n - 1) as u64);
        let c = paley_conference(q);
        let sq = (q as f64).sqrt();
        let dim = c.rows();
        let g = Mat::from_fn(dim, dim, |i, j| {
            let base = if i == j { 1.0 } else { 0.0 };
            0.5 * (base + c.get(i, j) / sq)
        });
        let (s, gram_scale) = frame_from_projection_gram(&g, n, seed);
        Ok(PaleyEtfEncoder { n, s, gram_scale })
    }
}

impl Encoder for PaleyEtfEncoder {
    fn name(&self) -> &'static str {
        "paley"
    }

    fn rows_in(&self) -> usize {
        self.n
    }

    fn rows_out(&self) -> usize {
        self.s.rows()
    }

    fn encode(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        self.s.matmul(x)
    }

    fn materialize(&self) -> Mat {
        self.s.clone()
    }

    fn gram_scale(&self) -> f64 {
        self.gram_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::etf::{row_coherence, welch_bound};

    #[test]
    fn primality_helper() {
        assert!(is_prime(5) && is_prime(13) && is_prime(97) && is_prime(7919));
        assert!(!is_prime(1) && !is_prime(91) && !is_prime(100));
    }

    #[test]
    fn next_paley_prime_is_1_mod_4() {
        for lo in [5u64, 10, 50, 123, 1000] {
            let q = next_paley_prime(lo);
            assert!(q >= lo && q % 4 == 1 && is_prime(q));
        }
    }

    #[test]
    fn conference_matrix_identity() {
        // C C^T = q I, symmetric, zero diagonal
        for q in [5u64, 13, 17] {
            let c = paley_conference(q);
            let n = c.rows();
            assert!(c.max_abs_diff(&c.transpose()) < 1e-12, "symmetric");
            for i in 0..n {
                assert_eq!(c.get(i, i), 0.0);
            }
            let cct = c.matmul(&c.transpose());
            assert!(cct.max_abs_diff(&Mat::eye(n).scaled(q as f64)) < 1e-9, "q={q}");
        }
    }

    #[test]
    fn full_size_paley_is_equiangular_at_welch_bound() {
        // n = (q+1)/2 exactly: no subsampling, true ETF
        let q = 13u64;
        let n = ((q + 1) / 2) as usize; // 7
        let enc = PaleyEtfEncoder::new(n, 0).unwrap();
        let s = enc.materialize();
        assert_eq!(s.rows(), (q + 1) as usize);
        // tight
        assert!(s.gram().max_abs_diff(&Mat::eye(n).scaled(2.0)) < 1e-7);
        // rows unit norm
        for i in 0..s.rows() {
            assert!((crate::linalg::norm2(s.row(i)) - 1.0).abs() < 1e-7);
        }
        // coherence == Welch bound
        let coh = row_coherence(&s);
        let wb = welch_bound(s.rows(), n);
        assert!((coh - wb).abs() < 1e-6, "coherence {coh} vs welch {wb}");
    }

    #[test]
    fn subsampled_paley_still_tight() {
        let enc = PaleyEtfEncoder::new(20, 3).unwrap();
        let s = enc.materialize();
        assert!(s.gram().max_abs_diff(&Mat::eye(20).scaled(2.0)) < 1e-7);
        assert!(enc.beta() >= 2.0);
    }
}
