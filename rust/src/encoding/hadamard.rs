//! Randomized subsampled-Hadamard encoding via FWHT (§4 "Fast transforms").
//!
//! The paper: "insert rows of zeroes at random locations into the data pair
//! (X, y), and then take the FWHT of each column of the augmented matrix
//! — a randomized Hadamard ensemble, known to satisfy the RIP w.h.p."
//!
//! Concretely `S = (1/√n) · H_N · D · E`, where `N = 2^⌈log₂ βn⌉`, `E` is
//! an `N × n` selector placing the `n` data rows at uniformly random
//! distinct positions (the "zero rows" insertion), `D` a random ±1
//! diagonal (sign flips — free extra randomization), and `H_N` the
//! unnormalized Sylvester Hadamard. Then `SᵀS = (N/n)·I = β_eff I`
//! *exactly* — a tight frame — and the encode costs `O(N log N)` per
//! column instead of the dense `O(N·n)`.
//!
//! This is the encoder used for the ridge-regression experiment (Fig. 4,
//! "Hadamard (FWHT)-coded").

use super::Encoder;
use crate::linalg::fwht::fwht_columns;
use crate::linalg::{DataMat, Mat};
use crate::rng::Pcg64;

/// FWHT-based randomized Hadamard encoder.
pub struct HadamardEncoder {
    n: usize,
    n_out: usize,
    /// position[i] = row of the augmented matrix holding data row i
    positions: Vec<usize>,
    /// sign[i] = ±1 flip applied to data row i before the transform
    signs: Vec<f64>,
}

impl HadamardEncoder {
    /// Build for `n` input rows at target redundancy `beta`; the output
    /// row count is rounded up to the next power of two for the FWHT.
    pub fn new(n: usize, beta: f64, seed: u64) -> Self {
        let target = (beta * n as f64).round().max(n as f64) as usize;
        let n_out = target.next_power_of_two();
        let mut rng = Pcg64::new(seed, 0xfa57);
        let positions = rng.sample_indices(n_out, n);
        let signs = (0..n)
            .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect();
        HadamardEncoder { n, n_out, positions, signs }
    }
}

impl Encoder for HadamardEncoder {
    fn name(&self) -> &'static str {
        "hadamard"
    }

    fn rows_in(&self) -> usize {
        self.n
    }

    fn rows_out(&self) -> usize {
        self.n_out
    }

    fn encode(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        let c = x.cols();
        let mut buf = vec![0.0; self.n_out * c];
        for (i, (&pos, &sign)) in self.positions.iter().zip(&self.signs).enumerate() {
            let src = x.row(i);
            let dst = &mut buf[pos * c..(pos + 1) * c];
            for j in 0..c {
                dst[j] = sign * src[j];
            }
        }
        fwht_columns(&mut buf, self.n_out, c);
        let scale = 1.0 / (self.n as f64).sqrt();
        for v in &mut buf {
            *v *= scale;
        }
        Mat::from_vec(self.n_out, c, buf)
    }

    /// `S` is applied as an *operator*: sparse input rows scatter their
    /// stored entries (sign-flipped, at their random positions) directly
    /// into the FWHT buffer, so `S·A` never materializes a dense copy of
    /// `A` just to encode. The transform output is dense by nature — the
    /// randomized Hadamard ensemble mixes every row — so the result is
    /// always dense storage.
    fn encode_data(&self, x: &DataMat) -> DataMat {
        match x {
            DataMat::Dense(d) => DataMat::Dense(self.encode(d)),
            DataMat::Csr(c) => {
                assert_eq!(c.rows(), self.n, "encode: row mismatch");
                let ncols = c.cols();
                let mut buf = vec![0.0; self.n_out * ncols];
                for (i, (&pos, &sign)) in self.positions.iter().zip(&self.signs).enumerate() {
                    let dst = &mut buf[pos * ncols..(pos + 1) * ncols];
                    let (cols, vals) = c.row(i);
                    for (cc, vv) in cols.iter().zip(vals) {
                        dst[*cc as usize] = sign * vv;
                    }
                }
                fwht_columns(&mut buf, self.n_out, ncols);
                let scale = 1.0 / (self.n as f64).sqrt();
                for v in &mut buf {
                    *v *= scale;
                }
                DataMat::Dense(Mat::from_vec(self.n_out, ncols, buf))
            }
            // f32 shard variants never reach an encoder: encoding always
            // runs in f64 and shards are narrowed afterwards
            // (`EncodedProblem::encode_stored_prec`). Widen defensively.
            other => DataMat::Dense(self.encode(&other.to_dense())),
        }
    }

    fn materialize(&self) -> Mat {
        // S = encode(I): one FWHT per basis column — O(N^2 log N) total,
        // used only by spectrum analysis and tests.
        self.encode(&Mat::eye(self.n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn rows_out_is_power_of_two() {
        for &(n, beta) in &[(24usize, 2.0), (100, 2.0), (64, 2.0), (7, 3.0)] {
            let enc = HadamardEncoder::new(n, beta, 0);
            assert!(enc.rows_out().is_power_of_two());
            assert!(enc.rows_out() as f64 >= beta * n as f64);
            assert!(enc.beta() >= beta);
        }
    }

    #[test]
    fn tight_frame_exact() {
        let enc = HadamardEncoder::new(24, 2.0, 5);
        let g = enc.materialize().gram();
        let beta_eff = enc.beta(); // 64/24
        assert!(g.max_abs_diff(&Mat::eye(24).scaled(beta_eff)) < 1e-10);
    }

    #[test]
    fn encode_preserves_scaled_energy() {
        let mut rng = Pcg64::seeded(1);
        let x = Mat::from_fn(48, 3, |_, _| rng.next_gaussian());
        let enc = HadamardEncoder::new(48, 2.0, 2);
        let sx = enc.encode(&x);
        // ||Sx||^2 = beta_eff ||x||^2 per column (S^T S = beta I)
        for j in 0..3 {
            let e_in: f64 = x.col(j).iter().map(|v| v * v).sum();
            let e_out: f64 = sx.col(j).iter().map(|v| v * v).sum();
            assert!((e_out - enc.beta() * e_in).abs() < 1e-8 * e_out.max(1.0));
        }
    }

    #[test]
    fn sparse_encode_matches_dense() {
        use crate::linalg::{CsrMat, DataMat};
        let x = Mat::from_fn(32, 5, |i, j| {
            if (i + j) % 3 == 0 {
                1.0 + i as f64 + 10.0 * j as f64
            } else {
                0.0
            }
        });
        let enc = HadamardEncoder::new(32, 2.0, 9);
        let dense_out = enc.encode(&x);
        let sparse_out = enc.encode_data(&DataMat::Csr(CsrMat::from_dense(&x)));
        assert!(!sparse_out.is_sparse(), "transform output must be dense");
        // value-equal (the scatter skips zeros, so only the sign of exact
        // zeros may differ from the dense `sign * 0.0` writes)
        assert!(sparse_out.max_abs_diff(&DataMat::Dense(dense_out)) == 0.0);
        assert!(!enc.preserves_sparsity());
    }

    #[test]
    fn deterministic_in_seed() {
        let mut rng = Pcg64::seeded(3);
        let x = Mat::from_fn(16, 2, |_, _| rng.next_gaussian());
        let a = HadamardEncoder::new(16, 2.0, 7).encode(&x);
        let b = HadamardEncoder::new(16, 2.0, 7).encode(&x);
        let c = HadamardEncoder::new(16, 2.0, 8).encode(&x);
        assert!(a.max_abs_diff(&b) < 1e-15);
        assert!(a.max_abs_diff(&c) > 1e-6);
    }
}
