//! Real Fourier (DCT-II) ensemble encoder — the paper's second
//! fast-transform family ("FFT, if S is chosen as a subsampled DFT
//! matrix"). We use the orthonormal DCT-II as the real orthogonal
//! transform: `S = √(N/n) · C_N · D · E` with random row embedding `E`
//! and sign flips `D`, giving `SᵀS = (N/n)·I` exactly.
//!
//! Kept dense (O(N·n) apply) — this family exists for spectrum comparisons
//! and tests; the FWHT encoder is the fast path used in the experiments.

use super::Encoder;
use crate::linalg::Mat;
use crate::rng::Pcg64;

/// Orthonormal DCT-II ensemble encoder.
pub struct DftEncoder {
    n: usize,
    n_out: usize,
    s: Mat,
}

impl DftEncoder {
    /// Build for `n` input rows at target redundancy `beta` (rows are
    /// placed and sign-flipped pseudo-randomly from `seed`).
    pub fn new(n: usize, beta: f64, seed: u64) -> Self {
        let n_out = (beta * n as f64).round().max(n as f64) as usize;
        let mut rng = Pcg64::new(seed, 0xd347);
        let positions = rng.sample_indices(n_out, n);
        let signs: Vec<f64> = (0..n)
            .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect();
        // Orthonormal DCT-II: C[k][j] = a_k cos(pi (j + 1/2) k / N),
        // a_0 = sqrt(1/N), a_k = sqrt(2/N).
        let nf = n_out as f64;
        let scale = (n_out as f64 / n as f64).sqrt();
        let s = Mat::from_fn(n_out, n, |k, i| {
            let j = positions[i] as f64;
            let a = if k == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
            scale * signs[i] * a * (std::f64::consts::PI * (j + 0.5) * k as f64 / nf).cos()
        });
        DftEncoder { n, n_out, s }
    }
}

impl Encoder for DftEncoder {
    fn name(&self) -> &'static str {
        "dft"
    }

    fn rows_in(&self) -> usize {
        self.n
    }

    fn rows_out(&self) -> usize {
        self.n_out
    }

    fn encode(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        self.s.matmul(x)
    }

    fn materialize(&self) -> Mat {
        self.s.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tight_frame_exact() {
        let enc = DftEncoder::new(20, 2.0, 1);
        let g = enc.materialize().gram();
        assert!(g.max_abs_diff(&Mat::eye(20).scaled(2.0)) < 1e-10);
    }

    #[test]
    fn beta_effective() {
        let enc = DftEncoder::new(10, 2.5, 0);
        assert_eq!(enc.rows_out(), 25);
        assert!((enc.beta() - 2.5).abs() < 1e-12);
    }
}
