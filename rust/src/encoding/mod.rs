//! Encoding-matrix library — §4 "Code Design" of the paper.
//!
//! An [`Encoder`] owns a fixed encoding matrix `S ∈ R^{(βn)×n}` (implicitly
//! or explicitly) and applies it to data: `X̃ = S X`, `ỹ = S y`. The system
//! is *coding-oblivious* downstream — workers never see `S`.
//!
//! Normalization convention across every family: `SᵀS = β I` (tight-frame
//! scaling; exact for the ETFs, the fast transforms, and replication; in
//! expectation for Gaussian). Under this convention the first-k gradient
//! estimate `(1/(βηn)) X̃_Aᵀ(X̃_A w − ỹ_A)` is an unbiased-scale estimate of
//! `∇f`, and property (4) reads `λ(S_AᵀS_A/(βη)) ∈ [1−ε, 1+ε]` — which is
//! what [`spectrum`] measures for Figures 2–3.
//!
//! Families (paper → module):
//!
//! | paper §4            | here |
//! |---------------------|------|
//! | uncoded `S = I`     | [`identity`] |
//! | replication         | [`replication`] |
//! | i.i.d. Gaussian     | [`gaussian`] |
//! | fast transforms (FWHT randomized Hadamard) | [`hadamard`] |
//! | fast transforms (real DFT/DCT ensemble)    | [`dft`] |
//! | Paley ETF           | [`etf::paley`] |
//! | Hadamard ETF        | [`etf::hadamard_etf`] |
//! | Steiner ETF (App. D)| [`etf::steiner`] |

pub mod dft;
pub mod etf;
pub mod gaussian;
pub mod hadamard;
pub mod identity;
pub mod replication;
pub mod spectrum;
pub mod temporal;

use crate::linalg::{DataMat, Mat};
use anyhow::{bail, Result};

pub use spectrum::{normalized_gram_eigs, SpectrumStats};

/// A data-encoding operator `S ∈ R^{rows_out × rows_in}` with `SᵀS = β I`.
pub trait Encoder: Send + Sync {
    /// Human-readable family name (used by the CLI / bench tables).
    fn name(&self) -> &'static str;

    /// Input (raw data) row count `n`.
    fn rows_in(&self) -> usize;

    /// Output (encoded) row count `βn` (after any padding the family needs).
    fn rows_out(&self) -> usize;

    /// Effective redundancy factor `β = rows_out / rows_in`.
    fn beta(&self) -> f64 {
        self.rows_out() as f64 / self.rows_in() as f64
    }

    /// Apply `S` to an `n × p` matrix (columns encoded independently).
    fn encode(&self, x: &Mat) -> Mat {
        // default: dense multiply; fast-transform families override
        self.materialize().matmul(x)
    }

    /// Apply `S` to a matrix in either storage backend. The default
    /// densifies once and encodes (correct for every family — transforms
    /// and random ensembles produce dense rows regardless); families that
    /// preserve sparsity ([`identity`]) or consume sparse input without a
    /// dense intermediate ([`hadamard`]'s FWHT scatter) override this.
    fn encode_data(&self, x: &DataMat) -> DataMat {
        match x {
            DataMat::Dense(d) => DataMat::Dense(self.encode(d)),
            _ => DataMat::Dense(self.encode(&x.to_dense())),
        }
    }

    /// Whether `S·X` of a sparse `X` stays sparse (row-selection-like
    /// families only: identity here, replication/gradient-coding at the
    /// partitioner). Gates `--storage sparse`: requesting CSR shards from
    /// a densifying family is a hard error, not a silent densify.
    fn preserves_sparsity(&self) -> bool {
        false
    }

    /// Dense `S` (spectrum analysis, tests). May be expensive.
    fn materialize(&self) -> Mat;

    /// The exact (or expected) multiple `c` with `SᵀS = c·I`.
    ///
    /// Equals [`Encoder::beta`] for row-homogeneous families, but differs
    /// for ETFs built from a larger bank and column-subsampled (the
    /// paper's §5 bank approach): a column subset of a tight frame stays
    /// tight at the *construction* scale (e.g. 2 for Paley), while the
    /// effective redundancy `rows_out/rows_in` is slightly larger. The
    /// optimizer's gradient normalization must divide by this, not β.
    fn gram_scale(&self) -> f64 {
        self.beta()
    }

    /// Whether `k = m` recovers the *exact* original optimum (true for
    /// tight frames / replication / identity; false for Gaussian — §4).
    fn exact_at_full_participation(&self) -> bool {
        true
    }
}

/// Encoder family selector (CLI/config surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EncoderKind {
    /// Uncoded baseline, `S = I` (β forced to 1).
    Identity,
    /// Partition replication (integer β).
    Replication,
    /// i.i.d. `N(0, 1/n)` entries.
    Gaussian,
    /// Randomized subsampled Hadamard via FWHT (fast transform).
    Hadamard,
    /// Real DFT (orthonormal DCT-II) ensemble (fast transform family).
    Dft,
    /// Paley conference-matrix ETF (β ≈ 2).
    PaleyEtf,
    /// Sylvester-Hadamard projection ETF (β ≈ 2).
    HadamardEtf,
    /// Steiner ETF, Appendix D construction (β ≈ 2, block-sparse, FWHT-fast).
    SteinerEtf,
}

impl EncoderKind {
    /// All families, in the order the paper's tables list them.
    pub const ALL: [EncoderKind; 8] = [
        EncoderKind::Identity,
        EncoderKind::Replication,
        EncoderKind::Gaussian,
        EncoderKind::Hadamard,
        EncoderKind::Dft,
        EncoderKind::PaleyEtf,
        EncoderKind::HadamardEtf,
        EncoderKind::SteinerEtf,
    ];

    /// Parse a CLI name (accepts the aliases listed per arm).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "identity" | "uncoded" | "none" => EncoderKind::Identity,
            "replication" | "repl" => EncoderKind::Replication,
            "gaussian" | "gauss" => EncoderKind::Gaussian,
            "hadamard" | "fwht" => EncoderKind::Hadamard,
            "dft" | "dct" | "fourier" => EncoderKind::Dft,
            "paley" | "paley-etf" => EncoderKind::PaleyEtf,
            "hadamard-etf" | "hetf" => EncoderKind::HadamardEtf,
            "steiner" | "steiner-etf" => EncoderKind::SteinerEtf,
            other => bail!("unknown encoder kind: {other:?}"),
        })
    }

    /// Canonical CLI/table label for this family.
    pub fn label(&self) -> &'static str {
        match self {
            EncoderKind::Identity => "uncoded",
            EncoderKind::Replication => "replication",
            EncoderKind::Gaussian => "gaussian",
            EncoderKind::Hadamard => "hadamard",
            EncoderKind::Dft => "dft",
            EncoderKind::PaleyEtf => "paley",
            EncoderKind::HadamardEtf => "hadamard-etf",
            EncoderKind::SteinerEtf => "steiner",
        }
    }

    /// Build an encoder for `n` input rows with target redundancy `beta`.
    ///
    /// Families with structural constraints round `βn` up (Hadamard: next
    /// power of two; ETFs: next valid construction size) — check
    /// [`Encoder::beta`] for the effective factor. `seed` drives any
    /// randomization (Gaussian entries, row placement, shuffles).
    pub fn build(&self, n: usize, beta: f64, seed: u64) -> Result<Box<dyn Encoder>> {
        if n == 0 {
            bail!("encoder needs at least one input row");
        }
        if beta < 1.0 {
            bail!("redundancy beta must be >= 1, got {beta}");
        }
        Ok(match self {
            EncoderKind::Identity => Box::new(identity::IdentityEncoder::new(n)),
            EncoderKind::Replication => {
                Box::new(replication::ReplicationEncoder::new(n, beta.round() as usize)?)
            }
            EncoderKind::Gaussian => Box::new(gaussian::GaussianEncoder::new(n, beta, seed)),
            EncoderKind::Hadamard => Box::new(hadamard::HadamardEncoder::new(n, beta, seed)),
            EncoderKind::Dft => Box::new(dft::DftEncoder::new(n, beta, seed)),
            EncoderKind::PaleyEtf => Box::new(etf::paley::PaleyEtfEncoder::new(n, seed)?),
            EncoderKind::HadamardEtf => Box::new(etf::hadamard_etf::HadamardEtfEncoder::new(n, seed)),
            EncoderKind::SteinerEtf => Box::new(etf::steiner::SteinerEtfEncoder::new(n, seed)?),
        })
    }
}

impl std::fmt::Display for EncoderKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Shared conformance check: SᵀS ≈ β I and encode() ≡ materialize()·X.
    fn conformance(kind: EncoderKind, n: usize, beta: f64, tol_tight: f64) {
        let enc = kind.build(n, beta, 7).unwrap();
        assert_eq!(enc.rows_in(), n);
        let s = enc.materialize();
        assert_eq!(s.rows(), enc.rows_out());
        assert_eq!(s.cols(), n);
        // S^T S ≈ gram_scale · I (construction tightness)
        let gram = s.gram();
        let c = enc.gram_scale();
        let target = Mat::eye(n).scaled(c);
        let err = gram.max_abs_diff(&target);
        assert!(
            err < tol_tight * c,
            "{kind}: ||S^T S - c I||_max = {err:.4} (gram_scale={c:.3})"
        );
        assert!(enc.beta() >= 1.0 && enc.beta() + 1e-9 >= c * 0.99,
            "{kind}: beta {} vs gram_scale {c}", enc.beta());
        // encode agrees with dense multiply
        let mut rng = Pcg64::seeded(3);
        let x = Mat::from_fn(n, 3, |_, _| rng.next_gaussian());
        let direct = s.matmul(&x);
        let fast = enc.encode(&x);
        assert!(
            fast.max_abs_diff(&direct) < 1e-8,
            "{kind}: encode() disagrees with materialize()@X"
        );
    }

    #[test]
    fn identity_conformance() {
        conformance(EncoderKind::Identity, 24, 1.0, 1e-12);
    }

    #[test]
    fn replication_conformance() {
        conformance(EncoderKind::Replication, 24, 2.0, 1e-12);
    }

    #[test]
    fn gaussian_conformance_loose() {
        // Gaussian is tight only in expectation — allow loose tolerance.
        conformance(EncoderKind::Gaussian, 32, 8.0, 0.45);
    }

    #[test]
    fn hadamard_conformance() {
        conformance(EncoderKind::Hadamard, 24, 2.0, 1e-9);
    }

    #[test]
    fn dft_conformance() {
        conformance(EncoderKind::Dft, 20, 2.0, 1e-9);
    }

    #[test]
    fn paley_conformance() {
        conformance(EncoderKind::PaleyEtf, 24, 2.0, 1e-6);
    }

    #[test]
    fn hadamard_etf_conformance() {
        conformance(EncoderKind::HadamardEtf, 24, 2.0, 1e-6);
    }

    #[test]
    fn steiner_conformance() {
        conformance(EncoderKind::SteinerEtf, 24, 2.0, 1e-9);
    }

    #[test]
    fn encode_data_default_densifies_sparse_input() {
        use crate::linalg::{CsrMat, DataMat};
        let enc = EncoderKind::Gaussian.build(16, 2.0, 1).unwrap();
        let x = Mat::from_fn(16, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        let sparse = DataMat::Csr(CsrMat::from_dense(&x));
        let out = enc.encode_data(&sparse);
        assert!(!out.is_sparse(), "random ensembles densify");
        assert!(out.to_dense().max_abs_diff(&enc.encode(&x)) < 1e-12);
        assert!(!enc.preserves_sparsity());
        assert!(EncoderKind::Identity.build(16, 1.0, 0).unwrap().preserves_sparsity());
    }

    #[test]
    fn parse_roundtrip() {
        for kind in EncoderKind::ALL {
            assert_eq!(EncoderKind::parse(kind.label()).unwrap(), kind);
        }
        assert!(EncoderKind::parse("nope").is_err());
    }

    #[test]
    fn build_rejects_bad_args() {
        assert!(EncoderKind::Gaussian.build(0, 2.0, 0).is_err());
        assert!(EncoderKind::Gaussian.build(8, 0.5, 0).is_err());
    }

    #[test]
    fn exactness_flags() {
        assert!(EncoderKind::Hadamard.build(16, 2.0, 0).unwrap().exact_at_full_participation());
        assert!(!EncoderKind::Gaussian.build(16, 2.0, 0).unwrap().exact_at_full_participation());
    }
}
