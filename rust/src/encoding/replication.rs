//! Replication baseline: each row stored `β` times (integer β).
//!
//! `S = [Iₙ; Iₙ; …]` — `SᵀS = βI` exactly. The *scheme* semantics (leader
//! keeps the fastest arriving copy of each partition, §5) live in the
//! coordinator's gather policy; this encoder just realizes the storage
//! layout. Partition-aware placement (copies of the same partition on
//! different workers) is handled by the partitioner in `problem/`.

use super::Encoder;
use crate::linalg::Mat;
use anyhow::{ensure, Result};

/// β-fold row replication.
#[derive(Debug, Clone)]
pub struct ReplicationEncoder {
    n: usize,
    beta: usize,
}

impl ReplicationEncoder {
    /// `beta`-fold replication of `n` rows (integer redundancy).
    pub fn new(n: usize, beta: usize) -> Result<Self> {
        ensure!(beta >= 1, "replication factor must be >= 1, got {beta}");
        Ok(ReplicationEncoder { n, beta })
    }
}

impl Encoder for ReplicationEncoder {
    fn name(&self) -> &'static str {
        "replication"
    }

    fn rows_in(&self) -> usize {
        self.n
    }

    fn rows_out(&self) -> usize {
        self.n * self.beta
    }

    fn encode(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        let blocks: Vec<&Mat> = std::iter::repeat(x).take(self.beta).collect();
        Mat::vstack(&blocks)
    }

    fn materialize(&self) -> Mat {
        let eye = Mat::eye(self.n);
        let blocks: Vec<&Mat> = std::iter::repeat(&eye).take(self.beta).collect();
        Mat::vstack(&blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn stacks_beta_copies() {
        let mut rng = Pcg64::seeded(0);
        let x = Mat::from_fn(6, 2, |_, _| rng.next_gaussian());
        let enc = ReplicationEncoder::new(6, 3).unwrap();
        let sx = enc.encode(&x);
        assert_eq!(sx.rows(), 18);
        for c in 0..3 {
            assert!(sx.row_band(c * 6, (c + 1) * 6).max_abs_diff(&x) < 1e-15);
        }
    }

    #[test]
    fn gram_is_beta_identity() {
        let enc = ReplicationEncoder::new(5, 4).unwrap();
        let g = enc.materialize().gram();
        assert!(g.max_abs_diff(&Mat::eye(5).scaled(4.0)) < 1e-12);
    }

    #[test]
    fn rejects_zero_beta() {
        assert!(ReplicationEncoder::new(5, 0).is_err());
    }
}
