//! Uncoded baseline: `S = I` (the paper's "uncoded" scheme).

use super::Encoder;
use crate::linalg::{DataMat, Mat};

/// `S = I_n`. With first-k gather this degenerates to plain sub-sampled
/// distributed gradient descent — the baseline the paper shows failing to
/// converge at small η (Fig. 4).
#[derive(Debug, Clone)]
pub struct IdentityEncoder {
    n: usize,
}

impl IdentityEncoder {
    /// The `n x n` identity (uncoded baseline).
    pub fn new(n: usize) -> Self {
        IdentityEncoder { n }
    }
}

impl Encoder for IdentityEncoder {
    fn name(&self) -> &'static str {
        "uncoded"
    }

    fn rows_in(&self) -> usize {
        self.n
    }

    fn rows_out(&self) -> usize {
        self.n
    }

    fn encode(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        x.clone()
    }

    fn encode_data(&self, x: &DataMat) -> DataMat {
        assert_eq!(x.rows(), self.n, "encode: row mismatch");
        x.clone() // sparse in, sparse out — S = I preserves storage
    }

    fn preserves_sparsity(&self) -> bool {
        true
    }

    fn materialize(&self) -> Mat {
        Mat::eye(self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn encode_is_identity() {
        let mut rng = Pcg64::seeded(0);
        let x = Mat::from_fn(10, 4, |_, _| rng.next_gaussian());
        let enc = IdentityEncoder::new(10);
        assert!(enc.encode(&x).max_abs_diff(&x) < 1e-15);
        assert_eq!(enc.beta(), 1.0);
    }
}
