//! Temporal gradient-coding encoders — coding *across* rounds.
//!
//! The paper's families in the sibling modules amortize redundancy
//! *within* one round: every worker's shard mixes many raw rows, and any
//! k of m responses recover the full gradient. The temporal schemes here
//! take the complementary view of Tandon et al.'s gradient coding: keep
//! raw rows intact (so per-round worker cost is a plain partial
//! gradient) and place the redundancy across a *window* of rounds, so
//! that stragglers who miss a bounded burst of consecutive rounds are
//! covered by a buddy's backup copy.
//!
//! Our round loop is synchronous first-k, so the window structure is
//! realized spatially — each worker's home block is split into `W`
//! per-round slots and the first `B` slots are mirrored on a buddy —
//! and the across-round story lives in [`runtime::temporal`]'s
//! pipelined stepper, which keeps up to `depth` rounds' straggler tails
//! in flight over these layouts.
//!
//! Two schemes, both row-selection codes (every output row is a scaled
//! copy of exactly one raw row):
//!
//! * [`SequentialGradientCoding`] (`--scheme seq:W:B`): deterministic.
//!   Worker `i`'s home block is split into `W` slots; slots `0..B` are
//!   backed on buddy `(i + 1 + j) mod m` with weight `1/√2` on both
//!   copies, the rest carry weight 1. Squared weights per raw row sum
//!   to 1, so `SᵀS = I` exactly — a unit-tight frame with redundancy
//!   `β ≈ 1 + B/W` — and full participation is exact.
//! * [`StochasticGradientCoding`] (`--scheme stoch:Q`): probabilistic.
//!   Every raw row sits on its home worker with weight 1 and, with
//!   probability `q`, on a uniformly random buddy with weight 1.
//!   `SᵀS = diag(1 + dup)` — identity only in expectation after the
//!   scheme-aware `1/(gram_scale·η·n)` normalization — so recovery is
//!   approximate even at full participation (mirroring the paper's
//!   Gaussian caveat).
//!
//! [`runtime::temporal`]: crate::runtime::temporal

use super::spectrum::partition_rows;
use super::Encoder;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use anyhow::{bail, ensure, Result};
use std::f64::consts::FRAC_1_SQRT_2;

/// Temporal-coding scheme selector (CLI grammar `none | seq:W:B | stoch:Q`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TemporalScheme {
    /// No temporal coding — within-round encoding only (the default).
    None,
    /// Sequential gradient coding: `W`-round windows, `B`-burst tolerance.
    Seq { window: usize, burst: usize },
    /// Stochastic gradient coding: pair-wise backup with probability `q`.
    Stoch { q: f64 },
}

impl TemporalScheme {
    /// Parse the CLI grammar `none | seq:W:B | stoch:Q`.
    pub fn parse(s: &str) -> Result<Self> {
        let lower = s.to_ascii_lowercase();
        if lower == "none" {
            return Ok(TemporalScheme::None);
        }
        let mut parts = lower.split(':');
        match parts.next() {
            Some("seq") => {
                let window: usize = parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("seq scheme needs a window: seq:W:B"))?
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad seq window in {s:?}"))?;
                let burst: usize = parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("seq scheme needs a burst: seq:W:B"))?
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad seq burst in {s:?}"))?;
                ensure!(parts.next().is_none(), "trailing fields in scheme {s:?}");
                ensure!(window >= 1, "seq window must be >= 1, got {window}");
                ensure!(
                    (1..=window).contains(&burst),
                    "seq burst must be in 1..=window, got {burst} (window {window})"
                );
                Ok(TemporalScheme::Seq { window, burst })
            }
            Some("stoch") => {
                let q: f64 = parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("stoch scheme needs a probability: stoch:Q"))?
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad stoch probability in {s:?}"))?;
                ensure!(parts.next().is_none(), "trailing fields in scheme {s:?}");
                ensure!(
                    q > 0.0 && q <= 1.0 && q.is_finite(),
                    "stoch probability must be in (0, 1], got {q}"
                );
                Ok(TemporalScheme::Stoch { q })
            }
            _ => bail!("unknown temporal scheme {s:?} (expected none | seq:W:B | stoch:Q)"),
        }
    }
}

impl std::fmt::Display for TemporalScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TemporalScheme::None => f.write_str("none"),
            TemporalScheme::Seq { window, burst } => write!(f, "seq:{window}:{burst}"),
            TemporalScheme::Stoch { q } => write!(f, "stoch:{q}"),
        }
    }
}

/// Shared body of the two temporal codes: a worker-grouped row-selection
/// operator. Output row `r` is `taps[r].1 ×` raw row `taps[r].0`;
/// `boundaries[i]` is worker `i`'s half-open output-row range.
struct TapCode {
    n: usize,
    taps: Vec<(usize, f64)>,
    boundaries: Vec<(usize, usize)>,
}

/// One worker's output rows during construction: home copies first (raw
/// row order), then backup copies it hosts for others (raw row order).
#[derive(Default)]
struct WorkerRows {
    home: Vec<(usize, f64)>,
    backup: Vec<(usize, f64)>,
}

impl TapCode {
    fn assemble(n: usize, per_worker: Vec<WorkerRows>) -> TapCode {
        let mut taps = Vec::new();
        let mut boundaries = Vec::with_capacity(per_worker.len());
        for mut w in per_worker {
            let lo = taps.len();
            taps.append(&mut w.home);
            taps.append(&mut w.backup);
            boundaries.push((lo, taps.len()));
        }
        TapCode { n, taps, boundaries }
    }

    fn encode(&self, x: &Mat) -> Mat {
        Mat::from_fn(self.taps.len(), x.cols(), |r, c| {
            let (src, wgt) = self.taps[r];
            wgt * x.get(src, c)
        })
    }

    fn materialize(&self) -> Mat {
        let mut s = Mat::zeros(self.taps.len(), self.n);
        for (r, &(src, wgt)) in self.taps.iter().enumerate() {
            s.set(r, src, wgt);
        }
        s
    }
}

/// Sequential gradient coding (`seq:W:B`) — see the module docs.
///
/// Constraints: `1 ≤ B ≤ W`, `m ≥ B + 1` (every backed slot needs a
/// buddy distinct from its home), and `n ≥ m·W` (every per-round slot
/// non-empty).
pub struct SequentialGradientCoding {
    code: TapCode,
    window: usize,
    burst: usize,
}

impl SequentialGradientCoding {
    /// Build for `n` raw rows across `m` workers.
    pub fn new(n: usize, m: usize, window: usize, burst: usize) -> Result<Self> {
        ensure!(window >= 1, "seq window must be >= 1, got {window}");
        ensure!(
            (1..=window).contains(&burst),
            "seq burst must be in 1..=window, got {burst} (window {window})"
        );
        ensure!(
            m >= burst + 1,
            "seq:{window}:{burst} needs at least {} workers, got {m}",
            burst + 1
        );
        ensure!(
            n >= m * window,
            "seq:{window}:{burst} needs n >= m*W = {} rows, got {n}",
            m * window
        );
        let home = partition_rows(n, m);
        let mut per_worker: Vec<WorkerRows> = (0..m).map(|_| WorkerRows::default()).collect();
        for (i, &(lo, hi)) in home.iter().enumerate() {
            let slots = partition_rows(hi - lo, window);
            for (j, &(slo, shi)) in slots.iter().enumerate() {
                let backed = j < burst;
                let wgt = if backed { FRAC_1_SQRT_2 } else { 1.0 };
                for r in lo + slo..lo + shi {
                    per_worker[i].home.push((r, wgt));
                    if backed {
                        let buddy = (i + 1 + j) % m;
                        per_worker[buddy].backup.push((r, FRAC_1_SQRT_2));
                    }
                }
            }
        }
        for w in &mut per_worker {
            w.backup.sort_unstable_by_key(|&(src, _)| src);
        }
        let code = TapCode::assemble(n, per_worker);
        Ok(SequentialGradientCoding { code, window, burst })
    }

    /// Half-open output-row ranges, one per worker, in worker order.
    /// The problem constructor shards exactly at these boundaries.
    pub fn worker_boundaries(&self) -> &[(usize, usize)] {
        &self.code.boundaries
    }

    /// Window length `W` (rounds per coding window).
    pub fn window(&self) -> usize {
        self.window
    }

    /// Burst tolerance `B` (consecutive missed rounds covered).
    pub fn burst(&self) -> usize {
        self.burst
    }
}

impl Encoder for SequentialGradientCoding {
    fn name(&self) -> &'static str {
        "seq-gc"
    }

    fn rows_in(&self) -> usize {
        self.code.n
    }

    fn rows_out(&self) -> usize {
        self.code.taps.len()
    }

    fn encode(&self, x: &Mat) -> Mat {
        self.code.encode(x)
    }

    fn materialize(&self) -> Mat {
        self.code.materialize()
    }

    /// Unit-tight by construction: each raw row's squared weights sum to
    /// `(1/√2)² + (1/√2)² = 1` (backed) or `1²` (unbacked), so `SᵀS = I`.
    fn gram_scale(&self) -> f64 {
        1.0
    }
}

/// Stochastic gradient coding (`stoch:Q`) — see the module docs.
///
/// Constraints: `m ≥ 2` (a buddy must differ from the home worker),
/// `n ≥ m`, `q ∈ (0, 1]`.
pub struct StochasticGradientCoding {
    code: TapCode,
    q: f64,
}

impl StochasticGradientCoding {
    /// Build for `n` raw rows across `m` workers; `seed` fixes the
    /// backup draws (rows are visited in raw order, one `u64` for the
    /// coin and one for the buddy — reproducible across runs).
    pub fn new(n: usize, m: usize, q: f64, seed: u64) -> Result<Self> {
        ensure!(m >= 2, "stoch coding needs at least 2 workers, got {m}");
        ensure!(n >= m, "stoch coding needs n >= m, got n={n} m={m}");
        ensure!(
            q > 0.0 && q <= 1.0 && q.is_finite(),
            "stoch probability must be in (0, 1], got {q}"
        );
        let home = partition_rows(n, m);
        let mut rng = Pcg64::new(seed, 0x7e4d_0a11);
        let mut per_worker: Vec<WorkerRows> = (0..m).map(|_| WorkerRows::default()).collect();
        for (i, &(lo, hi)) in home.iter().enumerate() {
            for r in lo..hi {
                per_worker[i].home.push((r, 1.0));
                if rng.next_f64() < q {
                    // uniform over the m-1 workers that are not the home
                    let draw = rng.next_below(m as u64 - 1) as usize;
                    let buddy = if draw >= i { draw + 1 } else { draw };
                    per_worker[buddy].backup.push((r, 1.0));
                }
            }
        }
        for w in &mut per_worker {
            w.backup.sort_unstable_by_key(|&(src, _)| src);
        }
        let code = TapCode::assemble(n, per_worker);
        Ok(StochasticGradientCoding { code, q })
    }

    /// Half-open output-row ranges, one per worker, in worker order.
    pub fn worker_boundaries(&self) -> &[(usize, usize)] {
        &self.code.boundaries
    }

    /// Backup probability `q`.
    pub fn q(&self) -> f64 {
        self.q
    }
}

impl Encoder for StochasticGradientCoding {
    fn name(&self) -> &'static str {
        "stoch-gc"
    }

    fn rows_in(&self) -> usize {
        self.code.n
    }

    fn rows_out(&self) -> usize {
        self.code.taps.len()
    }

    fn encode(&self, x: &Mat) -> Mat {
        self.code.encode(x)
    }

    fn materialize(&self) -> Mat {
        self.code.materialize()
    }

    // gram_scale: default (= realized β = rows_out/n). SᵀS is diagonal
    // with entries in {1, 2}; dividing by the realized average makes the
    // first-k estimate unbiased in expectation over the backup draws.

    /// `SᵀS ≠ c·I` row-wise, so even k = m recovery is approximate.
    fn exact_at_full_participation(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_grammar_round_trips() {
        for s in ["none", "seq:4:1", "seq:6:3", "stoch:0.25", "stoch:1"] {
            let parsed = TemporalScheme::parse(s).unwrap();
            assert_eq!(TemporalScheme::parse(&parsed.to_string()).unwrap(), parsed, "{s}");
        }
        assert_eq!(TemporalScheme::parse("NONE").unwrap(), TemporalScheme::None);
        assert_eq!(
            TemporalScheme::parse("seq:4:2").unwrap(),
            TemporalScheme::Seq { window: 4, burst: 2 }
        );
        assert_eq!(TemporalScheme::parse("stoch:0.5").unwrap(), TemporalScheme::Stoch { q: 0.5 });
    }

    #[test]
    fn scheme_grammar_rejects_malformed() {
        for s in [
            "", "seq", "seq:4", "seq:4:0", "seq:2:3", "seq:0:0", "seq:4:1:9", "seq:x:1",
            "stoch", "stoch:0", "stoch:1.5", "stoch:-0.1", "stoch:nan", "stoch:0.5:2", "burst:3",
        ] {
            assert!(TemporalScheme::parse(s).is_err(), "accepted malformed scheme {s:?}");
        }
    }

    #[test]
    fn seq_is_a_unit_tight_frame() {
        let enc = SequentialGradientCoding::new(48, 6, 4, 2).unwrap();
        let s = enc.materialize();
        assert_eq!(s.rows(), enc.rows_out());
        assert_eq!(s.cols(), 48);
        let gram = s.gram();
        let err = gram.max_abs_diff(&Mat::eye(48));
        assert!(err < 1e-12, "seq gram deviates from I by {err}");
        assert_eq!(enc.gram_scale(), 1.0);
        assert!(enc.exact_at_full_participation());
        // β = 1 + B/W when W divides every home block evenly (48/6 = 8 rows, W=4)
        assert!((enc.beta() - 1.5).abs() < 1e-12, "beta {}", enc.beta());
    }

    #[test]
    fn seq_encode_matches_materialized_multiply() {
        let enc = SequentialGradientCoding::new(40, 5, 4, 1).unwrap();
        let mut rng = Pcg64::seeded(3);
        let x = Mat::from_fn(40, 3, |_, _| rng.next_gaussian());
        let err = enc.encode(&x).max_abs_diff(&enc.materialize().matmul(&x));
        assert!(err < 1e-14, "encode disagrees with S@X by {err}");
    }

    #[test]
    fn seq_boundaries_cover_all_output_rows_and_buddies_differ() {
        let (n, m, window, burst) = (50, 7, 3, 2);
        let enc = SequentialGradientCoding::new(n, m, window, burst).unwrap();
        let b = enc.worker_boundaries();
        assert_eq!(b.len(), m);
        assert_eq!(b[0].0, 0);
        assert_eq!(b[m - 1].1, enc.rows_out());
        for w in b.windows(2) {
            assert_eq!(w[0].1, w[1].0, "worker ranges must tile the output");
        }
        // every backed raw row appears on exactly two distinct workers
        let home = partition_rows(n, m);
        let s = enc.materialize();
        for src in 0..n {
            let holders: Vec<usize> = (0..m)
                .filter(|&i| (b[i].0..b[i].1).any(|r| s.get(r, src) != 0.0))
                .collect();
            let home_w = home.iter().position(|&(lo, hi)| (lo..hi).contains(&src)).unwrap();
            assert!(holders.contains(&home_w), "row {src} missing from home worker");
            assert!(holders.len() <= 2, "row {src} on {} workers", holders.len());
        }
    }

    #[test]
    fn seq_rejects_bad_geometry() {
        assert!(SequentialGradientCoding::new(48, 6, 4, 0).is_err());
        assert!(SequentialGradientCoding::new(48, 6, 2, 3).is_err());
        assert!(SequentialGradientCoding::new(48, 2, 4, 2).is_err()); // m < B+1
        assert!(SequentialGradientCoding::new(10, 6, 4, 1).is_err()); // n < m*W
    }

    #[test]
    fn stoch_gram_is_diagonal_with_unit_or_double_entries() {
        let enc = StochasticGradientCoding::new(40, 5, 0.5, 11).unwrap();
        let s = enc.materialize();
        let gram = s.gram();
        for i in 0..40 {
            for j in 0..40 {
                let g = gram.get(i, j);
                if i == j {
                    assert!(g == 1.0 || g == 2.0, "diag {i} = {g}");
                } else {
                    assert_eq!(g, 0.0, "off-diag ({i},{j}) = {g}");
                }
            }
        }
        // gram_scale is the realized average duplication
        let trace: f64 = (0..40).map(|i| gram.get(i, i)).sum();
        assert!((enc.gram_scale() - trace / 40.0).abs() < 1e-12);
        assert!(!enc.exact_at_full_participation());
    }

    #[test]
    fn stoch_is_seeded_and_q_one_backs_every_row() {
        let a = StochasticGradientCoding::new(30, 4, 0.3, 9).unwrap();
        let b = StochasticGradientCoding::new(30, 4, 0.3, 9).unwrap();
        assert_eq!(a.rows_out(), b.rows_out());
        assert!(a.materialize().max_abs_diff(&b.materialize()) == 0.0, "same seed, same code");
        let c = StochasticGradientCoding::new(30, 4, 0.3, 10).unwrap();
        let differs =
            a.rows_out() != c.rows_out() || a.materialize().max_abs_diff(&c.materialize()) > 0.0;
        assert!(differs, "different seeds must draw different codes");
        let full = StochasticGradientCoding::new(30, 4, 1.0, 1).unwrap();
        assert_eq!(full.rows_out(), 60, "q = 1 duplicates every row");
        assert!((full.beta() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stoch_rejects_bad_args() {
        assert!(StochasticGradientCoding::new(30, 1, 0.5, 0).is_err());
        assert!(StochasticGradientCoding::new(2, 4, 0.5, 0).is_err());
        assert!(StochasticGradientCoding::new(30, 4, 0.0, 0).is_err());
        assert!(StochasticGradientCoding::new(30, 4, 1.5, 0).is_err());
    }
}
